//! Fig. 1 (render rows): execution run-time with per-step rendering.
//!
//! CaiRL side: native env + software rasteriser into a reused
//! framebuffer (the paper's §II-B recommendation).  Gym side: the
//! interpreted env + the calibrated hardware-render cost model (OpenGL
//! draw + PBO-less readback stall; DESIGN.md §Substitutions — this image
//! has no GPU).  Expected shape: software rendering wins by roughly an
//! order of magnitude more than the console gap (paper: ~80x).
//!
//! Full protocol: `CAIRL_TRIALS=100 CAIRL_STEPS=100000 cargo bench --bench fig1_render`
//! (defaults are lighter because the simulated readback stall is real
//! wall-clock time).

#[path = "harness/mod.rs"]
mod harness;

use cairl::coordinator::experiment::{stepping_trials, RenderMode};
use cairl::make;
use cairl::tooling::stats::Summary;
use harness::*;

fn main() {
    let trials = knob_q("CAIRL_TRIALS", 5, 2) as u32;
    let steps = knob_q("CAIRL_STEPS", 3_000, 600);
    banner(&format!(
        "Fig. 1 / render — {steps} steps x {trials} trials (paper: 100000 x 100)"
    ));

    let pairs = [
        ("CartPole-v1", "Script/CartPole-v1"),
        ("MountainCar-v0", "Script/MountainCar-v0"),
        ("Acrobot-v1", "Script/Acrobot-v1"),
        ("PendulumDiscrete-v1", "Script/Pendulum-v1"),
    ];

    let mut log = comparison_csv("fig1_render");
    let mut speedups = Vec::new();
    for (native_id, script_id) in pairs {
        // CaiRL: native stepping + software rendering.
        let cairl_times = stepping_trials(
            &|| make(native_id).unwrap(),
            trials,
            steps,
            0,
            RenderMode::Software,
        );
        // Gym: interpreted stepping + hardware render/readback model.
        let gym_times = stepping_trials(
            &|| make(script_id).unwrap(),
            trials,
            steps,
            0,
            RenderMode::SimulatedHardware,
        );
        let c = Summary::of(&cairl_times);
        let b = Summary::of(&gym_times);
        let s = report_pair(native_id, &c, &b);
        log_pair(&mut log, native_id, &c, &b, trials as u64, steps);
        speedups.push(s);
    }
    log.flush().unwrap();

    let mean_speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("\nmean speedup {mean_speedup:.1}x (paper Fig. 1 render: ~80x)");
    println!("rows -> results/fig1_render.csv");
    assert!(
        speedups.iter().all(|&s| s > 20.0),
        "render speedup collapsed below the paper band: {speedups:?}"
    );
}
