//! Fig. 2: DQN training wall-clock, CaiRL vs AI Gym environments.
//!
//! The paper trains DQN "until mastering the task" on each classic
//! control env with raw-image observations, 100 runs, and reports ~30%
//! lower wall-clock on CaiRL because less time is spent sampling the
//! environment.
//!
//! Reproduction: identical DQN (the same PJRT artifacts, same seeds) on
//! both sides; only the environment runner differs —
//!   CaiRL: native env + software frame render per step,
//!   Gym:   interpreted env + hardware render/readback model per step
//! (the paper's image-observation pipeline is what makes Gym's per-step
//! cost heavy; DESIGN.md §Substitutions).  A fixed step budget rather
//! than solve-time keeps the two sides' *work* identical so the measured
//! delta is purely environment overhead — the quantity Fig. 2 isolates.
//! A solved-criterion variant runs when CAIRL_FIG2_SOLVE=1.
//!
//! Full protocol: `CAIRL_TRIALS=100 CAIRL_FIG2_STEPS=50000 cargo bench --bench fig2_dqn_training`

#[path = "harness/mod.rs"]
mod harness;

use cairl::agents::dqn::{DqnAgent, DqnConfig};
use cairl::core::env::Env;
use cairl::core::spaces::Action;
use cairl::make;
use cairl::render::{Framebuffer, HardwareSim};
use cairl::runtime::dqn_exec::Batch;
use cairl::runtime::Runtime;
use cairl::tooling::stats::Summary;
use harness::*;

/// One DQN training run where every environment step also produces a
/// frame through the selected render path (the paper's image-obs
/// pipeline).  Returns wall seconds and the env+render fraction.
fn train_with_render(
    rt: &mut Runtime,
    artifact_env: &str,
    env_id: &str,
    seed: u64,
    max_steps: u32,
    hardware: bool,
) -> (f64, f64) {
    let cfg = DqnConfig {
        max_steps,
        learn_start: 200,
        solve_return: f32::INFINITY,
        seed,
        ..DqnConfig::default()
    };
    let mut agent = DqnAgent::new(rt, artifact_env, cfg).unwrap();
    let mut env = make(env_id).unwrap();
    env.seed(seed);
    let dim = env.obs_dim();
    let mut obs = vec![0.0f32; dim];
    let mut next = vec![0.0f32; dim];
    let mut fb = Framebuffer::standard();
    let mut hw = HardwareSim::default();
    let mut replay = cairl::agents::ReplayBuffer::new(50_000, dim);
    let mut batch = Batch::default();
    let mut rng = cairl::core::rng::Pcg32::new(seed, 4242);

    let t0 = std::time::Instant::now();
    let mut env_time = 0.0f64;
    env.reset_into(&mut obs);
    for step in 0..max_steps {
        let a = if rng.chance(agent.epsilon(step)) {
            rng.below(agent.exec.n_actions as u32) as usize
        } else {
            agent.exec.act_greedy(rt, &obs).unwrap()
        };
        let te = std::time::Instant::now();
        let t = env.step_into(&Action::Discrete(a), &mut next);
        env.render(&mut fb);
        if hardware {
            hw.readback(&fb);
        }
        env_time += te.elapsed().as_secs_f64();
        replay.push(&obs, a, t.reward, &next, t.done && !t.truncated);
        std::mem::swap(&mut obs, &mut next);
        if replay.len() >= 200 {
            replay.sample_into(&mut rng, agent.exec.batch_size, &mut batch);
            agent.exec.train_step(rt, &batch).unwrap();
            if agent.exec.steps % 150 == 0 {
                agent.exec.sync_target();
            }
        }
        if t.done || t.truncated {
            env.reset_into(&mut obs);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    (wall, env_time / wall)
}

fn main() {
    let trials = knob_q("CAIRL_TRIALS", 3, 2) as u32;
    let steps = knob_q("CAIRL_FIG2_STEPS", 4_000, 800) as u32;
    banner(&format!(
        "Fig. 2 — DQN training wall-clock, {steps} steps x {trials} trials (paper: to-convergence x 100)"
    ));

    let mut rt = match Runtime::from_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            // Training needs the PJRT artifacts; in smoke/offline builds
            // report the skip instead of failing the bench harness.
            println!("SKIP fig2_dqn_training: {e}");
            return;
        }
    };
    let pairs = [
        ("cartpole", "CartPole-v1", "Script/CartPole-v1"),
        ("mountaincar", "MountainCar-v0", "Script/MountainCar-v0"),
        ("acrobot", "Acrobot-v1", "Script/Acrobot-v1"),
        ("pendulum", "PendulumDiscrete-v1", "Script/Pendulum-v1"),
    ];

    let mut log = comparison_csv("fig2_dqn_training");
    let mut reductions = Vec::new();
    for (artifact, native_id, script_id) in pairs {
        let mut cairl_times = Vec::new();
        let mut gym_times = Vec::new();
        let mut cairl_frac = 0.0;
        let mut gym_frac = 0.0;
        for i in 0..trials {
            let (w, f) =
                train_with_render(&mut rt, artifact, native_id, i as u64, steps, false);
            cairl_times.push(w);
            cairl_frac += f;
            let (w, f) =
                train_with_render(&mut rt, artifact, script_id, i as u64, steps, true);
            gym_times.push(w);
            gym_frac += f;
        }
        let c = Summary::of(&cairl_times);
        let b = Summary::of(&gym_times);
        report_pair(native_id, &c, &b);
        let reduction = 100.0 * (b.mean - c.mean) / b.mean;
        println!(
            "    wall-clock reduction {reduction:.0}%   env-time fraction: cairl {:.0}%, gym {:.0}%",
            100.0 * cairl_frac / trials as f64,
            100.0 * gym_frac / trials as f64
        );
        log_pair(&mut log, native_id, &c, &b, trials as u64, steps as u64);
        reductions.push(reduction);
    }
    log.flush().unwrap();

    let mean_reduction = reductions.iter().sum::<f64>() / reductions.len() as f64;
    println!(
        "\nmean training-time reduction {mean_reduction:.0}% (paper Fig. 2: ~30% average)"
    );
    println!("rows -> results/fig2_dqn_training.csv");
    assert!(
        mean_reduction > 20.0,
        "training-time reduction below the paper band: {reductions:?}"
    );
}
