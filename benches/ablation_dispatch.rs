//! Ablation: compile-time wrapper composition (paper Listing 1) vs
//! dynamic dispatch vs the interpreted runner.
//!
//! The paper's §III-B design claim is that template (here: generic)
//! composition "evaluates much of the program logic during compile-time"
//! with "considerable run-time benefits".  This bench quantifies the
//! claim on the stack the paper names — `Flatten<TimeLimit<CartPole>>`:
//!
//!   static   — monomorphised generics, zero vtable calls
//!   dynamic  — the same stack behind Box<dyn Env> (registry-style)
//!   script   — the same dynamics on the interpreted runner
//!
//! Expected shape: static <= dynamic << script; the static-vs-dynamic gap
//! is small in absolute terms (a vtable call per step) while the
//! interpreter pays orders of magnitude — i.e. the language choice, not
//! the dispatch mechanism, carries Fig. 1.
//!
//! The scripting-tentpole rows re-run one MiniScript program
//! (`examples/bounce.mpy`) on all three script runners: the tree-walk
//! AST interpreter, the register-bytecode VM (target: >=5x over the
//! tree-walk), and the SoA `ScriptBatch` kernel where a single VM steps
//! a 32-lane group's state columns.
//!
//! The telemetry rows A/B the 32-lane fused pool with the process-wide
//! metrics gate on vs off and assert the observability tax stays under
//! 2% — the budget README §"Observability" promises.  The tracing rows
//! repeat the A/B with the span recorder (`cairl run --trace`) on vs
//! off under the same budget, and the roofline sweep steps every
//! classic-control fused kernel at lane widths 8..512 so the
//! `roofline` block in BENCH_ci.json tracks where each kernel stops
//! amortising per-batch overhead.

#[path = "harness/mod.rs"]
mod harness;

use cairl::core::env::{DynEnv, Env};
use cairl::core::rng::Pcg32;
use cairl::envs::CartPole;
use cairl::tooling::csvlog::CsvLogger;
use cairl::wrappers::{Flatten, TimeLimit};
use harness::*;

fn drive<E: Env + ?Sized>(env: &mut E, steps: u64, seed: u64) -> f64 {
    env.seed(seed);
    let mut rng = Pcg32::new(seed, 3);
    let space = env.action_space();
    let mut obs = vec![0.0f32; env.obs_dim()];
    env.reset_into(&mut obs);
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let a = space.sample(&mut rng);
        let t = env.step_into(&a, &mut obs);
        if t.done || t.truncated {
            env.reset_into(&mut obs);
        }
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let steps = knob_q("CAIRL_STEPS", 2_000_000, 100_000);
    let trials = knob_q("CAIRL_TRIALS", 5, 2);
    banner(&format!(
        "Ablation — dispatch & runner cost on Flatten<TimeLimit<CartPole, 200>>, {steps} steps x {trials}"
    ));

    let stat = time_trials(trials, |i| {
        let mut env = Flatten::new(TimeLimit::new(CartPole::new(), 200));
        drive(&mut env, steps, i);
    });
    let dynamic = time_trials(trials, |i| {
        let mut env: DynEnv =
            Box::new(Flatten::new(TimeLimit::new(CartPole::new(), 200)));
        drive(env.as_mut(), steps, i);
    });
    let script_steps = steps / 20; // the interpreter is ~2 orders slower
    let script = time_trials(trials, |i| {
        let mut env = TimeLimit::new(cairl::script::envs::cartpole(), 200);
        drive(&mut env, script_steps, i);
    });

    let ns = |mean_s: f64, n: u64| 1e9 * mean_s / n as f64;
    let static_ns = ns(stat.mean, steps);
    let dyn_ns = ns(dynamic.mean, steps);
    let script_ns = ns(script.mean, script_steps);
    println!("static  (monomorphised): {static_ns:>9.1} ns/step");
    println!("dynamic (Box<dyn Env>):  {dyn_ns:>9.1} ns/step  ({:.2}x static)", dyn_ns / static_ns);
    println!(
        "script  (interpreted):   {script_ns:>9.1} ns/step  ({:.1}x static)",
        script_ns / static_ns
    );

    // --- executor-layer dispatch: the same workload behind the
    // BatchedExecutor trait, sequential vs persistent-worker pools, on
    // both stepping kernels (scalar per-lane dispatch vs fused SoA
    // batch — the ISSUE-4 A/B).  Per-lane-step cost includes action
    // sampling and (for the pools) the per-batch synchronisation, i.e.
    // the executor overhead the fig1_console comparison amortises with
    // large batches.
    use cairl::coordinator::experiment::{
        build_executor_with_kernel, run_batched_workload, ExecutorKind, KernelMode,
    };
    let lanes = knob_q("CAIRL_LANES", 256, 64) as usize;
    let lane_steps = (steps / lanes as u64).max(1);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // The homogeneous rows plus a scenario mixture (half CartPole, half
    // MountainCar): per-lane dispatch through heterogeneous env ids and
    // obs padding, at the same lane count.  `max(1)` keeps the spec
    // valid when CAIRL_LANES=1.
    let half = (lanes / 2).max(1);
    let mix = format!("CartPole-v1:{half},MountainCar-v0:{half}");
    let bench_executor = |spec: &str, kind: ExecutorKind, n_lanes: usize, kernel| {
        let lane_budget = (steps / n_lanes as u64).max(1);
        let best: f64 = (0..trials)
            .map(|i| {
                let mut exec =
                    build_executor_with_kernel(spec, kind, n_lanes, threads, i, &[], kernel)
                        .unwrap();
                run_batched_workload(exec.as_mut(), lane_budget, i).throughput
            })
            .fold(0.0, f64::max);
        1e9 / best
    };
    let mut executor_rows: Vec<(String, &'static str, f64, u64)> = Vec::new();
    for (spec, kind, name) in [
        ("CartPole-v1", ExecutorKind::Sequential, "vec-env"),
        ("CartPole-v1", ExecutorKind::PoolSync, "pool-sync"),
        ("CartPole-v1", ExecutorKind::PoolAsync, "pool-async"),
        (mix.as_str(), ExecutorKind::PoolSync, "pool-mix"),
    ] {
        for kernel in [KernelMode::Scalar, KernelMode::Fused] {
            let exec_ns = bench_executor(spec, kind, lanes, kernel);
            println!(
                "{:<16} ({lanes} lanes): {exec_ns:>9.1} ns/lane-step  ({:.2}x static)",
                format!("{name}/{}", kernel.label()),
                exec_ns / static_ns
            );
            executor_rows.push((
                name.to_string(),
                kernel.label(),
                exec_ns,
                lane_steps * lanes as u64,
            ));
        }
    }

    // The ISSUE-4 acceptance workload: a 32-lane homogeneous CartPole
    // pool, --kernel fused vs --kernel scalar.
    let pool32_scalar =
        bench_executor("CartPole-v1", ExecutorKind::PoolSync, 32, KernelMode::Scalar);
    let pool32_fused =
        bench_executor("CartPole-v1", ExecutorKind::PoolSync, 32, KernelMode::Fused);
    println!(
        "pool-32/scalar   (32 lanes): {pool32_scalar:>9.1} ns/lane-step\n\
         pool-32/fused    (32 lanes): {pool32_fused:>9.1} ns/lane-step\n\
         fused-kernel speedup on the 32-lane CartPole pool: {:.2}x",
        pool32_scalar / pool32_fused
    );
    executor_rows.push((
        "pool-32".to_string(),
        KernelMode::Scalar.label(),
        pool32_scalar,
        (steps / 32).max(1) * 32,
    ));
    executor_rows.push((
        "pool-32".to_string(),
        KernelMode::Fused.label(),
        pool32_fused,
        (steps / 32).max(1) * 32,
    ));

    // --- telemetry overhead A/B (ISSUE-8 acceptance): the same 32-lane
    // fused pool workload with the process-wide metrics gate on vs off.
    // The record path is a relaxed-atomic add per batch, so the on/off
    // delta must stay under 2% (plus a small absolute floor to keep a
    // sub-nanosecond baseline from making the ratio meaningless).
    cairl::telemetry::set_enabled(false);
    let pool32_metrics_off =
        bench_executor("CartPole-v1", ExecutorKind::PoolSync, 32, KernelMode::Fused);
    cairl::telemetry::set_enabled(true);
    let pool32_metrics_on =
        bench_executor("CartPole-v1", ExecutorKind::PoolSync, 32, KernelMode::Fused);
    let overhead_pct = 100.0 * (pool32_metrics_on / pool32_metrics_off - 1.0);
    println!(
        "pool-32/metrics-off (32 lanes): {pool32_metrics_off:>9.1} ns/lane-step\n\
         pool-32/metrics-on  (32 lanes): {pool32_metrics_on:>9.1} ns/lane-step\n\
         telemetry overhead on the 32-lane fused pool: {overhead_pct:+.2}%"
    );
    executor_rows.push((
        "pool-32-metrics-off".to_string(),
        KernelMode::Fused.label(),
        pool32_metrics_off,
        (steps / 32).max(1) * 32,
    ));
    executor_rows.push((
        "pool-32-metrics-on".to_string(),
        KernelMode::Fused.label(),
        pool32_metrics_on,
        (steps / 32).max(1) * 32,
    ));

    // --- tracing overhead A/B (ISSUE-10 acceptance): the same 32-lane
    // fused pool with the span recorder (`cairl run --trace`) on vs
    // off.  Disabled tracing is one relaxed load and a branch per
    // record site; enabled it writes POD records into per-thread
    // rings, so the on/off delta shares the metrics budget: <2% plus
    // the same absolute floor.  The metrics gate stays on for both
    // rows so the delta isolates the span recorder alone.
    cairl::telemetry::trace::set_enabled(false);
    let pool32_trace_off =
        bench_executor("CartPole-v1", ExecutorKind::PoolSync, 32, KernelMode::Fused);
    cairl::telemetry::trace::set_enabled(true);
    let pool32_trace_on =
        bench_executor("CartPole-v1", ExecutorKind::PoolSync, 32, KernelMode::Fused);
    cairl::telemetry::trace::set_enabled(false);
    let trace_pct = 100.0 * (pool32_trace_on / pool32_trace_off - 1.0);
    println!(
        "pool-32/trace-off   (32 lanes): {pool32_trace_off:>9.1} ns/lane-step\n\
         pool-32/trace-on    (32 lanes): {pool32_trace_on:>9.1} ns/lane-step\n\
         tracing overhead on the 32-lane fused pool: {trace_pct:+.2}%"
    );
    executor_rows.push((
        "pool-32-trace-off".to_string(),
        KernelMode::Fused.label(),
        pool32_trace_off,
        (steps / 32).max(1) * 32,
    ));
    executor_rows.push((
        "pool-32-trace-on".to_string(),
        KernelMode::Fused.label(),
        pool32_trace_on,
        (steps / 32).max(1) * 32,
    ));

    // --- scripting tentpole: the same MiniScript program on all three
    // script runners.  Single-env rows first (one lane, Env trait), then
    // the batched row: the program is registered at runtime, so the
    // registry's fused lane builder picks it up and ONE bytecode VM
    // steps every lane's SoA state columns.
    const BOUNCE: &str = include_str!("../examples/bounce.mpy");
    const BOUNCE_STREAM: u64 = 0xb0b;
    use cairl::script::envs::{RenderHint, ScriptEnv};
    use cairl::script::vm::CompiledScriptEnv;
    let tree = time_trials(trials, |i| {
        let mut env =
            ScriptEnv::try_load("Script/Bounce-v0", BOUNCE, BOUNCE_STREAM, RenderHint::None)
                .unwrap();
        drive(&mut env, script_steps, i);
    });
    let vm = time_trials(trials, |i| {
        let mut env = CompiledScriptEnv::try_load(
            "Script/Bounce-v0",
            BOUNCE,
            BOUNCE_STREAM,
            RenderHint::None,
        )
        .unwrap();
        drive(&mut env, script_steps, i);
    });
    let tree_ns = ns(tree.mean, script_steps);
    let vm_ns = ns(vm.mean, script_steps);
    let vm_speedup = tree_ns / vm_ns;
    cairl::coordinator::registry::register_script("Bounce-v0", BOUNCE).unwrap();
    let bench_bounce_pool = |kernel: KernelMode| {
        let n_lanes = 32usize;
        let lane_budget = (script_steps / n_lanes as u64).max(1);
        let best: f64 = (0..trials)
            .map(|i| {
                let mut exec = build_executor_with_kernel(
                    "Script/Bounce-v0",
                    ExecutorKind::Sequential,
                    n_lanes,
                    1,
                    i,
                    &[],
                    kernel,
                )
                .unwrap();
                run_batched_workload(exec.as_mut(), lane_budget, i).throughput
            })
            .fold(0.0, f64::max);
        1e9 / best
    };
    let bounce_scalar = bench_bounce_pool(KernelMode::Scalar);
    let bounce_fused = bench_bounce_pool(KernelMode::Fused);
    println!(
        "bounce/tree-walk  (1 lane):   {tree_ns:>9.1} ns/step\n\
         bounce/bytecode   (1 lane):   {vm_ns:>9.1} ns/step  ({vm_speedup:.1}x over tree-walk)\n\
         bounce/scalar     (32 lanes): {bounce_scalar:>9.1} ns/lane-step\n\
         bounce/fused-soa  (32 lanes): {bounce_fused:>9.1} ns/lane-step  ({:.1}x over scalar lanes)\n\
         bytecode-vs-tree-walk speedup on examples/bounce.mpy: {vm_speedup:.1}x",
        bounce_scalar / bounce_fused
    );
    // steps/s spellings of the same rows, so the bench-trend tooling
    // tracks the script runners PR over PR like every other workload.
    for (label, row_ns) in [
        ("tree-walk", tree_ns),
        ("bytecode", vm_ns),
        ("batched-soa", bounce_fused),
    ] {
        println!("bounce {label:<12} {:>12.0} steps/s", 1e9 / row_ns);
    }
    let bounce_lane_steps = (script_steps / 32).max(1) * 32;
    executor_rows.push((
        "bounce-ast".to_string(),
        KernelMode::Scalar.label(),
        tree_ns,
        script_steps,
    ));
    executor_rows.push((
        "bounce-vm".to_string(),
        KernelMode::Scalar.label(),
        vm_ns,
        script_steps,
    ));
    executor_rows.push((
        "bounce-32".to_string(),
        KernelMode::Scalar.label(),
        bounce_scalar,
        bounce_lane_steps,
    ));
    executor_rows.push((
        "bounce-32".to_string(),
        KernelMode::Fused.label(),
        bounce_fused,
        bounce_lane_steps,
    ));

    // --- roofline sweep: every classic-control fused kernel at lane
    // widths 8/32/128/512, on the sequential executor so each row
    // isolates the SoA kernel's arithmetic from pool synchronisation.
    // ns/lane-step falling as lanes grow means the kernel is still
    // amortising per-batch overhead; the flat tail is its roofline.
    // bench_json.py lifts these rows into the `roofline` block of
    // BENCH_ci.json and bench_trend.py tracks them PR over PR.
    let roofline_steps = (steps / 4).max(1);
    let mut roofline = CsvLogger::create(
        std::path::Path::new("results/roofline.csv"),
        &["env", "lanes", "kernel", "ns_per_lane_step", "lane_steps_per_sec", "trials"],
    )
    .unwrap();
    for env in ["CartPole-v1", "MountainCar-v0", "Acrobot-v1", "Pendulum-v1"] {
        for n_lanes in [8usize, 32, 128, 512] {
            let lane_budget = (roofline_steps / n_lanes as u64).max(1);
            let best: f64 = (0..trials)
                .map(|i| {
                    let mut exec = build_executor_with_kernel(
                        env,
                        ExecutorKind::Sequential,
                        n_lanes,
                        1,
                        i,
                        &[],
                        KernelMode::Fused,
                    )
                    .unwrap();
                    run_batched_workload(exec.as_mut(), lane_budget, i).throughput
                })
                .fold(0.0, f64::max);
            let row_ns = 1e9 / best;
            println!("roofline {env:<16} {n_lanes:>3} lanes: {row_ns:>9.1} ns/lane-step");
            roofline
                .row(&[
                    env.to_string(),
                    n_lanes.to_string(),
                    "fused".into(),
                    format!("{row_ns:.2}"),
                    format!("{best:.0}"),
                    trials.to_string(),
                ])
                .unwrap();
        }
    }
    roofline.flush().unwrap();
    println!("rows -> results/roofline.csv");

    let mut log = CsvLogger::create(
        std::path::Path::new("results/ablation_dispatch.csv"),
        &["variant", "kernel", "ns_per_step", "steps", "trials"],
    )
    .unwrap();
    let mut rows: Vec<(String, &'static str, f64, u64)> = vec![
        ("static".to_string(), "scalar", static_ns, steps),
        ("dynamic".to_string(), "scalar", dyn_ns, steps),
        ("script".to_string(), "scalar", script_ns, script_steps),
    ];
    rows.extend(executor_rows);
    for (name, kernel, v, n) in rows {
        log.row(&[
            name,
            kernel.into(),
            format!("{v:.2}"),
            n.to_string(),
            trials.to_string(),
        ])
        .unwrap();
    }
    log.flush().unwrap();
    println!("rows -> results/ablation_dispatch.csv");

    assert!(
        script_ns > 10.0 * static_ns,
        "interpreter should dominate dispatch costs"
    );
    assert!(
        vm_speedup >= 5.0,
        "bytecode VM should be >=5x over the tree-walk on bounce.mpy, \
         got {vm_speedup:.1}x"
    );
    assert!(
        pool32_metrics_on <= pool32_metrics_off * 1.02 + 5.0,
        "telemetry must cost <2% on the steady-state step path: \
         {pool32_metrics_on:.1} ns on vs {pool32_metrics_off:.1} ns off \
         ({overhead_pct:+.2}%)"
    );
    assert!(
        pool32_trace_on <= pool32_trace_off * 1.02 + 5.0,
        "tracing must cost <2% on the steady-state step path: \
         {pool32_trace_on:.1} ns on vs {pool32_trace_off:.1} ns off \
         ({trace_pct:+.2}%)"
    );
}
