//! §V-B flash-runner claims: achieved FPS with the frame rate unlocked,
//! and the speed-up over the browser-locked frame clock.
//!
//! Paper numbers: ~140 FPS on an Intel 8700K in Multitask with the rate
//! unlocked, and a 4.6x factor over in-browser execution (browsers lock
//! Flash to the SWF clock — here 30 FPS — because the game loop lives
//! inside the render loop).  Expected shape: unlocked FPS >> locked FPS,
//! factor comfortably above the paper's 4.6x (this VM is lighter than
//! LightSpark).
//!
//! `CAIRL_FLASH_FRAMES=20000 cargo bench --bench flash_speedup` scales up.

#[path = "harness/mod.rs"]
mod harness;

use cairl::core::env::Env;
use cairl::core::rng::Pcg32;
use cairl::flash::games;
use cairl::flash::runner::FrameClock;
use cairl::tooling::csvlog::CsvLogger;
use harness::*;

fn run_frames(clock: FrameClock, frames: u64, seed: u64) -> f64 {
    let mut env = games::multitask().with_clock(clock);
    env.seed(seed);
    let mut rng = Pcg32::new(seed, 31);
    let mut obs = vec![0.0f32; env.obs_dim()];
    env.reset_into(&mut obs);
    let t0 = std::time::Instant::now();
    let mut done_frames = 0;
    while done_frames < frames {
        let a = cairl::core::spaces::Action::Discrete(rng.below(4) as usize);
        let t = env.step_into(&a, &mut obs);
        // Rendering every frame: the paper's game-loop-in-render-loop.
        let mut fb = cairl::render::Framebuffer::standard();
        env.render(&mut fb);
        done_frames += 1;
        if t.done {
            env.reset_into(&mut obs);
        }
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let unlocked_frames = knob_q("CAIRL_FLASH_FRAMES", 50_000, 5_000);
    // Locked at 30 FPS, keep the wall time reasonable (the quick budget
    // still spans ~3s of frame-clock so the 25-32 FPS window is stable).
    let locked_frames = knob_q("CAIRL_FLASH_LOCKED_FRAMES", 240, 90);
    banner("SS V-B — flash runner: unlocked FPS and speed-up over browser-locked");

    let unlocked_secs = run_frames(FrameClock::Unlocked, unlocked_frames, 0);
    let unlocked_fps = unlocked_frames as f64 / unlocked_secs;

    let locked_secs = run_frames(FrameClock::Locked { fps: 30.0 }, locked_frames, 0);
    let locked_fps = locked_frames as f64 / locked_secs;

    let factor = unlocked_fps / locked_fps;
    println!("unlocked: {unlocked_frames} frames in {unlocked_secs:.2}s = {unlocked_fps:.0} FPS");
    println!("locked(30): {locked_frames} frames in {locked_secs:.2}s = {locked_fps:.1} FPS");
    println!("speed-up factor {factor:.1}x  (paper: 4.6x over browsers, ~140 FPS on 8700K)");
    println!("note: the ASVM is far lighter than LightSpark, so the absolute FPS and");
    println!("factor exceed the paper's; the *shape* (unlock >> locked) is the claim.");

    let mut log = CsvLogger::create(
        std::path::Path::new("results/flash_speedup.csv"),
        &["mode", "frames", "seconds", "fps"],
    )
    .unwrap();
    log.row(&[
        "unlocked".into(),
        unlocked_frames.to_string(),
        format!("{unlocked_secs:.4}"),
        format!("{unlocked_fps:.1}"),
    ])
    .unwrap();
    log.row(&[
        "locked30".into(),
        locked_frames.to_string(),
        format!("{locked_secs:.4}"),
        format!("{locked_fps:.1}"),
    ])
    .unwrap();
    log.flush().unwrap();
    println!("rows -> results/flash_speedup.csv");

    assert!(unlocked_fps > 140.0, "unlocked FPS {unlocked_fps} below the paper's 140");
    assert!(factor > 4.6, "unlock factor {factor} below the paper's 4.6x");
    // The first frame of each episode is unpaced, so the measured rate
    // sits fractionally above the 30 FPS budget.
    assert!((25.0..=32.0).contains(&locked_fps), "frame clock drifted: {locked_fps}");
}
