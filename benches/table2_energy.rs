//! Table II: carbon emission and power draw, CaiRL vs AI Gym, console
//! and graphical variants, DQN on CartPole-v1.
//!
//! Paper protocol: DQN on CartPole, 1 000 000 timesteps console /
//! 10 000 graphical, measured with the experiment-impact-tracker and
//! reported as CO2/kg and mWh with the Gym:CaiRL ratio.  Only the
//! environment run-time is charged ("we measure the emissions by
//! subtracting the DQN time usage"), which here means tracking the
//! stepping+rendering workload rather than the artifact calls.
//!
//! Expected shape: console ratio ~20x (paper 20.9x); graphical ratio
//! >> 100x (the paper's 1.5e5x is dominated by Gym's locked window
//! capture, which our readback model represents conservatively).
//!
//! Full protocol: `CAIRL_T2_CONSOLE=1000000 CAIRL_T2_RENDER=10000 cargo bench --bench table2_energy`

#[path = "harness/mod.rs"]
mod harness;

use cairl::coordinator::experiment::{run_stepping_workload, RenderMode};
use cairl::energy::{EnergyReport, EnergyTracker};
use cairl::make;
use cairl::tooling::csvlog::CsvLogger;
use harness::*;

fn measure(env_id: &str, steps: u64, mode: RenderMode, label: &str) -> EnergyReport {
    let mut env = make(env_id).unwrap();
    let tracker = EnergyTracker::start_default(label);
    run_stepping_workload(&mut env, steps, 0, mode);
    tracker.stop()
}

fn main() {
    let console_steps = knob_q("CAIRL_T2_CONSOLE", 200_000, 30_000);
    let render_steps = knob_q("CAIRL_T2_RENDER", 4_000, 800);
    banner(&format!(
        "Table II — energy/carbon, console {console_steps} steps, graphical {render_steps} steps (paper: 1e6 / 1e4)"
    ));

    let console_cairl = measure(
        "CartPole-v1",
        console_steps,
        RenderMode::Console,
        "cairl-console",
    );
    let console_gym = measure(
        "Script/CartPole-v1",
        console_steps,
        RenderMode::Console,
        "gym-console",
    );
    let render_cairl = measure(
        "CartPole-v1",
        render_steps,
        RenderMode::Software,
        "cairl-graphical",
    );
    let render_gym = measure(
        "Script/CartPole-v1",
        render_steps,
        RenderMode::SimulatedHardware,
        "gym-graphical",
    );

    let console_ratio = console_cairl.co2_ratio_vs(&console_gym);
    let render_ratio = render_cairl.co2_ratio_vs(&render_gym);

    println!(
        "\n{:<12} {:<11} {:>12} {:>12} {:>14}",
        "Measurement", "Environment", "CaiRL", "Gym", "Ratio"
    );
    println!(
        "{:<12} {:<11} {:>12.3e} {:>12.3e} {:>14.1}",
        "CO2/kg", "Console", console_cairl.co2_kg, console_gym.co2_kg, console_ratio
    );
    println!(
        "{:<12} {:<11} {:>12.3e} {:>12.3e} {:>14.1}",
        "CO2/kg", "Graphical", render_cairl.co2_kg, render_gym.co2_kg, render_ratio
    );
    println!(
        "{:<12} {:<11} {:>12.6} {:>12.6} {:>14.1}",
        "Power (mWh)", "Console", console_cairl.mwh(), console_gym.mwh(), console_ratio
    );
    println!(
        "{:<12} {:<11} {:>12.6} {:>12.6} {:>14.1}",
        "Power (mWh)", "Graphical", render_cairl.mwh(), render_gym.mwh(), render_ratio
    );
    println!(
        "\n(paper Table II ratios: console 20.9x, graphical 1.48e5x — the\n graphical magnitude depends on how long the locked GL window path\n stalls; our readback model is deliberately conservative)"
    );

    let mut log = CsvLogger::create(
        std::path::Path::new("results/table2_energy.csv"),
        &["label", "cpu_s", "wall_s", "kwh", "mwh", "co2_kg"],
    )
    .unwrap();
    for r in [&console_cairl, &console_gym, &render_cairl, &render_gym] {
        log.row(&[
            r.label.clone(),
            format!("{:.3}", r.cpu_seconds),
            format!("{:.3}", r.wall_seconds),
            format!("{:.9}", r.kwh),
            format!("{:.6}", r.mwh()),
            format!("{:.9}", r.co2_kg),
        ])
        .unwrap();
    }
    log.flush().unwrap();
    println!("rows -> results/table2_energy.csv");

    assert!(
        console_ratio > 3.0,
        "console energy ratio collapsed: {console_ratio}"
    );
    assert!(
        render_ratio > 20.0,
        "graphical energy ratio collapsed: {render_ratio}"
    );
}
