//! Shared benchmark harness (criterion is unavailable offline).
//!
//! Protocol mirrors the paper's §V: every measurement is repeated over
//! `trials` seeded trials and reported as mean +- std; rows go to stdout
//! *and* a CSV under `results/` so EXPERIMENTS.md has provenance.
//!
//! Environment variables scale the workload:
//!   CAIRL_TRIALS       — trials per configuration (paper: 100; default lighter)
//!   CAIRL_STEPS        — steps per trial          (paper: 100 000)
//!   CAIRL_BENCH_QUICK  — `1` = smoke mode: tiny step budgets so CI can
//!                        execute every bench binary end-to-end (shape
//!                        checks still run; absolute numbers are noise)
//! so `CAIRL_TRIALS=100 CAIRL_STEPS=100000 cargo bench` reproduces the
//! full paper protocol and `CAIRL_BENCH_QUICK=1 cargo bench` is the CI
//! smoke path.  An explicit knob always beats the quick default.

#![allow(dead_code)]

use std::path::Path;

use cairl::tooling::csvlog::CsvLogger;
use cairl::tooling::stats::Summary;

/// True when the CI smoke path (`CAIRL_BENCH_QUICK=1`) is active.
pub fn quick() -> bool {
    std::env::var("CAIRL_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Read a workload knob from the environment.
pub fn knob(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Read a workload knob with a separate smoke-mode default: explicit
/// env var > quick default (under `CAIRL_BENCH_QUICK=1`) > default.
pub fn knob_q(name: &str, default: u64, quick_default: u64) -> u64 {
    match std::env::var(name).ok().and_then(|v| v.parse().ok()) {
        Some(v) => v,
        None if quick() => quick_default,
        None => default,
    }
}

/// Run `trials` timed trials of `f(trial_index)` and summarise seconds.
pub fn time_trials(trials: u64, mut f: impl FnMut(u64)) -> Summary {
    let times: Vec<f64> = (0..trials)
        .map(|i| {
            let t0 = std::time::Instant::now();
            f(i);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Summary::of(&times)
}

/// Print a section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// One comparison line in the Fig.-1 style, plus the speedup ratio.
pub fn report_pair(label: &str, cairl: &Summary, baseline: &Summary) -> f64 {
    let speedup = baseline.mean / cairl.mean;
    println!(
        "{label:<34} cairl {:>9.4}s +-{:>7.4}  baseline {:>9.4}s +-{:>7.4}  speedup {speedup:>7.1}x",
        cairl.mean, cairl.std, baseline.mean, baseline.std
    );
    speedup
}

/// CSV logger under results/ with standard comparison columns.
pub fn comparison_csv(name: &str) -> CsvLogger {
    CsvLogger::create(
        Path::new(&format!("results/{name}.csv")),
        &[
            "label",
            "cairl_mean_s",
            "cairl_std_s",
            "baseline_mean_s",
            "baseline_std_s",
            "speedup",
            "trials",
            "steps",
        ],
    )
    .expect("create results csv")
}

/// Write one comparison row.
pub fn log_pair(
    log: &mut CsvLogger,
    label: &str,
    cairl: &Summary,
    baseline: &Summary,
    trials: u64,
    steps: u64,
) {
    let speedup = baseline.mean / cairl.mean;
    log.row(&[
        label.to_string(),
        format!("{:.6}", cairl.mean),
        format!("{:.6}", cairl.std),
        format!("{:.6}", baseline.mean),
        format!("{:.6}", baseline.std),
        format!("{speedup:.3}"),
        trials.to_string(),
        steps.to_string(),
    ])
    .expect("csv row");
}
