//! Fig. 1 (console rows): execution run-time, CaiRL vs AI Gym, on the
//! four classic-control tasks without rendering — plus the executor
//! comparison: sequential `VecEnv` vs the persistent-worker `EnvPool`
//! (sync and async) on CartPole-v1, in steps/sec.
//!
//! Paper protocol: 100 000 steps per trial, averaged over 100 trials;
//! the CaiRL side is the native compiled env, the Gym side the
//! interpreted-runner surrogate (DESIGN.md §Substitutions).  Expected
//! shape: native wins by >=5x on every env (the paper reports ~5x for
//! CPython Gym), and pooled execution beats sequential once >=4 worker
//! threads have real cores behind them.
//!
//! Full protocol: `CAIRL_TRIALS=100 CAIRL_STEPS=100000 cargo bench --bench fig1_console`

#[path = "harness/mod.rs"]
mod harness;

use cairl::coordinator::experiment::{
    build_executor_with_kernel, run_batched_workload, run_random_workload, stepping_trials,
    ExecutorKind, KernelMode, RenderMode,
};
use cairl::coordinator::pool::EnvPool;
use cairl::make;
use cairl::tooling::csvlog::CsvLogger;
use harness::*;

/// Best-of-`trials` steps/sec for one executor configuration over an
/// env spec (a bare id or a scenario mixture).
fn executor_throughput(
    env_spec: &str,
    kind: ExecutorKind,
    kernel: KernelMode,
    lanes: usize,
    threads: usize,
    steps_per_lane: u64,
    trials: u64,
) -> f64 {
    (0..trials)
        .map(|trial| {
            let mut exec =
                build_executor_with_kernel(env_spec, kind, lanes, threads, trial, &[], kernel)
                    .unwrap();
            run_batched_workload(exec.as_mut(), steps_per_lane, trial).throughput
        })
        .fold(0.0, f64::max)
}

/// The executor-layer comparison (the scaling substrate this repo's
/// EnvPool refactor added): sequential vs pooled stepping on CartPole.
fn executor_comparison() {
    // Big batches amortise the per-batch barrier; cheap even in smoke
    // mode, so quick only trims the step budget.
    let lanes = knob_q("CAIRL_LANES", 1024, 1024) as usize;
    let steps_per_lane = knob_q("CAIRL_POOL_STEPS", 400, 100);
    let trials = knob_q("CAIRL_POOL_TRIALS", 3, 3);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    banner(&format!(
        "Executor comparison — CartPole-v1, {lanes} lanes x {steps_per_lane} steps, best of {trials} ({cores} cores)"
    ));

    let mut log = CsvLogger::create(
        std::path::Path::new("results/fig1_executors.csv"),
        &[
            "executor",
            "kernel",
            "threads",
            "lanes",
            "steps_per_lane",
            "steps_per_sec",
            "topology",
        ],
    )
    .expect("create results csv");

    // The historical rows run the scalar kernel (their meaning since
    // PR 1); the fused SoA rows follow below as an explicit A/B.
    let seq = executor_throughput(
        "CartPole-v1",
        ExecutorKind::Sequential,
        KernelMode::Scalar,
        lanes,
        1,
        steps_per_lane,
        trials,
    );
    println!("{:<26} {seq:>12.0} steps/s", "VecEnv (sequential)");
    log.row(&[
        "vec".into(),
        "scalar".into(),
        "1".into(),
        lanes.to_string(),
        steps_per_lane.to_string(),
        format!("{seq:.0}"),
        "local".into(),
    ])
    .unwrap();

    let mut thread_counts: Vec<usize> = vec![2, 4, cores.min(8)];
    thread_counts.retain(|&t| t >= 2);
    thread_counts.sort_unstable();
    thread_counts.dedup();
    let mut pooled_at_4_plus: Vec<(usize, f64)> = Vec::new();
    for &threads in &thread_counts {
        for (kind, label) in [
            (ExecutorKind::PoolSync, "pool"),
            (ExecutorKind::PoolAsync, "pool-async"),
        ] {
            let tput = executor_throughput(
                "CartPole-v1",
                kind,
                KernelMode::Scalar,
                lanes,
                threads,
                steps_per_lane,
                trials,
            );
            println!(
                "{:<26} {tput:>12.0} steps/s  ({:.2}x sequential)",
                format!("EnvPool {label} ({threads}t)"),
                tput / seq
            );
            log.row(&[
                label.into(),
                "scalar".into(),
                threads.to_string(),
                lanes.to_string(),
                steps_per_lane.to_string(),
                format!("{tput:.0}"),
                "local".into(),
            ])
            .unwrap();
            if kind == ExecutorKind::PoolSync && threads >= 4 {
                pooled_at_4_plus.push((threads, tput));
            }
        }
    }

    // Fused SoA kernel rows (the ISSUE-4 A/B): the same workloads with
    // --kernel fused.  Distinct labels keep the trend tracker pairing
    // like against like across runs.
    let fused_threads = cores.min(8).max(1);
    for (kind, label, threads) in [
        (ExecutorKind::Sequential, "vec-fused", 1usize),
        (ExecutorKind::PoolSync, "pool-fused", fused_threads),
        (ExecutorKind::PoolAsync, "pool-async-fused", fused_threads),
    ] {
        let tput = executor_throughput(
            "CartPole-v1",
            kind,
            KernelMode::Fused,
            lanes,
            threads,
            steps_per_lane,
            trials,
        );
        println!(
            "{:<26} {tput:>12.0} steps/s  ({:.2}x sequential, fused kernel)",
            format!("EnvPool {label} ({threads}t)"),
            tput / seq
        );
        log.row(&[
            label.into(),
            "fused".into(),
            threads.to_string(),
            lanes.to_string(),
            steps_per_lane.to_string(),
            format!("{tput:.0}"),
            "local".into(),
        ])
        .unwrap();
    }

    // Free-running row: the whole random workload executes worker-side
    // behind one barrier (`run_random_workload`), bounding what per-step
    // synchronisation costs the lockstep rows above.
    let max_threads = cores.min(8).max(1);
    let free = (0..trials)
        .map(|trial| {
            let mut pool = EnvPool::new(lanes, trial, max_threads, || {
                make("CartPole-v1").unwrap()
            });
            run_random_workload(&mut pool, steps_per_lane).throughput
        })
        .fold(0.0, f64::max);
    println!(
        "{:<26} {free:>12.0} steps/s  ({:.2}x sequential)",
        format!("EnvPool free-run ({max_threads}t)"),
        free / seq
    );
    log.row(&[
        "pool-free-running".into(),
        "scalar".into(),
        max_threads.to_string(),
        lanes.to_string(),
        steps_per_lane.to_string(),
        format!("{free:.0}"),
        "local".into(),
    ])
    .unwrap();

    // Scenario-mixture rows: half CartPole, half Acrobot lanes through
    // one heterogeneous pool (per-lane env ids + obs padding).  `max(1)`
    // keeps the spec valid when CAIRL_LANES=1.
    let half = (lanes / 2).max(1);
    let mix = format!("CartPole-v1:{half},Acrobot-v1:{half}");
    for (kind, label) in [
        (ExecutorKind::PoolSync, "pool-mix"),
        (ExecutorKind::PoolAsync, "pool-async-mix"),
    ] {
        let tput = executor_throughput(
            &mix,
            kind,
            KernelMode::Scalar,
            lanes,
            max_threads,
            steps_per_lane,
            trials,
        );
        println!(
            "{:<26} {tput:>12.0} steps/s  ({:.2}x sequential)",
            format!("EnvPool {label} ({max_threads}t)"),
            tput / seq
        );
        log.row(&[
            label.into(),
            "scalar".into(),
            max_threads.to_string(),
            lanes.to_string(),
            steps_per_lane.to_string(),
            format!("{tput:.0}"),
            "local".into(),
        ])
        .unwrap();
    }

    // Mixture with per-group fusion: the fused CartPole/Acrobot groups
    // step as SoA batches inside one heterogeneous pool.
    let mix_fused = executor_throughput(
        &mix,
        ExecutorKind::PoolSync,
        KernelMode::Fused,
        lanes,
        max_threads,
        steps_per_lane,
        trials,
    );
    println!(
        "{:<26} {mix_fused:>12.0} steps/s  ({:.2}x sequential, fused kernel)",
        format!("EnvPool pool-mix-fused ({max_threads}t)"),
        mix_fused / seq
    );
    log.row(&[
        "pool-mix-fused".into(),
        "fused".into(),
        max_threads.to_string(),
        lanes.to_string(),
        steps_per_lane.to_string(),
        format!("{mix_fused:.0}"),
        "local".into(),
    ])
    .unwrap();

    // Sharded row: the same CartPole workload through two in-process
    // `cairl serve` shards over Unix sockets — BENCH_ci.json starts
    // tracking transport overhead per PR (topology column).
    shard_rows(&mut log, seq, lanes, steps_per_lane, trials);

    log.flush().unwrap();
    println!("rows -> results/fig1_executors.csv");

    // Acceptance gate: pooled must beat sequential at >=4 threads — but
    // only assert where >=4 hardware cores exist to back those threads.
    if cores >= 4 {
        let best = pooled_at_4_plus
            .iter()
            .cloned()
            .fold((0usize, 0.0f64), |a, b| if b.1 > a.1 { b } else { a });
        assert!(
            best.1 > seq,
            "EnvPool sync at >=4 threads ({}t: {:.0} steps/s) failed to beat \
             sequential VecEnv ({seq:.0} steps/s)",
            best.0,
            best.1
        );
    } else {
        println!("(only {cores} cores: pooled-beats-sequential assert skipped)");
    }
}

/// The 2-shard Unix-socket row: spin up two shard daemons, connect a
/// `ShardedEnvPool` and run the standard batched workload.  The label
/// carries "shard" so `bench_trend.py` can pair (and, for older
/// baselines, skip) sharded rows explicitly.
#[cfg(unix)]
fn shard_rows(log: &mut CsvLogger, seq: f64, lanes: usize, steps_per_lane: u64, trials: u64) {
    use cairl::shard::{ServeConfig, ShardPoolOptions, ShardServer, ShardedEnvPool};

    let shards = 2usize;
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for i in 0..shards {
        let path = std::env::temp_dir().join(format!(
            "cairl-bench-shard-{}-{i}.sock",
            std::process::id()
        ));
        let config = ServeConfig {
            threads: 2,
            ..ServeConfig::new("CartPole-v1")
        };
        let server = ShardServer::bind(&format!("unix://{}", path.display()), config)
            .expect("bind bench shard");
        addrs.push(server.local_addr());
        handles.push(server.spawn());
    }

    let mut costs = std::collections::BTreeMap::new();
    costs.insert("CartPole-v1".to_string(), 1.0);
    let tput = (0..trials)
        .map(|trial| {
            let mut pool =
                ShardedEnvPool::connect_with_costs(&addrs, "CartPole-v1", lanes, trial, &costs)
                    .expect("connect bench shards");
            run_batched_workload(&mut pool, steps_per_lane, trial).throughput
        })
        .fold(0.0, f64::max);
    println!(
        "{:<26} {tput:>12.0} steps/s  ({:.2}x sequential, unix transport)",
        format!("EnvPool shard-{shards} (in-proc)"),
        tput / seq
    );
    log.row(&[
        format!("shard-{shards}"),
        "fused".into(),
        "2".into(),
        lanes.to_string(),
        steps_per_lane.to_string(),
        format!("{tput:.0}"),
        format!("shard-{shards}"),
    ])
    .unwrap();

    // Pipelined row: the same fabric with 4 batches in flight per
    // shard, so wire latency overlaps env compute.  The label's
    // digit-collapsed shape ("shard-#-pipe#") keeps the trend tracker
    // from pairing it against the lockstep "shard-#" row.
    let depth = 4usize;
    let mut pipe_latency_us = f64::INFINITY;
    let pipe_tput = (0..trials)
        .map(|trial| {
            let opts = ShardPoolOptions {
                lanes,
                base_seed: trial,
                pipeline: depth,
                costs: Some(costs.clone()),
                ..Default::default()
            };
            let mut pool = ShardedEnvPool::connect_opts(&addrs, "CartPole-v1", opts)
                .expect("connect bench shards (pipelined)");
            let r = pool.run_pipelined_workload(steps_per_lane, trial);
            let per_batch = r.elapsed.as_secs_f64() * 1e6 / steps_per_lane as f64;
            pipe_latency_us = pipe_latency_us.min(per_batch);
            r.throughput
        })
        .fold(0.0, f64::max);
    println!(
        "{:<26} {pipe_tput:>12.0} steps/s  ({:.2}x sequential, {:.1} us/batch, depth {depth})",
        format!("EnvPool shard-{shards}-pipe{depth}"),
        pipe_tput / seq,
        pipe_latency_us
    );
    log.row(&[
        format!("shard-{shards}-pipe{depth}"),
        "fused".into(),
        "2".into(),
        lanes.to_string(),
        steps_per_lane.to_string(),
        format!("{pipe_tput:.0}"),
        format!("shard-{shards}"),
    ])
    .unwrap();
    for handle in handles {
        handle.shutdown();
    }
}

#[cfg(not(unix))]
fn shard_rows(_log: &mut CsvLogger, _seq: f64, _lanes: usize, _steps_per_lane: u64, _trials: u64) {
    println!("(non-unix host: shard-2 unix-socket row skipped)");
}

fn main() {
    let trials = knob_q("CAIRL_TRIALS", 10, 2) as u32;
    let steps = knob_q("CAIRL_STEPS", 100_000, 6_000);
    banner(&format!(
        "Fig. 1 / console — {steps} steps x {trials} trials (paper: 100000 x 100)"
    ));

    let pairs = [
        ("CartPole-v1", "Script/CartPole-v1"),
        ("MountainCar-v0", "Script/MountainCar-v0"),
        ("Acrobot-v1", "Script/Acrobot-v1"),
        ("PendulumDiscrete-v1", "Script/Pendulum-v1"),
    ];

    let mut log = comparison_csv("fig1_console");
    let mut speedups = Vec::new();
    for (native_id, script_id) in pairs {
        let native = stepping_trials(
            &|| make(native_id).unwrap(),
            trials,
            steps,
            0,
            RenderMode::Console,
        );
        let script = stepping_trials(
            &|| make(script_id).unwrap(),
            trials,
            steps,
            0,
            RenderMode::Console,
        );
        let c = cairl::tooling::stats::Summary::of(&native);
        let b = cairl::tooling::stats::Summary::of(&script);
        let s = report_pair(native_id, &c, &b);
        log_pair(&mut log, native_id, &c, &b, trials as u64, steps);
        speedups.push(s);
    }
    log.flush().unwrap();

    let mean_speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("\nmean speedup {mean_speedup:.1}x (paper Fig. 1 console: ~5x)");
    println!("rows -> results/fig1_console.csv");
    assert!(
        speedups.iter().all(|&s| s > 3.0),
        "console speedup collapsed below the paper band: {speedups:?}"
    );

    executor_comparison();
}
