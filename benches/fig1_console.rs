//! Fig. 1 (console rows): execution run-time, CaiRL vs AI Gym, on the
//! four classic-control tasks without rendering.
//!
//! Paper protocol: 100 000 steps per trial, averaged over 100 trials;
//! the CaiRL side is the native compiled env, the Gym side the
//! interpreted-runner surrogate (DESIGN.md §Substitutions).  Expected
//! shape: native wins by >=5x on every env (the paper reports ~5x for
//! CPython Gym).
//!
//! Full protocol: `CAIRL_TRIALS=100 CAIRL_STEPS=100000 cargo bench --bench fig1_console`

#[path = "harness/mod.rs"]
mod harness;

use cairl::coordinator::experiment::{stepping_trials, RenderMode};
use cairl::make;
use harness::*;

fn main() {
    let trials = knob("CAIRL_TRIALS", 10) as u32;
    let steps = knob("CAIRL_STEPS", 100_000);
    banner(&format!(
        "Fig. 1 / console — {steps} steps x {trials} trials (paper: 100000 x 100)"
    ));

    let pairs = [
        ("CartPole-v1", "Script/CartPole-v1"),
        ("MountainCar-v0", "Script/MountainCar-v0"),
        ("Acrobot-v1", "Script/Acrobot-v1"),
        ("PendulumDiscrete-v1", "Script/Pendulum-v1"),
    ];

    let mut log = comparison_csv("fig1_console");
    let mut speedups = Vec::new();
    for (native_id, script_id) in pairs {
        let native = stepping_trials(
            &|| make(native_id).unwrap(),
            trials,
            steps,
            0,
            RenderMode::Console,
        );
        let script = stepping_trials(
            &|| make(script_id).unwrap(),
            trials,
            steps,
            0,
            RenderMode::Console,
        );
        let c = cairl::tooling::stats::Summary::of(&native);
        let b = cairl::tooling::stats::Summary::of(&script);
        let s = report_pair(native_id, &c, &b);
        log_pair(&mut log, native_id, &c, &b, trials as u64, steps);
        speedups.push(s);
    }
    log.flush().unwrap();

    let mean_speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("\nmean speedup {mean_speedup:.1}x (paper Fig. 1 console: ~5x)");
    println!("rows -> results/fig1_console.csv");
    assert!(
        speedups.iter().all(|&s| s > 3.0),
        "console speedup collapsed below the paper band: {speedups:?}"
    );
}
