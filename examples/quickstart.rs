//! Quickstart — the paper's Listings 1 & 2 in this toolkit.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use cairl::prelude::*;

fn main() {
    // ---- Listing 2: the Gym-compatible dynamic API -------------------
    // #e = gym.make("CartPole-v1")
    //  e = cairl.make("CartPole-v1")   # Use CaiRL
    let mut e = cairl::make("CartPole-v1").expect("registered env");
    let mut rng = Pcg32::new(0, 1);
    let mut total_steps = 0u32;
    for ep in 0..100 {
        e.reset();
        let mut term = false;
        let mut steps = 0u32;
        while !term {
            steps += 1;
            let a = e.action_space().sample(&mut rng);
            let step = e.step(&a);
            term = step.done;
            // obs = e.render()
            let mut fb = Framebuffer::standard();
            e.render(&mut fb);
        }
        total_steps += steps;
        if ep % 25 == 0 {
            println!("episode {ep:>3}: {steps} steps");
        }
    }
    println!("dynamic API: 100 random episodes, {total_steps} total steps");

    // ---- Listing 1: zero-cost static composition ---------------------
    // e = Flatten<TimeLimit<200, CartPoleEnv>>()
    let mut e = Flatten::new(TimeLimit::new(CartPole::new(), 200));
    e.seed(0);
    let mut obs = vec![0.0f32; e.obs_dim()];
    let mut episodes = 0;
    let mut steps = 0u64;
    e.reset_into(&mut obs);
    for _ in 0..10_000 {
        let a = e.action_space().sample(&mut rng);
        let t = e.step_into(&a, &mut obs);
        steps += 1;
        if t.done || t.truncated {
            episodes += 1;
            e.reset_into(&mut obs);
        }
    }
    println!(
        "static API:  {steps} steps over {episodes} episodes through {}",
        e.id()
    );

    // ---- The other runners behind the same interface -----------------
    for id in ["Script/CartPole-v1", "Flash/Pong-v0", "Puzzle/LightsOut-v0"] {
        let mut env = cairl::make(id).expect("registered env");
        env.seed(0);
        let (ret, len) = cairl::core::env::random_rollout(env.as_mut(), &mut rng, 200);
        println!("{id:<24} random episode: return {ret:>8.1}, length {len}");
    }

    // ---- ASCII render, because everyone wants to see the pole --------
    let mut cart = CartPole::new();
    cart.seed(7);
    let mut obs = vec![0.0f32; 4];
    cart.reset_into(&mut obs);
    // The painter's geometry is fixed to the 64x64 agent resolution
    // (it must match the L1 render kernel pixel-for-pixel), so render
    // there and downsample for the terminal.
    let mut fb = Framebuffer::standard();
    cart.render(&mut fb);
    let mut small = Framebuffer::new(32, 32);
    fb.downsample_into(&mut small);
    println!("\nCartPole, software-rendered (downsampled 32x32):\n{}", small.to_ascii());
}
