//! Fig. 3: DQN on the Flash-runner Multitask game.
//!
//! The paper trains DQN on Multitask through the Flash runtime and shows
//! the environment is learnable (solved after ~1.5-3M frames over 10
//! trials, ~6h per trial on their emulator).  This driver reproduces the
//! *learnability* claim at this testbed's scale: DQN on the ASVM
//! Multitask with virtual-flash-memory observations, mean episode length
//! as the mastery signal, curve to results/multitask_curve.csv.
//!
//! ```sh
//! cargo run --release --example multitask_flash                 # 150k frames
//! CAIRL_MT_STEPS=30000 cargo run --release --example multitask_flash
//! ```

use std::path::Path;

use cairl::agents::dqn::{DqnAgent, DqnConfig};
use cairl::make;
use cairl::runtime::Runtime;
use cairl::tooling::csvlog::CsvLogger;

fn main() {
    let max_steps: u32 = std::env::var("CAIRL_MT_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150_000);
    let trials: u32 = std::env::var("CAIRL_MT_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);

    let mut rt = Runtime::from_default_artifacts().expect("make artifacts first");
    let mut log = CsvLogger::create(
        Path::new("results/multitask_curve.csv"),
        &["trial", "episode", "env_steps", "return", "length"],
    )
    .unwrap();

    for trial in 0..trials {
        let cfg = DqnConfig {
            max_steps,
            // Mastery: surviving >= 900 frames per episode on average
            // (random lasts ~45; the scripted heuristic >= 2000).
            solve_return: 900.0,
            solve_window: 10,
            epsilon_decay_steps: max_steps / 3,
            learn_start: 1_000,
            seed: trial as u64,
            ..DqnConfig::default()
        };
        let mut agent = DqnAgent::new(&rt, "multitask", cfg).unwrap();
        let mut env = make("Flash/Multitask-v0").unwrap();
        println!("trial {trial}: training DQN on Flash/Multitask-v0 ({max_steps} frames max)...");
        let out = agent.train(&mut rt, &mut env).unwrap();
        println!(
            "trial {trial}: solved={} frames={} episodes={} wall={:.1}s mean_return={:.1}",
            out.solved,
            out.env_steps,
            out.episodes,
            out.wall_time.as_secs_f64(),
            out.final_mean_return
        );

        for (i, p) in out.curve.iter().enumerate() {
            log.row(&[
                trial.to_string(),
                i.to_string(),
                p.env_steps.to_string(),
                format!("{}", p.ret),
                p.len.to_string(),
            ])
            .unwrap();
        }

        // Early/late comparison — the learnability claim in one number.
        let k = (out.curve.len() / 5).max(1);
        let early: f32 =
            out.curve.iter().take(k).map(|p| p.ret).sum::<f32>() / k as f32;
        let late: f32 = out.curve.iter().rev().take(k).map(|p| p.ret).sum::<f32>()
            / k as f32;
        println!(
            "trial {trial}: mean return first-{k} episodes {early:.1} -> last-{k} {late:.1} ({:.1}x)",
            late / early.max(1e-6)
        );
    }
    log.flush().unwrap();
    println!("curve -> results/multitask_curve.csv");
    println!("(paper Fig. 3: solved after ~1.5-3M frames, 10 trials, on LightSpark)");
}
