//! Tournament tooling (paper §III-A) on the GridRTS substrate: a Swiss
//! tournament and a single-elimination bracket over the built-in bots.
//!
//! ```sh
//! cargo run --release --example tournament
//! ```

use cairl::core::rng::Pcg32;
use cairl::envs::gridrts::{play_match, Bot, HarvestBot, MatchResult, RandomBot, RushBot};
use cairl::tooling::tournament::{single_elimination, swiss, GameOutcome};

/// Bridge a bot-vs-bot GridRTS match into a tournament outcome.
fn run_pairing(bots: &mut [Box<dyn Bot>], a: usize, b: usize) -> GameOutcome {
    let (lo, hi) = (a.min(b), a.max(b));
    let (left, right) = bots.split_at_mut(hi);
    let (bot_lo, bot_hi) = (&mut left[lo], &mut right[0]);
    let result = if a < b {
        play_match(bot_lo.as_mut(), bot_hi.as_mut())
    } else {
        play_match(bot_hi.as_mut(), bot_lo.as_mut())
    };
    match result {
        MatchResult::Win(0) => GameOutcome::WinA,
        MatchResult::Win(_) => GameOutcome::WinB,
        MatchResult::Draw => GameOutcome::Draw,
    }
}

fn roster(seed: u64) -> (Vec<Box<dyn Bot>>, Vec<String>) {
    let bots: Vec<Box<dyn Bot>> = vec![
        Box::new(RushBot),
        Box::new(HarvestBot),
        Box::new(RandomBot(Pcg32::new(seed, 1))),
        Box::new(RandomBot(Pcg32::new(seed, 2))),
        Box::new(RandomBot(Pcg32::new(seed, 3))),
        Box::new(HarvestBot),
    ];
    let names = vec![
        "rush".to_string(),
        "harvest".to_string(),
        "random-1".to_string(),
        "random-2".to_string(),
        "random-3".to_string(),
        "harvest-2".to_string(),
    ];
    (bots, names)
}

fn main() {
    let seed = 0;

    println!("== Swiss, 4 rounds, 6 GridRTS bots ==");
    let (mut bots, names) = roster(seed);
    let mut rng = Pcg32::new(seed, 99);
    let standings = swiss(bots.len(), 4, &mut rng, |a, b| run_pairing(&mut bots, a, b));
    for (rank, s) in standings.iter().enumerate() {
        println!(
            "  {}. {:<10} {:>2} pts  ({} matches)",
            rank + 1,
            names[s.player],
            s.score,
            s.played
        );
    }

    println!("\n== Single elimination, same roster ==");
    let (mut bots, names) = roster(seed + 1);
    let mut rng = Pcg32::new(seed + 1, 99);
    let bracket =
        single_elimination(bots.len(), &mut rng, |a, b| run_pairing(&mut bots, a, b));
    for (rank, s) in bracket.iter().enumerate() {
        println!(
            "  {}. {:<10} survived {} round(s)  ({} matches)",
            rank + 1,
            names[s.player],
            s.score,
            s.played
        );
    }
    println!("\nchampion: {}", names[bracket[0].player]);

    // Sanity: the rush strategy dominates this map (it razes an
    // undefended base before economy pays off) — mirror of the unit test.
    assert_eq!(names[bracket[0].player], "rush");
}
