//! Perf probe: per-component timing of the DQN hot loop (§Perf in
//! EXPERIMENTS.md).
//!
//! Breaks one training step into its cost centres so the optimisation
//! pass can attack the top one:
//!   env step | act (PJRT) | literal marshalling | train execute (PJRT)
//!
//! ```sh
//! cargo run --release --example perf_probe
//! ```

use std::time::Instant;

use cairl::core::env::Env;
use cairl::core::rng::Pcg32;
use cairl::core::spaces::Action;
use cairl::envs::CartPole;
use cairl::runtime::dqn_exec::{Batch, DqnExecutor};
use cairl::runtime::pjrt::{literal_f32, Runtime};
use cairl::wrappers::TimeLimit;

fn main() {
    let n: u64 = std::env::var("CAIRL_PROBE_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000);
    let mut rt = Runtime::from_default_artifacts().unwrap();
    let mut exec = DqnExecutor::new(&rt, "cartpole", 0).unwrap();

    // --- env stepping -------------------------------------------------
    let mut env = TimeLimit::new(CartPole::new(), 500);
    env.seed(0);
    let mut rng = Pcg32::new(0, 1);
    let mut obs = vec![0.0f32; 4];
    env.reset_into(&mut obs);
    let t0 = Instant::now();
    for _ in 0..n * 50 {
        let a = Action::Discrete(rng.below(2) as usize);
        let t = env.step_into(&a, &mut obs);
        if t.done || t.truncated {
            env.reset_into(&mut obs);
        }
    }
    let env_ns = t0.elapsed().as_nanos() as f64 / (n * 50) as f64;

    // --- act() through PJRT --------------------------------------------
    let t0 = Instant::now();
    for _ in 0..n {
        exec.q_values(&mut rt, &obs).unwrap();
    }
    let act_us = t0.elapsed().as_micros() as f64 / n as f64;

    // --- act() natively on the host (SSPerf fast path) ------------------
    let t0 = Instant::now();
    for _ in 0..n * 100 {
        std::hint::black_box(exec.q_values_native(&obs));
    }
    let native_act_ns = t0.elapsed().as_nanos() as f64 / (n * 100) as f64;

    // --- literal marshalling only (the train step's 30 operands) -------
    let b = exec.batch_size;
    let batch = Batch {
        s: vec![0.01; b * 4],
        a: vec![0; b],
        r: vec![1.0; b],
        s2: vec![0.02; b * 4],
        done: vec![0.0; b],
    };
    let t0 = Instant::now();
    for _ in 0..n {
        // Representative marshalling load: 24 param tensors + batch.
        let mut lits = Vec::with_capacity(30);
        for tensor in exec.params() {
            lits.push(literal_f32(tensor, &[tensor.len()]).unwrap());
        }
        for tensor in exec.params() {
            lits.push(literal_f32(tensor, &[tensor.len()]).unwrap());
        }
        for tensor in exec.params() {
            lits.push(literal_f32(tensor, &[tensor.len()]).unwrap());
        }
        for tensor in exec.params() {
            lits.push(literal_f32(tensor, &[tensor.len()]).unwrap());
        }
        lits.push(literal_f32(&batch.s, &[b, 4]).unwrap());
        lits.push(literal_f32(&batch.r, &[b]).unwrap());
        std::hint::black_box(lits);
    }
    let marshal_us = t0.elapsed().as_micros() as f64 / n as f64;

    // --- full train step ------------------------------------------------
    let t0 = Instant::now();
    for _ in 0..n {
        exec.train_step(&mut rt, &batch).unwrap();
    }
    let train_us = t0.elapsed().as_micros() as f64 / n as f64;

    println!("iters per section: {n}");
    println!("env step (native TimeLimit<CartPole>): {env_ns:>9.1} ns");
    println!("act (7-operand PJRT call):             {act_us:>9.1} us");
    println!("act (native host forward):             {:>9.2} us", native_act_ns / 1e3);
    println!("train-step literal marshalling (est):  {marshal_us:>9.1} us");
    println!("train step (30-operand PJRT call):     {train_us:>9.1} us");
    println!(
        "\nDQN loop step (PJRT act)   = {:.1} us -> {:.0} steps/s",
        act_us + train_us,
        1e6 / (act_us + train_us)
    );
    println!(
        "DQN loop step (native act) = {:.1} us -> {:.0} steps/s",
        native_act_ns / 1e3 + train_us,
        1e6 / (native_act_ns / 1e3 + train_us)
    );

    // --- device-resident buffer chaining experiment ---------------------
    // Feed one call's output buffers straight into the next call.
    let module = rt.load("dqn_train_cartpole").unwrap();
    let mut state: Vec<xla::PjRtBuffer> = Vec::new();
    // params, target, m, v (4 x 6 tensors)
    let shapes: Vec<Vec<usize>> =
        vec![vec![4, 32], vec![32], vec![32, 32], vec![32], vec![32, 2], vec![2]];
    for _ in 0..2 {
        for (t, sh) in exec.params().iter().zip(&shapes) {
            state.push(rt2_to_device(&rt, t, sh));
        }
    }
    for _ in 0..2 {
        for sh in &shapes {
            let zeros = vec![0.0f32; sh.iter().product()];
            state.push(rt2_to_device(&rt, &zeros, sh));
        }
    }
    let mut t_buf = rt2_to_device(&rt, &[0.0f32], &[]);
    let out_len;
    {
        // One probing call to see whether outputs come back untupled.
        let mut inputs: Vec<&xla::PjRtBuffer> = state.iter().collect();
        inputs.push(&t_buf);
        let s_b = rt2_to_device(&rt, &batch.s, &[b, 4]);
        let a_b = rt.to_device_i32(&batch.a, &[b]).unwrap();
        let r_b = rt2_to_device(&rt, &batch.r, &[b]);
        let s2_b = rt2_to_device(&rt, &batch.s2, &[b, 4]);
        let d_b = rt2_to_device(&rt, &batch.done, &[b]);
        inputs.push(&s_b);
        inputs.push(&a_b);
        inputs.push(&r_b);
        inputs.push(&s2_b);
        inputs.push(&d_b);
        let owned: Vec<xla::PjRtBuffer> = Vec::new();
        let _ = owned;
        let module = rt.load("dqn_train_cartpole").unwrap();
        let outs = module
            .execute_buffers_ref(&inputs)
            .expect("execute_b works");
        out_len = outs.len();
        println!("\nexecute_b output buffer count: {out_len} (20 = untupled)");
        if out_len == 20 {
            // Timed chained loop: reuse output buffers as inputs.
            let mut bufs = outs;
            let t0 = Instant::now();
            for _ in 0..n {
                let mut inputs: Vec<&xla::PjRtBuffer> = bufs[0..6].iter().collect();
                inputs.extend(bufs[0..6].iter()); // target := online (sync'd)
                inputs.extend(bufs[6..12].iter());
                inputs.extend(bufs[12..18].iter());
                inputs.push(&bufs[18]);
                inputs.push(&s_b);
                inputs.push(&a_b);
                inputs.push(&r_b);
                inputs.push(&s2_b);
                inputs.push(&d_b);
                bufs = module.execute_buffers_ref(&inputs).unwrap();
            }
            // One loss readback at the end.
            let loss = bufs[19].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
            let chained_us = t0.elapsed().as_micros() as f64 / n as f64;
            println!(
                "train step (buffer-chained):           {chained_us:>9.1} us (final loss {:.4})",
                loss[0]
            );
            println!(
                "DQN loop step (chained)    = {:.1} us -> {:.0} steps/s",
                native_act_ns / 1e3 + chained_us,
                1e6 / (native_act_ns / 1e3 + chained_us)
            );
        }
    }
    let _ = &mut t_buf;
}

fn rt2_to_device(rt: &Runtime, data: &[f32], shape: &[usize]) -> xla::PjRtBuffer {
    rt.to_device(data, shape).unwrap()
}
