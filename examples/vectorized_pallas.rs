//! Vectorised simulation two ways: the executor layer (native lanes on
//! `VecEnv` / `EnvPool`, config-flippable) and the L1 Pallas kernel
//! (256 CartPole lanes advanced per PJRT call).
//!
//! This is the §Hardware-Adaptation demo: the paper vectorises
//! environment arithmetic with CPU SIMD; the TPU translation is a
//! batched Pallas kernel (`python/compile/kernels/env_step.py`) lowered
//! into `artifacts/env_step_cartpole.hlo.txt` and driven from Rust.  On
//! the CPU PJRT backend the call overhead dominates at this tiny state
//! size — the point is the *architecture* (batched lanes, one dispatch)
//! plus a numerics cross-check, with per-lane cost reported honestly.
//! The native section shows the same batched shape on the host executors
//! so the comparison runs even where PJRT/artifacts are absent.
//!
//! ```sh
//! cargo run --release --example vectorized_pallas
//! CAIRL_EXECUTOR=pool-async cargo run --release --example vectorized_pallas
//! ```

use cairl::coordinator::experiment::{
    build_executor, run_batched_workload, ExecutorKind,
};
use cairl::core::rng::Pcg32;
use cairl::envs::CartPole;
use cairl::runtime::pjrt::{literal_f32, Runtime};

const BATCH: usize = 256; // lowering batch of env_step_cartpole

fn env_knob(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Native batched stepping through the executor layer: the workload is
/// identical across executors, only the stepping engine flips.
fn executor_section(rounds: usize) {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let chosen = std::env::var("CAIRL_EXECUTOR")
        .ok()
        .and_then(|v| ExecutorKind::parse(&v))
        .unwrap_or(ExecutorKind::PoolSync);
    println!("native executor layer ({BATCH} lanes, {rounds} rounds, {threads} threads):");
    for kind in [ExecutorKind::Sequential, chosen] {
        let mut exec = build_executor("CartPole-v1", kind, BATCH, threads, 0)
            .expect("CartPole-v1 is registered");
        let r = run_batched_workload(exec.as_mut(), rounds as u64, 0);
        println!(
            "  {:<12} {:>12.0} lane-steps/s  ({} episodes finished)",
            kind.label(),
            r.throughput,
            r.episodes
        );
    }
}

/// The original kernel demo: one PJRT call advances all 256 lanes; the
/// native scalar loop replays the identical workload for a numerics
/// cross-check.
fn kernel_section(rt: &mut Runtime, rounds: usize) {
    // Seed 256 lanes with small random states and a fixed action stream.
    let mut rng = Pcg32::new(0, 5);
    let mut states: Vec<f32> = (0..BATCH * 4).map(|_| rng.uniform(-0.05, 0.05)).collect();
    let mut native_states = states.clone();
    let actions: Vec<Vec<f32>> = (0..rounds)
        .map(|_| (0..BATCH).map(|_| rng.below(2) as f32).collect())
        .collect();

    // --- kernel path: one PJRT call advances all 256 lanes -----------
    let module = rt.load("env_step_cartpole").unwrap();
    let t0 = std::time::Instant::now();
    let mut kernel_resets = 0u64;
    for acts in &actions {
        let out = module
            .execute_f32(&[
                literal_f32(&states, &[BATCH, 4]).unwrap(),
                literal_f32(acts, &[BATCH]).unwrap(),
            ])
            .unwrap();
        states.copy_from_slice(&out[0]);
        // Auto-reset finished lanes to the origin (matches the native loop).
        for (lane, &done) in out[2].iter().enumerate() {
            if done != 0.0 {
                kernel_resets += 1;
                for k in 0..4 {
                    states[lane * 4 + k] = 0.0;
                }
            }
        }
    }
    let kernel_secs = t0.elapsed().as_secs_f64();
    let lane_steps = (rounds * BATCH) as f64;

    // --- native path: the same lanes, scalar Rust dynamics -----------
    let t0 = std::time::Instant::now();
    let mut native_resets = 0u64;
    for acts in &actions {
        for lane in 0..BATCH {
            let s = [
                native_states[lane * 4],
                native_states[lane * 4 + 1],
                native_states[lane * 4 + 2],
                native_states[lane * 4 + 3],
            ];
            let (ns, done) = CartPole::dynamics(s, acts[lane] > 0.5);
            if done {
                native_resets += 1;
                native_states[lane * 4..lane * 4 + 4].fill(0.0);
            } else {
                native_states[lane * 4..lane * 4 + 4].copy_from_slice(&ns);
            }
        }
    }
    let native_secs = t0.elapsed().as_secs_f64();

    // --- numerics agreement -------------------------------------------
    let max_diff = states
        .iter()
        .zip(&native_states)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("\nkernel path: lanes {BATCH}, rounds {rounds} -> {lane_steps:.0} lane-steps");
    println!(
        "kernel (PJRT, batched):  {kernel_secs:.3}s = {:>8.0} lane-steps/s  ({} resets)",
        lane_steps / kernel_secs,
        kernel_resets
    );
    println!(
        "native (scalar rust):    {native_secs:.3}s = {:>8.0} lane-steps/s  ({} resets)",
        lane_steps / native_secs,
        native_resets
    );
    println!("max state divergence after {rounds} rounds: {max_diff:.2e}");
    println!(
        "\nper-call overhead dominates on CPU PJRT at 4-float states; on a real\n\
         TPU the same artifact amortises one dispatch over the VPU lanes (see\n\
         DESIGN.md SSHardware-Adaptation for the VMEM/MXU accounting)."
    );
    assert!(max_diff < 1e-4, "kernel and native dynamics diverged");
    assert_eq!(kernel_resets, native_resets);
}

fn main() {
    let rounds = env_knob("CAIRL_VEC_ROUNDS", 200);
    executor_section(rounds);
    match Runtime::from_default_artifacts() {
        Ok(mut rt) => kernel_section(&mut rt, rounds),
        Err(e) => {
            println!(
                "\nkernel path skipped (PJRT runtime unavailable): {e}\n\
                 run `make artifacts` with the real xla bindings to enable it"
            );
        }
    }
}
