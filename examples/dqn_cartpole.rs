//! End-to-end driver: train the Table-I DQN on CartPole-v1 through the
//! complete three-layer stack until the solve criterion.
//!
//! This is the repository's headline validation run (EXPERIMENTS.md
//! §End-to-end): every layer composes —
//!   L3  rust env + replay + epsilon schedule + target sync,
//!   L2  jax train-step artifact executed via PJRT,
//!   L1  the fused Pallas Q-network kernels inside that artifact.
//!
//! Writes the return curve and loss curve to results/dqn_cartpole_*.csv.
//!
//! ```sh
//! cargo run --release --example dqn_cartpole            # solve (<= 150k steps)
//! CAIRL_DQN_MAX_STEPS=5000 cargo run --release --example dqn_cartpole
//! ```

use std::path::Path;

use cairl::agents::dqn::{DqnAgent, DqnConfig};
use cairl::make;
use cairl::runtime::Runtime;
use cairl::tooling::csvlog::CsvLogger;

fn main() {
    let max_steps: u32 = std::env::var("CAIRL_DQN_MAX_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150_000);
    let seed: u64 = std::env::var("CAIRL_DQN_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);

    println!("loading PJRT runtime + artifacts...");
    let mut rt = Runtime::from_default_artifacts().expect("make artifacts first");
    let hp = rt.manifest().hyperparameters.clone();
    println!(
        "DQN (Table I): hidden {}x{}, batch {}, lr {}, gamma {}",
        hp.hidden, hp.hidden, hp.batch, hp.lr, hp.gamma
    );

    let cfg = DqnConfig {
        max_steps,
        solve_return: 195.0,
        solve_window: 20,
        epsilon_decay_steps: 8_000,
        seed,
        ..DqnConfig::default()
    };
    let mut agent = DqnAgent::new(&rt, "cartpole", cfg).unwrap();
    let mut env = make("CartPole-v1").unwrap();

    println!("training on CartPole-v1 (solve: mean return >= 195 over 20 episodes)...");
    let out = agent.train(&mut rt, &mut env).expect("training run");

    println!(
        "\nsolved={}  env_steps={}  train_steps={}  episodes={}  wall={:.1}s  mean_return={:.1}",
        out.solved,
        out.env_steps,
        out.train_steps,
        out.episodes,
        out.wall_time.as_secs_f64(),
        out.final_mean_return
    );

    // Return curve.
    let mut curve = CsvLogger::create(
        Path::new("results/dqn_cartpole_curve.csv"),
        &["episode", "env_steps", "return", "length"],
    )
    .unwrap();
    for (i, p) in out.curve.iter().enumerate() {
        curve
            .row(&[
                i.to_string(),
                p.env_steps.to_string(),
                format!("{}", p.ret),
                p.len.to_string(),
            ])
            .unwrap();
    }
    curve.flush().unwrap();

    // Loss curve (every 100 train steps).
    let mut losses = CsvLogger::create(
        Path::new("results/dqn_cartpole_loss.csv"),
        &["train_step_x100", "loss"],
    )
    .unwrap();
    for (i, l) in out.losses.iter().enumerate() {
        losses.row(&[i.to_string(), format!("{l}")]).unwrap();
    }
    losses.flush().unwrap();

    // Compact curve preview on stdout.
    println!("\nreturn curve (every ~10th episode):");
    for (i, p) in out.curve.iter().enumerate() {
        if i % 10 == 0 || i + 1 == out.curve.len() {
            let bar = "#".repeat((p.ret / 10.0) as usize);
            println!("  ep {i:>4} @ step {:>6}: {:>6.1} {bar}", p.env_steps, p.ret);
        }
    }
    println!("\ncurves -> results/dqn_cartpole_curve.csv, results/dqn_cartpole_loss.csv");

    if !out.solved && max_steps >= 150_000 {
        eprintln!("warning: not solved within {max_steps} steps (seed {seed})");
        std::process::exit(1);
    }
}
