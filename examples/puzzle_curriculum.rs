//! Curriculum learning on the puzzle runtime (paper §IV-D): the
//! heuristic solvers grade instance difficulty, and a tabular Q-learner
//! climbs a LightsOut curriculum from 1-press scrambles upward.
//!
//! ```sh
//! cargo run --release --example puzzle_curriculum
//! ```

use cairl::agents::QTableAgent;
use cairl::core::env::Env;
use cairl::core::spaces::Action;
use cairl::puzzles::{Fifteen, LightsOut, Nonogram};
use cairl::wrappers::TimeLimit;

fn main() {
    // --- solvers certify the generated instances ----------------------
    println!("== solver certificates ==");
    let mut lo = LightsOut::new(5);
    lo.seed(0);
    let mut obs = vec![0.0; 25];
    lo.reset_into(&mut obs);
    let presses = lo.solve().expect("solvable");
    println!("LightsOut 5x5: exact GF(2) solution in {} presses", presses.len());

    let mut ft = Fifteen::new(4).with_scramble(14);
    ft.seed(0);
    let mut obs = vec![0.0; 16];
    ft.reset_into(&mut obs);
    let path = ft.solve(40).expect("IDA* solves short scrambles");
    println!("Fifteen 4x4 (14-move scramble): IDA* path of {} moves", path.len());

    let mut ng = Nonogram::new();
    ng.seed(0);
    let mut obs = vec![0.0; ng.obs_dim()];
    ng.reset_into(&mut obs);
    assert!(ng.solve().is_some());
    println!("Nonogram 5x5: line-propagation solver found a satisfying grid");

    // --- curriculum: Q-learning over increasing scramble depth --------
    println!("\n== LightsOut 3x3 curriculum (tabular Q-learning) ==");
    let n = 3;
    let mut agent = QTableAgent::new(
        2,                       // binary cells -> 2 bins per dim
        vec![0.0; n * n],
        vec![1.0; n * n],
        n * n,
        7,
    );
    agent.alpha = 0.3;
    agent.gamma = 0.95;
    agent.epsilon = 0.2;

    for difficulty in 1..=4u32 {
        let mut env = TimeLimit::new(
            LightsOut::new(n).with_scramble(difficulty),
            (3 * difficulty) as u32,
        );
        env.seed(difficulty as u64);
        // Train.
        for _ in 0..4_000 {
            agent.train_episode(&mut env, 3 * difficulty);
        }
        // Evaluate greedily.
        let mut solved = 0;
        let trials = 200;
        let mut obs = vec![0.0f32; n * n];
        for t in 0..trials {
            env.seed(1_000 + t);
            env.reset_into(&mut obs);
            for _ in 0..3 * difficulty {
                let s = agent.state_of(&obs);
                let a = agent.greedy(s);
                let tr = env.step_into(&Action::Discrete(a), &mut obs);
                if tr.done && !tr.truncated {
                    solved += 1;
                    break;
                }
                if tr.truncated {
                    break;
                }
            }
        }
        let rate = 100.0 * solved as f32 / trials as f32;
        println!("  scramble depth {difficulty}: greedy solve rate {rate:.0}%");
        if difficulty == 1 {
            assert!(rate > 60.0, "depth-1 should be mastered, got {rate}%");
        }
    }
    println!("\n(the solvers provide both difficulty grading and demonstration\n trajectories — the transfer/curriculum hook the paper motivates)");
}
