//! Integration: the MiniScript bytecode pipeline (lexer → parser →
//! compiler → register VM) against the tree-walk interpreter.
//!
//! The contract under test is **observable equivalence**: for any
//! program the two runners return bit-identical values, draw the RNG in
//! the same order, and fail with the same error strings — which is what
//! lets the registry serve the tree-walk on the scalar path and the
//! bytecode VM on the fused batch path while `--kernel` stays a pure
//! performance transform.
//!
//! Thread counts default to 1/2/4; the CI determinism matrix re-runs
//! the suite with `CAIRL_TEST_THREADS` pinned to each of 1, 2, 4, 8.

mod common;

use cairl::coordinator::experiment::{build_executor_with_kernel, ExecutorKind, KernelMode};
use cairl::coordinator::pool::BatchedExecutor;
use cairl::coordinator::registry;
use cairl::core::env::{Env, Transition};
use cairl::core::rng::Pcg32;
use cairl::core::spaces::{Action, Space};
use cairl::script::compile::{compile, compile_src};
use cairl::script::envs::{RenderHint, ScriptEnv};
use cairl::script::lexer::lex;
use cairl::script::parser::parse;
use cairl::script::vm::CompiledScriptEnv;
use cairl::script::{Interpreter, Value, Vm};

/// Well-formed programs: `(source, function, args, expected value)`.
/// Deliberately spans every statement and expression form the language
/// has — arithmetic, loops with break/continue, `for`, lists, builtins,
/// user-function calls, recursion, short-circuit logic, elif chains,
/// compound assignment and unary negation.
const CORPUS: &[(&str, &str, &[f64], f64)] = &[
    ("def f(a, b) { return a * 10 + b; }", "f", &[4.0, 2.0], 42.0),
    (
        "def f() { s = 0; i = 0; while (true) { i += 1; if (i > 10) { break; } \
         if (i % 2 == 0) { continue; } s += i; } return s; }",
        "f",
        &[],
        25.0,
    ),
    ("def f() { s = 0; for i = 0, 10 { s += i; } return s; }", "f", &[], 45.0),
    (
        "def f() { xs = zeros(3); xs[1] = 7; push(xs, 9); \
         return xs[1] + xs[3] + len(xs); }",
        "f",
        &[],
        20.0,
    ),
    ("def f() { return clamp(cos(0) * 5, 0, 2) + sqrt(16); }", "f", &[], 6.0),
    (
        "def sq(x) { return x * x; } def f(x) { return sq(x) + sq(x + 1); }",
        "f",
        &[2.0],
        13.0,
    ),
    (
        "def fib(n) { if (n < 2) { return n; } return fib(n - 1) + fib(n - 2); }",
        "fib",
        &[10.0],
        55.0,
    ),
    (
        "def f() { x = 0; if (x != 0 and 1 / x > 0) { return 1; } return 0; }",
        "f",
        &[],
        0.0,
    ),
    (
        "def f(x) { if (x > 0) { return 1; } elif (x < 0) { return -1; } \
         else { return 0; } }",
        "f",
        &[-5.0],
        -1.0,
    ),
    ("def f() { x = 2; x += 3 * 4; return x; }", "f", &[], 14.0),
    ("def f(x) { return -x + max(pow(2, 3), pi()); }", "f", &[4.0], 4.0),
    (
        "g = 3; def f() { global g; g = g * 7; return g; }",
        "f",
        &[],
        21.0,
    ),
];

/// Broken programs that load fine and fail at call time — the error
/// string must be identical on both runners.
const ERROR_CORPUS: &[(&str, &str, &[f64])] = &[
    ("def f() { return missing; }", "f", &[]),
    ("def f() { xs = zeros(2); return xs[5]; }", "f", &[]),
    ("def f() { xs = zeros(2); return xs + 1; }", "f", &[]),
    ("def g(a) { return a; } def f() { return g(); }", "f", &[]),
    ("def f() { return nosuchfn(1); }", "f", &[]),
];

fn nums(args: &[f64]) -> Vec<Value> {
    args.iter().map(|&a| Value::Num(a)).collect()
}

#[test]
fn vm_values_match_the_tree_walk_across_the_corpus() {
    for &(src, func, args, want) in CORPUS {
        let args = nums(args);
        let tree = Interpreter::load(src)
            .unwrap()
            .call(func, &args)
            .unwrap()
            .as_num()
            .unwrap();
        let vm = Vm::load(src).unwrap().call(func, &args).unwrap().as_num().unwrap();
        assert_eq!(tree.to_bits(), vm.to_bits(), "{src}");
        assert_eq!(tree, want, "{src}: corpus expectation drifted");
    }
}

#[test]
fn runtime_errors_match_the_tree_walk_verbatim() {
    for &(src, func, args) in ERROR_CORPUS {
        let args = nums(args);
        let tree = Interpreter::load(src).unwrap().call(func, &args).unwrap_err();
        let vm = Vm::load(src).unwrap().call(func, &args).unwrap_err();
        assert_eq!(format!("{tree}"), format!("{vm}"), "{src}");
    }
    // Calling a function that does not exist errors identically too.
    let tree = Interpreter::load("x = 1;").unwrap().call("nope", &[]).unwrap_err();
    let vm = Vm::load("x = 1;").unwrap().call("nope", &[]).unwrap_err();
    assert_eq!(format!("{tree}"), format!("{vm}"));
}

#[test]
fn rng_draw_order_is_preserved_by_compilation() {
    // uniform() calls threaded through loops, conditions and nested
    // calls: the VM must consume the PCG stream in exactly the
    // tree-walk's order, so equal seeds give bit-equal results.
    let src = "def inner() { return uniform(0, 1); } \
               def draw(n) { s = 0; for i = 0, n { u = uniform(-1, 1); \
               if (u > 0) { s += u * inner(); } else { s -= u * 0.5; } } return s; }";
    for seed in [0u64, 7, 42, 0xdead_beef] {
        let mut tree = Interpreter::load(src).unwrap();
        let mut vm = Vm::load(src).unwrap();
        tree.seed_with_stream(seed, 17);
        vm.seed_with_stream(seed, 17);
        for _ in 0..5 {
            let a = tree.call("draw", &[Value::Num(20.0)]).unwrap().as_num().unwrap();
            let b = vm.call("draw", &[Value::Num(20.0)]).unwrap().as_num().unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
        }
    }
}

#[test]
fn front_end_stages_round_trip() {
    // lex → parse → compile must agree with the one-shot compile_src on
    // every corpus program and on the shipped env sources, and parse
    // errors must surface identically from both loaders.
    let mut sources: Vec<&str> = CORPUS.iter().map(|&(src, ..)| src).collect();
    sources.extend([
        cairl::script::envs::CARTPOLE_SRC,
        cairl::script::envs::MOUNTAINCAR_SRC,
        cairl::script::envs::ACROBOT_SRC,
        cairl::script::envs::PENDULUM_SRC,
    ]);
    for src in sources {
        assert!(!lex(src).unwrap().is_empty(), "{src}");
        let ast = parse(src).unwrap();
        let direct = compile_src(src).unwrap();
        let via_ast = compile(&ast).unwrap();
        // Op carries no PartialEq; the Debug rendering is exact.
        assert_eq!(format!("{:?}", direct.code), format!("{:?}", via_ast.code));
        assert_eq!(direct.global_names, via_ast.global_names);
        assert_eq!(direct.funcs.len(), via_ast.funcs.len());
    }
    for bad in ["def f( {", "x = ;", "def f() { if (1 { return 1; } }"] {
        let tree = Interpreter::load(bad).unwrap_err();
        let compiled = compile_src(bad).unwrap_err();
        assert_eq!(format!("{tree}"), format!("{compiled}"), "{bad}");
    }
}

/// Step both env adapters over the same deterministic action tape
/// (Env-level auto-reset on done) and compare the full streams bitwise.
fn assert_env_parity(id: &str, src: &str, stream: u64, steps: usize) {
    let mut tree = ScriptEnv::try_load(id, src, stream, RenderHint::None).unwrap();
    let mut vm = CompiledScriptEnv::try_load(id, src, stream, RenderHint::None).unwrap();
    assert_eq!(tree.obs_dim(), vm.obs_dim(), "{id}");
    assert_eq!(tree.action_space(), vm.action_space(), "{id}");
    let space = tree.action_space();
    let d = tree.obs_dim();
    let mut rng = Pcg32::new(0xac7_1011, 3);
    let tape: Vec<Action> = (0..steps).map(|_| space.sample(&mut rng)).collect();
    let run = |env: &mut dyn Env| -> (Vec<f32>, Vec<Transition>) {
        let mut obs = vec![f32::NAN; d];
        let mut obs_stream = Vec::new();
        let mut tr_stream = Vec::new();
        env.seed(99);
        env.reset_into(&mut obs);
        obs_stream.extend_from_slice(&obs);
        for action in &tape {
            let t = env.step_into(action, &mut obs);
            obs_stream.extend_from_slice(&obs);
            tr_stream.push(t);
            if t.done {
                env.reset_into(&mut obs);
                obs_stream.extend_from_slice(&obs);
            }
        }
        (obs_stream, tr_stream)
    };
    let (obs_tree, tr_tree) = run(&mut tree);
    let (obs_vm, tr_vm) = run(&mut vm);
    assert_eq!(tr_tree, tr_vm, "{id}: transitions diverged");
    assert_eq!(
        obs_tree.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        obs_vm.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "{id}: observations diverged"
    );
}

#[test]
fn compiled_envs_match_tree_walk_envs_on_the_builtin_sources() {
    assert_env_parity("Script/CartPole-v1", cairl::script::envs::CARTPOLE_SRC, 11, 300);
    assert_env_parity("Script/MountainCar-v0", cairl::script::envs::MOUNTAINCAR_SRC, 12, 300);
    assert_env_parity("Script/Acrobot-v1", cairl::script::envs::ACROBOT_SRC, 13, 200);
    assert_env_parity("Script/Pendulum-v1", cairl::script::envs::PENDULUM_SRC, 14, 200);
}

#[test]
fn compiled_env_matches_tree_walk_on_the_example_script() {
    // The user-facing example (`cairl run --register-script
    // MyEnv=examples/bounce.mpy`) through both runners.
    let src = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/bounce.mpy"
    ))
    .unwrap();
    assert_env_parity("Script/Bounce-v0", &src, 5, 400);
}

#[test]
fn script_trajectories_are_thread_count_invariant() {
    // The determinism-matrix hook: fused (bytecode SoA) script lanes
    // must reproduce the single-thread trajectory at every worker
    // count, mixture grouping included.
    let spec = "Script/CartPole-v1?max_steps=25:3,Script/MountainCar-v0?max_steps=30:3";
    let build = |kind: ExecutorKind, threads: usize| {
        build_executor_with_kernel(spec, kind, 1, threads, 5, &[], KernelMode::Fused).unwrap()
    };
    let mut reference = build(ExecutorKind::Sequential, 1);
    let specs = reference.lane_specs().to_vec();
    let mut rng = Pcg32::new(0x7ead5, 1);
    let tape: Vec<Vec<Action>> = (0..90)
        .map(|_| specs.iter().map(|s| s.action_space.sample(&mut rng)).collect())
        .collect();
    let run = |exec: &mut dyn BatchedExecutor| -> (Vec<f32>, Vec<Transition>) {
        let n = exec.num_lanes();
        let d = exec.obs_dim();
        let mut obs = vec![f32::NAN; n * d];
        let mut tr = vec![Transition::default(); n];
        let mut obs_stream = Vec::new();
        let mut tr_stream = Vec::new();
        exec.reset_into(&mut obs);
        obs_stream.extend_from_slice(&obs);
        for actions in &tape {
            exec.step_into(actions, &mut obs, &mut tr);
            obs_stream.extend_from_slice(&obs);
            tr_stream.extend_from_slice(&tr);
        }
        (obs_stream, tr_stream)
    };
    let want = run(reference.as_mut());
    for kind in [ExecutorKind::PoolSync, ExecutorKind::PoolAsync] {
        for threads in common::test_threads() {
            let mut exec = build(kind, threads);
            assert_eq!(exec.lane_specs(), &specs[..]);
            assert_eq!(
                run(exec.as_mut()),
                want,
                "{kind:?} at {threads} threads diverged"
            );
        }
    }
}

#[test]
fn hot_reload_reaches_both_runners_through_the_registry() {
    // register_script → make() env (tree-walk) and a fused executor
    // (bytecode batch): re-registering swaps the program for live envs
    // at their next reset and for every build thereafter.
    const SRC_A: &str = "obs_dim = 1; n_actions = 2; t = 0; \
        def reset() { global t; t = 0; return [0.5]; } \
        def step(action) { global t; t = t + 1; done = 0; \
        if (t >= 5) { done = 1; } return [0.5, 1.0, done]; }";
    const SRC_B: &str = "obs_dim = 1; n_actions = 2; t = 0; \
        def reset() { global t; t = 0; return [0.25]; } \
        def step(action) { global t; t = t + 1; done = 0; \
        if (t >= 5) { done = 1; } return [0.25, 2.0, done]; }";
    let id = registry::register_script("VmHotReload", SRC_A).unwrap();
    assert_eq!(id, "Script/VmHotReload");
    assert!(registry::env_spec(&id).unwrap().batch_capable(), "{id}");

    let mut env = cairl::make(&id).unwrap();
    env.seed(1);
    assert_eq!(env.reset(), vec![0.5]);

    registry::register_script("VmHotReload", SRC_B).unwrap();
    // The live tree-walk env rebuilds at its next reset...
    assert_eq!(env.reset(), vec![0.25]);
    let step = env.step(&Action::Discrete(0));
    assert_eq!(step.reward, 2.0);
    // ...and a fresh fused build snapshots the new program.
    let mut exec = build_executor_with_kernel(
        &format!("{id}:2"),
        ExecutorKind::PoolSync,
        1,
        2,
        7,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let mut obs = vec![f32::NAN; exec.num_lanes() * exec.obs_dim()];
    exec.reset_into(&mut obs);
    assert_eq!(obs, vec![0.25, 0.25]);
    let mut tr = vec![Transition::default(); exec.num_lanes()];
    exec.step_into(
        &[Action::Discrete(0), Action::Discrete(1)],
        &mut obs,
        &mut tr,
    );
    assert!(tr.iter().all(|t| t.reward == 2.0));
}

#[test]
fn compiled_env_spaces_match_the_script_protocol() {
    // The VM adapter derives spaces from the same obs_dim/n_actions
    // globals as the tree-walk adapter.
    let mut env = CompiledScriptEnv::try_load(
        "Script/UnitSpaces",
        "obs_dim = 3; n_actions = 4; \
         def reset() { return [0, 0, 0]; } \
         def step(a) { return [0, 0, 0, 1, 0]; }",
        1,
        RenderHint::None,
    )
    .unwrap();
    env.probe().unwrap();
    assert_eq!(env.action_space(), Space::Discrete { n: 4 });
    assert_eq!(env.obs_dim(), 3);
    // Shape violations carry the ScriptEnv error wording.
    let err = CompiledScriptEnv::try_load(
        "Script/UnitBad",
        "obs_dim = 2; n_actions = 2; def reset() { return [0]; } \
         def step(a) { return [0, 0, 1, 0]; }",
        1,
        RenderHint::None,
    )
    .and_then(|mut env| env.probe())
    .unwrap_err();
    assert!(format!("{err}").contains("reset()"), "{err}");
}
