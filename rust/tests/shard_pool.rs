//! Integration: the sharded environment service.
//!
//! The load-bearing invariant: a [`ShardedEnvPool`] is a pure
//! *transport* transform — for the same env spec and seed, a sharded
//! run reproduces the local executor's trajectories **bit for bit**,
//! across 1 and 2 shards, scalar and fused serving kernels,
//! heterogeneous mixtures (padded-obs reassembly included), any
//! pipeline depth, and **across mid-workload connection kills** (the
//! failover replay log reconstructs lost lanes exactly).  On top of
//! that: the protocol rejects truncated/corrupt/mis-sequenced frames
//! with errors (never panics), the daemon enforces lane budgets
//! (`Busy`) and auth tokens, `shard_status` reports the live client
//! table, the cost-aware [`ShardPlan`] places mixtures unevenly
//! (asserted on the plan, not wall-clock), and the free-running
//! workload and batched greedy evaluation run unchanged over shards.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU32, Ordering};

use cairl::coordinator::experiment::{
    build_executor_with_kernel, run_batched_workload, run_random_workload, ExecutorKind,
    KernelMode,
};
use cairl::coordinator::pool::{BatchedExecutor, EnvPool, LaneSpec};
use cairl::coordinator::registry::MixtureEntry;
use cairl::core::env::Transition;
use cairl::core::error::CairlError;
use cairl::core::rng::Pcg32;
use cairl::core::spaces::Action;
use cairl::shard::{
    proto, shard_status, ConnectOptions, FailoverConfig, ServeConfig, ShardClient, ShardPlan,
    ShardPoolOptions, ShardServer, ShardedEnvPool,
};

const MIX: &str = "CartPole-v1?max_steps=25:3,MountainCar-v0?max_steps=30:3";
const STEPS: usize = 70;
const SEED: u64 = 21;

/// Uniform synthetic costs: placement becomes deterministic (no
/// wall-clock calibration inside the bit-equality tests).
fn uniform_costs() -> BTreeMap<String, f64> {
    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1?max_steps=25".to_string(), 1.0);
    costs.insert("MountainCar-v0?max_steps=30".to_string(), 1.0);
    costs
}

/// Unique listen address per server (unix socket on unix, TCP loopback
/// elsewhere).
fn fresh_addr() -> String {
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let k = NEXT.fetch_add(1, Ordering::Relaxed);
    #[cfg(unix)]
    {
        let path = std::env::temp_dir().join(format!(
            "cairl-shard-test-{}-{k}.sock",
            std::process::id()
        ));
        format!("unix://{}", path.display())
    }
    #[cfg(not(unix))]
    {
        let _ = k;
        "tcp://127.0.0.1:0".to_string()
    }
}

/// Spawn `shards` daemons with the given serving kernel, returning
/// their dialable addresses plus the shutdown handles.
fn spawn_shards(
    shards: usize,
    kernel: KernelMode,
) -> (Vec<String>, Vec<cairl::shard::ShardServerHandle>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..shards {
        let config = ServeConfig {
            kernel,
            threads: 2,
            ..ServeConfig::new("CartPole-v1")
        };
        let server = ShardServer::bind(&fresh_addr(), config).expect("bind shard");
        addrs.push(server.local_addr());
        handles.push(server.spawn());
    }
    (addrs, handles)
}

/// Deterministic action tape from the per-lane action spaces.
fn action_tape(specs: &[LaneSpec], steps: usize) -> Vec<Vec<Action>> {
    let mut rng = Pcg32::new(0x5aa4d, 42);
    (0..steps)
        .map(|_| specs.iter().map(|s| s.action_space.sample(&mut rng)).collect())
        .collect()
}

/// Replay a tape, returning the full (obs, transition) stream.
fn trajectory(
    exec: &mut dyn BatchedExecutor,
    tape: &[Vec<Action>],
) -> (Vec<f32>, Vec<Transition>) {
    let n = exec.num_lanes();
    let d = exec.obs_dim();
    let mut obs = vec![f32::NAN; n * d];
    let mut tr = vec![Transition::default(); n];
    let mut obs_stream = Vec::new();
    let mut tr_stream = Vec::new();
    exec.reset_into(&mut obs);
    obs_stream.extend_from_slice(&obs);
    for actions in tape {
        exec.step_into(actions, &mut obs, &mut tr);
        obs_stream.extend_from_slice(&obs);
        tr_stream.extend_from_slice(&tr);
    }
    (obs_stream, tr_stream)
}

#[test]
fn sharded_mixture_is_bit_identical_to_local_across_shards_and_kernels() {
    // Local reference: sequential, scalar kernel.
    let mut local = build_executor_with_kernel(
        MIX,
        ExecutorKind::Sequential,
        1,
        1,
        SEED,
        &[],
        KernelMode::Scalar,
    )
    .unwrap();
    let specs_ref = local.lane_specs().to_vec();
    let tape = action_tape(&specs_ref, STEPS);
    let (obs_ref, tr_ref) = trajectory(local.as_mut(), &tape);
    let ends = tr_ref.iter().filter(|t| t.done || t.truncated).count();
    assert!(ends > 0, "the tape must exercise auto-reset");

    for shards in [1usize, 2] {
        for kernel in [KernelMode::Scalar, KernelMode::Fused] {
            let (addrs, handles) = spawn_shards(shards, kernel);
            let mut pool =
                ShardedEnvPool::connect_with_costs(&addrs, MIX, 1, SEED, &uniform_costs())
                    .unwrap();
            assert_eq!(pool.shards(), shards);
            assert_eq!(pool.num_lanes(), 6);
            // The remote layout is indistinguishable from the local one.
            assert_eq!(pool.obs_dim(), 4, "{shards} shards, {kernel:?}");
            assert_eq!(
                pool.lane_specs(),
                &specs_ref[..],
                "{shards} shards, {kernel:?}: lane specs diverged"
            );
            let (obs, tr) = trajectory(&mut pool, &tape);
            assert_eq!(
                tr_ref, tr,
                "{shards} shards, {kernel:?}: transitions diverged"
            );
            assert_eq!(
                obs_ref, obs,
                "{shards} shards, {kernel:?}: observations diverged"
            );
            drop(pool);
            for handle in handles {
                handle.shutdown();
            }
        }
    }
}

#[test]
fn sharded_padding_reassembles_and_zeroes_tails() {
    // Shard 1 hosts only MountainCar lanes (local padding 2) inside a
    // pool padded to 4: reassembly must re-pad and zero the tails.
    let (addrs, handles) = spawn_shards(2, KernelMode::Fused);
    let mut pool =
        ShardedEnvPool::connect_with_costs(&addrs, MIX, 1, SEED, &uniform_costs()).unwrap();
    let specs = pool.lane_specs().to_vec();
    assert_eq!(specs[5].obs_dim, 2);
    let tape = action_tape(&specs, 30);
    let (obs, _) = trajectory(&mut pool, &tape);
    for frame in obs.chunks(6 * 4) {
        for spec in specs.iter().filter(|s| s.obs_dim < 4) {
            assert_eq!(
                &frame[spec.offset + spec.obs_dim..spec.offset + 4],
                &[0.0, 0.0],
                "padded tail must stay zero through reassembly"
            );
        }
    }
    drop(pool);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn sharded_random_workload_counts_match_local() {
    // The free-running rollout crosses the wire once per shard and
    // draws lane action streams from *global* lane ids, so counts are
    // identical to the local pool's.
    let spec = "CartPole-v1?max_steps=40:4,MountainCar-v0?max_steps=35:2";
    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1?max_steps=40".to_string(), 1.0);
    costs.insert("MountainCar-v0?max_steps=35".to_string(), 1.0);
    let mut local = cairl::coordinator::experiment::build_env_pool_shard(
        spec,
        1,
        2,
        SEED,
        0,
        KernelMode::Fused,
        &[],
    )
    .unwrap();
    let local_result = run_random_workload(&mut local, 300);
    assert_eq!(local_result.steps, 6 * 300);
    assert!(local_result.episodes > 10);

    let (addrs, handles) = spawn_shards(2, KernelMode::Fused);
    let mut pool = ShardedEnvPool::connect_with_costs(&addrs, spec, 1, SEED, &costs).unwrap();
    let sharded_result = run_random_workload(&mut pool, 300);
    assert_eq!(
        (local_result.steps, local_result.episodes),
        (sharded_result.steps, sharded_result.episodes),
        "free-running counts must be shard-layout invariant"
    );
    drop(pool);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn sharded_greedy_evaluation_matches_local() {
    use cairl::agents::dqn::evaluate_greedy_batched;
    use cairl::runtime::dqn_exec::DqnExecutor;
    // One fixed network evaluated over a local pool and a sharded pool:
    // identical lanes, identical greedy trajectories, identical stats.
    let exec = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 5);
    let mut local = EnvPool::new(4, 33, 2, || cairl::make("CartPole-v1?max_steps=50").unwrap());
    let local_out = evaluate_greedy_batched(&exec, &mut local, 120);
    assert!(local_out.episodes > 0);

    let (addrs, handles) = spawn_shards(2, KernelMode::Fused);
    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1?max_steps=50".to_string(), 1.0);
    let mut pool =
        ShardedEnvPool::connect_with_costs(&addrs, "CartPole-v1?max_steps=50", 4, 33, &costs)
            .unwrap();
    let sharded_out = evaluate_greedy_batched(&exec, &mut pool, 120);
    assert_eq!(local_out.episodes, sharded_out.episodes);
    assert_eq!(local_out.lane_steps, sharded_out.lane_steps);
    assert_eq!(local_out.mean_return, sharded_out.mean_return);
    drop(pool);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn cost_aware_plan_places_skewed_mixtures_unevenly() {
    // The ISSUE acceptance shape: CartPole-v1:32,GridRTS-v0:4 with
    // GridRTS costed far above CartPole.  Asserted on the plan itself.
    let entries = vec![
        MixtureEntry::bare("CartPole-v1", 32),
        MixtureEntry::bare("GridRTS-v0", 4),
    ];
    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1".to_string(), 1.0);
    costs.insert("GridRTS-v0".to_string(), 50.0);
    let plan = ShardPlan::plan(&entries, 2, &costs).unwrap();
    let a = plan.assignments();
    assert_eq!(a.len(), 2);
    assert_eq!(a[0].lanes + a[1].lanes, 36);
    assert_ne!(
        (a[0].lanes, a[1].lanes),
        (18, 18),
        "cost-aware placement must not fall back to an even lane split"
    );
    // The cheap-heavy shard carries far more lanes; modelled costs land
    // near parity.
    assert!(a[0].lanes >= 30, "shard 0 got {} lanes", a[0].lanes);
    assert!(a[1].lanes <= 6, "shard 1 got {} lanes", a[1].lanes);
    let ratio = a[0].cost / a[1].cost;
    assert!((0.3..3.0).contains(&ratio), "cost ratio {ratio}");
    // Contiguity: the plan covers lanes [0, 36) in order.
    assert_eq!(a[0].first_lane, 0);
    assert_eq!(a[1].first_lane, a[0].lanes);
    // Calibration itself orders the real costs correctly: a GridRTS
    // step costs (much) more than a fused-able CartPole step.
    let measured = cairl::shard::calibrate_costs(&entries).unwrap();
    assert!(measured["GridRTS-v0"] > measured["CartPole-v1"]);
}

#[test]
fn serve_wrap_chains_apply_server_side_and_match_local() {
    use cairl::wrappers::WrapperSpec;
    const CHAIN: &str = "TimeLimit(25),RewardScale(0.5)";
    // Local reference: the same pool-level chain applied in process.
    let chain = WrapperSpec::parse_chain(CHAIN).unwrap();
    let mut local = build_executor_with_kernel(
        "CartPole-v1",
        ExecutorKind::Sequential,
        4,
        1,
        SEED,
        &chain,
        KernelMode::Fused,
    )
    .unwrap();
    let specs = local.lane_specs().to_vec();
    let tape = action_tape(&specs, 60);
    let (obs_ref, tr_ref) = trajectory(local.as_mut(), &tape);
    assert!(
        tr_ref.iter().any(|t| t.truncated),
        "TimeLimit(25) must truncate within the tape"
    );
    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1".to_string(), 1.0);

    // Client-supplied wrap: travels in the Hello `wrap` field and is
    // applied by the daemon — bit-identical to the local chain.
    let (addrs, handles) = spawn_shards(1, KernelMode::Fused);
    let mut pool = ShardedEnvPool::connect_opts(
        &addrs,
        "CartPole-v1",
        ShardPoolOptions {
            lanes: 4,
            base_seed: SEED,
            wrap: CHAIN.to_string(),
            costs: Some(costs.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    let (obs, tr) = trajectory(&mut pool, &tape);
    assert_eq!(tr_ref, tr, "client-wrap transitions diverged");
    assert_eq!(obs_ref, obs, "client-wrap observations diverged");
    drop(pool);
    handles.into_iter().for_each(|h| h.shutdown());

    // Daemon-default wrap: an empty client wrap defers to the
    // `cairl serve --wrap` chain.
    let server = ShardServer::bind(
        &fresh_addr(),
        ServeConfig {
            wrap: CHAIN.to_string(),
            threads: 2,
            ..ServeConfig::new("CartPole-v1")
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();
    let mut pool = ShardedEnvPool::connect_opts(
        &[addr.clone()],
        "CartPole-v1",
        ShardPoolOptions {
            lanes: 4,
            base_seed: SEED,
            costs: Some(costs),
            ..Default::default()
        },
    )
    .unwrap();
    let (obs, tr) = trajectory(&mut pool, &tape);
    assert_eq!(tr_ref, tr, "daemon-default wrap transitions diverged");
    assert_eq!(obs_ref, obs, "daemon-default wrap observations diverged");
    drop(pool);
    handle.shutdown();

    // Malformed chains fail fast: at bind time for the daemon default,
    // at connect time for the client option, and over the wire for a
    // raw Hello.
    assert!(ShardServer::bind(
        &fresh_addr(),
        ServeConfig {
            wrap: "TimeLimit(".to_string(),
            ..ServeConfig::new("CartPole-v1")
        },
    )
    .is_err());
    let (addrs, handles) = spawn_shards(1, KernelMode::Fused);
    assert!(ShardedEnvPool::connect_opts(
        &addrs,
        "CartPole-v1",
        ShardPoolOptions {
            wrap: "NotAWrapper".to_string(),
            ..Default::default()
        },
    )
    .is_err());
    let opts = ConnectOptions {
        wrap: "NotAWrapper".to_string(),
        ..ConnectOptions::default()
    };
    let err = match ShardClient::connect_with(&addrs[0], "CartPole-v1:1", 0, 0, &opts) {
        Ok(_) => panic!("daemon must reject an unknown wrapper"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("wrap"), "{err}");
    handles.into_iter().for_each(|h| h.shutdown());
}

#[test]
fn protocol_fuzz_rejects_corruption_without_panicking() {
    // Random mutations over every message shape: decoding must always
    // return (Ok or Err), never panic, and any Ok must re-encode to a
    // self-consistent frame.
    let specs = vec![LaneSpec {
        env_id: "CartPole-v1".into(),
        obs_dim: 4,
        offset: 0,
        action_space: cairl::core::spaces::Space::Discrete { n: 2 },
    }];
    let frames: Vec<Vec<u8>> = vec![
        proto::encode(
            1,
            proto::MsgRef::Hello {
                spec: MIX,
                base_seed: 7,
                first_lane: 3,
                pipeline: 4,
                token: "s3cret",
                wrap: "TimeLimit(25)",
            },
        ),
        proto::encode(
            1,
            proto::MsgRef::Spec {
                obs_dim: 4,
                lane_specs: &specs,
            },
        ),
        proto::encode(
            2,
            proto::MsgRef::Step {
                actions: &[Action::Discrete(1), Action::Continuous(vec![0.25, -1.0])],
            },
        ),
        proto::encode(
            2,
            proto::MsgRef::StepResult {
                obs: &[0.0, 1.0, 2.0, 3.0],
                transitions: &[Transition::live(1.0)],
            },
        ),
        proto::encode(
            3,
            proto::MsgRef::Busy {
                active_lanes: 96,
                max_lanes: 96,
                retry_ms: 50,
            },
        ),
        proto::encode(proto::SEQ_NONE, proto::MsgRef::Error { message: "x" }),
    ];
    let mut rng = Pcg32::new(0xf522, 2);
    let mut rejected = 0u32;
    for frame in &frames {
        // Single-byte corruption at every offset.
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 1 << (rng.below(8) as u8);
            let mut cursor = &bad[..];
            if proto::read_msg(&mut cursor).is_err() {
                rejected += 1;
            }
        }
        // Truncation at every length.
        for keep in 0..frame.len() {
            let mut cursor = &frame[..keep];
            assert!(proto::read_msg(&mut cursor).is_err());
        }
        // Multi-byte random garbage.
        for _ in 0..200 {
            let mut bad = frame.clone();
            for _ in 0..1 + rng.below(6) {
                let idx = rng.below(bad.len() as u32) as usize;
                bad[idx] = rng.below(256) as u8;
            }
            let mut cursor = &bad[..];
            let _ = proto::read_msg(&mut cursor); // must not panic
        }
    }
    assert!(rejected > 0, "corruption must be detected");
}

#[test]
fn server_rejects_bad_hellos_and_garbage_streams() {
    let (addrs, handles) = spawn_shards(1, KernelMode::Fused);

    // Unknown env spec in the handshake: a clean Error, not a hang.
    let err = cairl::shard::ShardClient::connect(&addrs[0], "NoSuchEnv-v0:4", 0, 0).unwrap_err();
    assert!(
        matches!(err, CairlError::Shard(_)),
        "expected a shard error, got {err}"
    );
    assert!(err.to_string().contains("NoSuchEnv-v0"), "{err}");

    // Raw garbage bytes: the daemon answers with an Error frame (or
    // hangs up) and stays alive for the next client.
    {
        let addr = cairl::shard::ShardAddr::parse(&addrs[0]).unwrap();
        match addr {
            #[cfg(unix)]
            cairl::shard::ShardAddr::Unix(path) => {
                let mut stream = std::os::unix::net::UnixStream::connect(path).unwrap();
                stream.write_all(&[0xde, 0xad, 0xbe, 0xef, 1, 2, 3]).unwrap();
                let _ = stream.flush();
            }
            cairl::shard::ShardAddr::Tcp(hp) => {
                let mut stream = std::net::TcpStream::connect(hp).unwrap();
                stream.write_all(&[0xde, 0xad, 0xbe, 0xef, 1, 2, 3]).unwrap();
                let _ = stream.flush();
            }
        }
    }

    // The daemon still serves a well-formed client afterwards.
    let client = cairl::shard::ShardClient::connect(&addrs[0], "CartPole-v1:2", 0, 0).unwrap();
    assert_eq!(client.num_lanes(), 2);
    drop(client);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn tcp_shards_round_trip_too() {
    // Port 0: the daemon reports the real bound port and the client
    // dials it — the cross-host transport in one process.
    let server =
        ShardServer::bind("tcp://127.0.0.1:0", ServeConfig::new("CartPole-v1")).unwrap();
    let addr = server.local_addr();
    assert!(addr.starts_with("tcp://127.0.0.1:"), "{addr}");
    let handle = server.spawn();

    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1?max_steps=30".to_string(), 1.0);
    let mut pool = ShardedEnvPool::connect_with_costs(
        &[addr],
        "CartPole-v1?max_steps=30",
        3,
        9,
        &costs,
    )
    .unwrap();
    let mut local = build_executor_with_kernel(
        "CartPole-v1?max_steps=30",
        ExecutorKind::Sequential,
        3,
        1,
        9,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let tape = action_tape(&local.lane_specs().to_vec(), 50);
    assert_eq!(trajectory(local.as_mut(), &tape), trajectory(&mut pool, &tape));
    drop(pool);
    handle.shutdown();
}

/// Quick failover policy for tests: short backoff, a few re-dials.
fn fast_failover() -> FailoverConfig {
    FailoverConfig {
        redial_attempts: 5,
        backoff_ms: 5,
        backoff_cap_ms: 40,
        replan: true,
    }
}

#[test]
fn pipelined_driver_matches_lockstep_returns_at_any_depth() {
    // The pipelined driver samples actions obs-independently in batch
    // order — the same RNG stream as the lockstep loop — so its
    // episode-return log must match byte for byte at every depth.
    let mut local = build_executor_with_kernel(
        MIX,
        ExecutorKind::Sequential,
        1,
        1,
        SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let reference = run_batched_workload(local.as_mut(), 80, SEED);
    assert!(reference.episodes > 0);

    let (addrs, handles) = spawn_shards(2, KernelMode::Fused);
    for depth in [1usize, 2, 4] {
        let opts = ShardPoolOptions {
            base_seed: SEED,
            pipeline: depth,
            costs: Some(uniform_costs()),
            failover: fast_failover(),
            ..Default::default()
        };
        let mut pool = ShardedEnvPool::connect_opts(&addrs, MIX, opts).unwrap();
        assert_eq!(pool.pipeline_depth(), depth);
        let r = pool.run_pipelined_workload(80, SEED);
        assert_eq!(r.steps, reference.steps, "depth {depth}");
        assert_eq!(r.episodes, reference.episodes, "depth {depth}");
        assert_eq!(
            r.episode_returns, reference.episode_returns,
            "depth {depth}: episode returns diverged"
        );
        assert_eq!(pool.reconnects(), &[0, 0], "healthy run must not reconnect");
    }
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn failover_replays_killed_connections_bit_exactly() {
    // Kill every live connection on both daemons mid-tape (daemons stay
    // up): the pool must re-dial, replay its operation log against the
    // fresh executors, and finish with a bit-identical trajectory.
    let mut local = build_executor_with_kernel(
        MIX,
        ExecutorKind::Sequential,
        1,
        1,
        SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let tape = action_tape(&local.lane_specs().to_vec(), STEPS);
    let (obs_ref, tr_ref) = trajectory(local.as_mut(), &tape);

    let (addrs, handles) = spawn_shards(2, KernelMode::Fused);
    let opts = ShardPoolOptions {
        base_seed: SEED,
        costs: Some(uniform_costs()),
        failover: fast_failover(),
        ..Default::default()
    };
    let mut pool = ShardedEnvPool::connect_opts(&addrs, MIX, opts).unwrap();
    let n = pool.num_lanes();
    let d = pool.obs_dim();
    let mut obs = vec![f32::NAN; n * d];
    let mut tr = vec![Transition::default(); n];
    let mut obs_stream = Vec::new();
    let mut tr_stream = Vec::new();
    pool.reset_into(&mut obs);
    obs_stream.extend_from_slice(&obs);
    for (i, actions) in tape.iter().enumerate() {
        if i == STEPS / 2 {
            let killed: usize = handles.iter().map(|h| h.kill_connections()).sum();
            assert!(killed >= 2, "expected live connections to kill, got {killed}");
        }
        pool.step_into(actions, &mut obs, &mut tr);
        obs_stream.extend_from_slice(&obs);
        tr_stream.extend_from_slice(&tr);
    }
    assert_eq!(tr_ref, tr_stream, "transitions diverged across the kill");
    assert_eq!(obs_ref, obs_stream, "observations diverged across the kill");
    let reconnects: u64 = pool.reconnects().iter().sum();
    assert!(reconnects >= 2, "both shards must have failed over: {reconnects}");
    drop(pool);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn pipelined_workload_survives_mid_run_kill_with_identical_returns() {
    // The acceptance shape: a heterogeneous workload at depth >= 2 with
    // connections killed mid-run.  This replicates the pipelined driver
    // loop so the kill lands at a deterministic batch index.
    let steps_per_lane = 120u64;
    let mut local = build_executor_with_kernel(
        MIX,
        ExecutorKind::Sequential,
        1,
        1,
        SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let reference = run_batched_workload(local.as_mut(), steps_per_lane, SEED);

    let (addrs, handles) = spawn_shards(2, KernelMode::Fused);
    let opts = ShardPoolOptions {
        base_seed: SEED,
        pipeline: 3,
        costs: Some(uniform_costs()),
        failover: fast_failover(),
        ..Default::default()
    };
    let mut pool = ShardedEnvPool::connect_opts(&addrs, MIX, opts).unwrap();
    let specs = pool.lane_specs().to_vec();
    let n = pool.num_lanes();
    let mut rng = Pcg32::new(SEED, 23);
    let mut obs = vec![0.0f32; n * pool.obs_dim()];
    let mut transitions = vec![Transition::default(); n];
    let mut actions: Vec<Action> = Vec::with_capacity(n);
    pool.reset_into(&mut obs);
    let mut episode_returns = Vec::new();
    let mut lane_return = vec![0.0f32; n];
    let mut episodes = 0u64;
    let (mut submitted, mut consumed) = (0u64, 0u64);
    while consumed < steps_per_lane {
        while submitted < steps_per_lane && pool.in_flight() < pool.pipeline_depth() {
            actions.clear();
            actions.extend(specs.iter().map(|s| s.action_space.sample(&mut rng)));
            pool.submit_step(&actions);
            submitted += 1;
        }
        if consumed == steps_per_lane / 2 {
            // The in-flight tail (up to depth batches) is replayed and
            // left pending on the fresh connections.
            let killed: usize = handles.iter().map(|h| h.kill_connections()).sum();
            assert!(killed >= 2, "expected live connections to kill, got {killed}");
        }
        pool.recv_oldest_step(&mut obs, &mut transitions);
        consumed += 1;
        for (acc, t) in lane_return.iter_mut().zip(&transitions) {
            *acc += t.reward;
            if t.done || t.truncated {
                episodes += 1;
                episode_returns.push(*acc);
                *acc = 0.0;
            }
        }
    }
    assert_eq!(episodes, reference.episodes);
    assert_eq!(
        episode_returns, reference.episode_returns,
        "episode returns diverged across a depth-3 mid-run kill"
    );
    let reconnects: u64 = pool.reconnects().iter().sum();
    assert!(reconnects >= 2, "both shards must have failed over: {reconnects}");
    drop(pool);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn shard_death_replans_onto_survivor_and_preserves_returns() {
    // A daemon that is gone for good (listener down, socket removed):
    // re-dials exhaust, the lost assignment re-plans onto the survivor,
    // and the workload's returns are still byte-identical to local.
    let mut local = build_executor_with_kernel(
        MIX,
        ExecutorKind::Sequential,
        1,
        1,
        SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let reference = run_batched_workload(local.as_mut(), 40, SEED);

    let (addrs, mut handles) = spawn_shards(2, KernelMode::Fused);
    let opts = ShardPoolOptions {
        base_seed: SEED,
        pipeline: 2,
        costs: Some(uniform_costs()),
        failover: FailoverConfig {
            redial_attempts: 1,
            backoff_ms: 5,
            backoff_cap_ms: 10,
            replan: true,
        },
        ..Default::default()
    };
    let mut pool = ShardedEnvPool::connect_opts(&addrs, MIX, opts).unwrap();
    assert_eq!(pool.shards(), 2);
    // Take shard 1's daemon down entirely.
    let dead = handles.remove(1);
    dead.kill_connections();
    dead.shutdown();

    let r = pool.run_pipelined_workload(40, SEED);
    assert_eq!(r.episodes, reference.episodes);
    assert_eq!(
        r.episode_returns, reference.episode_returns,
        "returns diverged after re-planning onto the survivor"
    );
    assert!(pool.reconnects()[1] >= 1, "shard 1 must have re-planned");
    drop(pool);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn lane_budget_answers_busy_and_frees_on_disconnect() {
    let config = ServeConfig {
        max_lanes: 2,
        threads: 1,
        ..ServeConfig::new("CartPole-v1")
    };
    let server = ShardServer::bind(&fresh_addr(), config).unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();

    let first = ShardClient::connect(&addr, "CartPole-v1:2", 0, 0).unwrap();
    assert_eq!(first.num_lanes(), 2);

    // Budget exhausted: an impatient client gets Unavailable, not a hang.
    let opts = ConnectOptions {
        busy_retries: 0,
        ..ConnectOptions::default()
    };
    let err = ShardClient::connect_with(&addr, "CartPole-v1:1", 0, 0, &opts).unwrap_err();
    assert!(
        matches!(err, CairlError::Unavailable(_)),
        "expected Unavailable, got {err}"
    );
    assert!(handle.stats().busy_rejections() >= 1);

    // A patient client wins the lanes once the first disconnects.
    drop(first);
    let opts = ConnectOptions {
        busy_retries: 40,
        ..ConnectOptions::default()
    };
    let second = ShardClient::connect_with(&addr, "CartPole-v1:2", 0, 0, &opts).unwrap();
    assert_eq!(second.num_lanes(), 2);
    drop(second);
    handle.shutdown();
}

#[test]
fn auth_token_gates_hello_and_status() {
    let config = ServeConfig {
        token: "s3cret".to_string(),
        threads: 1,
        ..ServeConfig::new("CartPole-v1")
    };
    let server = ShardServer::bind(&fresh_addr(), config).unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();

    let err = ShardClient::connect(&addr, "CartPole-v1:1", 0, 0).unwrap_err();
    assert!(err.to_string().contains("unauthorized"), "{err}");
    assert!(shard_status(&addr, "").is_err());
    assert!(shard_status(&addr, "wrong").is_err());

    let opts = ConnectOptions {
        token: "s3cret".to_string(),
        ..ConnectOptions::default()
    };
    let client = ShardClient::connect_with(&addr, "CartPole-v1:1", 0, 0, &opts).unwrap();
    assert_eq!(client.num_lanes(), 1);
    let report = shard_status(&addr, "s3cret").unwrap();
    assert!(report.contains("\"active_lanes\""), "{report}");
    drop(client);
    handle.shutdown();
}

#[test]
fn status_report_exposes_the_client_table() {
    let (addrs, handles) = spawn_shards(1, KernelMode::Fused);
    let opts = ConnectOptions {
        pipeline: 3,
        ..ConnectOptions::default()
    };
    let client = ShardClient::connect_with(&addrs[0], "CartPole-v1:2", 11, 0, &opts).unwrap();

    let report = shard_status(&addrs[0], "").unwrap();
    let v = cairl::core::json::parse(&report).unwrap();
    assert_eq!(
        v.get("proto_version").and_then(|x| x.as_usize()),
        Some(proto::PROTO_VERSION as usize)
    );
    assert_eq!(v.get("active_clients").and_then(|x| x.as_usize()), Some(1));
    assert_eq!(v.get("active_lanes").and_then(|x| x.as_usize()), Some(2));
    assert_eq!(v.get("max_lanes").and_then(|x| x.as_usize()), Some(0));
    let clients = v.get("clients").and_then(|x| x.as_array()).unwrap();
    assert_eq!(clients.len(), 1);
    assert_eq!(clients[0].get("lanes").and_then(|x| x.as_usize()), Some(2));
    assert_eq!(clients[0].get("pipeline").and_then(|x| x.as_usize()), Some(3));
    assert_eq!(
        clients[0].get("spec").and_then(|x| x.as_str()),
        Some("CartPole-v1:2")
    );
    // The status probe itself must not reserve lanes or count as a client.
    let again = shard_status(&addrs[0], "").unwrap();
    let v2 = cairl::core::json::parse(&again).unwrap();
    assert_eq!(v2.get("active_clients").and_then(|x| x.as_usize()), Some(1));
    drop(client);
    handles.into_iter().for_each(|h| h.shutdown());
}

#[test]
fn sequence_fuzz_accepts_only_strict_successors() {
    // Reorder / duplicate / stale-seq fuzz over the tracker: only the
    // strict successor ever advances, everything else errors (and does
    // not advance the window).
    let mut rng = Pcg32::new(0x5e9f, 7);
    let mut tracker = proto::SeqTracker::new();
    let mut expected = 1u32;
    let mut accepted = 0u32;
    for _ in 0..20_000 {
        let roll = rng.below(10);
        let candidate = match roll {
            0..=3 => expected,                            // in order
            4..=5 => expected.wrapping_sub(1 + rng.below(8)), // stale / duplicate
            6..=7 => expected.wrapping_add(1 + rng.below(8)), // gap / reorder
            _ => rng.below(u32::MAX),                     // anything
        };
        let ok = tracker.accept(candidate).is_ok();
        assert_eq!(
            ok,
            candidate == expected,
            "seq {candidate} vs expected {expected}"
        );
        if ok {
            accepted += 1;
            expected = proto::next_seq(expected);
        }
    }
    assert!(accepted > 1000, "fuzz must exercise the accept path");
    // Decoded frames carry their seq verbatim for the tracker to judge.
    for seq in [1u32, 2, 0xdead_beef, u32::MAX] {
        let frame = proto::encode(seq, proto::MsgRef::Reset);
        let mut cursor = &frame[..];
        assert_eq!(proto::read_msg(&mut cursor).unwrap().seq, seq);
    }
}

#[test]
fn server_closes_connections_on_sequence_violations() {
    use std::net::TcpStream;
    // Raw TCP so the test controls the seq bytes on the wire.
    let server =
        ShardServer::bind("tcp://127.0.0.1:0", ServeConfig::new("CartPole-v1")).unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();
    let hp = addr.strip_prefix("tcp://").unwrap();

    // A Hello arriving with seq 5 (expected 1): rejected as a gap, and
    // the error frame carries the reserved seq 0.
    {
        let mut stream = TcpStream::connect(hp).unwrap();
        stream
            .write_all(&proto::encode(
                5,
                proto::MsgRef::Hello {
                    spec: "CartPole-v1:1",
                    base_seed: 0,
                    first_lane: 0,
                    pipeline: 1,
                    token: "",
                    wrap: "",
                },
            ))
            .unwrap();
        let frame = proto::read_msg(&mut stream).unwrap();
        assert_eq!(frame.seq, proto::SEQ_NONE);
        match frame.msg {
            proto::Msg::Error { message } => {
                assert!(message.contains("sequence"), "{message}")
            }
            other => panic!("expected Error, got {other:?}"),
        }
        // The connection is closed after the violation.
        assert!(proto::read_msg(&mut stream).is_err());
    }

    // A duplicate seq after a good handshake: same rejection.
    {
        let mut stream = TcpStream::connect(hp).unwrap();
        stream
            .write_all(&proto::encode(
                1,
                proto::MsgRef::Hello {
                    spec: "CartPole-v1:1",
                    base_seed: 0,
                    first_lane: 0,
                    pipeline: 1,
                    token: "",
                    wrap: "",
                },
            ))
            .unwrap();
        let spec_frame = proto::read_msg(&mut stream).unwrap();
        assert_eq!(spec_frame.seq, 1);
        assert!(matches!(spec_frame.msg, proto::Msg::Spec { .. }));
        stream.write_all(&proto::encode(1, proto::MsgRef::Reset)).unwrap();
        let frame = proto::read_msg(&mut stream).unwrap();
        match frame.msg {
            proto::Msg::Error { message } => {
                assert!(message.contains("stale"), "{message}")
            }
            other => panic!("expected Error, got {other:?}"),
        }
        assert!(proto::read_msg(&mut stream).is_err());
    }

    // The daemon survives both abuses.
    let client = ShardClient::connect(&addr, "CartPole-v1:1", 0, 0).unwrap();
    assert_eq!(client.num_lanes(), 1);
    drop(client);
    handle.shutdown();
}
