//! Integration: fused SoA batch kernels vs scalar dispatch.
//!
//! The load-bearing invariant of the ISSUE-4 fusion refactor: **the
//! kernel mode is a pure performance transform**.  For every
//! classic-control env, on every executor kind, at every thread count,
//! `--kernel fused` must reproduce `--kernel scalar` trajectories
//! bit-for-bit — same observations, same rewards, same episode
//! boundaries (the fused `TimeLimit` step counter included), auto-reset
//! and mixtures with mixed fused/fallback groups included.
//!
//! `Script/*` ids are under the same pin with a twist: their scalar
//! path is the tree-walk interpreter and their fused path is the
//! register-bytecode `ScriptBatch` VM, so kernel equality here **is**
//! the tree-walk-vs-bytecode-vs-batched equivalence of the scripting
//! tentpole.
//!
//! Thread counts under test default to 1/2/4; the CI determinism matrix
//! re-runs this suite pinned to each of 1, 2, 4 and 8 via
//! `CAIRL_TEST_THREADS=<t>`.

mod common;

use cairl::coordinator::experiment::{
    build_executor_with_kernel, run_batched_workload, ExecutorKind, KernelMode,
};
use cairl::coordinator::pool::{BatchedExecutor, LaneSpec};
use cairl::coordinator::registry::{self, MixtureSpec};
use cairl::core::env::Transition;
use cairl::core::rng::Pcg32;
use cairl::core::spaces::Action;
use cairl::wrappers::WrapperSpec;
use common::test_threads;

const LANES: usize = 8;
const STEPS: usize = 90;
const BASE_SEED: u64 = 7;

const EXECUTORS: [ExecutorKind; 3] = [
    ExecutorKind::Sequential,
    ExecutorKind::PoolSync,
    ExecutorKind::PoolAsync,
];

/// The fused-kernel env ids, capped short so auto-reset fires many
/// times inside the tape (random CartPole also terminates naturally).
const CLASSIC: [&str; 5] = [
    "CartPole-v1?max_steps=25",
    "MountainCar-v0?max_steps=30",
    "Acrobot-v1?max_steps=40",
    "Pendulum-v1?max_steps=20",
    "PendulumDiscrete-v1?max_steps=20",
];

/// Deterministic action tape drawn from the per-lane action spaces
/// (spec order), so mixtures and continuous-action lanes replay the
/// identical workload on every executor/kernel combination.
fn action_tape(specs: &[LaneSpec], steps: usize, stream: u64) -> Vec<Vec<Action>> {
    let mut rng = Pcg32::new(0xba7c4 ^ stream, 42);
    (0..steps)
        .map(|_| specs.iter().map(|s| s.action_space.sample(&mut rng)).collect())
        .collect()
}

/// Replay a tape, returning the full (obs, transition) stream.
fn trajectory(
    exec: &mut dyn BatchedExecutor,
    tape: &[Vec<Action>],
) -> (Vec<f32>, Vec<Transition>) {
    let n = exec.num_lanes();
    let d = exec.obs_dim();
    let mut obs = vec![f32::NAN; n * d];
    let mut tr = vec![Transition::default(); n];
    let mut obs_stream = Vec::with_capacity((tape.len() + 1) * n * d);
    let mut tr_stream = Vec::with_capacity(tape.len() * n);
    exec.reset_into(&mut obs);
    obs_stream.extend_from_slice(&obs);
    for actions in tape {
        exec.step_into(actions, &mut obs, &mut tr);
        obs_stream.extend_from_slice(&obs);
        tr_stream.extend_from_slice(&tr);
    }
    (obs_stream, tr_stream)
}

/// Scalar-vs-fused equality for one env spec across every executor kind
/// and thread count, including lane-spec equality.
fn assert_kernel_equality(spec: &str, lanes: usize) {
    let mut reference = build_executor_with_kernel(
        spec,
        ExecutorKind::Sequential,
        lanes,
        1,
        BASE_SEED,
        &[],
        KernelMode::Scalar,
    )
    .unwrap();
    let specs_ref = reference.lane_specs().to_vec();
    let tape = action_tape(&specs_ref, STEPS, spec.len() as u64);
    let (obs_ref, tr_ref) = trajectory(reference.as_mut(), &tape);
    let ends = tr_ref.iter().filter(|t| t.done || t.truncated).count();
    assert!(ends > 0, "{spec}: the tape must exercise auto-reset");
    for kind in EXECUTORS {
        for threads in test_threads() {
            for kernel in [KernelMode::Scalar, KernelMode::Fused] {
                let mut exec =
                    build_executor_with_kernel(spec, kind, lanes, threads, BASE_SEED, &[], kernel)
                        .unwrap();
                assert_eq!(
                    exec.lane_specs(),
                    &specs_ref[..],
                    "{spec}: lane specs diverged ({kind:?}, {threads}t, {kernel:?})"
                );
                let (obs, tr) = trajectory(exec.as_mut(), &tape);
                assert_eq!(
                    tr_ref, tr,
                    "{spec}: transitions diverged ({kind:?}, {threads}t, {kernel:?})"
                );
                assert_eq!(
                    obs_ref, obs,
                    "{spec}: observations diverged ({kind:?}, {threads}t, {kernel:?})"
                );
            }
        }
    }
}

#[test]
fn fused_kernels_are_bit_identical_for_every_classic_env() {
    for spec in CLASSIC {
        assert_kernel_equality(spec, LANES);
    }
}

#[test]
fn registered_limits_fuse_bit_identically_too() {
    // The unparameterized ids carry their Gym-standard limits (500/200)
    // into the fused step counter; natural termination dominates the
    // episode ends here.
    assert_kernel_equality("CartPole-v1", 4);
}

#[test]
fn mixtures_fuse_per_group_with_scalar_fallback_lanes() {
    // Fused CartPole group + a per-component `+ClipReward` chain the
    // kernels cannot absorb (forcing that group onto the scalar
    // fallback — Script/CartPole-v1 itself fuses now) + fused
    // MountainCar group in one pool: per-group fusion, padding and
    // zeroed tails must match the scalar build everywhere.
    let spec = "CartPole-v1?max_steps=20:3,Script/CartPole-v1+ClipReward(-1,1):2,\
                MountainCar-v0?max_steps=30:3";
    assert!(MixtureSpec::is_mixture(spec));
    assert_kernel_equality(spec, 1);

    // Spot-check the layout: MountainCar lanes are narrower than the
    // padded width and their tails stay zero on the fused path.
    let mut exec = build_executor_with_kernel(
        spec,
        ExecutorKind::PoolSync,
        1,
        2,
        BASE_SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    assert_eq!(exec.num_lanes(), 8);
    assert_eq!(exec.obs_dim(), 4);
    let specs = exec.lane_specs().to_vec();
    assert_eq!(specs[0].env_id, "CartPole-v1?max_steps=20");
    assert_eq!(specs[3].env_id, "Script/CartPole-v1+ClipReward(-1,1)");
    assert_eq!(specs[5].env_id, "MountainCar-v0?max_steps=30");
    assert_eq!(specs[5].obs_dim, 2);
    let tape = action_tape(&specs, 40, 3);
    let (obs, _) = trajectory(exec.as_mut(), &tape);
    for frame in obs.chunks(8 * 4) {
        for spec in &specs[5..] {
            assert_eq!(
                &frame[spec.offset + spec.obs_dim..spec.offset + 4],
                &[0.0, 0.0],
                "padded tail must stay zero"
            );
        }
    }
}

#[test]
fn wrap_chains_force_the_scalar_fallback_and_stay_identical() {
    // An extra --wrap chain the kernels cannot absorb (ClipReward, and
    // a two-layer affine stack): both kernel modes must run the same
    // scalar lanes.
    for chain in [
        vec![WrapperSpec::ClipReward { lo: -1.0, hi: 0.5 }],
        vec![
            WrapperSpec::NormalizeObs,
            WrapperSpec::RewardScale { scale: 0.5, shift: 0.25 },
        ],
    ] {
        assert!(
            registry::fused_lane_builder_with("CartPole-v1?max_steps=25", &chain)
                .unwrap()
                .is_none(),
            "{chain:?} must not fuse"
        );
        let run = |kernel: KernelMode| {
            let mut exec = build_executor_with_kernel(
                "CartPole-v1?max_steps=25",
                ExecutorKind::PoolSync,
                4,
                2,
                BASE_SEED,
                &chain,
                kernel,
            )
            .unwrap();
            let specs = exec.lane_specs().to_vec();
            let tape = action_tape(&specs, 60, 9);
            trajectory(exec.as_mut(), &tape)
        };
        assert_eq!(run(KernelMode::Scalar), run(KernelMode::Fused));
    }
}

#[test]
fn trailing_affine_wrap_chains_fuse_bit_identically() {
    // A single trailing NormalizeObs or RewardScale is absorbed into
    // the kernel's affine epilogue — the fused path must reproduce the
    // scalar wrapper stack bit for bit, on every executor kind and
    // thread count, auto-reset included.
    let chains = [
        vec![WrapperSpec::NormalizeObs],
        vec![WrapperSpec::RewardScale { scale: 2.0, shift: -0.5 }],
    ];
    for chain in &chains {
        for spec in ["CartPole-v1?max_steps=25", "MountainCar-v0?max_steps=30"] {
            // The configuration really takes the fused path.
            assert!(
                registry::fused_lane_builder_with(spec, chain).unwrap().is_some(),
                "{spec} + {chain:?} must fuse"
            );
            let mut reference = build_executor_with_kernel(
                spec,
                ExecutorKind::Sequential,
                4,
                1,
                BASE_SEED,
                chain,
                KernelMode::Scalar,
            )
            .unwrap();
            let specs_ref = reference.lane_specs().to_vec();
            let tape = action_tape(&specs_ref, STEPS, 13);
            let reference_trace = trajectory(reference.as_mut(), &tape);
            let ends = reference_trace.1.iter().filter(|t| t.done || t.truncated).count();
            assert!(ends > 0, "{spec}: the tape must exercise auto-reset");
            for kind in EXECUTORS {
                for threads in test_threads() {
                    let mut fused = build_executor_with_kernel(
                        spec,
                        kind,
                        4,
                        threads,
                        BASE_SEED,
                        chain,
                        KernelMode::Fused,
                    )
                    .unwrap();
                    assert_eq!(fused.lane_specs(), &specs_ref[..]);
                    let trace = trajectory(fused.as_mut(), &tape);
                    assert_eq!(
                        reference_trace, trace,
                        "{spec} + {chain:?} diverged ({kind:?}, {threads}t)"
                    );
                }
            }
        }
    }
}

#[test]
fn adjacent_identical_components_merge_into_one_group() {
    // "CartPole-v1:4,CartPole-v1:4" is one 8-lane fused group; it must
    // equal the single-component spelling bit for bit.
    let merged = build_and_run("CartPole-v1?max_steps=25:8");
    let split = build_and_run("CartPole-v1?max_steps=25:4,CartPole-v1?max_steps=25:4");
    assert_eq!(merged, split);
}

fn build_and_run(spec: &str) -> (Vec<f32>, Vec<Transition>) {
    let mut exec = build_executor_with_kernel(
        spec,
        ExecutorKind::PoolSync,
        1,
        3,
        BASE_SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let specs = exec.lane_specs().to_vec();
    let tape = action_tape(&specs, STEPS, 1);
    trajectory(exec.as_mut(), &tape)
}

#[test]
fn fused_workload_counts_match_scalar_on_every_executor() {
    // The workload-level face of the invariant, through the public
    // run_batched_workload driver (per-lane action sampling included).
    for kind in EXECUTORS {
        let run = |kernel: KernelMode| {
            let mut exec =
                build_executor_with_kernel("CartPole-v1", kind, 6, 2, 40, &[], kernel).unwrap();
            let r = run_batched_workload(exec.as_mut(), 80, 7);
            (r.steps, r.episodes)
        };
        let scalar = run(KernelMode::Scalar);
        assert!(scalar.1 > 0, "{kind:?}: random cartpole must end episodes");
        assert_eq!(scalar, run(KernelMode::Fused), "{kind:?}");
    }
}

#[test]
fn every_classic_spec_advertises_a_fused_builder() {
    for id in [
        "CartPole-v1",
        "MountainCar-v0",
        "Acrobot-v1",
        "Pendulum-v1",
        "PendulumDiscrete-v1",
        // Script ids fuse through the bytecode ScriptBatch kernel.
        "Script/CartPole-v1",
        "Script/MountainCar-v0",
        "Script/Acrobot-v1",
        "Script/Pendulum-v1",
    ] {
        assert!(registry::env_spec(id).unwrap().batch_capable(), "{id}");
        assert!(
            registry::fused_lane_builder(id).unwrap().is_some(),
            "{id}: registered chain must fuse"
        );
    }
    // Flash/puzzle and pixel-wrapped specs fall back.
    for id in ["Flash/Pong-v0", "Puzzle/Nonogram-v0"] {
        assert!(registry::fused_lane_builder(id).unwrap().is_none(), "{id}");
    }
    assert!(registry::fused_lane_builder("Pixel/CartPole-v1").unwrap().is_none());
}

#[test]
fn script_bytecode_batches_are_bit_identical_to_tree_walk() {
    // The tentpole pin: scalar mode steps the tree-walk ScriptEnv
    // interpreter, fused mode steps the register-VM ScriptBatch SoA
    // kernel — bit-identical trajectories on every executor kind and
    // thread count, auto-reset and TimeLimit truncation included.
    for spec in [
        "Script/CartPole-v1?max_steps=25",
        "Script/MountainCar-v0?max_steps=30",
        "Script/Acrobot-v1?max_steps=40",
        "Script/Pendulum-v1?max_steps=20",
    ] {
        assert_kernel_equality(spec, 4);
    }
}

#[test]
fn script_lanes_with_affine_chains_fuse_bit_identically() {
    // A trailing NormalizeObs rides the ScriptBatch epilogue exactly as
    // it does on the native kernels — both via --wrap and via the
    // per-component `+` mixture grammar.
    let chain = vec![WrapperSpec::NormalizeObs];
    assert!(
        registry::fused_lane_builder_with("Script/CartPole-v1?max_steps=25", &chain)
            .unwrap()
            .is_some(),
        "Script + NormalizeObs must fuse"
    );
    assert_kernel_equality(
        "Script/CartPole-v1?max_steps=25+NormalizeObs:3,CartPole-v1?max_steps=25+NormalizeObs:3",
        1,
    );
}
