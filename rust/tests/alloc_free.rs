//! The zero-allocation guarantees of the executor hot paths (ISSUE 2 +
//! ISSUE 4 acceptance): once warmed up,
//!
//! * a steady-state `recv_batch` → `send_actions` cycle on
//!   [`AsyncEnvPool`] performs **zero heap allocations** — observations
//!   travel through per-lane slots of one shared block, lane ids
//!   through capacity-reserved queues, and the batch view borrows
//!   instead of copying;
//! * a steady-state lockstep `step_into` loop on the sync [`EnvPool`]
//!   allocates nothing, on **both** kernel modes — the fused SoA
//!   `step_batch` path steps columns in place, and the scalar fallback
//!   replays the pre-fusion per-lane loop without a single allocation.
//!
//! Pinned with a counting global allocator, which is why these tests
//! live alone in their own integration binary: every allocation from
//! any thread in the process is counted, so a measured window must
//! contain nothing but the pool loop (the tests serialise on a mutex
//! to keep each other's warm-up out of the windows).
//!
//! The telemetry record path (counter/gauge/histogram updates) is
//! pinned here too: instrumentation rides the loops above, so it must
//! be atomics-only once the handles exist.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use cairl::coordinator::pool::{AsyncEnvPool, BatchedExecutor, EnvPool, LaneGroupSpec};
use cairl::core::batch::DynBatchEnv;
use cairl::core::env::Transition;
use cairl::core::spaces::Action;
use cairl::envs::CartPole;
use cairl::wrappers::TimeLimit;

/// Serialises the measuring tests: the counter is process-global, so a
/// concurrently warming-up sibling test would pollute every window.
static WINDOW_LOCK: Mutex<()> = Mutex::new(());

/// System allocator with a global allocation counter (frees are not
/// counted: the guarantee is about allocations).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Drive `iters` recv/send cycles, keeping every received lane busy.
fn drive_cycles(pool: &mut AsyncEnvPool, n: usize, sends: &mut Vec<(usize, Action)>, iters: u32) {
    for _ in 0..iters {
        let batch = pool.recv_batch(n);
        sends.clear();
        for (j, &lane) in batch.lanes().iter().enumerate() {
            // Touch the zero-copy observation view so the read path is
            // part of the measured loop.
            std::hint::black_box(batch.obs(j)[0]);
            sends.push((lane, Action::Discrete(lane % 2)));
        }
        pool.send_actions(sends);
    }
}

/// Measure `run(iters)` over a few windows; pass as soon as one window
/// is allocation-free.  The loop itself must allocate nothing, but the
/// counter is process-global, so tolerate windows polluted by harness
/// background activity — a clean window proves the loop allocates zero
/// (noise only adds).
fn assert_some_window_is_clean(what: &str, mut run: impl FnMut(u32)) {
    let mut deltas = Vec::new();
    for _ in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        run(2_000);
        let after = ALLOCS.load(Ordering::SeqCst);
        deltas.push(after - before);
        if after == before {
            return; // proven allocation-free
        }
    }
    panic!(
        "steady-state {what} allocated in every measured window: \
         {deltas:?} allocations per 2000-cycle window"
    );
}

#[test]
fn steady_state_recv_and_send_allocate_nothing() {
    let _guard = WINDOW_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let n = 8;
    let mut pool = AsyncEnvPool::new(n, 17, 2, || TimeLimit::new(CartPole::new(), 50));
    let mut sends: Vec<(usize, Action)> = Vec::with_capacity(n);

    // Warm-up: first touches of every code path (initial resets,
    // auto-resets, condvar parking) and of lazy runtime structures.
    drive_cycles(&mut pool, n, &mut sends, 400);

    assert_some_window_is_clean("AsyncEnvPool recv_batch/send_actions", |iters| {
        drive_cycles(&mut pool, n, &mut sends, iters)
    });
}

/// Drive `iters` lockstep batches on a sync pool with fixed buffers.
fn drive_lockstep(
    pool: &mut EnvPool,
    actions: &[Action],
    obs: &mut [f32],
    tr: &mut [Transition],
    iters: u32,
) {
    for _ in 0..iters {
        BatchedExecutor::step_into(pool, actions, obs, tr);
        std::hint::black_box(obs[0]);
    }
}

/// The sync-pool steady-state loop on a given pool: warm up, then
/// require a clean window.
fn assert_sync_pool_step_loop_is_clean(mut pool: EnvPool, what: &str) {
    let n = pool.num_lanes();
    let d = pool.obs_dim();
    let actions: Vec<Action> = (0..n).map(|i| Action::Discrete(i % 2)).collect();
    let mut obs = vec![0.0f32; n * d];
    let mut tr = vec![Transition::default(); n];
    BatchedExecutor::reset_into(&mut pool, &mut obs);
    drive_lockstep(&mut pool, &actions, &mut obs, &mut tr, 400);
    assert_some_window_is_clean(what, |iters| {
        drive_lockstep(&mut pool, &actions, &mut obs, &mut tr, iters)
    });
}

#[test]
fn fused_step_batch_path_allocates_nothing() {
    let _guard = WINDOW_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let pool = EnvPool::from_groups(
        vec![LaneGroupSpec::new("CartPole-v1", 8, |lanes| -> DynBatchEnv {
            Box::new(CartPole::batch(lanes, Some(50)))
        })],
        17,
        2,
    );
    assert_sync_pool_step_loop_is_clean(pool, "fused EnvPool step_batch loop");
}

#[test]
fn scalar_sync_pool_step_loop_allocates_nothing() {
    let _guard = WINDOW_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let pool = EnvPool::new(8, 17, 2, || TimeLimit::new(CartPole::new(), 50));
    assert_sync_pool_step_loop_is_clean(pool, "scalar EnvPool step_into loop");
}

#[test]
fn telemetry_record_path_allocates_nothing() {
    let _guard = WINDOW_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Registration is the cold path and may allocate; grab the handles
    // once up front, exactly as the executors do at construction.
    let c = cairl::telemetry::counter("alloc_free_test_counter");
    let g = cairl::telemetry::gauge("alloc_free_test_gauge");
    let h = cairl::telemetry::histogram(
        "alloc_free_test_histogram",
        &cairl::telemetry::LATENCY_BOUNDS_US,
    );
    // Warm-up: first touches of each handle.
    c.add(2);
    g.set(-3);
    h.record(777);
    let mut i: u64 = 0;
    assert_some_window_is_clean("telemetry counter/gauge/histogram record", |iters| {
        for _ in 0..iters {
            c.inc();
            c.add(3);
            g.set(i as i64 - 7);
            // Sweep the value so every histogram bucket (including the
            // overflow slot) is exercised inside the window.
            h.record(i * 131);
            i += 1;
        }
        std::hint::black_box(&i);
    });
}
