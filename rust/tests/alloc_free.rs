//! The zero-allocation guarantee of the async executor (ISSUE 2
//! acceptance): once warmed up, a steady-state
//! `recv_batch` → `send_actions` cycle on [`AsyncEnvPool`] performs
//! **zero heap allocations** — observations travel through per-lane
//! slots of one shared block, lane ids through capacity-reserved
//! queues, and the batch view borrows instead of copying.
//!
//! Pinned with a counting global allocator, which is why this test
//! lives alone in its own integration binary: every allocation from
//! any thread in the process is counted, so the measured window must
//! contain nothing but the pool loop.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use cairl::coordinator::pool::AsyncEnvPool;
use cairl::core::spaces::Action;
use cairl::envs::CartPole;
use cairl::wrappers::TimeLimit;

/// System allocator with a global allocation counter (frees are not
/// counted: the guarantee is about allocations).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Drive `iters` recv/send cycles, keeping every received lane busy.
fn drive_cycles(pool: &mut AsyncEnvPool, n: usize, sends: &mut Vec<(usize, Action)>, iters: u32) {
    for _ in 0..iters {
        let batch = pool.recv_batch(n);
        sends.clear();
        for (j, &lane) in batch.lanes().iter().enumerate() {
            // Touch the zero-copy observation view so the read path is
            // part of the measured loop.
            std::hint::black_box(batch.obs(j)[0]);
            sends.push((lane, Action::Discrete(lane % 2)));
        }
        pool.send_actions(sends);
    }
}

#[test]
fn steady_state_recv_and_send_allocate_nothing() {
    let n = 8;
    let mut pool = AsyncEnvPool::new(n, 17, 2, || TimeLimit::new(CartPole::new(), 50));
    let mut sends: Vec<(usize, Action)> = Vec::with_capacity(n);

    // Warm-up: first touches of every code path (initial resets,
    // auto-resets, condvar parking) and of lazy runtime structures.
    drive_cycles(&mut pool, n, &mut sends, 400);

    // Measure a few windows; the loop itself must allocate nothing, but
    // the counter is process-global, so tolerate a window polluted by
    // harness background activity as long as one window is clean — a
    // clean window proves the loop allocates zero (noise only adds).
    let mut deltas = Vec::new();
    for _ in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        drive_cycles(&mut pool, n, &mut sends, 2_000);
        let after = ALLOCS.load(Ordering::SeqCst);
        deltas.push(after - before);
        if after == before {
            return; // proven allocation-free
        }
    }
    panic!(
        "steady-state AsyncEnvPool recv_batch/send_actions allocated in every \
         measured window: {deltas:?} allocations per 2000-cycle window"
    );
}
