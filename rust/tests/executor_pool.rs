//! Integration: the batched-executor layer.
//!
//! The load-bearing invariant of the EnvPool refactor: **threading is a
//! pure performance transform**.  `EnvPool` in sync mode (any thread
//! count) and `AsyncEnvPool` driven in lockstep must reproduce
//! sequential `VecEnv` trajectories bit-for-bit — same observations,
//! same rewards, same episode boundaries — for every environment id the
//! registry exposes, auto-reset included.  The async-mode tests pin the
//! ready-queue semantics: every lane makes progress and each episode
//! end is reported exactly once.
//!
//! Thread counts under test default to 1/2/4; the CI determinism matrix
//! re-runs this suite pinned to each of 1, 2, 4 and 8 via
//! `CAIRL_TEST_THREADS=<t>` so every per-thread configuration gets its
//! own hard gate.

mod common;

use cairl::coordinator::pool::{AsyncEnvPool, BatchedExecutor, EnvPool};
use cairl::coordinator::vec_env::VecEnv;
use cairl::core::env::{Env, Transition};
use cairl::core::rng::Pcg32;
use cairl::core::spaces::Action;
use cairl::envs::CartPole;
use cairl::wrappers::TimeLimit;
use cairl::{list_envs, make};
use common::test_threads;

// 8 lanes so every CI matrix leg (1/2/4/8 threads) gets a distinct
// worker partitioning — pools clamp threads to the lane count.
const LANES: usize = 8;
const STEPS: usize = 220;
const BASE_SEED: u64 = 7;

/// Deterministic action tape: `steps` batches of `lanes` actions drawn
/// from the env's action space with a fixed stream, so every executor
/// replays the identical workload.
fn action_tape(id: &str, steps: usize, lanes: usize) -> Vec<Vec<Action>> {
    let env = make(id).unwrap();
    let space = env.action_space();
    let mut rng = Pcg32::new(0x5eed_0000 + id.len() as u64, 42);
    (0..steps)
        .map(|_| (0..lanes).map(|_| space.sample(&mut rng)).collect())
        .collect()
}

/// Replay a tape on any executor, returning the full (obs, transition)
/// stream.
fn trajectory(
    exec: &mut dyn BatchedExecutor,
    tape: &[Vec<Action>],
) -> (Vec<f32>, Vec<Transition>) {
    let n = exec.num_lanes();
    let d = exec.obs_dim();
    let mut obs = vec![0.0f32; n * d];
    let mut tr = vec![Transition::default(); n];
    let mut obs_stream = Vec::with_capacity((tape.len() + 1) * n * d);
    let mut tr_stream = Vec::with_capacity(tape.len() * n);
    exec.reset_into(&mut obs);
    obs_stream.extend_from_slice(&obs);
    for actions in tape {
        exec.step_into(actions, &mut obs, &mut tr);
        obs_stream.extend_from_slice(&obs);
        tr_stream.extend_from_slice(&tr);
    }
    (obs_stream, tr_stream)
}

#[test]
fn pool_sync_is_bit_identical_to_vec_env_for_every_registered_env() {
    for (id, _) in list_envs() {
        let tape = action_tape(&id, STEPS, LANES);
        let mut reference = VecEnv::new(LANES, BASE_SEED, || make(&id).unwrap());
        let (obs_ref, tr_ref) = trajectory(&mut reference, &tape);
        for threads in test_threads() {
            let mut pool =
                EnvPool::new(LANES, BASE_SEED, threads, || make(&id).unwrap());
            let (obs, tr) = trajectory(&mut pool, &tape);
            assert_eq!(tr_ref, tr, "{id}: transitions diverged at {threads} threads");
            assert_eq!(obs_ref, obs, "{id}: observations diverged at {threads} threads");
        }
    }
}

#[test]
fn async_pool_lockstep_is_bit_identical_on_representative_envs() {
    // The async pool under the lockstep (trait) driver: same invariant,
    // exercised on one env per runner family to keep the wall-clock sane.
    for id in [
        "CartPole-v1",
        "Pendulum-v1",
        "Script/MountainCar-v0",
        "Flash/Pong-v0",
        "Puzzle/LightsOut-v0",
    ] {
        let tape = action_tape(id, STEPS, LANES);
        let mut reference = VecEnv::new(LANES, BASE_SEED, || make(id).unwrap());
        let (obs_ref, tr_ref) = trajectory(&mut reference, &tape);
        for threads in test_threads() {
            let mut pool =
                AsyncEnvPool::new(LANES, BASE_SEED, threads, || make(id).unwrap());
            let (obs, tr) = trajectory(&mut pool, &tape);
            assert_eq!(tr_ref, tr, "{id}: async transitions diverged at {threads} threads");
            assert_eq!(obs_ref, obs, "{id}: async observations diverged at {threads} threads");
        }
    }
}

#[test]
fn executor_reset_is_repeatable_mid_run() {
    // reset_into must be callable at any point on every executor and
    // keep the lanes aligned (a second reset continues each lane's RNG
    // stream exactly like the sequential reference).
    let factory = || TimeLimit::new(CartPole::new(), 50);
    let tape = action_tape("CartPole-v1", 40, LANES);

    let run = |exec: &mut dyn BatchedExecutor| {
        let n = exec.num_lanes();
        let d = exec.obs_dim();
        let mut obs = vec![0.0f32; n * d];
        let mut tr = vec![Transition::default(); n];
        let mut stream = Vec::new();
        exec.reset_into(&mut obs);
        for actions in &tape[..20] {
            exec.step_into(actions, &mut obs, &mut tr);
        }
        exec.reset_into(&mut obs);
        stream.extend_from_slice(&obs);
        for actions in &tape[20..] {
            exec.step_into(actions, &mut obs, &mut tr);
            stream.extend_from_slice(&obs);
        }
        stream
    };

    let mut vec_env = VecEnv::new(LANES, 11, factory);
    let reference = run(&mut vec_env);
    for threads in test_threads() {
        let mut sync_pool = EnvPool::new(LANES, 11, threads, factory);
        let mut async_pool = AsyncEnvPool::new(LANES, 11, threads, factory);
        assert_eq!(reference, run(&mut sync_pool), "sync at {threads} threads");
        assert_eq!(reference, run(&mut async_pool), "async at {threads} threads");
    }
}

#[test]
fn async_native_api_all_lanes_progress_and_episode_ends_report_once() {
    let n = 6usize;
    let per_lane = 100u32;
    let cap = 25;
    let seed = 3u64;
    let mut pool =
        AsyncEnvPool::new(n, seed, 3, || TimeLimit::new(CartPole::new(), cap));

    // Drive the ready-queue API: every received lane immediately gets its
    // next action (a fixed per-lane policy, so per-lane trajectories are
    // deterministic no matter how the queue interleaves lanes).
    let mut sent = vec![0u32; n];
    let mut received: Vec<Vec<(Vec<f32>, Transition)>> = vec![Vec::new(); n];
    let target = n * (per_lane as usize + 1); // initial reset + per_lane steps
    let mut total = 0usize;
    while total < target {
        let batch = pool.recv_batch(n);
        let mut sends = Vec::new();
        for (j, &lane) in batch.lanes().iter().enumerate() {
            received[lane].push((
                batch.obs_unpadded(j).to_vec(),
                batch.transitions()[j],
            ));
            total += 1;
            if sent[lane] < per_lane {
                sends.push((lane, Action::Discrete(lane % 2)));
                sent[lane] += 1;
            }
        }
        pool.send_actions(&sends);
    }

    // Progress: every lane executed its full budget.
    assert_eq!(sent, vec![per_lane; n]);

    // Exactly-once episode reporting + per-lane bit-determinism: replay
    // each lane sequentially and compare the full stream.
    for lane in 0..n {
        let mut env = TimeLimit::new(CartPole::new(), cap);
        env.seed(seed + lane as u64);
        let mut obs = vec![0.0f32; 4];
        env.reset_into(&mut obs);
        let mut expected = vec![(obs.clone(), Transition::default())];
        let mut ends = 0u32;
        for _ in 0..per_lane {
            let t = env.step_into(&Action::Discrete(lane % 2), &mut obs);
            if t.done || t.truncated {
                ends += 1;
                env.reset_into(&mut obs);
            }
            expected.push((obs.clone(), t));
        }
        assert!(
            ends >= 3,
            "lane {lane}: {cap}-step cap over {per_lane} steps ended {ends} times"
        );
        let got_ends = received[lane]
            .iter()
            .filter(|(_, t)| t.done || t.truncated)
            .count() as u32;
        assert_eq!(got_ends, ends, "lane {lane}: episode ends reported {got_ends}x");
        assert_eq!(received[lane], expected, "lane {lane}: stream diverged");
    }
}

#[test]
fn async_native_api_serves_scenario_mixtures() {
    // A mixture through the native ready-queue API: lane specs are
    // reachable per entry, unpadded views have per-lane widths, and the
    // padded tails read back zero.
    let spec = cairl::coordinator::registry::MixtureSpec::parse(
        "CartPole-v1:2,MountainCar-v0:2",
    )
    .unwrap();
    let (ids, envs): (Vec<String>, Vec<_>) =
        spec.build_labeled_envs().unwrap().into_iter().unzip();
    let mut apool = AsyncEnvPool::from_labeled_envs(ids, envs, 9, 2);
    let n = apool.num_lanes();
    let mut rounds = 0;
    let mut seen_mountain_car = false;
    while rounds < 50 {
        let batch = apool.recv_batch(n);
        let mut sends = Vec::new();
        for (j, &lane) in batch.lanes().iter().enumerate() {
            let spec = batch.lane_spec(j).clone();
            assert_eq!(batch.obs(j).len(), 4, "padded width");
            assert_eq!(batch.obs_unpadded(j).len(), spec.obs_dim);
            if spec.env_id == "MountainCar-v0" {
                seen_mountain_car = true;
                assert_eq!(&batch.obs(j)[2..], &[0.0, 0.0], "tail must stay zero");
            }
            sends.push((lane, Action::Discrete(0)));
        }
        rounds += 1;
        apool.send_actions(&sends);
    }
    assert!(seen_mountain_car, "mixture lanes must all surface");
}
