//! Integration: fleet observability — deterministic trajectory tapes
//! and the telemetry metrics registry.
//!
//! The tape contract (ISSUE 8): recording the same `(spec, seed,
//! steps)` workload produces **byte-identical** tape files across every
//! executor kind, thread count, kernel mode and local-vs-sharded
//! transport — and replaying a tape against a freshly built executor of
//! any of those shapes matches every transition bit for bit.  Tape
//! corruption surfaces [`CairlError::Tape`], never a panic.  On the
//! metrics side: stepped workloads populate the `cairl_exec_*` counters
//! and the snapshot has the documented shape.
//!
//! Thread counts default to 1/2/4; the CI determinism matrix re-runs
//! the suite with `CAIRL_TEST_THREADS` pinned to each of 1, 2, 4, 8.

mod common;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};

use cairl::coordinator::experiment::{
    build_executor_with_kernel, run_recorded_workload, ExecutorKind, KernelMode,
};
use cairl::coordinator::pool::BatchedExecutor;
use cairl::core::env::Env;
use cairl::core::error::CairlError;
use cairl::core::spaces::Action;
use cairl::envs::Pendulum;
use cairl::shard::{ServeConfig, ShardPoolOptions, ShardServer, ShardedEnvPool};
use cairl::telemetry::{
    counter, render_prometheus, replay_against, snapshot, TapeHeader, TapeReader, TapeWriter,
};
use cairl::wrappers::{RecordEpisodeStatistics, TimeLimit};
use common::test_threads;

/// Heterogeneous reference mixture: wide + narrow lanes, 8 total so
/// every CI matrix leg (1/2/4/8 threads) partitions workers
/// differently.  Short truncation horizons force auto-resets into the
/// recorded window.
const MIX: &str = "CartPole-v1?max_steps=25:4,MountainCar-v0?max_steps=30:4";
const LANES: usize = 8;
const SEED: u64 = 57;
const STEPS_PER_LANE: u64 = 60;

/// Unique temp path per tape (tests run in parallel).
fn fresh_tape(tag: &str) -> PathBuf {
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let k = NEXT.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "cairl-telemetry-{}-{k}-{tag}.tape",
        std::process::id()
    ))
}

fn build(kind: &str, threads: usize, kernel: &str) -> Box<dyn BatchedExecutor> {
    build_executor_with_kernel(
        MIX,
        ExecutorKind::parse(kind).unwrap(),
        1, // lane counts come from the mixture spec
        threads,
        SEED,
        &[],
        KernelMode::parse(kernel).unwrap(),
    )
    .unwrap()
}

/// Record the standard workload on `exec` into `path`.
fn record_tape(exec: &mut dyn BatchedExecutor, path: &Path) {
    let header = TapeHeader::for_executor(exec, MIX, "", SEED, STEPS_PER_LANE);
    let mut w = TapeWriter::create(path, &header).unwrap();
    run_recorded_workload(exec, STEPS_PER_LANE, SEED, Some(&mut w)).unwrap();
    assert_eq!(w.finish().unwrap(), STEPS_PER_LANE);
}

/// Unique listen address per in-process shard daemon.
fn fresh_addr() -> String {
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let k = NEXT.fetch_add(1, Ordering::Relaxed);
    #[cfg(unix)]
    {
        let path = std::env::temp_dir().join(format!(
            "cairl-telemetry-shard-{}-{k}.sock",
            std::process::id()
        ));
        format!("unix://{}", path.display())
    }
    #[cfg(not(unix))]
    {
        let _ = k;
        "tcp://127.0.0.1:0".to_string()
    }
}

#[test]
fn tapes_are_byte_identical_across_executors_threads_and_kernels() {
    let ref_path = fresh_tape("ref");
    let mut reference = build("vec", 1, "fused");
    record_tape(reference.as_mut(), &ref_path);
    let ref_bytes = std::fs::read(&ref_path).unwrap();
    assert!(!ref_bytes.is_empty());

    for kind in ["vec", "pool", "pool-async"] {
        for &threads in &test_threads() {
            for kernel in ["scalar", "fused"] {
                let path = fresh_tape(&format!("{kind}-{threads}t-{kernel}"));
                let mut exec = build(kind, threads, kernel);
                record_tape(exec.as_mut(), &path);
                let bytes = std::fs::read(&path).unwrap();
                assert_eq!(
                    bytes, ref_bytes,
                    "{kind}/{threads} threads/{kernel}: tape differs from vec/1/fused"
                );
                let _ = std::fs::remove_file(&path);
            }
        }
    }
    let _ = std::fs::remove_file(&ref_path);
}

#[test]
fn replay_matches_bit_for_bit_on_every_executor_shape() {
    let path = fresh_tape("replay");
    let mut rec = build("pool", 2, "fused");
    record_tape(rec.as_mut(), &path);

    for kind in ["vec", "pool", "pool-async"] {
        for kernel in ["scalar", "fused"] {
            let mut exec = build(kind, 2, kernel);
            let mut reader = TapeReader::open(&path).unwrap();
            assert_eq!(reader.header().lanes, LANES);
            assert_eq!(reader.header().base_seed, SEED);
            let outcome = replay_against(exec.as_mut(), &mut reader).unwrap();
            assert!(
                outcome.divergence.is_none(),
                "{kind}/{kernel}: diverged at {:?}",
                outcome.divergence
            );
            assert_eq!(outcome.batches, STEPS_PER_LANE);
            assert_eq!(outcome.lanes, LANES);
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sharded_recording_and_replay_match_local() {
    // Local reference tape.
    let local = fresh_tape("local");
    let mut reference = build("vec", 1, "fused");
    record_tape(reference.as_mut(), &local);
    let local_bytes = std::fs::read(&local).unwrap();

    // Two in-process shard daemons.
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..2 {
        let config = ServeConfig {
            threads: 2,
            ..ServeConfig::new("CartPole-v1")
        };
        let server = ShardServer::bind(&fresh_addr(), config).expect("bind shard");
        addrs.push(server.local_addr());
        handles.push(server.spawn());
    }
    let opts = ShardPoolOptions {
        lanes: LANES,
        base_seed: SEED,
        ..Default::default()
    };

    // Recording over the transport produces the same bytes...
    let mut pool = ShardedEnvPool::connect_opts(&addrs, MIX, opts.clone()).unwrap();
    let sharded = fresh_tape("sharded");
    record_tape(&mut pool, &sharded);
    assert_eq!(
        std::fs::read(&sharded).unwrap(),
        local_bytes,
        "sharded tape differs from local"
    );

    // ...and the local tape replays cleanly over a fresh sharded pool.
    let mut pool2 = ShardedEnvPool::connect_opts(&addrs, MIX, opts).unwrap();
    let mut reader = TapeReader::open(&local).unwrap();
    let outcome = replay_against(&mut pool2, &mut reader).unwrap();
    assert!(
        outcome.divergence.is_none(),
        "sharded replay diverged at {:?}",
        outcome.divergence
    );
    assert_eq!(outcome.batches, STEPS_PER_LANE);

    drop(pool);
    drop(pool2);
    handles.into_iter().for_each(|h| h.shutdown());
    let _ = std::fs::remove_file(&local);
    let _ = std::fs::remove_file(&sharded);
}

#[test]
fn replay_reports_the_first_divergence() {
    let path = fresh_tape("diverge");
    let mut rec = build("pool", 2, "fused");
    record_tape(rec.as_mut(), &path);

    // A fresh executor seeded differently walks different episode
    // boundaries, so the transition streams must split.
    let mut wrong = build_executor_with_kernel(
        MIX,
        ExecutorKind::parse("pool").unwrap(),
        1,
        2,
        SEED + 1,
        &[],
        KernelMode::parse("fused").unwrap(),
    )
    .unwrap();
    let mut reader = TapeReader::open(&path).unwrap();
    let outcome = replay_against(wrong.as_mut(), &mut reader).unwrap();
    let d = outcome
        .divergence
        .expect("a differently seeded replay must diverge");
    assert!(d.batch < STEPS_PER_LANE);
    assert!(d.lane < LANES);
    assert_eq!(d.batch, outcome.batches, "divergence stops the replay");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_tapes_surface_errors_never_panics() {
    let path = fresh_tape("corrupt");
    let mut rec = build("vec", 1, "fused");
    record_tape(rec.as_mut(), &path);
    let clean = std::fs::read(&path).unwrap();

    // Truncation mid-stream: the header still parses, replay errors.
    let cut = fresh_tape("corrupt-cut");
    std::fs::write(&cut, &clean[..clean.len() - 10]).unwrap();
    let mut exec = build("vec", 1, "fused");
    let mut reader = TapeReader::open(&cut).unwrap();
    let err = replay_against(exec.as_mut(), &mut reader).unwrap_err();
    assert!(matches!(err, CairlError::Tape(_)), "got {err}");

    // A flipped byte mid-file fails the record checksum.
    let mut flipped = clean.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0xff;
    std::fs::write(&cut, &flipped).unwrap();
    let mut drain = || -> Result<(), CairlError> {
        let mut r = TapeReader::open(&cut)?;
        while r.next_batch()?.is_some() {}
        Ok(())
    };
    assert!(drain().is_err(), "flipped byte must be detected");

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&cut);
}

#[test]
fn workloads_populate_exec_metrics() {
    let steps = counter("cairl_exec_steps_total{exec=\"pool\"}");
    let batches = counter("cairl_exec_batches_total{exec=\"pool\"}");
    let before_steps = steps.get();
    let before_batches = batches.get();

    let mut exec = build("pool", 2, "fused");
    run_recorded_workload(exec.as_mut(), STEPS_PER_LANE, SEED, None).unwrap();

    assert!(
        steps.get() >= before_steps + STEPS_PER_LANE * LANES as u64,
        "pool lane-step counter did not advance"
    );
    assert!(batches.get() >= before_batches + STEPS_PER_LANE);

    // Snapshot shape: the counter shows up under "counters" and the
    // Prometheus rendering splits its label block back out.
    let snap = snapshot();
    assert!(snap
        .path(&["counters", "cairl_exec_steps_total{exec=\"pool\"}"])
        .is_some());
    let text = render_prometheus();
    assert!(text.contains("# TYPE cairl_exec_steps_total counter"));
    assert!(text.contains("cairl_exec_steps_total{exec=\"pool\"}"));
}

#[test]
fn record_stats_feeds_fleet_episode_counters() {
    let episodes = counter("cairl_episodes_total");
    let ep_steps = counter("cairl_episode_steps_total");
    let before_eps = episodes.get();
    let before_steps = ep_steps.get();

    let mut env = RecordEpisodeStatistics::new(TimeLimit::new(Pendulum::discrete(), 5), 10);
    env.seed(0);
    env.reset();
    let a = Action::Discrete(0);
    for _ in 0..5 {
        env.step(&a);
    }
    assert!(env.last_episode().is_some(), "episode must have completed");
    assert!(episodes.get() >= before_eps + 1);
    assert!(ep_steps.get() >= before_steps + 1);
}
