//! Helpers shared by the executor integration suites (not a test
//! binary itself: `tests/common/` is only compiled where `mod common;`
//! pulls it in).

/// Worker-thread counts exercised by the determinism tests:
/// `CAIRL_TEST_THREADS=<t>` pins a single count (the CI determinism
/// matrix runs 1, 2, 4 and 8), otherwise a 1/2/4 sweep runs locally.
pub fn test_threads() -> Vec<usize> {
    match std::env::var("CAIRL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        Some(t) if t > 0 => vec![t],
        _ => vec![1, 2, 4],
    }
}
