//! Integration: cross-runner trajectory agreement.
//!
//! The same dynamics run on three runners — native Rust, the interpreted
//! MiniScript baseline, and (for CartPole) the L1 Pallas kernel via PJRT.
//! For equal seeds and action sequences all runners must produce the same
//! trajectory to floating-point tolerance.  This is the paper's implicit
//! validity claim for Fig. 1/2: the speed comparison is only meaningful
//! because both sides compute the same thing.

use cairl::core::env::Env;
use cairl::core::rng::Pcg32;
use cairl::core::spaces::Action;
use cairl::envs::CartPole;
use cairl::runtime::pjrt::{literal_f32, Runtime};
use cairl::script;

#[test]
fn three_way_cartpole_agreement() {
    // Native vs script vs kernel over a seeded 40-step trajectory.  The
    // kernel leg needs the PJRT artifacts; without them (offline `xla`
    // stub) this degrades to the two-way native-vs-script comparison.
    let seed = 2024;
    let mut native = CartPole::new();
    let mut scripted = script::envs::cartpole();
    native.seed(seed);
    scripted.seed(seed);
    let mut obs_n = vec![0.0f32; 4];
    let mut obs_s = vec![0.0f32; 4];
    native.reset_into(&mut obs_n);
    scripted.reset_into(&mut obs_s);

    let mut rt_opt = match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("three_way downgraded to native-vs-script only: {e}");
            None
        }
    };
    let module = rt_opt
        .as_mut()
        .map(|rt| rt.load("env_step_cartpole").unwrap());
    let batch = 256;

    let mut kernel_state = obs_n.clone();
    let mut rng = Pcg32::new(9, 9);
    for step in 0..40 {
        let a = rng.below(2) as usize;
        let tn = native.step_into(&Action::Discrete(a), &mut obs_n);
        let ts = scripted.step_into(&Action::Discrete(a), &mut obs_s);

        if let Some(module) = module {
            // Kernel step on lane 0.
            let mut s = vec![0.0f32; batch * 4];
            s[..4].copy_from_slice(&kernel_state);
            let mut act = vec![0.0f32; batch];
            act[0] = a as f32;
            let out = module
                .execute_f32(&[
                    literal_f32(&s, &[batch, 4]).unwrap(),
                    literal_f32(&act, &[batch]).unwrap(),
                ])
                .unwrap();
            kernel_state = out[0][..4].to_vec();
            let kernel_done = out[2][0] != 0.0;
            for k in 0..4 {
                assert!(
                    (obs_n[k] - kernel_state[k]).abs() < 1e-4,
                    "step {step} dim {k}: native {obs_n:?} kernel {kernel_state:?}"
                );
            }
            assert_eq!(tn.done, kernel_done, "step {step}");
        }

        for k in 0..4 {
            assert!(
                (obs_n[k] - obs_s[k]).abs() < 1e-3,
                "step {step} dim {k}: native {obs_n:?} script {obs_s:?}"
            );
        }
        assert_eq!(tn.done, ts.done, "step {step}");
        if tn.done {
            break;
        }
    }
}

#[test]
fn script_runner_is_substantially_slower_than_native() {
    // The Fig.-1 premise, asserted as an invariant: the interpreted
    // runner must cost at least 5x the native env per step (the paper
    // reports ~5x for CPython; the tree-walker sits in the same class).
    use std::time::Instant;

    let steps = 20_000;
    let time_env = |env: &mut dyn Env| {
        env.seed(0);
        let mut rng = Pcg32::new(1, 1);
        let space = env.action_space();
        let mut obs = vec![0.0f32; env.obs_dim()];
        env.reset_into(&mut obs);
        let t0 = Instant::now();
        for _ in 0..steps {
            let a = space.sample(&mut rng);
            let t = env.step_into(&a, &mut obs);
            if t.done {
                env.reset_into(&mut obs);
            }
        }
        t0.elapsed().as_secs_f64()
    };
    let mut native = CartPole::new();
    let mut scripted = script::envs::cartpole();
    let t_native = time_env(&mut native);
    let t_script = time_env(&mut scripted);
    let ratio = t_script / t_native;
    assert!(
        ratio > 5.0,
        "interpreted/native ratio only {ratio:.1}x (native {t_native:.4}s, script {t_script:.4}s)"
    );
}

#[test]
fn all_script_envs_track_native_returns() {
    // Return-level agreement over full episodes with a fixed policy.
    let run = |env: &mut dyn Env, seed: u64| -> f32 {
        env.seed(seed);
        let mut obs = vec![0.0f32; env.obs_dim()];
        env.reset_into(&mut obs);
        let mut ret = 0.0;
        for i in 0..200 {
            let a = Action::Discrete(i % 2);
            let t = env.step_into(&a, &mut obs);
            ret += t.reward;
            if t.done {
                break;
            }
        }
        ret
    };
    let mut nat = cairl::envs::MountainCar::new();
    let mut scr = script::envs::mountain_car();
    assert_eq!(run(&mut nat, 5), run(&mut scr, 5));

    let mut nat = CartPole::new();
    let mut scr = script::envs::cartpole();
    let (a, b) = (run(&mut nat, 5), run(&mut scr, 5));
    assert!((a - b).abs() <= 1.0, "cartpole returns {a} vs {b}");
}

#[test]
fn flash_env_trajectories_are_seed_stable() {
    // Regression guard for the ASVM games: seeded rollouts pin the full
    // observation stream.
    let collect = |seed: u64| -> Vec<f32> {
        let mut env = cairl::flash::games::multitask();
        env.seed(seed);
        let mut obs = vec![0.0f32; env.obs_dim()];
        env.reset_into(&mut obs);
        let mut trace = Vec::new();
        for i in 0..50 {
            let t = env.step_into(&Action::Discrete(i % 4), &mut obs);
            trace.push(obs[0]);
            trace.push(obs[5]);
            if t.done {
                break;
            }
        }
        trace
    };
    assert_eq!(collect(7), collect(7));
    assert_ne!(collect(7), collect(8));
}
