//! Property tests: scenario-mixture pools.
//!
//! The heterogeneous-pool contract (ISSUE 2): a mixture pool's per-lane
//! trajectories are **bit-identical** to N single-env pools constructed
//! with the same per-lane seeds — across every executor kind and thread
//! count, through auto-reset boundaries — and the zero-padded tail of
//! every narrow lane stays zero no matter what garbage the caller's
//! batch buffer held.
//!
//! Thread counts default to 1/2/4; the CI determinism matrix re-runs
//! the suite with `CAIRL_TEST_THREADS` pinned to each of 1, 2, 4, 8.

mod common;

use cairl::coordinator::experiment::{build_mixture_executor, ExecutorKind};
use cairl::coordinator::pool::BatchedExecutor;
use cairl::coordinator::registry::MixtureSpec;
use cairl::coordinator::vec_env::VecEnv;
use cairl::core::env::Transition;
use cairl::core::rng::Pcg32;
use cairl::core::spaces::Action;
use cairl::make;
use common::test_threads;

const BASE_SEED: u64 = 41;
/// Enough steps to cross MountainCar-v0's 200-step truncation boundary
/// and many random-action CartPole terminations (auto-reset coverage).
const STEPS: usize = 230;

/// The reference mixture: wide + narrow + interpreted lanes.  8 lanes
/// so every CI matrix leg (1/2/4/8 threads) gets a distinct worker
/// partitioning — pools clamp threads to the lane count.
const SPEC: &str = "CartPole-v1:4,MountainCar-v0:2,Script/CartPole-v1:2";

/// Per-step, per-lane action tape drawn from each lane's own action
/// space with a lane-keyed rng stream (tape is independent of executor
/// and thread count).
fn mixture_tape(spec: &MixtureSpec, steps: usize) -> Vec<Vec<Action>> {
    let mut spaces = Vec::new();
    for entry in spec.entries() {
        let env = make(&entry.spec).unwrap();
        for _ in 0..entry.count {
            spaces.push(env.action_space());
        }
    }
    let mut rngs: Vec<Pcg32> = (0..spaces.len())
        .map(|lane| Pcg32::new(0x7a9e_5eed, lane as u64 + 1))
        .collect();
    (0..steps)
        .map(|_| {
            spaces
                .iter()
                .zip(rngs.iter_mut())
                .map(|(space, rng)| space.sample(rng))
                .collect()
        })
        .collect()
}

/// Replay the tape on a mixture executor, poisoning the batch buffer
/// before every call (the executor must overwrite lanes and re-zero
/// tails), returning per-lane unpadded (obs, transition) streams.
fn mixture_trajectory(
    exec: &mut dyn BatchedExecutor,
    tape: &[Vec<Action>],
) -> Vec<Vec<(Vec<f32>, Transition)>> {
    let n = exec.num_lanes();
    let padded = exec.obs_dim();
    let specs = exec.lane_specs().to_vec();
    let mut obs = vec![f32::NAN; n * padded];
    let mut tr = vec![Transition::default(); n];
    let mut streams: Vec<Vec<(Vec<f32>, Transition)>> = vec![Vec::new(); n];
    exec.reset_into(&mut obs);
    for (lane, spec) in specs.iter().enumerate() {
        let slot = &obs[spec.offset..spec.offset + padded];
        assert!(
            slot[spec.obs_dim..].iter().all(|&v| v == 0.0),
            "lane {lane}: padded tail not zeroed on reset"
        );
        streams[lane].push((slot[..spec.obs_dim].to_vec(), Transition::default()));
    }
    for actions in tape {
        obs.fill(f32::NAN); // executors must fully own the buffer
        exec.step_into(actions, &mut obs, &mut tr);
        for (lane, spec) in specs.iter().enumerate() {
            let slot = &obs[spec.offset..spec.offset + padded];
            assert!(
                slot[spec.obs_dim..].iter().all(|&v| v == 0.0),
                "lane {lane}: padded tail not zeroed on step"
            );
            streams[lane].push((slot[..spec.obs_dim].to_vec(), tr[lane]));
        }
    }
    streams
}

/// The single-env references: one homogeneous `VecEnv` per mixture
/// component, seeded with the same per-lane seeds the mixture assigns
/// (`BASE_SEED + global_lane`), replaying the same per-lane actions.
fn reference_trajectories(
    spec: &MixtureSpec,
    tape: &[Vec<Action>],
) -> Vec<Vec<(Vec<f32>, Transition)>> {
    let mut streams = Vec::new();
    let mut lane0 = 0usize;
    for entry in spec.entries() {
        let count = entry.count;
        let id = entry.spec.clone();
        let mut v = VecEnv::new(count, BASE_SEED + lane0 as u64, move || {
            make(&id).unwrap()
        });
        let d = BatchedExecutor::obs_dim(&v);
        let mut obs = vec![0.0f32; count * d];
        let mut tr = vec![Transition::default(); count];
        let mut comp: Vec<Vec<(Vec<f32>, Transition)>> = vec![Vec::new(); count];
        v.reset_into(&mut obs);
        for (k, stream) in comp.iter_mut().enumerate() {
            stream.push((obs[k * d..(k + 1) * d].to_vec(), Transition::default()));
        }
        let mut actions = Vec::with_capacity(count);
        for step_actions in tape {
            actions.clear();
            actions.extend_from_slice(&step_actions[lane0..lane0 + count]);
            v.step_into(&actions, &mut obs, &mut tr);
            for (k, stream) in comp.iter_mut().enumerate() {
                stream.push((obs[k * d..(k + 1) * d].to_vec(), tr[k]));
            }
        }
        streams.extend(comp);
        lane0 += count;
    }
    streams
}

#[test]
fn mixture_lanes_are_bit_identical_to_single_env_pools() {
    let spec = MixtureSpec::parse(SPEC).unwrap();
    let tape = mixture_tape(&spec, STEPS);
    let reference = reference_trajectories(&spec, &tape);

    for kind in [
        ExecutorKind::Sequential,
        ExecutorKind::PoolSync,
        ExecutorKind::PoolAsync,
    ] {
        for threads in test_threads() {
            let mut exec =
                build_mixture_executor(&spec, kind, threads, BASE_SEED).unwrap();
            let streams = mixture_trajectory(exec.as_mut(), &tape);
            assert_eq!(streams.len(), reference.len());
            for (lane, (got, want)) in streams.iter().zip(&reference).enumerate() {
                assert_eq!(
                    got, want,
                    "{kind:?} at {threads} threads: lane {lane} diverged from its \
                     single-env reference"
                );
            }
        }
    }
}

#[test]
fn mixture_crosses_auto_reset_boundaries() {
    // The tape is long enough that every component finishes episodes:
    // assert it actually happened, so the bit-equality above is known to
    // cover auto-reset boundaries rather than vacuously passing.
    let spec = MixtureSpec::parse(SPEC).unwrap();
    let tape = mixture_tape(&spec, STEPS);
    let reference = reference_trajectories(&spec, &tape);
    let mut lane0 = 0usize;
    for entry in spec.entries() {
        for lane in lane0..lane0 + entry.count {
            let ends = reference[lane]
                .iter()
                .filter(|(_, t)| t.done || t.truncated)
                .count();
            assert!(
                ends > 0,
                "{} lane {lane}: no episode ended in {STEPS} steps — \
                 auto-reset boundaries not exercised",
                entry.spec
            );
        }
        lane0 += entry.count;
    }
}

#[test]
fn every_script_env_participates_in_the_mixture_namespace() {
    // Script-runner ids are first-class mixture components.
    for id in cairl::script::envs::ids() {
        let spec = MixtureSpec::parse(&format!("CartPole-v1:1,{id}:1")).unwrap();
        let mut exec =
            build_mixture_executor(&spec, ExecutorKind::PoolSync, 2, 3).unwrap();
        assert_eq!(exec.num_lanes(), 2);
        assert_eq!(exec.lane_specs()[1].env_id, id);
        let tape = mixture_tape(&spec, 25);
        let streams = mixture_trajectory(exec.as_mut(), &tape);
        assert!(streams[1]
            .iter()
            .all(|(obs, _)| obs.iter().all(|v| v.is_finite())));
    }
}
