//! Integration: the `cairl` launcher binary end to end.

use std::process::Command;

fn cairl(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_cairl"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn no_args_prints_usage() {
    let (stdout, _, ok) = cairl(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("list-envs"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let (stdout, _, ok) = cairl(&["frobnicate"]);
    assert!(!ok);
    assert!(stdout.contains("USAGE"));
}

#[test]
fn list_envs_shows_all_runners() {
    let (stdout, _, ok) = cairl(&["list-envs"]);
    assert!(ok);
    for id in [
        "CartPole-v1",
        "Script/CartPole-v1",
        "Flash/Multitask-v0",
        "Puzzle/LightsOut-v0",
        "GridRTS-v0",
    ] {
        assert!(stdout.contains(id), "missing {id}:\n{stdout}");
    }
}

#[test]
fn run_reports_throughput() {
    let (stdout, _, ok) = cairl(&["run", "--env", "CartPole-v1", "--steps", "5000"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("5000 steps"));
    assert!(stdout.contains("steps/s"));
}

#[test]
fn run_rejects_unknown_env() {
    let (_, stderr, ok) = cairl(&["run", "--env", "NoSuchEnv-v9"]);
    assert!(!ok);
    assert!(stderr.contains("NoSuchEnv-v9"), "{stderr}");
}

#[test]
fn run_ascii_renders_a_frame() {
    let (stdout, _, ok) = cairl(&[
        "run", "--env", "CartPole-v1", "--steps", "50", "--render", "--ascii",
    ]);
    assert!(ok);
    // ASCII art contains at least one shaded row.
    assert!(stdout.lines().filter(|l| l.contains('#') || l.contains('@')).count() > 0
        || stdout.contains('.'), "{stdout}");
}

#[test]
fn config_show_dqn_prints_table_one() {
    let (stdout, _, ok) = cairl(&["config", "--show-dqn"]);
    assert!(ok);
    for row in ["Discount", "Huber", "50000", "3e-4", "Table I"] {
        assert!(stdout.contains(row), "missing {row}:\n{stdout}");
    }
}

#[test]
fn config_default_is_parseable_json() {
    let (stdout, _, ok) = cairl(&["config"]);
    assert!(ok);
    // The printed config must round-trip through the toolkit's parser.
    assert!(stdout.trim_start().starts_with('{'));
    assert!(stdout.contains("\"dqn\""));
}

#[test]
fn tournament_prints_standings() {
    let (stdout, _, ok) = cairl(&["tournament", "--rounds", "2", "--seed", "1"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("Swiss tournament"));
    assert!(stdout.contains("rush"));
    assert!(stdout.contains("pts"));
}

#[test]
fn energy_reports_co2() {
    let (stdout, _, ok) = cairl(&["energy", "--env", "CartPole-v1", "--steps", "20000"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("co2="));
    assert!(stdout.contains("mWh"));
}

#[test]
fn train_smoke_via_cli() {
    let (stdout, stderr, ok) = cairl(&[
        "train", "--env", "cartpole", "--max-steps", "700", "--seed", "3",
    ]);
    // Training needs the PJRT artifacts; without them (offline `xla`
    // stub) the launcher must fail with a runtime error, not a panic.
    if !ok && stderr.contains("runtime error") {
        eprintln!("SKIP train_smoke_via_cli (runtime unavailable): {stderr}");
        return;
    }
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("training DQN on CartPole-v1"));
    assert!(stdout.contains("steps=700"));
}

#[test]
fn run_batched_executor_reports_lane_throughput() {
    let (stdout, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1", "--steps", "8000", "--lanes", "8",
        "--executor", "pool", "--threads", "2",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("[pool x 8 lanes, fused kernel]"), "{stdout}");
    assert!(stdout.contains("8000 lane-steps"), "{stdout}");
    assert!(stdout.contains("steps/s"), "{stdout}");
}

#[test]
fn run_honors_executor_config_file() {
    let dir = std::env::temp_dir().join(format!("cairl_cli_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(
        &path,
        r#"{"env": "CartPole-v1", "executor": {"kind": "pool", "lanes": 4, "threads": 2}}"#,
    )
    .unwrap();
    let (stdout, stderr, ok) = cairl(&[
        "run", "--steps", "4000", "--config", path.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    // The executor block alone must select the pooled batched path.
    assert!(stdout.contains("[pool x 4 lanes, fused kernel]"), "{stdout}");
    assert!(stdout.contains("4000 lane-steps"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_mixture_spec_selects_batched_path_with_spec_lanes() {
    let (stdout, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1:3,Acrobot-v1:2", "--steps", "500",
        "--executor", "pool", "--threads", "2",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    // 5 lanes come from the spec, not --lanes.
    assert!(stdout.contains("[pool x 5 lanes, fused kernel]"), "{stdout}");
    assert!(stdout.contains("500 lane-steps"), "{stdout}");
}

#[test]
fn run_mixture_spec_ignores_lanes_flag_with_a_note() {
    let (stdout, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1:2,MountainCar-v0:2", "--steps", "400",
        "--lanes", "64",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("x 4 lanes, fused kernel]"), "{stdout}");
    assert!(stderr.contains("--lanes is ignored"), "{stderr}");
}

#[test]
fn run_rejects_bad_mixture_spec() {
    let (_, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1:0", "--steps", "100",
    ]);
    assert!(!ok);
    assert!(stderr.contains("zero lanes"), "{stderr}");
}

#[test]
fn run_writes_a_deterministic_returns_log() {
    // The same spec/seed must produce byte-identical episode-return
    // logs on different executors — the artifact the CI shard-smoke
    // job diffs.
    let dir = std::env::temp_dir();
    let log = |tag: &str| {
        dir.join(format!("cairl-returns-{}-{tag}.log", std::process::id()))
    };
    let run = |executor: &str, path: &std::path::Path| {
        let (stdout, stderr, ok) = cairl(&[
            "run", "--env", "CartPole-v1?max_steps=20", "--steps", "2000",
            "--seed", "3", "--lanes", "4", "--executor", executor,
            "--threads", "2", "--returns-log", path.to_str().unwrap(),
        ]);
        assert!(ok, "{stdout}\n{stderr}");
    };
    let (vec_log, pool_log) = (log("vec"), log("pool"));
    run("vec", &vec_log);
    run("pool", &pool_log);
    let a = std::fs::read_to_string(&vec_log).unwrap();
    let b = std::fs::read_to_string(&pool_log).unwrap();
    assert!(a.lines().count() > 10, "{a:?}");
    assert_eq!(a, b, "returns logs must be executor-invariant");
    let _ = std::fs::remove_file(&vec_log);
    let _ = std::fs::remove_file(&pool_log);
}

#[cfg(unix)]
#[test]
fn serve_and_run_shard_round_trip_via_cli() {
    // The CI shard-smoke job in miniature: serve a mixture on a unix
    // socket, run a seeded sharded workload against it, and require
    // the episode-return log to equal the local executor's.
    use std::process::{Command, Stdio};
    let dir = std::env::temp_dir();
    let sock = dir.join(format!("cairl-cli-shard-{}.sock", std::process::id()));
    let addr = format!("unix://{}", sock.display());
    let spec = "CartPole-v1?max_steps=25:3,MountainCar-v0?max_steps=30:2";
    let mut server = Command::new(env!("CARGO_BIN_EXE_cairl"))
        .args(["serve", "--env", spec, "--listen", &addr, "--threads", "2"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn serve daemon");
    // Wait for the socket to appear.
    for _ in 0..100 {
        if sock.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert!(sock.exists(), "serve daemon never bound {addr}");

    let shard_log = dir.join(format!("cairl-cli-shard-{}.log", std::process::id()));
    let local_log = dir.join(format!("cairl-cli-local-{}.log", std::process::id()));
    let (stdout, stderr, ok) = cairl(&[
        "run", "--env", spec, "--steps", "4000", "--seed", "11",
        "--shard", &addr, "--returns-log", shard_log.to_str().unwrap(),
    ]);
    let _ = server.kill();
    let _ = server.wait();
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("[1 shards x 5 lanes]"), "{stdout}");
    assert!(stderr.contains("shard plan:"), "{stderr}");

    let (stdout, stderr, ok) = cairl(&[
        "run", "--env", spec, "--steps", "4000", "--seed", "11",
        "--executor", "vec", "--returns-log", local_log.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    let sharded = std::fs::read_to_string(&shard_log).unwrap();
    let local = std::fs::read_to_string(&local_log).unwrap();
    assert!(sharded.lines().count() > 5, "{sharded:?}");
    assert_eq!(sharded, local, "sharded and local returns logs must match");
    let _ = std::fs::remove_file(&shard_log);
    let _ = std::fs::remove_file(&local_log);
    let _ = std::fs::remove_file(&sock);
}

#[test]
fn run_shard_rejects_wrap_chains() {
    let (_, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1", "--steps", "100",
        "--shard", "unix:///tmp/nonexistent-cairl.sock", "--wrap", "NormalizeObs",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--wrap is not supported"), "{stderr}");
}

/// The episode count out of a `run` report line
/// (`"...: N steps, M episodes, ..."`).
fn episode_count(stdout: &str) -> u64 {
    stdout
        .split(" episodes")
        .next()
        .and_then(|head| head.rsplit(' ').next())
        .and_then(|n| n.parse().ok())
        .unwrap_or_else(|| panic!("no episode count in {stdout:?}"))
}

#[test]
fn run_register_script_builds_heterogeneous_pool_without_recompiling() {
    // The acceptance path: register a user MiniScript env from a file,
    // then run it in one pool next to a kwarg-parameterized native env.
    let script = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/bounce.mpy");
    let (stdout, stderr, ok) = cairl(&[
        "run",
        "--register-script",
        &format!("MyEnv={script}"),
        "--env",
        "Script/MyEnv:8,CartPole-v1?max_steps=200:4",
        "--steps",
        "1200",
        "--threads",
        "2",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stderr.contains("registered Script/MyEnv"), "{stderr}");
    assert!(stdout.contains("x 12 lanes, fused kernel]"), "{stdout}");
    assert!(stdout.contains("1200 lane-steps"), "{stdout}");
    assert!(stdout.contains("steps/s"), "{stdout}");
}

#[test]
fn run_register_script_rejects_broken_sources_and_specs() {
    let dir = std::env::temp_dir().join(format!("cairl_cli_mpy_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("broken.mpy");
    std::fs::write(&path, "this is not MiniScript (").unwrap();
    let (_, stderr, ok) = cairl(&[
        "run",
        "--register-script",
        &format!("Broken={}", path.display()),
        "--steps",
        "10",
    ]);
    assert!(!ok);
    assert!(stderr.contains("script error"), "{stderr}");
    let (_, stderr, ok) = cairl(&["run", "--register-script", "NoEquals", "--steps", "10"]);
    assert!(!ok);
    assert!(stderr.contains("NAME=FILE.mpy"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_id_kwargs_shorten_episodes() {
    let (stdout, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1?max_steps=5", "--steps", "400",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    // A 5-step cap over 400 steps ends at least 400/5 = 80 episodes.
    let episodes = episode_count(&stdout);
    assert!(episodes >= 80, "{stdout}");
}

#[test]
fn run_rejects_unknown_kwargs_with_the_valid_set() {
    let (_, stderr, ok) = cairl(&["run", "--env", "CartPole-v1?nope=3", "--steps", "100"]);
    assert!(!ok);
    assert!(stderr.contains("nope"), "{stderr}");
    assert!(stderr.contains("max_steps"), "valid kwargs listed: {stderr}");
}

#[test]
fn run_wrap_applies_a_declarative_chain() {
    let (stdout, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1", "--steps", "400", "--wrap", "TimeLimit(5)",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    let episodes = episode_count(&stdout);
    assert!(episodes >= 80, "{stdout}");

    let (_, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1", "--steps", "100", "--wrap", "Bogus(1)",
    ]);
    assert!(!ok);
    assert!(stderr.contains("Bogus"), "{stderr}");
}

#[test]
fn run_honors_config_wrappers_block() {
    let dir = std::env::temp_dir().join(format!("cairl_cli_wrap_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(&path, r#"{"env": "CartPole-v1", "wrappers": ["TimeLimit(5)"]}"#).unwrap();
    let (stdout, stderr, ok) = cairl(&[
        "run", "--steps", "400", "--config", path.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    let episodes = episode_count(&stdout);
    assert!(episodes >= 80, "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_kernel_flag_flips_the_stepping_path() {
    // Same workload on both kernels: identical counts (bit-equality is
    // pinned at the library level), distinct report labels.
    let run = |kernel: &str| {
        let (stdout, stderr, ok) = cairl(&[
            "run", "--env", "CartPole-v1", "--steps", "4000", "--lanes", "4",
            "--executor", "pool", "--threads", "2", "--kernel", kernel,
        ]);
        assert!(ok, "{stdout}\n{stderr}");
        assert!(
            stdout.contains(&format!("[pool x 4 lanes, {kernel} kernel]")),
            "{stdout}"
        );
        episode_count(&stdout)
    };
    assert_eq!(run("scalar"), run("fused"));

    let (_, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1", "--steps", "100", "--lanes", "2",
        "--kernel", "warp",
    ]);
    assert!(!ok);
    assert!(stderr.contains("warp"), "{stderr}");
}

#[test]
fn envs_json_dumps_the_registry() {
    for cmd in ["envs", "list-envs"] {
        let (stdout, _, ok) = cairl(&[cmd, "--json"]);
        assert!(ok);
        assert!(stdout.trim_start().starts_with('{'), "{cmd}: {stdout}");
        for needle in [
            "\"schema\":\"cairl-envs/v1\"",
            "\"id\":\"CartPole-v1\"",
            "\"batch_capable\":true",
            "\"batch_capable\":false",
            "\"max_steps\":500",
            "TimeLimit(500)",
        ] {
            assert!(stdout.contains(needle), "{cmd}: missing {needle}\n{stdout}");
        }
    }
    // Without --json the human listing is unchanged.
    let (stdout, _, ok) = cairl(&["envs"]);
    assert!(ok);
    assert!(stdout.contains("CartPole-v1"));
    assert!(!stdout.trim_start().starts_with('{'));
}

#[test]
fn run_rejects_unknown_executor() {
    let (_, stderr, ok) = cairl(&[
        "run", "--env", "CartPole-v1", "--steps", "100", "--executor", "warp",
    ]);
    assert!(!ok);
    assert!(stderr.contains("warp"), "{stderr}");
}
