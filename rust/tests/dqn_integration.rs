//! Integration: DQN agent training through the full stack —
//! Rust env -> replay -> epsilon-greedy -> PJRT train-step artifact.
//!
//! Short-budget runs (seconds, not the full Fig.-2 protocol — that lives
//! in `examples/dqn_cartpole.rs` and `benches/fig2_dqn_training.rs`).

use cairl::agents::dqn::{DqnAgent, DqnConfig};
use cairl::make;
use cairl::runtime::Runtime;

/// These tests train through the PJRT artifacts; skip visibly when the
/// runtime is unavailable (offline `xla` stub or missing `artifacts/`).
fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP dqn_integration: {e}");
            None
        }
    }
}

fn quick_config(seed: u64, max_steps: u32) -> DqnConfig {
    DqnConfig {
        max_steps,
        learn_start: 200,
        epsilon_decay_steps: 2_000,
        solve_return: f32::INFINITY, // never early-stop in smoke tests
        seed,
        ..DqnConfig::default()
    }
}

#[test]
fn dqn_runs_2000_steps_on_cartpole() {
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    let mut agent = DqnAgent::new(&rt, "cartpole", quick_config(0, 2_000)).unwrap();
    let mut env = make("CartPole-v1").unwrap();
    let out = agent.train(&mut rt, &mut env).unwrap();
    assert_eq!(out.env_steps, 2_000);
    assert!(out.train_steps > 1_000, "{}", out.train_steps);
    assert!(out.episodes > 10);
    assert!(!out.curve.is_empty());
    assert!(out.curve.iter().all(|p| p.ret.is_finite()));
    assert!(out.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn dqn_improves_over_random_on_cartpole() {
    // 15k steps is enough for DQN to hold the pole noticeably longer
    // than the ~22-step random baseline.
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    let mut agent = DqnAgent::new(&rt, "cartpole", quick_config(1, 15_000)).unwrap();
    let mut env = make("CartPole-v1").unwrap();
    let out = agent.train(&mut rt, &mut env).unwrap();
    let last20: Vec<f32> = out.curve.iter().rev().take(20).map(|p| p.ret).collect();
    let mean_late = last20.iter().sum::<f32>() / last20.len() as f32;
    // Random CartPole averages ~22 steps/episode; require a clear >1.5x
    // improvement within this short budget (full convergence is the
    // Fig.-2 bench's job, not a unit test's).
    assert!(
        mean_late > 35.0,
        "late mean return {mean_late} (curve tail: {last20:?})"
    );
}

#[test]
fn dqn_training_is_seed_reproducible() {
    if runtime_or_skip().is_none() {
        return;
    }
    let run = |seed: u64| {
        let mut rt = Runtime::from_default_artifacts().expect("checked above");
        let mut agent =
            DqnAgent::new(&rt, "cartpole", quick_config(seed, 1_200)).unwrap();
        let mut env = make("CartPole-v1").unwrap();
        let out = agent.train(&mut rt, &mut env).unwrap();
        (
            out.episodes,
            out.curve.iter().map(|p| p.ret).collect::<Vec<f32>>(),
        )
    };
    let (ep_a, curve_a) = run(42);
    let (ep_b, curve_b) = run(42);
    assert_eq!(ep_a, ep_b);
    assert_eq!(curve_a, curve_b, "same seed must give identical curves");
    let (_, curve_c) = run(43);
    assert_ne!(curve_a, curve_c, "different seeds must differ");
}

#[test]
fn dqn_trains_on_flash_multitask() {
    // Fig.-3 smoke: the flash runner feeds DQN through the same loop.
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    let mut cfg = quick_config(3, 1_500);
    cfg.learn_start = 300;
    let mut agent = DqnAgent::new(&rt, "multitask", cfg).unwrap();
    let mut env = make("Flash/Multitask-v0").unwrap();
    let out = agent.train(&mut rt, &mut env).unwrap();
    assert_eq!(out.env_steps, 1_500);
    assert!(out.episodes >= 1);
    assert!(out.train_steps > 0);
}

#[test]
fn dqn_trains_on_every_artifact_env() {
    let pairs = [
        ("cartpole", "CartPole-v1"),
        ("mountaincar", "MountainCar-v0"),
        ("acrobot", "Acrobot-v1"),
        ("pendulum", "PendulumDiscrete-v1"),
    ];
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    for (art, env_id) in pairs {
        let mut agent = DqnAgent::new(&rt, art, quick_config(0, 600)).unwrap();
        let mut env = make(env_id).unwrap();
        let out = agent.train(&mut rt, &mut env).unwrap();
        assert_eq!(out.env_steps, 600, "{env_id}");
        assert!(out.train_steps > 0, "{env_id}");
    }
}

#[test]
fn epsilon_schedule_reaches_final_value() {
    let Some(rt) = runtime_or_skip() else {
        return;
    };
    let agent = DqnAgent::new(&rt, "cartpole", quick_config(0, 100)).unwrap();
    assert!((agent.epsilon(0) - 1.0).abs() < 1e-6);
    assert!((agent.epsilon(2_000) - 0.01).abs() < 1e-6);
    assert!(agent.epsilon(1_000) < 0.6);
    assert!(agent.epsilon(1_000) > 0.4);
}
