//! Integration: the robustness layer (protocol v5).
//!
//! The load-bearing invariants: (1) a silent-but-open peer surfaces as
//! [`CairlError::DeadlineExceeded`] within the configured window, never
//! an indefinite stall — including a SIGSTOP'd daemon whose kernel
//! still accepts connects; (2) deterministic seed-driven fault
//! injection (`--chaos`) exercises the corruption / truncation / delay
//! / reset machinery while the workload's episode returns stay **bit
//! identical** to a fault-free local run (every fault routes into the
//! failover replay path from PR 6); (3) `Ping`/`Pong` heartbeats keep
//! idle connections off the server's idle reaper, and the reaper bites
//! when they are absent; (4) a draining daemon finishes its in-flight
//! clients, answers new `Hello`s with `Busy`, and exits.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::{Duration, Instant};

use cairl::coordinator::experiment::{
    build_executor_with_kernel, run_batched_workload, ExecutorKind, KernelMode,
};
use cairl::coordinator::pool::BatchedExecutor;
use cairl::core::error::CairlError;
use cairl::faults::ChaosProfile;
use cairl::shard::{
    ConnectOptions, FailoverConfig, ServeConfig, ShardClient, ShardPoolOptions, ShardServer,
    ShardedEnvPool,
};
use cairl::telemetry;

const MIX: &str = "CartPole-v1?max_steps=25:3,MountainCar-v0?max_steps=30:3";
const SEED: u64 = 21;

fn uniform_costs() -> BTreeMap<String, f64> {
    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1?max_steps=25".to_string(), 1.0);
    costs.insert("MountainCar-v0?max_steps=30".to_string(), 1.0);
    costs
}

fn cartpole_costs() -> BTreeMap<String, f64> {
    let mut costs = BTreeMap::new();
    costs.insert("CartPole-v1".to_string(), 1.0);
    costs
}

/// Unique listen address per server (unix socket on unix, TCP loopback
/// elsewhere).
fn fresh_addr() -> String {
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let k = NEXT.fetch_add(1, Ordering::Relaxed);
    #[cfg(unix)]
    {
        let path = std::env::temp_dir().join(format!(
            "cairl-chaos-test-{}-{k}.sock",
            std::process::id()
        ));
        format!("unix://{}", path.display())
    }
    #[cfg(not(unix))]
    {
        let _ = k;
        "tcp://127.0.0.1:0".to_string()
    }
}

/// Quick failover policy: short backoff, a few re-dials, replan on.
fn fast_failover() -> FailoverConfig {
    FailoverConfig {
        redial_attempts: 5,
        backoff_ms: 5,
        backoff_cap_ms: 40,
        replan: true,
    }
}

/// Sum of every wire-fault kind the injector counts.
fn faults_injected() -> u64 {
    ["corrupt", "truncate", "delay", "reset", "freeze"]
        .iter()
        .map(|k| {
            telemetry::counter(&format!("cairl_faults_injected_total{{kind={k:?}}}")).get()
        })
        .sum()
}

#[test]
fn read_deadline_surfaces_a_silent_peer_within_bound() {
    // A black-hole peer: accepts the connection, holds it open, never
    // answers a byte — the exact wire signature of a frozen shard.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    std::thread::spawn(move || {
        let mut held = Vec::new();
        while let Ok((stream, _)) = listener.accept() {
            held.push(stream);
        }
    });

    let before = telemetry::counter("cairl_deadline_timeouts_total").get();
    let opts = ConnectOptions {
        read_timeout: Some(Duration::from_millis(150)),
        ..ConnectOptions::default()
    };
    let start = Instant::now();
    let err = ShardClient::connect_with(
        &format!("tcp://127.0.0.1:{port}"),
        "CartPole-v1:1",
        0,
        0,
        &opts,
    )
    .expect_err("a silent peer must trip the read deadline");
    let elapsed = start.elapsed();
    assert!(
        matches!(err, CairlError::DeadlineExceeded(_)),
        "expected DeadlineExceeded, got {err}"
    );
    assert!(
        elapsed >= Duration::from_millis(100) && elapsed < Duration::from_secs(5),
        "deadline fired after {elapsed:?}, configured 150ms"
    );
    assert!(
        telemetry::counter("cairl_deadline_timeouts_total").get() > before,
        "timeout must count into cairl_deadline_timeouts_total"
    );
}

#[test]
fn ping_round_trips_and_counts_heartbeats() {
    let server = ShardServer::bind(&fresh_addr(), ServeConfig::new("CartPole-v1")).unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();

    let sent_before = telemetry::counter("cairl_heartbeats_sent_total").get();
    let mut client = ShardClient::connect(&addr, "CartPole-v1:1", 0, 0).unwrap();
    client.ping().expect("ping over a healthy connection");
    client.ping().expect("pings are repeatable");
    assert!(
        telemetry::counter("cairl_heartbeats_sent_total").get() >= sent_before + 2,
        "each probe must count into cairl_heartbeats_sent_total"
    );
    // The probed connection still serves batches afterwards.
    client.send_reset(cairl::telemetry::trace::TraceCtx::NONE).unwrap();
    let obs = client.recv_obs().unwrap();
    assert_eq!(obs.len(), client.obs_dim() * client.num_lanes());
    drop(client);
    handle.shutdown();
}

#[test]
fn idle_reaper_bites_without_heartbeats_and_spares_with_them() {
    // Local reference for the returns comparison across the reap.
    let mut local = build_executor_with_kernel(
        "CartPole-v1",
        ExecutorKind::Sequential,
        2,
        1,
        SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let reference = run_batched_workload(local.as_mut(), 30, SEED);

    let config = ServeConfig {
        read_timeout: Some(Duration::from_millis(250)),
        threads: 1,
        ..ServeConfig::new("CartPole-v1")
    };
    let server = ShardServer::bind(&fresh_addr(), config).unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();

    // No heartbeats: the daemon reaps the idle connection, and the next
    // batch rides the failover replay path — returns unaffected.
    let mut quiet = ShardedEnvPool::connect_opts(
        &[addr.clone()],
        "CartPole-v1",
        ShardPoolOptions {
            lanes: 2,
            base_seed: SEED,
            costs: Some(cartpole_costs()),
            failover: fast_failover(),
            ..Default::default()
        },
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(700));
    let r = quiet.run_pipelined_workload(30, SEED);
    assert_eq!(
        r.episode_returns, reference.episode_returns,
        "returns diverged across the idle reap"
    );
    assert!(
        quiet.reconnects()[0] >= 1,
        "the reaper must have severed the idle connection"
    );
    drop(quiet);

    // With heartbeats under the reaper interval the connection stays
    // warm through a much longer idle stretch.
    let mut warm = ShardedEnvPool::connect_opts(
        &[addr],
        "CartPole-v1",
        ShardPoolOptions {
            lanes: 2,
            base_seed: SEED,
            costs: Some(cartpole_costs()),
            failover: fast_failover(),
            heartbeat: Some(Duration::from_millis(50)),
            ..Default::default()
        },
    )
    .unwrap();
    let idle_until = Instant::now() + Duration::from_millis(900);
    while Instant::now() < idle_until {
        std::thread::sleep(Duration::from_millis(80));
        warm.heartbeat();
    }
    assert_eq!(
        warm.reconnects(),
        &[0],
        "heartbeats must keep the idle connection off the reaper"
    );
    let r = warm.run_pipelined_workload(30, SEED);
    assert_eq!(r.episode_returns, reference.episode_returns);
    drop(warm);
    handle.shutdown();
}

#[test]
fn seeded_chaos_leaves_pipelined_returns_bit_identical() {
    // The acceptance shape: a heterogeneous pipelined sharded workload
    // under an aggressive seeded fault profile finishes byte-identical
    // to the fault-free local run.
    let mut local = build_executor_with_kernel(
        MIX,
        ExecutorKind::Sequential,
        1,
        1,
        SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let reference = run_batched_workload(local.as_mut(), 120, SEED);
    assert!(reference.episodes > 0);

    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..2 {
        let config = ServeConfig {
            threads: 2,
            ..ServeConfig::new("CartPole-v1")
        };
        let server = ShardServer::bind(&fresh_addr(), config).unwrap();
        addrs.push(server.local_addr());
        handles.push(server.spawn());
    }

    // Rates in basis points: ~1.5% corrupt, 1% truncate, 2% delay, 1%
    // reset per frame send — dozens of injections over this workload,
    // every one reproducible from (profile, stream, send index).
    let profile =
        ChaosProfile::parse("corrupt=150,truncate=100,delay=200,delay_ms=1,reset=100@11")
            .unwrap();
    let before = faults_injected();
    let opts = ShardPoolOptions {
        base_seed: SEED,
        pipeline: 4,
        costs: Some(uniform_costs()),
        failover: fast_failover(),
        read_timeout: Some(Duration::from_millis(500)),
        chaos: Some(profile),
        ..Default::default()
    };
    let mut pool = ShardedEnvPool::connect_opts(&addrs, MIX, opts).unwrap();
    let r = pool.run_pipelined_workload(120, SEED);
    assert_eq!(r.episodes, reference.episodes, "episode count diverged under chaos");
    assert_eq!(
        r.episode_returns, reference.episode_returns,
        "chaos must never change episode returns"
    );
    assert!(
        faults_injected() > before,
        "the profile must actually inject faults"
    );
    drop(pool);
    for handle in handles {
        handle.shutdown();
    }
}

#[test]
fn draining_daemon_answers_busy_then_exits() {
    let server = ShardServer::bind(&fresh_addr(), ServeConfig::new("CartPole-v1")).unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();

    // An in-flight client connected before the drain keeps working.
    let mut client = ShardClient::connect(&addr, "CartPole-v1:1", 0, 0).unwrap();
    handle.drain();
    assert!(handle.draining());
    client.ping().expect("existing connections survive the drain");

    // New Hellos are turned away with Busy while draining.
    let opts = ConnectOptions {
        busy_retries: 0,
        ..ConnectOptions::default()
    };
    let err = ShardClient::connect_with(&addr, "CartPole-v1:1", 0, 0, &opts).unwrap_err();
    assert!(
        matches!(err, CairlError::Unavailable(_)),
        "a draining daemon must answer Hello with Busy, got {err}"
    );

    // Once the last client leaves, the accept loop exits well inside
    // the grace window.
    drop(client);
    let start = Instant::now();
    handle.shutdown_graceful(Duration::from_secs(30));
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "drain must exit when the connection table empties, not at the deadline"
    );
}

/// The ISSUE acceptance: a SIGSTOP'd daemon — kernel still accepting
/// connects, process answering nothing — triggers deadline-driven
/// failover onto the survivor within the configured bound, with episode
/// returns identical to a healthy run.
#[cfg(unix)]
#[test]
fn sigstopped_daemon_fails_over_within_deadline_bound() {
    use std::process::{Command, Stdio};

    let mut local = build_executor_with_kernel(
        "CartPole-v1",
        ExecutorKind::Sequential,
        4,
        1,
        SEED,
        &[],
        KernelMode::Fused,
    )
    .unwrap();
    let reference = run_batched_workload(local.as_mut(), 60, SEED);

    // Two real daemons in child processes (SIGSTOP must freeze a whole
    // process, not a thread).
    let bin = env!("CARGO_BIN_EXE_cairl");
    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let addr = fresh_addr();
        let child = Command::new(bin)
            .args(["serve", "--env", "CartPole-v1", "--listen", &addr, "--threads", "1"])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn cairl serve");
        addrs.push(addr);
        children.push(child);
    }
    // Wait for both daemons to answer a handshake.
    for addr in &addrs {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            match ShardClient::connect(addr, "CartPole-v1:1", 0, 0) {
                Ok(probe) => {
                    drop(probe);
                    break;
                }
                Err(_) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50))
                }
                Err(e) => panic!("daemon at {addr} never came up: {e}"),
            }
        }
    }

    let opts = ShardPoolOptions {
        base_seed: SEED,
        costs: Some(cartpole_costs()),
        failover: FailoverConfig {
            redial_attempts: 2,
            backoff_ms: 5,
            backoff_cap_ms: 20,
            replan: true,
        },
        read_timeout: Some(Duration::from_millis(300)),
        ..Default::default()
    };
    let mut pool =
        ShardedEnvPool::connect_opts(&addrs, "CartPole-v1:4", opts).unwrap();
    assert_eq!(pool.shards(), 2);

    // Freeze shard 0's daemon mid-run: the socket stays open and the
    // kernel keeps accepting, but no byte ever comes back.
    let frozen = children[0].id().to_string();
    let status = Command::new("kill").args(["-STOP", &frozen]).status().unwrap();
    assert!(status.success(), "kill -STOP failed");

    let start = Instant::now();
    let r = pool.run_pipelined_workload(60, SEED);
    let elapsed = start.elapsed();
    assert_eq!(
        r.episode_returns, reference.episode_returns,
        "returns diverged across the SIGSTOP failover"
    );
    assert!(
        pool.reconnects()[0] >= 1,
        "the frozen shard must have failed over"
    );
    // Bound: a handful of 300ms deadline windows plus replay, far from
    // an indefinite stall.
    assert!(
        elapsed < Duration::from_secs(30),
        "failover took {elapsed:?} against a 300ms deadline"
    );
    drop(pool);

    // Thaw, then exercise the SIGTERM drain path on both daemons: with
    // no clients left they must exit promptly, of their own accord.
    let _ = Command::new("kill").args(["-CONT", &frozen]).status();
    for child in &children {
        let _ = Command::new("kill").args(["-TERM", &child.id().to_string()]).status();
    }
    for mut child in children {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50))
                }
                _ => {
                    let _ = child.kill();
                    panic!("daemon did not exit within the drain grace after SIGTERM");
                }
            }
        }
    }
}
