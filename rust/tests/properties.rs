//! Property-based tests over coordinator invariants.
//!
//! proptest is unavailable in this offline build, so these are
//! PCG-driven randomised properties: each test draws hundreds of random
//! cases from a seeded generator and asserts the invariant on every one
//! (failures print the offending case).  The invariants mirror the
//! DESIGN.md §Testing list: wrapper semantics, vec-env equivalence,
//! replay-buffer bounds, VM safety, tournament pairing rules, RNG
//! reproducibility.

use cairl::coordinator::vec_env::VecEnv;
use cairl::core::env::{Env, Transition};
use cairl::core::rng::Pcg32;
use cairl::core::spaces::{Action, Space};
use cairl::envs::{CartPole, MountainCar, Pendulum};
use cairl::flash::assembler::assemble;
use cairl::flash::opcode::Op;
use cairl::flash::vm::Vm;
use cairl::tooling::tournament::{swiss, GameOutcome};
use cairl::wrappers::{Flatten, FrameStack, NormalizeObs, TimeLimit};

/// Draw `n` random cases with a labelled seed loop.
fn cases(n: u32) -> impl Iterator<Item = (u32, Pcg32)> {
    (0..n).map(|i| (i, Pcg32::new(0xC0FFEE + i as u64, i as u64 + 1)))
}

#[test]
fn prop_time_limit_never_exceeds_cap() {
    for (case, mut rng) in cases(60) {
        let cap = 1 + rng.below(50);
        let mut env = TimeLimit::new(Pendulum::discrete(), cap);
        env.seed(case as u64);
        let mut obs = vec![0.0f32; 3];
        env.reset_into(&mut obs);
        let mut len = 0;
        loop {
            let a = Action::Discrete(rng.below(5) as usize);
            let t = env.step_into(&a, &mut obs);
            len += 1;
            assert!(len <= cap, "case {case}: exceeded cap {cap}");
            if t.done || t.truncated {
                assert_eq!(len, cap, "case {case}: pendulum only ends by cap");
                assert!(t.truncated);
                break;
            }
        }
    }
}

#[test]
fn prop_flatten_preserves_values_and_count() {
    for (case, mut rng) in cases(40) {
        let mut plain = CartPole::new();
        let mut flat = Flatten::new(CartPole::new());
        plain.seed(case as u64);
        flat.seed(case as u64);
        let mut o1 = vec![0.0f32; 4];
        let mut o2 = vec![0.0f32; 4];
        plain.reset_into(&mut o1);
        flat.reset_into(&mut o2);
        for _ in 0..30 {
            let a = Action::Discrete(rng.below(2) as usize);
            let t1 = plain.step_into(&a, &mut o1);
            let t2 = flat.step_into(&a, &mut o2);
            assert_eq!(o1, o2, "case {case}");
            assert_eq!(t1, t2);
            if t1.done {
                break;
            }
        }
        assert_eq!(flat.obs_dim(), plain.obs_dim());
    }
}

#[test]
fn prop_normalize_bounded_dims_stay_in_unit_box() {
    for (case, mut rng) in cases(40) {
        let mut env = NormalizeObs::new(MountainCar::new());
        env.seed(case as u64);
        let mut obs = vec![0.0f32; 2];
        env.reset_into(&mut obs);
        for _ in 0..200 {
            let a = Action::Discrete(rng.below(3) as usize);
            let t = env.step_into(&a, &mut obs);
            for &v in &obs {
                assert!(
                    (-1.0 - 1e-5..=1.0 + 1e-5).contains(&v),
                    "case {case}: {obs:?}"
                );
            }
            if t.done {
                break;
            }
        }
    }
}

#[test]
fn prop_frame_stack_window_shifts_by_one() {
    for (case, mut rng) in cases(30) {
        let k = 2 + rng.below(4) as usize;
        let mut env = FrameStack::new(Pendulum::discrete(), k);
        env.seed(case as u64);
        let dim = 3;
        let mut prev = vec![0.0f32; dim * k];
        let mut cur = vec![0.0f32; dim * k];
        env.reset_into(&mut prev);
        for _ in 0..10 {
            let a = Action::Discrete(rng.below(5) as usize);
            env.step_into(&a, &mut cur);
            // cur[0..(k-1)*dim] must equal prev[dim..k*dim].
            assert_eq!(
                &cur[..(k - 1) * dim],
                &prev[dim..],
                "case {case} k={k}"
            );
            std::mem::swap(&mut prev, &mut cur);
        }
    }
}

#[test]
fn prop_vec_env_equals_sequential() {
    for (case, mut rng) in cases(15) {
        let n = 1 + rng.below(6) as usize;
        let seed = 1000 + case as u64;
        let mut venv = VecEnv::new(n, seed, || TimeLimit::new(CartPole::new(), 30));
        let mut obs = vec![0.0f32; n * 4];
        venv.reset_into(&mut obs);
        let mut refs: Vec<_> = (0..n)
            .map(|i| {
                let mut e = TimeLimit::new(CartPole::new(), 30);
                e.seed(seed + i as u64);
                let mut o = vec![0.0f32; 4];
                e.reset_into(&mut o);
                (e, o)
            })
            .collect();
        let mut tr = vec![Transition::default(); n];
        for _ in 0..60 {
            let actions: Vec<Action> = (0..n)
                .map(|_| Action::Discrete(rng.below(2) as usize))
                .collect();
            venv.step_into(&actions, &mut obs, &mut tr);
            for (i, (e, o)) in refs.iter_mut().enumerate() {
                let t = e.step_into(&actions[i], o);
                if t.done || t.truncated {
                    e.reset_into(o);
                }
                assert_eq!(tr[i], t, "case {case} lane {i}");
                assert_eq!(&obs[i * 4..(i + 1) * 4], &o[..], "case {case} lane {i}");
            }
        }
    }
}

#[test]
fn prop_replay_buffer_len_bounded_and_samples_valid() {
    use cairl::agents::ReplayBuffer;
    use cairl::runtime::dqn_exec::Batch;
    for (case, mut rng) in cases(30) {
        let cap = 1 + rng.below(64) as usize;
        let dim = 1 + rng.below(8) as usize;
        let mut rb = ReplayBuffer::new(cap, dim);
        let pushes = rng.below(200) + 1;
        for p in 0..pushes {
            let v = p as f32;
            rb.push(&vec![v; dim], p as usize % 4, v, &vec![v + 1.0; dim], p % 3 == 0);
            assert!(rb.len() <= cap, "case {case}");
            assert_eq!(rb.len(), ((p + 1) as usize).min(cap));
        }
        let n = 1 + rng.below(rb.len() as u32) as usize;
        let mut batch = Batch::default();
        rb.sample_into(&mut rng, n, &mut batch);
        // Sampled transitions must each be one of the last `cap` pushes.
        let oldest = pushes as i64 - cap as i64;
        for k in 0..n {
            let v = batch.s[k * dim];
            assert!(
                (v as i64) >= oldest.max(0) && (v as i64) < pushes as i64,
                "case {case}: sampled stale transition {v}"
            );
            assert_eq!(batch.s2[k * dim], v + 1.0);
        }
    }
}

#[test]
fn prop_vm_never_panics_on_random_linear_programs() {
    // Random (jump-free) instruction sequences either run to Halt or trap
    // with a clean error — never panic, never corrupt memory bounds.
    for (case, mut rng) in cases(300) {
        let ops = [
            "push 1.5", "push -2", "load 3", "store 3", "dup", "pop", "add",
            "sub", "mul", "div", "min", "max", "neg", "abs", "sign", "eq",
            "lt", "not", "rand", "input", "reward",
        ];
        let len = 1 + rng.below(30);
        let mut src = String::from("halt\nframe:\n");
        for _ in 0..len {
            src.push_str(ops[rng.below(ops.len() as u32) as usize]);
            src.push('\n');
        }
        src.push_str("halt\n");
        let program = assemble(&src).unwrap();
        // Structural sanity: all stores stay in bounds by construction.
        assert!(program.code.iter().all(|op| match op {
            Op::Store(s) | Op::Load(s) => (*s as usize) < 64,
            _ => true,
        }));
        let mut vm = Vm::new(program);
        vm.seed(case as u64);
        vm.reset().unwrap();
        // Result may be Ok or Err(trap) — both acceptable, panics are not.
        let _ = vm.frame(1.0);
    }
}

#[test]
fn prop_swiss_points_conserved_and_no_rematch() {
    for (case, mut rng) in cases(40) {
        let n = 2 + rng.below(9) as usize;
        let rounds = 1 + rng.below(4);
        let mut pairs_seen = std::collections::HashSet::new();
        let mut outcome_rng = Pcg32::new(case as u64, 77);
        let standings = swiss(n, rounds, &mut rng, |a, b| {
            assert!(
                pairs_seen.insert((a.min(b), a.max(b))),
                "case {case}: rematch"
            );
            match outcome_rng.below(3) {
                0 => GameOutcome::WinA,
                1 => GameOutcome::WinB,
                _ => GameOutcome::Draw,
            }
        });
        // Each round hands out exactly 2 points per pair + 2 per bye; with
        // n players that is 2 * ceil(n/2) per round when a bye exists.
        let total: u32 = standings.iter().map(|s| s.score).sum();
        let per_round = 2 * n.div_ceil(2) as u32;
        assert!(
            total <= rounds * per_round,
            "case {case}: {total} > {}",
            rounds * per_round
        );
        assert_eq!(standings.len(), n);
        // Sorted best-first.
        for w in standings.windows(2) {
            assert!(w[0].score >= w[1].score, "case {case}");
        }
    }
}

#[test]
fn prop_pcg_streams_reproducible_and_independent() {
    for (case, _) in cases(50) {
        let seed = 0xABCD + case as u64;
        let mut a1 = Pcg32::new(seed, 1);
        let mut a2 = Pcg32::new(seed, 1);
        let mut b = Pcg32::new(seed, 2);
        let mut equal_ab = 0;
        for _ in 0..200 {
            let x = a1.next_u32();
            assert_eq!(x, a2.next_u32());
            if x == b.next_u32() {
                equal_ab += 1;
            }
        }
        assert!(equal_ab < 5, "case {case}: streams correlate");
    }
}

#[test]
fn prop_space_sample_always_contained() {
    for (case, mut rng) in cases(60) {
        let dim = 1 + rng.below(6) as usize;
        let mut low = Vec::new();
        let mut high = Vec::new();
        for _ in 0..dim {
            let a = rng.uniform(-10.0, 10.0);
            let b = a + rng.uniform(0.1, 5.0);
            low.push(a);
            high.push(b);
        }
        let space = Space::box1(low, high);
        for _ in 0..50 {
            let a = space.sample(&mut rng);
            assert!(space.contains(&a), "case {case}: {a:?}");
        }
        let d = Space::Discrete {
            n: 1 + rng.below(20) as usize,
        };
        for _ in 0..50 {
            assert!(d.contains(&d.sample(&mut rng)), "case {case}");
        }
    }
}
