//! Integration: distributed batch tracing (ISSUE 10).
//!
//! The tracing contract: spans recorded across every layer a batch
//! crosses form a well-formed tree under one trace id per executor —
//! including across the shard transport, where the client stitches
//! server-measured `decode`/`server_step` spans into its own timeline —
//! and tracing **never perturbs execution**: episode-return logs are
//! bit-identical with the recorder on and off, on every executor kind
//! and thread count.  Ring overflow drops the oldest spans and counts
//! them; corrupt or truncated wire trace contexts are protocol errors,
//! never panics.
//!
//! Every test that toggles the process-wide gate serialises on one
//! mutex and filters drained spans by its own trace ids, so the suite
//! stays parallel-safe.

mod common;

use std::collections::{HashMap, HashSet};
use std::io::Write;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use cairl::coordinator::experiment::{
    build_executor_with_kernel, run_batched_workload, ExecutorKind, KernelMode,
};
use cairl::coordinator::pool::BatchedExecutor;
use cairl::shard::proto::{self, Msg, MsgRef};
use cairl::shard::{ServeConfig, ShardPoolOptions, ShardServer, ShardedEnvPool};
use cairl::telemetry::counter;
use cairl::telemetry::trace::{self, SpanKind, SpanRecord, TraceCtx};
use common::test_threads;

const MIX: &str = "CartPole-v1?max_steps=25:4,MountainCar-v0?max_steps=30:4";
const LANES: usize = 8;
const SEED: u64 = 57;
const STEPS_PER_LANE: u64 = 60;

/// Tests that flip the process-wide tracing gate run one at a time.
fn gate() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with tracing enabled and return its result plus every span
/// recorded while it ran (rings are cleared first).
fn traced<R>(f: impl FnOnce() -> R) -> (R, Vec<SpanRecord>) {
    let _g = gate();
    let _ = trace::drain();
    trace::set_enabled(true);
    let out = f();
    trace::set_enabled(false);
    let spans = trace::drain().into_iter().map(|(_, s)| s).collect();
    (out, spans)
}

fn build(kind: &str, threads: usize, kernel: &str) -> Box<dyn BatchedExecutor> {
    build_executor_with_kernel(
        MIX,
        ExecutorKind::parse(kind).unwrap(),
        1, // lane counts come from the mixture spec
        threads,
        SEED,
        &[],
        KernelMode::parse(kernel).unwrap(),
    )
    .unwrap()
}

/// Unique listen address per in-process shard daemon.
fn fresh_addr() -> String {
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let k = NEXT.fetch_add(1, Ordering::Relaxed);
    #[cfg(unix)]
    {
        let path = std::env::temp_dir().join(format!(
            "cairl-trace-shard-{}-{k}.sock",
            std::process::id()
        ));
        format!("unix://{}", path.display())
    }
    #[cfg(not(unix))]
    {
        let _ = k;
        "tcp://127.0.0.1:0".to_string()
    }
}

/// Assert every non-root parent in `spans` resolves to a span recorded
/// under the same trace id.
fn assert_parents_resolve(spans: &[SpanRecord]) {
    let mut ids: HashMap<u64, HashSet<u64>> = HashMap::new();
    for s in spans {
        ids.entry(s.trace_id).or_default().insert(s.span_id);
    }
    for s in spans {
        if s.parent != 0 {
            assert!(
                ids.get(&s.trace_id).is_some_and(|set| set.contains(&s.parent)),
                "{:?} span {} parents under {}, absent from trace {}",
                s.kind,
                s.span_id,
                s.parent,
                s.trace_id
            );
        }
    }
}

#[test]
fn traced_pool_run_produces_a_well_formed_span_tree() {
    let (_, spans) = traced(|| {
        let mut exec = build("pool", 2, "fused");
        run_batched_workload(exec.as_mut(), STEPS_PER_LANE, SEED);
    });

    let batches: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.kind == SpanKind::Batch).collect();
    assert_eq!(batches.len() as u64, STEPS_PER_LANE, "one batch span per step_into");
    let tid = batches[0].trace_id;
    assert_ne!(tid, 0);
    assert!(
        batches.iter().all(|s| s.trace_id == tid && s.parent == 0),
        "every batch span is a root of the executor's single trace"
    );
    assert!(
        spans.iter().any(|s| s.kind == SpanKind::Reset && s.trace_id == tid && s.parent == 0),
        "the reset broadcast records its own root span"
    );
    for kind in [SpanKind::Dispatch, SpanKind::Queue, SpanKind::Kernel] {
        assert!(
            spans.iter().any(|s| s.kind == kind && s.trace_id == tid),
            "{kind:?} spans missing from the pool trace"
        );
    }
    assert_parents_resolve(&spans);

    // Worker kernel spans nest inside the batch window that dispatched
    // them (same clock, so strict containment must hold).
    let window: HashMap<u64, (u64, u64)> = batches
        .iter()
        .map(|b| (b.span_id, (b.t_start_ns, b.t_end_ns)))
        .collect();
    for s in spans.iter().filter(|s| s.kind == SpanKind::Kernel && s.trace_id == tid) {
        let (t0, t1) = window[&s.parent];
        assert!(
            s.t_start_ns >= t0 && s.t_end_ns <= t1,
            "kernel span [{}, {}] escapes its batch window [{t0}, {t1}]",
            s.t_start_ns,
            s.t_end_ns
        );
    }

    // Satellite: the batch-latency histogram derives from the same
    // timestamps as the batch spans.
    let text = cairl::telemetry::render_prometheus();
    assert!(
        text.contains("cairl_batch_latency_us_bucket{exec=\"pool\""),
        "traced batches must feed the per-executor latency histogram"
    );
}

#[test]
fn sharded_run_stitches_server_spans_under_one_trace_id() {
    let (_, spans) = traced(|| {
        let mut addrs = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..2 {
            let config = ServeConfig {
                threads: 2,
                ..ServeConfig::new("CartPole-v1")
            };
            let server = ShardServer::bind(&fresh_addr(), config).expect("bind shard");
            addrs.push(server.local_addr());
            handles.push(server.spawn());
        }
        let opts = ShardPoolOptions {
            lanes: LANES,
            base_seed: SEED,
            ..Default::default()
        };
        let mut pool = ShardedEnvPool::connect_opts(&addrs, MIX, opts).unwrap();
        run_batched_workload(&mut pool, STEPS_PER_LANE, SEED);
        drop(pool);
        handles.into_iter().for_each(|h| h.shutdown());
    });

    // The in-process daemons host executors of their own whose spans
    // land in the same process registry under their own trace ids; the
    // client pool's trace is the one whose wire spans cover the full
    // workload.
    let wire_tids: HashSet<u64> = spans
        .iter()
        .filter(|s| s.kind == SpanKind::Wire)
        .map(|s| s.trace_id)
        .collect();
    let ours: Vec<u64> = wire_tids
        .into_iter()
        .filter(|tid| {
            let batches = spans
                .iter()
                .filter(|s| s.kind == SpanKind::Batch && s.trace_id == *tid)
                .count();
            batches as u64 == STEPS_PER_LANE
        })
        .collect();
    assert_eq!(ours.len(), 1, "exactly one trace owns the sharded workload");
    let tid = ours[0];
    let trace_spans: Vec<SpanRecord> =
        spans.iter().filter(|s| s.trace_id == tid).copied().collect();

    // Client and server sides of the same trace: the batch roots are
    // local, the decode/server_step spans are attributed to both
    // shards, and every parent resolves locally.
    let batch_local = trace_spans
        .iter()
        .filter(|s| s.kind == SpanKind::Batch)
        .all(|s| s.shard == trace::SHARD_LOCAL);
    assert!(batch_local, "client batch roots must be local spans");
    for shard in [0u32, 1] {
        for kind in [SpanKind::Decode, SpanKind::ServerStep] {
            assert!(
                trace_spans.iter().any(|s| s.kind == kind && s.shard == shard),
                "{kind:?} span missing for shard {shard}"
            );
        }
    }
    for kind in [SpanKind::Encode, SpanKind::Wire, SpanKind::Reassemble] {
        assert!(
            trace_spans.iter().any(|s| s.kind == kind),
            "{kind:?} spans missing from the sharded trace"
        );
    }
    assert_parents_resolve(&trace_spans);

    // Stitched server spans stay inside their parent batch window.
    let window: HashMap<u64, (u64, u64)> = trace_spans
        .iter()
        .filter(|s| s.kind == SpanKind::Batch)
        .map(|b| (b.span_id, (b.t_start_ns, b.t_end_ns)))
        .collect();
    for s in trace_spans
        .iter()
        .filter(|s| matches!(s.kind, SpanKind::Decode | SpanKind::ServerStep))
    {
        let (t0, t1) = window[&s.parent];
        assert!(
            s.t_start_ns >= t0 && s.t_end_ns <= t1,
            "{:?} span [{}, {}] escapes its batch window [{t0}, {t1}]",
            s.kind,
            s.t_start_ns,
            s.t_end_ns
        );
    }

    // Chrome-trace round trip is lossless, and the attribution summary
    // names the stitched kinds with high critical-path coverage.
    let path = std::env::temp_dir().join(format!("cairl-trace-{}.json", std::process::id()));
    let pairs: Vec<(u32, SpanRecord)> = trace_spans.iter().map(|s| (0u32, *s)).collect();
    trace::write_atomic(&path, trace::chrome_trace_json(&pairs).as_bytes()).unwrap();
    let parsed = trace::read_chrome_trace(&path).unwrap();
    assert_eq!(parsed, trace_spans, "Chrome JSON round-trip must be lossless");
    let summary = trace::summarize(&parsed);
    for label in ["batch", "wire", "decode", "server_step", "critical-path coverage:"] {
        assert!(summary.contains(label), "summary missing {label:?}:\n{summary}");
    }
    let cov = trace::coverage(&parsed);
    assert!(cov >= 0.90, "critical-path coverage {:.1}% below 90%", cov * 100.0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tracing_on_off_keeps_episode_returns_bit_identical() {
    let _g = gate();
    trace::set_enabled(false);
    for kind in ["vec", "pool", "pool-async"] {
        for &threads in &test_threads() {
            for kernel in ["scalar", "fused"] {
                let mut off_exec = build(kind, threads, kernel);
                let off = run_batched_workload(off_exec.as_mut(), STEPS_PER_LANE, SEED)
                    .episode_returns;
                trace::set_enabled(true);
                let mut on_exec = build(kind, threads, kernel);
                let on = run_batched_workload(on_exec.as_mut(), STEPS_PER_LANE, SEED)
                    .episode_returns;
                trace::set_enabled(false);
                let _ = trace::drain();
                assert!(!off.is_empty(), "workload must complete episodes");
                let off_bits: Vec<u32> = off.iter().map(|r| r.to_bits()).collect();
                let on_bits: Vec<u32> = on.iter().map(|r| r.to_bits()).collect();
                assert_eq!(
                    on_bits, off_bits,
                    "{kind}/{threads} threads/{kernel}: tracing perturbed the returns"
                );
            }
        }
    }
}

#[test]
fn ring_overflow_drops_oldest_and_counts() {
    let _g = gate();
    let _ = trace::drain();
    trace::set_enabled(true);
    let dropped_before = trace::spans_dropped();
    let ctr = counter("cairl_trace_spans_dropped_total");
    let ctr_before = ctr.get();

    // A fresh thread gets a fresh ring, created at the test capacity.
    let tid = trace::new_trace_id();
    std::thread::spawn(move || {
        trace::set_ring_capacity(8);
        for i in 0..20u64 {
            trace::record(SpanRecord {
                span_id: 1000 + i,
                parent: 0,
                trace_id: tid,
                t_start_ns: i,
                t_end_ns: i + 1,
                lane_group: 0,
                shard: trace::SHARD_LOCAL,
                kind: SpanKind::Kernel,
            });
        }
        trace::set_ring_capacity(trace::DEFAULT_RING_CAPACITY);
    })
    .join()
    .unwrap();
    trace::set_enabled(false);

    let kept: Vec<u64> = trace::drain()
        .into_iter()
        .map(|(_, s)| s)
        .filter(|s| s.trace_id == tid)
        .map(|s| s.span_id)
        .collect();
    let newest: Vec<u64> = (1012..1020).collect();
    assert_eq!(kept, newest, "ring keeps the newest spans, drained oldest-first");
    assert_eq!(trace::spans_dropped() - dropped_before, 12, "count every overwritten span");
    assert!(ctr.get() - ctr_before >= 12, "dropped-span counter must advance");
}

#[test]
fn corrupt_or_short_trace_ctx_is_a_protocol_error_not_a_panic() {
    let ctx = TraceCtx {
        trace_id: 0xdead,
        span_id: 0xbeef,
    };
    // Frame layout: len(4) | version tag seq(4) ctx(16) ... | checksum.
    // Flip each ctx byte of a Reset frame; every one must fail decode.
    let frame = proto::encode(1, MsgRef::Reset { ctx });
    for i in 10..26 {
        let mut bad = frame.clone();
        bad[i] ^= 0xff;
        let mut cursor = &bad[..];
        assert!(
            proto::read_msg(&mut cursor).is_err(),
            "ctx byte {i} corruption must not decode"
        );
    }
    // A frame that ends mid-ctx is an error, not an out-of-range slice.
    let mut cursor = &frame[..frame.len() - 10];
    assert!(proto::read_msg(&mut cursor).is_err());

    // End to end: a daemon fed a Hello whose ctx bytes are corrupted
    // answers with a protocol Error (or hangs up) — and stays alive
    // for well-formed clients afterwards.
    let server = ShardServer::bind("tcp://127.0.0.1:0", ServeConfig::new("CartPole-v1")).unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();
    let hello = proto::encode(
        1,
        MsgRef::Hello {
            spec: "",
            base_seed: 1,
            first_lane: 0,
            pipeline: 1,
            token: "",
            wrap: "",
            ctx,
        },
    );
    let mut bad = hello.clone();
    bad[12] ^= 0xff; // inside the 16-byte ctx
    let sock = addr.strip_prefix("tcp://").unwrap();
    let mut stream = std::net::TcpStream::connect(sock).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(&bad).unwrap();
    // A clean hang-up (Err) is equally acceptable — never a panic.
    if let Ok(frame) = proto::read_msg(&mut stream) {
        assert!(
            matches!(frame.msg, Msg::Error { .. }),
            "expected a protocol Error reply, got {:?}",
            frame.msg
        );
    }
    drop(stream);

    let pool = ShardedEnvPool::connect(&[addr], "CartPole-v1", 4, 7).unwrap();
    assert_eq!(pool.num_lanes(), 4);
    drop(pool);
    handle.shutdown();
}
