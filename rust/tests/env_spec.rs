//! Integration: the dynamic `EnvSpec` registry.
//!
//! Four contracts pinned here:
//!
//! 1. **Wrapper-chain equivalence** — a declarative [`WrapperSpec`]
//!    chain is bit-identical to the hand-composed generic wrapper
//!    stack, standalone and through every executor/thread count.
//! 2. **Parameterized construction** — `make("Id?kwargs")` and
//!    `make_with` agree bit-for-bit, and malformed kwargs are errors.
//! 3. **Parameterized mixtures** — kwarg-carrying mixture components
//!    reproduce hand-built per-lane envs exactly, on every executor.
//! 4. **Registry thread safety** — concurrent `register_script` +
//!    `make` traffic races cleanly (and duplicate ids get exactly one
//!    winner).
//!
//! Thread counts default to 1/2/4; the CI determinism matrix re-runs
//! the suite with `CAIRL_TEST_THREADS` pinned to each of 1, 2, 4, 8.

mod common;

use cairl::coordinator::experiment::{
    build_executor, build_executor_wrapped, run_batched_workload, ExecutorKind,
};
use cairl::coordinator::pool::{BatchedExecutor, EnvPool};
use cairl::coordinator::vec_env::VecEnv;
use cairl::core::env::{DynEnv, Env, Transition};
use cairl::core::kwargs::{Kwargs, KwargValue};
use cairl::core::rng::Pcg32;
use cairl::core::spaces::Action;
use cairl::envs::CartPole;
use cairl::wrappers::{
    apply_wrappers, ClipReward, FrameStack, NormalizeObs, RewardScale, TimeLimit, WrapperSpec,
};
use cairl::{list_envs, make, make_with, register_script};
use common::test_threads;

/// Deterministic single-env rollout with auto-reset: seed, then follow
/// a fixed discrete action stream, recording every observation and
/// transition.
fn rollout<E: Env + ?Sized>(env: &mut E, steps: u32, seed: u64) -> (Vec<f32>, Vec<Transition>) {
    let mut rng = Pcg32::new(seed, 5);
    let mut obs = vec![0.0f32; env.obs_dim()];
    env.seed(seed);
    env.reset_into(&mut obs);
    let mut obs_stream = obs.clone();
    let mut tr_stream = Vec::new();
    for _ in 0..steps {
        let a = Action::Discrete(rng.below(2) as usize);
        let t = env.step_into(&a, &mut obs);
        obs_stream.extend_from_slice(&obs);
        tr_stream.push(t);
        if t.done || t.truncated {
            env.reset_into(&mut obs);
            obs_stream.extend_from_slice(&obs);
        }
    }
    (obs_stream, tr_stream)
}

/// Replay a per-step action tape on any executor, returning the full
/// (obs, transition) stream.
fn batch_trajectory(
    exec: &mut dyn BatchedExecutor,
    tape: &[Vec<Action>],
) -> (Vec<f32>, Vec<Transition>) {
    let n = exec.num_lanes();
    let d = exec.obs_dim();
    let mut obs = vec![0.0f32; n * d];
    let mut tr = vec![Transition::default(); n];
    let mut obs_stream = Vec::new();
    let mut tr_stream = Vec::new();
    exec.reset_into(&mut obs);
    obs_stream.extend_from_slice(&obs);
    for actions in tape {
        exec.step_into(actions, &mut obs, &mut tr);
        obs_stream.extend_from_slice(&obs);
        tr_stream.extend_from_slice(&tr);
    }
    (obs_stream, tr_stream)
}

/// `steps` batches of identical-space discrete actions for `lanes`
/// lanes, from a fixed stream.
fn discrete_tape(steps: usize, lanes: usize, seed: u64) -> Vec<Vec<Action>> {
    let mut rng = Pcg32::new(seed, 3);
    (0..steps)
        .map(|_| {
            (0..lanes)
                .map(|_| Action::Discrete(rng.below(2) as usize))
                .collect()
        })
        .collect()
}

#[test]
fn declarative_chain_matches_hand_composed_stack() {
    let chain = WrapperSpec::parse_chain(
        "TimeLimit(90),NormalizeObs,FrameStack(2),RewardScale(2,2),ClipReward(-3,3)",
    )
    .unwrap();
    let mut declarative = apply_wrappers(Box::new(CartPole::new()), &chain);
    let mut manual = ClipReward::new(
        RewardScale::new(
            FrameStack::new(NormalizeObs::new(TimeLimit::new(CartPole::new(), 90)), 2),
            2.0,
            2.0,
        ),
        -3.0,
        3.0,
    );
    assert_eq!(declarative.id(), manual.id());
    assert_eq!(declarative.obs_dim(), manual.obs_dim());
    let (obs_d, tr_d) = rollout(declarative.as_mut(), 400, 9);
    let (obs_m, tr_m) = rollout(&mut manual, 400, 9);
    assert_eq!(tr_d, tr_m, "declarative vs static transitions diverged");
    assert_eq!(obs_d, obs_m, "declarative vs static observations diverged");
    // The clip actually engaged (reward 1 -> x2 + 2 = 4 -> clipped 3).
    assert!(tr_d.iter().all(|t| t.reward == 3.0));
}

#[test]
fn declarative_chains_are_bit_identical_across_executors_and_threads() {
    const LANES: usize = 8;
    let chain = [
        WrapperSpec::TimeLimit { max_steps: 40 },
        WrapperSpec::NormalizeObs,
    ];
    let factory = || apply_wrappers(Box::new(CartPole::new()) as DynEnv, &chain);
    let tape = discrete_tape(120, LANES, 77);
    let mut reference = VecEnv::new(LANES, 5, factory);
    let (obs_ref, tr_ref) = batch_trajectory(&mut reference, &tape);
    for threads in test_threads() {
        let mut pool = EnvPool::new(LANES, 5, threads, factory);
        let (obs, tr) = batch_trajectory(&mut pool, &tape);
        assert_eq!(tr_ref, tr, "transitions diverged at {threads} threads");
        assert_eq!(obs_ref, obs, "observations diverged at {threads} threads");
    }
}

#[test]
fn make_with_and_id_kwargs_agree_bit_for_bit() {
    let kwargs = Kwargs::new().with("max_steps", KwargValue::Int(60));
    let mut from_id = make("CartPole-v1?max_steps=60").unwrap();
    let mut from_kwargs = make_with("CartPole-v1", &kwargs).unwrap();
    let (obs_a, tr_a) = rollout(from_id.as_mut(), 300, 3);
    let (obs_b, tr_b) = rollout(from_kwargs.as_mut(), 300, 3);
    assert_eq!(tr_a, tr_b);
    assert_eq!(obs_a, obs_b);
    // The 60-step cap binds: every episode ends within 60 steps.
    let mut run_len = 0u32;
    for t in &tr_a {
        run_len += 1;
        if t.done || t.truncated {
            assert!(run_len <= 60, "episode ran {run_len} > 60 steps");
            run_len = 0;
        }
    }
}

#[test]
fn malformed_kwargs_are_rejected_everywhere() {
    // Unknown key, bad value, missing '=', unknown id.
    assert!(make("CartPole-v1?bogus=1").is_err());
    assert!(make("CartPole-v1?max_steps=banana").is_err());
    assert!(make("CartPole-v1?max_steps").is_err());
    assert!(make("NoSuchEnv-v0?max_steps=1").is_err());
    let bogus = Kwargs::new().with("bogus", KwargValue::Int(1));
    assert!(make_with("CartPole-v1", &bogus).is_err());
    let wrong_type = Kwargs::new().with("max_steps", KwargValue::Str("banana".into()));
    assert!(make_with("CartPole-v1", &wrong_type).is_err());
    // The same validation guards executor construction, mixtures included.
    let kind = ExecutorKind::Sequential;
    assert!(build_executor("CartPole-v1?bogus=1", kind, 2, 1, 0).is_err());
    assert!(build_executor("CartPole-v1?bogus=1:2,Acrobot-v1:2", kind, 1, 1, 0).is_err());
}

#[test]
fn parameterized_mixture_lanes_match_hand_built_envs() {
    const SPEC: &str = "CartPole-v1?max_steps=7:2,CartPole-v1:2";
    let tape = discrete_tape(40, 4, 13);
    // Hand-built reference: the kwargs resolve to per-lane TimeLimits.
    let hand_built: Vec<DynEnv> = vec![
        Box::new(TimeLimit::new(CartPole::new(), 7)),
        Box::new(TimeLimit::new(CartPole::new(), 7)),
        Box::new(TimeLimit::new(CartPole::new(), 500)),
        Box::new(TimeLimit::new(CartPole::new(), 500)),
    ];
    let mut reference = VecEnv::from_envs(hand_built, 11);
    let (obs_ref, tr_ref) = batch_trajectory(&mut reference, &tape);
    for kind in [
        ExecutorKind::Sequential,
        ExecutorKind::PoolSync,
        ExecutorKind::PoolAsync,
    ] {
        for threads in test_threads() {
            let mut exec = build_executor(SPEC, kind, 1, threads, 11).unwrap();
            assert_eq!(exec.num_lanes(), 4);
            assert_eq!(exec.lane_specs()[0].env_id, "CartPole-v1?max_steps=7");
            assert_eq!(exec.lane_specs()[2].env_id, "CartPole-v1");
            let (obs, tr) = batch_trajectory(exec.as_mut(), &tape);
            assert_eq!(tr_ref, tr, "{kind:?} diverged at {threads} threads");
            assert_eq!(obs_ref, obs, "{kind:?} diverged at {threads} threads");
        }
    }
}

#[test]
fn registered_script_joins_mixture_pools_end_to_end() {
    // The CLI acceptance path, at the library level: register the
    // checked-in example script, then run it next to a parameterized
    // native env in one pool on every executor kind.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/bounce.mpy");
    let src = std::fs::read_to_string(path).unwrap();
    let id = register_script("BounceSuite", &src).unwrap();
    assert_eq!(id, "Script/BounceSuite");
    let spec = format!("{id}:3,CartPole-v1?max_steps=50:2");
    let mut counts = Vec::new();
    for kind in [
        ExecutorKind::Sequential,
        ExecutorKind::PoolSync,
        ExecutorKind::PoolAsync,
    ] {
        let mut exec = build_executor(&spec, kind, 1, 2, 7).unwrap();
        assert_eq!(exec.num_lanes(), 5, "{kind:?}");
        assert_eq!(exec.obs_dim(), 4, "{kind:?}: padded to CartPole's width");
        assert_eq!(exec.lane_specs()[0].env_id, id);
        assert_eq!(exec.lane_specs()[0].obs_dim, 2);
        let r = run_batched_workload(exec.as_mut(), 60, 3);
        assert_eq!(r.steps, 5 * 60);
        counts.push((r.steps, r.episodes));
    }
    assert_eq!(counts[0], counts[1], "sync pool diverged from sequential");
    assert_eq!(counts[0], counts[2], "async pool diverged from sequential");

    // A --wrap chain applies to every lane, script lanes included.
    let chain = [WrapperSpec::TimeLimit { max_steps: 5 }];
    let kind = ExecutorKind::Sequential;
    let mut wrapped = build_executor_wrapped(&spec, kind, 1, 1, 7, &chain).unwrap();
    let r = run_batched_workload(wrapped.as_mut(), 60, 3);
    assert!(
        r.episodes >= 5 * (60 / 5),
        "5-step cap on 5 lanes x 60 steps must end >= 60 episodes, got {}",
        r.episodes
    );
}

#[test]
fn concurrent_register_script_and_make_are_thread_safe() {
    const SRC: &str = "obs_dim = 1;\nn_actions = 2;\nx = 0;\n\
                       def reset() { global x; x = 0; return [x]; }\n\
                       def step(a) { global x; x = x + 1; return [x, 1.0, 0]; }";
    // Four writers registering unique ids, each interleaving reads of
    // both built-in and freshly registered specs.
    let registered: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|worker| {
                scope.spawn(move || {
                    let mut ids = Vec::new();
                    for i in 0..8 {
                        let name = format!("SpecRace{worker}x{i}");
                        let id = register_script(&name, SRC).unwrap();
                        let mut builtin = make("CartPole-v1").unwrap();
                        assert_eq!(builtin.reset().len(), 4);
                        let mut own = make(&id).unwrap();
                        assert_eq!(own.reset(), vec![0.0]);
                        ids.push(id);
                    }
                    ids
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    });
    assert_eq!(registered.len(), 32);
    let listed: std::collections::HashSet<String> =
        list_envs().into_iter().map(|(id, _)| id).collect();
    for id in &registered {
        assert!(listed.contains(id), "{id} missing from list_envs");
        let mut env = make(id).unwrap();
        assert_eq!(env.reset(), vec![0.0]);
    }

    // Racing duplicate registrations: exactly one winner.
    let errors: usize = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| scope.spawn(|| register_script("SpecRaceDup", SRC).is_err()))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(|&failed| failed)
            .count()
    });
    assert_eq!(errors, 3, "exactly one of four racing registrations wins");
}
