//! Integration: every registered environment survives a long random
//! rollout — the toolkit-wide smoke test the paper's "extensive testing
//! and verification" (§VII) calls for.

use cairl::core::env::{random_rollout, Env};
use cairl::core::rng::Pcg32;
use cairl::core::spaces::{Action, Space};
use cairl::render::Framebuffer;
use cairl::{list_envs, make};

#[test]
fn every_env_survives_1000_random_steps() {
    for (id, _) in list_envs() {
        let mut env = make(&id).unwrap();
        env.seed(1);
        let mut rng = Pcg32::new(2, 2);
        let mut steps = 0u32;
        let mut episodes = 0u32;
        while steps < 1_000 {
            let (ret, len) = random_rollout(env.as_mut(), &mut rng, 1_000 - steps);
            assert!(ret.is_finite(), "{id}: non-finite return");
            steps += len.max(1);
            episodes += 1;
            if episodes > 2_000 {
                break;
            }
        }
        assert!(steps >= 1_000 || episodes > 0, "{id}");
    }
}

#[test]
fn every_env_renders_without_panicking() {
    let mut fb = Framebuffer::standard();
    for (id, _) in list_envs() {
        let mut env = make(&id).unwrap();
        env.seed(0);
        let mut obs = vec![0.0f32; env.obs_dim()];
        env.reset_into(&mut obs);
        let a = env.action_space().sample(&mut Pcg32::new(3, 3));
        env.step_into(&a, &mut obs);
        env.render(&mut fb);
        // Intensities must stay in a sane range on every env that paints.
        assert!(fb.max() <= 1.0 + 1e-6, "{id}: intensity {}", fb.max());
    }
}

#[test]
fn observation_matches_declared_space_dim() {
    for (id, _) in list_envs() {
        let mut env = make(&id).unwrap();
        let obs = env.reset();
        assert_eq!(obs.len(), env.obs_dim(), "{id}");
        assert_eq!(
            env.obs_dim(),
            env.observation_space().flat_dim(),
            "{id}: obs_dim() disagrees with the space"
        );
    }
}

#[test]
fn sampled_actions_are_always_contained() {
    let mut rng = Pcg32::new(5, 5);
    for (id, _) in list_envs() {
        let env = make(&id).unwrap();
        let space = env.action_space();
        for _ in 0..200 {
            let a = space.sample(&mut rng);
            assert!(space.contains(&a), "{id}: {a:?} outside {space:?}");
        }
    }
}

#[test]
fn discrete_envs_accept_every_action() {
    for (id, _) in list_envs() {
        let mut env = make(&id).unwrap();
        env.seed(9);
        if let Space::Discrete { n } = env.action_space() {
            let mut obs = vec![0.0f32; env.obs_dim()];
            env.reset_into(&mut obs);
            for a in 0..n {
                let t = env.step_into(&Action::Discrete(a), &mut obs);
                if t.done || t.truncated {
                    env.reset_into(&mut obs);
                }
            }
        }
    }
}

#[test]
fn seeding_controls_reset_distribution() {
    for (id, _) in list_envs() {
        // Puzzle/flash envs with constant starts are allowed to be equal
        // across seeds only if they are *also* equal for the same seed.
        let mut env = make(&id).unwrap();
        env.seed(100);
        let a = env.reset();
        env.seed(100);
        let b = env.reset();
        assert_eq!(a, b, "{id}: same seed must reproduce reset");
    }
}
