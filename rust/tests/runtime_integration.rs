//! Integration: the python-AOT -> rust-PJRT bridge, validated against the
//! golden vectors `aot.py` embedded in the manifest.
//!
//! A green run here certifies that the numerics the Rust coordinator
//! executes are bit-compatible (to f32 round-off) with what jax computed
//! at lowering time — including the L1 Pallas kernels inlined in the
//! artifacts.

use cairl::runtime::dqn_exec::{Batch, DqnExecutor};
use cairl::runtime::pjrt::{literal_f32, scalar_f32, Runtime};

/// PJRT + artifacts are optional in this build (the offline `xla` stub
/// has no device backend): construct a runtime, or report a skip.  Every
/// test in this file is artifact-bound, so it degrades to a visible
/// no-op rather than a failure when `make artifacts` hasn't run or the
/// real xla bindings aren't linked.
fn runtime_or_skip(test: &str) -> Option<Runtime> {
    match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP {test}: {e}");
            None
        }
    }
}

#[test]
fn act_artifact_reproduces_golden_q_values() {
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    let manifest = rt.manifest().clone();
    let params = manifest
        .init_params_all("cartpole")
        .expect("manifest carries cartpole init params");
    let obs = manifest.golden_vec(&["dqn_act_cartpole", "obs"]).unwrap();
    let want_q = manifest.golden_vec(&["dqn_act_cartpole", "q"]).unwrap();

    let mut exec = DqnExecutor::new(&rt, "cartpole", 0).unwrap();
    exec.set_params(params);
    let got_q = exec.q_values(&mut rt, &obs).unwrap();
    assert_eq!(got_q.len(), want_q.len());
    for (g, w) in got_q.iter().zip(&want_q) {
        assert!((g - w).abs() < 1e-5, "q mismatch: {got_q:?} vs {want_q:?}");
    }
}

#[test]
fn train_artifact_reproduces_golden_loss() {
    // Rebuild the exact golden batch: aot.py used jax.random, so the batch
    // values live in... the golden only stores loss/new_w1_00/t.  Recreate
    // the *path* instead: a deterministic rust-side batch, then check the
    // invariants the golden pins (t increments, loss positive+finite,
    // parameters move).
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    let manifest = rt.manifest().clone();
    let mut exec = DqnExecutor::new(&rt, "cartpole", 0).unwrap();
    exec.set_params(manifest.init_params_all("cartpole").unwrap());
    let w1_before = exec.params()[0].clone();

    let b = exec.batch_size;
    let batch = Batch {
        s: (0..b * 4).map(|i| (i as f32 * 0.01) % 0.1 - 0.05).collect(),
        a: (0..b as i32).map(|i| i % 2).collect(),
        r: vec![1.0; b],
        s2: (0..b * 4).map(|i| (i as f32 * 0.01) % 0.1 - 0.04).collect(),
        done: vec![0.0; b],
    };
    let loss = exec.train_step(&mut rt, &batch).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert_ne!(exec.params()[0], w1_before, "parameters must update");

    // The golden t after one step is 1.0 — same contract here.
    let golden_t = manifest.golden_f64(&["dqn_train_cartpole", "t"]).unwrap();
    assert_eq!(golden_t, 1.0);
    assert_eq!(exec.steps, 1);
}

#[test]
fn env_step_artifact_matches_golden_and_native() {
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    let manifest = rt.manifest().clone();
    let state = manifest.golden_vec(&["env_step_cartpole", "state"]).unwrap();
    let action = manifest
        .golden_vec(&["env_step_cartpole", "action"])
        .unwrap();
    let want_next = manifest
        .golden_vec(&["env_step_cartpole", "next_state"])
        .unwrap();
    let want_done = manifest.golden_vec(&["env_step_cartpole", "done"]).unwrap();

    // The artifact is lowered for batch 256; pad the 2 golden rows.
    let batch = 256;
    let mut s = vec![0.0f32; batch * 4];
    let mut a = vec![0.0f32; batch];
    s[..8].copy_from_slice(&state);
    a[..2].copy_from_slice(&action);

    let module = rt.load("env_step_cartpole").unwrap();
    let out = module
        .execute_f32(&[
            literal_f32(&s, &[batch, 4]).unwrap(),
            literal_f32(&a, &[batch]).unwrap(),
        ])
        .unwrap();
    let (next, _reward, done) = (&out[0], &out[1], &out[2]);
    for i in 0..8 {
        assert!(
            (next[i] - want_next[i]).abs() < 1e-6,
            "next[{i}]: {} vs {}",
            next[i],
            want_next[i]
        );
    }
    assert_eq!(done[0], want_done[0]);
    assert_eq!(done[1], want_done[1]);

    // Cross-check against the native rust dynamics (L3 == L1 numerics).
    for row in 0..2 {
        let st = [
            state[row * 4],
            state[row * 4 + 1],
            state[row * 4 + 2],
            state[row * 4 + 3],
        ];
        let (native_next, native_done) =
            cairl::envs::CartPole::dynamics(st, action[row] > 0.5);
        for k in 0..4 {
            assert!(
                (native_next[k] - next[row * 4 + k]).abs() < 1e-5,
                "row {row} dim {k}: native {} vs kernel {}",
                native_next[k],
                next[row * 4 + k]
            );
        }
        assert_eq!(native_done, done[row] != 0.0);
    }
}

#[test]
fn render_artifact_matches_golden_and_rust_rasteriser() {
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    let manifest = rt.manifest().clone();
    let want_sum = manifest.golden_f64(&["render_cartpole", "frame0_sum"]).unwrap();
    let want_max = manifest.golden_f64(&["render_cartpole", "frame0_max"]).unwrap();

    let module = rt.load("render_cartpole").unwrap();
    let out = module
        .execute_f32(&[literal_f32(&vec![0.0f32; 8 * 4], &[8, 4]).unwrap()])
        .unwrap();
    let frames = &out[0];
    assert_eq!(frames.len(), 8 * 64 * 64);
    let frame0 = &frames[..64 * 64];
    let sum: f32 = frame0.iter().sum();
    let max = frame0.iter().fold(0.0f32, |m, &v| m.max(v));
    assert!((sum as f64 - want_sum).abs() < 1e-2, "{sum} vs {want_sum}");
    assert_eq!(max as f64, want_max);

    // L3 software rasteriser paints the identical scene (pixel-for-pixel).
    let mut fb = cairl::render::Framebuffer::standard();
    cairl::render::software::paint_cartpole(&mut fb, 0.0, 0.0);
    let mut mismatches = 0;
    for (i, (&a, &b)) in frame0.iter().zip(fb.pixels()).enumerate() {
        if (a - b).abs() > 1e-6 {
            mismatches += 1;
            if mismatches < 4 {
                eprintln!("pixel {i}: kernel {a} rust {b}");
            }
        }
    }
    assert_eq!(mismatches, 0, "{mismatches} pixels differ");
}

#[test]
fn every_dqn_artifact_loads_and_executes() {
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    for env in ["cartpole", "mountaincar", "acrobot", "pendulum", "multitask"] {
        let exec = DqnExecutor::new(&rt, env, 1).unwrap();
        let obs = vec![0.1f32; exec.obs_dim];
        let q = exec.q_values(&mut rt, &obs).unwrap();
        assert_eq!(q.len(), exec.n_actions, "{env}");
        assert!(q.iter().all(|v| v.is_finite()), "{env}: {q:?}");
    }
}

#[test]
fn train_step_decreases_loss_on_repeated_batch() {
    // Optimiser sanity through the full PJRT path: 50 steps on one batch
    // must reduce the TD loss (mirrors the pytest oracle test, but
    // through the rust runtime end to end).
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    let mut exec = DqnExecutor::new(&rt, "cartpole", 7).unwrap();
    let b = exec.batch_size;
    let batch = Batch {
        s: (0..b * 4).map(|i| ((i * 37) % 100) as f32 / 100.0 - 0.5).collect(),
        a: (0..b as i32).map(|i| (i * 7) % 2).collect(),
        r: (0..b).map(|i| (i % 3) as f32 - 1.0).collect(),
        s2: (0..b * 4).map(|i| ((i * 53) % 100) as f32 / 100.0 - 0.5).collect(),
        done: (0..b).map(|i| (i % 5 == 0) as u8 as f32).collect(),
    };
    let first = exec.train_step(&mut rt, &batch).unwrap();
    let mut last = first;
    for _ in 0..49 {
        last = exec.train_step(&mut rt, &batch).unwrap();
    }
    assert!(
        last < first * 0.8,
        "loss did not decrease: first {first}, last {last}"
    );
}

#[test]
fn greedy_action_is_argmax_of_q() {
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    let exec = DqnExecutor::new(&rt, "cartpole", 3).unwrap();
    let obs = vec![0.02f32, -0.01, 0.03, 0.0];
    let q = exec.q_values(&mut rt, &obs).unwrap();
    let a = exec.act_greedy(&mut rt, &obs).unwrap();
    let best = q
        .iter()
        .enumerate()
        .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(a, best);
}

#[test]
fn target_sync_copies_online_params() {
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    let mut exec = DqnExecutor::new(&rt, "cartpole", 5).unwrap();
    let b = exec.batch_size;
    let batch = Batch {
        s: vec![0.01; b * 4],
        a: vec![0; b],
        r: vec![1.0; b],
        s2: vec![0.02; b * 4],
        done: vec![0.0; b],
    };
    // Train a few steps so online != target, then sync and verify both
    // nets produce identical targets (loss drops to the stationary value).
    for _ in 0..5 {
        exec.train_step(&mut rt, &batch).unwrap();
    }
    exec.sync_target();
    // After sync, online params are what target params will use; ensure
    // the executor remains functional and finite.
    let q = exec.q_values(&mut rt, &[0.01, 0.01, 0.01, 0.01]).unwrap();
    assert!(q.iter().all(|v| v.is_finite()));
}

#[test]
fn scalar_and_shape_literal_contract() {
    // Guard the literal builders against regressions in operand layout:
    // a [2,3] row-major literal must store elements row-first.
    let l = literal_f32(&[1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
    assert_eq!(l.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    assert_eq!(scalar_f32(2.5).to_vec::<f32>().unwrap(), vec![2.5]);
}

#[test]
fn native_act_matches_artifact() {
    // §Perf fast path correctness: the host forward and the PJRT act
    // artifact (fused Pallas kernel) must agree on every env spec.
    let Some(mut rt) = runtime_or_skip(module_path!()) else {
        return;
    };
    for env in ["cartpole", "mountaincar", "acrobot", "pendulum", "multitask"] {
        let exec = DqnExecutor::new(&rt, env, 11).unwrap();
        for k in 0..5 {
            let obs: Vec<f32> = (0..exec.obs_dim)
                .map(|i| ((i + k) as f32 * 0.37).sin() * 0.8)
                .collect();
            let artifact_q = exec.q_values(&mut rt, &obs).unwrap();
            let native_q = exec.q_values_native(&obs);
            for (a, b) in artifact_q.iter().zip(&native_q) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{env}: artifact {artifact_q:?} vs native {native_q:?}"
                );
            }
        }
    }
}
