//! The ASVM bytecode interpreter.
//!
//! Executes one entry point per call (init or frame), producing a display
//! list, a reward accumulator and a game-over flag.  A gas limit bounds
//! per-frame execution so malformed programs trap instead of hanging the
//! toolkit (the paper's emulator gets the same property from the Flash
//! frame budget).

use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::flash::opcode::{DrawCmd, Op, Program, MEMORY_SLOTS};

/// Maximum instructions per entry-point run.
pub const GAS_LIMIT: u64 = 200_000;

/// One loaded ASVM game instance.
pub struct Vm {
    program: Program,
    /// The virtual flash memory — observable by RL agents.
    pub memory: [f64; MEMORY_SLOTS],
    stack: Vec<f64>,
    rng: Pcg32,
    /// Agent action for the current frame (read by `Input`).
    pub input: f64,
    /// Reward accumulated during the current run.
    pub reward: f64,
    /// Set by `Die`.
    pub game_over: bool,
    /// Display list of the most recent frame.
    pub display: Vec<DrawCmd>,
    /// Total instructions retired (profiling).
    pub instructions: u64,
}

impl Vm {
    pub fn new(program: Program) -> Vm {
        Vm {
            program,
            memory: [0.0; MEMORY_SLOTS],
            stack: Vec::with_capacity(32),
            rng: Pcg32::new(0, 0x14057b7ef767814f),
            input: 0.0,
            reward: 0.0,
            game_over: false,
            display: Vec::new(),
            instructions: 0,
        }
    }

    pub fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x14057b7ef767814f);
    }

    /// Reset episode state and run the init section.
    pub fn reset(&mut self) -> Result<()> {
        self.memory = [0.0; MEMORY_SLOTS];
        self.game_over = false;
        self.reward = 0.0;
        self.input = 0.0;
        self.run(self.program.init_entry)
    }

    /// Run one frame: set the agent action, execute the frame entry.
    /// Returns the frame's accumulated reward.
    pub fn frame(&mut self, action: f64) -> Result<f64> {
        self.input = action;
        self.reward = 0.0;
        self.run(self.program.frame_entry)?;
        Ok(self.reward)
    }

    fn trap(&self, pc: usize, msg: &str) -> CairlError {
        CairlError::Vm(format!("pc={pc}: {msg}"))
    }

    fn run(&mut self, entry: u32) -> Result<()> {
        let code = std::mem::take(&mut self.program.code);
        let result = self.run_inner(&code, entry);
        self.program.code = code;
        result
    }

    fn run_inner(&mut self, code: &[Op], entry: u32) -> Result<()> {
        let mut pc = entry as usize;
        let mut gas = 0u64;
        self.display.clear();
        self.stack.clear();

        macro_rules! pop {
            () => {
                match self.stack.pop() {
                    Some(v) => v,
                    None => return Err(self.trap(pc, "stack underflow")),
                }
            };
        }
        macro_rules! bin {
            ($f:expr) => {{
                let b = pop!();
                let a = pop!();
                self.stack.push($f(a, b));
            }};
        }

        loop {
            gas += 1;
            if gas > GAS_LIMIT {
                return Err(self.trap(pc, "gas limit exceeded"));
            }
            let op = *code
                .get(pc)
                .ok_or_else(|| self.trap(pc, "pc out of bounds"))?;
            pc += 1;
            match op {
                Op::Push(v) => self.stack.push(v),
                Op::Load(slot) => self.stack.push(self.memory[slot as usize]),
                Op::Store(slot) => {
                    let v = pop!();
                    self.memory[slot as usize] = v;
                }
                Op::Dup => {
                    let v = *self
                        .stack
                        .last()
                        .ok_or_else(|| self.trap(pc, "dup on empty stack"))?;
                    self.stack.push(v);
                }
                Op::Pop => {
                    pop!();
                }
                Op::Add => bin!(|a, b| a + b),
                Op::Sub => bin!(|a, b| a - b),
                Op::Mul => bin!(|a, b| a * b),
                Op::Div => bin!(|a, b| a / b),
                Op::Mod => bin!(|a: f64, b: f64| a.rem_euclid(b)),
                Op::Min => bin!(|a: f64, b: f64| a.min(b)),
                Op::Max => bin!(|a: f64, b: f64| a.max(b)),
                Op::Neg => {
                    let v = pop!();
                    self.stack.push(-v);
                }
                Op::Abs => {
                    let v = pop!();
                    self.stack.push(v.abs());
                }
                Op::Floor => {
                    let v = pop!();
                    self.stack.push(v.floor());
                }
                Op::Sign => {
                    let v = pop!();
                    self.stack.push(if v > 0.0 {
                        1.0
                    } else if v < 0.0 {
                        -1.0
                    } else {
                        0.0
                    });
                }
                Op::Eq => bin!(|a, b| (a == b) as u8 as f64),
                Op::Ne => bin!(|a, b| (a != b) as u8 as f64),
                Op::Lt => bin!(|a, b| (a < b) as u8 as f64),
                Op::Le => bin!(|a, b| (a <= b) as u8 as f64),
                Op::Gt => bin!(|a, b| (a > b) as u8 as f64),
                Op::Ge => bin!(|a, b| (a >= b) as u8 as f64),
                Op::Not => {
                    let v = pop!();
                    self.stack.push((v == 0.0) as u8 as f64);
                }
                Op::Jmp(t) => pc = t as usize,
                Op::Jz(t) => {
                    if pop!() == 0.0 {
                        pc = t as usize;
                    }
                }
                Op::Jnz(t) => {
                    if pop!() != 0.0 {
                        pc = t as usize;
                    }
                }
                Op::Halt => break,
                Op::Rand => self.stack.push(self.rng.next_f64()),
                Op::Input => self.stack.push(self.input),
                Op::Clear => {
                    let i = pop!();
                    self.display.push(DrawCmd::Clear(i as f32));
                }
                Op::Rect => {
                    let i = pop!();
                    let h = pop!();
                    let w = pop!();
                    let y = pop!();
                    let x = pop!();
                    self.display.push(DrawCmd::Rect {
                        x: x as f32,
                        y: y as f32,
                        w: w as f32,
                        h: h as f32,
                        i: i as f32,
                    });
                }
                Op::Disc => {
                    let i = pop!();
                    let r = pop!();
                    let y = pop!();
                    let x = pop!();
                    self.display.push(DrawCmd::Disc {
                        x: x as f32,
                        y: y as f32,
                        r: r as f32,
                        i: i as f32,
                    });
                }
                Op::Reward => {
                    let v = pop!();
                    self.reward += v;
                }
                Op::Die => self.game_over = true,
            }
        }
        self.instructions += gas;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flash::assembler::assemble;

    fn vm(src: &str) -> Vm {
        Vm::new(assemble(src).unwrap())
    }

    #[test]
    fn arithmetic_and_store() {
        let mut m = vm("halt\nframe:\npush 6\npush 7\nmul\nstore 0\nhalt\n");
        m.reset().unwrap();
        m.frame(0.0).unwrap();
        assert_eq!(m.memory[0], 42.0);
    }

    #[test]
    fn init_runs_on_reset_only() {
        let mut m = vm("push 5\nstore 1\nhalt\nframe:\nload 1\npush 1\nadd\nstore 1\nhalt\n");
        m.reset().unwrap();
        assert_eq!(m.memory[1], 5.0);
        m.frame(0.0).unwrap();
        m.frame(0.0).unwrap();
        assert_eq!(m.memory[1], 7.0);
        m.reset().unwrap();
        assert_eq!(m.memory[1], 5.0);
    }

    #[test]
    fn input_is_visible() {
        let mut m = vm("halt\nframe:\ninput\nstore 2\nhalt\n");
        m.reset().unwrap();
        m.frame(3.0).unwrap();
        assert_eq!(m.memory[2], 3.0);
    }

    #[test]
    fn branches_and_loops() {
        // Sum 0..10 with a loop.
        let src = "
halt
frame:
    push 0
    store 0      ; i = 0
    push 0
    store 1      ; s = 0
loop:
    load 0
    push 10
    ge
    jnz done
    load 1
    load 0
    add
    store 1
    load 0
    push 1
    add
    store 0
    jmp loop
done:
    halt
";
        let mut m = vm(src);
        m.reset().unwrap();
        m.frame(0.0).unwrap();
        assert_eq!(m.memory[1], 45.0);
    }

    #[test]
    fn reward_and_die() {
        let mut m = vm("halt\nframe:\npush 2.5\nreward\npush -1\nreward\ndie\nhalt\n");
        m.reset().unwrap();
        let r = m.frame(0.0).unwrap();
        assert_eq!(r, 1.5);
        assert!(m.game_over);
        m.reset().unwrap();
        assert!(!m.game_over);
    }

    #[test]
    fn display_list_is_rebuilt_each_frame() {
        let src = "halt\nframe:\npush 0\nclear\npush 1\npush 2\npush 3\npush 4\n\
                   push 0.5\nrect\nhalt\n";
        let mut m = vm(src);
        m.reset().unwrap();
        m.frame(0.0).unwrap();
        assert_eq!(m.display.len(), 2);
        m.frame(0.0).unwrap();
        assert_eq!(m.display.len(), 2);
        match m.display[1] {
            DrawCmd::Rect { x, y, w, h, i } => {
                assert_eq!((x, y, w, h, i), (1.0, 2.0, 3.0, 4.0, 0.5));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rand_is_seeded() {
        let mut a = vm("halt\nframe:\nrand\nstore 0\nhalt\n");
        let mut b = vm("halt\nframe:\nrand\nstore 0\nhalt\n");
        a.seed(9);
        b.seed(9);
        a.reset().unwrap();
        b.reset().unwrap();
        for _ in 0..10 {
            a.frame(0.0).unwrap();
            b.frame(0.0).unwrap();
            assert_eq!(a.memory[0], b.memory[0]);
        }
    }

    #[test]
    fn stack_underflow_traps() {
        let mut m = vm("halt\nframe:\nadd\nhalt\n");
        m.reset().unwrap();
        assert!(m.frame(0.0).is_err());
    }

    #[test]
    fn infinite_loop_hits_gas_limit() {
        let mut m = vm("halt\nframe:\nspin:\njmp spin\n");
        m.reset().unwrap();
        let err = m.frame(0.0).unwrap_err().to_string();
        assert!(err.contains("gas"), "{err}");
    }

    #[test]
    fn comparison_ops() {
        let mut m = vm("halt\nframe:\npush 3\npush 3\neq\nstore 0\npush 2\npush 3\nlt\n\
                        store 1\npush 2\npush 3\nge\nstore 2\nhalt\n");
        m.reset().unwrap();
        m.frame(0.0).unwrap();
        assert_eq!(m.memory[0], 1.0);
        assert_eq!(m.memory[1], 1.0);
        assert_eq!(m.memory[2], 0.0);
    }

    #[test]
    fn sign_and_abs() {
        let mut m = vm("halt\nframe:\npush -7\nsign\nstore 0\npush -7\nabs\nstore 1\n\
                        push 0\nsign\nstore 2\nhalt\n");
        m.reset().unwrap();
        m.frame(0.0).unwrap();
        assert_eq!(m.memory[0], -1.0);
        assert_eq!(m.memory[1], 7.0);
        assert_eq!(m.memory[2], 0.0);
    }
}
