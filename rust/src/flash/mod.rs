//! The Flash runner — CaiRL's headline feature (§IV-C), as an embedded
//! bytecode VM.
//!
//! The paper embeds LightSpark/Gnash to run ActionScript games inside the
//! toolkit.  Shipping a real Flash emulator is out of scope for this
//! image, so this module implements **ASVM**, an ActionScript-class stack
//! bytecode VM that preserves every property the paper's experiments
//! exercise (DESIGN.md §Substitutions):
//!
//! * games are *foreign bytecode* executed by an embedded interpreter
//!   behind the standard [`Env`](crate::core::env::Env) trait — the
//!   runner-bridge architecture of §III-A;
//! * observations are either the **virtual flash memory** (the VM's
//!   register file, §IV-C "the game observations are either raw pixels or
//!   the virtual Flash memory") or raw pixels from the display list;
//! * the game loop lives *inside the render loop* (§V-B: "Flash games
//!   have the game loop inside the rendering loop"), so a frame clock
//!   ([`runner::FrameClock`]) governs execution speed and unlocking it
//!   reproduces the paper's 4.6x speed-up experiment;
//! * rewards are positive per surviving frame and negative on
//!   termination — the Multitask reward scheme of §IV-C.
//!
//! Games ship as assembly text ([`assembler`]) compiled to [`opcode`]
//! programs: [`games`] contains Multitask (the Fig.-3 environment), Pong
//! and Dodge.

pub mod assembler;
pub mod games;
pub mod opcode;
pub mod runner;
pub mod vm;

pub use runner::{FlashEnv, FrameClock};
pub use vm::Vm;
