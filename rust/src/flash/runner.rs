//! The flash runner: [`FlashEnv`] adapts a [`Vm`] game to the [`Env`]
//! trait, and [`FrameClock`] reproduces the browser's locked frame pacing
//! (the game loop lives inside the render loop, paper §V-B).

use std::time::{Duration, Instant};

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::flash::opcode::DrawCmd;
use crate::flash::vm::Vm;
use crate::render::{raster, Framebuffer};

/// Frame pacing: browsers lock Flash to the SWF frame rate; CaiRL's
/// runner can unlock it (the paper's 4.6x experiment, §V-B).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FrameClock {
    /// Enforce a fixed frames-per-second budget (busy-wait like the
    /// player's timer loop).
    Locked { fps: f64 },
    /// Run as fast as the VM executes.
    Unlocked,
}

impl FrameClock {
    fn frame_budget(&self) -> Option<Duration> {
        match self {
            FrameClock::Locked { fps } => Some(Duration::from_secs_f64(1.0 / fps)),
            FrameClock::Unlocked => None,
        }
    }
}

/// An ASVM game behind the standard [`Env`] trait.
pub struct FlashEnv {
    id: String,
    vm: Vm,
    obs_dim: usize,
    n_actions: usize,
    clock: FrameClock,
    next_deadline: Option<Instant>,
    frames: u64,
    started: Option<Instant>,
    /// Per-slot observation scale (virtual memory is raw game units —
    /// pixel coordinates, counters — which would blow up a unit-scale
    /// MLP; games ship sensible normalisers).
    obs_scale: Vec<f32>,
}

impl FlashEnv {
    /// Wrap a VM.  `obs_dim` selects how many virtual-memory slots the
    /// agent observes; `n_actions` the discrete action count.
    pub fn new(id: &str, vm: Vm, obs_dim: usize, n_actions: usize) -> FlashEnv {
        FlashEnv {
            id: id.to_string(),
            vm,
            obs_dim,
            n_actions,
            clock: FrameClock::Unlocked,
            next_deadline: None,
            frames: 0,
            started: None,
            obs_scale: vec![1.0; obs_dim],
        }
    }

    /// Set per-slot observation normalisers (builder style).  Slots
    /// beyond the vector keep scale 1.
    pub fn with_obs_scale(mut self, scale: &[f32]) -> FlashEnv {
        for (dst, &s) in self.obs_scale.iter_mut().zip(scale) {
            *dst = s;
        }
        self
    }

    /// Switch frame pacing (builder style).
    pub fn with_clock(mut self, clock: FrameClock) -> FlashEnv {
        self.clock = clock;
        self
    }

    /// Change pacing in place.
    pub fn set_clock(&mut self, clock: FrameClock) {
        self.clock = clock;
        self.next_deadline = None;
    }

    /// Frames executed since construction.
    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Measured frames per second since the first frame.
    pub fn measured_fps(&self) -> Option<f64> {
        let started = self.started?;
        let secs = started.elapsed().as_secs_f64();
        (secs > 0.0).then(|| self.frames as f64 / secs)
    }

    /// Direct VM access (tests, memory inspection).
    pub fn vm(&self) -> &Vm {
        &self.vm
    }

    fn pace(&mut self) {
        if let Some(budget) = self.clock.frame_budget() {
            let now = Instant::now();
            let deadline = self.next_deadline.unwrap_or(now);
            // Busy-wait to the frame deadline, like the player's timer.
            while Instant::now() < deadline {
                std::hint::spin_loop();
            }
            self.next_deadline = Some(deadline.max(now) + budget);
        }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        for ((o, m), s) in obs
            .iter_mut()
            .zip(self.vm.memory.iter())
            .zip(self.obs_scale.iter())
        {
            *o = *m as f32 * s;
        }
    }
}

impl Env for FlashEnv {
    fn id(&self) -> String {
        self.id.clone()
    }

    fn observation_space(&self) -> Space {
        // Virtual flash memory is unbounded in general.
        Space::box1(vec![f32::MIN; self.obs_dim], vec![f32::MAX; self.obs_dim])
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: self.n_actions }
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn seed(&mut self, seed: u64) {
        self.vm.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.vm
            .reset()
            .unwrap_or_else(|e| panic!("{}: init trap: {e}", self.id));
        self.next_deadline = None;
        self.write_obs(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        self.pace();
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let reward = self
            .vm
            .frame(action.index() as f64)
            .unwrap_or_else(|e| panic!("{}: frame trap: {e}", self.id));
        self.frames += 1;
        self.write_obs(obs);
        Transition {
            reward: reward as f32,
            done: self.vm.game_over,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        for cmd in &self.vm.display {
            match *cmd {
                DrawCmd::Clear(i) => fb.clear(i),
                DrawCmd::Rect { x, y, w, h, i } => raster::fill_rect(
                    fb,
                    x as i32,
                    y as i32,
                    (x + w) as i32,
                    (y + h) as i32,
                    i,
                ),
                DrawCmd::Disc { x, y, r, i } => raster::fill_disc(fb, x, y, r, i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flash::assembler::assemble;

    fn tiny_game() -> Vm {
        // Survives 10 frames then dies; draws one rect per frame.
        Vm::new(
            assemble(
                "
push 0
store 0
halt
frame:
    push 0
    clear
    load 0
    push 1
    add
    store 0
    push 10
    push 10
    push 5
    push 5
    push 1
    rect
    push 1
    reward
    load 0
    push 10
    ge
    jz alive
    push -5
    reward
    die
alive:
    halt
",
            )
            .unwrap(),
        )
    }

    #[test]
    fn env_runs_episode_to_termination() {
        let mut env = FlashEnv::new("Flash/Tiny-v0", tiny_game(), 4, 2);
        env.seed(0);
        let mut obs = vec![0.0; 4];
        env.reset_into(&mut obs);
        assert_eq!(obs[0], 0.0);
        let mut total = 0.0;
        let mut steps = 0;
        loop {
            let t = env.step_into(&Action::Discrete(0), &mut obs);
            total += t.reward;
            steps += 1;
            if t.done {
                break;
            }
        }
        assert_eq!(steps, 10);
        assert_eq!(total, 10.0 - 5.0); // +1 x10, -5 at death
        assert_eq!(obs[0], 10.0); // frame counter visible in memory
    }

    #[test]
    fn reset_restarts_the_game() {
        let mut env = FlashEnv::new("Flash/Tiny-v0", tiny_game(), 1, 2);
        let mut obs = vec![0.0; 1];
        env.reset_into(&mut obs);
        for _ in 0..10 {
            env.step_into(&Action::Discrete(0), &mut obs);
        }
        env.reset_into(&mut obs);
        assert_eq!(obs[0], 0.0);
        let t = env.step_into(&Action::Discrete(0), &mut obs);
        assert!(!t.done);
    }

    #[test]
    fn render_replays_display_list() {
        let mut env = FlashEnv::new("Flash/Tiny-v0", tiny_game(), 1, 2);
        let mut obs = vec![0.0; 1];
        env.reset_into(&mut obs);
        env.step_into(&Action::Discrete(0), &mut obs);
        let mut fb = Framebuffer::standard();
        env.render(&mut fb);
        assert_eq!(fb.sum(), 25.0); // 5x5 rect at intensity 1
    }

    #[test]
    fn locked_clock_caps_fps() {
        let mut env = FlashEnv::new("Flash/Tiny-v0", tiny_game(), 1, 2)
            .with_clock(FrameClock::Locked { fps: 200.0 });
        let mut obs = vec![0.0; 1];
        env.reset_into(&mut obs);
        let t0 = Instant::now();
        for _ in 0..10 {
            env.step_into(&Action::Discrete(0), &mut obs);
        }
        // 10 frames at 200 fps >= ~45 ms (first frame unpaced).
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }

    #[test]
    fn unlocked_is_much_faster_than_locked() {
        let run = |clock: FrameClock| {
            let mut env =
                FlashEnv::new("Flash/Tiny-v0", tiny_game(), 1, 2).with_clock(clock);
            let mut obs = vec![0.0; 1];
            let t0 = Instant::now();
            for _ in 0..5 {
                env.reset_into(&mut obs);
                for _ in 0..10 {
                    if env.step_into(&Action::Discrete(0), &mut obs).done {
                        break;
                    }
                }
            }
            t0.elapsed()
        };
        let locked = run(FrameClock::Locked { fps: 100.0 });
        let unlocked = run(FrameClock::Unlocked);
        assert!(
            locked.as_secs_f64() > unlocked.as_secs_f64() * 4.0,
            "locked={locked:?} unlocked={unlocked:?}"
        );
    }
}
