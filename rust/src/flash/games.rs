//! The toolkit's ASVM game library: Multitask (the Fig.-3 environment),
//! Pong and Dodge.
//!
//! Each game is authored in ASVM assembly (the paper's games are
//! ActionScript bytecode — foreign code executed by the embedded runner,
//! not Rust).  Conventions:
//!
//! * memory slots 0..8 hold the gameplay state the agent observes,
//! * every frame ends by rebuilding the display list (game loop inside
//!   the render loop, §V-B),
//! * rewards follow the paper's Multitask scheme: positive while the game
//!   runs, a negative burst when the engine terminates (§IV-C).

use crate::flash::assembler::assemble;
use crate::flash::runner::FlashEnv;
use crate::flash::vm::Vm;

/// Multitask — two concurrent mini-games (paper §IV-C).
///
/// Task A (pong-like): keep the bouncing ball on the paddle.  Task B
/// (balance): a randomly drifting bar must stay within +-6; action 3
/// re-centres it.  Failing either task ends the game.
///
/// Actions: 0 noop, 1 paddle left, 2 paddle right, 3 stabilise bar.
/// Memory: 0 ball_x, 1 ball_y, 2 ball_vx, 3 ball_vy, 4 paddle_x,
/// 5 bar, 6 bar_v, 7 frames.
pub const MULTITASK_ASM: &str = "
; ---- init ----
    push 32
    store 0      ; ball_x
    push 20
    store 1      ; ball_y
    push 1.3
    store 2      ; ball_vx
    push 1.1
    store 3      ; ball_vy
    push 32
    store 4      ; paddle_x
    push 0
    store 5      ; bar
    push 0
    store 6      ; bar_v
    push 0
    store 7      ; frames
    halt
frame:
; ---- input: paddle / stabiliser ----
    input
    push 1
    eq
    jz not_left
    load 4
    push 2
    sub
    push 9
    max
    store 4
not_left:
    input
    push 2
    eq
    jz not_right
    load 4
    push 2
    add
    push 55
    min
    store 4
not_right:
    input
    push 3
    eq
    jz not_stab
    load 5
    push 0.7
    mul
    store 5
    load 6
    push 0.5
    mul
    store 6
not_stab:
; ---- task B: bar random walk ----
    load 6
    rand
    push 0.5
    sub
    push 0.4
    mul
    add
    store 6
    load 5
    load 6
    add
    store 5
    load 5
    abs
    push 6
    gt
    jz bar_ok
    push -10
    reward
    die
    jmp draw
bar_ok:
; ---- task A: ball physics ----
    load 0
    load 2
    add
    store 0
    load 0
    push 2
    lt
    jz no_lwall
    push 2
    store 0
    load 2
    abs
    store 2
no_lwall:
    load 0
    push 62
    gt
    jz no_rwall
    push 62
    store 0
    load 2
    abs
    neg
    store 2
no_rwall:
    load 1
    load 3
    add
    store 1
    load 1
    push 2
    lt
    jz no_top
    push 2
    store 1
    load 3
    abs
    store 3
no_top:
    load 1
    push 56
    ge
    jz no_bottom
    load 0
    load 4
    sub
    abs
    push 9
    le
    jz miss
    push 56
    store 1
    load 3
    abs
    neg
    store 3
    push 0.5
    reward
    jmp no_bottom
miss:
    push -10
    reward
    die
    jmp draw
no_bottom:
; ---- survive: reward + frame count ----
    load 7
    push 1
    add
    store 7
    push 1
    reward
draw:
; ---- display list ----
    push 0
    clear
    load 5
    push 2
    mul
    push 30
    add
    push 2
    push 4
    push 3
    push 0.5
    rect
    load 4
    push 9
    sub
    push 58
    push 18
    push 3
    push 0.8
    rect
    load 0
    load 1
    push 2
    push 1
    disc
    halt
";

/// Pong — single-player wall pong.  Actions: 0 noop, 1 left, 2 right.
/// Reward +0.1 per frame, +1 per paddle return, -5 and game over on a
/// miss.  Memory: 0 ball_x, 1 ball_y, 2 vx, 3 vy, 4 paddle_x, 5 hits.
pub const PONG_ASM: &str = "
    push 20
    store 0
    push 10
    store 1
    push 1.6
    store 2
    push 1.2
    store 3
    push 32
    store 4
    push 0
    store 5
    halt
frame:
    input
    push 1
    eq
    jz p_not_left
    load 4
    push 3
    sub
    push 8
    max
    store 4
p_not_left:
    input
    push 2
    eq
    jz p_not_right
    load 4
    push 3
    add
    push 56
    min
    store 4
p_not_right:
    load 0
    load 2
    add
    store 0
    load 0
    push 2
    lt
    jz p_no_lwall
    push 2
    store 0
    load 2
    abs
    store 2
p_no_lwall:
    load 0
    push 62
    gt
    jz p_no_rwall
    push 62
    store 0
    load 2
    abs
    neg
    store 2
p_no_rwall:
    load 1
    load 3
    add
    store 1
    load 1
    push 2
    lt
    jz p_no_top
    push 2
    store 1
    load 3
    abs
    store 3
p_no_top:
    load 1
    push 57
    ge
    jz p_no_bottom
    load 0
    load 4
    sub
    abs
    push 8
    le
    jz p_miss
    push 57
    store 1
    load 3
    abs
    neg
    store 3
    push 1
    reward
    load 5
    push 1
    add
    store 5
    jmp p_no_bottom
p_miss:
    push -5
    reward
    die
    jmp p_draw
p_no_bottom:
    push 0.1
    reward
p_draw:
    push 0
    clear
    load 4
    push 8
    sub
    push 59
    push 16
    push 3
    push 0.8
    rect
    load 0
    load 1
    push 2
    push 1
    disc
    halt
";

/// Dodge — avoid three falling blocks.  Actions: 0 noop, 1 left,
/// 2 right.  Reward +1 per surviving frame, -10 and game over on a hit.
/// Memory: 0 player_x, 1/2 block0 x/y, 3/4 block1 x/y, 5/6 block2 x/y,
/// 7 frames.
pub const DODGE_ASM: &str = "
    push 32
    store 0
    rand
    push 56
    mul
    push 4
    add
    store 1
    push 0
    store 2
    rand
    push 56
    mul
    push 4
    add
    store 3
    push -20
    store 4
    rand
    push 56
    mul
    push 4
    add
    store 5
    push -40
    store 6
    push 0
    store 7
    halt
frame:
    input
    push 1
    eq
    jz d_not_left
    load 0
    push 2.5
    sub
    push 5
    max
    store 0
d_not_left:
    input
    push 2
    eq
    jz d_not_right
    load 0
    push 2.5
    add
    push 59
    min
    store 0
d_not_right:
; block 0 falls
    load 2
    push 1.4
    add
    store 2
    load 2
    push 62
    le
    jnz d_b0_alive
    rand
    push 56
    mul
    push 4
    add
    store 1
    push 0
    store 2
d_b0_alive:
; block 1 falls
    load 4
    push 1.4
    add
    store 4
    load 4
    push 62
    le
    jnz d_b1_alive
    rand
    push 56
    mul
    push 4
    add
    store 3
    push 0
    store 4
d_b1_alive:
; block 2 falls
    load 6
    push 1.4
    add
    store 6
    load 6
    push 62
    le
    jnz d_b2_alive
    rand
    push 56
    mul
    push 4
    add
    store 5
    push 0
    store 6
d_b2_alive:
; collisions: block in player band (y >= 54) and |x - player| < 6
    load 2
    push 54
    ge
    jz d_c0_ok
    load 1
    load 0
    sub
    abs
    push 6
    lt
    jz d_c0_ok
    push -10
    reward
    die
    jmp d_draw
d_c0_ok:
    load 4
    push 54
    ge
    jz d_c1_ok
    load 3
    load 0
    sub
    abs
    push 6
    lt
    jz d_c1_ok
    push -10
    reward
    die
    jmp d_draw
d_c1_ok:
    load 6
    push 54
    ge
    jz d_c2_ok
    load 5
    load 0
    sub
    abs
    push 6
    lt
    jz d_c2_ok
    push -10
    reward
    die
    jmp d_draw
d_c2_ok:
    load 7
    push 1
    add
    store 7
    push 1
    reward
d_draw:
    push 0
    clear
    load 0
    push 5
    sub
    push 58
    push 10
    push 4
    push 0.8
    rect
    load 1
    push 3
    sub
    load 2
    push 6
    push 6
    push 1
    rect
    load 3
    push 3
    sub
    load 4
    push 6
    push 6
    push 1
    rect
    load 5
    push 3
    sub
    load 6
    push 6
    push 6
    push 1
    rect
    halt
";

/// Build the Multitask environment (paper Fig. 3).  Observation: 32
/// virtual-memory slots; 4 actions.
pub fn multitask() -> FlashEnv {
    FlashEnv::new(
        "Flash/Multitask-v0",
        Vm::new(assemble(MULTITASK_ASM).expect("multitask assembles")),
        32,
        4,
    )
    // Normalise the virtual memory for MLP consumption: pixel coords /64,
    // velocities /2, bar /6, frame counter /1000.
    .with_obs_scale(&[
        1.0 / 64.0, // ball_x
        1.0 / 64.0, // ball_y
        0.5,        // ball_vx
        0.5,        // ball_vy
        1.0 / 64.0, // paddle_x
        1.0 / 6.0,  // bar
        1.0,        // bar_v
        1e-3,       // frames
    ])
}

/// Build the Pong environment.
pub fn pong() -> FlashEnv {
    FlashEnv::new(
        "Flash/Pong-v0",
        Vm::new(assemble(PONG_ASM).expect("pong assembles")),
        8,
        3,
    )
    .with_obs_scale(&[1.0 / 64.0, 1.0 / 64.0, 0.5, 0.5, 1.0 / 64.0, 0.05])
}

/// Build the Dodge environment.
pub fn dodge() -> FlashEnv {
    FlashEnv::new(
        "Flash/Dodge-v0",
        Vm::new(assemble(DODGE_ASM).expect("dodge assembles")),
        8,
        3,
    )
    .with_obs_scale(&[
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1e-3,
    ])
}

/// X1337 Space Shooter — the paper's §III novel-game namesake.
/// Actions: 0 noop, 1 left, 2 right, 3 fire.  Reward +0.05 per frame,
/// +2 per enemy destroyed, -10 and game over when an enemy lands.
/// Memory: 0 ship_x, 1 bullet_x, 2 bullet_y (0 = inactive),
/// 3/4 enemy0 x/y, 5/6 enemy1 x/y, 7 score.
pub const SHOOTER_ASM: &str = "
    push 32
    store 0
    push 0
    store 1
    push 0
    store 2
    rand
    push 52
    mul
    push 6
    add
    store 3
    push 0
    store 4
    rand
    push 52
    mul
    push 6
    add
    store 5
    push -25
    store 6
    push 0
    store 7
    halt
frame:
    input
    push 1
    eq
    jz s_not_left
    load 0
    push 2.5
    sub
    push 6
    max
    store 0
s_not_left:
    input
    push 2
    eq
    jz s_not_right
    load 0
    push 2.5
    add
    push 58
    min
    store 0
s_not_right:
; fire: only when the bullet is inactive
    input
    push 3
    eq
    jz s_not_fire
    load 2
    push 0
    gt
    jnz s_not_fire
    load 0
    store 1
    push 56
    store 2
s_not_fire:
; bullet flight
    load 2
    push 0
    gt
    jz s_no_bullet
    load 2
    push 3
    sub
    push 0
    max
    store 2
s_no_bullet:
; enemy 0 descends
    load 4
    push 0.6
    add
    store 4
; enemy 1 descends
    load 6
    push 0.6
    add
    store 6
; bullet vs enemy 0
    load 2
    push 0
    gt
    jz s_b0_done
    load 1
    load 3
    sub
    abs
    push 4
    lt
    jz s_b0_done
    load 2
    load 4
    sub
    abs
    push 4
    lt
    jz s_b0_done
    push 2
    reward
    load 7
    push 1
    add
    store 7
    rand
    push 52
    mul
    push 6
    add
    store 3
    push 0
    store 4
    push 0
    store 2
s_b0_done:
; bullet vs enemy 1
    load 2
    push 0
    gt
    jz s_b1_done
    load 1
    load 5
    sub
    abs
    push 4
    lt
    jz s_b1_done
    load 2
    load 6
    sub
    abs
    push 4
    lt
    jz s_b1_done
    push 2
    reward
    load 7
    push 1
    add
    store 7
    rand
    push 52
    mul
    push 6
    add
    store 5
    push 0
    store 6
    push 0
    store 2
s_b1_done:
; landings end the game
    load 4
    push 58
    ge
    jz s_e0_ok
    push -10
    reward
    die
    jmp s_draw
s_e0_ok:
    load 6
    push 58
    ge
    jz s_e1_ok
    push -10
    reward
    die
    jmp s_draw
s_e1_ok:
    push 0.05
    reward
s_draw:
    push 0
    clear
    load 0
    push 4
    sub
    push 58
    push 8
    push 4
    push 0.8
    rect
    load 1
    load 2
    push 1
    push 1
    disc
    load 3
    push 3
    sub
    load 4
    push 6
    push 4
    push 1
    rect
    load 5
    push 3
    sub
    load 6
    push 6
    push 4
    push 1
    rect
    halt
";

/// Build the X1337 Space Shooter environment.
pub fn shooter() -> FlashEnv {
    FlashEnv::new(
        "Flash/X1337Shooter-v0",
        Vm::new(assemble(SHOOTER_ASM).expect("shooter assembles")),
        8,
        4,
    )
    .with_obs_scale(&[
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        1.0 / 64.0,
        0.05,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::env::Env;
    use crate::core::rng::Pcg32;
    use crate::core::spaces::Action;
    use crate::render::Framebuffer;

    #[test]
    fn all_games_assemble_and_run_random_frames() {
        for mut env in [multitask(), pong(), dodge()] {
            env.seed(1);
            let mut rng = Pcg32::new(2, 2);
            let mut obs = vec![0.0; env.obs_dim()];
            env.reset_into(&mut obs);
            for _ in 0..300 {
                let a = env.action_space().sample(&mut rng);
                let t = env.step_into(&a, &mut obs);
                assert!(t.reward.is_finite());
                if t.done {
                    env.reset_into(&mut obs);
                }
            }
        }
    }

    #[test]
    fn multitask_heuristic_outlives_noop() {
        // Track the ball with the paddle; stabilise when the bar drifts.
        let run = |policy: &dyn Fn(&[f32]) -> usize, seed: u64| -> u32 {
            let mut env = multitask();
            env.seed(seed);
            let mut obs = vec![0.0; 32];
            env.reset_into(&mut obs);
            let mut steps = 0;
            while steps < 3000 {
                let a = policy(&obs);
                let t = env.step_into(&Action::Discrete(a), &mut obs);
                steps += 1;
                if t.done {
                    break;
                }
            }
            steps
        };
        // Observations are normalised (coords /64, bar /6).
        let heuristic = |obs: &[f32]| -> usize {
            let (ball_x, paddle_x, bar) = (obs[0], obs[4], obs[5]);
            if bar.abs() > 0.5 {
                3
            } else if ball_x < paddle_x - 2.0 / 64.0 {
                1
            } else if ball_x > paddle_x + 2.0 / 64.0 {
                2
            } else {
                0
            }
        };
        let noop = |_: &[f32]| 0usize;
        let mut h_total = 0;
        let mut n_total = 0;
        for seed in 0..5 {
            h_total += run(&heuristic, seed);
            n_total += run(&noop, seed);
        }
        assert!(
            h_total > n_total * 3,
            "heuristic {h_total} vs noop {n_total}"
        );
        // The heuristic should essentially master the game.
        assert!(h_total >= 5 * 2000, "heuristic survived only {h_total}");
    }

    #[test]
    fn multitask_bar_failure_terminates() {
        let mut env = multitask();
        env.seed(3);
        let mut obs = vec![0.0; 32];
        env.reset_into(&mut obs);
        // Never stabilise: only track the ball; the bar must eventually
        // kill the game (random walk exits +-6).
        let mut died = false;
        for _ in 0..20_000 {
            let a = if obs[0] < obs[4] - 2.0 / 64.0 {
                1
            } else if obs[0] > obs[4] + 2.0 / 64.0 {
                2
            } else {
                0
            };
            let t = env.step_into(&Action::Discrete(a), &mut obs);
            if t.done {
                died = true;
                assert!(t.reward < 0.0, "death carries the negative burst");
                break;
            }
        }
        assert!(died, "bar task should eventually fail without action 3");
    }

    #[test]
    fn pong_returns_score_in_memory() {
        let mut env = pong();
        env.seed(0);
        let mut obs = vec![0.0; 8];
        env.reset_into(&mut obs);
        // Perfect tracking: paddle follows ball x (normalised coords).
        for _ in 0..600 {
            let a = if obs[0] < obs[4] - 2.0 / 64.0 {
                1
            } else if obs[0] > obs[4] + 2.0 / 64.0 {
                2
            } else {
                0
            };
            let t = env.step_into(&Action::Discrete(a), &mut obs);
            assert!(!t.done, "perfect tracking should never miss");
        }
        // hits counter (slot 5) is scaled by 0.05: 2 hits -> 0.1.
        assert!(obs[5] >= 0.1, "hits counter should advance, got {}", obs[5]);
    }

    #[test]
    fn dodge_noop_eventually_hit() {
        let mut env = dodge();
        env.seed(7);
        let mut obs = vec![0.0; 8];
        env.reset_into(&mut obs);
        let mut died = false;
        for _ in 0..5_000 {
            let t = env.step_into(&Action::Discrete(0), &mut obs);
            if t.done {
                died = true;
                break;
            }
        }
        assert!(died, "standing still must eventually be hit");
    }

    #[test]
    fn games_render_nonempty_frames() {
        for mut env in [multitask(), pong(), dodge()] {
            env.seed(0);
            let mut obs = vec![0.0; env.obs_dim()];
            env.reset_into(&mut obs);
            env.step_into(&Action::Discrete(0), &mut obs);
            let mut fb = Framebuffer::standard();
            env.render(&mut fb);
            assert!(fb.sum() > 5.0, "{} renders blank", env.id());
        }
    }

    #[test]
    fn multitask_observation_exposes_vm_memory() {
        let mut env = multitask();
        env.seed(0);
        let mut obs = vec![0.0; 32];
        env.reset_into(&mut obs);
        assert_eq!(obs[0], 0.5); // ball_x init (32 px, scaled /64)
        assert_eq!(obs[4], 0.5); // paddle_x init
        env.step_into(&Action::Discrete(0), &mut obs);
        assert!((obs[0] - 33.3 / 64.0).abs() < 1e-4); // ball moved by vx
    }

    #[test]
    fn shooter_assembles_and_survival_needs_play() {
        let mut env = shooter();
        env.seed(2);
        let mut obs = vec![0.0; 8];
        env.reset_into(&mut obs);
        // Noop: enemies land eventually.
        let mut died = false;
        for _ in 0..2_000 {
            let t = env.step_into(&Action::Discrete(0), &mut obs);
            if t.done {
                died = true;
                break;
            }
        }
        assert!(died, "idle ship must lose");
    }

    #[test]
    fn shooter_aim_and_fire_scores() {
        // Heuristic: move under the lowest enemy and fire.
        let mut env = shooter();
        env.seed(4);
        let mut obs = vec![0.0; 8];
        env.reset_into(&mut obs);
        let mut score_seen = 0.0f32;
        for _ in 0..4_000 {
            let (ship, e0x, e0y, e1x, e1y) = (obs[0], obs[3], obs[4], obs[5], obs[6]);
            let (tx, _ty) = if e0y > e1y { (e0x, e0y) } else { (e1x, e1y) };
            let a = if (ship - tx).abs() < 2.0 / 64.0 {
                3
            } else if tx < ship {
                1
            } else {
                2
            };
            let t = env.step_into(&Action::Discrete(a), &mut obs);
            score_seen = score_seen.max(obs[7]);
            if t.done {
                break;
            }
        }
        // score slot is scaled by 0.05: 2 kills -> 0.1.
        assert!(score_seen >= 0.1, "heuristic should down some enemies: {score_seen}");
    }
}
