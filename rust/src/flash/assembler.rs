//! ASVM assembler: text assembly -> [`Program`].
//!
//! Syntax, one instruction per line:
//!
//! ```text
//! ; comment
//! label:
//!     push 1.5
//!     load 4        ; slot index
//!     jz  miss      ; label reference
//!     halt
//! frame:            ; required: per-frame entry point
//!     ...
//! ```
//!
//! Two-pass: collect label offsets, then encode with resolved jumps.  The
//! special label `frame:` marks the per-frame entry; code before it is
//! the episode-init section.

use std::collections::HashMap;

use crate::core::error::{CairlError, Result};
use crate::flash::opcode::{Op, Program, MEMORY_SLOTS};

fn parse_slot(arg: &str, line_no: usize) -> Result<u8> {
    let slot: usize = arg.parse().map_err(|_| {
        CairlError::Vm(format!("line {line_no}: bad slot {arg:?}"))
    })?;
    if slot >= MEMORY_SLOTS {
        return Err(CairlError::Vm(format!(
            "line {line_no}: slot {slot} out of range (max {})",
            MEMORY_SLOTS - 1
        )));
    }
    Ok(slot as u8)
}

/// Assemble a program.  Errors carry 1-based line numbers.
pub fn assemble(src: &str) -> Result<Program> {
    // Pass 1: label offsets.
    let mut labels: HashMap<&str, u32> = HashMap::new();
    let mut offset = 0u32;
    for (idx, raw) in src.lines().enumerate() {
        let line = raw.split(';').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(label) = line.strip_suffix(':') {
            if labels.insert(label, offset).is_some() {
                return Err(CairlError::Vm(format!(
                    "line {}: duplicate label {label:?}",
                    idx + 1
                )));
            }
        } else {
            offset += 1;
        }
    }
    let frame_entry = *labels.get("frame").ok_or_else(|| {
        CairlError::Vm("missing required `frame:` label".into())
    })?;

    // Pass 2: encode.
    let mut code = Vec::with_capacity(offset as usize);
    for (idx, raw) in src.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw.split(';').next().unwrap_or("").trim();
        if line.is_empty() || line.ends_with(':') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let mnemonic = parts.next().unwrap();
        let arg = parts.next();
        if parts.next().is_some() {
            return Err(CairlError::Vm(format!(
                "line {line_no}: trailing tokens in {line:?}"
            )));
        }
        let need_arg = || {
            arg.ok_or_else(|| {
                CairlError::Vm(format!("line {line_no}: {mnemonic} needs an operand"))
            })
        };
        let target = |labels: &HashMap<&str, u32>| -> Result<u32> {
            let name = need_arg()?;
            labels.get(name).copied().ok_or_else(|| {
                CairlError::Vm(format!("line {line_no}: unknown label {name:?}"))
            })
        };
        let op = match mnemonic {
            "push" => {
                let v: f64 = need_arg()?.parse().map_err(|_| {
                    CairlError::Vm(format!("line {line_no}: bad number"))
                })?;
                Op::Push(v)
            }
            "load" => Op::Load(parse_slot(need_arg()?, line_no)?),
            "store" => Op::Store(parse_slot(need_arg()?, line_no)?),
            "dup" => Op::Dup,
            "pop" => Op::Pop,
            "add" => Op::Add,
            "sub" => Op::Sub,
            "mul" => Op::Mul,
            "div" => Op::Div,
            "mod" => Op::Mod,
            "min" => Op::Min,
            "max" => Op::Max,
            "neg" => Op::Neg,
            "abs" => Op::Abs,
            "floor" => Op::Floor,
            "sign" => Op::Sign,
            "eq" => Op::Eq,
            "ne" => Op::Ne,
            "lt" => Op::Lt,
            "le" => Op::Le,
            "gt" => Op::Gt,
            "ge" => Op::Ge,
            "not" => Op::Not,
            "jmp" => Op::Jmp(target(&labels)?),
            "jz" => Op::Jz(target(&labels)?),
            "jnz" => Op::Jnz(target(&labels)?),
            "halt" => Op::Halt,
            "rand" => Op::Rand,
            "input" => Op::Input,
            "clear" => Op::Clear,
            "rect" => Op::Rect,
            "disc" => Op::Disc,
            "reward" => Op::Reward,
            "die" => Op::Die,
            other => {
                return Err(CairlError::Vm(format!(
                    "line {line_no}: unknown mnemonic {other:?}"
                )))
            }
        };
        code.push(op);
        // Operand sanity: only the ops above consume `arg`.
        if arg.is_some()
            && !matches!(
                mnemonic,
                "push" | "load" | "store" | "jmp" | "jz" | "jnz"
            )
        {
            return Err(CairlError::Vm(format!(
                "line {line_no}: {mnemonic} takes no operand"
            )));
        }
    }

    Ok(Program {
        code,
        init_entry: 0,
        frame_entry,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assembles_minimal_program() {
        let p = assemble("halt\nframe:\n  push 1\n  reward\n  halt\n").unwrap();
        assert_eq!(p.code.len(), 4);
        assert_eq!(p.init_entry, 0);
        assert_eq!(p.frame_entry, 1);
        assert_eq!(p.code[1], Op::Push(1.0));
        assert_eq!(p.code[2], Op::Reward);
    }

    #[test]
    fn resolves_forward_and_backward_labels() {
        let src = "
top:
    jmp skip
    die
skip:
    halt
frame:
    jmp top
";
        let p = assemble(src).unwrap();
        assert_eq!(p.code[0], Op::Jmp(2));
        assert_eq!(p.code[3], Op::Jmp(0));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let p = assemble("; header\n\nhalt ; inline\nframe:\nhalt\n").unwrap();
        assert_eq!(p.code.len(), 2);
    }

    #[test]
    fn missing_frame_label_is_error() {
        assert!(assemble("halt\n").is_err());
    }

    #[test]
    fn unknown_mnemonic_reports_line() {
        let err = assemble("halt\nframe:\nfly\n").unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn unknown_label_is_error() {
        assert!(assemble("frame:\njmp nowhere\n").is_err());
    }

    #[test]
    fn duplicate_label_is_error() {
        assert!(assemble("a:\nhalt\na:\nframe:\nhalt\n").is_err());
    }

    #[test]
    fn slot_bounds_checked() {
        assert!(assemble("frame:\nload 63\nhalt\n").is_ok());
        assert!(assemble("frame:\nload 64\nhalt\n").is_err());
    }

    #[test]
    fn stray_operand_is_error() {
        assert!(assemble("frame:\nadd 3\n").is_err());
        assert!(assemble("frame:\npush\n").is_err());
    }
}
