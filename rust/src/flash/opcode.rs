//! ASVM instruction set.
//!
//! A compact stack machine over f64 values with 64 memory slots (the
//! "virtual flash memory" the paper exposes as observations) and a
//! display-list output channel.  Control flow uses absolute code offsets
//! resolved by the assembler.

/// Number of virtual-flash-memory slots (the observable register file).
pub const MEMORY_SLOTS: usize = 64;

/// One ASVM instruction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Push an immediate constant.
    Push(f64),
    /// Push `memory[slot]`.
    Load(u8),
    /// Pop into `memory[slot]`.
    Store(u8),
    /// Duplicate the top of stack.
    Dup,
    /// Discard the top of stack.
    Pop,
    // -- arithmetic (pop b, pop a, push a OP b) --
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Min,
    Max,
    /// Pop a, push -a.
    Neg,
    /// Pop a, push |a|.
    Abs,
    /// Pop a, push floor(a).
    Floor,
    /// Pop a, push sign(a) in {-1, 0, 1}.
    Sign,
    // -- comparisons (pop b, pop a, push 1.0 / 0.0) --
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    /// Pop a, push 1.0 if a == 0.0 else 0.0.
    Not,
    // -- control flow --
    /// Unconditional jump to code offset.
    Jmp(u32),
    /// Pop; jump if zero.
    Jz(u32),
    /// Pop; jump if non-zero.
    Jnz(u32),
    /// End the current entry point.
    Halt,
    // -- environment syscalls --
    /// Push a uniform random f64 in [0, 1).
    Rand,
    /// Push the current frame's agent action.
    Input,
    /// Pop intensity; clear the frame to it.
    Clear,
    /// Pop i, h, w, y, x; queue a filled rect draw.
    Rect,
    /// Pop i, r, y, x; queue a filled disc draw.
    Disc,
    /// Pop delta; accumulate into the frame reward.
    Reward,
    /// Flag the game as over (episode terminal).
    Die,
}

/// A deferred draw command (the display list the runner rasterises).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DrawCmd {
    Clear(f32),
    Rect {
        x: f32,
        y: f32,
        w: f32,
        h: f32,
        i: f32,
    },
    Disc {
        x: f32,
        y: f32,
        r: f32,
        i: f32,
    },
}

/// A fully assembled program: code plus its two entry points.
#[derive(Clone, Debug)]
pub struct Program {
    pub code: Vec<Op>,
    /// Entry run once per episode (reset).  Always offset 0.
    pub init_entry: u32,
    /// Entry run once per frame (the `frame:` label).
    pub frame_entry: u32,
}
