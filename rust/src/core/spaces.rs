//! Observation/action spaces — the paper's §III-A "Spaces" module.
//!
//! Mirrors AI Gym's two workhorse types: `Box` (n-dimensional bounded
//! f32 tensor) and `Discrete` (integers `0..n`).  Sampling uses the
//! toolkit [`Pcg32`](crate::core::rng::Pcg32) so trajectories are
//! reproducible across runs and runners.

use crate::core::rng::Pcg32;

/// An action as passed to [`Env::step`](crate::core::env::Env::step).
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    /// Index into a [`Space::Discrete`].
    Discrete(usize),
    /// Vector for a [`Space::Box`] action space.
    Continuous(Vec<f32>),
}

impl Action {
    /// The discrete index, panicking on a continuous action.  Native envs
    /// use this in the hot path; they validate once via
    /// [`Space::contains`] in debug builds.
    #[inline]
    pub fn index(&self) -> usize {
        match self {
            Action::Discrete(i) => *i,
            Action::Continuous(_) => {
                panic!("expected a discrete action, got a continuous one")
            }
        }
    }

    /// The continuous vector, panicking on a discrete action.
    #[inline]
    pub fn vector(&self) -> &[f32] {
        match self {
            Action::Continuous(v) => v,
            Action::Discrete(_) => {
                panic!("expected a continuous action, got a discrete one")
            }
        }
    }
}

/// Shape description of an observation or action space.
#[derive(Clone, Debug, PartialEq)]
pub enum Space {
    /// Bounded f32 tensor.  `low`/`high` are element-wise bounds with
    /// `low.len() == high.len() == shape.iter().product()`.
    Box {
        low: Vec<f32>,
        high: Vec<f32>,
        shape: Vec<usize>,
    },
    /// Integers `0..n`.
    Discrete { n: usize },
}

impl Space {
    /// Convenience constructor for a symmetric 1-D box `[-bound, bound]^dim`.
    pub fn symmetric_box(bound: f32, dim: usize) -> Space {
        Space::Box {
            low: vec![-bound; dim],
            high: vec![bound; dim],
            shape: vec![dim],
        }
    }

    /// Box with per-element bounds and a 1-D shape.
    pub fn box1(low: Vec<f32>, high: Vec<f32>) -> Space {
        assert_eq!(low.len(), high.len());
        let d = low.len();
        Space::Box {
            low,
            high,
            shape: vec![d],
        }
    }

    /// Total number of scalar elements.
    pub fn flat_dim(&self) -> usize {
        match self {
            Space::Box { shape, .. } => shape.iter().product(),
            Space::Discrete { .. } => 1,
        }
    }

    /// The shape vector (`[1]` for Discrete, matching Gym's convention of
    /// scalar discrete observations).
    pub fn shape(&self) -> Vec<usize> {
        match self {
            Space::Box { shape, .. } => shape.clone(),
            Space::Discrete { .. } => vec![1],
        }
    }

    /// Draw a uniform random element — `env.action_space().sample(rng)` is
    /// the paper's Listing-1/2 exploration idiom.
    ///
    /// Unbounded box dimensions (|bound| >= f32::MAX) sample standard
    /// normal, matching Gym's behaviour.
    pub fn sample(&self, rng: &mut Pcg32) -> Action {
        match self {
            Space::Discrete { n } => Action::Discrete(rng.below(*n as u32) as usize),
            Space::Box { low, high, .. } => {
                let v = low
                    .iter()
                    .zip(high)
                    .map(|(&lo, &hi)| {
                        if lo <= f32::MIN || hi >= f32::MAX {
                            rng.normal()
                        } else {
                            rng.uniform(lo, hi)
                        }
                    })
                    .collect();
                Action::Continuous(v)
            }
        }
    }

    /// Membership test (used by debug assertions and the validation
    /// wrapper).
    pub fn contains(&self, a: &Action) -> bool {
        match (self, a) {
            (Space::Discrete { n }, Action::Discrete(i)) => i < n,
            (Space::Box { low, high, .. }, Action::Continuous(v)) => {
                v.len() == low.len()
                    && v.iter()
                        .zip(low.iter().zip(high))
                        .all(|(&x, (&lo, &hi))| x >= lo && x <= hi && x.is_finite())
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discrete_samples_in_range() {
        let s = Space::Discrete { n: 4 };
        let mut rng = Pcg32::new(0, 1);
        let mut seen = [false; 4];
        for _ in 0..1000 {
            match s.sample(&mut rng) {
                Action::Discrete(i) => {
                    assert!(i < 4);
                    seen[i] = true;
                }
                _ => panic!("wrong action kind"),
            }
        }
        assert!(seen.iter().all(|&b| b), "all actions reachable");
    }

    #[test]
    fn box_samples_respect_bounds() {
        let s = Space::box1(vec![-2.0, 0.0], vec![2.0, 1.0]);
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..1000 {
            let a = s.sample(&mut rng);
            assert!(s.contains(&a));
        }
    }

    #[test]
    fn contains_rejects_wrong_kind_and_out_of_range() {
        let d = Space::Discrete { n: 2 };
        assert!(!d.contains(&Action::Discrete(2)));
        assert!(!d.contains(&Action::Continuous(vec![0.0])));
        let b = Space::symmetric_box(1.0, 2);
        assert!(!b.contains(&Action::Continuous(vec![0.0, 1.5])));
        assert!(!b.contains(&Action::Continuous(vec![0.0])));
        assert!(!b.contains(&Action::Continuous(vec![f32::NAN, 0.0])));
    }

    #[test]
    fn flat_dim_and_shape() {
        let b = Space::Box {
            low: vec![0.0; 6],
            high: vec![1.0; 6],
            shape: vec![2, 3],
        };
        assert_eq!(b.flat_dim(), 6);
        assert_eq!(b.shape(), vec![2, 3]);
        assert_eq!(Space::Discrete { n: 5 }.flat_dim(), 1);
    }

    #[test]
    #[should_panic]
    fn index_on_continuous_panics() {
        Action::Continuous(vec![0.0]).index();
    }
}
