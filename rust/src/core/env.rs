//! The [`Env`] trait — the paper's AI-Gym-compatible environment
//! interface (§III-B, Listings 1/2), with a zero-allocation hot path.
//!
//! Two calling conventions:
//!
//! * **Hot path** — [`Env::reset_into`] / [`Env::step_into`] write the
//!   observation into a caller-owned buffer and return a [`Transition`]
//!   by value.  No allocation per step; this is what the benchmarks and
//!   the DQN training loop use, and it is where the paper's "orders of
//!   magnitude" stepping advantage is measured.
//! * **Gym-compatible** — [`Env::reset`] / [`Env::step`] allocate a fresh
//!   observation `Vec` and return a [`Step`], matching the
//!   `s1, r, term, info = e.step(a)` shape of the paper's Listing 2.
//!
//! Static composition (paper Listing 1) works because wrappers are
//! generic structs implementing `Env` over any `E: Env`:
//! `Flatten<TimeLimit<CartPole>>` monomorphises to straight-line code.
//! The dynamic registry ([`crate::make`]) erases to [`DynEnv`] instead.

use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Per-step result of the no-allocation hot path.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Transition {
    /// Scalar reward for this step.
    pub reward: f32,
    /// Environment reached a terminal state.
    pub done: bool,
    /// Episode ended by a wrapper limit (e.g. [`TimeLimit`]
    /// (crate::wrappers::TimeLimit)), not by the dynamics.  `truncated`
    /// implies `done`.
    pub truncated: bool,
}

impl Transition {
    /// A live (non-terminal) transition with the given reward.
    #[inline]
    pub fn live(reward: f32) -> Self {
        Transition { reward, done: false, truncated: false }
    }

    /// A terminal transition with the given reward.
    #[inline]
    pub fn terminal(reward: f32) -> Self {
        Transition { reward, done: true, truncated: false }
    }
}

/// Statistics attached to the final step of an episode by
/// [`RecordEpisodeStatistics`](crate::wrappers::RecordEpisodeStatistics).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EpisodeStats {
    /// Undiscounted return of the finished episode.
    pub ret: f32,
    /// Number of steps in the finished episode.
    pub len: u32,
}

/// Allocating step result — the Gym-shaped `(s1, r, term, info)` tuple.
#[derive(Clone, Debug)]
pub struct Step {
    /// Next observation (flattened f32s).
    pub obs: Vec<f32>,
    /// Scalar reward.
    pub reward: f32,
    /// Terminal flag (includes truncation).
    pub done: bool,
    /// True when the episode ended via a wrapper limit.
    pub truncated: bool,
    /// Episode statistics, present on the last step when a
    /// stats-recording wrapper is in the stack.
    pub episode: Option<EpisodeStats>,
}

/// A reinforcement-learning environment.
///
/// Implementations must be deterministic given [`Env::seed`]: the same
/// seed and action sequence must reproduce the same trajectory (the
/// paper's fixed-seed experiment protocol relies on this, and the
/// cross-runner tests compare native vs scripted trajectories).
pub trait Env {
    /// Stable identifier, e.g. `"CartPole-v1"`.
    fn id(&self) -> String;

    /// Observation space description.
    fn observation_space(&self) -> Space;

    /// Action space description.
    fn action_space(&self) -> Space;

    /// Flattened observation length.  Hot-path callers size their buffer
    /// with this once, outside the loop.
    fn obs_dim(&self) -> usize {
        self.observation_space().flat_dim()
    }

    /// Re-seed the environment's RNG (affects subsequent `reset`s).
    fn seed(&mut self, seed: u64);

    /// Start a new episode, writing the initial observation into `obs`
    /// (`obs.len() == self.obs_dim()`).
    fn reset_into(&mut self, obs: &mut [f32]);

    /// Advance one step, writing the next observation into `obs`.
    ///
    /// Calling `step_into` on a finished episode is a logic error; native
    /// envs debug-assert, matching Gym's warning semantics.
    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition;

    /// Paint the current state into a framebuffer (software rendering,
    /// paper §II-B).  Default: leave the buffer untouched (console-only
    /// environments).
    fn render(&self, fb: &mut Framebuffer) {
        let _ = fb;
    }

    /// Gym-compatible allocating reset.
    fn reset(&mut self) -> Vec<f32> {
        let mut obs = vec![0.0; self.obs_dim()];
        self.reset_into(&mut obs);
        obs
    }

    /// Gym-compatible allocating step.
    fn step(&mut self, action: &Action) -> Step {
        let mut obs = vec![0.0; self.obs_dim()];
        let t = self.step_into(action, &mut obs);
        Step {
            obs,
            reward: t.reward,
            done: t.done || t.truncated,
            truncated: t.truncated,
            episode: None,
        }
    }
}

/// Boxed, type-erased environment as returned by [`crate::make`].
pub type DynEnv = Box<dyn Env + Send>;

// Box<E: Env> forwards, so wrappers compose over DynEnv too
// (`TimeLimit::new(make("...")?, 200)` works).
impl<E: Env + ?Sized> Env for Box<E> {
    fn id(&self) -> String {
        (**self).id()
    }
    fn observation_space(&self) -> Space {
        (**self).observation_space()
    }
    fn action_space(&self) -> Space {
        (**self).action_space()
    }
    fn obs_dim(&self) -> usize {
        (**self).obs_dim()
    }
    fn seed(&mut self, seed: u64) {
        (**self).seed(seed)
    }
    fn reset_into(&mut self, obs: &mut [f32]) {
        (**self).reset_into(obs)
    }
    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        (**self).step_into(action, obs)
    }
    fn render(&self, fb: &mut Framebuffer) {
        (**self).render(fb)
    }
    fn reset(&mut self) -> Vec<f32> {
        (**self).reset()
    }
    fn step(&mut self, action: &Action) -> Step {
        (**self).step(action)
    }
}

/// Run one episode with uniform-random actions, returning (return, length).
///
/// Shared by benchmarks, smoke tests and the CLI `run` subcommand; uses
/// the hot path (caller-invisible, zero alloc per step).
pub fn random_rollout<E: Env + ?Sized>(
    env: &mut E,
    rng: &mut Pcg32,
    max_steps: u32,
) -> (f32, u32) {
    let space = env.action_space();
    let mut obs = vec![0.0; env.obs_dim()];
    env.reset_into(&mut obs);
    let mut ret = 0.0;
    let mut len = 0;
    while len < max_steps {
        let a = space.sample(rng);
        let t = env.step_into(&a, &mut obs);
        ret += t.reward;
        len += 1;
        if t.done || t.truncated {
            break;
        }
    }
    (ret, len)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal env: counts to 3 then terminates, obs = [count].
    struct Counter {
        count: u32,
    }

    impl Env for Counter {
        fn id(&self) -> String {
            "Counter-v0".into()
        }
        fn observation_space(&self) -> Space {
            Space::box1(vec![0.0], vec![3.0])
        }
        fn action_space(&self) -> Space {
            Space::Discrete { n: 2 }
        }
        fn seed(&mut self, _seed: u64) {}
        fn reset_into(&mut self, obs: &mut [f32]) {
            self.count = 0;
            obs[0] = 0.0;
        }
        fn step_into(&mut self, _a: &Action, obs: &mut [f32]) -> Transition {
            self.count += 1;
            obs[0] = self.count as f32;
            if self.count >= 3 {
                Transition::terminal(1.0)
            } else {
                Transition::live(0.0)
            }
        }
    }

    #[test]
    fn allocating_step_matches_hot_path() {
        let mut env = Counter { count: 0 };
        env.reset();
        let s = env.step(&Action::Discrete(0));
        assert_eq!(s.obs, vec![1.0]);
        assert!(!s.done);
        let _ = env.step(&Action::Discrete(0));
        let s3 = env.step(&Action::Discrete(0));
        assert!(s3.done);
        assert_eq!(s3.reward, 1.0);
    }

    #[test]
    fn boxed_env_forwards() {
        let mut env: DynEnv = Box::new(Counter { count: 0 });
        assert_eq!(env.id(), "Counter-v0");
        assert_eq!(env.obs_dim(), 1);
        let obs = env.reset();
        assert_eq!(obs, vec![0.0]);
    }

    #[test]
    fn random_rollout_terminates() {
        let mut env = Counter { count: 0 };
        let mut rng = Pcg32::new(0, 1);
        let (ret, len) = random_rollout(&mut env, &mut rng, 100);
        assert_eq!(len, 3);
        assert_eq!(ret, 1.0);
    }

    #[test]
    fn random_rollout_respects_cap() {
        struct Forever;
        impl Env for Forever {
            fn id(&self) -> String {
                "Forever-v0".into()
            }
            fn observation_space(&self) -> Space {
                Space::box1(vec![0.0], vec![1.0])
            }
            fn action_space(&self) -> Space {
                Space::Discrete { n: 1 }
            }
            fn seed(&mut self, _s: u64) {}
            fn reset_into(&mut self, obs: &mut [f32]) {
                obs[0] = 0.0;
            }
            fn step_into(&mut self, _a: &Action, _o: &mut [f32]) -> Transition {
                Transition::live(1.0)
            }
        }
        let mut rng = Pcg32::new(0, 1);
        let (ret, len) = random_rollout(&mut Forever, &mut rng, 50);
        assert_eq!(len, 50);
        assert_eq!(ret, 50.0);
    }
}
