//! Deterministic PRNG for the whole toolkit: PCG32 (O'Neill 2014).
//!
//! Every stochastic component (space sampling, env reset noise, epsilon
//! exploration, tournament seeding) draws from a seeded [`Pcg32`] so that
//! experiments are bit-reproducible — the paper's §V "fixed randomization
//! seed" protocol.  No external crates: the generator is 2 u64s and ~10
//! lines of arithmetic, trivially auditable.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.  Different stream
    /// ids give statistically independent sequences for the same seed
    /// (used by the vectorised executor to give each lane its own stream).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits -> exactly representable uniform grid.
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u32() as u64) as f64 / 4_294_967_296.0
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (no caching: keeps `Clone` cheap and
    /// the state trivially serialisable).
    pub fn normal(&mut self) -> f32 {
        let u1 = loop {
            let u = self.next_f32();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// True with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

impl Default for Pcg32 {
    fn default() -> Self {
        Pcg32::new(0x853c49e6748fea9b, 0xda3e39cb94b95bdb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproducible_for_same_seed() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::new(7, 7);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..10_000 {
            let x = rng.uniform(-0.05, 0.05);
            assert!((-0.05..0.05).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut rng = Pcg32::new(3, 3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn below_one_is_always_zero() {
        let mut rng = Pcg32::new(9, 9);
        for _ in 0..100 {
            assert_eq!(rng.below(1), 0);
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Pcg32::new(11, 4);
        let n = 100_000;
        let (mut sum, mut sum2) = (0f64, 0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::new(5, 5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
