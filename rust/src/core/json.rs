//! Minimal JSON parser — the manifest/config interchange format.
//!
//! The offline build environment provides no serde, so the toolkit
//! carries its own strict-enough JSON reader: full value model, UTF-8
//! strings with escapes, f64 numbers, recursive-descent, line-tagged
//! errors.  Writing is limited to what the toolkit emits (reports), via
//! [`Value::render`].

use std::collections::BTreeMap;
use std::fmt;

use crate::core::error::{CairlError, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Object field lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(map) => map.get(key),
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Array(xs) => xs.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers as f32s.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_array().map(|xs| {
            xs.iter()
                .filter_map(|v| v.as_f64())
                .map(|v| v as f32)
                .collect()
        })
    }

    /// Nested path lookup.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialise (compact).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Array(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            Value::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: u32,
}

impl<'a> JsonParser<'a> {
    fn err(&self, msg: &str) -> CairlError {
        CairlError::Config(format!("json line {}: {msg}", self.line))
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            match b {
                b'\n' => {
                    self.line += 1;
                    self.pos += 1;
                }
                b' ' | b'\t' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!(
                "expected {:?}, found {:?}",
                b as char,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.keyword("true", Value::Bool(true)),
            Some(b'f') => self.keyword("false", Value::Bool(false)),
            Some(b'n') => self.keyword("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(self.err(&format!(
                "unexpected {:?}",
                other.map(|c| c as char)
            ))),
        }
    }

    fn keyword(&mut self, word: &str, value: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("bad keyword, expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E' => self.pos += 1,
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err(self.err("dangling escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs are not emitted by our
                            // producers; map unpaired surrogates to the
                            // replacement character.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => {
                            return Err(self.err(&format!(
                                "unknown escape \\{}",
                                other as char
                            )))
                        }
                    }
                }
                _ => {
                    // Re-consume as UTF-8: back up and take the full char.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = JsonParser {
        bytes: text.as_bytes(),
        pos: 0,
        line: 1,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content after document"));
    }
    Ok(v)
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.path(&["a"]).unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.path(&["d", "e"]).unwrap().as_bool(), Some(false));
    }

    #[test]
    fn handles_escapes_and_unicode() {
        let v = parse(r#""line\nbreak \"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nbreak \"q\" A"));
        let v2 = parse("\"héllo\"").unwrap();
        assert_eq!(v2.as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn error_carries_line_number() {
        let err = parse("{\n\"a\": 1,\n!}").unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(BTreeMap::new()));
    }

    #[test]
    fn f32_vec_helper() {
        let v = parse("[1.5, 2, 3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.5, 2.0, 3.0]);
    }

    #[test]
    fn render_roundtrips() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":true}}"#;
        let v = parse(src).unwrap();
        let rendered = v.render();
        assert_eq!(parse(&rendered).unwrap(), v);
    }

    #[test]
    fn parses_the_real_manifest() {
        let path = crate::runtime::artifacts::default_artifact_dir()
            .join("manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert!(v.get("artifacts").is_some());
            assert_eq!(v.path(&["format"]).unwrap().as_str(), Some("hlo-text"));
        }
    }
}
