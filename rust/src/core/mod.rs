//! Core abstractions: the [`env::Env`] trait, the fused [`batch`]
//! stepping layer, [`spaces`], deterministic [`rng`], construction
//! [`kwargs`], and toolkit-wide [`error`] types.
//!
//! This is the paper's §III-A "building blocks" layer (Environments +
//! Spaces), kept dependency-free so every other module (native envs,
//! script runner, flash runner, wrappers, coordinator) builds on the same
//! minimal surface.

pub mod batch;
pub mod env;
pub mod error;
pub mod json;
pub mod kwargs;
pub mod rng;
pub mod spaces;
