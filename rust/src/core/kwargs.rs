//! Construction kwargs — the typed key/value surface behind
//! [`make_with`](crate::coordinator::registry::make_with) and Gym-style
//! id kwargs (`"CartPole-v1?max_steps=200"`).
//!
//! An [`EnvSpec`](crate::coordinator::registry::EnvSpec) declares its
//! permitted keys with **typed defaults**; user kwargs are merged over
//! those defaults with strict validation — an unknown key or an
//! uncoercible value is a [`CairlError::Config`], never a silent
//! fallback.  Query-string kwargs arrive as [`KwargValue::Str`] and are
//! coerced against the default's type during the merge, so
//! `"max_steps=200"` and `KwargValue::Int(200)` behave identically.

use std::fmt;

use crate::core::error::{CairlError, Result};

/// A typed kwarg value.  The default's variant fixes the key's type;
/// user-supplied strings are parsed to that type at merge time.
#[derive(Clone, Debug, PartialEq)]
pub enum KwargValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl KwargValue {
    /// Human-readable type name for error messages.
    pub fn type_name(&self) -> &'static str {
        match self {
            KwargValue::Int(_) => "int",
            KwargValue::Float(_) => "float",
            KwargValue::Bool(_) => "bool",
            KwargValue::Str(_) => "string",
        }
    }

    fn parse_as(raw: &str, template: &KwargValue) -> Option<KwargValue> {
        match template {
            KwargValue::Int(_) => raw.parse::<i64>().ok().map(KwargValue::Int),
            KwargValue::Float(_) => raw.parse::<f64>().ok().map(KwargValue::Float),
            KwargValue::Bool(_) => match raw {
                "true" | "1" => Some(KwargValue::Bool(true)),
                "false" | "0" => Some(KwargValue::Bool(false)),
                _ => None,
            },
            KwargValue::Str(_) => Some(KwargValue::Str(raw.to_string())),
        }
    }

    /// Coerce this value to the template's type: strings parse, ints
    /// widen to floats, matching variants clone.  `None` = type error.
    pub fn coerce_like(&self, template: &KwargValue) -> Option<KwargValue> {
        match (self, template) {
            (KwargValue::Str(s), t) if !matches!(t, KwargValue::Str(_)) => {
                KwargValue::parse_as(s, t)
            }
            (KwargValue::Int(i), KwargValue::Float(_)) => Some(KwargValue::Float(*i as f64)),
            (v, t) if std::mem::discriminant(v) == std::mem::discriminant(t) => Some(v.clone()),
            _ => None,
        }
    }
}

impl fmt::Display for KwargValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KwargValue::Int(i) => write!(f, "{i}"),
            KwargValue::Float(x) => write!(f, "{x}"),
            KwargValue::Bool(b) => write!(f, "{b}"),
            KwargValue::Str(s) => write!(f, "{s}"),
        }
    }
}

/// An ordered key → [`KwargValue`] map (insertion order is preserved so
/// rendered specs stay stable).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Kwargs {
    pairs: Vec<(String, KwargValue)>,
}

impl Kwargs {
    /// An empty kwarg set.
    pub fn new() -> Kwargs {
        Kwargs { pairs: Vec::new() }
    }

    /// Builder-style insert.
    ///
    /// ```
    /// use cairl::core::kwargs::{KwargValue, Kwargs};
    /// let kw = Kwargs::new().with("max_steps", KwargValue::Int(200));
    /// assert_eq!(kw.i64_or("max_steps", 0), 200);
    /// ```
    pub fn with(mut self, key: &str, value: KwargValue) -> Kwargs {
        self.insert(key, value);
        self
    }

    /// Insert or overwrite a key.
    pub fn insert(&mut self, key: &str, value: KwargValue) {
        if let Some(slot) = self.pairs.iter_mut().find(|(k, _)| k.as_str() == key) {
            slot.1 = value;
        } else {
            self.pairs.push((key.to_string(), value));
        }
    }

    /// Look up a key.
    pub fn get(&self, key: &str) -> Option<&KwargValue> {
        self.pairs
            .iter()
            .find(|(k, _)| k.as_str() == key)
            .map(|(_, v)| v)
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// `(key, value)` pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &KwargValue)> {
        self.pairs.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// The keys in insertion order.
    pub fn keys(&self) -> Vec<&str> {
        self.pairs.iter().map(|(k, _)| k.as_str()).collect()
    }

    /// The value of an `Int` key, or `default` when absent (or not an
    /// int).  Post-merge kwargs are type-stable, so builders use this.
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        match self.get(key) {
            Some(KwargValue::Int(i)) => *i,
            _ => default,
        }
    }

    /// The value of a `Float` key, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.get(key) {
            Some(KwargValue::Float(x)) => *x,
            _ => default,
        }
    }

    /// The value of a `Bool` key, or `default`.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some(KwargValue::Bool(b)) => *b,
            _ => default,
        }
    }

    /// Parse a Gym-style query string, `"max_steps=200&size=5"`.  Every
    /// value arrives as [`KwargValue::Str`]; the merge against the
    /// spec's defaults types it.
    pub fn parse_query(query: &str) -> Result<Kwargs> {
        let mut kwargs = Kwargs::new();
        for part in query.split('&') {
            let part = part.trim();
            if part.is_empty() {
                return Err(CairlError::Config(format!(
                    "kwargs {query:?}: empty component"
                )));
            }
            let Some((key, value)) = part.split_once('=') else {
                return Err(CairlError::Config(format!(
                    "kwargs {query:?}: expected key=value, got {part:?}"
                )));
            };
            let key = key.trim();
            if key.is_empty() {
                return Err(CairlError::Config(format!(
                    "kwargs {query:?}: empty key in {part:?}"
                )));
            }
            kwargs.insert(key, KwargValue::Str(value.trim().to_string()));
        }
        Ok(kwargs)
    }

    /// Merge `user` kwargs over `defaults`: every user key must exist in
    /// the defaults and its value must coerce to the default's type.
    /// `context` names the env id in error messages.
    pub fn merged_over(defaults: &Kwargs, user: &Kwargs, context: &str) -> Result<Kwargs> {
        let mut merged = defaults.clone();
        for (key, value) in user.iter() {
            let Some(template) = defaults.get(key) else {
                let valid = if defaults.is_empty() {
                    "none".to_string()
                } else {
                    defaults.keys().join(", ")
                };
                return Err(CairlError::Config(format!(
                    "{context}: unknown kwarg {key:?} (valid kwargs: {valid})"
                )));
            };
            let Some(coerced) = value.coerce_like(template) else {
                return Err(CairlError::Config(format!(
                    "{context}: kwarg {key:?}: cannot read {value:?} as {}",
                    template.type_name()
                )));
            };
            merged.insert(key, coerced);
        }
        Ok(merged)
    }

    /// Render back to the canonical `key=value&key=value` query string.
    pub fn render(&self) -> String {
        self.pairs
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join("&")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_parses_and_renders() {
        let kw = Kwargs::parse_query("max_steps=200&size=5").unwrap();
        assert_eq!(kw.len(), 2);
        assert_eq!(kw.get("max_steps"), Some(&KwargValue::Str("200".into())));
        assert_eq!(kw.render(), "max_steps=200&size=5");
    }

    #[test]
    fn query_rejects_malformed_input() {
        assert!(Kwargs::parse_query("").is_err());
        assert!(Kwargs::parse_query("max_steps").is_err());
        assert!(Kwargs::parse_query("=5").is_err());
        assert!(Kwargs::parse_query("a=1&&b=2").is_err());
    }

    #[test]
    fn merge_types_string_values_against_defaults() {
        let defaults = Kwargs::new()
            .with("max_steps", KwargValue::Int(500))
            .with("scale", KwargValue::Float(1.0))
            .with("verbose", KwargValue::Bool(false));
        let user = Kwargs::parse_query("max_steps=200&scale=2&verbose=true").unwrap();
        let merged = Kwargs::merged_over(&defaults, &user, "Test-v0").unwrap();
        assert_eq!(merged.i64_or("max_steps", 0), 200);
        assert_eq!(merged.f64_or("scale", 0.0), 2.0);
        assert!(merged.bool_or("verbose", false));
    }

    #[test]
    fn merge_rejects_unknown_keys_and_bad_values() {
        let defaults = Kwargs::new().with("max_steps", KwargValue::Int(500));
        let unknown = Kwargs::new().with("nope", KwargValue::Int(1));
        let err = Kwargs::merged_over(&defaults, &unknown, "Test-v0").unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
        assert!(err.to_string().contains("max_steps"), "{err}");
        let bad = Kwargs::new().with("max_steps", KwargValue::Str("abc".into()));
        assert!(Kwargs::merged_over(&defaults, &bad, "Test-v0").is_err());
    }

    #[test]
    fn merge_keeps_defaults_for_unset_keys() {
        let defaults = Kwargs::new()
            .with("max_steps", KwargValue::Int(500))
            .with("size", KwargValue::Int(5));
        let user = Kwargs::new().with("size", KwargValue::Int(3));
        let merged = Kwargs::merged_over(&defaults, &user, "Test-v0").unwrap();
        assert_eq!(merged.i64_or("max_steps", 0), 500);
        assert_eq!(merged.i64_or("size", 0), 3);
    }

    #[test]
    fn int_widens_to_float_but_not_the_reverse() {
        let defaults = Kwargs::new().with("scale", KwargValue::Float(1.0));
        let user = Kwargs::new().with("scale", KwargValue::Int(2));
        let merged = Kwargs::merged_over(&defaults, &user, "Test-v0").unwrap();
        assert_eq!(merged.f64_or("scale", 0.0), 2.0);

        let defaults = Kwargs::new().with("n", KwargValue::Int(1));
        let user = Kwargs::new().with("n", KwargValue::Float(2.5));
        assert!(Kwargs::merged_over(&defaults, &user, "Test-v0").is_err());
    }
}
