//! Fused batch stepping — the SoA (struct-of-arrays) kernel layer
//! behind the executors' hot loop.
//!
//! The scalar hot path steps every lane through a separate virtual
//! [`Env::step_into`](crate::core::env::Env::step_into) call on a
//! `Box<dyn Env>`: 32 CartPole lanes pay 32 dynamic dispatches through a
//! wrapper chain and 32 scattered state structs per batch tick.  A
//! [`BatchEnv`] is a whole group of same-type lanes stepped as one unit:
//! state lives in parallel `Vec<f32>` columns, the physics runs in one
//! tight loop over all lanes, and auto-reset happens inline — the
//! EnvPool/Jumanji fusion that turns the per-lane dispatch tax into a
//! single virtual call per *group* per batch.
//!
//! Three implementations cover every environment:
//!
//! * [`FusedBatch`]`<K>` — the fused kernel: a [`LaneKernel`] owns the
//!   SoA state columns (one per state variable), and the generic shell
//!   adds per-lane RNG streams, the registered `TimeLimit` (folded into
//!   a per-lane step counter instead of a wrapper layer), an optional
//!   trailing `NormalizeObs`/`RewardScale` (folded in as a per-lane
//!   [`AffineEpilogue`]) and inline auto-reset.  The classic-control envs each provide a kernel
//!   ([`CartPole::batch`](crate::envs::CartPole::batch),
//!   [`MountainCar::batch`](crate::envs::MountainCar::batch),
//!   [`Pendulum::batch`](crate::envs::Pendulum::batch),
//!   [`Acrobot::batch`](crate::envs::Acrobot::batch)) built on the same
//!   pure `dynamics` functions as the scalar envs, so fused trajectories
//!   are **bit-identical** to the scalar path (pinned by
//!   `rust/tests/batch_kernel.rs`).
//! * [`ScriptBatch`](crate::script::batch::ScriptBatch) — the fused
//!   kernel for `Script/*` lane groups: one register-bytecode VM
//!   ([`crate::script::vm`]) steps every lane's SoA state columns,
//!   with the same folded `TimeLimit`, affine epilogues and inline
//!   auto-reset as [`FusedBatch`]; bit-identical to the tree-walk
//!   scalar path (pinned by `rust/tests/script_vm.rs` and
//!   `rust/tests/batch_kernel.rs`).
//! * [`ScalarBatch`] — the universal fallback: wraps any existing
//!   [`Env`] lane list unchanged and replays the exact per-lane
//!   `step_into` + auto-reset loop the executors used before fusion.
//!   Wrapped lanes, flash/puzzle envs and `--kernel scalar` all run
//!   through it.
//!
//! The executors ([`crate::coordinator::vec_env::VecEnv`],
//! [`crate::coordinator::pool::EnvPool`],
//! [`crate::coordinator::pool::AsyncEnvPool`]) group contiguous lanes by
//! (env id, kwargs, wrapper chain) at construction and drive each group
//! through one [`BatchEnv::step_batch`] call; the registry advertises
//! fused builders per spec
//! ([`EnvSpec::with_batch`](crate::coordinator::registry::EnvSpec::with_batch)).
//!
//! ```
//! use cairl::core::batch::BatchEnv;
//! use cairl::core::env::Transition;
//! use cairl::core::spaces::Action;
//! use cairl::envs::CartPole;
//!
//! // A fused 4-lane CartPole group with the registered 500-step limit.
//! let mut batch = CartPole::batch(4, Some(500));
//! batch.seed(7); // lane k draws from the stream of a scalar env seeded 7 + k
//! let dim = batch.obs_dim();
//! let mut obs = vec![0.0f32; 4 * dim];
//! let mut transitions = vec![Transition::default(); 4];
//! batch.reset_batch(&mut obs, dim);
//! let actions = vec![Action::Discrete(1); 4];
//! batch.step_batch(&actions, &mut obs, dim, &mut transitions);
//! assert!(obs.iter().all(|v| v.is_finite()));
//! assert!(transitions.iter().all(|t| t.reward == 1.0));
//! ```

use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::telemetry::trace::{self, SpanKind};

/// A group of environment lanes stepped as one unit, with auto-reset
/// inline: a finished lane's transition reports the episode end exactly
/// once and its observation is the first observation of the next
/// episode (the executor convention).
///
/// Batch buffers are strided: lane `k` owns
/// `obs[k * stride .. (k + 1) * stride]`, writes its true observation
/// (length [`BatchEnv::lane_obs_dim`]) at the front and zeroes the tail
/// — `stride` is the pool-wide padded width, `>= obs_dim()`.
///
/// Implementations provide the per-lane primitives
/// ([`BatchEnv::reset_lane`] / [`BatchEnv::step_lane`], used by the
/// async executor's eager per-lane stepping); the batch loops are
/// default methods, so on a concrete type the whole loop monomorphises
/// with zero per-lane dispatch — one virtual call per group per batch.
pub trait BatchEnv {
    /// Number of lanes in the group.
    fn lanes(&self) -> usize;

    /// The widest lane's observation length (fused groups are uniform;
    /// [`ScalarBatch`] may hold mixed-width lanes).
    fn obs_dim(&self) -> usize;

    /// Lane `k`'s true (unpadded) observation length.
    fn lane_obs_dim(&self, k: usize) -> usize {
        let _ = k;
        self.obs_dim()
    }

    /// Lane 0's action space.
    fn action_space(&self) -> Space;

    /// Lane `k`'s action space.
    fn lane_action_space(&self, k: usize) -> Space {
        let _ = k;
        self.action_space()
    }

    /// Seed lane `k` with `first_seed + k` — the executor rule that
    /// makes a group starting at lane `L` of a pool seeded `s` hold the
    /// exact RNG streams of scalar lanes `s + L + k`.
    fn seed(&mut self, first_seed: u64);

    /// Start a new episode on lane `k`, writing the initial observation
    /// into `obs` (`obs.len() == self.lane_obs_dim(k)`).
    fn reset_lane(&mut self, k: usize, obs: &mut [f32]);

    /// Step lane `k`; finished lanes reset inline (the returned
    /// transition reports the episode end, `obs` the new episode).
    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition;

    /// Reset every lane into a strided batch buffer
    /// (`obs.len() == lanes * stride`), zeroing padded tails.
    fn reset_batch(&mut self, obs: &mut [f32], stride: usize) {
        let lanes = self.lanes();
        assert_eq!(obs.len(), lanes * stride);
        for k in 0..lanes {
            let slot = &mut obs[k * stride..(k + 1) * stride];
            let (lane_obs, tail) = slot.split_at_mut(self.lane_obs_dim(k));
            self.reset_lane(k, lane_obs);
            tail.fill(0.0);
        }
    }

    /// Step every lane with its action (`actions.len() ==
    /// transitions.len() == lanes`, `obs.len() == lanes * stride`);
    /// finished lanes auto-reset, padded tails are re-zeroed.
    fn step_batch(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        stride: usize,
        transitions: &mut [Transition],
    ) {
        let lanes = self.lanes();
        assert_eq!(actions.len(), lanes);
        assert_eq!(obs.len(), lanes * stride);
        assert_eq!(transitions.len(), lanes);
        for k in 0..lanes {
            let slot = &mut obs[k * stride..(k + 1) * stride];
            let (lane_obs, tail) = slot.split_at_mut(self.lane_obs_dim(k));
            transitions[k] = self.step_lane(k, &actions[k], lane_obs);
            tail.fill(0.0);
        }
    }
}

/// Boxed, thread-movable batch group — what the executors store.
pub type DynBatchEnv = Box<dyn BatchEnv + Send>;

/// The scalar fallback: any [`Env`] lane list behind the [`BatchEnv`]
/// interface, bit-identical to the executors' pre-fusion per-lane loop.
/// Lanes may have different observation widths (the group reports the
/// widest).
pub struct ScalarBatch<E: Env> {
    envs: Vec<E>,
    dims: Vec<usize>,
}

impl<E: Env> ScalarBatch<E> {
    /// Wrap a lane-ordered env list (unseeded; the executor calls
    /// [`BatchEnv::seed`]).
    pub fn from_envs(envs: Vec<E>) -> ScalarBatch<E> {
        assert!(!envs.is_empty(), "a batch group needs at least one lane");
        let dims = envs.iter().map(|e| e.obs_dim()).collect();
        ScalarBatch { envs, dims }
    }

    /// Direct lane access (debugging, tests).
    pub fn lane_mut(&mut self, k: usize) -> &mut E {
        &mut self.envs[k]
    }
}

impl<E: Env> BatchEnv for ScalarBatch<E> {
    fn lanes(&self) -> usize {
        self.envs.len()
    }

    fn obs_dim(&self) -> usize {
        self.dims.iter().copied().max().unwrap_or(0)
    }

    fn lane_obs_dim(&self, k: usize) -> usize {
        self.dims[k]
    }

    fn action_space(&self) -> Space {
        self.envs[0].action_space()
    }

    fn lane_action_space(&self, k: usize) -> Space {
        self.envs[k].action_space()
    }

    fn seed(&mut self, first_seed: u64) {
        for (k, env) in self.envs.iter_mut().enumerate() {
            env.seed(first_seed + k as u64);
        }
    }

    fn reset_lane(&mut self, k: usize, obs: &mut [f32]) {
        self.envs[k].reset_into(obs);
    }

    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let t = self.envs[k].step_into(action, obs);
        if t.done || t.truncated {
            self.envs[k].reset_into(obs);
        }
        t
    }
}

/// The wrapper chains a fused kernel can absorb, as data: an optional
/// [`TimeLimit`](crate::wrappers::TimeLimit) (folded into the step
/// counter) plus at most one **trailing affine epilogue**
/// ([`AffineEpilogue`]).  Produced by
/// [`WrapperSpec::as_fused_chain`](crate::wrappers::WrapperSpec::as_fused_chain);
/// consumed by [`FusedBatch::with_epilogue`].
#[derive(Clone, Debug, PartialEq)]
pub struct FusedChain {
    /// `Some(n)` reproduces `TimeLimit(env, n)` exactly.
    pub max_steps: Option<u32>,
    /// The trailing affine layer, if any.
    pub epilogue: Option<AffineEpilogue>,
}

/// A single trailing per-lane affine wrapper a fused kernel absorbs:
/// both [`NormalizeObs`](crate::wrappers::NormalizeObs) (a per-dimension
/// affine map of the observation) and [`RewardScale`]
/// (crate::wrappers::RewardScale) (an affine map of the reward) are
/// pure element-wise transforms, so folding them into the kernel's
/// epilogue reproduces the wrapper stack to the f32 operation (pinned
/// by `rust/tests/batch_kernel.rs`).  Longer chains fall back to
/// [`ScalarBatch`].
#[derive(Clone, Debug, PartialEq)]
pub enum AffineEpilogue {
    /// Rescale bounded observation dims to `[-1, 1]` from the kernel's
    /// observation-space bounds — `NormalizeObs` semantics.
    NormalizeObs,
    /// `r' = scale * r + shift` — `RewardScale` semantics.
    RewardScale { scale: f32, shift: f32 },
}

/// Per-dimension `(centre, half-range)` affine factors precomputed from
/// a [`Space`], applied as `(o - centre) / half` — **the** bounded-dim
/// rescaling arithmetic, shared by the
/// [`NormalizeObs`](crate::wrappers::NormalizeObs) wrapper and the
/// fused epilogue so the two can never drift apart (unbounded or
/// degenerate dims pass through).
#[derive(Clone, Debug)]
pub struct ObsAffine {
    scale: Vec<Option<(f32, f32)>>,
}

impl ObsAffine {
    /// Derive the factors from a space's bounds.
    pub fn from_space(space: &Space) -> ObsAffine {
        let scale = match space {
            Space::Box { low, high, .. } => low
                .iter()
                .zip(high)
                .map(|(&lo, &hi)| {
                    if lo <= f32::MIN || hi >= f32::MAX || hi <= lo {
                        None
                    } else {
                        Some(((lo + hi) * 0.5, (hi - lo) * 0.5))
                    }
                })
                .collect(),
            Space::Discrete { .. } => vec![None],
        };
        ObsAffine { scale }
    }

    /// Rescale every bounded dimension in place.
    #[inline]
    pub fn apply(&self, obs: &mut [f32]) {
        for (o, s) in obs.iter_mut().zip(&self.scale) {
            if let Some((centre, half)) = s {
                *o = (*o - centre) / half;
            }
        }
    }

    /// Whether dimension `i` is rescaled (bounded) — the space-reporting
    /// half of `NormalizeObs` keys off this.
    pub fn is_bounded(&self, i: usize) -> bool {
        self.scale.get(i).is_some_and(|s| s.is_some())
    }
}

/// The per-env half of a fused kernel: SoA state columns plus the pure
/// single-lane physics, with the RNG passed in so [`FusedBatch`] owns
/// the per-lane streams.  Implementations must reproduce the scalar
/// env's `reset_into`/`step_into` to the f32 operation — they share the
/// same `dynamics` functions, so this holds by construction.
pub trait LaneKernel {
    /// Observation length (uniform across the group).
    fn obs_dim(&self) -> usize;

    /// The group's observation space — must match the scalar env's
    /// bounds exactly (the fused `NormalizeObs` epilogue derives its
    /// affine factors from it).
    fn observation_space(&self) -> Space;

    /// The group's action space.
    fn action_space(&self) -> Space;

    /// The PCG stream id the scalar env seeds its RNG with — fused
    /// lanes must draw from the identical streams.
    fn rng_stream(&self) -> u64;

    /// Number of lanes (the column length).
    fn lanes(&self) -> usize;

    /// Draw lane `k`'s initial state from `rng` (the exact draws of the
    /// scalar `reset_into`) and write the observation.
    fn reset_lane(&mut self, k: usize, rng: &mut Pcg32, obs: &mut [f32]);

    /// Advance lane `k` one step and write the observation; returns the
    /// raw transition (time limits are [`FusedBatch`]'s job).
    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition;
}

/// The generic fused-group shell: a [`LaneKernel`] plus per-lane RNG
/// streams, the registered time limit (fused into a step counter — no
/// wrapper layer, no extra dispatch), an optional trailing
/// [`AffineEpilogue`] and inline auto-reset.
pub struct FusedBatch<K: LaneKernel> {
    kernel: K,
    rngs: Vec<Pcg32>,
    elapsed: Vec<u32>,
    /// `Some(n)` reproduces `TimeLimit(env, n)` exactly; `None` runs
    /// the bare dynamics.
    max_steps: Option<u32>,
    /// Fused `NormalizeObs`: applied to every observation write (reset
    /// and step, auto-reset included), exactly like the outermost
    /// wrapper would.
    obs_affine: Option<ObsAffine>,
    /// Fused `RewardScale`: `(scale, shift)` applied to every step
    /// reward after the time-limit flags are set (the wrapper sits
    /// outside `TimeLimit`, which never touches rewards — the two
    /// orders are arithmetically identical).
    reward_affine: Option<(f32, f32)>,
}

impl<K: LaneKernel> FusedBatch<K> {
    /// Wrap a kernel; lanes start on the unseeded stream (seed 0, like
    /// a scalar env's `new()`) until [`BatchEnv::seed`] is called.
    pub fn new(kernel: K, max_steps: Option<u32>) -> FusedBatch<K> {
        let lanes = kernel.lanes();
        assert!(lanes > 0, "a fused batch needs at least one lane");
        let stream = kernel.rng_stream();
        FusedBatch {
            kernel,
            rngs: (0..lanes).map(|_| Pcg32::new(0, stream)).collect(),
            elapsed: vec![0; lanes],
            max_steps,
            obs_affine: None,
            reward_affine: None,
        }
    }

    /// Fold a trailing affine wrapper into the group (builder style):
    /// `NormalizeObs` precomputes its per-dimension factors from the
    /// kernel's observation space, `RewardScale` records its `(scale,
    /// shift)`.  `None` leaves the batch unchanged.
    pub fn with_epilogue(mut self, epilogue: Option<&AffineEpilogue>) -> FusedBatch<K> {
        match epilogue {
            None => {}
            Some(AffineEpilogue::NormalizeObs) => {
                self.obs_affine = Some(ObsAffine::from_space(&self.kernel.observation_space()));
            }
            Some(AffineEpilogue::RewardScale { scale, shift }) => {
                self.reward_affine = Some((*scale, *shift));
            }
        }
        self
    }

    /// The fused time limit (`None` = no limit).
    pub fn max_steps(&self) -> Option<u32> {
        self.max_steps
    }

    /// One lane step *without* the observation epilogue — the shared
    /// body of [`BatchEnv::step_lane`] (which applies the affine
    /// inline) and the two-pass [`BatchEnv::step_batch`] override
    /// (which applies it to the whole group afterwards).  The affine is
    /// a pure element-wise map of the output buffer, so the two orders
    /// are bit-identical.
    fn step_lane_raw(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let mut t = self.kernel.step_lane(k, action, obs);
        self.elapsed[k] += 1;
        if let Some(max) = self.max_steps {
            // TimeLimit semantics: truncation is distinct from (and
            // masked by) environment termination.
            if self.elapsed[k] >= max && !t.done {
                t.truncated = true;
            }
        }
        if let Some((scale, shift)) = self.reward_affine {
            t.reward = t.reward * scale + shift;
        }
        if t.done || t.truncated {
            self.kernel.reset_lane(k, &mut self.rngs[k], obs);
            self.elapsed[k] = 0;
        }
        t
    }
}

impl<K: LaneKernel> BatchEnv for FusedBatch<K> {
    fn lanes(&self) -> usize {
        self.kernel.lanes()
    }

    fn obs_dim(&self) -> usize {
        self.kernel.obs_dim()
    }

    fn action_space(&self) -> Space {
        self.kernel.action_space()
    }

    fn seed(&mut self, first_seed: u64) {
        let stream = self.kernel.rng_stream();
        for (k, rng) in self.rngs.iter_mut().enumerate() {
            *rng = Pcg32::new(first_seed + k as u64, stream);
        }
    }

    fn reset_lane(&mut self, k: usize, obs: &mut [f32]) {
        self.kernel.reset_lane(k, &mut self.rngs[k], obs);
        self.elapsed[k] = 0;
        if let Some(affine) = &self.obs_affine {
            affine.apply(obs);
        }
    }

    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let t = self.step_lane_raw(k, action, obs);
        // One application covers both the step observation and the
        // auto-reset observation — exactly what the outermost
        // NormalizeObs wrapper sees in the scalar path.
        if let Some(affine) = &self.obs_affine {
            affine.apply(obs);
        }
        t
    }

    /// Two-pass batch step: the dynamics loop over all lanes, then one
    /// epilogue pass applying the fused `NormalizeObs` affine to the
    /// whole group.  The affine is a pure element-wise map of the
    /// output buffer (it never touches kernel state or RNG streams), so
    /// this is bit-identical to the per-lane order — and the epilogue
    /// pass is a traceable unit: it records an `epilogue` span under
    /// the thread's current trace context when tracing is on.
    fn step_batch(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        stride: usize,
        transitions: &mut [Transition],
    ) {
        let lanes = self.lanes();
        assert_eq!(actions.len(), lanes);
        assert_eq!(obs.len(), lanes * stride);
        assert_eq!(transitions.len(), lanes);
        let dim = self.kernel.obs_dim();
        for k in 0..lanes {
            let slot = &mut obs[k * stride..(k + 1) * stride];
            let (lane_obs, tail) = slot.split_at_mut(dim);
            transitions[k] = self.step_lane_raw(k, &actions[k], lane_obs);
            tail.fill(0.0);
        }
        if let Some(affine) = &self.obs_affine {
            let (trace_id, parent) = if trace::enabled() { trace::current() } else { (0, 0) };
            trace::with_span(SpanKind::Epilogue, trace_id, parent, 0, trace::SHARD_LOCAL, || {
                for k in 0..lanes {
                    affine.apply(&mut obs[k * stride..k * stride + dim]);
                }
            });
        }
    }
}

/// Free-running uniform-random rollout over one group — the worker-side
/// body of `EnvPool::random_rollout`, reproducing the scalar version
/// exactly: lane `first_lane + k` draws actions from the dedicated
/// stream `Pcg32::new(base_seed ^ 0xabcd, first_lane + k + 1)`, resets
/// before starting and auto-resets inline.  Returns the episode-end
/// count (steps are `lanes * steps_per_lane` by construction).
pub fn batch_random_steps(
    batch: &mut dyn BatchEnv,
    steps_per_lane: u64,
    base_seed: u64,
    first_lane: usize,
) -> u64 {
    let mut episodes = 0u64;
    let mut obs = vec![0.0f32; batch.obs_dim()];
    for k in 0..batch.lanes() {
        let lane = first_lane + k;
        let mut rng = Pcg32::new(base_seed ^ 0xabcd, lane as u64 + 1);
        let space = batch.lane_action_space(k);
        let lane_obs = &mut obs[..batch.lane_obs_dim(k)];
        batch.reset_lane(k, lane_obs);
        for _ in 0..steps_per_lane {
            let a = space.sample(&mut rng);
            let t = batch.step_lane(k, &a, lane_obs);
            if t.done || t.truncated {
                episodes += 1;
            }
        }
    }
    episodes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{CartPole, MountainCar};
    use crate::wrappers::TimeLimit;

    /// The load-bearing property: a fused CartPole group is bit-identical
    /// to per-lane `TimeLimit<CartPole>` scalars with the same seeds,
    /// auto-reset included.
    #[test]
    fn fused_cartpole_matches_scalar_lanes_bitwise() {
        let lanes = 3;
        let limit = 20;
        let mut fused = CartPole::batch(lanes, Some(limit));
        fused.seed(41);
        let mut scalars: Vec<_> = (0..lanes)
            .map(|k| {
                let mut e = TimeLimit::new(CartPole::new(), limit);
                e.seed(41 + k as u64);
                e
            })
            .collect();

        let dim = fused.obs_dim();
        let mut obs = vec![0.0f32; lanes * dim];
        let mut tr = vec![Transition::default(); lanes];
        fused.reset_batch(&mut obs, dim);
        let mut ref_obs = vec![0.0f32; dim];
        for (k, e) in scalars.iter_mut().enumerate() {
            e.reset_into(&mut ref_obs);
            assert_eq!(&obs[k * dim..(k + 1) * dim], &ref_obs[..]);
        }
        for step in 0..200 {
            let actions: Vec<Action> =
                (0..lanes).map(|k| Action::Discrete((step + k) % 2)).collect();
            fused.step_batch(&actions, &mut obs, dim, &mut tr);
            for (k, e) in scalars.iter_mut().enumerate() {
                let t = e.step_into(&actions[k], &mut ref_obs);
                if t.done || t.truncated {
                    e.reset_into(&mut ref_obs);
                }
                assert_eq!(tr[k], t, "lane {k} step {step}");
                assert_eq!(&obs[k * dim..(k + 1) * dim], &ref_obs[..], "lane {k} step {step}");
            }
        }
        // The 20-step cap must have fired somewhere in 200 steps.
    }

    #[test]
    fn fused_time_limit_truncates_like_the_wrapper() {
        // MountainCar under random-ish actions never terminates, so every
        // episode end in a capped batch is a truncation.
        let mut fused = MountainCar::batch(2, Some(5));
        fused.seed(3);
        let dim = fused.obs_dim();
        let mut obs = vec![0.0f32; 2 * dim];
        let mut tr = vec![Transition::default(); 2];
        fused.reset_batch(&mut obs, dim);
        let mut ends = 0;
        for _ in 0..20 {
            let actions = vec![Action::Discrete(1); 2];
            fused.step_batch(&actions, &mut obs, dim, &mut tr);
            for t in &tr {
                if t.truncated {
                    assert!(!t.done, "truncation is not termination");
                    ends += 1;
                }
            }
        }
        assert_eq!(ends, 8, "5-step cap over 20 steps x 2 lanes");
    }

    #[test]
    fn scalar_batch_pads_and_auto_resets() {
        let envs = vec![
            TimeLimit::new(CartPole::new(), 4),
            TimeLimit::new(CartPole::new(), 4),
        ];
        let mut batch = ScalarBatch::from_envs(envs);
        batch.seed(0);
        assert_eq!(batch.lanes(), 2);
        assert_eq!(batch.obs_dim(), 4);
        let stride = 6; // padded wider than the lane width
        let mut obs = vec![f32::NAN; 2 * stride];
        let mut tr = vec![Transition::default(); 2];
        batch.reset_batch(&mut obs, stride);
        assert_eq!(&obs[4..6], &[0.0, 0.0], "tail must be zeroed");
        let mut ends = 0;
        for _ in 0..12 {
            let actions = vec![Action::Discrete(0); 2];
            batch.step_batch(&actions, &mut obs, stride, &mut tr);
            assert_eq!(&obs[4..6], &[0.0, 0.0]);
            ends += tr.iter().filter(|t| t.done || t.truncated).count();
        }
        assert!(ends >= 4, "4-step cap over 12 steps x 2 lanes: {ends}");
    }

    #[test]
    fn batch_random_steps_counts_are_kernel_invariant() {
        // Fused and scalar groups with the same seeds tally the same
        // episode ends under the dedicated per-lane action streams.
        let mut fused = CartPole::batch(4, Some(40));
        fused.seed(9);
        let mut scalar = ScalarBatch::from_envs(
            (0..4).map(|_| TimeLimit::new(CartPole::new(), 40)).collect(),
        );
        scalar.seed(9);
        let a = batch_random_steps(&mut fused, 500, 9, 0);
        let b = batch_random_steps(&mut scalar, 500, 9, 0);
        assert_eq!(a, b);
        assert!(a > 10, "40-step-capped cartpole over 500 steps/lane: {a}");
    }

    #[test]
    fn affine_epilogues_match_the_wrapper_stack_bitwise() {
        use crate::wrappers::{NormalizeObs, RewardScale};
        // NormalizeObs outside TimeLimit(15) on MountainCar: bounded
        // dims rescale on reset, step and auto-reset alike.
        let lanes = 2;
        let mut fused = MountainCar::batch(lanes, Some(15))
            .with_epilogue(Some(&AffineEpilogue::NormalizeObs));
        fused.seed(11);
        let mut scalars: Vec<_> = (0..lanes)
            .map(|k| {
                let mut e = NormalizeObs::new(TimeLimit::new(MountainCar::new(), 15));
                e.seed(11 + k as u64);
                e
            })
            .collect();
        let dim = fused.obs_dim();
        let mut obs = vec![0.0f32; lanes * dim];
        let mut tr = vec![Transition::default(); lanes];
        let mut ref_obs = vec![0.0f32; dim];
        fused.reset_batch(&mut obs, dim);
        for (k, e) in scalars.iter_mut().enumerate() {
            e.reset_into(&mut ref_obs);
            assert_eq!(&obs[k * dim..(k + 1) * dim], &ref_obs[..]);
        }
        for step in 0..60 {
            let actions: Vec<Action> =
                (0..lanes).map(|k| Action::Discrete((step + k) % 3)).collect();
            fused.step_batch(&actions, &mut obs, dim, &mut tr);
            for (k, e) in scalars.iter_mut().enumerate() {
                let t = e.step_into(&actions[k], &mut ref_obs);
                if t.done || t.truncated {
                    e.reset_into(&mut ref_obs);
                }
                assert_eq!(tr[k], t, "lane {k} step {step}");
                assert_eq!(&obs[k * dim..(k + 1) * dim], &ref_obs[..], "lane {k} step {step}");
            }
        }

        // RewardScale outside TimeLimit(10) on CartPole: every reward
        // (terminating steps included) maps through scale/shift.
        let mut fused = CartPole::batch(1, Some(10)).with_epilogue(Some(
            &AffineEpilogue::RewardScale { scale: 2.0, shift: -0.5 },
        ));
        fused.seed(4);
        let mut scalar = RewardScale::new(TimeLimit::new(CartPole::new(), 10), 2.0, -0.5);
        scalar.seed(4);
        let dim = fused.obs_dim();
        let mut obs = vec![0.0f32; dim];
        let mut tr = vec![Transition::default(); 1];
        let mut ref_obs = vec![0.0f32; dim];
        fused.reset_batch(&mut obs, dim);
        scalar.reset_into(&mut ref_obs);
        assert_eq!(obs, ref_obs);
        for step in 0..40 {
            let actions = vec![Action::Discrete(step % 2)];
            fused.step_batch(&actions, &mut obs, dim, &mut tr);
            let t = scalar.step_into(&actions[0], &mut ref_obs);
            if t.done || t.truncated {
                scalar.reset_into(&mut ref_obs);
            }
            assert_eq!(tr[0], t, "step {step}");
            assert_eq!(obs, ref_obs, "step {step}");
        }
    }

    #[test]
    fn seed_gives_each_lane_its_own_stream() {
        let mut batch = CartPole::batch(2, None);
        batch.seed(5);
        let dim = batch.obs_dim();
        let mut obs = vec![0.0f32; 2 * dim];
        batch.reset_batch(&mut obs, dim);
        assert_ne!(&obs[..dim], &obs[dim..], "lanes must differ");
        // Re-seeding reproduces the exact draws.
        let first = obs.clone();
        batch.seed(5);
        batch.reset_batch(&mut obs, dim);
        assert_eq!(first, obs);
    }
}
