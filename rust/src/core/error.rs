//! Toolkit-wide error type.
//!
//! One small enum instead of a boxed-trait soup: the hot path never
//! constructs errors, so ergonomics beat extensibility here.

use std::fmt;

/// All the ways a CaiRL call can fail.
#[derive(Debug)]
pub enum CairlError {
    /// `make()` was called with an id that no runner registered.
    UnknownEnv(String),
    /// An action outside the environment's action space.
    InvalidAction(String),
    /// Artifact loading / PJRT failures (runtime module).
    Runtime(String),
    /// Script runner: lexer/parser/interpreter errors with location.
    Script(String),
    /// Flash runner: assembler or VM trap.
    Vm(String),
    /// Configuration file problems.
    Config(String),
    /// Shard transport/protocol failures (frame corruption, handshake
    /// mismatches, a remote shard replying with an error).
    Shard(String),
    /// A shard daemon exists but cannot take the work right now (lane
    /// budget exhausted, `Busy` retries spent).  Distinct from
    /// [`CairlError::Shard`] so callers can back off instead of failing.
    Unavailable(String),
    /// Trajectory-tape problems: corruption, truncation, a replay
    /// against a mismatched executor (telemetry module).
    Tape(String),
    /// A configured read/write deadline elapsed before the peer
    /// produced (or accepted) a frame — the bounded-window signal that
    /// a shard is frozen rather than merely slow.  Recoverable: the
    /// shard client classifies it like a lost connection and fails
    /// over.
    DeadlineExceeded(String),
    /// Underlying I/O.
    Io(std::io::Error),
}

impl fmt::Display for CairlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CairlError::UnknownEnv(id) => {
                write!(f, "unknown environment id {id:?} (see `cairl list-envs`)")
            }
            CairlError::InvalidAction(m) => write!(f, "invalid action: {m}"),
            CairlError::Runtime(m) => write!(f, "runtime error: {m}"),
            CairlError::Script(m) => write!(f, "script error: {m}"),
            CairlError::Vm(m) => write!(f, "vm trap: {m}"),
            CairlError::Config(m) => write!(f, "config error: {m}"),
            CairlError::Shard(m) => write!(f, "shard error: {m}"),
            CairlError::Unavailable(m) => write!(f, "shard unavailable: {m}"),
            CairlError::Tape(m) => write!(f, "tape error: {m}"),
            CairlError::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
            CairlError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for CairlError {}

impl From<std::io::Error> for CairlError {
    fn from(e: std::io::Error) -> Self {
        CairlError::Io(e)
    }
}

/// Toolkit-wide result alias.
pub type Result<T> = std::result::Result<T, CairlError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_mentions_env_id() {
        let e = CairlError::UnknownEnv("NoSuchEnv-v0".into());
        assert!(e.to_string().contains("NoSuchEnv-v0"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: CairlError = io.into();
        assert!(matches!(e, CairlError::Io(_)));
    }
}
