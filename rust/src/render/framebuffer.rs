//! Grayscale f32 framebuffer — the pixel store every renderer writes into.
//!
//! Row-major, intensity in `[0, 1]`.  The buffer is caller-owned and
//! reused across frames (the paper's no-copy discipline: the agent reads
//! the same memory the rasteriser wrote, no GPU readback, no per-frame
//! allocation).

/// A row-major grayscale framebuffer.
#[derive(Clone, Debug, PartialEq)]
pub struct Framebuffer {
    width: usize,
    height: usize,
    data: Vec<f32>,
}

impl Framebuffer {
    /// Allocate a `width x height` buffer cleared to 0.
    pub fn new(width: usize, height: usize) -> Self {
        Framebuffer {
            width,
            height,
            data: vec![0.0; width * height],
        }
    }

    /// Standard agent-facing resolution used across the toolkit (matches
    /// the L1 render kernel's 64x64).
    pub fn standard() -> Self {
        Framebuffer::new(64, 64)
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn height(&self) -> usize {
        self.height
    }

    /// Flat pixel slice (row-major), e.g. to feed the DQN as observations.
    #[inline]
    pub fn pixels(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat pixel slice for rasterisers.
    #[inline]
    pub fn pixels_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// One row as a contiguous slice — the rasteriser's unit of work
    /// (contiguous fills auto-vectorise).
    #[inline]
    pub fn row_mut(&mut self, y: usize) -> &mut [f32] {
        let w = self.width;
        &mut self.data[y * w..(y + 1) * w]
    }

    /// Read one pixel (bounds-checked; test/debug use).
    #[inline]
    pub fn get(&self, x: usize, y: usize) -> f32 {
        self.data[y * self.width + x]
    }

    /// Write one pixel, ignoring out-of-bounds coordinates (clip-safe for
    /// shape edges).
    #[inline]
    pub fn put(&mut self, x: i32, y: i32, v: f32) {
        if x >= 0 && (x as usize) < self.width && y >= 0 && (y as usize) < self.height {
            self.data[y as usize * self.width + x as usize] = v;
        }
    }

    /// Clear the whole buffer to an intensity.
    pub fn clear(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Sum of all intensities (golden tests against the L1 kernel).
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Maximum intensity.
    pub fn max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b))
    }

    /// Downsample into `out` (area averaging), e.g. 256x256 -> 64x64 for
    /// agent observations.  `out` dimensions must divide `self`'s.
    pub fn downsample_into(&self, out: &mut Framebuffer) {
        let fx = self.width / out.width;
        let fy = self.height / out.height;
        assert!(fx >= 1 && fy >= 1);
        assert_eq!(fx * out.width, self.width);
        assert_eq!(fy * out.height, self.height);
        let norm = 1.0 / (fx * fy) as f32;
        for oy in 0..out.height {
            for ox in 0..out.width {
                let mut acc = 0.0;
                for sy in 0..fy {
                    let row = (oy * fy + sy) * self.width + ox * fx;
                    acc += self.data[row..row + fx].iter().sum::<f32>();
                }
                out.data[oy * out.width + ox] = acc * norm;
            }
        }
    }

    /// Render as ASCII art (debugging / CLI `--render-ascii`).
    pub fn to_ascii(&self) -> String {
        const RAMP: &[u8] = b" .:-=+*#%@";
        let mut s = String::with_capacity((self.width + 1) * self.height);
        for y in 0..self.height {
            for x in 0..self.width {
                let v = self.get(x, y).clamp(0.0, 1.0);
                let i = (v * (RAMP.len() - 1) as f32).round() as usize;
                s.push(RAMP[i] as char);
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_is_black() {
        let fb = Framebuffer::new(8, 4);
        assert_eq!(fb.width(), 8);
        assert_eq!(fb.height(), 4);
        assert_eq!(fb.sum(), 0.0);
        assert_eq!(fb.pixels().len(), 32);
    }

    #[test]
    fn put_get_roundtrip_and_clipping() {
        let mut fb = Framebuffer::new(4, 4);
        fb.put(1, 2, 0.5);
        assert_eq!(fb.get(1, 2), 0.5);
        fb.put(-1, 0, 1.0); // silently clipped
        fb.put(4, 0, 1.0);
        fb.put(0, 4, 1.0);
        assert_eq!(fb.sum(), 0.5);
    }

    #[test]
    fn clear_sets_everything() {
        let mut fb = Framebuffer::new(3, 3);
        fb.clear(0.25);
        assert_eq!(fb.sum(), 0.25 * 9.0);
        assert_eq!(fb.max(), 0.25);
    }

    #[test]
    fn downsample_averages_blocks() {
        let mut big = Framebuffer::new(4, 4);
        // Top-left 2x2 block all ones.
        for y in 0..2 {
            for x in 0..2 {
                big.put(x, y, 1.0);
            }
        }
        let mut small = Framebuffer::new(2, 2);
        big.downsample_into(&mut small);
        assert_eq!(small.get(0, 0), 1.0);
        assert_eq!(small.get(1, 0), 0.0);
        assert_eq!(small.get(0, 1), 0.0);
        assert_eq!(small.get(1, 1), 0.0);
    }

    #[test]
    fn ascii_has_one_row_per_line() {
        let fb = Framebuffer::new(5, 3);
        let art = fb.to_ascii();
        assert_eq!(art.lines().count(), 3);
        assert!(art.lines().all(|l| l.len() == 5));
    }
}
