//! Per-environment scene painters (software rendering).
//!
//! `paint_cartpole` reproduces the geometry of the L1 Pallas kernel
//! (`python/compile/kernels/render.py`) *exactly* — same constants, same
//! inclusive mask comparisons, same paint order (track, cart, pole) — so
//! the two implementations are golden-tested against each other through
//! the artifact manifest (`frame0_sum`).
//!
//! The other painters follow the same style: distinct intensities per
//! element, all geometry derived from the environment's public state.

use crate::render::raster;
use crate::render::Framebuffer;

// Constants shared with python/compile/kernels/render.py.
pub const CART_W: f32 = 8.0;
pub const CART_H: f32 = 4.0;
pub const CART_Y: f32 = 48.0;
pub const POLE_LEN: f32 = 20.0;
pub const POLE_HALF_THICK: f32 = 1.0;
pub const TRACK_I: f32 = 0.3;
pub const CART_I: f32 = 0.6;
pub const POLE_I: f32 = 1.0;
const X_THRESHOLD: f32 = 2.4;

/// CartPole scene: track line, cart rectangle, pole segment.
///
/// `x` is the world cart position, `theta` the pole angle (0 = upright).
pub fn paint_cartpole(fb: &mut Framebuffer, x: f32, theta: f32) {
    let w = fb.width() as f32;
    fb.clear(0.0);

    let cx = (x / X_THRESHOLD) * (w / 2.0 - CART_W) + w / 2.0;
    let cy = CART_Y;

    // Track line at row CART_Y + CART_H/2 (kernel: rows == 50).
    raster::hline(fb, (CART_Y + CART_H / 2.0) as i32, TRACK_I);

    // Cart: |col - cx| <= CART_W/2 and |row - cy| <= CART_H/2, inclusive —
    // compute the integer span satisfying the float comparison.
    let x0 = (cx - CART_W / 2.0).ceil() as i32;
    let x1 = (cx + CART_W / 2.0).floor() as i32;
    let y0 = (cy - CART_H / 2.0).ceil() as i32;
    let y1 = (cy + CART_H / 2.0).floor() as i32;
    raster::fill_rect(fb, x0, y0, x1 + 1, y1 + 1, CART_I);

    // Pole: distance-to-segment mask, identical formula to the kernel.
    let dx = theta.sin();
    let dy = -theta.cos();
    let fx1 = cx + POLE_LEN * dx;
    let fy1 = cy + POLE_LEN * dy;
    let pad = POLE_HALF_THICK + 1.0;
    let bx0 = ((cx.min(fx1) - pad).floor() as i32).max(0);
    let bx1 = ((cx.max(fx1) + pad).ceil() as i32).min(fb.width() as i32 - 1);
    let by0 = ((cy.min(fy1) - pad).floor() as i32).max(0);
    let by1 = ((cy.max(fy1) + pad).ceil() as i32).min(fb.height() as i32 - 1);
    let ht2 = POLE_HALF_THICK * POLE_HALF_THICK;
    for yy in by0..=by1 {
        let row = fb.row_mut(yy as usize);
        let py = yy as f32 - cy;
        for xx in bx0..=bx1 {
            let px = xx as f32 - cx;
            let t = (px * dx + py * dy).clamp(0.0, POLE_LEN);
            let ex = px - t * dx;
            let ey = py - t * dy;
            if ex * ex + ey * ey <= ht2 {
                row[xx as usize] = POLE_I;
            }
        }
    }
}

/// MountainCar scene: sinusoidal hill, car disc, goal flag.
pub fn paint_mountaincar(fb: &mut Framebuffer, pos: f32, _vel: f32) {
    let w = fb.width() as f32;
    let h = fb.height() as f32;
    fb.clear(0.0);
    let to_px = |p: f32| (p + 1.2) / 1.8 * (w - 1.0);
    let hill_y = |p: f32| h * 0.75 - (3.0 * p).sin() * h * 0.22;

    // Hill as a polyline sampled once per column.
    let mut pts = Vec::with_capacity(fb.width());
    for i in 0..fb.width() {
        let p = -1.2 + 1.8 * i as f32 / (w - 1.0);
        pts.push((i as f32, hill_y(p)));
    }
    raster::draw_polyline(fb, &pts, 0.6, 0.3);

    // Goal flag at pos = 0.5.
    let gx = to_px(0.5);
    let gy = hill_y(0.5);
    raster::draw_line(fb, gx, gy, gx, gy - 10.0, 0.6, 0.8);
    raster::fill_rect(fb, gx as i32, (gy - 10.0) as i32, gx as i32 + 4, (gy - 7.0) as i32, 0.8);

    // Car.
    raster::fill_disc(fb, to_px(pos), hill_y(pos) - 2.5, 2.5, 1.0);
}

/// Acrobot scene: two links hanging from the frame centre.
pub fn paint_acrobot(fb: &mut Framebuffer, theta1: f32, theta2: f32) {
    let w = fb.width() as f32;
    let h = fb.height() as f32;
    fb.clear(0.0);
    let cx = w / 2.0;
    let cy = h / 2.0;
    let scale = h * 0.22; // each link ~22% of frame height

    // Gym convention: theta1 measured from the downward vertical.
    let x1 = cx + scale * theta1.sin();
    let y1 = cy + scale * theta1.cos();
    let x2 = x1 + scale * (theta1 + theta2).sin();
    let y2 = y1 + scale * (theta1 + theta2).cos();

    // Target height line (the paper's classic visualisation).
    raster::hline(fb, (cy - scale) as i32, 0.3);
    raster::draw_line(fb, cx, cy, x1, y1, 1.2, 0.7);
    raster::draw_line(fb, x1, y1, x2, y2, 1.2, 1.0);
    raster::fill_disc(fb, cx, cy, 1.6, 0.5);
    raster::fill_disc(fb, x1, y1, 1.6, 0.5);
}

/// Pendulum scene: rod from centre, bob at the tip, torque unused.
pub fn paint_pendulum(fb: &mut Framebuffer, theta: f32) {
    let w = fb.width() as f32;
    let h = fb.height() as f32;
    fb.clear(0.0);
    let cx = w / 2.0;
    let cy = h / 2.0;
    let len = h * 0.35;
    // Gym convention: theta = 0 is upright.
    let tx = cx + len * theta.sin();
    let ty = cy - len * theta.cos();
    raster::draw_line(fb, cx, cy, tx, ty, 1.5, 1.0);
    raster::fill_disc(fb, tx, ty, 3.0, 0.8);
    raster::fill_disc(fb, cx, cy, 1.5, 0.4);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartpole_centre_geometry_matches_kernel_spec() {
        let mut fb = Framebuffer::standard();
        paint_cartpole(&mut fb, 0.0, 0.0);
        // Pole pixel straight above the cart centre.
        assert_eq!(fb.get(32, 38), POLE_I);
        // Cart body pixel outside the pole's thickness.
        assert_eq!(fb.get(35, 48), CART_I);
        // Track line far from the cart.
        assert_eq!(fb.get(2, 50), TRACK_I);
        // Background corner.
        assert_eq!(fb.get(0, 0), 0.0);
    }

    #[test]
    fn cartpole_cart_tracks_x() {
        let mut l = Framebuffer::standard();
        let mut r = Framebuffer::standard();
        paint_cartpole(&mut l, -1.2, 0.0);
        paint_cartpole(&mut r, 1.2, 0.0);
        let centroid = |fb: &Framebuffer| {
            let mut s = 0.0;
            let mut n = 0.0;
            for y in 0..fb.height() {
                for x in 0..fb.width() {
                    if fb.get(x, y) == CART_I {
                        s += x as f32;
                        n += 1.0;
                    }
                }
            }
            s / n
        };
        assert!(centroid(&r) > centroid(&l) + 20.0);
    }

    #[test]
    fn cartpole_pole_tilts_with_theta() {
        let mut fb = Framebuffer::standard();
        paint_cartpole(&mut fb, 0.0, 0.35);
        // Tilted right: a pole pixel right of centre above the cart.
        let found = (33..45).any(|x| fb.get(x, 34) == POLE_I || fb.get(x, 40) == POLE_I);
        assert!(found);
    }

    #[test]
    fn mountaincar_scene_nonempty_and_bounded() {
        let mut fb = Framebuffer::standard();
        paint_mountaincar(&mut fb, -0.5, 0.0);
        assert!(fb.sum() > 10.0);
        assert!(fb.max() <= 1.0);
    }

    #[test]
    fn acrobot_links_move() {
        let mut a = Framebuffer::standard();
        let mut b = Framebuffer::standard();
        paint_acrobot(&mut a, 0.0, 0.0);
        paint_acrobot(&mut b, 1.2, 0.8);
        assert_ne!(a.pixels(), b.pixels());
        assert!(a.sum() > 10.0);
    }

    #[test]
    fn pendulum_bob_follows_theta() {
        let mut up = Framebuffer::standard();
        let mut down = Framebuffer::standard();
        paint_pendulum(&mut up, 0.0);
        paint_pendulum(&mut down, std::f32::consts::PI);
        // Upright: bright pixels above centre row. Down: below.
        let upper_sum: f32 = (0..28)
            .map(|y| (0..64).map(|x| up.get(x, y)).sum::<f32>())
            .sum();
        let lower_sum: f32 = (36..64)
            .map(|y| (0..64).map(|x| down.get(x, y)).sum::<f32>())
            .sum();
        assert!(upper_sum > 1.0);
        assert!(lower_sum > 1.0);
    }
}
