//! Simulated hardware-rendering path — the paper's Fig.-1 baseline.
//!
//! This image has no GPU, so the OpenGL render + `glReadPixels` readback
//! pipeline the paper benchmarks against is modelled as a calibrated cost
//! model (DESIGN.md §Substitutions).  The paper's own analysis (§II-B)
//! attributes the hardware path's loss to exactly three costs, which we
//! reproduce:
//!
//! 1. **Draw/dispatch overhead** — driver command submission per frame.
//! 2. **Pipeline sync stall** — without pixel-buffer objects,
//!    `glReadPixels` blocks until the GPU drains; the dominant term.
//! 3. **Transfer** — framebuffer bytes over the bus at PCIe-class
//!    bandwidth.
//!
//! The stall is implemented as a busy-wait so that the energy tracker
//! (paper Table II) sees the same CPU-occupancy behaviour a real blocked
//! `glReadPixels` exhibits (the GL driver spins).  Constants are
//! calibrated so that at Fig.-1 scale (64x64 frames, classic control) the
//! software:hardware ratio lands in the paper's reported ~80x band; they
//! are deliberately conservative versus the paper's own measurements of
//! pyglet/OpenGL (1–2 ms/frame on desktop GL).
//!
//! The pixels themselves are produced by the *software* rasteriser — the
//! model charges time, not different pixels, so correctness tests can run
//! the hardware path too.

use std::time::{Duration, Instant};

use crate::render::Framebuffer;

/// Cost model for one GPU frame: draw + sync stall + readback transfer.
#[derive(Clone, Debug)]
pub struct HardwareSim {
    /// Per-frame driver/dispatch overhead.
    pub draw_overhead: Duration,
    /// Pipeline-drain stall on readback (the PBO-less `glReadPixels` cost).
    pub sync_stall: Duration,
    /// Modelled host transfer bandwidth in bytes/second.
    pub transfer_bandwidth: f64,
    /// When true (default) the model busy-waits so wall-clock and CPU time
    /// both reflect the stall; `charge_only` mode just accumulates the
    /// virtual cost (used by unit tests to stay fast).
    pub realtime: bool,
    virtual_cost: Duration,
    frames: u64,
}

impl Default for HardwareSim {
    fn default() -> Self {
        HardwareSim {
            // Calibrated to the desktop-GL classic-control pipeline the
            // paper measured (pyglet: ~1-2 ms/frame end to end).
            draw_overhead: Duration::from_micros(150),
            sync_stall: Duration::from_micros(450),
            transfer_bandwidth: 6.0e9, // PCIe 3.0 x16 effective
            realtime: true,
            virtual_cost: Duration::ZERO,
            frames: 0,
        }
    }
}

impl HardwareSim {
    /// Cost model that only accumulates virtual time (fast unit tests,
    /// analytic ratio computations).
    pub fn charge_only() -> Self {
        HardwareSim {
            realtime: false,
            ..Default::default()
        }
    }

    /// Per-frame cost for a framebuffer of `bytes` bytes.
    pub fn frame_cost(&self, bytes: usize) -> Duration {
        let transfer = Duration::from_secs_f64(bytes as f64 / self.transfer_bandwidth);
        self.draw_overhead + self.sync_stall + transfer
    }

    /// "Render" a frame through the simulated hardware path: charge the
    /// cost model for the readback of `fb`'s pixels.
    ///
    /// The caller paints `fb` with the software rasteriser first; this
    /// call only models the *time* the GPU path would add.
    pub fn readback(&mut self, fb: &Framebuffer) {
        let bytes = fb.pixels().len() * std::mem::size_of::<f32>();
        let cost = self.frame_cost(bytes);
        self.virtual_cost += cost;
        self.frames += 1;
        if self.realtime {
            // Busy-wait (not sleep): a blocked glReadPixels burns CPU in
            // the driver, which is what the Table-II energy model must see.
            let start = Instant::now();
            while start.elapsed() < cost {
                std::hint::spin_loop();
            }
        }
    }

    /// Total modelled cost so far.
    pub fn total_cost(&self) -> Duration {
        self.virtual_cost
    }

    /// Frames rendered through the model.
    pub fn frames(&self) -> u64 {
        self.frames
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_cost_scales_with_bytes() {
        let sim = HardwareSim::charge_only();
        let small = sim.frame_cost(64 * 64 * 4);
        let large = sim.frame_cost(1024 * 1024 * 4);
        assert!(large > small);
        // Fixed costs dominate small frames (the paper's point: the stall,
        // not the bytes, kills small-scene hardware rendering).
        let fixed = sim.draw_overhead + sim.sync_stall;
        assert!(small < fixed + Duration::from_micros(10));
    }

    #[test]
    fn charge_only_accumulates_without_waiting() {
        let mut sim = HardwareSim::charge_only();
        let fb = Framebuffer::standard();
        let wall = Instant::now();
        for _ in 0..1000 {
            sim.readback(&fb);
        }
        assert_eq!(sim.frames(), 1000);
        // 1000 frames at ~0.6 ms virtual cost each but near-zero wall time.
        assert!(sim.total_cost() > Duration::from_millis(500));
        assert!(wall.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn realtime_mode_actually_waits() {
        let mut sim = HardwareSim::default();
        let fb = Framebuffer::standard();
        let wall = Instant::now();
        for _ in 0..5 {
            sim.readback(&fb);
        }
        let expect = sim.total_cost();
        assert!(wall.elapsed() >= expect - Duration::from_millis(1));
    }

    #[test]
    fn ratio_vs_software_lands_in_paper_band() {
        // Analytic check of the Fig.-1 calibration: software render of the
        // cartpole scene takes single-digit microseconds; the hardware
        // model must cost 40-200x more at 64x64.
        use crate::render::software::paint_cartpole;
        let mut fb = Framebuffer::standard();
        // Measure software cost over many frames.
        let n = 2000;
        let t0 = Instant::now();
        for i in 0..n {
            paint_cartpole(&mut fb, (i % 5) as f32 * 0.3 - 0.6, 0.1);
        }
        let sw = t0.elapsed() / n;
        let hw = HardwareSim::charge_only().frame_cost(64 * 64 * 4) + sw;
        let ratio = hw.as_secs_f64() / sw.as_secs_f64().max(1e-9);
        assert!(ratio > 20.0, "hardware model should dominate: {ratio}");
    }
}
