//! Rendering — the paper's §III-A "Renderers" module.
//!
//! The paper's empirical claim (§II-B, Fig. 1): for simple 2-D scenes,
//! *software* rendering into a CPU-resident framebuffer massively
//! outperforms hardware (OpenGL) rendering whenever the agent needs the
//! pixels, because reading the GPU framebuffer back stalls the pipeline.
//!
//! * [`framebuffer`] — the pixel store (f32 grayscale; RL agents consume
//!   intensity planes, and one channel keeps the hot loop bandwidth-lean).
//! * [`raster`] — scanline shape rasterisation (rects, discs, lines,
//!   polylines) written so the inner loops auto-vectorise (row-contiguous
//!   fills, no per-pixel branches) — the SIMD discipline of [21].
//! * [`software`] — per-environment scene painters (the geometry matches
//!   `python/compile/kernels/render.py` so L1 and L3 renderers can be
//!   golden-tested against each other).
//! * [`hardware_sim`] — a calibrated cost model of the GPU render +
//!   readback path the paper benchmarks against (no GPU in this image;
//!   DESIGN.md §Substitutions).

pub mod framebuffer;
pub mod hardware_sim;
pub mod raster;
pub mod software;

pub use framebuffer::Framebuffer;
pub use hardware_sim::HardwareSim;
