//! Shape rasterisation — scanline fills written for auto-vectorisation.
//!
//! The discipline (after [21], SIMD 2-D rendering): decompose every shape
//! into horizontal runs and fill each run with a branch-free contiguous
//! `slice::fill`.  LLVM vectorises those fills; there is no per-pixel
//! branching anywhere in this module.  All edges clip against the
//! framebuffer rectangle *before* the inner loop.

use crate::render::Framebuffer;

/// Fill an axis-aligned rectangle `[x0, x1) x [y0, y1)`.
pub fn fill_rect(fb: &mut Framebuffer, x0: i32, y0: i32, x1: i32, y1: i32, v: f32) {
    let w = fb.width() as i32;
    let h = fb.height() as i32;
    let cx0 = x0.max(0);
    let cy0 = y0.max(0);
    let cx1 = x1.min(w);
    let cy1 = y1.min(h);
    if cx0 >= cx1 || cy0 >= cy1 {
        return;
    }
    for y in cy0..cy1 {
        fb.row_mut(y as usize)[cx0 as usize..cx1 as usize].fill(v);
    }
}

/// Fill a disc of radius `r` centred at `(cx, cy)` (pixel centres).
pub fn fill_disc(fb: &mut Framebuffer, cx: f32, cy: f32, r: f32, v: f32) {
    if r <= 0.0 {
        return;
    }
    let h = fb.height() as i32;
    let w = fb.width() as i32;
    let y0 = ((cy - r).floor() as i32).max(0);
    let y1 = ((cy + r).ceil() as i32).min(h - 1);
    for y in y0..=y1 {
        // Horizontal chord of the circle at this row.
        let dy = y as f32 - cy;
        let half = (r * r - dy * dy).max(0.0).sqrt();
        let x0 = (((cx - half).ceil()) as i32).max(0);
        let x1 = (((cx + half).floor()) as i32).min(w - 1);
        if x0 <= x1 {
            fb.row_mut(y as usize)[x0 as usize..=x1 as usize].fill(v);
        }
    }
}

/// Draw a line segment of the given half-thickness.
///
/// Implemented as a distance-to-segment test over the segment's bounding
/// box, evaluated row by row so each row's span is a contiguous fill where
/// possible; for thin lines the box is small and the cost negligible.
pub fn draw_line(
    fb: &mut Framebuffer,
    x0: f32,
    y0: f32,
    x1: f32,
    y1: f32,
    half_thick: f32,
    v: f32,
) {
    let dx = x1 - x0;
    let dy = y1 - y0;
    let len2 = dx * dx + dy * dy;
    if len2 < 1e-12 {
        fill_disc(fb, x0, y0, half_thick, v);
        return;
    }
    let w = fb.width() as i32;
    let h = fb.height() as i32;
    let pad = half_thick + 1.0;
    let bx0 = ((x0.min(x1) - pad).floor() as i32).max(0);
    let bx1 = ((x0.max(x1) + pad).ceil() as i32).min(w - 1);
    let by0 = ((y0.min(y1) - pad).floor() as i32).max(0);
    let by1 = ((y0.max(y1) + pad).ceil() as i32).min(h - 1);
    let ht2 = half_thick * half_thick;
    let inv_len2 = 1.0 / len2;
    for y in by0..=by1 {
        let row = fb.row_mut(y as usize);
        let py = y as f32 - y0;
        for x in bx0..=bx1 {
            let px = x as f32 - x0;
            let t = ((px * dx + py * dy) * inv_len2).clamp(0.0, 1.0);
            let ex = px - t * dx;
            let ey = py - t * dy;
            // Branch-free select: LLVM lowers this to a blend.
            let inside = (ex * ex + ey * ey <= ht2) as u32 as f32;
            let cur = row[x as usize];
            row[x as usize] = cur + inside * (v - cur);
        }
    }
}

/// Horizontal 1-px line across the full width (track lines, horizons).
pub fn hline(fb: &mut Framebuffer, y: i32, v: f32) {
    if y >= 0 && (y as usize) < fb.height() {
        fb.row_mut(y as usize).fill(v);
    }
}

/// Polyline: consecutive segments through the given points.
pub fn draw_polyline(fb: &mut Framebuffer, pts: &[(f32, f32)], half_thick: f32, v: f32) {
    for pair in pts.windows(2) {
        draw_line(fb, pair[0].0, pair[0].1, pair[1].0, pair[1].1, half_thick, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_fills_exact_area() {
        let mut fb = Framebuffer::new(16, 16);
        fill_rect(&mut fb, 2, 3, 6, 8, 1.0);
        assert_eq!(fb.sum(), (4 * 5) as f32);
        assert_eq!(fb.get(2, 3), 1.0);
        assert_eq!(fb.get(5, 7), 1.0);
        assert_eq!(fb.get(6, 8), 0.0); // exclusive edges
    }

    #[test]
    fn rect_clips_out_of_bounds() {
        let mut fb = Framebuffer::new(8, 8);
        fill_rect(&mut fb, -5, -5, 3, 3, 1.0);
        assert_eq!(fb.sum(), 9.0);
        fill_rect(&mut fb, 100, 100, 200, 200, 1.0); // fully outside
        assert_eq!(fb.sum(), 9.0);
    }

    #[test]
    fn degenerate_rect_is_empty() {
        let mut fb = Framebuffer::new(8, 8);
        fill_rect(&mut fb, 4, 4, 4, 8, 1.0);
        assert_eq!(fb.sum(), 0.0);
    }

    #[test]
    fn disc_is_symmetric_and_bounded() {
        let mut fb = Framebuffer::new(32, 32);
        fill_disc(&mut fb, 16.0, 16.0, 5.0, 1.0);
        // Area roughly pi*r^2, generous tolerance for pixelation.
        let area = fb.sum();
        assert!((60.0..100.0).contains(&area), "area={area}");
        // Symmetry about the centre.
        for dy in -5i32..=5 {
            for dx in -5i32..=5 {
                let a = fb.get((16 + dx) as usize, (16 + dy) as usize);
                let b = fb.get((16 - dx) as usize, (16 - dy) as usize);
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn line_connects_endpoints() {
        let mut fb = Framebuffer::new(32, 32);
        draw_line(&mut fb, 2.0, 2.0, 28.0, 28.0, 1.0, 1.0);
        assert!(fb.get(2, 2) > 0.0);
        assert!(fb.get(28, 28) > 0.0);
        assert!(fb.get(15, 15) > 0.0);
        assert_eq!(fb.get(30, 2), 0.0);
    }

    #[test]
    fn vertical_line_has_thickness() {
        let mut fb = Framebuffer::new(16, 16);
        draw_line(&mut fb, 8.0, 2.0, 8.0, 14.0, 1.5, 1.0);
        assert!(fb.get(8, 8) > 0.0);
        assert!(fb.get(7, 8) > 0.0);
        assert!(fb.get(9, 8) > 0.0);
        assert_eq!(fb.get(3, 8), 0.0);
    }

    #[test]
    fn zero_length_line_is_a_dot() {
        let mut fb = Framebuffer::new(16, 16);
        draw_line(&mut fb, 8.0, 8.0, 8.0, 8.0, 1.0, 1.0);
        assert!(fb.get(8, 8) > 0.0);
        assert!(fb.sum() < 10.0);
    }

    #[test]
    fn hline_spans_width() {
        let mut fb = Framebuffer::new(10, 10);
        hline(&mut fb, 4, 0.3);
        assert!((fb.sum() - 3.0).abs() < 1e-5);
        hline(&mut fb, -1, 1.0); // clipped
        hline(&mut fb, 10, 1.0);
        assert!((fb.sum() - 3.0).abs() < 1e-5);
    }

    #[test]
    fn polyline_draws_all_segments() {
        let mut fb = Framebuffer::new(32, 32);
        draw_polyline(
            &mut fb,
            &[(2.0, 2.0), (20.0, 2.0), (20.0, 20.0)],
            0.8,
            1.0,
        );
        assert!(fb.get(10, 2) > 0.0);
        assert!(fb.get(20, 10) > 0.0);
    }
}
