//! Deterministic fault injection: seed-driven chaos for the shard
//! fabric and the env layer.
//!
//! Everything here follows the toolkit's determinism contract: a
//! [`ChaosProfile`] is `(rates, seed)`, a [`FaultPlan`] is a [`Pcg32`]
//! stream over that seed, and the `n`-th decision of a plan is a pure
//! function of `(profile, seed, stream, n)`.  A CI chaos failure
//! therefore reproduces exactly from the profile string it was run
//! with — `cairl run --chaos "corrupt=20,delay=50@7"` injects the same
//! faults at the same points on every machine.
//!
//! Three injection surfaces:
//!
//! * **Wire** — [`FramedStream`](crate::shard::net) consults a plan on
//!   every frame send and may corrupt a byte, truncate the frame,
//!   delay, or reset the connection ([`WireFault`]).  Injectors attach
//!   **after** the handshake, so connects and failover re-dials always
//!   succeed and every injected fault lands on a connection the
//!   failover path knows how to replace.
//! * **Server freeze** — a one-shot long delay drawn from the same
//!   stream ([`ChaosProfile::freeze`]), long enough to trip a client
//!   read deadline: the frozen-shard drill.
//! * **Env** — [`FaultyEnv`] wraps any [`Env`] and panics on a
//!   plan-chosen step, driving the pool poison/quarantine machinery.
//!
//! Injections count into `cairl_faults_injected_total{kind=...}` so a
//! chaos run's fault mix is visible in `cairl metrics`.

use std::time::Duration;

use crate::core::env::{Env, Step, Transition};
use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;
use crate::telemetry::{counter, Counter};

fn err(msg: impl Into<String>) -> CairlError {
    CairlError::Config(msg.into())
}

/// Fault rates are expressed per [`RATE_SCALE`] sends (basis points):
/// `corrupt = 25` corrupts ~0.25% of frames.
pub const RATE_SCALE: u32 = 10_000;

/// While a freeze budget remains, each send freezes with this
/// probability (per [`RATE_SCALE`]) — 1%, early enough to land mid-run
/// without dominating short workloads.
const FREEZE_BAND: u32 = 100;

/// A named, seeded fault mix.  Parsed from the `--chaos` flag / config
/// grammar: a preset name (`off`, `light`, `heavy`) or a `k=v` list
/// over the field names below, either followed by an optional `@seed`
/// (`"light@7"`, `"corrupt=20,delay_ms=3@123"`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChaosProfile {
    /// Per-[`RATE_SCALE`] rate of single-byte frame corruption.
    pub corrupt: u32,
    /// Per-[`RATE_SCALE`] rate of mid-frame truncation (the connection
    /// is killed after the partial write).
    pub truncate: u32,
    /// Per-[`RATE_SCALE`] rate of a [`ChaosProfile::delay_ms`] send
    /// delay.
    pub delay: u32,
    /// Per-[`RATE_SCALE`] rate of an abrupt connection reset.
    pub reset: u32,
    /// Per-[`RATE_SCALE`] rate of an injected env-step panic
    /// ([`FaultyEnv`] only; wire plans ignore it).
    pub panic: u32,
    /// Length of an injected delay, in milliseconds.
    pub delay_ms: u64,
    /// Budget of one-shot freezes (long stalls) to inject; each fires
    /// with a fixed 1% per-send chance while budget remains.
    pub freeze: u32,
    /// Length of an injected freeze, in milliseconds.  Must exceed the
    /// victim's read deadline for the frozen-shard drill to trip it.
    pub freeze_ms: u64,
    /// Seed of the plan's PCG stream.
    pub seed: u64,
}

impl ChaosProfile {
    /// The all-zero profile: no faults, ever.
    pub fn off() -> ChaosProfile {
        ChaosProfile {
            corrupt: 0,
            truncate: 0,
            delay: 0,
            reset: 0,
            panic: 0,
            delay_ms: 0,
            freeze: 0,
            freeze_ms: 0,
            seed: 1,
        }
    }

    /// Mild background noise: occasional corruption, truncation, short
    /// delays and resets — every fault recoverable via failover.
    pub fn light() -> ChaosProfile {
        ChaosProfile {
            corrupt: 10,
            truncate: 5,
            delay: 40,
            reset: 5,
            panic: 0,
            delay_ms: 2,
            freeze: 0,
            freeze_ms: 0,
            seed: 1,
        }
    }

    /// Aggressive mix plus one mid-run freeze (1.5 s — longer than any
    /// sane client read deadline, so the drill trips it).
    pub fn heavy() -> ChaosProfile {
        ChaosProfile {
            corrupt: 80,
            truncate: 40,
            delay: 200,
            reset: 40,
            panic: 0,
            delay_ms: 5,
            freeze: 1,
            freeze_ms: 1_500,
            seed: 1,
        }
    }

    /// True when no fault can ever fire (all rates and budgets zero).
    pub fn is_off(&self) -> bool {
        self.corrupt == 0
            && self.truncate == 0
            && self.delay == 0
            && self.reset == 0
            && self.panic == 0
            && self.freeze == 0
    }

    /// Parse the `--chaos` grammar (see the type docs).
    pub fn parse(s: &str) -> Result<ChaosProfile> {
        let s = s.trim();
        let (body, seed) = match s.rsplit_once('@') {
            Some((body, seed)) => {
                let seed = seed
                    .parse::<u64>()
                    .map_err(|_| err(format!("chaos profile {s:?}: bad seed {seed:?}")))?;
                (body, Some(seed))
            }
            None => (s, None),
        };
        let mut p = match body {
            "" | "off" => ChaosProfile::off(),
            "light" => ChaosProfile::light(),
            "heavy" => ChaosProfile::heavy(),
            _ => {
                let mut p = ChaosProfile::off();
                for kv in body.split(',') {
                    let (k, v) = kv.split_once('=').ok_or_else(|| {
                        err(format!(
                            "chaos profile {s:?}: expected key=value, got {kv:?} \
                             (or a preset: off, light, heavy)"
                        ))
                    })?;
                    let n = v.trim().parse::<u64>().map_err(|_| {
                        err(format!("chaos profile {s:?}: bad value in {kv:?}"))
                    })?;
                    let rate = || -> Result<u32> {
                        u32::try_from(n)
                            .ok()
                            .filter(|&r| r <= RATE_SCALE)
                            .ok_or_else(|| {
                                err(format!(
                                    "chaos profile {s:?}: rate {n} out of range 0..={RATE_SCALE}"
                                ))
                            })
                    };
                    match k.trim() {
                        "corrupt" => p.corrupt = rate()?,
                        "truncate" => p.truncate = rate()?,
                        "delay" => p.delay = rate()?,
                        "reset" => p.reset = rate()?,
                        "panic" => p.panic = rate()?,
                        "delay_ms" => p.delay_ms = n,
                        "freeze" => p.freeze = rate()?,
                        "freeze_ms" => p.freeze_ms = n,
                        "seed" => p.seed = n,
                        other => {
                            return Err(err(format!(
                                "chaos profile {s:?}: unknown key {other:?}"
                            )))
                        }
                    }
                }
                p
            }
        };
        if let Some(seed) = seed {
            p.seed = seed;
        }
        Ok(p)
    }

    /// Canonical `k=v,...@seed` form; `parse(render(p)) == p`.
    pub fn render(&self) -> String {
        if self.is_off() {
            return format!("off@{}", self.seed);
        }
        format!(
            "corrupt={},truncate={},delay={},reset={},panic={},delay_ms={},\
             freeze={},freeze_ms={}@{}",
            self.corrupt,
            self.truncate,
            self.delay,
            self.reset,
            self.panic,
            self.delay_ms,
            self.freeze,
            self.freeze_ms,
            self.seed
        )
    }
}

/// One wire-level fault decision (see
/// [`FramedStream::send`](crate::shard::net)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFault {
    /// XOR `mask` into the frame byte at `offset % len`.
    Corrupt {
        /// Raw offset; the injector reduces it modulo the frame length.
        offset: u64,
        /// Nonzero XOR mask (a single flipped bit).
        mask: u8,
    },
    /// Write only `1 + keep % (len-1)` bytes, then kill the connection.
    Truncate {
        /// Raw prefix length; reduced modulo `len-1` at the injection
        /// site so at least one byte is written and at least one lost.
        keep: u64,
    },
    /// Sleep this long, then send normally (covers both background
    /// delays and the one-shot freeze).
    Delay(Duration),
    /// Kill the connection without sending.
    Reset,
}

/// A live fault stream: the profile's rates driven by one PCG stream.
/// Every [`FaultPlan::next_wire_fault`] / [`FaultPlan::next_panic`]
/// call advances the stream by exactly one base draw, so decision `n`
/// is a pure function of `(profile, stream, n)` regardless of which
/// faults actually fired.
#[derive(Debug)]
pub struct FaultPlan {
    profile: ChaosProfile,
    rng: Pcg32,
    freeze_left: u32,
    corrupt_count: Counter,
    truncate_count: Counter,
    delay_count: Counter,
    reset_count: Counter,
    freeze_count: Counter,
    panic_count: Counter,
}

impl FaultPlan {
    /// Build a plan over `profile.seed` and the given stream id.  Use a
    /// distinct stream per connection/lane so concurrent injectors draw
    /// independent (but individually reproducible) sequences.
    pub fn new(profile: &ChaosProfile, stream: u64) -> FaultPlan {
        FaultPlan {
            profile: profile.clone(),
            rng: Pcg32::new(profile.seed, stream),
            freeze_left: profile.freeze,
            corrupt_count: counter("cairl_faults_injected_total{kind=\"corrupt\"}"),
            truncate_count: counter("cairl_faults_injected_total{kind=\"truncate\"}"),
            delay_count: counter("cairl_faults_injected_total{kind=\"delay\"}"),
            reset_count: counter("cairl_faults_injected_total{kind=\"reset\"}"),
            freeze_count: counter("cairl_faults_injected_total{kind=\"freeze\"}"),
            panic_count: counter("cairl_faults_injected_total{kind=\"panic\"}"),
        }
    }

    /// The wire-fault decision for the next frame send, if any.  Bands
    /// are checked in a fixed order (freeze, corrupt, truncate, delay,
    /// reset) against one roll in `[0, RATE_SCALE)`.
    pub fn next_wire_fault(&mut self) -> Option<WireFault> {
        let roll = self.rng.below(RATE_SCALE);
        let p = &self.profile;
        let mut lo = 0;
        if self.freeze_left > 0 {
            if roll < FREEZE_BAND {
                self.freeze_left -= 1;
                self.freeze_count.inc();
                return Some(WireFault::Delay(Duration::from_millis(p.freeze_ms)));
            }
            lo += FREEZE_BAND;
        }
        if roll < lo + p.corrupt {
            // Extra draws only inside a fired band keep the base stream
            // one-draw-per-call.
            let offset = ((self.rng.next_u32() as u64) << 32) | self.rng.next_u32() as u64;
            let mask = 1u8 << self.rng.below(8);
            self.corrupt_count.inc();
            return Some(WireFault::Corrupt { offset, mask });
        }
        lo += p.corrupt;
        if roll < lo + p.truncate {
            let keep = self.rng.next_u32() as u64;
            self.truncate_count.inc();
            return Some(WireFault::Truncate { keep });
        }
        lo += p.truncate;
        if roll < lo + p.delay {
            self.delay_count.inc();
            return Some(WireFault::Delay(Duration::from_millis(p.delay_ms)));
        }
        lo += p.delay;
        if roll < lo + p.reset {
            self.reset_count.inc();
            return Some(WireFault::Reset);
        }
        None
    }

    /// The env-panic decision for the next step ([`FaultyEnv`]).
    pub fn next_panic(&mut self) -> bool {
        let fired = self.rng.below(RATE_SCALE) < self.profile.panic;
        if fired {
            self.panic_count.inc();
        }
        fired
    }
}

/// An [`Env`] wrapper that panics on plan-chosen steps — the
/// deterministic stand-in for a buggy environment, used to drive the
/// pools' poison/quarantine machinery in chaos tests.
pub struct FaultyEnv<E: Env> {
    env: E,
    plan: FaultPlan,
}

impl<E: Env> FaultyEnv<E> {
    /// Wrap `env`; panics are drawn from `profile.panic` on the given
    /// stream.
    pub fn new(env: E, profile: &ChaosProfile, stream: u64) -> FaultyEnv<E> {
        FaultyEnv {
            env,
            plan: FaultPlan::new(profile, stream),
        }
    }
}

impl<E: Env> Env for FaultyEnv<E> {
    fn id(&self) -> String {
        self.env.id()
    }
    fn observation_space(&self) -> Space {
        self.env.observation_space()
    }
    fn action_space(&self) -> Space {
        self.env.action_space()
    }
    fn obs_dim(&self) -> usize {
        self.env.obs_dim()
    }
    fn seed(&mut self, seed: u64) {
        self.env.seed(seed)
    }
    fn reset_into(&mut self, obs: &mut [f32]) {
        self.env.reset_into(obs)
    }
    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        if self.plan.next_panic() {
            panic!("chaos: injected env panic in {}", self.env.id());
        }
        self.env.step_into(action, obs)
    }
    fn render(&self, fb: &mut Framebuffer) {
        self.env.render(fb)
    }
    fn reset(&mut self) -> Vec<f32> {
        self.env.reset()
    }
    fn step(&mut self, action: &Action) -> Step {
        self.env.step(action)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_grammar_parses_presets_and_overrides() {
        assert_eq!(ChaosProfile::parse("off").unwrap(), ChaosProfile::off());
        assert_eq!(ChaosProfile::parse("light").unwrap(), ChaosProfile::light());
        let mut heavy7 = ChaosProfile::heavy();
        heavy7.seed = 7;
        assert_eq!(ChaosProfile::parse("heavy@7").unwrap(), heavy7);

        let p = ChaosProfile::parse("corrupt=20,delay=50,delay_ms=3@123").unwrap();
        assert_eq!(p.corrupt, 20);
        assert_eq!(p.delay, 50);
        assert_eq!(p.delay_ms, 3);
        assert_eq!(p.seed, 123);
        assert_eq!(p.truncate, 0);
        assert!(!p.is_off());

        for bad in [
            "nosuchpreset",
            "corrupt",
            "corrupt=x",
            "corrupt=10001",
            "nope=1",
            "light@notanum",
        ] {
            assert!(ChaosProfile::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn profile_render_round_trips() {
        for p in [
            ChaosProfile::off(),
            ChaosProfile::light(),
            ChaosProfile::heavy(),
            ChaosProfile::parse("corrupt=7,freeze=2,freeze_ms=900@42").unwrap(),
        ] {
            assert_eq!(ChaosProfile::parse(&p.render()).unwrap(), p, "{}", p.render());
        }
    }

    #[test]
    fn plans_are_deterministic_per_seed_and_stream() {
        let profile = ChaosProfile::parse("corrupt=500,truncate=300,delay=800,reset=200@9")
            .unwrap();
        let mut a = FaultPlan::new(&profile, 4);
        let mut b = FaultPlan::new(&profile, 4);
        let seq_a: Vec<_> = (0..2_000).map(|_| a.next_wire_fault()).collect();
        let seq_b: Vec<_> = (0..2_000).map(|_| b.next_wire_fault()).collect();
        assert_eq!(seq_a, seq_b, "same (profile, stream) must replay identically");
        assert!(
            seq_a.iter().any(|f| f.is_some()),
            "rates this high must fire within 2000 draws"
        );

        let mut c = FaultPlan::new(&profile, 5);
        let seq_c: Vec<_> = (0..2_000).map(|_| c.next_wire_fault()).collect();
        assert_ne!(seq_a, seq_c, "distinct streams must diverge");
    }

    #[test]
    fn freeze_budget_is_one_shot() {
        let profile = ChaosProfile::parse("freeze=1,freeze_ms=77@3").unwrap();
        let mut plan = FaultPlan::new(&profile, 1);
        let freezes = (0..50_000)
            .filter_map(|_| plan.next_wire_fault())
            .filter(|f| *f == WireFault::Delay(Duration::from_millis(77)))
            .count();
        assert_eq!(freezes, 1, "budget of one means exactly one freeze");
    }

    #[test]
    fn off_profile_never_fires() {
        let mut plan = FaultPlan::new(&ChaosProfile::off(), 0);
        assert!((0..10_000).all(|_| plan.next_wire_fault().is_none()));
        assert!((0..10_000).all(|_| !plan.next_panic()));
    }

    #[test]
    #[should_panic(expected = "chaos: injected env panic")]
    fn faulty_env_panics_on_schedule() {
        use crate::envs::CartPole;
        let profile = ChaosProfile::parse("panic=10000@1").unwrap();
        let mut env = FaultyEnv::new(CartPole::new(), &profile, 0);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset_into(&mut obs);
        env.step_into(&Action::Discrete(0), &mut obs);
    }
}
