//! Pendulum-v1 — exact port of the Gym dynamics, plus the discrete-torque
//! variant the DQN experiments need.
//!
//! Observation `[cos theta, sin theta, theta_dot]`.  The native action
//! space is a 1-D box `[-2, 2]` (torque); [`PENDULUM_TORQUES`] defines the
//! 5-level discretisation used when DQN (a discrete-action algorithm, the
//! paper's Table-I agent) trains on it.  There is no terminal state — the
//! standard TimeLimit(200) wrapper ends episodes.

use crate::core::batch::{FusedBatch, LaneKernel};
use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{software, Framebuffer};

pub const MAX_SPEED: f32 = 8.0;
pub const MAX_TORQUE: f32 = 2.0;
pub const DT: f32 = 0.05;
pub const G: f32 = 10.0;
pub const M: f32 = 1.0;
pub const L: f32 = 1.0;

/// Torque levels for the discrete (DQN-compatible) action mode.
pub const PENDULUM_TORQUES: [f32; 5] = [-2.0, -1.0, 0.0, 1.0, 2.0];

fn angle_normalize(x: f32) -> f32 {
    let two_pi = 2.0 * std::f32::consts::PI;
    ((x + std::f32::consts::PI).rem_euclid(two_pi)) - std::f32::consts::PI
}

/// The pendulum swing-up task.
#[derive(Clone, Debug)]
pub struct Pendulum {
    theta: f32,
    theta_dot: f32,
    rng: Pcg32,
    /// When true the action space is `Discrete(5)` over
    /// [`PENDULUM_TORQUES`]; when false it is the Gym box `[-2, 2]`.
    discrete: bool,
}

impl Pendulum {
    /// Gym-faithful continuous-torque pendulum.
    pub fn new() -> Self {
        Pendulum {
            theta: 0.0,
            theta_dot: 0.0,
            rng: Pcg32::new(0, 0x6a09e667f3bcc909),
            discrete: false,
        }
    }

    /// Discrete-torque variant for DQN (5 levels).
    pub fn discrete() -> Self {
        Pendulum {
            discrete: true,
            ..Self::new()
        }
    }

    /// A fused SoA batch of `lanes` continuous-torque pendulums
    /// ([`CartPole::batch`](crate::envs::CartPole::batch) semantics).
    pub fn batch(lanes: usize, max_steps: Option<u32>) -> FusedBatch<PendulumLanes> {
        FusedBatch::new(PendulumLanes::new(lanes, false), max_steps)
    }

    /// [`Pendulum::batch`] for the discrete-torque (DQN) variant.
    pub fn batch_discrete(lanes: usize, max_steps: Option<u32>) -> FusedBatch<PendulumLanes> {
        FusedBatch::new(PendulumLanes::new(lanes, true), max_steps)
    }

    pub fn state(&self) -> [f32; 2] {
        [self.theta, self.theta_dot]
    }

    pub fn set_state(&mut self, s: [f32; 2]) {
        self.theta = s[0];
        self.theta_dot = s[1];
    }

    /// Pure dynamics: returns (theta', theta_dot', reward).
    #[inline]
    pub fn dynamics(theta: f32, theta_dot: f32, torque: f32) -> (f32, f32, f32) {
        let u = torque.clamp(-MAX_TORQUE, MAX_TORQUE);
        let norm = angle_normalize(theta);
        let cost = norm * norm + 0.1 * theta_dot * theta_dot + 0.001 * u * u;
        let mut new_dot = theta_dot
            + (3.0 * G / (2.0 * L) * theta.sin() + 3.0 / (M * L * L) * u) * DT;
        new_dot = new_dot.clamp(-MAX_SPEED, MAX_SPEED);
        let new_theta = theta + new_dot * DT;
        (new_theta, new_dot, -cost)
    }

    fn torque_of(&self, action: &Action) -> f32 {
        if self.discrete {
            PENDULUM_TORQUES[action.index()]
        } else {
            action.vector()[0]
        }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs[0] = self.theta.cos();
        obs[1] = self.theta.sin();
        obs[2] = self.theta_dot;
    }
}

/// The Gym observation-space bounds — one definition shared by the
/// scalar env and the fused lane kernel.
fn obs_space() -> Space {
    Space::box1(
        vec![-1.0, -1.0, -MAX_SPEED],
        vec![1.0, 1.0, MAX_SPEED],
    )
}

impl Default for Pendulum {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for Pendulum {
    fn id(&self) -> String {
        if self.discrete {
            "PendulumDiscrete-v1".into()
        } else {
            "Pendulum-v1".into()
        }
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        if self.discrete {
            Space::Discrete {
                n: PENDULUM_TORQUES.len(),
            }
        } else {
            Space::box1(vec![-MAX_TORQUE], vec![MAX_TORQUE])
        }
    }

    fn obs_dim(&self) -> usize {
        3
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x6a09e667f3bcc909);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.theta = self
            .rng
            .uniform(-std::f32::consts::PI, std::f32::consts::PI);
        self.theta_dot = self.rng.uniform(-1.0, 1.0);
        self.write_obs(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let torque = self.torque_of(action);
        let (t, td, reward) = Self::dynamics(self.theta, self.theta_dot, torque);
        self.theta = t;
        self.theta_dot = td;
        self.write_obs(obs);
        // Never terminal: Pendulum relies on TimeLimit.
        Transition::live(reward)
    }

    fn render(&self, fb: &mut Framebuffer) {
        software::paint_pendulum(fb, self.theta);
    }
}

/// SoA state columns of a fused pendulum group ([`Pendulum::batch`] /
/// [`Pendulum::batch_discrete`]).
pub struct PendulumLanes {
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
    discrete: bool,
}

impl PendulumLanes {
    fn new(lanes: usize, discrete: bool) -> PendulumLanes {
        PendulumLanes {
            theta: vec![0.0; lanes],
            theta_dot: vec![0.0; lanes],
            discrete,
        }
    }
}

impl LaneKernel for PendulumLanes {
    fn obs_dim(&self) -> usize {
        3
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        if self.discrete {
            Space::Discrete {
                n: PENDULUM_TORQUES.len(),
            }
        } else {
            Space::box1(vec![-MAX_TORQUE], vec![MAX_TORQUE])
        }
    }

    fn rng_stream(&self) -> u64 {
        0x6a09e667f3bcc909
    }

    fn lanes(&self) -> usize {
        self.theta.len()
    }

    fn reset_lane(&mut self, k: usize, rng: &mut Pcg32, obs: &mut [f32]) {
        self.theta[k] = rng.uniform(-std::f32::consts::PI, std::f32::consts::PI);
        self.theta_dot[k] = rng.uniform(-1.0, 1.0);
        obs[0] = self.theta[k].cos();
        obs[1] = self.theta[k].sin();
        obs[2] = self.theta_dot[k];
    }

    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let torque = if self.discrete {
            PENDULUM_TORQUES[action.index()]
        } else {
            action.vector()[0]
        };
        let (t, td, reward) = Pendulum::dynamics(self.theta[k], self.theta_dot[k], torque);
        self.theta[k] = t;
        self.theta_dot[k] = td;
        obs[0] = t.cos();
        obs[1] = t.sin();
        obs[2] = td;
        // Never terminal: the fused TimeLimit ends episodes.
        Transition::live(reward)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn angle_normalize_wraps() {
        assert!((angle_normalize(0.0)).abs() < 1e-6);
        assert!((angle_normalize(2.0 * std::f32::consts::PI)).abs() < 1e-6);
        // 3*pi normalises to +-pi (the two are equivalent angles; float
        // rounding selects the sign).
        assert!(
            (angle_normalize(3.0 * std::f32::consts::PI).abs() - std::f32::consts::PI)
                .abs()
                < 1e-5
        );
    }

    #[test]
    fn upright_no_torque_costs_nothing() {
        let (_, _, r) = Pendulum::dynamics(0.0, 0.0, 0.0);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn hanging_costs_pi_squared() {
        let (_, _, r) = Pendulum::dynamics(std::f32::consts::PI, 0.0, 0.0);
        assert!((r + std::f32::consts::PI.powi(2)).abs() < 1e-4);
    }

    #[test]
    fn gravity_pulls_from_side() {
        // theta = pi/2 (horizontal): sin(pi/2)=1 accelerates theta_dot.
        let (_, td, _) = Pendulum::dynamics(std::f32::consts::FRAC_PI_2, 0.0, 0.0);
        assert!(td > 0.0);
    }

    #[test]
    fn speed_clamped() {
        let (_, td, _) = Pendulum::dynamics(std::f32::consts::FRAC_PI_2, 100.0, 2.0);
        assert!(td <= MAX_SPEED);
    }

    #[test]
    fn torque_clamped() {
        let (_, a, _) = Pendulum::dynamics(0.0, 0.0, 100.0);
        let (_, b, _) = Pendulum::dynamics(0.0, 0.0, MAX_TORQUE);
        assert_eq!(a, b);
    }

    #[test]
    fn discrete_variant_exposes_five_actions() {
        let env = Pendulum::discrete();
        assert_eq!(env.action_space(), Space::Discrete { n: 5 });
        assert_eq!(env.id(), "PendulumDiscrete-v1");
    }

    #[test]
    fn continuous_variant_accepts_box_action() {
        let mut env = Pendulum::new();
        env.seed(0);
        let mut obs = [0.0f32; 3];
        env.reset_into(&mut obs);
        let t = env.step_into(&Action::Continuous(vec![1.0]), &mut obs);
        assert!(!t.done);
        assert!(t.reward <= 0.0);
    }

    #[test]
    fn never_terminates() {
        let mut env = Pendulum::discrete();
        env.seed(1);
        let mut obs = [0.0f32; 3];
        env.reset_into(&mut obs);
        for _ in 0..1000 {
            let t = env.step_into(&Action::Discrete(4), &mut obs);
            assert!(!t.done);
        }
    }

    #[test]
    fn obs_is_unit_circle() {
        let mut env = Pendulum::new();
        env.seed(2);
        let obs = env.reset();
        let norm = obs[0] * obs[0] + obs[1] * obs[1];
        assert!((norm - 1.0).abs() < 1e-5);
    }
}
