//! MountainCar-v0 — exact port of the Gym dynamics.
//!
//! An under-powered car must rock between two hills to reach the right
//! summit.  Observation `[position, velocity]`, actions `{0: push left,
//! 1: coast, 2: push right}`, reward -1 per step, terminal at the goal.

use crate::core::batch::{FusedBatch, LaneKernel};
use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{software, Framebuffer};

pub const MIN_POSITION: f32 = -1.2;
pub const MAX_POSITION: f32 = 0.6;
pub const MAX_SPEED: f32 = 0.07;
pub const GOAL_POSITION: f32 = 0.5;
pub const FORCE: f32 = 0.001;
pub const GRAVITY: f32 = 0.0025;

/// The mountain-car task.
#[derive(Clone, Debug)]
pub struct MountainCar {
    position: f32,
    velocity: f32,
    rng: Pcg32,
    done: bool,
}

impl MountainCar {
    pub fn new() -> Self {
        MountainCar {
            position: 0.0,
            velocity: 0.0,
            rng: Pcg32::new(0, 0xd3c5b1a49e7f2263),
            done: true,
        }
    }

    pub fn state(&self) -> [f32; 2] {
        [self.position, self.velocity]
    }

    pub fn set_state(&mut self, s: [f32; 2]) {
        self.position = s[0];
        self.velocity = s[1];
        self.done = false;
    }

    /// A fused SoA batch of `lanes` mountain cars ([`CartPole::batch`]
    /// (crate::envs::CartPole::batch) semantics: same dynamics as the
    /// scalar env, `TimeLimit` and auto-reset folded in).
    pub fn batch(lanes: usize, max_steps: Option<u32>) -> FusedBatch<MountainCarLanes> {
        FusedBatch::new(
            MountainCarLanes {
                position: vec![0.0; lanes],
                velocity: vec![0.0; lanes],
            },
            max_steps,
        )
    }

    /// Pure dynamics shared with the scripted baseline tests.
    #[inline]
    pub fn dynamics(pos: f32, vel: f32, action: usize) -> (f32, f32, bool) {
        let mut velocity =
            vel + (action as f32 - 1.0) * FORCE + (3.0 * pos).cos() * (-GRAVITY);
        velocity = velocity.clamp(-MAX_SPEED, MAX_SPEED);
        let mut position = pos + velocity;
        position = position.clamp(MIN_POSITION, MAX_POSITION);
        if position == MIN_POSITION && velocity < 0.0 {
            velocity = 0.0;
        }
        // Gym v0: goal_velocity = 0.
        let done = position >= GOAL_POSITION;
        (position, velocity, done)
    }
}

impl Default for MountainCar {
    fn default() -> Self {
        Self::new()
    }
}

/// The Gym observation-space bounds — one definition shared by the
/// scalar env and the fused lane kernel.
fn obs_space() -> Space {
    Space::box1(
        vec![MIN_POSITION, -MAX_SPEED],
        vec![MAX_POSITION, MAX_SPEED],
    )
}

impl Env for MountainCar {
    fn id(&self) -> String {
        "MountainCar-v0".into()
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 3 }
    }

    fn obs_dim(&self) -> usize {
        2
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0xd3c5b1a49e7f2263);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.position = self.rng.uniform(-0.6, -0.4);
        self.velocity = 0.0;
        self.done = false;
        obs[0] = self.position;
        obs[1] = self.velocity;
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        debug_assert!(!self.done, "step() called on a finished episode");
        let (p, v, done) = Self::dynamics(self.position, self.velocity, action.index());
        self.position = p;
        self.velocity = v;
        self.done = done;
        obs[0] = p;
        obs[1] = v;
        Transition {
            reward: -1.0,
            done,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        software::paint_mountaincar(fb, self.position, self.velocity);
    }
}

/// SoA state columns of a fused mountain-car group
/// ([`MountainCar::batch`]).
pub struct MountainCarLanes {
    position: Vec<f32>,
    velocity: Vec<f32>,
}

impl LaneKernel for MountainCarLanes {
    fn obs_dim(&self) -> usize {
        2
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 3 }
    }

    fn rng_stream(&self) -> u64 {
        0xd3c5b1a49e7f2263
    }

    fn lanes(&self) -> usize {
        self.position.len()
    }

    fn reset_lane(&mut self, k: usize, rng: &mut Pcg32, obs: &mut [f32]) {
        self.position[k] = rng.uniform(-0.6, -0.4);
        self.velocity[k] = 0.0;
        obs[0] = self.position[k];
        obs[1] = self.velocity[k];
    }

    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let (p, v, done) =
            MountainCar::dynamics(self.position[k], self.velocity[k], action.index());
        self.position[k] = p;
        self.velocity[k] = v;
        obs[0] = p;
        obs[1] = v;
        Transition {
            reward: -1.0,
            done,
            truncated: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_in_start_band() {
        let mut env = MountainCar::new();
        env.seed(7);
        for _ in 0..20 {
            let obs = env.reset();
            assert!((-0.6..-0.4).contains(&obs[0]));
            assert_eq!(obs[1], 0.0);
        }
    }

    #[test]
    fn coasting_in_valley_stays_put() {
        // At the valley bottom cos(3p) term: p* where cos(3p)=0 -> p=-pi/6.
        let p = -std::f32::consts::PI / 6.0;
        let (p2, v2, done) = MountainCar::dynamics(p, 0.0, 1);
        assert!((p2 - p).abs() < 1e-6);
        assert!(v2.abs() < 1e-6);
        assert!(!done);
    }

    #[test]
    fn push_right_increases_velocity() {
        let (_, v_push, _) = MountainCar::dynamics(-0.5, 0.0, 2);
        let (_, v_coast, _) = MountainCar::dynamics(-0.5, 0.0, 1);
        assert!(v_push > v_coast);
    }

    #[test]
    fn velocity_is_clamped() {
        let (_, v, _) = MountainCar::dynamics(-0.5, MAX_SPEED, 2);
        assert!(v <= MAX_SPEED);
    }

    #[test]
    fn left_wall_inelastic() {
        let (p, v, _) = MountainCar::dynamics(MIN_POSITION, -MAX_SPEED, 0);
        assert_eq!(p, MIN_POSITION);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn reaches_goal_and_terminates() {
        let (p, _, done) = MountainCar::dynamics(0.49, MAX_SPEED, 2);
        assert!(p >= GOAL_POSITION);
        assert!(done);
    }

    #[test]
    fn random_policy_never_solves_in_200() {
        let mut env = MountainCar::new();
        env.seed(0);
        let mut rng = Pcg32::new(5, 5);
        for _ in 0..10 {
            let (ret, len) = crate::core::env::random_rollout(&mut env, &mut rng, 200);
            assert_eq!(len, 200);
            assert_eq!(ret, -200.0);
        }
    }

    #[test]
    fn rocking_policy_beats_constant_push() {
        // The classic energy-pumping policy: push in the direction of the
        // velocity. This must reach the goal within 200 steps.
        let mut env = MountainCar::new();
        env.seed(3);
        let mut obs = [0.0f32; 2];
        env.reset_into(&mut obs);
        let mut solved = false;
        for _ in 0..200 {
            let a = if obs[1] >= 0.0 { 2 } else { 0 };
            let t = env.step_into(&Action::Discrete(a), &mut obs);
            if t.done {
                solved = true;
                break;
            }
        }
        assert!(solved, "energy pumping should solve mountain car");
    }
}
