//! Native environments — the paper's §III "classical RL problems"
//! implemented directly in the compiled language (the toolkit's headline
//! feature).
//!
//! Dynamics are ports of the OpenAI-Gym reference implementations,
//! constant for constant, so that the interpreted baseline
//! ([`crate::script`]) and the L1 batched kernel
//! (`python/compile/kernels/env_step.py`) produce the same trajectories —
//! the cross-runner integration tests rely on this.
//!
//! [`gridrts`] is the MicroRTS-class adversarial substrate standing in for
//! the paper's JVM runner environments (DESIGN.md §Substitutions).

pub mod acrobot;
pub mod cartpole;
pub mod gridrts;
pub mod linewars;
pub mod mountain_car;
pub mod pendulum;

pub use acrobot::{Acrobot, AcrobotLanes};
pub use cartpole::{CartPole, CartPoleLanes};
pub use gridrts::GridRts;
pub use linewars::LineWars;
pub use mountain_car::{MountainCar, MountainCarLanes};
pub use pendulum::{Pendulum, PendulumLanes, PENDULUM_TORQUES};
