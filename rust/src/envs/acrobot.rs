//! Acrobot-v1 — exact port of the Gym dynamics (RK4, "book" parameters).
//!
//! A two-link underactuated pendulum: torque on the *second* joint must
//! swing the tip above the bar.  Observation is the 6-vector
//! `[cos t1, sin t1, cos t2, sin t2, dt1, dt2]`, actions `{0: -1, 1: 0,
//! 2: +1}` torque, reward -1 per step until termination.

use crate::core::batch::{FusedBatch, LaneKernel};
use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{software, Framebuffer};

pub const DT: f32 = 0.2;
pub const LINK_LENGTH_1: f32 = 1.0;
pub const LINK_MASS_1: f32 = 1.0;
pub const LINK_MASS_2: f32 = 1.0;
pub const LINK_COM_POS_1: f32 = 0.5;
pub const LINK_COM_POS_2: f32 = 0.5;
pub const LINK_MOI: f32 = 1.0;
pub const MAX_VEL_1: f32 = 4.0 * std::f32::consts::PI;
pub const MAX_VEL_2: f32 = 9.0 * std::f32::consts::PI;
const G: f32 = 9.8;

/// The acrobot swing-up task.  Internal state `[theta1, theta2, dtheta1,
/// dtheta2]` (angles from the downward vertical).
#[derive(Clone, Debug)]
pub struct Acrobot {
    state: [f32; 4],
    rng: Pcg32,
    done: bool,
}

fn wrap(x: f32, lo: f32, hi: f32) -> f32 {
    let range = hi - lo;
    let mut x = x;
    while x > hi {
        x -= range;
    }
    while x < lo {
        x += range;
    }
    x
}

/// Equations of motion from Sutton & Barto (the Gym "book" variant):
/// returns d/dt of `[theta1, theta2, dtheta1, dtheta2]` under `torque`.
fn dsdt(s: [f32; 4], torque: f32) -> [f32; 4] {
    let m1 = LINK_MASS_1;
    let m2 = LINK_MASS_2;
    let l1 = LINK_LENGTH_1;
    let lc1 = LINK_COM_POS_1;
    let lc2 = LINK_COM_POS_2;
    let i1 = LINK_MOI;
    let i2 = LINK_MOI;
    let [theta1, theta2, dtheta1, dtheta2] = s;

    let d1 = m1 * lc1 * lc1
        + m2 * (l1 * l1 + lc2 * lc2 + 2.0 * l1 * lc2 * theta2.cos())
        + i1
        + i2;
    let d2 = m2 * (lc2 * lc2 + l1 * lc2 * theta2.cos()) + i2;
    let phi2 = m2 * lc2 * G * (theta1 + theta2 - std::f32::consts::FRAC_PI_2).cos();
    let phi1 = -m2 * l1 * lc2 * dtheta2 * dtheta2 * theta2.sin()
        - 2.0 * m2 * l1 * lc2 * dtheta2 * dtheta1 * theta2.sin()
        + (m1 * lc1 + m2 * l1) * G * (theta1 - std::f32::consts::FRAC_PI_2).cos()
        + phi2;
    // "book" variant of ddtheta2.
    let ddtheta2 = (torque + d2 / d1 * phi1
        - m2 * l1 * lc2 * dtheta1 * dtheta1 * theta2.sin()
        - phi2)
        / (m2 * lc2 * lc2 + i2 - d2 * d2 / d1);
    let ddtheta1 = -(d2 * ddtheta2 + phi1) / d1;
    [dtheta1, dtheta2, ddtheta1, ddtheta2]
}

/// One RK4 step of size `DT` (Gym integrates over `[0, dt]` in one step).
fn rk4(s: [f32; 4], torque: f32) -> [f32; 4] {
    let add = |a: [f32; 4], b: [f32; 4], h: f32| {
        [a[0] + h * b[0], a[1] + h * b[1], a[2] + h * b[2], a[3] + h * b[3]]
    };
    let k1 = dsdt(s, torque);
    let k2 = dsdt(add(s, k1, DT / 2.0), torque);
    let k3 = dsdt(add(s, k2, DT / 2.0), torque);
    let k4 = dsdt(add(s, k3, DT), torque);
    [
        s[0] + DT / 6.0 * (k1[0] + 2.0 * k2[0] + 2.0 * k3[0] + k4[0]),
        s[1] + DT / 6.0 * (k1[1] + 2.0 * k2[1] + 2.0 * k3[1] + k4[1]),
        s[2] + DT / 6.0 * (k1[2] + 2.0 * k2[2] + 2.0 * k3[2] + k4[2]),
        s[3] + DT / 6.0 * (k1[3] + 2.0 * k2[3] + 2.0 * k3[3] + k4[3]),
    ]
}

impl Acrobot {
    pub fn new() -> Self {
        Acrobot {
            state: [0.0; 4],
            rng: Pcg32::new(0, 0x2545f4914f6cdd1d),
            done: true,
        }
    }

    pub fn state(&self) -> [f32; 4] {
        self.state
    }

    pub fn set_state(&mut self, s: [f32; 4]) {
        self.state = s;
        self.done = false;
    }

    /// A fused SoA batch of `lanes` acrobots ([`CartPole::batch`]
    /// (crate::envs::CartPole::batch) semantics).
    pub fn batch(lanes: usize, max_steps: Option<u32>) -> FusedBatch<AcrobotLanes> {
        FusedBatch::new(
            AcrobotLanes {
                theta1: vec![0.0; lanes],
                theta2: vec![0.0; lanes],
                dtheta1: vec![0.0; lanes],
                dtheta2: vec![0.0; lanes],
            },
            max_steps,
        )
    }

    /// Pure dynamics: one environment step on an explicit state.
    pub fn dynamics(s: [f32; 4], action: usize) -> ([f32; 4], bool) {
        let torque = action as f32 - 1.0;
        let mut ns = rk4(s, torque);
        ns[0] = wrap(ns[0], -std::f32::consts::PI, std::f32::consts::PI);
        ns[1] = wrap(ns[1], -std::f32::consts::PI, std::f32::consts::PI);
        ns[2] = ns[2].clamp(-MAX_VEL_1, MAX_VEL_1);
        ns[3] = ns[3].clamp(-MAX_VEL_2, MAX_VEL_2);
        let done = -ns[0].cos() - (ns[1] + ns[0]).cos() > 1.0;
        (ns, done)
    }

    fn write_obs(&self, obs: &mut [f32]) {
        let [t1, t2, dt1, dt2] = self.state;
        obs[0] = t1.cos();
        obs[1] = t1.sin();
        obs[2] = t2.cos();
        obs[3] = t2.sin();
        obs[4] = dt1;
        obs[5] = dt2;
    }
}

impl Default for Acrobot {
    fn default() -> Self {
        Self::new()
    }
}

/// The Gym observation-space bounds — one definition shared by the
/// scalar env and the fused lane kernel.
fn obs_space() -> Space {
    Space::box1(
        vec![-1.0, -1.0, -1.0, -1.0, -MAX_VEL_1, -MAX_VEL_2],
        vec![1.0, 1.0, 1.0, 1.0, MAX_VEL_1, MAX_VEL_2],
    )
}

impl Env for Acrobot {
    fn id(&self) -> String {
        "Acrobot-v1".into()
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 3 }
    }

    fn obs_dim(&self) -> usize {
        6
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x2545f4914f6cdd1d);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        for s in self.state.iter_mut() {
            *s = self.rng.uniform(-0.1, 0.1);
        }
        self.done = false;
        self.write_obs(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        debug_assert!(!self.done, "step() called on a finished episode");
        let (ns, done) = Self::dynamics(self.state, action.index());
        self.state = ns;
        self.done = done;
        self.write_obs(obs);
        Transition {
            reward: if done { 0.0 } else { -1.0 },
            done,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        software::paint_acrobot(fb, self.state[0], self.state[1]);
    }
}

/// SoA state columns of a fused acrobot group ([`Acrobot::batch`]).
pub struct AcrobotLanes {
    theta1: Vec<f32>,
    theta2: Vec<f32>,
    dtheta1: Vec<f32>,
    dtheta2: Vec<f32>,
}

impl LaneKernel for AcrobotLanes {
    fn obs_dim(&self) -> usize {
        6
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 3 }
    }

    fn rng_stream(&self) -> u64 {
        0x2545f4914f6cdd1d
    }

    fn lanes(&self) -> usize {
        self.theta1.len()
    }

    fn reset_lane(&mut self, k: usize, rng: &mut Pcg32, obs: &mut [f32]) {
        // Draw order matches the scalar `reset_into` (state array order).
        self.theta1[k] = rng.uniform(-0.1, 0.1);
        self.theta2[k] = rng.uniform(-0.1, 0.1);
        self.dtheta1[k] = rng.uniform(-0.1, 0.1);
        self.dtheta2[k] = rng.uniform(-0.1, 0.1);
        self.write_obs(k, obs);
    }

    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let s = [self.theta1[k], self.theta2[k], self.dtheta1[k], self.dtheta2[k]];
        let (ns, done) = Acrobot::dynamics(s, action.index());
        [self.theta1[k], self.theta2[k], self.dtheta1[k], self.dtheta2[k]] = ns;
        self.write_obs(k, obs);
        Transition {
            reward: if done { 0.0 } else { -1.0 },
            done,
            truncated: false,
        }
    }
}

impl AcrobotLanes {
    fn write_obs(&self, k: usize, obs: &mut [f32]) {
        obs[0] = self.theta1[k].cos();
        obs[1] = self.theta1[k].sin();
        obs[2] = self.theta2[k].cos();
        obs[3] = self.theta2[k].sin();
        obs[4] = self.dtheta1[k];
        obs[5] = self.dtheta2[k];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_is_trig_encoded() {
        let mut env = Acrobot::new();
        env.set_state([0.5, -0.3, 1.0, -2.0]);
        let mut obs = [0.0f32; 6];
        env.write_obs(&mut obs);
        assert!((obs[0] - 0.5f32.cos()).abs() < 1e-6);
        assert!((obs[1] - 0.5f32.sin()).abs() < 1e-6);
        assert!((obs[4] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn hanging_at_rest_is_stable_without_torque() {
        // theta = 0 (both links straight down) is an equilibrium.
        let (ns, done) = Acrobot::dynamics([0.0; 4], 1);
        for v in ns {
            assert!(v.abs() < 1e-5, "{ns:?}");
        }
        assert!(!done);
    }

    #[test]
    fn torque_accelerates_second_joint() {
        let (right, _) = Acrobot::dynamics([0.0; 4], 2);
        let (left, _) = Acrobot::dynamics([0.0; 4], 0);
        assert!(right[3] > 0.0);
        assert!(left[3] < 0.0);
        assert!((right[3] + left[3]).abs() < 1e-5, "symmetric response");
    }

    #[test]
    fn angles_wrap_to_pi() {
        let (ns, _) = Acrobot::dynamics([3.1, -3.1, 4.0, -4.0], 2);
        assert!(ns[0].abs() <= std::f32::consts::PI + 1e-5);
        assert!(ns[1].abs() <= std::f32::consts::PI + 1e-5);
    }

    #[test]
    fn velocities_clamped() {
        let (ns, _) = Acrobot::dynamics([0.0, 0.0, 100.0, 100.0], 2);
        assert!(ns[2] <= MAX_VEL_1);
        assert!(ns[3] <= MAX_VEL_2);
    }

    #[test]
    fn termination_when_tip_above_bar() {
        // theta1 = pi (first link straight up), theta2 = 0:
        // -cos(pi) - cos(pi) = 2 > 1 -> the *previous* state already
        // satisfies it, but termination is evaluated on the next state, so
        // drive from a nearly-up state with zero velocity.
        let (_, done) = Acrobot::dynamics([std::f32::consts::PI - 0.01, 0.0, 0.0, 0.0], 1);
        assert!(done);
    }

    #[test]
    fn episode_reward_is_negative_until_done() {
        let mut env = Acrobot::new();
        env.seed(1);
        let mut obs = [0.0f32; 6];
        env.reset_into(&mut obs);
        let t = env.step_into(&Action::Discrete(1), &mut obs);
        assert_eq!(t.reward, -1.0);
    }

    #[test]
    fn reset_reproducible() {
        let mut env = Acrobot::new();
        env.seed(9);
        let a = env.reset();
        env.seed(9);
        let b = env.reset();
        assert_eq!(a, b);
    }
}
