//! CartPole-v1 — exact port of the Gym dynamics (explicit Euler).
//!
//! Constants and update order match `gym/envs/classic_control/cartpole.py`
//! and the L1 kernel (`python/compile/kernels/env_step.py`) to the f32
//! operation: the integration tests step all three implementations with
//! identical states and assert trajectory agreement.

use crate::core::batch::{FusedBatch, LaneKernel};
use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{software, Framebuffer};

pub const GRAVITY: f32 = 9.8;
pub const MASS_CART: f32 = 1.0;
pub const MASS_POLE: f32 = 0.1;
pub const TOTAL_MASS: f32 = MASS_CART + MASS_POLE;
pub const LENGTH: f32 = 0.5; // half pole length
pub const POLEMASS_LENGTH: f32 = MASS_POLE * LENGTH;
pub const FORCE_MAG: f32 = 10.0;
pub const TAU: f32 = 0.02;
pub const THETA_THRESHOLD: f32 = 12.0 * 2.0 * std::f32::consts::PI / 360.0;
pub const X_THRESHOLD: f32 = 2.4;

/// The Gym observation-space bounds — one definition shared by the
/// scalar env and the fused lane kernel (the fused `NormalizeObs`
/// epilogue derives its affine factors from these, so the two impls
/// must never diverge).
fn obs_space() -> Space {
    Space::box1(
        vec![-X_THRESHOLD * 2.0, f32::MIN, -THETA_THRESHOLD * 2.0, f32::MIN],
        vec![X_THRESHOLD * 2.0, f32::MAX, THETA_THRESHOLD * 2.0, f32::MAX],
    )
}

/// The cart-pole balancing task.  Observation `[x, x_dot, theta,
/// theta_dot]`, actions `{0: push left, 1: push right}`, reward 1 per
/// step, terminal when `|x| > 2.4` or `|theta| > 12 deg`.
#[derive(Clone, Debug)]
pub struct CartPole {
    state: [f32; 4],
    rng: Pcg32,
    done: bool,
}

impl CartPole {
    pub fn new() -> Self {
        CartPole {
            state: [0.0; 4],
            rng: Pcg32::new(0, 0x9e3779b97f4a7c15),
            done: true,
        }
    }

    /// Direct state access (benchmarks, renderers, golden tests).
    pub fn state(&self) -> [f32; 4] {
        self.state
    }

    /// Set the state directly (cross-implementation trajectory tests).
    pub fn set_state(&mut self, s: [f32; 4]) {
        self.state = s;
        self.done = false;
    }

    /// A fused SoA batch of `lanes` cart-poles: state in parallel
    /// columns, physics stepped in one tight loop, the registered
    /// `TimeLimit` (`max_steps`) and auto-reset folded in.  Trajectories
    /// are bit-identical to per-lane `TimeLimit<CartPole>` scalars with
    /// the same seeds (`rust/tests/batch_kernel.rs`).
    pub fn batch(lanes: usize, max_steps: Option<u32>) -> FusedBatch<CartPoleLanes> {
        FusedBatch::new(
            CartPoleLanes {
                x: vec![0.0; lanes],
                x_dot: vec![0.0; lanes],
                theta: vec![0.0; lanes],
                theta_dot: vec![0.0; lanes],
            },
            max_steps,
        )
    }

    /// One step of the dynamics on an explicit state — the pure function
    /// shared by this env, the vectorised executor and the tests.
    #[inline]
    pub fn dynamics(s: [f32; 4], push_right: bool) -> ([f32; 4], bool) {
        let [mut x, mut x_dot, mut theta, mut theta_dot] = s;
        let force = if push_right { FORCE_MAG } else { -FORCE_MAG };
        let costheta = theta.cos();
        let sintheta = theta.sin();
        let temp =
            (force + POLEMASS_LENGTH * theta_dot * theta_dot * sintheta) / TOTAL_MASS;
        let thetaacc = (GRAVITY * sintheta - costheta * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * costheta * costheta / TOTAL_MASS));
        let xacc = temp - POLEMASS_LENGTH * thetaacc * costheta / TOTAL_MASS;
        // Explicit Euler, position updated with the *old* velocity (Gym's
        // "euler" kinematics integrator).
        x += TAU * x_dot;
        x_dot += TAU * xacc;
        theta += TAU * theta_dot;
        theta_dot += TAU * thetaacc;
        let done = !(-X_THRESHOLD..=X_THRESHOLD).contains(&x)
            || !(-THETA_THRESHOLD..=THETA_THRESHOLD).contains(&theta);
        ([x, x_dot, theta, theta_dot], done)
    }
}

impl Default for CartPole {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for CartPole {
    fn id(&self) -> String {
        "CartPole-v1".into()
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 2 }
    }

    fn obs_dim(&self) -> usize {
        4
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x9e3779b97f4a7c15);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        for s in self.state.iter_mut() {
            *s = self.rng.uniform(-0.05, 0.05);
        }
        self.done = false;
        obs.copy_from_slice(&self.state);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        debug_assert!(!self.done, "step() called on a finished episode");
        let push_right = action.index() == 1;
        let (next, done) = Self::dynamics(self.state, push_right);
        self.state = next;
        self.done = done;
        obs.copy_from_slice(&self.state);
        // Gym: reward 1.0 on every step, including the terminating one.
        Transition {
            reward: 1.0,
            done,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        software::paint_cartpole(fb, self.state[0], self.state[2]);
    }
}

/// SoA state columns of a fused cart-pole group ([`CartPole::batch`]):
/// one `Vec<f32>` per state variable, stepped through the same
/// [`CartPole::dynamics`] as the scalar env.
pub struct CartPoleLanes {
    x: Vec<f32>,
    x_dot: Vec<f32>,
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
}

impl LaneKernel for CartPoleLanes {
    fn obs_dim(&self) -> usize {
        4
    }

    fn observation_space(&self) -> Space {
        obs_space()
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 2 }
    }

    fn rng_stream(&self) -> u64 {
        0x9e3779b97f4a7c15
    }

    fn lanes(&self) -> usize {
        self.x.len()
    }

    fn reset_lane(&mut self, k: usize, rng: &mut Pcg32, obs: &mut [f32]) {
        // Draw order matches the scalar `reset_into` (state array order).
        self.x[k] = rng.uniform(-0.05, 0.05);
        self.x_dot[k] = rng.uniform(-0.05, 0.05);
        self.theta[k] = rng.uniform(-0.05, 0.05);
        self.theta_dot[k] = rng.uniform(-0.05, 0.05);
        obs.copy_from_slice(&[self.x[k], self.x_dot[k], self.theta[k], self.theta_dot[k]]);
    }

    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let s = [self.x[k], self.x_dot[k], self.theta[k], self.theta_dot[k]];
        let (next, done) = CartPole::dynamics(s, action.index() == 1);
        [self.x[k], self.x_dot[k], self.theta[k], self.theta_dot[k]] = next;
        obs.copy_from_slice(&next);
        Transition {
            reward: 1.0,
            done,
            truncated: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_is_seeded_and_small() {
        let mut env = CartPole::new();
        env.seed(42);
        let a = env.reset();
        env.seed(42);
        let b = env.reset();
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.abs() <= 0.05));
    }

    #[test]
    fn different_seeds_differ() {
        let mut env = CartPole::new();
        env.seed(1);
        let a = env.reset();
        env.seed(2);
        let b = env.reset();
        assert_ne!(a, b);
    }

    #[test]
    fn push_right_from_rest_moves_right() {
        let mut env = CartPole::new();
        env.set_state([0.0; 4]);
        let mut obs = [0.0; 4];
        let t = env.step_into(&Action::Discrete(1), &mut obs);
        assert!(obs[1] > 0.0, "x_dot should increase");
        assert!(obs[3] < 0.0, "pole lags left");
        assert!(!t.done);
        assert_eq!(t.reward, 1.0);
    }

    #[test]
    fn terminates_on_angle() {
        let mut env = CartPole::new();
        env.set_state([0.0, 0.0, THETA_THRESHOLD - 1e-4, 3.0]);
        let mut obs = [0.0; 4];
        let t = env.step_into(&Action::Discrete(1), &mut obs);
        assert!(t.done);
        assert_eq!(t.reward, 1.0);
    }

    #[test]
    fn terminates_on_position() {
        let mut env = CartPole::new();
        env.set_state([X_THRESHOLD - 1e-4, 5.0, 0.0, 0.0]);
        let mut obs = [0.0; 4];
        let t = env.step_into(&Action::Discrete(0), &mut obs);
        assert!(t.done);
    }

    #[test]
    fn dynamics_matches_kernel_golden() {
        // Same inputs as the aot.py golden: state [0,0,0.05,0], action 1
        // and state [1,-0.5,-0.1,0.2], action 0.  Exact values are
        // asserted against manifest.json in the integration tests; here we
        // pin the qualitative fields.
        let (s1, d1) = CartPole::dynamics([0.0, 0.0, 0.05, 0.0], true);
        assert!(!d1);
        assert_eq!(s1[0], 0.0); // x unchanged on first Euler step (x_dot was 0)
        assert!(s1[1] > 0.0);
        let (s2, d2) = CartPole::dynamics([1.0, -0.5, -0.1, 0.2], false);
        assert!(!d2);
        assert!((s2[0] - (1.0 - 0.5 * TAU)).abs() < 1e-6);
    }

    #[test]
    fn random_policy_fails_quickly() {
        // Balancing untrained should end well before 200 steps on average.
        let mut env = CartPole::new();
        env.seed(0);
        let mut rng = Pcg32::new(1, 1);
        let mut total = 0u32;
        let trials = 50;
        for _ in 0..trials {
            let (_, len) = crate::core::env::random_rollout(&mut env, &mut rng, 500);
            total += len;
        }
        let avg = total as f32 / trials as f32;
        assert!((10.0..70.0).contains(&avg), "avg episode len {avg}");
    }

    #[test]
    fn render_paints_cart() {
        let mut env = CartPole::new();
        env.set_state([0.0; 4]);
        let mut fb = Framebuffer::standard();
        env.render(&mut fb);
        assert!(fb.sum() > 10.0);
    }
}
