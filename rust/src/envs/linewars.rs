//! LineWars — a Deep-Line-Wars-class lane strategy environment
//! (the paper names Deep Line Wars among CaiRL's novel high-complexity
//! games, §III).
//!
//! Two players on a 1-D lane of length [`LANE`].  Each tick both earn
//! income; a player may spend gold to send a unit (three tiers).  Units
//! march toward the enemy base, fight on contact (simultaneous damage),
//! and damage the base on arrival.  First base to fall loses; income
//! grows each time a unit is *sent* (economy scaling), so there is a
//! real aggression/economy trade-off.
//!
//! Single-agent [`Env`]: player 0 versus a scripted balanced opponent.
//! Actions: 0 save, 1 send grunt (cost 10), 2 send soldier (cost 25),
//! 3 send tank (cost 60).

use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{raster, Framebuffer};

pub const LANE: f32 = 100.0;
pub const BASE_HP: f32 = 50.0;
pub const MAX_TICKS: u32 = 2_000;
/// (cost, hp, damage, speed) per unit tier.
pub const TIERS: [(f32, f32, f32, f32); 3] = [
    (10.0, 10.0, 2.0, 1.2),
    (25.0, 30.0, 4.0, 0.9),
    (60.0, 90.0, 8.0, 0.6),
];
pub const BASE_INCOME: f32 = 1.0;
/// Income added per unit sent (economy scaling).
pub const INCOME_PER_SEND: f32 = 0.02;

/// One marching unit.
#[derive(Clone, Copy, Debug)]
pub struct Unit {
    /// Position from its owner's base (0 = home, LANE = enemy base).
    pub pos: f32,
    pub hp: f32,
    pub dmg: f32,
    pub speed: f32,
}

/// Per-player economy and army.
#[derive(Clone, Debug)]
pub struct Side {
    pub base_hp: f32,
    pub gold: f32,
    pub income: f32,
    pub units: Vec<Unit>,
}

impl Side {
    fn new() -> Side {
        Side {
            base_hp: BASE_HP,
            gold: 20.0,
            income: BASE_INCOME,
            units: Vec::new(),
        }
    }

    fn send(&mut self, tier: usize) -> bool {
        let (cost, hp, dmg, speed) = TIERS[tier];
        if self.gold < cost {
            return false;
        }
        self.gold -= cost;
        self.income += INCOME_PER_SEND * cost;
        self.units.push(Unit {
            pos: 0.0,
            hp,
            dmg,
            speed,
        });
        true
    }
}

/// The two-sided game state.
#[derive(Clone, Debug)]
pub struct LineWarsState {
    pub sides: [Side; 2],
    pub tick: u32,
}

impl LineWarsState {
    fn new() -> LineWarsState {
        LineWarsState {
            sides: [Side::new(), Side::new()],
            tick: 0,
        }
    }

    /// Advance one tick with both players' actions (0..=3).
    /// Returns shaping rewards for player 0.
    pub fn step(&mut self, a0: usize, a1: usize) -> f32 {
        let mut reward = 0.0;
        for (i, a) in [(0usize, a0), (1usize, a1)] {
            self.sides[i].gold += self.sides[i].income * 0.1;
            if (1..=3).contains(&a) && self.sides[i].send(a - 1) && i == 0 {
                reward += 0.01; // tiny shaping for acting
            }
        }
        // March.
        for side in self.sides.iter_mut() {
            for u in side.units.iter_mut() {
                u.pos += u.speed;
            }
        }
        // Combat: front unit of each side fights when they meet
        // (positions measured from opposite ends: meet when
        // pos0 + pos1 >= LANE).
        loop {
            let (front0, front1) = (self.front(0), self.front(1));
            let (Some(f0), Some(f1)) = (front0, front1) else { break };
            if self.sides[0].units[f0].pos + self.sides[1].units[f1].pos < LANE {
                break;
            }
            let d0 = self.sides[0].units[f0].dmg;
            let d1 = self.sides[1].units[f1].dmg;
            self.sides[0].units[f0].hp -= d1;
            self.sides[1].units[f1].hp -= d0;
            let dead0 = self.sides[0].units[f0].hp <= 0.0;
            let dead1 = self.sides[1].units[f1].hp <= 0.0;
            if dead0 {
                self.sides[0].units.remove(f0);
                reward -= 0.05;
            }
            if dead1 {
                self.sides[1].units.remove(f1);
                reward += 0.05;
            }
            if !dead0 && !dead1 {
                break; // both alive: combat continues next tick
            }
        }
        // Arrivals damage bases.
        for i in 0..2 {
            let enemy = 1 - i;
            let mut k = 0;
            while k < self.sides[i].units.len() {
                if self.sides[i].units[k].pos >= LANE {
                    let dmg = self.sides[i].units[k].dmg;
                    self.sides[enemy].base_hp -= dmg;
                    self.sides[i].units.remove(k);
                    reward += if i == 0 { 0.2 } else { -0.2 };
                } else {
                    k += 1;
                }
            }
        }
        self.tick += 1;
        reward
    }

    /// Index of the foremost unit of `side`.
    fn front(&self, side: usize) -> Option<usize> {
        self.sides[side]
            .units
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.pos.partial_cmp(&b.1.pos).unwrap())
            .map(|(i, _)| i)
    }

    pub fn winner(&self) -> Option<usize> {
        if self.sides[1].base_hp <= 0.0 {
            Some(0)
        } else if self.sides[0].base_hp <= 0.0 {
            Some(1)
        } else {
            None
        }
    }

    pub fn over(&self) -> bool {
        self.winner().is_some() || self.tick >= MAX_TICKS
    }

    /// Lane occupancy histogram for one side: unit hp mass in `buckets`
    /// bins along the lane (the observation encoding).
    pub fn occupancy(&self, side: usize, buckets: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; buckets];
        for u in &self.sides[side].units {
            let b = ((u.pos / LANE) * buckets as f32) as usize;
            out[b.min(buckets - 1)] += u.hp / 100.0;
        }
        out
    }
}

/// The scripted opponent: saves to a tier threshold, then sends —
/// a balanced economy/aggression baseline.
fn scripted_opponent(state: &LineWarsState, rng: &mut Pcg32) -> usize {
    let me = &state.sides[1];
    if me.gold >= 60.0 && rng.chance(0.5) {
        3
    } else if me.gold >= 25.0 && rng.chance(0.4) {
        2
    } else if me.gold >= 10.0 && rng.chance(0.3) {
        1
    } else {
        0
    }
}

const BUCKETS: usize = 8;

/// LineWars as a single-agent environment (player 0).
///
/// Observation (4 + 2*BUCKETS = 20 floats, normalised): own base hp,
/// enemy base hp, own gold/100, own income/5, own lane occupancy
/// (BUCKETS), enemy lane occupancy (BUCKETS).
pub struct LineWars {
    state: LineWarsState,
    rng: Pcg32,
}

impl LineWars {
    pub fn new() -> LineWars {
        LineWars {
            state: LineWarsState::new(),
            rng: Pcg32::new(0, 0x94d049bb133111eb),
        }
    }

    pub fn game_state(&self) -> &LineWarsState {
        &self.state
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs[0] = self.state.sides[0].base_hp / BASE_HP;
        obs[1] = self.state.sides[1].base_hp / BASE_HP;
        obs[2] = (self.state.sides[0].gold / 100.0).min(2.0);
        obs[3] = (self.state.sides[0].income / 5.0).min(2.0);
        let own = self.state.occupancy(0, BUCKETS);
        let foe = self.state.occupancy(1, BUCKETS);
        obs[4..4 + BUCKETS].copy_from_slice(&own);
        obs[4 + BUCKETS..4 + 2 * BUCKETS].copy_from_slice(&foe);
    }
}

impl Default for LineWars {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for LineWars {
    fn id(&self) -> String {
        "LineWars-v0".into()
    }

    fn observation_space(&self) -> Space {
        let d = 4 + 2 * BUCKETS;
        Space::box1(vec![0.0; d], vec![2.0; d])
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 4 }
    }

    fn obs_dim(&self) -> usize {
        4 + 2 * BUCKETS
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x94d049bb133111eb);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.state = LineWarsState::new();
        self.write_obs(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let a1 = scripted_opponent(&self.state, &mut self.rng);
        let mut reward = self.state.step(action.index(), a1);
        let done = self.state.over();
        if let Some(w) = self.state.winner() {
            reward += if w == 0 { 10.0 } else { -10.0 };
        }
        self.write_obs(obs);
        Transition {
            reward,
            done,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        fb.clear(0.0);
        let w = fb.width() as f32;
        let mid = fb.height() as f32 / 2.0;
        raster::hline(fb, mid as i32, 0.2);
        // Bases.
        let hp0 = self.state.sides[0].base_hp / BASE_HP;
        let hp1 = self.state.sides[1].base_hp / BASE_HP;
        raster::fill_rect(fb, 0, (mid - 8.0) as i32, 4, (mid + 8.0) as i32, 0.3 + 0.5 * hp0);
        raster::fill_rect(
            fb,
            fb.width() as i32 - 4,
            (mid - 8.0) as i32,
            fb.width() as i32,
            (mid + 8.0) as i32,
            0.3 + 0.5 * hp1,
        );
        // Units: player 0 above the line, player 1 below.
        for u in &self.state.sides[0].units {
            let x = 4.0 + (u.pos / LANE) * (w - 8.0);
            raster::fill_disc(fb, x, mid - 4.0, 2.0, 1.0);
        }
        for u in &self.state.sides[1].units {
            let x = w - 4.0 - (u.pos / LANE) * (w - 8.0);
            raster::fill_disc(fb, x, mid + 4.0, 2.0, 0.6);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sending_units_costs_gold_and_grows_income() {
        let mut s = LineWarsState::new();
        let gold = s.sides[0].gold;
        let income = s.sides[0].income;
        assert!(s.sides[0].send(0));
        assert!(s.sides[0].gold < gold);
        assert!(s.sides[0].income > income);
        assert_eq!(s.sides[0].units.len(), 1);
    }

    #[test]
    fn cannot_send_without_gold() {
        let mut s = LineWarsState::new();
        s.sides[0].gold = 5.0;
        assert!(!s.sides[0].send(2));
        assert!(s.sides[0].units.is_empty());
    }

    #[test]
    fn unopposed_unit_damages_base() {
        let mut s = LineWarsState::new();
        s.sides[0].send(0);
        let hp = s.sides[1].base_hp;
        for _ in 0..200 {
            s.step(0, 0);
            if s.sides[1].base_hp < hp {
                return;
            }
        }
        panic!("unit never arrived");
    }

    #[test]
    fn opposing_units_fight_and_tank_beats_grunt() {
        let mut s = LineWarsState::new();
        s.sides[0].gold = 100.0;
        s.sides[1].gold = 100.0;
        s.sides[0].send(2); // tank
        s.sides[1].send(0); // grunt
        for _ in 0..300 {
            s.step(0, 0);
            if s.over() {
                break;
            }
        }
        // The grunt dies; the tank survives to damage the enemy base.
        assert!(s.sides[1].base_hp < BASE_HP, "{:?}", s.sides[1]);
        assert_eq!(s.winner(), None); // one tank doesn't raze a base
    }

    #[test]
    fn aggressive_player_beats_idle() {
        let mut s = LineWarsState::new();
        let mut ticks = 0;
        while !s.over() && ticks < MAX_TICKS {
            // Player 0 sends grunts whenever affordable; player 1 idles.
            let a0 = if s.sides[0].gold >= 10.0 { 1 } else { 0 };
            s.step(a0, 0);
            ticks += 1;
        }
        assert_eq!(s.winner(), Some(0));
    }

    #[test]
    fn env_episode_terminates_and_obs_normalised() {
        let mut env = LineWars::new();
        env.seed(1);
        let mut rng = Pcg32::new(2, 2);
        let (ret, len) =
            crate::core::env::random_rollout(&mut env, &mut rng, MAX_TICKS + 10);
        assert!(len <= MAX_TICKS);
        assert!(ret.is_finite());
        let obs = env.reset();
        assert_eq!(obs.len(), 20);
        assert!(obs.iter().all(|v| (0.0..=2.0).contains(v)));
    }

    #[test]
    fn occupancy_histogram_tracks_positions() {
        let mut s = LineWarsState::new();
        s.sides[0].send(0);
        for _ in 0..10 {
            s.step(0, 0);
        }
        let occ = s.occupancy(0, 8);
        assert!(occ.iter().sum::<f32>() > 0.0);
        // Unit at pos ~12 of 100 -> bucket 0 of 8 covers [0, 12.5).
        assert!(occ[0] > 0.0 || occ[1] > 0.0);
    }

    #[test]
    fn render_shows_lane_and_bases() {
        let mut env = LineWars::new();
        env.seed(0);
        env.reset();
        let mut fb = Framebuffer::standard();
        env.render(&mut fb);
        assert!(fb.sum() > 5.0);
    }
}
