//! GridRTS — a MicroRTS-class real-time-strategy substrate.
//!
//! Stands in for the paper's JVM runner environments (MicroRTS via JNI,
//! §IV-A): a small two-player RTS on a grid with bases, workers, resource
//! harvesting and combat.  It serves three roles:
//!
//! 1. an [`Env`] (player 0 controls a champion worker against a scripted
//!    opponent) so RL agents can train on an adversarial task,
//! 2. a two-[`Bot`] match runner ([`play_match`]) feeding the tournament
//!    tooling (§III-A "Tooling"),
//! 3. a stress test for the toolkit API beyond 1-D physics tasks.
//!
//! Rules (a distilled MicroRTS): each player owns a base and one worker.
//! Workers move orthogonally, harvest from adjacent resource nodes (one
//! unit of ore per step, capacity 1), deliver to their adjacent base
//! (+1 stored), and attack adjacent enemies (1 damage).  Destroying the
//! enemy base wins.  The game is simultaneous-move with deterministic
//! conflict resolution (player 0 resolves first on even ticks, player 1
//! on odd ticks — removes first-mover bias over a match).

use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{raster, Framebuffer};

pub const MAP_W: i32 = 8;
pub const MAP_H: i32 = 8;
pub const BASE_HP: i32 = 10;
pub const WORKER_HP: i32 = 4;
pub const RESOURCE_AMOUNT: i32 = 20;
pub const MAX_TICKS: u32 = 400;

/// Unit actions, also the RL action space (6 discrete actions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnitAction {
    /// Move north/south/east/west (0-3).
    Move(i32, i32),
    /// Harvest an adjacent resource or deliver to an adjacent base (4).
    Work,
    /// Attack an adjacent enemy unit or base (5).
    Attack,
}

impl UnitAction {
    /// Decode an RL discrete action index.
    pub fn from_index(i: usize) -> UnitAction {
        match i {
            0 => UnitAction::Move(0, -1),
            1 => UnitAction::Move(0, 1),
            2 => UnitAction::Move(1, 0),
            3 => UnitAction::Move(-1, 0),
            4 => UnitAction::Work,
            _ => UnitAction::Attack,
        }
    }
}

/// One player's pieces.
#[derive(Clone, Debug)]
pub struct PlayerState {
    pub base: (i32, i32),
    pub base_hp: i32,
    pub worker: (i32, i32),
    pub worker_hp: i32,
    pub carrying: bool,
    pub stored: i32,
}

/// Full game state (public to bots — perfect information, like MicroRTS).
#[derive(Clone, Debug)]
pub struct GameState {
    pub players: [PlayerState; 2],
    pub resources: Vec<((i32, i32), i32)>,
    pub tick: u32,
}

impl GameState {
    fn new() -> Self {
        GameState {
            players: [
                PlayerState {
                    base: (0, 0),
                    base_hp: BASE_HP,
                    worker: (1, 1),
                    worker_hp: WORKER_HP,
                    carrying: false,
                    stored: 0,
                },
                PlayerState {
                    base: (MAP_W - 1, MAP_H - 1),
                    base_hp: BASE_HP,
                    worker: (MAP_W - 2, MAP_H - 2),
                    worker_hp: WORKER_HP,
                    carrying: false,
                    stored: 0,
                },
            ],
            resources: vec![
                ((MAP_W / 2, 1), RESOURCE_AMOUNT),
                ((MAP_W / 2 - 1, MAP_H - 2), RESOURCE_AMOUNT),
            ],
            tick: 0,
        }
    }

    fn occupied(&self, p: (i32, i32)) -> bool {
        self.players.iter().any(|pl| {
            (pl.base == p && pl.base_hp > 0) || (pl.worker == p && pl.worker_hp > 0)
        }) || self.resources.iter().any(|&(rp, amt)| rp == p && amt > 0)
    }

    fn adjacent(a: (i32, i32), b: (i32, i32)) -> bool {
        (a.0 - b.0).abs() + (a.1 - b.1).abs() == 1
    }

    /// Apply one unit action for `player`.  Returns the reward shaping
    /// delta for that player (deliveries and damage).
    fn apply(&mut self, player: usize, action: UnitAction) -> f32 {
        let enemy = 1 - player;
        if self.players[player].worker_hp <= 0 {
            return 0.0;
        }
        let wpos = self.players[player].worker;
        match action {
            UnitAction::Move(dx, dy) => {
                let np = (wpos.0 + dx, wpos.1 + dy);
                let in_bounds =
                    np.0 >= 0 && np.0 < MAP_W && np.1 >= 0 && np.1 < MAP_H;
                if in_bounds && !self.occupied(np) {
                    self.players[player].worker = np;
                }
                0.0
            }
            UnitAction::Work => {
                if self.players[player].carrying {
                    // Deliver to own base if adjacent.
                    if Self::adjacent(wpos, self.players[player].base) {
                        self.players[player].carrying = false;
                        self.players[player].stored += 1;
                        return 1.0;
                    }
                } else if let Some(r) = self
                    .resources
                    .iter_mut()
                    .find(|(rp, amt)| Self::adjacent(wpos, *rp) && *amt > 0)
                {
                    r.1 -= 1;
                    self.players[player].carrying = true;
                    return 0.1;
                }
                0.0
            }
            UnitAction::Attack => {
                if self.players[enemy].worker_hp > 0
                    && Self::adjacent(wpos, self.players[enemy].worker)
                {
                    self.players[enemy].worker_hp -= 1;
                    return if self.players[enemy].worker_hp == 0 { 1.0 } else { 0.2 };
                }
                if Self::adjacent(wpos, self.players[enemy].base) {
                    self.players[enemy].base_hp -= 1;
                    return if self.players[enemy].base_hp == 0 { 5.0 } else { 0.2 };
                }
                0.0
            }
        }
    }

    /// Advance one tick with both players' actions.  Returns per-player
    /// shaping rewards.
    pub fn step(&mut self, a0: UnitAction, a1: UnitAction) -> [f32; 2] {
        let mut rewards = [0.0f32; 2];
        // Alternate resolution order to remove first-mover bias.
        if self.tick % 2 == 0 {
            rewards[0] = self.apply(0, a0);
            rewards[1] = self.apply(1, a1);
        } else {
            rewards[1] = self.apply(1, a1);
            rewards[0] = self.apply(0, a0);
        }
        self.tick += 1;
        rewards
    }

    /// Some(player) when that player has won.
    pub fn winner(&self) -> Option<usize> {
        if self.players[1].base_hp <= 0 {
            Some(0)
        } else if self.players[0].base_hp <= 0 {
            Some(1)
        } else {
            None
        }
    }

    /// Game over (win or tick limit).
    pub fn over(&self) -> bool {
        self.winner().is_some() || self.tick >= MAX_TICKS
    }
}

/// A scripted or learned policy over full game states.
pub trait Bot: Send {
    fn name(&self) -> &str;
    fn act(&mut self, state: &GameState, player: usize) -> UnitAction;
}

/// Moves toward the enemy base and attacks it — the classic rush.
pub struct RushBot;

fn step_toward(from: (i32, i32), to: (i32, i32)) -> UnitAction {
    let dx = to.0 - from.0;
    let dy = to.1 - from.1;
    if dx.abs() >= dy.abs() && dx != 0 {
        UnitAction::Move(dx.signum(), 0)
    } else if dy != 0 {
        UnitAction::Move(0, dy.signum())
    } else {
        UnitAction::Attack
    }
}

impl Bot for RushBot {
    fn name(&self) -> &str {
        "rush"
    }
    fn act(&mut self, state: &GameState, player: usize) -> UnitAction {
        let me = &state.players[player];
        let enemy = &state.players[1 - player];
        if GameState::adjacent(me.worker, enemy.base)
            || (enemy.worker_hp > 0 && GameState::adjacent(me.worker, enemy.worker))
        {
            UnitAction::Attack
        } else {
            step_toward(me.worker, enemy.base)
        }
    }
}

/// Harvests the nearest resource and delivers — the economy strategy.
pub struct HarvestBot;

impl Bot for HarvestBot {
    fn name(&self) -> &str {
        "harvest"
    }
    fn act(&mut self, state: &GameState, player: usize) -> UnitAction {
        let me = &state.players[player];
        if me.carrying {
            if GameState::adjacent(me.worker, me.base) {
                UnitAction::Work
            } else {
                step_toward(me.worker, me.base)
            }
        } else {
            let target = state
                .resources
                .iter()
                .filter(|(_, amt)| *amt > 0)
                .min_by_key(|((x, y), _)| {
                    (x - me.worker.0).abs() + (y - me.worker.1).abs()
                });
            match target {
                Some((rp, _)) if GameState::adjacent(me.worker, *rp) => UnitAction::Work,
                Some((rp, _)) => step_toward(me.worker, *rp),
                None => UnitAction::Attack,
            }
        }
    }
}

/// Uniform random actions.
pub struct RandomBot(pub Pcg32);

impl Bot for RandomBot {
    fn name(&self) -> &str {
        "random"
    }
    fn act(&mut self, _state: &GameState, _player: usize) -> UnitAction {
        UnitAction::from_index(self.0.below(6) as usize)
    }
}

/// Match outcome for the tournament tooling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchResult {
    Win(usize),
    Draw,
}

/// Play one full game between two bots.
pub fn play_match(bot0: &mut dyn Bot, bot1: &mut dyn Bot) -> MatchResult {
    let mut state = GameState::new();
    while !state.over() {
        let a0 = bot0.act(&state, 0);
        let a1 = bot1.act(&state, 1);
        state.step(a0, a1);
    }
    match state.winner() {
        Some(p) => MatchResult::Win(p),
        None => {
            // Tick limit: most stored resources wins, else draw.
            let (s0, s1) = (state.players[0].stored, state.players[1].stored);
            if s0 > s1 {
                MatchResult::Win(0)
            } else if s1 > s0 {
                MatchResult::Win(1)
            } else {
                MatchResult::Draw
            }
        }
    }
}

/// GridRTS as a single-agent [`Env`]: player 0's worker is the agent,
/// player 1 is a scripted [`HarvestBot`] (economy race with skirmishes).
///
/// Observation (10 floats, all normalised to `[0, 1]`-ish ranges): own
/// worker xy, own base hp, carrying, stored; enemy worker xy, enemy
/// base hp, enemy stored; tick fraction.
pub struct GridRts {
    state: GameState,
    opponent: HarvestBot,
    rng: Pcg32,
}

impl GridRts {
    pub fn new() -> Self {
        GridRts {
            state: GameState::new(),
            opponent: HarvestBot,
            rng: Pcg32::new(0, 0xb5297a4d36f4d31b),
        }
    }

    pub fn game_state(&self) -> &GameState {
        &self.state
    }

    fn write_obs(&self, obs: &mut [f32]) {
        let me = &self.state.players[0];
        let foe = &self.state.players[1];
        obs[0] = me.worker.0 as f32 / MAP_W as f32;
        obs[1] = me.worker.1 as f32 / MAP_H as f32;
        obs[2] = me.base_hp as f32 / BASE_HP as f32;
        obs[3] = me.carrying as u8 as f32;
        obs[4] = me.stored as f32 / RESOURCE_AMOUNT as f32;
        obs[5] = foe.worker.0 as f32 / MAP_W as f32;
        obs[6] = foe.worker.1 as f32 / MAP_H as f32;
        obs[7] = foe.base_hp as f32 / BASE_HP as f32;
        obs[8] = foe.stored as f32 / RESOURCE_AMOUNT as f32;
        obs[9] = self.state.tick as f32 / MAX_TICKS as f32;
    }
}

impl Default for GridRts {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for GridRts {
    fn id(&self) -> String {
        "GridRTS-v0".into()
    }

    fn observation_space(&self) -> Space {
        Space::box1(vec![0.0; 10], vec![1.0; 10])
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: 6 }
    }

    fn obs_dim(&self) -> usize {
        10
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0xb5297a4d36f4d31b);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.state = GameState::new();
        self.write_obs(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let a0 = UnitAction::from_index(action.index());
        let a1 = self.opponent.act(&self.state, 1);
        let rewards = self.state.step(a0, a1);
        self.write_obs(obs);
        let done = self.state.over();
        let mut reward = rewards[0];
        if let Some(w) = self.state.winner() {
            reward += if w == 0 { 10.0 } else { -10.0 };
        }
        Transition {
            reward,
            done,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        fb.clear(0.0);
        let cw = fb.width() as f32 / MAP_W as f32;
        let ch = fb.height() as f32 / MAP_H as f32;
        let cell = |p: (i32, i32)| (p.0 as f32 * cw, p.1 as f32 * ch);
        for &(rp, amt) in &self.state.resources {
            if amt > 0 {
                let (x, y) = cell(rp);
                raster::fill_rect(fb, x as i32, y as i32, (x + cw) as i32, (y + ch) as i32, 0.4);
            }
        }
        for (i, pl) in self.state.players.iter().enumerate() {
            let base_i = if i == 0 { 0.8 } else { 0.6 };
            if pl.base_hp > 0 {
                let (x, y) = cell(pl.base);
                raster::fill_rect(fb, x as i32, y as i32, (x + cw) as i32, (y + ch) as i32, base_i);
            }
            if pl.worker_hp > 0 {
                let (x, y) = cell(pl.worker);
                raster::fill_disc(fb, x + cw / 2.0, y + ch / 2.0, cw / 3.0, base_i + 0.2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state_is_symmetric() {
        let s = GameState::new();
        assert_eq!(s.players[0].base_hp, BASE_HP);
        assert_eq!(s.players[1].base_hp, BASE_HP);
        assert_eq!(s.winner(), None);
        assert!(!s.over());
    }

    #[test]
    fn worker_moves_and_respects_bounds() {
        let mut s = GameState::new();
        let start = s.players[0].worker;
        s.apply(0, UnitAction::Move(1, 0));
        assert_eq!(s.players[0].worker, (start.0 + 1, start.1));
        // Walk into the west wall.
        let mut s2 = GameState::new();
        s2.players[0].worker = (0, 3);
        s2.apply(0, UnitAction::Move(-1, 0));
        assert_eq!(s2.players[0].worker, (0, 3));
    }

    #[test]
    fn cannot_move_onto_base_or_resource() {
        let mut s = GameState::new();
        s.players[0].worker = (0, 1); // south of own base at (0,0)
        s.apply(0, UnitAction::Move(0, -1));
        assert_eq!(s.players[0].worker, (0, 1));
    }

    #[test]
    fn harvest_then_deliver_increments_store() {
        let mut s = GameState::new();
        let rp = s.resources[0].0;
        s.players[0].worker = (rp.0 - 1, rp.1);
        let r1 = s.apply(0, UnitAction::Work);
        assert!(s.players[0].carrying);
        assert!(r1 > 0.0);
        assert_eq!(s.resources[0].1, RESOURCE_AMOUNT - 1);
        // Teleport next to the base and deliver.
        s.players[0].worker = (0, 1);
        let r2 = s.apply(0, UnitAction::Work);
        assert!(!s.players[0].carrying);
        assert_eq!(s.players[0].stored, 1);
        assert_eq!(r2, 1.0);
    }

    #[test]
    fn attacking_base_wins_eventually() {
        let mut s = GameState::new();
        s.players[0].worker = (MAP_W - 2, MAP_H - 1); // adjacent to enemy base
        // Attack prioritises the adjacent enemy worker, then the base.
        for _ in 0..(WORKER_HP + BASE_HP) {
            s.apply(0, UnitAction::Attack);
        }
        assert_eq!(s.players[1].worker_hp, 0);
        assert_eq!(s.winner(), Some(0));
    }

    #[test]
    fn killing_worker_stops_it() {
        let mut s = GameState::new();
        s.players[0].worker = (4, 4);
        s.players[1].worker = (5, 4);
        for _ in 0..WORKER_HP {
            s.apply(0, UnitAction::Attack);
        }
        assert_eq!(s.players[1].worker_hp, 0);
        // Dead worker can't act.
        let before = s.players[1].clone();
        s.apply(1, UnitAction::Move(0, 1));
        assert_eq!(s.players[1].worker, before.worker);
    }

    #[test]
    fn rush_beats_random() {
        let mut wins = 0;
        for seed in 0..10 {
            let mut rush = RushBot;
            let mut rand = RandomBot(Pcg32::new(seed, 1));
            if play_match(&mut rush, &mut rand) == MatchResult::Win(0) {
                wins += 1;
            }
        }
        assert!(wins >= 8, "rush won only {wins}/10 vs random");
    }

    #[test]
    fn rush_beats_harvest_but_harvest_outscores_random() {
        let mut rush = RushBot;
        let mut harvest = HarvestBot;
        // Rush destroys an undefended base before the economy matters.
        assert_eq!(play_match(&mut rush, &mut harvest), MatchResult::Win(0));
        // Harvest vs harvest is symmetric -> draw or very close.
        let mut h1 = HarvestBot;
        let mut h2 = HarvestBot;
        let r = play_match(&mut h1, &mut h2);
        assert!(matches!(r, MatchResult::Draw | MatchResult::Win(_)));
    }

    #[test]
    fn env_roundtrip_and_termination() {
        let mut env = GridRts::new();
        env.seed(0);
        let mut rng = Pcg32::new(2, 2);
        let (ret, len) = crate::core::env::random_rollout(&mut env, &mut rng, 2000);
        assert!(len <= MAX_TICKS);
        assert!(ret.is_finite());
    }

    #[test]
    fn env_obs_is_normalised() {
        let mut env = GridRts::new();
        env.seed(0);
        let obs = env.reset();
        assert_eq!(obs.len(), 10);
        assert!(obs.iter().all(|v| (0.0..=1.2).contains(v)));
    }

    #[test]
    fn render_distinguishes_players() {
        let mut env = GridRts::new();
        env.seed(0);
        env.reset();
        let mut fb = Framebuffer::standard();
        env.render(&mut fb);
        assert!(fb.sum() > 10.0);
        assert!(fb.max() == 1.0); // player-0 worker intensity
    }
}
