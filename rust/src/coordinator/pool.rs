//! Persistent-worker batched executors — the EnvPool-style scaling
//! substrate (Weng et al., 2022).
//!
//! The seed toolkit stepped `VecEnv` lanes sequentially, and its only
//! threaded path spawned throwaway threads per call.  This module
//! replaces that with **persistent workers that own lanes for the life
//! of the pool** and step them against shared `[n * obs_dim]` batch
//! buffers:
//!
//! * [`BatchedExecutor`] — the common executor interface.  `VecEnv`
//!   (sequential), [`EnvPool`] (threaded, synchronous) and
//!   [`AsyncEnvPool`] (threaded, workers run ahead) all implement it, so
//!   every workload can flip executors via configuration
//!   ([`crate::coordinator::config::ExecutorSettings`]).
//! * [`EnvPool`] — **sync mode**: one spin-barrier per batch.  Lane `i`
//!   is seeded `base_seed + i` and stepped in order by exactly one
//!   worker, so trajectories are **bit-identical to sequential
//!   `VecEnv`** for any thread count (`rust/tests/executor_pool.rs`
//!   pins this for every registered env id).  Threading is a pure
//!   performance transform, never a semantics change.
//! * [`AsyncEnvPool`] — **async mode**: workers step a lane the moment
//!   its action arrives; the coordinator exchanges
//!   [`AsyncEnvPool::send_actions`] / [`AsyncEnvPool::recv_batch`] over
//!   a ready-queue.  Batches come back compacted (`[k * obs_dim]` plus
//!   the lane ids) — EnvPool's XLA-friendly shape, where the learner
//!   consumes whatever subset of lanes is ready instead of waiting for
//!   stragglers.
//!
//! Auto-reset follows the `VecEnv` convention everywhere: a finished
//! lane's transition reports the episode end exactly once and its
//! observation is the first observation of the next episode.
//!
//! Synchronisation in sync mode is a seqlock-style broadcast
//! (`AtomicU64` command sequence + `AtomicUsize` completion count) with
//! bounded spinning before yielding, because a condvar wake costs more
//! than an entire batch of cheap classic-control steps.  Workers burn
//! cycles only between `step_into` calls issued back-to-back; an idle
//! pool parks on `yield_now`.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};

/// A batch of homogeneous environment lanes stepped as one unit.
///
/// The contract every implementation upholds (and the property tests
/// enforce): lane `i` behaves exactly like a single env seeded
/// `base_seed + i`, stepped sequentially with auto-reset — executors
/// differ only in *how fast* the batch advances.
pub trait BatchedExecutor {
    /// Number of lanes in the batch.
    fn num_lanes(&self) -> usize;

    /// Flattened per-lane observation length.
    fn obs_dim(&self) -> usize;

    /// The (shared) action space of every lane.
    fn action_space(&self) -> Space;

    /// Reset every lane; `obs` is `[num_lanes * obs_dim]`.
    fn reset_into(&mut self, obs: &mut [f32]);

    /// Step every lane with its action; finished lanes auto-reset.
    /// `actions.len() == transitions.len() == num_lanes`,
    /// `obs.len() == num_lanes * obs_dim`.
    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    );
}

/// Iterations of `spin_loop` before a waiter starts yielding the core.
const SPIN_LIMIT: u32 = 1 << 12;

/// Spin until the command sequence moves past `last`, returning the new
/// value — or `None` if the pool was poisoned (a sibling worker
/// panicked), telling the caller to exit.
fn wait_for_seq(shared: &SyncShared, last: u64) -> Option<u64> {
    let mut spins = 0u32;
    loop {
        if shared.poisoned.load(Ordering::Acquire) {
            return None;
        }
        let s = shared.seq.load(Ordering::Acquire);
        if s != last {
            return Some(s);
        }
        spins = spins.saturating_add(1);
        if spins < SPIN_LIMIT {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

/// One broadcast command.  Raw pointers stay valid for the whole
/// barrier: the coordinator publishes a command and then blocks until
/// every worker has acknowledged completion, so the borrows behind
/// these pointers outlive all worker accesses.
#[derive(Clone, Copy)]
enum Cmd {
    Idle,
    Reset {
        obs: *mut f32,
    },
    Step {
        actions: *const Action,
        obs: *mut f32,
        transitions: *mut Transition,
    },
    /// Free-running random-action rollout executed entirely worker-side
    /// (one barrier for the whole workload) — the throughput mode behind
    /// [`crate::coordinator::vec_env::parallel_random_steps`].
    RandomSteps {
        steps_per_lane: u64,
    },
    Shutdown,
}

/// Coordinator/worker mailbox for the sync pool.
struct SyncShared {
    /// Bumped (release) by the coordinator after writing `cmd`.
    seq: AtomicU64,
    /// Incremented (release) by each worker when its lanes are done.
    done: AtomicUsize,
    /// Set when a worker's env panicked mid-command.  A panicking worker
    /// still acknowledges the round before exiting (so the barrier's ack
    /// quorum always completes), surviving workers exit on seeing the
    /// flag, and the coordinator re-raises the panic — no command is
    /// ever issued against a partially dead pool.
    poisoned: AtomicBool,
    /// The current command.  Written only by the coordinator while all
    /// workers are quiescent (`done` drained to 0), read only by
    /// workers after observing a new `seq` — never concurrently
    /// accessed for writing and reading.
    cmd: UnsafeCell<Cmd>,
}

// SAFETY: `cmd` is protected by the seq/done handshake described above,
// and the raw pointers it carries are only dereferenced for disjoint
// lane ranges while the owning borrow is pinned by the barrier.
unsafe impl Send for SyncShared {}
unsafe impl Sync for SyncShared {}

/// Persistent-worker pool, synchronous mode.
///
/// Construction partitions `n` lanes into contiguous chunks, one
/// long-lived worker thread per chunk.  [`EnvPool::step_into`] publishes
/// the batch command, every worker steps its own lanes directly into the
/// shared buffers, and the call returns once the last worker checks in —
/// a barrier per batch, amortised across all lanes.
pub struct EnvPool {
    shared: Arc<SyncShared>,
    handles: Vec<JoinHandle<()>>,
    n: usize,
    obs_dim: usize,
    action_space: Space,
    base_seed: u64,
}

impl EnvPool {
    /// Build a pool of `n` lanes across up to `threads` workers; lane
    /// `i` is seeded `base_seed + i` (the same rule as
    /// [`VecEnv::new`](crate::coordinator::vec_env::VecEnv::new), which
    /// is what makes the two executors trajectory-compatible).
    pub fn new<E, F>(n: usize, base_seed: u64, threads: usize, mut factory: F) -> EnvPool
    where
        E: Env + Send + 'static,
        F: FnMut() -> E,
    {
        assert!(n > 0, "EnvPool needs at least one lane");
        let mut envs: Vec<E> = (0..n).map(|_| factory()).collect();
        for (i, env) in envs.iter_mut().enumerate() {
            env.seed(base_seed + i as u64);
        }
        let obs_dim = envs[0].obs_dim();
        let action_space = envs[0].action_space();

        let threads = threads.clamp(1, n);
        let chunk = n.div_ceil(threads);
        let shared = Arc::new(SyncShared {
            seq: AtomicU64::new(0),
            done: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
            cmd: UnsafeCell::new(Cmd::Idle),
        });

        let mut handles = Vec::new();
        let mut lane_start = 0usize;
        let mut remaining = envs;
        while lane_start < n {
            let take = chunk.min(n - lane_start);
            let lane_envs: Vec<E> = remaining.drain(..take).collect();
            let shared_w = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("envpool-{lane_start}"))
                .spawn(move || {
                    sync_worker(shared_w, lane_envs, lane_start, obs_dim, base_seed)
                })
                .expect("spawn pool worker");
            handles.push(handle);
            lane_start += take;
        }

        EnvPool {
            shared,
            handles,
            n,
            obs_dim,
            action_space,
            base_seed,
        }
    }

    /// Number of worker threads actually running.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// The base seed the lanes were constructed with (lane `i` holds
    /// `base_seed + i`).
    pub fn base_seed(&self) -> u64 {
        self.base_seed
    }

    /// Run `steps_per_lane` uniform-random steps on every lane entirely
    /// worker-side — one barrier for the *whole workload*, so cheap envs
    /// run free of per-step synchronisation (the Fig.-1 aggregate
    /// throughput mode).  Lane `i` draws actions from the dedicated
    /// stream `Pcg32::new(base_seed ^ 0xabcd, i + 1)` and resets before
    /// starting, so results are reproducible and thread-count
    /// independent.  Returns total lane-steps executed.
    ///
    /// Note this advances lane state without reporting observations;
    /// don't interleave with trait-driven lockstep batches that assume
    /// they saw every transition.
    pub fn random_rollout(&mut self, steps_per_lane: u64) -> u64 {
        self.broadcast(Cmd::RandomSteps { steps_per_lane });
        steps_per_lane * self.n as u64
    }

    /// Publish `cmd` and block until every worker has processed it,
    /// re-raising any worker panic on the coordinator thread.
    ///
    /// Safety of the barrier under panics: workers only ever die by
    /// panicking inside a command, a panicking worker acknowledges the
    /// round *before* exiting, and a poisoned pool refuses to publish
    /// further commands — so every round's ack quorum is the full
    /// worker count and the caller's buffer borrows are never released
    /// while a worker could still write through them.
    fn broadcast(&self, cmd: Cmd) {
        if self.shared.poisoned.load(Ordering::Acquire) {
            panic!("EnvPool is poisoned: a worker panicked in an earlier batch");
        }
        debug_assert_eq!(self.shared.done.load(Ordering::Acquire), 0);
        // SAFETY: all workers are quiescent between barriers (done was
        // drained to 0), so this is the only access to `cmd`.
        unsafe {
            *self.shared.cmd.get() = cmd;
        }
        self.shared.seq.fetch_add(1, Ordering::Release);
        self.await_acks();
        if self.shared.poisoned.load(Ordering::Acquire) {
            panic!("EnvPool worker panicked while executing a batch command");
        }
    }

    /// Spin until every worker acknowledged the current command (a
    /// panicking worker still acks, so this always terminates).
    fn await_acks(&self) {
        let workers = self.handles.len();
        let mut spins = 0u32;
        while self.shared.done.load(Ordering::Acquire) < workers {
            spins = spins.saturating_add(1);
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        self.shared.done.store(0, Ordering::Release);
    }
}

impl BatchedExecutor for EnvPool {
    fn num_lanes(&self) -> usize {
        self.n
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn action_space(&self) -> Space {
        self.action_space.clone()
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.n * self.obs_dim);
        self.broadcast(Cmd::Reset {
            obs: obs.as_mut_ptr(),
        });
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.n);
        assert_eq!(obs.len(), self.n * self.obs_dim);
        assert_eq!(transitions.len(), self.n);
        self.broadcast(Cmd::Step {
            actions: actions.as_ptr(),
            obs: obs.as_mut_ptr(),
            transitions: transitions.as_mut_ptr(),
        });
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        if self.shared.poisoned.load(Ordering::Acquire) {
            // Workers exit on their own via the poison flag; never
            // panic out of drop.
        } else {
            // Publish Shutdown directly (broadcast would re-panic if a
            // worker somehow poisoned the final round).
            // SAFETY: workers are quiescent between barriers.
            unsafe {
                *self.shared.cmd.get() = Cmd::Shutdown;
            }
            self.shared.seq.fetch_add(1, Ordering::Release);
            self.await_acks();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Body of one sync worker: wait for a command, run it over the owned
/// lane range, acknowledge, repeat.  Env panics are caught so the
/// round's ack still happens; the pool is poisoned instead of deadlocked.
fn sync_worker<E: Env>(
    shared: Arc<SyncShared>,
    mut envs: Vec<E>,
    lane_start: usize,
    obs_dim: usize,
    base_seed: u64,
) {
    let mut last_seq = 0u64;
    loop {
        let Some(seq) = wait_for_seq(&shared, last_seq) else {
            return; // a sibling worker panicked: the pool is done
        };
        last_seq = seq;
        // SAFETY: the coordinator finished writing `cmd` before the seq
        // bump we just acquired, and will not write again until this
        // worker (and all others) increments `done`.
        let cmd = unsafe { *shared.cmd.get() };
        let shutdown = matches!(cmd, Cmd::Shutdown);
        let ok = catch_unwind(AssertUnwindSafe(|| {
            run_cmd(cmd, &mut envs, lane_start, obs_dim, base_seed);
        }))
        .is_ok();
        if !ok {
            shared.poisoned.store(true, Ordering::Release);
        }
        shared.done.fetch_add(1, Ordering::Release);
        if !ok || shutdown {
            return;
        }
    }
}

/// Execute one command over a worker's lane range.
fn run_cmd<E: Env>(
    cmd: Cmd,
    envs: &mut [E],
    lane_start: usize,
    obs_dim: usize,
    base_seed: u64,
) {
    match cmd {
        Cmd::Idle | Cmd::Shutdown => {}
        Cmd::Reset { obs } => {
            for (k, env) in envs.iter_mut().enumerate() {
                let lane = lane_start + k;
                // SAFETY: lane ranges are disjoint across workers and
                // the caller's `&mut [f32]` is pinned by the barrier.
                let lane_obs = unsafe {
                    std::slice::from_raw_parts_mut(obs.add(lane * obs_dim), obs_dim)
                };
                env.reset_into(lane_obs);
            }
        }
        Cmd::Step {
            actions,
            obs,
            transitions,
        } => {
            for (k, env) in envs.iter_mut().enumerate() {
                let lane = lane_start + k;
                // SAFETY: as above — disjoint lanes, barrier-pinned
                // borrows, actions only read.
                let action = unsafe { &*actions.add(lane) };
                let lane_obs = unsafe {
                    std::slice::from_raw_parts_mut(obs.add(lane * obs_dim), obs_dim)
                };
                let t = env.step_into(action, lane_obs);
                unsafe {
                    *transitions.add(lane) = t;
                }
                if t.done || t.truncated {
                    env.reset_into(lane_obs);
                }
            }
        }
        Cmd::RandomSteps { steps_per_lane } => {
            // Free-running: no coordinator round-trips, matching the
            // per-thread loop `parallel_random_steps` historically ran
            // (same per-lane rng streams, same seeding).
            for (k, env) in envs.iter_mut().enumerate() {
                let lane = lane_start + k;
                let mut rng = Pcg32::new(base_seed ^ 0xabcd, lane as u64 + 1);
                let space = env.action_space();
                let mut obs = vec![0.0f32; obs_dim];
                env.reset_into(&mut obs);
                for _ in 0..steps_per_lane {
                    let a = space.sample(&mut rng);
                    let t = env.step_into(&a, &mut obs);
                    if t.done || t.truncated {
                        env.reset_into(&mut obs);
                    }
                }
            }
        }
    }
}

/// One ready lane reported by an async worker.
pub struct ReadyLane {
    /// Global lane index.
    pub lane: usize,
    /// Current observation (first obs of the next episode if the lane
    /// just finished).
    pub obs: Vec<f32>,
    /// The transition that produced `obs` (`Transition::default()` for
    /// the initial reset).
    pub transition: Transition,
}

/// A compacted batch of ready lanes — EnvPool's XLA-friendly shape.
pub struct AsyncBatch {
    /// Lane ids, in ready order; `lanes[j]`'s observation occupies
    /// `obs[j * obs_dim .. (j + 1) * obs_dim]`.
    pub lanes: Vec<usize>,
    /// `[lanes.len() * obs_dim]` observation block.
    pub obs: Vec<f32>,
    /// Per-entry transitions, aligned with `lanes`.
    pub transitions: Vec<Transition>,
}

/// Queue contents plus the poison flag, under one lock so waiters can
/// check both atomically (no lost-wakeup window).
struct QueueState {
    q: VecDeque<ReadyLane>,
    poisoned: bool,
}

struct ReadyQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl ReadyQueue {
    fn push(&self, r: ReadyLane) {
        self.state.lock().unwrap().q.push_back(r);
        self.cv.notify_one();
    }

    /// Mark the pool dead (a worker's env panicked) and wake every
    /// waiter so blocked `recv_batch`/`collect_exact` calls surface the
    /// failure instead of sleeping forever.
    fn poison(&self) {
        self.state.lock().unwrap().poisoned = true;
        self.cv.notify_all();
    }
}

enum WorkerMsg {
    Step { lane: usize, action: Action },
    Reset,
}

/// Persistent-worker pool, asynchronous mode: workers run ahead.
///
/// After construction every lane is reset and enqueued ready.  The
/// coordinator loop is
/// [`recv_batch`](AsyncEnvPool::recv_batch) → act →
/// [`send_actions`](AsyncEnvPool::send_actions): a worker steps a lane
/// the moment its action lands, regardless of what other lanes are
/// doing, so slow lanes never stall the batch (the async half of
/// EnvPool's design).  There is no global barrier anywhere.
///
/// Per-lane trajectories remain bit-identical to sequential execution —
/// only the interleaving across lanes is nondeterministic.
///
/// The [`BatchedExecutor`] impl drives the same machinery in lockstep
/// (send all, receive all) for drop-in comparisons with the sync
/// executors; don't interleave trait calls with the native async API.
pub struct AsyncEnvPool {
    senders: Vec<Sender<WorkerMsg>>,
    handles: Vec<JoinHandle<()>>,
    ready: Arc<ReadyQueue>,
    /// lane -> owning worker index.
    owner: Vec<usize>,
    /// True until the construction-time reset results are consumed.  The
    /// first lockstep `reset_into` takes those instead of re-resetting,
    /// so lane RNG streams stay aligned with `VecEnv` (whose first
    /// `reset_into` is each env's *first* reset).
    pristine: bool,
    n: usize,
    obs_dim: usize,
    action_space: Space,
}

impl AsyncEnvPool {
    /// Build an async pool; seeding and lane partitioning follow
    /// [`EnvPool::new`] exactly.
    pub fn new<E, F>(
        n: usize,
        base_seed: u64,
        threads: usize,
        mut factory: F,
    ) -> AsyncEnvPool
    where
        E: Env + Send + 'static,
        F: FnMut() -> E,
    {
        assert!(n > 0, "AsyncEnvPool needs at least one lane");
        let mut envs: Vec<E> = (0..n).map(|_| factory()).collect();
        for (i, env) in envs.iter_mut().enumerate() {
            env.seed(base_seed + i as u64);
        }
        let obs_dim = envs[0].obs_dim();
        let action_space = envs[0].action_space();

        let threads = threads.clamp(1, n);
        let chunk = n.div_ceil(threads);
        let ready = Arc::new(ReadyQueue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                poisoned: false,
            }),
            cv: Condvar::new(),
        });

        let mut senders = Vec::new();
        let mut handles = Vec::new();
        let mut owner = vec![0usize; n];
        let mut lane_start = 0usize;
        let mut remaining = envs;
        while lane_start < n {
            let take = chunk.min(n - lane_start);
            let lane_envs: Vec<E> = remaining.drain(..take).collect();
            let worker_idx = senders.len();
            owner[lane_start..lane_start + take].fill(worker_idx);
            let (tx, rx) = channel::<WorkerMsg>();
            let ready_w = Arc::clone(&ready);
            let handle = std::thread::Builder::new()
                .name(format!("envpool-async-{lane_start}"))
                .spawn(move || async_worker(rx, ready_w, lane_envs, lane_start, obs_dim))
                .expect("spawn async pool worker");
            senders.push(tx);
            handles.push(handle);
            lane_start += take;
        }

        AsyncEnvPool {
            senders,
            handles,
            ready,
            owner,
            pristine: true,
            n,
            obs_dim,
            action_space,
        }
    }

    /// Number of worker threads actually running.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Submit actions for specific lanes.  Each named lane must be
    /// "owed" to the pool: received via [`recv_batch`]
    /// (AsyncEnvPool::recv_batch) (or initially ready) and not yet sent
    /// an action.
    pub fn send_actions(&mut self, actions: &[(usize, Action)]) {
        for (lane, action) in actions {
            assert!(*lane < self.n, "lane {lane} out of range");
            let msg = WorkerMsg::Step {
                lane: *lane,
                action: action.clone(),
            };
            if self.senders[self.owner[*lane]].send(msg).is_err() {
                panic!("AsyncEnvPool worker panicked before receiving an action");
            }
        }
    }

    /// Receive up to `max` ready lanes, blocking until at least one is
    /// available.  Only lanes with submitted (or initial) work become
    /// ready, so call this with outstanding lanes or it will block
    /// forever.
    pub fn recv_batch(&mut self, max: usize) -> AsyncBatch {
        assert!(max > 0);
        let mut batch = AsyncBatch {
            lanes: Vec::new(),
            obs: Vec::new(),
            transitions: Vec::new(),
        };
        let mut state = self.ready.state.lock().unwrap();
        while state.q.is_empty() {
            assert!(
                !state.poisoned,
                "AsyncEnvPool worker panicked; no more lanes will become ready"
            );
            state = self.ready.cv.wait(state).unwrap();
        }
        let k = state.q.len().min(max);
        batch.lanes.reserve(k);
        batch.obs.reserve(k * self.obs_dim);
        batch.transitions.reserve(k);
        for _ in 0..k {
            let r = state.q.pop_front().expect("non-empty by construction");
            batch.lanes.push(r.lane);
            batch.obs.extend_from_slice(&r.obs);
            batch.transitions.push(r.transition);
        }
        drop(state);
        self.pristine = false;
        batch
    }

    /// Pop exactly `k` ready lanes (blocking), handing each to `sink`.
    fn collect_exact(&self, k: usize, mut sink: impl FnMut(ReadyLane)) {
        let mut state = self.ready.state.lock().unwrap();
        for _ in 0..k {
            while state.q.is_empty() {
                assert!(
                    !state.poisoned,
                    "AsyncEnvPool worker panicked; no more lanes will become ready"
                );
                state = self.ready.cv.wait(state).unwrap();
            }
            sink(state.q.pop_front().expect("non-empty by construction"));
        }
    }
}

impl BatchedExecutor for AsyncEnvPool {
    fn num_lanes(&self) -> usize {
        self.n
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn action_space(&self) -> Space {
        self.action_space.clone()
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.n * self.obs_dim);
        if !self.pristine {
            // Re-reset every lane; the queue is empty between lockstep
            // calls, so the next n entries are exactly the reset results.
            for tx in &self.senders {
                if tx.send(WorkerMsg::Reset).is_err() {
                    panic!("AsyncEnvPool worker panicked before receiving a reset");
                }
            }
        }
        // A pristine pool consumes the construction-time reset instead:
        // each env's first reset, matching sequential `VecEnv` exactly.
        self.pristine = false;
        let d = self.obs_dim;
        self.collect_exact(self.n, |r| {
            obs[r.lane * d..(r.lane + 1) * d].copy_from_slice(&r.obs);
        });
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.n);
        assert_eq!(obs.len(), self.n * self.obs_dim);
        assert_eq!(transitions.len(), self.n);
        if self.pristine {
            // Stepping without an explicit reset: the lanes were reset at
            // construction; drain those entries so the collection below
            // sees only step results.
            self.collect_exact(self.n, |_| {});
            self.pristine = false;
        }
        for (lane, action) in actions.iter().enumerate() {
            let msg = WorkerMsg::Step {
                lane,
                action: action.clone(),
            };
            if self.senders[self.owner[lane]].send(msg).is_err() {
                panic!("AsyncEnvPool worker panicked before receiving an action");
            }
        }
        let d = self.obs_dim;
        // Collect all n results; per-lane writes land in lane order
        // regardless of arrival order, restoring batch determinism.
        // Exactly-once per lane holds because each lane was sent exactly
        // one action and workers publish one entry per action (pinned by
        // the executor_pool integration tests).
        self.collect_exact(self.n, |r| {
            obs[r.lane * d..(r.lane + 1) * d].copy_from_slice(&r.obs);
            transitions[r.lane] = r.transition;
        });
    }
}

impl Drop for AsyncEnvPool {
    fn drop(&mut self) {
        self.senders.clear(); // hang up: workers exit on recv error
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Body of one async worker: step a lane per message, publish the
/// result, auto-reset finished lanes.  Env panics poison the ready
/// queue (waking blocked receivers) instead of leaving them asleep.
fn async_worker<E: Env>(
    rx: Receiver<WorkerMsg>,
    ready: Arc<ReadyQueue>,
    mut envs: Vec<E>,
    lane_start: usize,
    obs_dim: usize,
) {
    fn publish_reset<E: Env>(
        envs: &mut [E],
        ready: &ReadyQueue,
        lane_start: usize,
        obs_dim: usize,
    ) {
        for (k, env) in envs.iter_mut().enumerate() {
            let mut obs = vec![0.0f32; obs_dim];
            env.reset_into(&mut obs);
            ready.push(ReadyLane {
                lane: lane_start + k,
                obs,
                transition: Transition::default(),
            });
        }
    }

    let result = catch_unwind(AssertUnwindSafe(|| {
        publish_reset(&mut envs, &ready, lane_start, obs_dim);
        while let Ok(msg) = rx.recv() {
            match msg {
                WorkerMsg::Reset => {
                    publish_reset(&mut envs, &ready, lane_start, obs_dim)
                }
                WorkerMsg::Step { lane, action } => {
                    let k = lane - lane_start;
                    let mut obs = vec![0.0f32; obs_dim];
                    let t = envs[k].step_into(&action, &mut obs);
                    if t.done || t.truncated {
                        envs[k].reset_into(&mut obs);
                    }
                    ready.push(ReadyLane {
                        lane,
                        obs,
                        transition: t,
                    });
                }
            }
        }
    }));
    if result.is_err() {
        ready.poison();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::vec_env::VecEnv;
    use crate::envs::CartPole;
    use crate::wrappers::TimeLimit;

    fn cartpole_factory() -> impl Fn() -> TimeLimit<CartPole> {
        || TimeLimit::new(CartPole::new(), 40)
    }

    /// Drive any executor with a fixed action pattern, returning the
    /// concatenated (obs, transition) stream.
    fn drive(
        exec: &mut dyn BatchedExecutor,
        steps: usize,
    ) -> (Vec<f32>, Vec<Transition>) {
        let n = exec.num_lanes();
        let d = exec.obs_dim();
        let mut obs = vec![0.0f32; n * d];
        let mut tr = vec![Transition::default(); n];
        let mut obs_trace = Vec::new();
        let mut tr_trace = Vec::new();
        exec.reset_into(&mut obs);
        obs_trace.extend_from_slice(&obs);
        for step in 0..steps {
            let actions: Vec<Action> =
                (0..n).map(|i| Action::Discrete((step + i) % 2)).collect();
            exec.step_into(&actions, &mut obs, &mut tr);
            obs_trace.extend_from_slice(&obs);
            tr_trace.extend_from_slice(&tr);
        }
        (obs_trace, tr_trace)
    }

    #[test]
    fn sync_pool_matches_vec_env_bitwise() {
        let mut vec_env = VecEnv::new(5, 900, cartpole_factory());
        let mut pool = EnvPool::new(5, 900, 2, cartpole_factory());
        let (obs_a, tr_a) = drive(&mut vec_env, 150);
        let (obs_b, tr_b) = drive(&mut pool, 150);
        assert_eq!(tr_a, tr_b);
        assert_eq!(obs_a, obs_b);
    }

    #[test]
    fn sync_pool_is_thread_count_invariant() {
        let traces: Vec<_> = [1usize, 3, 5]
            .iter()
            .map(|&threads| {
                let mut pool = EnvPool::new(4, 31, threads, cartpole_factory());
                drive(&mut pool, 120)
            })
            .collect();
        assert_eq!(traces[0], traces[1]);
        assert_eq!(traces[0], traces[2]);
    }

    #[test]
    fn async_pool_lockstep_matches_vec_env_bitwise() {
        let mut vec_env = VecEnv::new(4, 77, cartpole_factory());
        let mut pool = AsyncEnvPool::new(4, 77, 2, cartpole_factory());
        let (obs_a, tr_a) = drive(&mut vec_env, 100);
        let (obs_b, tr_b) = drive(&mut pool, 100);
        assert_eq!(tr_a, tr_b);
        assert_eq!(obs_a, obs_b);
    }

    #[test]
    fn async_native_api_initial_lanes_are_all_ready() {
        let n = 6;
        let mut pool = AsyncEnvPool::new(n, 5, 3, cartpole_factory());
        let mut seen = vec![false; n];
        let mut got = 0;
        while got < n {
            let batch = pool.recv_batch(n);
            for (j, &lane) in batch.lanes.iter().enumerate() {
                assert!(!seen[lane], "lane {lane} ready twice before any action");
                seen[lane] = true;
                assert_eq!(batch.obs[j * 4..(j + 1) * 4].len(), 4);
                assert!(!batch.transitions[j].done);
            }
            got += batch.lanes.len();
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn async_native_api_round_trips_actions() {
        let n = 4;
        let mut pool = AsyncEnvPool::new(n, 11, 2, cartpole_factory());
        let mut sends_per_lane = vec![0u32; n];
        // Keep every received lane busy: each ready state gets an action.
        for _ in 0..200 {
            let batch = pool.recv_batch(n);
            let sends: Vec<(usize, Action)> = batch
                .lanes
                .iter()
                .map(|&lane| {
                    sends_per_lane[lane] += 1;
                    (lane, Action::Discrete(lane % 2))
                })
                .collect();
            pool.send_actions(&sends);
        }
        for (lane, &s) in sends_per_lane.iter().enumerate() {
            assert!(s > 10, "lane {lane} starved: {s} actions submitted");
        }
    }

    #[test]
    fn pools_shut_down_cleanly_on_drop() {
        let pool = EnvPool::new(3, 0, 2, cartpole_factory());
        drop(pool);
        let pool = AsyncEnvPool::new(3, 0, 2, cartpole_factory());
        drop(pool);
    }

    #[test]
    fn random_rollout_counts_lane_steps_and_stays_reusable() {
        let mut pool = EnvPool::new(4, 9, 2, cartpole_factory());
        assert_eq!(pool.random_rollout(500), 2_000);
        // The pool survives the bulk command and still serves batches.
        assert_eq!(pool.random_rollout(10), 40);
        let mut obs = vec![0.0f32; 4 * 4];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        assert!(obs.iter().all(|v| v.is_finite()));
    }

    /// Env that panics on the `boom`-th step — exercises worker-death
    /// handling.
    struct Grenade {
        fuse: u32,
        boom: u32,
    }

    impl Env for Grenade {
        fn id(&self) -> String {
            "Grenade-v0".into()
        }
        fn observation_space(&self) -> Space {
            Space::box1(vec![0.0], vec![1.0])
        }
        fn action_space(&self) -> Space {
            Space::Discrete { n: 2 }
        }
        fn seed(&mut self, _seed: u64) {}
        fn reset_into(&mut self, obs: &mut [f32]) {
            obs[0] = 0.0;
        }
        fn step_into(&mut self, _a: &Action, obs: &mut [f32]) -> Transition {
            self.fuse += 1;
            assert!(self.fuse < self.boom, "grenade went off");
            obs[0] = self.fuse as f32;
            Transition::live(0.0)
        }
    }

    #[test]
    #[should_panic(expected = "EnvPool worker panicked")]
    fn sync_pool_surfaces_env_panics_instead_of_hanging() {
        let mut pool = EnvPool::new(4, 0, 2, || Grenade { fuse: 0, boom: 3 });
        let mut obs = vec![0.0f32; 4];
        let mut tr = vec![Transition::default(); 4];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        for _ in 0..10 {
            let actions = vec![Action::Discrete(0); 4];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
        }
    }

    #[test]
    #[should_panic(expected = "AsyncEnvPool worker panicked")]
    fn async_pool_surfaces_env_panics_instead_of_hanging() {
        let mut pool = AsyncEnvPool::new(4, 0, 2, || Grenade { fuse: 0, boom: 3 });
        let mut obs = vec![0.0f32; 4];
        let mut tr = vec![Transition::default(); 4];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        for _ in 0..10 {
            let actions = vec![Action::Discrete(0); 4];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
        }
    }

    #[test]
    fn pool_works_over_dyn_envs() {
        let mut pool = EnvPool::new(3, 1, 2, || {
            crate::coordinator::registry::make("CartPole-v1").unwrap()
        });
        let mut obs = vec![0.0f32; 3 * 4];
        let mut tr = vec![Transition::default(); 3];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        for _ in 0..10 {
            let actions = vec![Action::Discrete(0); 3];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
        }
        assert!(obs.iter().all(|v| v.is_finite()));
    }
}
