//! Persistent-worker batched executors — the EnvPool-style scaling
//! substrate (Weng et al., 2022).
//!
//! The seed toolkit stepped `VecEnv` lanes sequentially, and its only
//! threaded path spawned throwaway threads per call.  This module
//! replaces that with **persistent workers that own lanes for the life
//! of the pool** and step them against shared `[n * obs_dim]` batch
//! buffers:
//!
//! * [`BatchedExecutor`] — the common executor interface.  `VecEnv`
//!   (sequential), [`EnvPool`] (threaded, synchronous) and
//!   [`AsyncEnvPool`] (threaded, workers run ahead) all implement it, so
//!   every workload can flip executors via configuration
//!   ([`crate::coordinator::config::ExecutorSettings`]).
//! * [`EnvPool`] — **sync mode**: one spin-barrier per batch.  Lane `i`
//!   is seeded `base_seed + i` and stepped in order by exactly one
//!   worker, so trajectories are **bit-identical to sequential
//!   `VecEnv`** for any thread count (`rust/tests/executor_pool.rs`
//!   pins this for every registered env id).  Threading is a pure
//!   performance transform, never a semantics change.
//! * [`AsyncEnvPool`] — **async mode**: workers step a lane the moment
//!   its action arrives; the coordinator exchanges
//!   [`AsyncEnvPool::send_actions`] / [`AsyncEnvPool::recv_batch`] over
//!   a ready-queue.  Observations live in **per-lane slots of one shared
//!   block**: workers write a lane's slot in place and hand back only the
//!   lane id, so steady-state `send_actions`/`recv_batch` performs **zero
//!   heap allocations** (pinned by `rust/tests/alloc_free.rs` with a
//!   counting global allocator; continuous `Action`s carry a `Vec` and
//!   are the one exception).
//!
//! # Scenario mixtures (heterogeneous lanes)
//!
//! Every executor accepts **per-lane environments**: a pool can run 32
//! lanes of `CartPole-v1` next to 16 of `Acrobot-v1` and 16 of a
//! script-runner env behind the same batch interface
//! ([`crate::coordinator::experiment::build_executor`] parses
//! `"CartPole-v1:32,Acrobot-v1:16"` specs).  Batch buffers pad every
//! lane to the pool-wide maximum observation length:
//! [`BatchedExecutor::obs_dim`] is the **padded** width, lane `i` owns
//! `obs[i * padded .. (i + 1) * padded]`, writes its true observation at
//! the front and keeps the tail **zeroed**.  [`BatchedExecutor::lane_specs`]
//! exposes `(env_id, obs_dim, offset)` per lane so agents can slice
//! unpadded views without knowing the mixture layout.
//!
//! Auto-reset follows the `VecEnv` convention everywhere: a finished
//! lane's transition reports the episode end exactly once and its
//! observation is the first observation of the next episode.
//!
//! # Panic policy
//!
//! An env panic inside a pool **poisons** it by default — the
//! coordinator call re-raises the panic, nothing steps again
//! (fail-fast, and the long-standing determinism pins are untouched).
//! Opting into [`PanicPolicy::Quarantine`] via
//! [`BatchedExecutor::set_panic_policy`] (CLI: `--on-panic
//! quarantine`) retires only the panicking lane: its slot reads zeroed
//! observations and `done = true` transitions forever — across resets
//! too — while every healthy lane keeps its exact trajectory, and each
//! newly dead lane bumps the `cairl_quarantined_lanes_total` counter.
//!
//! # Fused lane groups
//!
//! Workers do not step lanes one `Box<dyn Env>` at a time: every worker
//! owns a list of [`BatchEnv`](crate::core::batch::BatchEnv) **groups**
//! — contiguous lane runs that step as one unit.  The generic
//! constructors wrap each worker's lanes in one
//! [`ScalarBatch`](crate::core::batch::ScalarBatch) (bit-identical to
//! the old per-lane loop), while the registry-driven
//! [`EnvPool::from_groups`] / [`AsyncEnvPool::from_groups`] path
//! ([`crate::coordinator::experiment::build_executor_with_kernel`])
//! fuses homogeneous runs into SoA kernels: 32 CartPole lanes become
//! one `step_batch` call on four `Vec<f32>` state columns instead of 32
//! virtual `step_into` calls.  A group never spans a worker boundary —
//! [`LaneGroupSpec`] builders are invoked per (group ∩ worker chunk),
//! so thread partitioning is unchanged and per-lane seeding
//! (`base_seed + lane`) is preserved exactly.  In the async pool the
//! ready-queue semantics (a lane steps the moment its action lands)
//! keep stepping per-lane, but each step is a single
//! [`BatchEnv::step_lane`](crate::core::batch::BatchEnv::step_lane)
//! call into the group's SoA state — no wrapper-chain dispatch.
//!
//! Synchronisation in sync mode is a seqlock-style broadcast
//! (`AtomicU64` command sequence + `AtomicUsize` completion count) with
//! bounded spinning before yielding, because a condvar wake costs more
//! than an entire batch of cheap classic-control steps.  Workers burn
//! cycles only between `step_into` calls issued back-to-back; an idle
//! pool parks on `yield_now`.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::core::batch::{batch_random_steps, BatchEnv, DynBatchEnv, ScalarBatch};
use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::telemetry::trace::{self, SpanKind, SpanRecord};
use crate::telemetry::{gauge, ExecMetrics, Gauge};

/// Per-lane metadata of a (possibly heterogeneous) batched executor.
///
/// `offset` addresses the lane's slot inside a `[n * padded]` batch
/// buffer where `padded` is [`BatchedExecutor::obs_dim`]; the lane's
/// true observation is `obs[offset .. offset + obs_dim]` and the tail
/// `obs[offset + obs_dim .. offset + padded]` is always zero.
#[derive(Clone, Debug, PartialEq)]
pub struct LaneSpec {
    /// Environment id this lane runs (e.g. `"CartPole-v1"`).
    pub env_id: String,
    /// The lane's true (unpadded) observation length.
    pub obs_dim: usize,
    /// Start of the lane's slot in a flat batch buffer.
    pub offset: usize,
    /// The lane's action space.
    pub action_space: Space,
}

/// Compute per-lane specs and the pool-wide padded observation width
/// (the maximum lane `obs_dim`) for a lane-ordered env list.  `ids[i]`
/// labels lane `i` — the registry id for registry-built mixtures
/// (wrapper composition like `TimeLimit(...)` is an implementation
/// detail the label should not leak).
pub(crate) fn lane_layout<E: Env>(envs: &[E], ids: &[String]) -> (Vec<LaneSpec>, usize) {
    assert!(!envs.is_empty(), "an executor needs at least one lane");
    assert_eq!(envs.len(), ids.len());
    let padded = envs.iter().map(|e| e.obs_dim()).max().unwrap_or(0);
    assert!(padded > 0, "lane observations must be non-empty");
    let specs = envs
        .iter()
        .zip(ids)
        .enumerate()
        .map(|(i, (e, id))| LaneSpec {
            env_id: id.clone(),
            obs_dim: e.obs_dim(),
            offset: i * padded,
            action_space: e.action_space(),
        })
        .collect();
    (specs, padded)
}

/// Lane labels derived from [`Env::id`] — the fallback when a caller
/// hands envs without registry labels.
pub(crate) fn own_ids<E: Env>(envs: &[E]) -> Vec<String> {
    envs.iter().map(|e| e.id()).collect()
}

/// One homogeneous lane group of an executor build plan: a label, a
/// lane count and a builder the executor may invoke once per worker
/// sub-range (a group never spans a worker boundary, so a 32-lane group
/// split across 2 workers becomes two independent 16-lane batches;
/// seeding by `base_seed + lane` keeps the split bit-invariant).
pub struct LaneGroupSpec {
    id: String,
    lanes: usize,
    build: Box<dyn FnMut(usize) -> DynBatchEnv>,
}

impl LaneGroupSpec {
    /// A group of `lanes` lanes labeled `id` in
    /// [`BatchedExecutor::lane_specs`]; `build(k)` must return a fresh
    /// `k`-lane batch each call.
    pub fn new(
        id: &str,
        lanes: usize,
        build: impl FnMut(usize) -> DynBatchEnv + 'static,
    ) -> LaneGroupSpec {
        assert!(lanes > 0, "lane group {id:?} needs at least one lane");
        LaneGroupSpec {
            id: id.to_string(),
            lanes,
            build: Box::new(build),
        }
    }

    /// The group's lane-spec label.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// Number of lanes the group contributes.
    pub fn lanes(&self) -> usize {
        self.lanes
    }
}

/// A constructed group bound to its first lane — what workers own.
pub(crate) struct BuiltGroup {
    pub(crate) lane_start: usize,
    pub(crate) batch: DynBatchEnv,
}

/// Build and seed every (group ∩ worker-chunk) sub-batch in lane order,
/// returning the built groups plus the executor-wide lane specs and
/// padded width.  `chunk` is the worker width (`n` for a sequential
/// executor: no splitting).
pub(crate) fn materialize_groups(
    groups: Vec<LaneGroupSpec>,
    base_seed: u64,
    chunk: usize,
) -> (Vec<BuiltGroup>, Vec<LaneSpec>, usize) {
    assert!(chunk > 0);
    let mut built = Vec::new();
    let mut meta: Vec<(String, usize, Space)> = Vec::new();
    let mut lane = 0usize;
    for mut group in groups {
        let mut remaining = group.lanes;
        while remaining > 0 {
            let until_chunk = chunk - (lane % chunk);
            let take = remaining.min(until_chunk);
            let mut batch = (group.build)(take);
            assert_eq!(
                batch.lanes(),
                take,
                "group {:?}: builder returned the wrong lane count",
                group.id
            );
            batch.seed(base_seed + lane as u64);
            for k in 0..take {
                meta.push((
                    group.id.clone(),
                    batch.lane_obs_dim(k),
                    batch.lane_action_space(k),
                ));
            }
            built.push(BuiltGroup { lane_start: lane, batch });
            lane += take;
            remaining -= take;
        }
    }
    assert!(lane > 0, "an executor needs at least one lane");
    let padded = meta.iter().map(|(_, d, _)| *d).max().unwrap_or(0);
    assert!(padded > 0, "lane observations must be non-empty");
    let specs = meta
        .into_iter()
        .enumerate()
        .map(|(i, (env_id, obs_dim, action_space))| LaneSpec {
            env_id,
            obs_dim,
            offset: i * padded,
            action_space,
        })
        .collect();
    (built, specs, padded)
}

/// Wrap a seeded lane-ordered env list into one [`ScalarBatch`] group
/// per `chunk`-wide worker range — the generic constructors' plan.
fn scalar_chunks<E: Env + Send + 'static>(envs: Vec<E>, chunk: usize) -> Vec<BuiltGroup> {
    let n = envs.len();
    let mut built = Vec::new();
    let mut lane_start = 0usize;
    let mut remaining = envs;
    while lane_start < n {
        let take = chunk.min(n - lane_start);
        let lane_envs: Vec<E> = remaining.drain(..take).collect();
        built.push(BuiltGroup {
            lane_start,
            batch: Box::new(ScalarBatch::from_envs(lane_envs)),
        });
        lane_start += take;
    }
    built
}

/// Distribute built groups to their owning workers (`lane_start /
/// chunk`; materialisation guarantees no group straddles a chunk
/// boundary, so every group maps to exactly one worker and every
/// worker's list is non-empty and lane-ordered).
fn group_by_worker(built: Vec<BuiltGroup>, n: usize, chunk: usize) -> Vec<Vec<BuiltGroup>> {
    let workers = n.div_ceil(chunk);
    let mut per_worker: Vec<Vec<BuiltGroup>> = (0..workers).map(|_| Vec::new()).collect();
    for group in built {
        per_worker[group.lane_start / chunk].push(group);
    }
    per_worker
}

/// What an executor does when a lane's env panics mid-batch.
///
/// The default, [`PanicPolicy::Poison`], fails fast: the whole pool is
/// poisoned and the coordinator call re-raises the panic — nothing
/// about the pre-existing determinism pins changes.  Opt-in
/// [`PanicPolicy::Quarantine`] (`--on-panic quarantine`) instead marks
/// only the offending lane dead: its observation slot reads zero and
/// its transition reports `done = true` (reward 0) forever, every
/// healthy lane keeps its exact trajectory, and each newly dead lane
/// bumps `cairl_quarantined_lanes_total`.  A quarantined lane stays
/// dead across resets — its env state is unknown after the panic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PanicPolicy {
    /// Poison the whole pool and re-raise the panic (the default).
    #[default]
    Poison,
    /// Mark only the panicking lane dead; the rest keep stepping.
    Quarantine,
}

impl PanicPolicy {
    /// Parse the `--on-panic` / config grammar (`"poison"` /
    /// `"quarantine"`).
    pub fn parse(s: &str) -> Option<PanicPolicy> {
        match s.trim() {
            "poison" => Some(PanicPolicy::Poison),
            "quarantine" => Some(PanicPolicy::Quarantine),
            _ => None,
        }
    }

    /// The canonical spelling `parse` accepts.
    pub fn render(&self) -> &'static str {
        match self {
            PanicPolicy::Poison => "poison",
            PanicPolicy::Quarantine => "quarantine",
        }
    }
}

/// The transition a quarantined lane reports on every step after its
/// env panicked: episode over, nothing earned.
fn quarantined_transition() -> Transition {
    Transition::terminal(0.0)
}

/// Count one newly quarantined lane (cold path — a lane dies at most
/// once, so the registry lookup never touches the steady state).
fn note_quarantined_lane() {
    crate::telemetry::counter("cairl_quarantined_lanes_total").inc();
}

/// A batch of environment lanes stepped as one unit.
///
/// The contract every implementation upholds (and the property tests
/// enforce): lane `i` behaves exactly like a single env seeded
/// `base_seed + i`, stepped sequentially with auto-reset — executors
/// differ only in *how fast* the batch advances.  Lanes may run
/// different environments; see the module docs on padding.  The
/// contract extends across the shard fabric: a
/// [`ShardedEnvPool`](crate::shard::ShardedEnvPool) upholds it over
/// remote lanes, through its pipelined in-flight window and even across
/// mid-workload shard failovers (`docs/ARCHITECTURE.md` states the full
/// determinism contract once).
pub trait BatchedExecutor {
    /// Number of lanes in the batch.
    fn num_lanes(&self) -> usize;

    /// Padded per-lane observation length: the maximum lane `obs_dim`
    /// across the pool.  Homogeneous pools pad nothing.
    fn obs_dim(&self) -> usize;

    /// Per-lane `(env_id, obs_dim, offset, action_space)` metadata, in
    /// lane order — the key to slicing unpadded views out of a mixture
    /// batch.
    fn lane_specs(&self) -> &[LaneSpec];

    /// Lane 0's action space.  For homogeneous pools this is *the*
    /// action space; mixtures must consult [`BatchedExecutor::lane_specs`]
    /// per lane.
    fn action_space(&self) -> Space {
        self.lane_specs()[0].action_space.clone()
    }

    /// Reset every lane; `obs` is `[num_lanes * obs_dim]`.
    fn reset_into(&mut self, obs: &mut [f32]);

    /// Step every lane with its action; finished lanes auto-reset.
    /// `actions.len() == transitions.len() == num_lanes`,
    /// `obs.len() == num_lanes * obs_dim`.
    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    );

    /// Select what happens when a lane's env panics mid-batch (see
    /// [`PanicPolicy`]).  The default implementation ignores the
    /// policy: executors without a quarantine path keep their
    /// fail-fast behaviour.
    fn set_panic_policy(&mut self, _policy: PanicPolicy) {}
}

/// Aggregate counts of a worker-side free-running rollout
/// ([`EnvPool::random_rollout`]), folded into
/// [`crate::coordinator::experiment::run_random_workload`] reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RolloutCounts {
    /// Total lane-steps executed.
    pub steps: u64,
    /// Episodes that ended (terminated or truncated) during the rollout.
    pub episodes: u64,
}

/// Executors that can run a whole free-running random workload on their
/// own (no per-step coordination) — [`EnvPool`] worker-side, and
/// [`ShardedEnvPool`](crate::shard::ShardedEnvPool) with one frame per
/// shard.  Lane `i` draws actions from the dedicated stream
/// `Pcg32::new(base_seed ^ 0xabcd, i + 1)` where `i` is the *global*
/// lane id, so counts are identical across thread counts, kernels and
/// shard layouts.
pub trait RandomRollout {
    /// Run `steps_per_lane` uniform-random steps on every lane,
    /// returning aggregate step and episode counts.
    fn random_rollout(&mut self, steps_per_lane: u64) -> RolloutCounts;
}

/// Iterations of `spin_loop` before a waiter starts yielding the core.
const SPIN_LIMIT: u32 = 1 << 12;

/// Spin until the command sequence moves past `last`, returning the new
/// value — or `None` if the pool was poisoned (a sibling worker
/// panicked), telling the caller to exit.
fn wait_for_seq(shared: &SyncShared, last: u64) -> Option<u64> {
    let mut spins = 0u32;
    loop {
        if shared.poisoned.load(Ordering::Acquire) {
            return None;
        }
        let s = shared.seq.load(Ordering::Acquire);
        if s != last {
            return Some(s);
        }
        spins = spins.saturating_add(1);
        if spins < SPIN_LIMIT {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

/// One broadcast command.  Raw pointers stay valid for the whole
/// barrier: the coordinator publishes a command and then blocks until
/// every worker has acknowledged completion, so the borrows behind
/// these pointers outlive all worker accesses.
#[derive(Clone, Copy)]
enum Cmd {
    Idle,
    Reset {
        obs: *mut f32,
    },
    Step {
        actions: *const Action,
        obs: *mut f32,
        transitions: *mut Transition,
        /// `(trace_id, batch span)` of the coordinator's batch, or
        /// `(0, 0)` when tracing is off — workers parent their kernel
        /// spans here (published with `cmd` under the same seqlock).
        trace: (u64, u64),
    },
    /// Free-running random-action rollout executed entirely worker-side
    /// (one barrier for the whole workload) — the throughput mode behind
    /// [`crate::coordinator::experiment::run_random_workload`].
    RandomSteps {
        steps_per_lane: u64,
    },
    Shutdown,
}

/// Coordinator/worker mailbox for the sync pool.
struct SyncShared {
    /// Bumped (release) by the coordinator after writing `cmd`.
    seq: AtomicU64,
    /// Incremented (release) by each worker when its lanes are done.
    done: AtomicUsize,
    /// Episode-end tally of the current `RandomSteps` command; workers
    /// add their local counts before acknowledging, the coordinator
    /// reads after the barrier.
    episodes: AtomicU64,
    /// Set when a worker's env panicked mid-command.  A panicking worker
    /// still acknowledges the round before exiting (so the barrier's ack
    /// quorum always completes), surviving workers exit on seeing the
    /// flag, and the coordinator re-raises the panic — no command is
    /// ever issued against a partially dead pool.
    poisoned: AtomicBool,
    /// [`PanicPolicy::Quarantine`] selected: workers step lanes
    /// individually under `catch_unwind` and retire panicking lanes
    /// instead of poisoning the pool.
    quarantine: AtomicBool,
    /// The current command.  Written only by the coordinator while all
    /// workers are quiescent (`done` drained to 0), read only by
    /// workers after observing a new `seq` — never concurrently
    /// accessed for writing and reading.
    cmd: UnsafeCell<Cmd>,
}

// SAFETY: `cmd` is protected by the seq/done handshake described above,
// and the raw pointers it carries are only dereferenced for disjoint
// lane ranges while the owning borrow is pinned by the barrier.
unsafe impl Send for SyncShared {}
unsafe impl Sync for SyncShared {}

/// Persistent-worker pool, synchronous mode.
///
/// Construction partitions `n` lanes into contiguous chunks, one
/// long-lived worker thread per chunk.  [`EnvPool::step_into`] publishes
/// the batch command, every worker steps its own lanes directly into the
/// shared buffers, and the call returns once the last worker checks in —
/// a barrier per batch, amortised across all lanes.
pub struct EnvPool {
    shared: Arc<SyncShared>,
    handles: Vec<JoinHandle<()>>,
    specs: Vec<LaneSpec>,
    n: usize,
    padded: usize,
    base_seed: u64,
    metrics: ExecMetrics,
    /// Trace id minted lazily on the first traced batch (0 until then);
    /// every batch this pool steps shares it.
    trace_id: u64,
}

/// The free-running rollout's action-stream origin: the global base
/// seed and this pool's first global lane.  A plain local pool is
/// `(base_seed, 0)`; a shard hosting lanes `[first, first + n)` of a
/// larger pool passes `(global_base, first)` so its lanes draw the
/// exact streams they would draw locally.
type RolloutOrigin = (u64, usize);

impl EnvPool {
    /// Build a homogeneous pool of `n` lanes across up to `threads`
    /// workers; lane `i` is seeded `base_seed + i` (the same rule as
    /// [`VecEnv::new`](crate::coordinator::vec_env::VecEnv::new), which
    /// is what makes the two executors trajectory-compatible).
    pub fn new<E, F>(n: usize, base_seed: u64, threads: usize, mut factory: F) -> EnvPool
    where
        E: Env + Send + 'static,
        F: FnMut() -> E,
    {
        assert!(n > 0, "EnvPool needs at least one lane");
        let envs: Vec<E> = (0..n).map(|_| factory()).collect();
        EnvPool::from_envs(envs, base_seed, threads)
    }

    /// Build a pool over an explicit lane-ordered env list — the
    /// scenario-mixture constructor.  Lane `i` runs `envs[i]` seeded
    /// `base_seed + i`; observations are padded to the widest lane.
    /// Lane labels come from [`Env::id`]; use
    /// [`EnvPool::from_labeled_envs`] to keep registry ids.
    pub fn from_envs<E>(envs: Vec<E>, base_seed: u64, threads: usize) -> EnvPool
    where
        E: Env + Send + 'static,
    {
        let ids = own_ids(&envs);
        EnvPool::from_labeled_envs(ids, envs, base_seed, threads)
    }

    /// [`EnvPool::from_envs`] with explicit lane labels (`ids[i]` names
    /// lane `i` in [`BatchedExecutor::lane_specs`]) — what the registry
    /// mixture path uses so specs carry `"CartPole-v1"`, not the
    /// wrapper-composed [`Env::id`].
    pub fn from_labeled_envs<E>(
        ids: Vec<String>,
        mut envs: Vec<E>,
        base_seed: u64,
        threads: usize,
    ) -> EnvPool
    where
        E: Env + Send + 'static,
    {
        let n = envs.len();
        assert!(n > 0, "EnvPool needs at least one lane");
        for (i, env) in envs.iter_mut().enumerate() {
            env.seed(base_seed + i as u64);
        }
        let (specs, padded) = lane_layout(&envs, &ids);

        let chunk = n.div_ceil(threads.clamp(1, n));
        EnvPool::spawn(
            scalar_chunks(envs, chunk),
            specs,
            padded,
            base_seed,
            chunk,
            (base_seed, 0),
        )
    }

    /// Build a pool from a lane-group plan — the fused-kernel
    /// constructor behind
    /// [`build_executor_with_kernel`]
    /// (crate::coordinator::experiment::build_executor_with_kernel).
    /// Groups occupy contiguous lanes in plan order; lane `i` is seeded
    /// `base_seed + i` exactly as in [`EnvPool::from_labeled_envs`], and
    /// a group split across worker chunks is rebuilt per sub-range, so
    /// trajectories are thread-count and kernel invariant.
    pub fn from_groups(groups: Vec<LaneGroupSpec>, base_seed: u64, threads: usize) -> EnvPool {
        EnvPool::from_groups_with_origin(groups, base_seed, threads, (base_seed, 0))
    }

    /// [`EnvPool::from_groups`] for a pool that is one **shard** of a
    /// larger lane space: `origin = (global_base, first_lane)` tells the
    /// free-running rollout to draw lane action streams from the global
    /// lane ids, so a sharded [`random_rollout`](EnvPool::random_rollout)
    /// tallies exactly what the equivalent local pool would.  Lane
    /// seeding is unchanged (`base_seed + local_lane`; the caller passes
    /// `base_seed = global_base + first_lane`).
    pub fn from_groups_with_origin(
        groups: Vec<LaneGroupSpec>,
        base_seed: u64,
        threads: usize,
        origin: RolloutOrigin,
    ) -> EnvPool {
        let n: usize = groups.iter().map(|g| g.lanes()).sum();
        assert!(n > 0, "EnvPool needs at least one lane");
        let chunk = n.div_ceil(threads.clamp(1, n));
        let (built, specs, padded) = materialize_groups(groups, base_seed, chunk);
        EnvPool::spawn(built, specs, padded, base_seed, chunk, origin)
    }

    /// Spawn one worker per `chunk`-wide lane range, handing it the
    /// groups that fall inside the range (materialisation guarantees no
    /// group straddles a boundary).
    fn spawn(
        built: Vec<BuiltGroup>,
        specs: Vec<LaneSpec>,
        padded: usize,
        base_seed: u64,
        chunk: usize,
        origin: RolloutOrigin,
    ) -> EnvPool {
        let n = specs.len();
        let shared = Arc::new(SyncShared {
            seq: AtomicU64::new(0),
            done: AtomicUsize::new(0),
            episodes: AtomicU64::new(0),
            poisoned: AtomicBool::new(false),
            quarantine: AtomicBool::new(false),
            cmd: UnsafeCell::new(Cmd::Idle),
        });

        let mut handles = Vec::new();
        for worker_groups in group_by_worker(built, n, chunk) {
            let first = worker_groups
                .first()
                .expect("every worker chunk owns at least one group")
                .lane_start;
            let shared_w = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("envpool-{first}"))
                .spawn(move || sync_worker(shared_w, worker_groups, padded, origin))
                .expect("spawn pool worker");
            handles.push(handle);
        }

        EnvPool {
            shared,
            handles,
            specs,
            n,
            padded,
            base_seed,
            metrics: ExecMetrics::for_executor("pool"),
            trace_id: 0,
        }
    }

    /// Number of worker threads actually running.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// The base seed the lanes were constructed with (lane `i` holds
    /// `base_seed + i`).
    pub fn base_seed(&self) -> u64 {
        self.base_seed
    }

    /// Run `steps_per_lane` uniform-random steps on every lane entirely
    /// worker-side — one barrier for the *whole workload*, so cheap envs
    /// run free of per-step synchronisation (the Fig.-1 aggregate
    /// throughput mode).  Lane `i` draws actions from the dedicated
    /// stream `Pcg32::new(base_seed ^ 0xabcd, i + 1)` and resets before
    /// starting, so results are reproducible and thread-count
    /// independent.  Returns aggregate step *and* episode counts.
    ///
    /// Note this advances lane state without reporting observations;
    /// don't interleave with trait-driven lockstep batches that assume
    /// they saw every transition.
    pub fn random_rollout(&mut self, steps_per_lane: u64) -> RolloutCounts {
        self.shared.episodes.store(0, Ordering::Relaxed);
        self.broadcast(Cmd::RandomSteps { steps_per_lane });
        let episodes = self.shared.episodes.load(Ordering::Acquire);
        let steps = steps_per_lane * self.n as u64;
        // One tally for the whole free-running workload (there is no
        // per-batch boundary to count worker-side).
        self.metrics.steps.add(steps);
        self.metrics.auto_resets.add(episodes);
        RolloutCounts { steps, episodes }
    }

    /// This pool's trace id, minted on first use while tracing is
    /// enabled; `0` while tracing is off (one load + branch).
    fn ensure_trace_id(&mut self) -> u64 {
        if !trace::enabled() {
            return 0;
        }
        if self.trace_id == 0 {
            self.trace_id = trace::new_trace_id();
        }
        self.trace_id
    }

    /// Publish `cmd` and block until every worker has processed it,
    /// re-raising any worker panic on the coordinator thread.
    ///
    /// Safety of the barrier under panics: workers only ever die by
    /// panicking inside a command, a panicking worker acknowledges the
    /// round *before* exiting, and a poisoned pool refuses to publish
    /// further commands — so every round's ack quorum is the full
    /// worker count and the caller's buffer borrows are never released
    /// while a worker could still write through them.
    fn broadcast(&self, cmd: Cmd) {
        self.broadcast_traced(cmd, 0, 0);
    }

    /// As [`EnvPool::broadcast`], recording a `dispatch` span (command
    /// publish) and a `queue` span (barrier wait) under `parent` when
    /// `trace_id` is nonzero.
    fn broadcast_traced(&self, cmd: Cmd, trace_id: u64, parent: u64) {
        if self.shared.poisoned.load(Ordering::Acquire) {
            panic!("EnvPool is poisoned: a worker panicked in an earlier batch");
        }
        debug_assert_eq!(self.shared.done.load(Ordering::Acquire), 0);
        let t0 = if trace_id != 0 { trace::now_ns() } else { 0 };
        // SAFETY: all workers are quiescent between barriers (done was
        // drained to 0), so this is the only access to `cmd`.
        unsafe {
            *self.shared.cmd.get() = cmd;
        }
        self.shared.seq.fetch_add(1, Ordering::Release);
        let t1 = if trace_id != 0 { trace::now_ns() } else { 0 };
        self.await_acks();
        if trace_id != 0 {
            let span = |kind, t_start_ns, t_end_ns| SpanRecord {
                span_id: trace::next_span_id(),
                parent,
                trace_id,
                t_start_ns,
                t_end_ns,
                lane_group: self.n as u32,
                shard: trace::SHARD_LOCAL,
                kind,
            };
            trace::record(span(SpanKind::Dispatch, t0, t1));
            trace::record(span(SpanKind::Queue, t1, trace::now_ns()));
        }
        if self.shared.poisoned.load(Ordering::Acquire) {
            panic!("EnvPool worker panicked while executing a batch command");
        }
    }

    /// Spin until every worker acknowledged the current command (a
    /// panicking worker still acks, so this always terminates).
    fn await_acks(&self) {
        let workers = self.handles.len();
        let mut spins = 0u32;
        while self.shared.done.load(Ordering::Acquire) < workers {
            spins = spins.saturating_add(1);
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        self.shared.done.store(0, Ordering::Release);
    }
}

impl RandomRollout for EnvPool {
    fn random_rollout(&mut self, steps_per_lane: u64) -> RolloutCounts {
        EnvPool::random_rollout(self, steps_per_lane)
    }
}

impl BatchedExecutor for EnvPool {
    fn num_lanes(&self) -> usize {
        self.n
    }

    fn obs_dim(&self) -> usize {
        self.padded
    }

    fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.n * self.padded);
        let trace_id = self.ensure_trace_id();
        let t0 = if trace_id != 0 { trace::now_ns() } else { 0 };
        self.broadcast(Cmd::Reset {
            obs: obs.as_mut_ptr(),
        });
        if trace_id != 0 {
            trace::record(SpanRecord {
                span_id: trace::next_span_id(),
                parent: 0,
                trace_id,
                t_start_ns: t0,
                t_end_ns: trace::now_ns(),
                lane_group: self.n as u32,
                shard: trace::SHARD_LOCAL,
                kind: SpanKind::Reset,
            });
        }
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.n);
        assert_eq!(obs.len(), self.n * self.padded);
        assert_eq!(transitions.len(), self.n);
        let trace_id = self.ensure_trace_id();
        let batch_span = if trace_id != 0 { trace::next_span_id() } else { 0 };
        let timed = trace_id != 0 || crate::telemetry::enabled();
        let t_batch = if timed { trace::now_ns() } else { 0 };
        self.broadcast_traced(
            Cmd::Step {
                actions: actions.as_ptr(),
                obs: obs.as_mut_ptr(),
                transitions: transitions.as_mut_ptr(),
                trace: (trace_id, batch_span),
            },
            trace_id,
            batch_span,
        );
        let ends = transitions.iter().filter(|t| t.done || t.truncated).count();
        if timed {
            let t_end = trace::now_ns();
            if batch_span != 0 {
                trace::record(SpanRecord {
                    span_id: batch_span,
                    parent: 0,
                    trace_id,
                    t_start_ns: t_batch,
                    t_end_ns: t_end,
                    lane_group: self.n as u32,
                    shard: trace::SHARD_LOCAL,
                    kind: SpanKind::Batch,
                });
            }
            self.metrics.record_batch_timed(self.n, ends, t_batch, t_end);
        } else {
            self.metrics.record_batch(self.n, ends);
        }
    }

    fn set_panic_policy(&mut self, policy: PanicPolicy) {
        self.shared
            .quarantine
            .store(matches!(policy, PanicPolicy::Quarantine), Ordering::Release);
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        if self.shared.poisoned.load(Ordering::Acquire) {
            // Workers exit on their own via the poison flag; never
            // panic out of drop.
        } else {
            // Publish Shutdown directly (broadcast would re-panic if a
            // worker somehow poisoned the final round).
            // SAFETY: workers are quiescent between barriers.
            unsafe {
                *self.shared.cmd.get() = Cmd::Shutdown;
            }
            self.shared.seq.fetch_add(1, Ordering::Release);
            self.await_acks();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Body of one sync worker: wait for a command, run it over the owned
/// lane groups, acknowledge, repeat.  Env panics are caught so the
/// round's ack still happens; the pool is poisoned instead of deadlocked.
fn sync_worker(
    shared: Arc<SyncShared>,
    mut groups: Vec<BuiltGroup>,
    padded: usize,
    origin: RolloutOrigin,
) {
    // Per-group dead-lane flags, only consulted in quarantine mode.
    let mut dead: Vec<Vec<bool>> = groups
        .iter()
        .map(|g| vec![false; g.batch.lanes()])
        .collect();
    let mut last_seq = 0u64;
    loop {
        let Some(seq) = wait_for_seq(&shared, last_seq) else {
            return; // a sibling worker panicked: the pool is done
        };
        last_seq = seq;
        // SAFETY: the coordinator finished writing `cmd` before the seq
        // bump we just acquired, and will not write again until this
        // worker (and all others) increments `done`.
        let cmd = unsafe { *shared.cmd.get() };
        let shutdown = matches!(cmd, Cmd::Shutdown);
        let quarantine = shared.quarantine.load(Ordering::Acquire);
        let ok = catch_unwind(AssertUnwindSafe(|| {
            run_cmd(cmd, &mut groups, padded, origin, &shared, quarantine, &mut dead);
        }))
        .is_ok();
        if !ok {
            shared.poisoned.store(true, Ordering::Release);
        }
        shared.done.fetch_add(1, Ordering::Release);
        if !ok || shutdown {
            return;
        }
    }
}

/// Execute one command over a worker's lane groups — one batch call per
/// group (the fusion hot path: a fused group advances all its lanes in
/// a single `step_batch`, a scalar group replays the per-lane loop).
/// Slots are `padded` wide; groups re-zero tails on every write (caller
/// buffers are arbitrary).
///
/// In quarantine mode (`quarantine` true) Reset/Step instead step each
/// lane individually — `step_lane`/`reset_lane` are bitwise identical
/// to the batch calls — under `catch_unwind`: a panicking lane flips
/// its `dead` flag and from then on reads a zeroed slot and a
/// [`quarantined_transition`], while every other lane is untouched.
fn run_cmd(
    cmd: Cmd,
    groups: &mut [BuiltGroup],
    padded: usize,
    origin: RolloutOrigin,
    shared: &SyncShared,
    quarantine: bool,
    dead: &mut [Vec<bool>],
) {
    match cmd {
        Cmd::Idle | Cmd::Shutdown => {}
        Cmd::Reset { obs } => {
            for (gi, group) in groups.iter_mut().enumerate() {
                let lanes = group.batch.lanes();
                // SAFETY: group lane ranges are disjoint across workers
                // and the caller's `&mut [f32]` is pinned by the barrier.
                let block = unsafe {
                    std::slice::from_raw_parts_mut(
                        obs.add(group.lane_start * padded),
                        lanes * padded,
                    )
                };
                if !quarantine {
                    group.batch.reset_batch(block, padded);
                    continue;
                }
                for k in 0..lanes {
                    let slot = &mut block[k * padded..(k + 1) * padded];
                    if dead[gi][k] {
                        slot.fill(0.0);
                        continue;
                    }
                    let (front, tail) = slot.split_at_mut(group.batch.lane_obs_dim(k));
                    match catch_unwind(AssertUnwindSafe(|| group.batch.reset_lane(k, front))) {
                        Ok(()) => tail.fill(0.0),
                        Err(_) => {
                            dead[gi][k] = true;
                            note_quarantined_lane();
                            front.fill(0.0);
                            tail.fill(0.0);
                        }
                    }
                }
            }
        }
        Cmd::Step {
            actions,
            obs,
            transitions,
            trace: (trace_id, parent),
        } => {
            for (gi, group) in groups.iter_mut().enumerate() {
                let lanes = group.batch.lanes();
                // SAFETY: as above — disjoint contiguous lane ranges,
                // barrier-pinned borrows, actions only read.
                let acts = unsafe {
                    std::slice::from_raw_parts(actions.add(group.lane_start), lanes)
                };
                let block = unsafe {
                    std::slice::from_raw_parts_mut(
                        obs.add(group.lane_start * padded),
                        lanes * padded,
                    )
                };
                let trs = unsafe {
                    std::slice::from_raw_parts_mut(transitions.add(group.lane_start), lanes)
                };
                if !quarantine {
                    let start = group.lane_start as u32;
                    trace::with_span(
                        SpanKind::Kernel,
                        trace_id,
                        parent,
                        start,
                        trace::SHARD_LOCAL,
                        || group.batch.step_batch(acts, block, padded, trs),
                    );
                    continue;
                }
                for k in 0..lanes {
                    let slot = &mut block[k * padded..(k + 1) * padded];
                    if dead[gi][k] {
                        slot.fill(0.0);
                        trs[k] = quarantined_transition();
                        continue;
                    }
                    let (front, tail) = slot.split_at_mut(group.batch.lane_obs_dim(k));
                    match catch_unwind(AssertUnwindSafe(|| {
                        group.batch.step_lane(k, &acts[k], front)
                    })) {
                        Ok(t) => {
                            tail.fill(0.0);
                            trs[k] = t;
                        }
                        Err(_) => {
                            dead[gi][k] = true;
                            note_quarantined_lane();
                            front.fill(0.0);
                            tail.fill(0.0);
                            trs[k] = quarantined_transition();
                        }
                    }
                }
            }
        }
        Cmd::RandomSteps { steps_per_lane } => {
            // Free-running: no coordinator round-trips.  Per-lane rng
            // streams and seeding are fixed, so counts are reproducible
            // and thread-count independent.
            let mut episodes = 0u64;
            for group in groups {
                episodes += batch_random_steps(
                    group.batch.as_mut(),
                    steps_per_lane,
                    origin.0,
                    origin.1 + group.lane_start,
                );
            }
            // Published to the coordinator by the Release ack in
            // `sync_worker` (it reads only after the barrier drains).
            shared.episodes.fetch_add(episodes, Ordering::Relaxed);
        }
    }
}

/// One ready lane handed back by an async worker: the lane id plus its
/// transition.  The observation is *not* carried here — it already sits
/// in the lane's slot of the shared block (the zero-copy handoff).
#[derive(Clone, Copy)]
struct ReadyEntry {
    lane: usize,
    transition: Transition,
}

/// The shared `[n * padded]` observation block behind [`AsyncEnvPool`].
///
/// Ownership protocol (which is what makes the unsafe accessors sound):
/// a lane's slot belongs to its worker from the moment the coordinator
/// enqueues a command for that lane until the worker pushes the lane id
/// onto the ready queue; it belongs to the coordinator from popping the
/// lane id until the next command for that lane.  Both handoffs happen
/// through a `Mutex`, so the writes are published before the other side
/// can touch the slot.
struct SlotBlock {
    ptr: *mut [f32],
    padded: usize,
}

impl SlotBlock {
    fn new(n: usize, padded: usize) -> SlotBlock {
        let block = vec![0.0f32; n * padded].into_boxed_slice();
        SlotBlock {
            ptr: Box::into_raw(block),
            padded,
        }
    }

    /// SAFETY: the caller must own `lane` per the protocol above.
    unsafe fn lane(&self, lane: usize) -> &[f32] {
        std::slice::from_raw_parts(
            (self.ptr as *const f32).add(lane * self.padded),
            self.padded,
        )
    }

    /// SAFETY: the caller must own `lane` per the protocol above.
    #[allow(clippy::mut_from_ref)] // interior mutability via the ownership protocol
    unsafe fn lane_mut(&self, lane: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(
            (self.ptr as *mut f32).add(lane * self.padded),
            self.padded,
        )
    }

    /// Contiguous slots of lanes `[first, first + lanes)` as one strided
    /// block — the group-drain fast path writes a whole `step_batch`
    /// result here in place.
    ///
    /// SAFETY: the caller must own **every** lane in the range per the
    /// protocol above.
    #[allow(clippy::mut_from_ref)] // interior mutability via the ownership protocol
    unsafe fn range_mut(&self, first: usize, lanes: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(
            (self.ptr as *mut f32).add(first * self.padded),
            lanes * self.padded,
        )
    }
}

impl Drop for SlotBlock {
    fn drop(&mut self) {
        // SAFETY: `ptr` came from `Box::into_raw` in `new` and is
        // dropped exactly once (SlotBlock is never cloned).
        unsafe {
            drop(Box::from_raw(self.ptr));
        }
    }
}

// SAFETY: slot access is serialised per lane by the ownership protocol.
unsafe impl Send for SlotBlock {}
unsafe impl Sync for SlotBlock {}

/// Queue contents plus the poison flag, under one lock so waiters can
/// check both atomically (no lost-wakeup window).
struct QueueState {
    q: VecDeque<ReadyEntry>,
    poisoned: bool,
}

struct ReadyQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl ReadyQueue {
    fn with_capacity(n: usize) -> ReadyQueue {
        ReadyQueue {
            state: Mutex::new(QueueState {
                q: VecDeque::with_capacity(n),
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, e: ReadyEntry) {
        self.state.lock().unwrap().q.push_back(e);
        self.cv.notify_one();
    }

    /// Mark the pool dead (a worker's env panicked) and wake every
    /// waiter so blocked `recv_batch`/`collect_exact` calls surface the
    /// failure instead of sleeping forever.
    fn poison(&self) {
        self.state.lock().unwrap().poisoned = true;
        self.cv.notify_all();
    }
}

enum WorkerMsg {
    Step { lane: usize, action: Action },
    Reset,
}

/// Per-worker command mailbox: a bounded-by-contract deque (at most one
/// outstanding action per lane) so pushes never reallocate in steady
/// state, plus a `closed` flag for shutdown and panic signalling.
struct Mailbox {
    state: Mutex<MailboxState>,
    cv: Condvar,
}

struct MailboxState {
    q: VecDeque<WorkerMsg>,
    closed: bool,
}

impl Mailbox {
    fn with_capacity(n: usize) -> Mailbox {
        Mailbox {
            state: Mutex::new(MailboxState {
                // +2: a Reset alongside a full complement of Steps,
                // with one slot of slack so a push never reallocates.
                q: VecDeque::with_capacity(n + 2),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a command; panics if the worker is gone.
    fn send(&self, msg: WorkerMsg, what: &str) {
        let mut st = self.state.lock().unwrap();
        assert!(
            !st.closed,
            "AsyncEnvPool worker panicked before receiving {what}"
        );
        st.q.push_back(msg);
        drop(st);
        self.cv.notify_one();
    }

    /// Close the mailbox (shutdown or worker panic) and wake the waiter.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Persistent-worker pool, asynchronous mode: workers run ahead.
///
/// After construction every lane is reset and enqueued ready.  The
/// coordinator loop is
/// [`recv_batch`](AsyncEnvPool::recv_batch) → act →
/// [`send_actions`](AsyncEnvPool::send_actions): a worker steps a lane
/// the moment its action lands, regardless of what other lanes are
/// doing, so slow lanes never stall the batch (the async half of
/// EnvPool's design).  There is no global barrier anywhere.
///
/// Observations travel zero-copy: each lane owns a slot in one shared
/// `[n * padded]` block ([`SlotBlock`]); a worker steps the env straight
/// into the slot and hands back only `(lane, transition)`.
/// [`AsyncBatch`] views borrow the slots in place, so a steady-state
/// `recv_batch`/`send_actions` cycle performs **zero heap allocations**
/// (continuous actions, which box a `Vec`, are the one exception).
///
/// Per-lane trajectories remain bit-identical to sequential execution —
/// only the interleaving across lanes is nondeterministic.
///
/// The [`BatchedExecutor`] impl drives the same machinery in lockstep
/// (send all, receive all) for drop-in comparisons with the sync
/// executors; don't interleave trait calls with the native async API.
pub struct AsyncEnvPool {
    mailboxes: Vec<Arc<Mailbox>>,
    handles: Vec<JoinHandle<()>>,
    ready: Arc<ReadyQueue>,
    slots: Arc<SlotBlock>,
    /// lane -> owning worker index.
    owner: Vec<usize>,
    /// Reusable `recv_batch` output buffers (capacity `n`, never grown
    /// past it — the allocation-free guarantee).
    batch_lanes: Vec<usize>,
    batch_transitions: Vec<Transition>,
    specs: Vec<LaneSpec>,
    /// True until the construction-time reset results are consumed.  The
    /// first lockstep `reset_into` takes those instead of re-resetting,
    /// so lane RNG streams stay aligned with `VecEnv` (whose first
    /// `reset_into` is each env's *first* reset).
    pristine: bool,
    n: usize,
    padded: usize,
    metrics: ExecMetrics,
    /// Ready-queue depth left behind by the last `recv_batch`
    /// (`cairl_async_ready_depth`).
    ready_depth: Gauge,
    /// [`PanicPolicy::Quarantine`] selected — workers step lanes under
    /// per-lane `catch_unwind` and retire panicking lanes.
    quarantine: Arc<AtomicBool>,
    /// Trace id minted lazily on the first traced batch (0 until then).
    trace_id: u64,
}

impl AsyncEnvPool {
    /// Build a homogeneous async pool; seeding and lane partitioning
    /// follow [`EnvPool::new`] exactly.
    pub fn new<E, F>(n: usize, base_seed: u64, threads: usize, mut factory: F) -> AsyncEnvPool
    where
        E: Env + Send + 'static,
        F: FnMut() -> E,
    {
        assert!(n > 0, "AsyncEnvPool needs at least one lane");
        let envs: Vec<E> = (0..n).map(|_| factory()).collect();
        AsyncEnvPool::from_envs(envs, base_seed, threads)
    }

    /// Build an async pool over an explicit lane-ordered env list — the
    /// scenario-mixture constructor ([`EnvPool::from_envs`] semantics).
    pub fn from_envs<E>(envs: Vec<E>, base_seed: u64, threads: usize) -> AsyncEnvPool
    where
        E: Env + Send + 'static,
    {
        let ids = own_ids(&envs);
        AsyncEnvPool::from_labeled_envs(ids, envs, base_seed, threads)
    }

    /// [`AsyncEnvPool::from_envs`] with explicit lane labels
    /// ([`EnvPool::from_labeled_envs`] semantics).
    pub fn from_labeled_envs<E>(
        ids: Vec<String>,
        mut envs: Vec<E>,
        base_seed: u64,
        threads: usize,
    ) -> AsyncEnvPool
    where
        E: Env + Send + 'static,
    {
        let n = envs.len();
        assert!(n > 0, "AsyncEnvPool needs at least one lane");
        for (i, env) in envs.iter_mut().enumerate() {
            env.seed(base_seed + i as u64);
        }
        let (specs, padded) = lane_layout(&envs, &ids);

        let chunk = n.div_ceil(threads.clamp(1, n));
        AsyncEnvPool::spawn(scalar_chunks(envs, chunk), specs, padded, chunk)
    }

    /// Build an async pool from a lane-group plan
    /// ([`EnvPool::from_groups`] semantics).  Groups give workers SoA
    /// lane state; stepping stays eager per lane (the ready-queue
    /// contract) through single [`BatchEnv::step_lane`] calls.
    pub fn from_groups(
        groups: Vec<LaneGroupSpec>,
        base_seed: u64,
        threads: usize,
    ) -> AsyncEnvPool {
        let n: usize = groups.iter().map(|g| g.lanes()).sum();
        assert!(n > 0, "AsyncEnvPool needs at least one lane");
        let chunk = n.div_ceil(threads.clamp(1, n));
        let (built, specs, padded) = materialize_groups(groups, base_seed, chunk);
        AsyncEnvPool::spawn(built, specs, padded, chunk)
    }

    /// Spawn one worker per `chunk`-wide lane range with the groups
    /// inside it, reset every lane and enqueue it ready.
    fn spawn(
        built: Vec<BuiltGroup>,
        specs: Vec<LaneSpec>,
        padded: usize,
        chunk: usize,
    ) -> AsyncEnvPool {
        let n = specs.len();
        let ready = Arc::new(ReadyQueue::with_capacity(n));
        let slots = Arc::new(SlotBlock::new(n, padded));
        let quarantine = Arc::new(AtomicBool::new(false));

        let per_worker = group_by_worker(built, n, chunk);
        let mut mailboxes = Vec::new();
        let mut handles = Vec::new();
        let mut owner = vec![0usize; n];
        // One shared backlog-depth gauge across workers (last write
        // wins — a depth sample, not a sum).
        let backlog_depth = gauge("cairl_async_backlog_depth");
        for (worker_idx, worker_groups) in per_worker.into_iter().enumerate() {
            let first = worker_groups
                .first()
                .expect("every worker chunk owns at least one group")
                .lane_start;
            let lanes: usize = worker_groups.iter().map(|g| g.batch.lanes()).sum();
            owner[first..first + lanes].fill(worker_idx);
            let mailbox = Arc::new(Mailbox::with_capacity(lanes));
            let mailbox_w = Arc::clone(&mailbox);
            let ready_w = Arc::clone(&ready);
            let slots_w = Arc::clone(&slots);
            let backlog_w = backlog_depth.clone();
            let quarantine_w = Arc::clone(&quarantine);
            let handle = std::thread::Builder::new()
                .name(format!("envpool-async-{first}"))
                .spawn(move || {
                    async_worker(mailbox_w, ready_w, slots_w, worker_groups, backlog_w, quarantine_w)
                })
                .expect("spawn async pool worker");
            mailboxes.push(mailbox);
            handles.push(handle);
        }

        AsyncEnvPool {
            mailboxes,
            handles,
            ready,
            slots,
            owner,
            batch_lanes: Vec::with_capacity(n),
            batch_transitions: Vec::with_capacity(n),
            specs,
            pristine: true,
            n,
            padded,
            metrics: ExecMetrics::for_executor("pool-async"),
            ready_depth: gauge("cairl_async_ready_depth"),
            quarantine,
            trace_id: 0,
        }
    }

    /// This pool's trace id, minted on first use while tracing is
    /// enabled; `0` while tracing is off (one load + branch).
    fn ensure_trace_id(&mut self) -> u64 {
        if !trace::enabled() {
            return 0;
        }
        if self.trace_id == 0 {
            self.trace_id = trace::new_trace_id();
        }
        self.trace_id
    }

    /// Number of worker threads actually running.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Submit actions for specific lanes.  Each named lane must be
    /// "owed" to the pool: received via
    /// [`recv_batch`](AsyncEnvPool::recv_batch) (or initially ready) and
    /// not yet sent an action.
    pub fn send_actions(&mut self, actions: &[(usize, Action)]) {
        for (lane, action) in actions {
            assert!(*lane < self.n, "lane {lane} out of range");
            self.mailboxes[self.owner[*lane]].send(
                WorkerMsg::Step {
                    lane: *lane,
                    action: action.clone(),
                },
                "an action",
            );
        }
    }

    /// Receive up to `max` ready lanes, blocking until at least one is
    /// available.  Only lanes with submitted (or initial) work become
    /// ready, so call this with outstanding lanes or it will block
    /// forever.
    ///
    /// The returned [`AsyncBatch`] borrows the pool: observations are
    /// read in place from the shared slot block (no copy, no
    /// allocation).  Drop the batch before the next
    /// [`send_actions`](AsyncEnvPool::send_actions).
    pub fn recv_batch(&mut self, max: usize) -> AsyncBatch<'_> {
        assert!(max > 0);
        self.batch_lanes.clear();
        self.batch_transitions.clear();
        let left_ready;
        {
            let mut state = self.ready.state.lock().unwrap();
            while state.q.is_empty() {
                assert!(
                    !state.poisoned,
                    "AsyncEnvPool worker panicked; no more lanes will become ready"
                );
                state = self.ready.cv.wait(state).unwrap();
            }
            let k = state.q.len().min(max);
            for _ in 0..k {
                let e = state.q.pop_front().expect("non-empty by construction");
                self.batch_lanes.push(e.lane);
                self.batch_transitions.push(e.transition);
            }
            left_ready = state.q.len();
        }
        self.ready_depth.set(left_ready as i64);
        let ends = self
            .batch_transitions
            .iter()
            .filter(|t| t.done || t.truncated)
            .count();
        self.metrics.record_batch(self.batch_lanes.len(), ends);
        self.pristine = false;
        AsyncBatch { pool: self }
    }

    /// Pop exactly `k` ready lanes (blocking), handing each entry's lane
    /// id, transition and slot contents to `sink`.
    fn collect_exact(&self, k: usize, mut sink: impl FnMut(usize, Transition, &[f32])) {
        let mut state = self.ready.state.lock().unwrap();
        for _ in 0..k {
            while state.q.is_empty() {
                assert!(
                    !state.poisoned,
                    "AsyncEnvPool worker panicked; no more lanes will become ready"
                );
                state = self.ready.cv.wait(state).unwrap();
            }
            let e = state.q.pop_front().expect("non-empty by construction");
            // SAFETY: popping the entry transferred slot ownership to us.
            let obs = unsafe { self.slots.lane(e.lane) };
            sink(e.lane, e.transition, obs);
        }
    }
}

/// A batch of ready lanes, borrowing the pool's shared slot block —
/// EnvPool's compacted XLA-friendly shape without the compaction copy.
///
/// Entry `j` is lane `lanes()[j]`; its padded observation slot is
/// [`obs`](AsyncBatch::obs)`(j)` and its true (unpadded) observation is
/// [`obs_unpadded`](AsyncBatch::obs_unpadded)`(j)`.  The borrow pins the
/// pool, so the slots cannot be overwritten while the batch is alive.
pub struct AsyncBatch<'p> {
    pool: &'p AsyncEnvPool,
}

impl AsyncBatch<'_> {
    /// Number of ready lanes in the batch.
    pub fn len(&self) -> usize {
        self.pool.batch_lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pool.batch_lanes.is_empty()
    }

    /// Lane ids, in ready order.
    pub fn lanes(&self) -> &[usize] {
        &self.pool.batch_lanes
    }

    /// Per-entry transitions, aligned with [`lanes`](AsyncBatch::lanes)
    /// (`Transition::default()` for the initial reset).
    pub fn transitions(&self) -> &[Transition] {
        &self.pool.batch_transitions
    }

    /// Entry `j`'s padded observation slot (length
    /// [`BatchedExecutor::obs_dim`]); the tail beyond the lane's true
    /// `obs_dim` is zero.
    pub fn obs(&self, j: usize) -> &[f32] {
        let lane = self.pool.batch_lanes[j];
        // SAFETY: lanes in the batch are coordinator-owned until the
        // next command, and the borrow of the pool pins that state.
        unsafe { self.pool.slots.lane(lane) }
    }

    /// Entry `j`'s observation sliced to its lane's true `obs_dim`.
    pub fn obs_unpadded(&self, j: usize) -> &[f32] {
        let lane = self.pool.batch_lanes[j];
        &self.obs(j)[..self.pool.specs[lane].obs_dim]
    }

    /// Entry `j`'s lane spec.
    pub fn lane_spec(&self, j: usize) -> &LaneSpec {
        &self.pool.specs[self.pool.batch_lanes[j]]
    }
}

impl BatchedExecutor for AsyncEnvPool {
    fn num_lanes(&self) -> usize {
        self.n
    }

    fn obs_dim(&self) -> usize {
        self.padded
    }

    fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.n * self.padded);
        let trace_id = self.ensure_trace_id();
        let t0 = if trace_id != 0 { trace::now_ns() } else { 0 };
        if !self.pristine {
            // Re-reset every lane; the queue is empty between lockstep
            // calls, so the next n entries are exactly the reset results.
            for mailbox in &self.mailboxes {
                mailbox.send(WorkerMsg::Reset, "a reset");
            }
        }
        // A pristine pool consumes the construction-time reset instead:
        // each env's first reset, matching sequential `VecEnv` exactly.
        self.pristine = false;
        let d = self.padded;
        self.collect_exact(self.n, |lane, _t, slot| {
            obs[lane * d..(lane + 1) * d].copy_from_slice(slot);
        });
        if trace_id != 0 {
            trace::record(SpanRecord {
                span_id: trace::next_span_id(),
                parent: 0,
                trace_id,
                t_start_ns: t0,
                t_end_ns: trace::now_ns(),
                lane_group: self.n as u32,
                shard: trace::SHARD_LOCAL,
                kind: SpanKind::Reset,
            });
        }
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.n);
        assert_eq!(obs.len(), self.n * self.padded);
        assert_eq!(transitions.len(), self.n);
        if self.pristine {
            // Stepping without an explicit reset: the lanes were reset at
            // construction; drain those entries so the collection below
            // sees only step results.
            self.collect_exact(self.n, |_, _, _| {});
            self.pristine = false;
        }
        let trace_id = self.ensure_trace_id();
        let batch_span = if trace_id != 0 { trace::next_span_id() } else { 0 };
        let timed = trace_id != 0 || crate::telemetry::enabled();
        let t_batch = if timed { trace::now_ns() } else { 0 };
        let n = self.n;
        let shard = trace::SHARD_LOCAL;
        trace::with_span(SpanKind::Dispatch, trace_id, batch_span, n as u32, shard, || {
            for (lane, action) in actions.iter().enumerate() {
                self.mailboxes[self.owner[lane]].send(
                    WorkerMsg::Step {
                        lane,
                        action: action.clone(),
                    },
                    "an action",
                );
            }
        });
        let d = self.padded;
        // Collect all n results; per-lane writes land in lane order
        // regardless of arrival order, restoring batch determinism.
        // Exactly-once per lane holds because each lane was sent exactly
        // one action and workers publish one entry per action (pinned by
        // the executor_pool integration tests).
        trace::with_span(SpanKind::Slot, trace_id, batch_span, n as u32, shard, || {
            self.collect_exact(n, |lane, t, slot| {
                obs[lane * d..(lane + 1) * d].copy_from_slice(slot);
                transitions[lane] = t;
            });
        });
        let ends = transitions.iter().filter(|t| t.done || t.truncated).count();
        if timed {
            let t_end = trace::now_ns();
            if batch_span != 0 {
                trace::record(SpanRecord {
                    span_id: batch_span,
                    parent: 0,
                    trace_id,
                    t_start_ns: t_batch,
                    t_end_ns: t_end,
                    lane_group: n as u32,
                    shard: trace::SHARD_LOCAL,
                    kind: SpanKind::Batch,
                });
            }
            self.metrics.record_batch_timed(n, ends, t_batch, t_end);
        } else {
            self.metrics.record_batch(n, ends);
        }
    }

    fn set_panic_policy(&mut self, policy: PanicPolicy) {
        self.quarantine
            .store(matches!(policy, PanicPolicy::Quarantine), Ordering::Release);
    }
}

impl Drop for AsyncEnvPool {
    fn drop(&mut self) {
        for mailbox in &self.mailboxes {
            mailbox.close(); // hang up: workers exit on the closed flag
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Body of one async worker: buffer the mailbox backlog, then step it.
///
/// The baseline behaviour is eager per-lane stepping (one
/// [`BatchEnv::step_lane`] call straight into the lane's shared slot the
/// moment its action lands — the ready-queue contract).  On top of
/// that, the worker **opportunistically drains** whatever has already
/// accumulated in its mailbox before stepping: when the backlog covers
/// *all* of a group's lanes — the steady state of a lockstep
/// coordinator, which posts every action before collecting — the whole
/// group advances through **one [`BatchEnv::step_batch`] call** into
/// its contiguous slot range instead of N `step_lane` dispatches, so
/// fused SoA kernels run their tight columnar loop even in the async
/// pool.  Partially covered groups step lane by lane as before; either
/// way the per-lane operations are identical, so trajectories are
/// unchanged bit for bit (the executor equality suites pin this — the
/// drain is a pure performance transform).
///
/// Env panics poison the ready queue (waking blocked receivers) and
/// close the mailbox (failing senders) instead of leaving them asleep.
fn async_worker(
    mailbox: Arc<Mailbox>,
    ready: Arc<ReadyQueue>,
    slots: Arc<SlotBlock>,
    mut groups: Vec<BuiltGroup>,
    backlog: Gauge,
    quarantine: Arc<AtomicBool>,
) {
    fn publish_reset(
        groups: &mut [BuiltGroup],
        ready: &ReadyQueue,
        slots: &SlotBlock,
        quarantine: bool,
        dead: &mut [Vec<bool>],
    ) {
        for (gi, group) in groups.iter_mut().enumerate() {
            for k in 0..group.batch.lanes() {
                let lane = group.lane_start + k;
                // SAFETY: a reset command (or construction) handed this
                // worker ownership of all its lanes' slots.
                let slot = unsafe { slots.lane_mut(lane) };
                if quarantine && dead[gi][k] {
                    // A quarantined lane stays dead across resets.
                    slot.fill(0.0);
                    ready.push(ReadyEntry {
                        lane,
                        transition: quarantined_transition(),
                    });
                    continue;
                }
                let (obs, tail) = slot.split_at_mut(group.batch.lane_obs_dim(k));
                if quarantine {
                    match catch_unwind(AssertUnwindSafe(|| group.batch.reset_lane(k, obs))) {
                        Ok(()) => tail.fill(0.0),
                        Err(_) => {
                            dead[gi][k] = true;
                            note_quarantined_lane();
                            obs.fill(0.0);
                            tail.fill(0.0);
                            ready.push(ReadyEntry {
                                lane,
                                transition: quarantined_transition(),
                            });
                            continue;
                        }
                    }
                } else {
                    group.batch.reset_lane(k, obs);
                    tail.fill(0.0);
                }
                ready.push(ReadyEntry {
                    lane,
                    transition: Transition::default(),
                });
            }
        }
    }

    /// Step every buffered action: one `step_batch` per fully covered
    /// group, `step_lane` for the rest.  Buffers are caller-owned and
    /// capacity-reserved, so the steady state allocates nothing.  In
    /// quarantine mode every lane steps individually under
    /// `catch_unwind` (bitwise identical per-lane operations); a
    /// panicking lane is retired in place.
    #[allow(clippy::too_many_arguments)]
    fn flush_pending(
        groups: &mut [BuiltGroup],
        first_lane: usize,
        pending: &mut [Option<Action>],
        pending_count: &mut usize,
        act_buf: &mut Vec<Action>,
        tr_buf: &mut [Transition],
        ready: &ReadyQueue,
        slots: &SlotBlock,
        quarantine: bool,
        dead: &mut [Vec<bool>],
    ) {
        if *pending_count == 0 {
            return;
        }
        for (gi, group) in groups.iter_mut().enumerate() {
            let lanes = group.batch.lanes();
            let base = group.lane_start - first_lane;
            let have = pending[base..base + lanes].iter().filter(|a| a.is_some()).count();
            if have == 0 {
                continue;
            }
            if quarantine {
                for k in 0..lanes {
                    let Some(action) = pending[base + k].take() else {
                        continue;
                    };
                    let lane = group.lane_start + k;
                    // SAFETY: the Step message handed us this lane's slot.
                    let slot = unsafe { slots.lane_mut(lane) };
                    *pending_count -= 1;
                    if dead[gi][k] {
                        slot.fill(0.0);
                        ready.push(ReadyEntry {
                            lane,
                            transition: quarantined_transition(),
                        });
                        continue;
                    }
                    let (obs, tail) = slot.split_at_mut(group.batch.lane_obs_dim(k));
                    match catch_unwind(AssertUnwindSafe(|| {
                        group.batch.step_lane(k, &action, obs)
                    })) {
                        Ok(t) => {
                            tail.fill(0.0);
                            ready.push(ReadyEntry {
                                lane,
                                transition: t,
                            });
                        }
                        Err(_) => {
                            dead[gi][k] = true;
                            note_quarantined_lane();
                            obs.fill(0.0);
                            tail.fill(0.0);
                            ready.push(ReadyEntry {
                                lane,
                                transition: quarantined_transition(),
                            });
                        }
                    }
                }
                continue;
            }
            if have == lanes {
                // Full backlog: the whole group steps as one batch,
                // straight into its contiguous slot range.
                act_buf.clear();
                for slot in &mut pending[base..base + lanes] {
                    act_buf.push(slot.take().expect("counted above"));
                }
                // SAFETY: every lane in the range carried a pending
                // action, so this worker owns all of their slots.
                let block = unsafe { slots.range_mut(group.lane_start, lanes) };
                group.batch.step_batch(act_buf, block, slots.padded, &mut tr_buf[..lanes]);
                for (k, t) in tr_buf[..lanes].iter().enumerate() {
                    ready.push(ReadyEntry {
                        lane: group.lane_start + k,
                        transition: *t,
                    });
                }
                *pending_count -= lanes;
            } else {
                for k in 0..lanes {
                    let Some(action) = pending[base + k].take() else {
                        continue;
                    };
                    let lane = group.lane_start + k;
                    // SAFETY: the Step message handed us this lane's slot.
                    let slot = unsafe { slots.lane_mut(lane) };
                    let (obs, tail) = slot.split_at_mut(group.batch.lane_obs_dim(k));
                    let t = group.batch.step_lane(k, &action, obs);
                    tail.fill(0.0);
                    ready.push(ReadyEntry {
                        lane,
                        transition: t,
                    });
                    *pending_count -= 1;
                }
            }
        }
    }

    let first_lane = groups.first().map_or(0, |g| g.lane_start);
    let total_lanes: usize = groups.iter().map(|g| g.batch.lanes()).sum();
    // Backlog buffers, allocated once: at most one outstanding action
    // per lane by the mailbox contract.
    let mut pending: Vec<Option<Action>> = vec![None; total_lanes];
    let mut pending_count = 0usize;
    let mut act_buf: Vec<Action> = Vec::with_capacity(total_lanes);
    let mut tr_buf: Vec<Transition> = vec![Transition::default(); total_lanes];
    // Per-group dead-lane flags, only consulted in quarantine mode.
    let mut dead: Vec<Vec<bool>> = groups
        .iter()
        .map(|g| vec![false; g.batch.lanes()])
        .collect();

    let result = catch_unwind(AssertUnwindSafe(|| {
        publish_reset(
            &mut groups,
            &ready,
            &slots,
            quarantine.load(Ordering::Acquire),
            &mut dead,
        );
        loop {
            // Block for the first message, then drain the backlog
            // without blocking.
            let msg = {
                let mut st = mailbox.state.lock().unwrap();
                loop {
                    if let Some(m) = st.q.pop_front() {
                        break m;
                    }
                    if st.closed {
                        return;
                    }
                    st = mailbox.cv.wait(st).unwrap();
                }
            };
            let quarantined = quarantine.load(Ordering::Acquire);
            let mut next = Some(msg);
            while let Some(msg) = next {
                match msg {
                    WorkerMsg::Reset => {
                        // Order-preserving: whatever was queued before
                        // the reset steps first.
                        flush_pending(
                            &mut groups,
                            first_lane,
                            &mut pending,
                            &mut pending_count,
                            &mut act_buf,
                            &mut tr_buf,
                            &ready,
                            &slots,
                            quarantined,
                            &mut dead,
                        );
                        publish_reset(&mut groups, &ready, &slots, quarantined, &mut dead);
                    }
                    WorkerMsg::Step { lane, action } => {
                        let idx = lane - first_lane;
                        // Hard assert (not debug): silently overwriting a
                        // buffered action would lose a transition and
                        // deadlock the coordinator; panicking poisons the
                        // pool and surfaces the contract violation.
                        assert!(
                            pending[idx].is_none(),
                            "lane {lane} was sent two actions without a recv"
                        );
                        pending[idx] = Some(action);
                        pending_count += 1;
                    }
                }
                next = mailbox.state.lock().unwrap().q.pop_front();
            }
            // Sample the backlog accumulated this round before stepping
            // it (post-flush it is always zero).
            backlog.set(pending_count as i64);
            flush_pending(
                &mut groups,
                first_lane,
                &mut pending,
                &mut pending_count,
                &mut act_buf,
                &mut tr_buf,
                &ready,
                &slots,
                quarantined,
                &mut dead,
            );
        }
    }));
    if result.is_err() {
        ready.poison();
        mailbox.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::vec_env::VecEnv;
    use crate::envs::{CartPole, MountainCar};
    use crate::wrappers::TimeLimit;

    fn cartpole_factory() -> impl Fn() -> TimeLimit<CartPole> {
        || TimeLimit::new(CartPole::new(), 40)
    }

    /// Drive any executor with a fixed action pattern, returning the
    /// concatenated (obs, transition) stream.
    fn drive(
        exec: &mut dyn BatchedExecutor,
        steps: usize,
    ) -> (Vec<f32>, Vec<Transition>) {
        let n = exec.num_lanes();
        let d = exec.obs_dim();
        let mut obs = vec![0.0f32; n * d];
        let mut tr = vec![Transition::default(); n];
        let mut obs_trace = Vec::new();
        let mut tr_trace = Vec::new();
        exec.reset_into(&mut obs);
        obs_trace.extend_from_slice(&obs);
        for step in 0..steps {
            let actions: Vec<Action> =
                (0..n).map(|i| Action::Discrete((step + i) % 2)).collect();
            exec.step_into(&actions, &mut obs, &mut tr);
            obs_trace.extend_from_slice(&obs);
            tr_trace.extend_from_slice(&tr);
        }
        (obs_trace, tr_trace)
    }

    #[test]
    fn sync_pool_matches_vec_env_bitwise() {
        let mut vec_env = VecEnv::new(5, 900, cartpole_factory());
        let mut pool = EnvPool::new(5, 900, 2, cartpole_factory());
        let (obs_a, tr_a) = drive(&mut vec_env, 150);
        let (obs_b, tr_b) = drive(&mut pool, 150);
        assert_eq!(tr_a, tr_b);
        assert_eq!(obs_a, obs_b);
    }

    #[test]
    fn sync_pool_is_thread_count_invariant() {
        let traces: Vec<_> = [1usize, 3, 5]
            .iter()
            .map(|&threads| {
                let mut pool = EnvPool::new(4, 31, threads, cartpole_factory());
                drive(&mut pool, 120)
            })
            .collect();
        assert_eq!(traces[0], traces[1]);
        assert_eq!(traces[0], traces[2]);
    }

    #[test]
    fn async_pool_lockstep_matches_vec_env_bitwise() {
        let mut vec_env = VecEnv::new(4, 77, cartpole_factory());
        let mut pool = AsyncEnvPool::new(4, 77, 2, cartpole_factory());
        let (obs_a, tr_a) = drive(&mut vec_env, 100);
        let (obs_b, tr_b) = drive(&mut pool, 100);
        assert_eq!(tr_a, tr_b);
        assert_eq!(obs_a, obs_b);
    }

    #[test]
    fn async_native_api_initial_lanes_are_all_ready() {
        let n = 6;
        let mut pool = AsyncEnvPool::new(n, 5, 3, cartpole_factory());
        let mut seen = vec![false; n];
        let mut got = 0;
        while got < n {
            let batch = pool.recv_batch(n);
            for (j, &lane) in batch.lanes().iter().enumerate() {
                assert!(!seen[lane], "lane {lane} ready twice before any action");
                seen[lane] = true;
                assert_eq!(batch.obs(j).len(), 4);
                assert_eq!(batch.obs_unpadded(j).len(), 4);
                assert!(!batch.transitions()[j].done);
            }
            got += batch.len();
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn async_native_api_round_trips_actions() {
        let n = 4;
        let mut pool = AsyncEnvPool::new(n, 11, 2, cartpole_factory());
        let mut sends_per_lane = vec![0u32; n];
        // Keep every received lane busy: each ready state gets an action.
        for _ in 0..200 {
            let batch = pool.recv_batch(n);
            let sends: Vec<(usize, Action)> = batch
                .lanes()
                .iter()
                .map(|&lane| {
                    sends_per_lane[lane] += 1;
                    (lane, Action::Discrete(lane % 2))
                })
                .collect();
            pool.send_actions(&sends);
        }
        for (lane, &s) in sends_per_lane.iter().enumerate() {
            assert!(s > 10, "lane {lane} starved: {s} actions submitted");
        }
    }

    #[test]
    fn pools_shut_down_cleanly_on_drop() {
        let pool = EnvPool::new(3, 0, 2, cartpole_factory());
        drop(pool);
        let pool = AsyncEnvPool::new(3, 0, 2, cartpole_factory());
        drop(pool);
    }

    #[test]
    fn random_rollout_counts_lane_steps_and_stays_reusable() {
        let mut pool = EnvPool::new(4, 9, 2, cartpole_factory());
        let counts = pool.random_rollout(500);
        assert_eq!(counts.steps, 2_000);
        assert!(
            counts.episodes > 10,
            "40-step-capped cartpole over 500 steps/lane: {} episodes",
            counts.episodes
        );
        // The pool survives the bulk command and still serves batches.
        assert_eq!(pool.random_rollout(10).steps, 40);
        let mut obs = vec![0.0f32; 4 * 4];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        assert!(obs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn random_rollout_episode_counts_are_thread_invariant() {
        // Fresh pools with the same lane seeds must tally the same
        // episode ends regardless of worker partitioning.
        let counts: Vec<RolloutCounts> = [1usize, 2, 4]
            .iter()
            .map(|&threads| {
                let mut pool = EnvPool::new(4, 9, threads, cartpole_factory());
                pool.random_rollout(500)
            })
            .collect();
        assert!(counts[0].episodes > 10);
        assert_eq!(counts[0], counts[1]);
        assert_eq!(counts[0], counts[2]);
    }

    #[test]
    fn mixture_pools_pad_and_expose_lane_specs() {
        // CartPole (dim 4) + MountainCar (dim 2): padded width 4, the
        // MountainCar lanes zero their tails on every executor.
        let build_envs = || -> Vec<crate::core::env::DynEnv> {
            vec![
                Box::new(TimeLimit::new(CartPole::new(), 40)),
                Box::new(TimeLimit::new(MountainCar::new(), 40)),
                Box::new(TimeLimit::new(MountainCar::new(), 40)),
            ]
        };
        let mut vec_env = VecEnv::from_envs(build_envs(), 5);
        let mut sync_pool = EnvPool::from_envs(build_envs(), 5, 2);
        let mut async_pool = AsyncEnvPool::from_envs(build_envs(), 5, 2);
        for exec in [
            &mut vec_env as &mut dyn BatchedExecutor,
            &mut sync_pool,
            &mut async_pool,
        ] {
            assert_eq!(exec.obs_dim(), 4);
            let specs = exec.lane_specs().to_vec();
            assert_eq!(specs.len(), 3);
            assert_eq!(specs[0].obs_dim, 4);
            assert_eq!(specs[1].obs_dim, 2);
            assert_eq!(specs[1].offset, 4);
            assert_eq!(specs[2].offset, 8);
            // Pre-poison the buffer: the executor must zero the tails.
            let mut obs = vec![f32::NAN; 3 * 4];
            exec.reset_into(&mut obs);
            for spec in &specs[1..] {
                assert_eq!(
                    &obs[spec.offset + spec.obs_dim..spec.offset + 4],
                    &[0.0, 0.0],
                    "padded tail must be zeroed"
                );
            }
        }
        // The heterogeneous trajectories agree bit-for-bit across all
        // three executors (the mixture determinism contract).
        let a = drive(&mut vec_env, 90);
        let b = drive(&mut sync_pool, 90);
        let c = drive(&mut async_pool, 90);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn grouped_pools_match_scalar_pools_bitwise() {
        use crate::core::batch::DynBatchEnv;
        let groups = || {
            vec![LaneGroupSpec::new("CartPole-v1", 5, |lanes| -> DynBatchEnv {
                Box::new(crate::envs::CartPole::batch(lanes, Some(40)))
            })]
        };
        let mut scalar = EnvPool::new(5, 900, 2, cartpole_factory());
        let (obs_ref, tr_ref) = drive(&mut scalar, 150);
        // Fused sync pools at several thread counts (group split across
        // workers included), plus the async pool in lockstep.
        for threads in [1, 2, 3] {
            let mut fused = EnvPool::from_groups(groups(), 900, threads);
            let (obs, tr) = drive(&mut fused, 150);
            assert_eq!(tr_ref, tr, "{threads} threads");
            assert_eq!(obs_ref, obs, "{threads} threads");
        }
        let mut fused_async = AsyncEnvPool::from_groups(groups(), 900, 2);
        let (obs, tr) = drive(&mut fused_async, 150);
        assert_eq!(tr_ref, tr);
        assert_eq!(obs_ref, obs);
    }

    #[test]
    fn grouped_random_rollout_counts_match_scalar() {
        use crate::core::batch::DynBatchEnv;
        let mut scalar = EnvPool::new(4, 9, 2, cartpole_factory());
        let mut fused = EnvPool::from_groups(
            vec![LaneGroupSpec::new("CartPole-v1", 4, |lanes| -> DynBatchEnv {
                Box::new(crate::envs::CartPole::batch(lanes, Some(40)))
            })],
            9,
            2,
        );
        assert_eq!(scalar.random_rollout(500), fused.random_rollout(500));
    }

    /// Env that panics on the `boom`-th step — exercises worker-death
    /// handling.
    struct Grenade {
        fuse: u32,
        boom: u32,
    }

    impl Env for Grenade {
        fn id(&self) -> String {
            "Grenade-v0".into()
        }
        fn observation_space(&self) -> Space {
            Space::box1(vec![0.0], vec![1.0])
        }
        fn action_space(&self) -> Space {
            Space::Discrete { n: 2 }
        }
        fn seed(&mut self, _seed: u64) {}
        fn reset_into(&mut self, obs: &mut [f32]) {
            obs[0] = 0.0;
        }
        fn step_into(&mut self, _a: &Action, obs: &mut [f32]) -> Transition {
            self.fuse += 1;
            assert!(self.fuse < self.boom, "grenade went off");
            obs[0] = self.fuse as f32;
            Transition::live(0.0)
        }
    }

    #[test]
    #[should_panic(expected = "EnvPool worker panicked")]
    fn sync_pool_surfaces_env_panics_instead_of_hanging() {
        let mut pool = EnvPool::new(4, 0, 2, || Grenade { fuse: 0, boom: 3 });
        let mut obs = vec![0.0f32; 4];
        let mut tr = vec![Transition::default(); 4];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        for _ in 0..10 {
            let actions = vec![Action::Discrete(0); 4];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
        }
    }

    #[test]
    #[should_panic(expected = "AsyncEnvPool worker panicked")]
    fn async_pool_surfaces_env_panics_instead_of_hanging() {
        let mut pool = AsyncEnvPool::new(4, 0, 2, || Grenade { fuse: 0, boom: 3 });
        let mut obs = vec![0.0f32; 4];
        let mut tr = vec![Transition::default(); 4];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        for _ in 0..10 {
            let actions = vec![Action::Discrete(0); 4];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
        }
    }

    #[test]
    fn sync_pool_quarantines_only_the_panicking_lane() {
        use crate::core::env::DynEnv;
        let envs = || -> Vec<DynEnv> {
            vec![
                Box::new(Grenade { fuse: 0, boom: 3 }),
                Box::new(TimeLimit::new(CartPole::new(), 40)),
            ]
        };
        let mut pool = EnvPool::from_envs(envs(), 7, 2);
        pool.set_panic_policy(PanicPolicy::Quarantine);
        // Reference for the healthy lane: pool lane 1 is seeded 7 + 1.
        let mut reference = VecEnv::from_envs(
            vec![Box::new(TimeLimit::new(CartPole::new(), 40)) as DynEnv],
            8,
        );
        let d = pool.obs_dim();
        assert_eq!(d, 4);
        let mut obs = vec![0.0f32; 2 * d];
        let mut tr = vec![Transition::default(); 2];
        let mut ref_obs = vec![0.0f32; d];
        let mut ref_tr = vec![Transition::default(); 1];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        BatchedExecutor::reset_into(&mut reference, &mut ref_obs);
        assert_eq!(&obs[d..], &ref_obs[..]);
        for step in 0..12 {
            let actions = vec![Action::Discrete(step % 2); 2];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
            BatchedExecutor::step_into(&mut reference, &actions[1..], &mut ref_obs, &mut ref_tr);
            // The healthy lane's trajectory is untouched by the blast.
            assert_eq!(&obs[d..], &ref_obs[..], "step {step}");
            assert_eq!(tr[1], ref_tr[0], "step {step}");
            if step >= 2 {
                // The grenade went off on its third step: dead lane,
                // zeroed slot, terminal transition — forever.
                assert_eq!(tr[0], Transition::terminal(0.0), "step {step}");
                assert_eq!(&obs[..d], &[0.0; 4], "step {step}");
            }
        }
        // Quarantine survives a reset: the env's state after a panic
        // is unknown, so the lane never comes back.
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        assert_eq!(&obs[..d], &[0.0; 4]);
        let actions = vec![Action::Discrete(0); 2];
        BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
        assert_eq!(tr[0], Transition::terminal(0.0));
    }

    #[test]
    fn async_pool_quarantines_only_the_panicking_lane() {
        use crate::core::env::DynEnv;
        let envs = || -> Vec<DynEnv> {
            vec![
                Box::new(Grenade { fuse: 0, boom: 3 }),
                Box::new(TimeLimit::new(CartPole::new(), 40)),
            ]
        };
        let mut pool = AsyncEnvPool::from_envs(envs(), 7, 2);
        pool.set_panic_policy(PanicPolicy::Quarantine);
        let mut reference = VecEnv::from_envs(
            vec![Box::new(TimeLimit::new(CartPole::new(), 40)) as DynEnv],
            8,
        );
        let d = pool.obs_dim();
        let mut obs = vec![0.0f32; 2 * d];
        let mut tr = vec![Transition::default(); 2];
        let mut ref_obs = vec![0.0f32; d];
        let mut ref_tr = vec![Transition::default(); 1];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        BatchedExecutor::reset_into(&mut reference, &mut ref_obs);
        for step in 0..12 {
            let actions = vec![Action::Discrete(step % 2); 2];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
            BatchedExecutor::step_into(&mut reference, &actions[1..], &mut ref_obs, &mut ref_tr);
            assert_eq!(&obs[d..], &ref_obs[..], "step {step}");
            assert_eq!(tr[1], ref_tr[0], "step {step}");
            if step >= 2 {
                assert_eq!(tr[0], Transition::terminal(0.0), "step {step}");
                assert_eq!(&obs[..d], &[0.0; 4], "step {step}");
            }
        }
    }

    #[test]
    fn panic_policy_parses_and_renders() {
        assert_eq!(PanicPolicy::parse("poison"), Some(PanicPolicy::Poison));
        assert_eq!(
            PanicPolicy::parse(" quarantine "),
            Some(PanicPolicy::Quarantine)
        );
        assert_eq!(PanicPolicy::parse("explode"), None);
        for p in [PanicPolicy::Poison, PanicPolicy::Quarantine] {
            assert_eq!(PanicPolicy::parse(p.render()), Some(p));
        }
        assert_eq!(PanicPolicy::default(), PanicPolicy::Poison);
    }

    #[test]
    fn pool_works_over_dyn_envs() {
        let mut pool = EnvPool::new(3, 1, 2, || {
            crate::coordinator::registry::make("CartPole-v1").unwrap()
        });
        let mut obs = vec![0.0f32; 3 * 4];
        let mut tr = vec![Transition::default(); 3];
        BatchedExecutor::reset_into(&mut pool, &mut obs);
        for _ in 0..10 {
            let actions = vec![Action::Discrete(0); 3];
            BatchedExecutor::step_into(&mut pool, &actions, &mut obs, &mut tr);
        }
        assert!(obs.iter().all(|v| v.is_finite()));
    }
}
