//! Experiment coordination — registry, config system, vectorised
//! execution and trial orchestration.
//!
//! This is the toolkit's L3 "coordinator" in the three-layer architecture:
//! it owns env construction ([`registry`]), the experiment configuration
//! surface ([`config`], Table I defaults), batched environment execution
//! ([`vec_env`]) and multi-trial experiment runs with stopping criteria
//! ([`experiment`]) — the machinery behind every figure and table
//! reproduction.

pub mod config;
pub mod experiment;
pub mod registry;
pub mod vec_env;
