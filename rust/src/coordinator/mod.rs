//! Experiment coordination — registry, config system, batched executors
//! and trial orchestration.
//!
//! This is the toolkit's L3 "coordinator" in the three-layer architecture:
//! it owns env construction ([`registry`] — a runtime [`registry::EnvSpec`]
//! table with parameterized `make` and declarative wrapper chains), the
//! experiment configuration
//! surface ([`config`], Table I defaults), batched environment execution
//! — the sequential [`vec_env`] reference and the persistent-worker
//! [`pool`] executors behind one [`pool::BatchedExecutor`] interface —
//! and multi-trial experiment runs with stopping criteria
//! ([`experiment`]): the machinery behind every figure and table
//! reproduction.

pub mod config;
pub mod experiment;
pub mod pool;
pub mod registry;
pub mod vec_env;

pub use pool::{AsyncEnvPool, BatchedExecutor, EnvPool, LaneSpec};
pub use registry::{EnvSpec, MixtureEntry, MixtureSpec};
pub use vec_env::VecEnv;
