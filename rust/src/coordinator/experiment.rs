//! Multi-trial experiment orchestration — the paper's measurement
//! protocol ("averaged over 100 consecutive trials", fixed seeds per
//! trial) as a reusable harness.
//!
//! The benchmark binaries build on these runners so every figure uses
//! identical timing methodology.

use std::time::{Duration, Instant};

use crate::coordinator::pool::{
    AsyncEnvPool, BatchedExecutor, EnvPool, LaneGroupSpec, LaneSpec, RandomRollout,
};
use crate::coordinator::registry::{self, MixtureEntry, MixtureSpec};
use crate::coordinator::vec_env::VecEnv;
use crate::core::batch::{DynBatchEnv, ScalarBatch};
use crate::core::env::{DynEnv, Env, Transition};
use crate::core::error::Result;
use crate::core::rng::Pcg32;
use crate::core::spaces::Action;
use crate::render::{Framebuffer, HardwareSim};
use crate::telemetry::TapeWriter;
use crate::tooling::stats::Summary;
use crate::wrappers::{apply_wrappers, WrapperSpec};

/// Which rendering path a stepping workload exercises (Fig. 1's rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RenderMode {
    /// No rendering (the "console" rows).
    Console,
    /// Software rendering into a reusable framebuffer (CaiRL's path).
    Software,
    /// Software raster + simulated GPU readback cost (the Gym path).
    SimulatedHardware,
}

/// Timing result of one stepping workload.
#[derive(Clone, Debug)]
pub struct SteppingResult {
    pub steps: u64,
    pub episodes: u64,
    pub elapsed: Duration,
    /// Steps per second.
    pub throughput: f64,
    /// Undiscounted return of every episode that finished, in
    /// deterministic completion order (step-major, lane-minor for
    /// batched workloads) — the seed-parity log `cairl run
    /// --returns-log` writes and the CI shard-smoke job diffs against
    /// the local executor.  Empty for free-running rollouts, which
    /// tally counts worker-side without reporting per-episode returns.
    pub episode_returns: Vec<f32>,
}

/// Run `steps` random-action steps on `env` (auto-reset), optionally
/// rendering every step — the Fig.-1 workload.
pub fn run_stepping_workload(
    env: &mut DynEnv,
    steps: u64,
    seed: u64,
    mode: RenderMode,
) -> SteppingResult {
    let mut rng = Pcg32::new(seed, 17);
    let space = env.action_space();
    let mut obs = vec![0.0f32; env.obs_dim()];
    let mut fb = Framebuffer::standard();
    let mut hw = HardwareSim::default();
    env.seed(seed);
    env.reset_into(&mut obs);
    let mut episodes = 0u64;
    let mut episode_returns = Vec::new();
    let mut ret = 0.0f32;
    let start = Instant::now();
    for _ in 0..steps {
        let a = space.sample(&mut rng);
        let t = env.step_into(&a, &mut obs);
        match mode {
            RenderMode::Console => {}
            RenderMode::Software => env.render(&mut fb),
            RenderMode::SimulatedHardware => {
                env.render(&mut fb);
                hw.readback(&fb);
            }
        }
        ret += t.reward;
        if t.done || t.truncated {
            episodes += 1;
            episode_returns.push(ret);
            ret = 0.0;
            env.reset_into(&mut obs);
        }
    }
    let elapsed = start.elapsed();
    SteppingResult {
        steps,
        episodes,
        elapsed,
        throughput: steps as f64 / elapsed.as_secs_f64(),
        episode_returns,
    }
}

/// Repeat a stepping workload over `trials` trials (trial `i` seeded
/// `base_seed + i`), returning per-trial elapsed seconds.
pub fn stepping_trials(
    make_env: &dyn Fn() -> DynEnv,
    trials: u32,
    steps_per_trial: u64,
    base_seed: u64,
    mode: RenderMode,
) -> Vec<f64> {
    (0..trials)
        .map(|i| {
            let mut env = make_env();
            run_stepping_workload(&mut env, steps_per_trial, base_seed + i as u64, mode)
                .elapsed
                .as_secs_f64()
        })
        .collect()
}

/// Which [`BatchedExecutor`] a batched workload runs on.  Selected by
/// configuration ([`crate::coordinator::config::ExecutorSettings`]) or
/// CLI flags so every workload can flip executors without code changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Sequential [`VecEnv`] — the bit-exact reference.
    Sequential,
    /// [`EnvPool`] sync mode: persistent workers, barrier per batch,
    /// trajectories identical to [`ExecutorKind::Sequential`].
    PoolSync,
    /// [`AsyncEnvPool`] driven in lockstep: persistent workers, ready
    /// queue, no barrier inside the pool.
    PoolAsync,
}

impl ExecutorKind {
    /// Parse a config/CLI name.
    pub fn parse(name: &str) -> Option<ExecutorKind> {
        match name {
            "vec" | "sequential" => Some(ExecutorKind::Sequential),
            "pool" | "pool-sync" => Some(ExecutorKind::PoolSync),
            "pool-async" | "async" => Some(ExecutorKind::PoolAsync),
            _ => None,
        }
    }

    /// Stable display name (also the accepted config spelling).
    pub fn label(&self) -> &'static str {
        match self {
            ExecutorKind::Sequential => "vec",
            ExecutorKind::PoolSync => "pool",
            ExecutorKind::PoolAsync => "pool-async",
        }
    }
}

/// Which stepping kernel a batched workload runs — the `cairl run
/// --kernel` A/B switch.
///
/// Both modes are **bit-identical** (`rust/tests/batch_kernel.rs` pins
/// it); they differ only in how homogeneous lane runs are stepped.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelMode {
    /// Every lane steps through its own `Box<dyn Env>` — the pre-fusion
    /// per-lane dispatch path, kept for A/B benchmarking.
    Scalar,
    /// Homogeneous lane groups with a registered batch builder step
    /// through one SoA `step_batch` call per group
    /// ([`crate::core::batch`]); everything else falls back to scalar
    /// lanes.  The default.
    #[default]
    Fused,
}

impl KernelMode {
    /// Parse a config/CLI name.
    pub fn parse(name: &str) -> Option<KernelMode> {
        match name {
            "scalar" => Some(KernelMode::Scalar),
            "fused" => Some(KernelMode::Fused),
            _ => None,
        }
    }

    /// Stable display name (also the accepted config spelling).
    pub fn label(&self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Fused => "fused",
        }
    }
}

/// Build a batched executor from an env spec.  `env_spec` is either a
/// bare registry id (`"CartPole-v1"` — `lanes` homogeneous copies,
/// optionally parameterized: `"CartPole-v1?max_steps=200"`) or a
/// scenario-mixture spec (`"CartPole-v1:32,Acrobot-v1:16"` — per-lane
/// env ids in spec order; `lanes` is ignored because the spec carries
/// its own counts).  Lane `i` is seeded `base_seed + i` on every
/// executor kind, which is what makes the kinds interchangeable
/// mid-experiment and mixture pools bit-identical to their single-env
/// references.  Runs the default fused kernel mode.
pub fn build_executor(
    env_spec: &str,
    kind: ExecutorKind,
    lanes: usize,
    threads: usize,
    base_seed: u64,
) -> Result<Box<dyn BatchedExecutor>> {
    build_executor_wrapped(env_spec, kind, lanes, threads, base_seed, &[])
}

/// [`build_executor`] with a declarative wrapper chain applied to every
/// lane (outside any wrappers the registry spec itself declares) — the
/// machinery behind `cairl run --wrap` and the config `"wrappers"`
/// block.  The empty chain is exactly [`build_executor`].
pub fn build_executor_wrapped(
    env_spec: &str,
    kind: ExecutorKind,
    lanes: usize,
    threads: usize,
    base_seed: u64,
    wrappers: &[WrapperSpec],
) -> Result<Box<dyn BatchedExecutor>> {
    build_executor_with_kernel(
        env_spec,
        kind,
        lanes,
        threads,
        base_seed,
        wrappers,
        KernelMode::default(),
    )
}

/// The full executor build surface: env spec (bare id or mixture, with
/// optional per-component `+`-joined wrapper chains), executor kind,
/// extra wrapper chain and kernel mode.
///
/// Lanes are planned as contiguous **groups** keyed by (env id, kwargs,
/// wrapper chain): under [`KernelMode::Fused`] each group whose
/// registry spec advertises a batch builder (and whose full effective
/// chain — component `+`-chain plus the extra `--wrap` chain — the
/// kernel can absorb) becomes one fused SoA batch, everything else a
/// [`ScalarBatch`] over per-lane envs.  A chain that breaks fusion
/// falls back to scalar lanes; it never errors.  [`KernelMode::Scalar`]
/// forces the fallback everywhere; trajectories are identical either
/// way.
pub fn build_executor_with_kernel(
    env_spec: &str,
    kind: ExecutorKind,
    lanes: usize,
    threads: usize,
    base_seed: u64,
    wrappers: &[WrapperSpec],
    kernel: KernelMode,
) -> Result<Box<dyn BatchedExecutor>> {
    for wrapper in wrappers {
        wrapper.validate()?;
    }
    let entries: Vec<MixtureEntry> = if MixtureSpec::is_mixture(env_spec) {
        // Parsing validates every component id + kwargs + chain eagerly.
        MixtureSpec::parse(env_spec)?.entries().to_vec()
    } else {
        registry::validate(env_spec)?;
        vec![MixtureEntry::bare(env_spec, lanes.max(1))]
    };
    let groups = lane_groups_for(&entries, wrappers, kernel)?;
    Ok(match kind {
        ExecutorKind::Sequential => Box::new(VecEnv::from_groups(groups, base_seed)),
        ExecutorKind::PoolSync => {
            Box::new(EnvPool::from_groups(groups, base_seed, threads))
        }
        ExecutorKind::PoolAsync => {
            Box::new(AsyncEnvPool::from_groups(groups, base_seed, threads))
        }
    })
}

/// Plan the contiguous lane groups of an executor build: adjacent
/// entries with the same id *and* the same per-component chain merge
/// into one group, each group resolves its fused builder (or a scalar
/// fallback closure) once, and the executors invoke the builder per
/// worker sub-range.
fn lane_groups_for(
    entries: &[MixtureEntry],
    wrappers: &[WrapperSpec],
    kernel: KernelMode,
) -> Result<Vec<LaneGroupSpec>> {
    let mut merged: Vec<MixtureEntry> = Vec::new();
    for entry in entries {
        match merged.last_mut() {
            Some(last) if last.spec == entry.spec && last.wrappers == entry.wrappers => {
                last.count += entry.count
            }
            _ => merged.push(entry.clone()),
        }
    }
    let mut groups = Vec::with_capacity(merged.len());
    for entry in merged {
        // The effective extra chain per lane: the component's own
        // `+`-chain first (innermost), then the pool-level `--wrap`
        // chain — both *outside* the registered spec's declared stack.
        // The batch hook sees the full effective chain and absorbs what
        // it can (a trailing NormalizeObs/RewardScale folds into the
        // kernel's affine epilogue); anything longer forces the scalar
        // fallback.
        let mut chain = entry.wrappers.clone();
        chain.extend_from_slice(wrappers);
        // Lane labels carry the component as written in the mixture
        // grammar (id + kwargs + `+`-chain); the pool-level chain stays
        // out of the label, as before.
        let label = entry.label();
        let fused = if kernel == KernelMode::Fused {
            registry::fused_lane_builder_with(&entry.spec, &chain)?
        } else {
            None
        };
        let group = match fused {
            Some(build) => {
                LaneGroupSpec::new(&label, entry.count, move |lanes| (*build)(lanes))
            }
            None => {
                // Probe one construction up front so *builder* errors
                // surface as Err (static kwarg/wrapper errors were
                // caught by validation, but an EnvBuilder may fail for
                // reasons of its own); the executor-side factory can
                // then never fail.
                let _ = registry::make(&entry.spec)?;
                let spec = entry.spec.clone();
                LaneGroupSpec::new(&label, entry.count, move |lanes| -> DynBatchEnv {
                    let envs: Vec<DynEnv> = (0..lanes)
                        .map(|_| {
                            apply_wrappers(
                                registry::make(&spec).expect("env spec validated above"),
                                &chain,
                            )
                        })
                        .collect();
                    Box::new(ScalarBatch::from_envs(envs))
                })
            }
        };
        groups.push(group);
    }
    Ok(groups)
}

/// Build a **sync** [`EnvPool`] directly (not boxed) for one shard of a
/// larger lane space: lanes seed `global_base + first_lane + local`,
/// and the free-running rollout draws lane action streams from the
/// *global* lane ids, so both lockstep trajectories and
/// [`EnvPool::random_rollout`] counts are bit-identical to the
/// equivalent local pool.  `first_lane = 0` is exactly the local build
/// — the `cairl serve` daemon calls this per connection.  `wrappers`
/// is the pool-level chain (`cairl serve --wrap` / the `Hello.wrap`
/// field), applied to every lane outside the registered spec;
/// absorbable chains still fuse, everything else falls back to scalar
/// lanes.
pub fn build_env_pool_shard(
    env_spec: &str,
    lanes: usize,
    threads: usize,
    global_base: u64,
    first_lane: usize,
    kernel: KernelMode,
    wrappers: &[WrapperSpec],
) -> Result<EnvPool> {
    for wrapper in wrappers {
        wrapper.validate()?;
    }
    let entries: Vec<MixtureEntry> = if MixtureSpec::is_mixture(env_spec) {
        MixtureSpec::parse(env_spec)?.entries().to_vec()
    } else {
        registry::validate(env_spec)?;
        vec![MixtureEntry::bare(env_spec, lanes.max(1))]
    };
    let groups = lane_groups_for(&entries, wrappers, kernel)?;
    Ok(EnvPool::from_groups_with_origin(
        groups,
        global_base + first_lane as u64,
        threads,
        (global_base, first_lane),
    ))
}

/// Build a heterogeneous executor over a parsed [`MixtureSpec`]: lane
/// `i` runs the `i`-th env of the flattened spec, seeded `base_seed + i`.
pub fn build_mixture_executor(
    spec: &MixtureSpec,
    kind: ExecutorKind,
    threads: usize,
    base_seed: u64,
) -> Result<Box<dyn BatchedExecutor>> {
    build_mixture_executor_wrapped(spec, kind, threads, base_seed, &[])
}

/// [`build_mixture_executor`] with a wrapper chain applied to every
/// lane; lane labels keep the component labels (id + kwargs +
/// per-component `+`-chain) — the pool-level chain stays out of the
/// labels.  Components whose
/// spec advertises a batch builder fuse per group, exactly as in
/// [`build_executor_with_kernel`] — this convenience API always runs
/// the default fused mode; pass the rendered spec string to
/// [`build_executor_with_kernel`] when the caller needs explicit
/// `--kernel` control (the CLI/config path does).
pub fn build_mixture_executor_wrapped(
    spec: &MixtureSpec,
    kind: ExecutorKind,
    threads: usize,
    base_seed: u64,
    wrappers: &[WrapperSpec],
) -> Result<Box<dyn BatchedExecutor>> {
    for wrapper in wrappers {
        wrapper.validate()?;
    }
    let groups = lane_groups_for(spec.entries(), wrappers, KernelMode::default())?;
    Ok(match kind {
        ExecutorKind::Sequential => Box::new(VecEnv::from_groups(groups, base_seed)),
        ExecutorKind::PoolSync => {
            Box::new(EnvPool::from_groups(groups, base_seed, threads))
        }
        ExecutorKind::PoolAsync => {
            Box::new(AsyncEnvPool::from_groups(groups, base_seed, threads))
        }
    })
}

/// Run `steps_per_lane` random-action batch steps on any executor
/// (auto-reset) — the batched counterpart of [`run_stepping_workload`],
/// and the workload behind the executor comparison in
/// `benches/fig1_console.rs`.  `steps` in the result counts lane-steps
/// (`steps_per_lane * num_lanes`).
///
/// Actions are sampled obs-independently, one batch ahead of the step
/// that consumes them; the pipelined driver
/// ([`ShardedEnvPool::run_pipelined_workload`]
/// (crate::shard::ShardedEnvPool::run_pipelined_workload)) draws the
/// identical RNG stream at submit time, so its `episode_returns` log is
/// byte-identical to this lockstep loop at any pipeline depth.
pub fn run_batched_workload(
    exec: &mut dyn BatchedExecutor,
    steps_per_lane: u64,
    seed: u64,
) -> SteppingResult {
    // Recording is off, so the tape writer can't fail.
    run_recorded_workload(exec, steps_per_lane, seed, None)
        .expect("workload without a tape is infallible")
}

/// [`run_batched_workload`] with an optional trajectory tape: every
/// batch's actions and transitions stream onto `tape` as they happen
/// (the `cairl run --record FILE` path).  The caller seals the tape
/// with [`TapeWriter::finish`] afterwards.  The action stream, stepping
/// order and `SteppingResult` are identical with and without a tape —
/// recording observes the workload, never perturbs it.
pub fn run_recorded_workload(
    exec: &mut dyn BatchedExecutor,
    steps_per_lane: u64,
    seed: u64,
    mut tape: Option<&mut TapeWriter>,
) -> Result<SteppingResult> {
    let n = exec.num_lanes();
    let d = exec.obs_dim();
    // Sample per lane from its own action space (spec order), so
    // mixtures draw valid actions everywhere; homogeneous pools draw
    // the exact stream the shared-space sampler produced.
    let specs: Vec<LaneSpec> = exec.lane_specs().to_vec();
    let mut rng = Pcg32::new(seed, 23);
    let mut obs = vec![0.0f32; n * d];
    let mut transitions = vec![Transition::default(); n];
    let mut actions: Vec<Action> = Vec::with_capacity(n);
    exec.reset_into(&mut obs);
    let mut episodes = 0u64;
    let mut episode_returns = Vec::new();
    let mut lane_return = vec![0.0f32; n];
    let start = Instant::now();
    for _ in 0..steps_per_lane {
        actions.clear();
        actions.extend(specs.iter().map(|s| s.action_space.sample(&mut rng)));
        exec.step_into(&actions, &mut obs, &mut transitions);
        if let Some(w) = tape.as_deref_mut() {
            w.write_batch(&actions, &transitions)?;
        }
        // Lane order inside a step is fixed, so the completion log is
        // deterministic for a given seed — identical on every executor
        // kind, kernel mode and shard layout.
        for (acc, t) in lane_return.iter_mut().zip(&transitions) {
            *acc += t.reward;
            if t.done || t.truncated {
                episodes += 1;
                episode_returns.push(*acc);
                *acc = 0.0;
            }
        }
    }
    let elapsed = start.elapsed();
    let steps = steps_per_lane * n as u64;
    Ok(SteppingResult {
        steps,
        episodes,
        elapsed,
        throughput: steps as f64 / elapsed.as_secs_f64(),
        episode_returns,
    })
}

/// Free-running random-action workload on any [`RandomRollout`]
/// executor: the whole rollout runs without per-step coordination —
/// worker-side behind **one** barrier on the sync [`EnvPool`], and
/// behind **one frame per shard** on a
/// [`ShardedEnvPool`](crate::shard::ShardedEnvPool) — with the
/// aggregate step *and* episode counts folded into the standard
/// [`SteppingResult`] reporting.  Counts are identical across thread
/// counts and shard layouts (global per-lane action streams).
pub fn run_random_workload(pool: &mut dyn RandomRollout, steps_per_lane: u64) -> SteppingResult {
    let start = Instant::now();
    let counts = pool.random_rollout(steps_per_lane);
    let elapsed = start.elapsed();
    SteppingResult {
        steps: counts.steps,
        episodes: counts.episodes,
        elapsed,
        throughput: counts.steps as f64 / elapsed.as_secs_f64(),
        episode_returns: Vec::new(),
    }
}

/// A named comparison row (CaiRL vs baseline) with the paper's ratio.
#[derive(Clone, Debug)]
pub struct ComparisonRow {
    pub label: String,
    pub cairl: Summary,
    pub baseline: Summary,
    pub speedup: f64,
}

impl ComparisonRow {
    pub fn new(label: &str, cairl: &[f64], baseline: &[f64]) -> ComparisonRow {
        let c = Summary::of(cairl);
        let b = Summary::of(baseline);
        ComparisonRow {
            label: label.to_string(),
            speedup: b.mean / c.mean,
            cairl: c,
            baseline: b,
        }
    }

    /// Fig.-1-style line.
    pub fn render(&self) -> String {
        format!(
            "{:<28} cairl {:>10.4}s  baseline {:>10.4}s  speedup {:>8.1}x",
            self.label, self.cairl.mean, self.baseline.mean, self.speedup
        )
    }
}

/// Generic timed trial runner: calls `trial(i)` for each trial and
/// summarises wall-clock seconds.
pub fn timed_trials(trials: u32, mut trial: impl FnMut(u32)) -> Summary {
    let times: Vec<f64> = (0..trials)
        .map(|i| {
            let t0 = Instant::now();
            trial(i);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Summary::of(&times)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::make;

    #[test]
    fn stepping_workload_counts_steps_and_episodes() {
        let mut env = make("CartPole-v1").unwrap();
        let r = run_stepping_workload(&mut env, 2_000, 0, RenderMode::Console);
        assert_eq!(r.steps, 2_000);
        assert!(r.episodes > 10, "random cartpole ends every ~20-40 steps");
        assert!(r.throughput > 1000.0);
    }

    #[test]
    fn software_render_mode_runs() {
        let mut env = make("CartPole-v1").unwrap();
        let r = run_stepping_workload(&mut env, 200, 0, RenderMode::Software);
        assert_eq!(r.steps, 200);
    }

    #[test]
    fn trials_are_seed_varied_but_comparable() {
        let make_env = || make("CartPole-v1").unwrap();
        let times = stepping_trials(&make_env, 3, 1_000, 0, RenderMode::Console);
        assert_eq!(times.len(), 3);
        assert!(times.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn comparison_row_computes_speedup() {
        let row = ComparisonRow::new("test", &[1.0, 1.0], &[5.0, 5.0]);
        assert!((row.speedup - 5.0).abs() < 1e-12);
        assert!(row.render().contains("5.0x"));
    }

    #[test]
    fn timed_trials_runs_each_once() {
        let mut count = 0;
        let s = timed_trials(4, |_| count += 1);
        assert_eq!(count, 4);
        assert_eq!(s.n, 4);
    }

    #[test]
    fn executor_kind_parses_config_names() {
        assert_eq!(ExecutorKind::parse("vec"), Some(ExecutorKind::Sequential));
        assert_eq!(ExecutorKind::parse("pool"), Some(ExecutorKind::PoolSync));
        assert_eq!(
            ExecutorKind::parse("pool-async"),
            Some(ExecutorKind::PoolAsync)
        );
        assert_eq!(ExecutorKind::parse("nope"), None);
        for kind in [
            ExecutorKind::Sequential,
            ExecutorKind::PoolSync,
            ExecutorKind::PoolAsync,
        ] {
            assert_eq!(ExecutorKind::parse(kind.label()), Some(kind));
        }
    }

    #[test]
    fn build_executor_rejects_unknown_env() {
        assert!(build_executor("NoSuchEnv-v0", ExecutorKind::PoolSync, 2, 2, 0).is_err());
        assert!(build_executor("NoSuchEnv-v0:4", ExecutorKind::PoolSync, 2, 2, 0).is_err());
    }

    #[test]
    fn build_executor_accepts_mixture_specs() {
        for kind in [
            ExecutorKind::Sequential,
            ExecutorKind::PoolSync,
            ExecutorKind::PoolAsync,
        ] {
            let exec =
                build_executor("CartPole-v1:3,MountainCar-v0:2", kind, 1, 2, 0).unwrap();
            assert_eq!(exec.num_lanes(), 5, "{kind:?}");
            // Padded to CartPole's width; MountainCar lanes are narrower.
            assert_eq!(exec.obs_dim(), 4, "{kind:?}");
            let specs = exec.lane_specs();
            assert_eq!(specs[0].env_id, "CartPole-v1");
            assert_eq!(specs[4].env_id, "MountainCar-v0");
            assert_eq!(specs[4].obs_dim, 2);
            assert_eq!(specs[4].offset, 16);
        }
    }

    #[test]
    fn batched_workload_runs_mixtures_on_every_executor_kind() {
        // Per-lane action sampling must respect each lane's space, and
        // the aggregate counts must be executor-invariant.
        let run = |kind: ExecutorKind| {
            let mut exec =
                build_executor("CartPole-v1:3,Acrobot-v1:2", kind, 1, 2, 11).unwrap();
            let r = run_batched_workload(exec.as_mut(), 60, 5);
            (r.steps, r.episodes)
        };
        let seq = run(ExecutorKind::Sequential);
        assert_eq!(seq.0, 5 * 60);
        assert_eq!(seq, run(ExecutorKind::PoolSync));
        assert_eq!(seq, run(ExecutorKind::PoolAsync));
    }

    #[test]
    fn build_executor_accepts_parameterized_specs_and_wrap_chains() {
        use crate::wrappers::WrapperSpec;
        // "?max_steps=5" and an explicit --wrap TimeLimit(5) chain must
        // produce the same workload counts: the 5-step cap dominates
        // either way and the action streams are identical.
        let kind = ExecutorKind::Sequential;
        let mut short = build_executor("CartPole-v1?max_steps=5", kind, 2, 1, 0).unwrap();
        let r = run_batched_workload(short.as_mut(), 50, 3);
        assert!(r.episodes >= 10, "5-step cap must end many episodes");

        let chain = [WrapperSpec::TimeLimit { max_steps: 5 }];
        let mut wrapped = build_executor_wrapped("CartPole-v1", kind, 2, 1, 0, &chain).unwrap();
        let rw = run_batched_workload(wrapped.as_mut(), 50, 3);
        assert_eq!((r.steps, r.episodes), (rw.steps, rw.episodes));

        // Invalid chains and kwargs fail fast, on every path.
        let bad = [WrapperSpec::TimeLimit { max_steps: 0 }];
        assert!(build_executor_wrapped("CartPole-v1", kind, 2, 1, 0, &bad).is_err());
        assert!(build_executor("CartPole-v1?nope=1", kind, 2, 1, 0).is_err());
    }

    #[test]
    fn mixture_components_with_chains_build_and_run() {
        // A fusable per-component chain (trailing NormalizeObs folds
        // into the kernel epilogue) next to bare lanes of the same env:
        // two distinct groups, labels carry the chain.
        let mut exec = build_executor(
            "CartPole-v1+NormalizeObs:2,CartPole-v1:2",
            ExecutorKind::Sequential,
            1,
            1,
            0,
        )
        .unwrap();
        assert_eq!(exec.num_lanes(), 4);
        let specs = exec.lane_specs();
        assert_eq!(specs[0].env_id, "CartPole-v1+NormalizeObs");
        assert_eq!(specs[2].env_id, "CartPole-v1");
        let r = run_batched_workload(exec.as_mut(), 30, 5);
        assert_eq!(r.steps, 4 * 30);

        // A chain the kernel cannot absorb falls back to ScalarBatch —
        // it builds and runs, it never errors.
        let mut stacked = build_executor(
            "CartPole-v1+FrameStack(2):2",
            ExecutorKind::PoolSync,
            1,
            2,
            0,
        )
        .unwrap();
        assert_eq!(stacked.num_lanes(), 2);
        assert_eq!(stacked.obs_dim(), 8, "FrameStack(2) doubles the window");
        assert_eq!(stacked.lane_specs()[0].env_id, "CartPole-v1+FrameStack(2)");
        let r = run_batched_workload(stacked.as_mut(), 20, 3);
        assert_eq!(r.steps, 2 * 20);
    }

    #[test]
    fn kernel_modes_parse_and_agree_on_workload_counts() {
        assert_eq!(KernelMode::parse("scalar"), Some(KernelMode::Scalar));
        assert_eq!(KernelMode::parse("fused"), Some(KernelMode::Fused));
        assert_eq!(KernelMode::parse("nope"), None);
        assert_eq!(KernelMode::default(), KernelMode::Fused);
        for kernel in [KernelMode::Scalar, KernelMode::Fused] {
            assert_eq!(KernelMode::parse(kernel.label()), Some(kernel));
        }
        // Same seeds, same action streams: both kernels count the same
        // steps and episode ends (full bit-equality is pinned by
        // rust/tests/batch_kernel.rs).
        let run = |kernel: KernelMode| {
            let mut exec = build_executor_with_kernel(
                "CartPole-v1",
                ExecutorKind::PoolSync,
                6,
                2,
                40,
                &[],
                kernel,
            )
            .unwrap();
            let r = run_batched_workload(exec.as_mut(), 80, 7);
            (r.steps, r.episodes)
        };
        let scalar = run(KernelMode::Scalar);
        assert!(scalar.1 > 0);
        assert_eq!(scalar, run(KernelMode::Fused));
    }

    #[test]
    fn random_workload_reports_steps_and_episodes() {
        use crate::envs::CartPole;
        use crate::wrappers::TimeLimit;
        let mut pool = EnvPool::new(4, 7, 4, || TimeLimit::new(CartPole::new(), 200));
        let r = run_random_workload(&mut pool, 10_000);
        assert_eq!(r.steps, 40_000);
        assert!(r.episodes > 100, "random cartpole ends every ~20-40 steps");
        assert!(r.throughput > 0.0);
        // Thread-count invariance of the folded counts.
        let mut single = EnvPool::new(4, 7, 1, || TimeLimit::new(CartPole::new(), 200));
        let r1 = run_random_workload(&mut single, 10_000);
        assert_eq!((r.steps, r.episodes), (r1.steps, r1.episodes));
    }

    #[test]
    fn batched_workload_agrees_across_executor_kinds() {
        // Same seed, same action stream: every executor kind must count
        // the same number of steps *and* episode ends — the workload-level
        // face of the bit-determinism invariant.
        let run = |kind: ExecutorKind| {
            let mut exec = build_executor("CartPole-v1", kind, 6, 3, 40).unwrap();
            let r = run_batched_workload(exec.as_mut(), 80, 7);
            (r.steps, r.episodes)
        };
        let seq = run(ExecutorKind::Sequential);
        assert_eq!(seq.0, 6 * 80);
        assert!(seq.1 > 0, "random cartpole must finish episodes");
        assert_eq!(seq, run(ExecutorKind::PoolSync));
        assert_eq!(seq, run(ExecutorKind::PoolAsync));
    }
}
