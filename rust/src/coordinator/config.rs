//! The experiment configuration surface — JSON files mapped onto the
//! toolkit's knobs, with Table-I defaults.
//!
//! `cairl run --config exp.json` and the benchmark binaries consume
//! this; `cairl config --show-dqn` prints the Table-I defaults.  (JSON
//! rather than TOML: the offline build carries its own JSON reader,
//! `core/json.rs`, and one interchange format is enough.)

use std::path::Path;

use crate::agents::dqn::DqnConfig;
use crate::coordinator::experiment::{ExecutorKind, KernelMode};
use crate::core::error::{CairlError, Result};
use crate::core::json::{self, Value};
use crate::faults::ChaosProfile;

/// DQN block — Table I plus the loop knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct DqnSettings {
    pub epsilon_start: f32,
    pub epsilon_final: f32,
    pub epsilon_decay_steps: u32,
    pub target_update_freq: u32,
    pub memory_size: usize,
    pub learn_start: usize,
    pub train_every: u32,
    pub max_steps: u32,
    pub solve_return: f32,
    pub solve_window: usize,
}

impl Default for DqnSettings {
    fn default() -> Self {
        let d = DqnConfig::default();
        DqnSettings {
            epsilon_start: d.epsilon_start,
            epsilon_final: d.epsilon_final,
            epsilon_decay_steps: d.epsilon_decay_steps,
            target_update_freq: d.target_update_freq,
            memory_size: d.memory_size,
            learn_start: d.learn_start,
            train_every: d.train_every,
            max_steps: d.max_steps,
            solve_return: d.solve_return,
            solve_window: d.solve_window,
        }
    }
}

impl DqnSettings {
    /// Materialise a [`DqnConfig`] with a seed.
    pub fn to_config(&self, seed: u64) -> DqnConfig {
        DqnConfig {
            epsilon_start: self.epsilon_start,
            epsilon_final: self.epsilon_final,
            epsilon_decay_steps: self.epsilon_decay_steps,
            target_update_freq: self.target_update_freq,
            memory_size: self.memory_size,
            learn_start: self.learn_start,
            train_every: self.train_every,
            max_steps: self.max_steps,
            solve_return: self.solve_return,
            solve_window: self.solve_window,
            seed,
            native_act: true,
        }
    }

    /// Overlay fields present in a JSON object.
    fn apply(&mut self, v: &Value) {
        let f = |key: &str| v.get(key).and_then(Value::as_f64);
        if let Some(x) = f("epsilon_start") {
            self.epsilon_start = x as f32;
        }
        if let Some(x) = f("epsilon_final") {
            self.epsilon_final = x as f32;
        }
        if let Some(x) = f("epsilon_decay_steps") {
            self.epsilon_decay_steps = x as u32;
        }
        if let Some(x) = f("target_update_freq") {
            self.target_update_freq = x as u32;
        }
        if let Some(x) = f("memory_size") {
            self.memory_size = x as usize;
        }
        if let Some(x) = f("learn_start") {
            self.learn_start = x as usize;
        }
        if let Some(x) = f("train_every") {
            self.train_every = x as u32;
        }
        if let Some(x) = f("max_steps") {
            self.max_steps = x as u32;
        }
        if let Some(x) = f("solve_return") {
            self.solve_return = x as f32;
        }
        if let Some(x) = f("solve_window") {
            self.solve_window = x as usize;
        }
    }

    /// Table-I rendering (hyperparameter, value).
    pub fn table_one(&self) -> Vec<(&'static str, String)> {
        vec![
            ("Discount", "0.99".into()),
            ("Units", "32, 32".into()),
            ("Activation", "elu".into()),
            ("Optimizer", "Adam".into()),
            ("Loss Function", "Huber".into()),
            ("Batch Size", "32".into()),
            ("Learning Rate", "3e-4".into()),
            ("Target Update Freq", self.target_update_freq.to_string()),
            ("Memory Size", self.memory_size.to_string()),
            ("Exploration Start", format!("{}", self.epsilon_start)),
            ("Exploration Final", format!("{}", self.epsilon_final)),
        ]
    }
}

/// Executor block — which [`BatchedExecutor`]
/// (crate::coordinator::pool::BatchedExecutor) runs batched workloads,
/// and at what width.
///
/// The experiment's `env` field may be a scenario-mixture spec
/// (`"CartPole-v1:32,Acrobot-v1:16"`, see
/// [`crate::coordinator::registry::MixtureSpec`]); in that case the
/// spec's per-component counts define the lane list and `lanes` here is
/// ignored.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecutorSettings {
    /// `"vec"` (sequential), `"pool"` (sync workers) or `"pool-async"`.
    pub kind: String,
    /// Environment lanes stepped per batch (homogeneous env ids only;
    /// mixture specs carry their own counts).
    pub lanes: usize,
    /// Worker threads for the pooled kinds; `0` = one per available core.
    pub threads: usize,
    /// `"fused"` (SoA batch kernels where available, the default) or
    /// `"scalar"` (per-lane dispatch, the A/B baseline) — `cairl run
    /// --kernel` overrides it.
    pub kernel: String,
    /// Remote shard addresses (`"unix:///tmp/s0.sock"` /
    /// `"tcp://host:port"`).  Non-empty routes batched workloads
    /// through a [`ShardedEnvPool`](crate::shard::ShardedEnvPool)
    /// instead of a local executor; `kind`/`threads`/`kernel` then
    /// apply on the serving side.  `cairl run --shard` overrides it.
    pub shards: Vec<String>,
    /// Batches kept in flight per shard connection (`1` = lockstep;
    /// clamped to [`MAX_PIPELINE`](crate::shard::MAX_PIPELINE)).
    /// `cairl run --pipeline` overrides it.
    pub pipeline: usize,
    /// Auth token presented to `--token`'d shard daemons (`""` = none).
    /// `cairl run --token` overrides it.
    pub shard_token: String,
}

impl Default for ExecutorSettings {
    fn default() -> Self {
        ExecutorSettings {
            kind: "vec".into(),
            lanes: 1,
            threads: 0,
            kernel: KernelMode::default().label().into(),
            shards: Vec::new(),
            pipeline: 1,
            shard_token: String::new(),
        }
    }
}

impl ExecutorSettings {
    /// Resolve the configured kind name.
    pub fn to_kind(&self) -> Result<ExecutorKind> {
        ExecutorKind::parse(&self.kind).ok_or_else(|| {
            CairlError::Config(format!(
                "unknown executor kind {:?} (expected vec | pool | pool-async)",
                self.kind
            ))
        })
    }

    /// Resolve the configured kernel name.
    pub fn to_kernel(&self) -> Result<KernelMode> {
        KernelMode::parse(&self.kernel).ok_or_else(|| {
            CairlError::Config(format!(
                "unknown kernel mode {:?} (expected scalar | fused)",
                self.kernel
            ))
        })
    }

    /// Worker-thread count with the `0 = all cores` default applied.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// Overlay fields present in a JSON object.
    fn apply(&mut self, v: &Value) {
        if let Some(s) = v.get("kind").and_then(Value::as_str) {
            self.kind = s.to_string();
        }
        if let Some(x) = v.get("lanes").and_then(Value::as_f64) {
            self.lanes = (x as usize).max(1);
        }
        if let Some(x) = v.get("threads").and_then(Value::as_f64) {
            self.threads = x as usize;
        }
        if let Some(s) = v.get("kernel").and_then(Value::as_str) {
            self.kernel = s.to_string();
        }
        if let Some(items) = v.get("shards").and_then(Value::as_array) {
            self.shards = items
                .iter()
                .filter_map(Value::as_str)
                .map(str::to_string)
                .collect();
        }
        if let Some(x) = v.get("pipeline").and_then(Value::as_f64) {
            self.pipeline = (x as usize).max(1);
        }
        if let Some(s) = v.get("shard_token").and_then(Value::as_str) {
            self.shard_token = s.to_string();
        }
    }
}

/// Chaos block — deterministic fault injection for robustness drills.
///
/// A CI failure under chaos reproduces exactly from this block: the
/// profile string carries both the fault rates and the seed (see
/// [`ChaosProfile::parse`]), and every injection decision is a pure
/// function of `(profile, connection stream, send index)`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosSettings {
    /// Fault profile in the `--chaos` grammar: a preset
    /// (`"light@7"`, `"heavy@3"`), an explicit rate list
    /// (`"corrupt=10,delay=40,delay_ms=2@seed"`) or `""` / `"off"` for
    /// no injection.  `cairl run --chaos` / `cairl serve --chaos`
    /// override it.
    pub profile: String,
}

impl ChaosSettings {
    /// Resolve the configured profile (`None` when empty/off).
    pub fn to_profile(&self) -> Result<Option<ChaosProfile>> {
        if self.profile.trim().is_empty() {
            return Ok(None);
        }
        let profile = ChaosProfile::parse(&self.profile)?;
        Ok(if profile.is_off() { None } else { Some(profile) })
    }

    /// Overlay fields present in a JSON object.
    fn apply(&mut self, v: &Value) {
        if let Some(s) = v.get("profile").and_then(Value::as_str) {
            self.profile = s.to_string();
        }
    }
}

/// A full experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// Registry id (e.g. "CartPole-v1", optionally with kwargs:
    /// "CartPole-v1?max_steps=200") or a scenario-mixture spec
    /// (e.g. "CartPole-v1:32,Acrobot-v1:16") for batched workloads.
    pub env: String,
    /// Declarative wrapper chain applied to every constructed env/lane,
    /// one [`WrapperSpec`](crate::wrappers::WrapperSpec) item per
    /// entry (e.g. `["TimeLimit(200)", "NormalizeObs"]`); validated
    /// when the experiment builds its envs.
    pub wrappers: Vec<String>,
    /// "dqn", "qtable" or "random".
    pub agent: String,
    /// Independent trials (paper: 100 for Fig. 1/2, 10 for Fig. 3).
    pub trials: u32,
    /// Base seed; trial `i` uses `seed + i`.
    pub seed: u64,
    /// Render each step through the software renderer.
    pub render: bool,
    /// Output directory for CSV results.
    pub out_dir: String,
    pub dqn: DqnSettings,
    /// Batched-executor selection for vectorised workloads.
    pub executor: ExecutorSettings,
    /// Deterministic fault injection (robustness drills; off by default).
    pub chaos: ChaosSettings,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            env: "CartPole-v1".into(),
            wrappers: Vec::new(),
            agent: "random".into(),
            trials: 1,
            seed: 0,
            render: false,
            out_dir: "results".into(),
            dqn: DqnSettings::default(),
            executor: ExecutorSettings::default(),
            chaos: ChaosSettings::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse a JSON file.
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
            .map_err(|e| CairlError::Config(format!("{}: {e}", path.display())))
    }

    /// Parse from a JSON string; missing fields keep defaults.
    pub fn parse(text: &str) -> Result<ExperimentConfig> {
        let v = json::parse(text)?;
        if v.as_object().is_none() {
            return Err(CairlError::Config("config must be a JSON object".into()));
        }
        let mut cfg = ExperimentConfig::default();
        if let Some(s) = v.get("env").and_then(Value::as_str) {
            cfg.env = s.to_string();
        }
        if let Some(items) = v.get("wrappers").and_then(Value::as_array) {
            for item in items {
                let Some(s) = item.as_str() else {
                    return Err(CairlError::Config(format!(
                        "\"wrappers\" entries must be strings, got {item:?}"
                    )));
                };
                cfg.wrappers.push(s.to_string());
            }
        }
        if let Some(s) = v.get("agent").and_then(Value::as_str) {
            cfg.agent = s.to_string();
        }
        if let Some(x) = v.get("trials").and_then(Value::as_f64) {
            cfg.trials = x as u32;
        }
        if let Some(x) = v.get("seed").and_then(Value::as_f64) {
            cfg.seed = x as u64;
        }
        if let Some(b) = v.get("render").and_then(Value::as_bool) {
            cfg.render = b;
        }
        if let Some(s) = v.get("out_dir").and_then(Value::as_str) {
            cfg.out_dir = s.to_string();
        }
        if let Some(d) = v.get("dqn") {
            cfg.dqn.apply(d);
        }
        if let Some(e) = v.get("executor") {
            cfg.executor.apply(e);
        }
        if let Some(c) = v.get("chaos") {
            cfg.chaos.apply(c);
        }
        Ok(cfg)
    }

    /// Serialise (pretty enough for `cairl config`).
    pub fn render(&self) -> String {
        let wrappers = self
            .wrappers
            .iter()
            .map(|w| format!("{w:?}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{{\n  \"env\": \"{}\",\n  \"wrappers\": [{}],\n  \"agent\": \"{}\",\n  \
             \"trials\": {},\n  \"seed\": {},\n  \"render\": {},\n  \"out_dir\": \"{}\",\n  \
             \"dqn\": {{\n    \"epsilon_start\": {},\n    \"epsilon_final\": {},\n    \
             \"epsilon_decay_steps\": {},\n    \"target_update_freq\": {},\n    \
             \"memory_size\": {},\n    \"learn_start\": {},\n    \"train_every\": {},\n    \
             \"max_steps\": {},\n    \"solve_return\": {},\n    \"solve_window\": {}\n  \
             }},\n  \"executor\": {{\n    \"kind\": \"{}\",\n    \"lanes\": {},\n    \
             \"threads\": {},\n    \"kernel\": \"{}\",\n    \"shards\": [{}],\n    \
             \"pipeline\": {},\n    \"shard_token\": {:?}\n  }},\n  \
             \"chaos\": {{\n    \"profile\": {:?}\n  }}\n}}",
            self.env,
            wrappers,
            self.agent,
            self.trials,
            self.seed,
            self.render,
            self.out_dir,
            self.dqn.epsilon_start,
            self.dqn.epsilon_final,
            self.dqn.epsilon_decay_steps,
            self.dqn.target_update_freq,
            self.dqn.memory_size,
            self.dqn.learn_start,
            self.dqn.train_every,
            self.dqn.max_steps,
            self.dqn.solve_return,
            self.dqn.solve_window,
            self.executor.kind,
            self.executor.lanes,
            self.executor.threads,
            self.executor.kernel,
            self.executor.shards.iter().map(|s| format!("{s:?}")).collect::<Vec<_>>().join(", "),
            self.executor.pipeline,
            self.executor.shard_token,
            self.chaos.profile,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_table_one() {
        let s = DqnSettings::default();
        assert_eq!(s.memory_size, 50_000);
        assert_eq!(s.target_update_freq, 150);
        let rows = s.table_one();
        assert!(rows.iter().any(|(k, v)| *k == "Batch Size" && v == "32"));
        assert!(rows.iter().any(|(k, v)| *k == "Learning Rate" && v == "3e-4"));
        assert_eq!(rows.len(), 11); // Table I has 11 rows
    }

    #[test]
    fn parses_partial_json() {
        let cfg = ExperimentConfig::parse(
            r#"{"env": "Acrobot-v1", "agent": "dqn", "trials": 5, "dqn": {"max_steps": 1000}}"#,
        )
        .unwrap();
        assert_eq!(cfg.env, "Acrobot-v1");
        assert_eq!(cfg.trials, 5);
        assert_eq!(cfg.dqn.max_steps, 1000);
        // Unspecified fields keep defaults.
        assert_eq!(cfg.dqn.memory_size, 50_000);
        assert_eq!(cfg.seed, 0);
    }

    #[test]
    fn bad_json_is_config_error() {
        assert!(matches!(
            ExperimentConfig::parse("env = ["),
            Err(CairlError::Config(_))
        ));
        assert!(ExperimentConfig::parse("[1,2]").is_err());
    }

    #[test]
    fn to_config_carries_seed() {
        let s = DqnSettings::default();
        let c = s.to_config(42);
        assert_eq!(c.seed, 42);
        assert_eq!(c.memory_size, s.memory_size);
    }

    #[test]
    fn roundtrips_through_render() {
        let cfg = ExperimentConfig::default();
        let back = ExperimentConfig::parse(&cfg.render()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn parses_executor_block() {
        let cfg = ExperimentConfig::parse(
            r#"{"executor": {"kind": "pool", "lanes": 256, "threads": 8}}"#,
        )
        .unwrap();
        assert_eq!(cfg.executor.kind, "pool");
        assert_eq!(cfg.executor.lanes, 256);
        assert_eq!(cfg.executor.threads, 8);
        assert_eq!(cfg.executor.effective_threads(), 8);
        assert!(cfg.executor.to_kind().is_ok());
        // Unset kernel keeps the fused default.
        assert_eq!(cfg.executor.to_kernel().unwrap(), KernelMode::Fused);
    }

    #[test]
    fn parses_kernel_mode() {
        let src = r#"{"executor": {"kind": "pool", "kernel": "scalar"}}"#;
        let cfg = ExperimentConfig::parse(src).unwrap();
        assert_eq!(cfg.executor.kernel, "scalar");
        assert_eq!(cfg.executor.to_kernel().unwrap(), KernelMode::Scalar);
        let bad = ExperimentConfig::parse(r#"{"executor": {"kernel": "warp"}}"#).unwrap();
        assert!(matches!(bad.executor.to_kernel(), Err(CairlError::Config(_))));
    }

    #[test]
    fn executor_defaults_to_sequential_vec() {
        let cfg = ExperimentConfig::parse("{}").unwrap();
        assert_eq!(cfg.executor, ExecutorSettings::default());
        use crate::coordinator::experiment::ExecutorKind;
        assert_eq!(cfg.executor.to_kind().unwrap(), ExecutorKind::Sequential);
        assert!(cfg.executor.effective_threads() >= 1);
    }

    #[test]
    fn parses_and_renders_wrappers_block() {
        let src = r#"{"wrappers": ["TimeLimit(200)", "NormalizeObs"]}"#;
        let cfg = ExperimentConfig::parse(src).unwrap();
        assert_eq!(cfg.wrappers, vec!["TimeLimit(200)", "NormalizeObs"]);
        use crate::wrappers::WrapperSpec;
        let chain = WrapperSpec::parse_chain(&cfg.wrappers.join(",")).unwrap();
        assert_eq!(chain.len(), 2);
        let back = ExperimentConfig::parse(&cfg.render()).unwrap();
        assert_eq!(back, cfg);
        assert!(ExperimentConfig::parse(r#"{"wrappers": [1]}"#).is_err());
        // A non-array value is ignored like every other wrong-typed field.
        let lax = ExperimentConfig::parse(r#"{"wrappers": "TimeLimit(200)"}"#).unwrap();
        assert!(lax.wrappers.is_empty());
    }

    #[test]
    fn env_field_accepts_mixture_specs() {
        let cfg = ExperimentConfig::parse(
            r#"{"env": "CartPole-v1:32,Acrobot-v1:16", "executor": {"kind": "pool-async", "threads": 4}}"#,
        )
        .unwrap();
        use crate::coordinator::registry::MixtureSpec;
        assert!(MixtureSpec::is_mixture(&cfg.env));
        let spec = MixtureSpec::parse(&cfg.env).unwrap();
        assert_eq!(spec.total_lanes(), 48);
        assert!(cfg.executor.to_kind().is_ok());
    }

    #[test]
    fn parses_and_renders_shard_addresses() {
        let cfg = ExperimentConfig::parse(
            r#"{"executor": {"kind": "pool", "shards": ["unix:///tmp/s0.sock", "tcp://10.0.0.2:7000"]}}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.executor.shards,
            vec!["unix:///tmp/s0.sock".to_string(), "tcp://10.0.0.2:7000".to_string()]
        );
        let back = ExperimentConfig::parse(&cfg.render()).unwrap();
        assert_eq!(back, cfg);
        // Default: no shards, local execution, lockstep, no token.
        let bare = ExperimentConfig::parse("{}").unwrap();
        assert!(bare.executor.shards.is_empty());
        assert_eq!(bare.executor.pipeline, 1);
        assert!(bare.executor.shard_token.is_empty());
    }

    #[test]
    fn parses_pipeline_and_token() {
        let cfg = ExperimentConfig::parse(
            r#"{"executor": {"shards": ["tcp://10.0.0.2:7000"], "pipeline": 4, "shard_token": "hunter2"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.executor.pipeline, 4);
        assert_eq!(cfg.executor.shard_token, "hunter2");
        let back = ExperimentConfig::parse(&cfg.render()).unwrap();
        assert_eq!(back, cfg);
        // pipeline 0 would deadlock the window; it clamps to lockstep.
        let zero = ExperimentConfig::parse(r#"{"executor": {"pipeline": 0}}"#).unwrap();
        assert_eq!(zero.executor.pipeline, 1);
    }

    #[test]
    fn bad_executor_kind_is_config_error() {
        let cfg =
            ExperimentConfig::parse(r#"{"executor": {"kind": "warp"}}"#).unwrap();
        assert!(matches!(cfg.executor.to_kind(), Err(CairlError::Config(_))));
    }

    #[test]
    fn parses_and_renders_chaos_block() {
        // Default: no chaos.
        let bare = ExperimentConfig::parse("{}").unwrap();
        assert!(bare.chaos.profile.is_empty());
        assert!(bare.chaos.to_profile().unwrap().is_none());

        let cfg = ExperimentConfig::parse(
            r#"{"chaos": {"profile": "corrupt=10,delay=40,delay_ms=2@7"}}"#,
        )
        .unwrap();
        let profile = cfg.chaos.to_profile().unwrap().expect("profile active");
        assert_eq!(profile.seed, 7);
        let back = ExperimentConfig::parse(&cfg.render()).unwrap();
        assert_eq!(back, cfg);

        // Presets resolve; "off" resolves to None.
        let light = ExperimentConfig::parse(r#"{"chaos": {"profile": "light@3"}}"#).unwrap();
        assert!(light.chaos.to_profile().unwrap().is_some());
        let off = ExperimentConfig::parse(r#"{"chaos": {"profile": "off"}}"#).unwrap();
        assert!(off.chaos.to_profile().unwrap().is_none());

        // A malformed profile is a config-time error, not a silent no-op.
        let bad = ExperimentConfig::parse(r#"{"chaos": {"profile": "explode=1"}}"#).unwrap();
        assert!(bad.chaos.to_profile().is_err());
    }
}
