//! The dynamic environment registry behind [`make`] — the paper's
//! `cairl.make("CartPole-v1")` Gym-compatible entry point (Listing 2),
//! redesigned around a first-class [`EnvSpec`].
//!
//! Every environment is one **spec**: id, summary, typed kwarg defaults,
//! a declarative [`WrapperSpec`] chain and a builder closure.  The
//! registry is a process-wide `RwLock` table seeded with the built-in
//! entries (native envs, the interpreted-script baselines `Script/...`,
//! the flash-runner games `Flash/...` and the puzzle runtime
//! `Puzzle/...`) and **extensible at runtime**:
//!
//! * [`register`] adds any [`EnvSpec`];
//! * [`register_script`] compiles a MiniScript source into the
//!   `Script/` namespace — `cairl run --register-script MyEnv=my.mpy`
//!   makes `--env "Script/MyEnv:8"` work without recompiling;
//! * [`make_with`] constructs with explicit kwargs, and [`make`] parses
//!   Gym-style id kwargs uniformly (`"CartPole-v1?max_steps=200"`).
//!
//! The same namespace feeds **scenario mixtures** ([`MixtureSpec`]):
//! `"Script/MyEnv:8,CartPole-v1?max_steps=200:4"` describes a
//! heterogeneous lane list that the batched executors run behind one
//! interface; any registered id — native, script, flash, puzzle or
//! runtime-registered — can appear as a component, parameterized or
//! not.  Gym-standard time limits are part of the registered spec
//! (CartPole-v1 is *defined* as 500-step-capped) exactly as before; an
//! unparameterized id builds the identical wrapper stack, so
//! pre-redesign trajectories are preserved bit for bit.

use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::sync::{Arc, OnceLock, RwLock};

use crate::core::batch::{DynBatchEnv, FusedChain};
use crate::core::env::DynEnv;
use crate::core::error::{CairlError, Result};
use crate::core::json::Value;
use crate::core::kwargs::{Kwargs, KwargValue};
use crate::core::spaces::Space;
use crate::envs::{Acrobot, CartPole, GridRts, LineWars, MountainCar, Pendulum};
use crate::flash;
use crate::puzzles;
use crate::script;
use crate::script::batch::ScriptBatch;
use crate::script::compile::compile_src;
use crate::script::envs::{LoadedScript, RenderHint, ScriptCell, ScriptEnv};
use crate::script::vm::CompiledScriptEnv;
use crate::wrappers::spec::split_top_level;
use crate::wrappers::{apply_wrappers, WrapperSpec};

/// The builder half of an [`EnvSpec`]: merged kwargs in, base env out
/// (wrappers are applied by the spec, not the builder).
pub type EnvBuilder = Arc<dyn Fn(&Kwargs) -> Result<DynEnv> + Send + Sync>;

/// A spec-level kwarg invariant (e.g. a value range the builder relies
/// on), run by [`EnvSpec::checked_kwargs`] — i.e. both by
/// [`EnvSpec::build`] *and* by [`validate`], so [`MixtureSpec::parse`]
/// rejects a bad component without constructing anything.
pub type KwargCheck = Arc<dyn Fn(&Kwargs) -> Result<()> + Send + Sync>;

/// A resolved fused-batch constructor: lane count in, SoA batch group
/// out.  The executors call it per worker sub-range, so it must be
/// reusable (each call builds an independent group; seeding happens
/// afterwards via [`BatchEnv::seed`](crate::core::batch::BatchEnv::seed)).
pub type LaneBatchBuilder = Arc<dyn Fn(usize) -> DynBatchEnv + Send + Sync>;

/// The batch half of an [`EnvSpec`]: given the merged kwargs and the
/// kwarg-overridden effective wrapper chain, decide whether this
/// configuration can run on a fused SoA kernel — `Some(builder)` when it
/// can, `None` to fall back to scalar stepping (e.g. a chain the kernel
/// cannot absorb; see
/// [`WrapperSpec::as_fused_chain`]).
pub type BatchHook = Arc<dyn Fn(&Kwargs, &[WrapperSpec]) -> Option<LaneBatchBuilder> + Send + Sync>;

/// One registry entry: everything needed to construct a parameterized,
/// wrapper-composed environment from its id.
///
/// ```
/// use cairl::coordinator::registry::{self, EnvSpec};
///
/// registry::register(
///     EnvSpec::new("Docs/CartPole-v1", "500-step cart-pole for the docs", |_| {
///         Ok(Box::new(cairl::envs::CartPole::new()) as cairl::DynEnv)
///     })
///     .with_time_limit(500),
/// )
/// .unwrap();
///
/// // Registered specs accept Gym-style id kwargs immediately:
/// let mut env = cairl::make("Docs/CartPole-v1?max_steps=10").unwrap();
/// assert_eq!(env.reset().len(), 4);
/// ```
#[derive(Clone)]
pub struct EnvSpec {
    id: String,
    summary: String,
    defaults: Kwargs,
    wrappers: Vec<WrapperSpec>,
    builder: EnvBuilder,
    check: Option<KwargCheck>,
    batch: Option<BatchHook>,
}

impl fmt::Debug for EnvSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("EnvSpec")
            .field("id", &self.id)
            .field("summary", &self.summary)
            .field("defaults", &self.defaults)
            .field("wrappers", &self.wrappers)
            .finish_non_exhaustive()
    }
}

impl EnvSpec {
    /// A spec with no kwargs and no wrappers; chain `with_*` builders
    /// to declare them.
    pub fn new(
        id: &str,
        summary: &str,
        builder: impl Fn(&Kwargs) -> Result<DynEnv> + Send + Sync + 'static,
    ) -> EnvSpec {
        EnvSpec {
            id: id.to_string(),
            summary: summary.to_string(),
            defaults: Kwargs::new(),
            wrappers: Vec::new(),
            builder: Arc::new(builder),
            check: None,
            batch: None,
        }
    }

    /// Advertise a fused-batch builder ([`BatchHook`]): homogeneous lane
    /// groups of this spec step through one SoA kernel instead of
    /// per-lane virtual dispatch wherever the hook accepts the
    /// configuration.  Fused trajectories must be bit-identical to the
    /// scalar build — `rust/tests/batch_kernel.rs` pins this for the
    /// built-in kernels.
    pub fn with_batch(
        mut self,
        hook: impl Fn(&Kwargs, &[WrapperSpec]) -> Option<LaneBatchBuilder> + Send + Sync + 'static,
    ) -> EnvSpec {
        self.batch = Some(Arc::new(hook));
        self
    }

    /// Whether this spec advertises a fused-batch builder at all
    /// (specific kwargs/wrapper configurations may still fall back).
    pub fn batch_capable(&self) -> bool {
        self.batch.is_some()
    }

    /// Resolve the fused-batch builder for these kwargs: `Ok(None)`
    /// when the spec has no hook or the hook declines this
    /// configuration (the caller falls back to scalar lanes).
    pub fn fused_builder(&self, user: &Kwargs) -> Result<Option<LaneBatchBuilder>> {
        self.fused_builder_with(user, &[])
    }

    /// [`EnvSpec::fused_builder`] with an extra wrapper chain appended
    /// *outside* the spec's own (the `--wrap`/config chain): the hook
    /// sees the full effective stack, so an absorbable extra layer
    /// (e.g. a trailing `NormalizeObs`) still fuses instead of forcing
    /// the scalar fallback.
    pub fn fused_builder_with(
        &self,
        user: &Kwargs,
        extra: &[WrapperSpec],
    ) -> Result<Option<LaneBatchBuilder>> {
        let merged = self.checked_kwargs(user)?;
        let mut wrappers = self.effective_wrappers(&merged)?;
        wrappers.extend_from_slice(extra);
        Ok(self.batch.as_ref().and_then(|hook| (**hook)(&merged, &wrappers)))
    }

    /// Attach a spec-level kwarg invariant, checked before the builder
    /// runs and by eager validation ([`validate`], mixture parsing).
    pub fn with_check(
        mut self,
        check: impl Fn(&Kwargs) -> Result<()> + Send + Sync + 'static,
    ) -> EnvSpec {
        self.check = Some(Arc::new(check));
        self
    }

    /// Declare a kwarg with its typed default value.
    ///
    /// Caveat for [`KwargValue::Str`] kwargs: a *value* containing `,`,
    /// `:` or `+` cannot be passed through a mixture spec string (those
    /// are the component/lane-count/wrapper-chain separators
    /// [`MixtureSpec::parse`] splits on first) — pass such values via
    /// [`make_with`] or a config file instead.
    pub fn with_default(mut self, key: &str, value: KwargValue) -> EnvSpec {
        self.defaults.insert(key, value);
        self
    }

    /// Append one wrapper to the declarative chain (applied
    /// innermost-first, see [`apply_wrappers`]).
    pub fn with_wrapper(mut self, wrapper: WrapperSpec) -> EnvSpec {
        self.wrappers.push(wrapper);
        self
    }

    /// Gym-style registration time limit: declares the `max_steps`
    /// kwarg *and* the [`WrapperSpec::TimeLimit`] chain entry it
    /// overrides.
    pub fn with_time_limit(self, max_steps: u32) -> EnvSpec {
        self.with_default("max_steps", KwargValue::Int(max_steps as i64))
            .with_wrapper(WrapperSpec::TimeLimit { max_steps })
    }

    /// Pixel observations: declares the `pixels` kwarg and the
    /// [`WrapperSpec::PixelObs`] chain entry.
    pub fn with_pixels(self, size: usize) -> EnvSpec {
        self.with_default("pixels", KwargValue::Int(size as i64))
            .with_wrapper(WrapperSpec::PixelObs { size })
    }

    /// The registered id.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// One-line human description.
    pub fn summary(&self) -> &str {
        &self.summary
    }

    /// Declared kwargs with their typed defaults.
    pub fn defaults(&self) -> &Kwargs {
        &self.defaults
    }

    /// The declarative wrapper chain (pre-override).
    pub fn wrappers(&self) -> &[WrapperSpec] {
        &self.wrappers
    }

    /// User kwargs merged over the defaults — the validation step
    /// ([`CairlError::Config`] on unknown keys or bad values).
    pub fn merged_kwargs(&self, user: &Kwargs) -> Result<Kwargs> {
        Kwargs::merged_over(&self.defaults, user, &self.id)
    }

    /// The wrapper chain with kwarg overrides substituted in (an
    /// out-of-range override is a [`CairlError::Config`]).
    pub fn effective_wrappers(&self, merged: &Kwargs) -> Result<Vec<WrapperSpec>> {
        self.wrappers
            .iter()
            .map(|w| w.overridden_by(merged))
            .collect()
    }

    /// The full static validation prefix shared by [`EnvSpec::build`]
    /// and [`validate`]: merge user kwargs over the defaults, resolve
    /// and range-check the wrapper chain, and run the spec-level
    /// [`KwargCheck`].  Returns the merged kwargs on success.
    pub fn checked_kwargs(&self, user: &Kwargs) -> Result<Kwargs> {
        let merged = self.merged_kwargs(user)?;
        for wrapper in self.effective_wrappers(&merged)? {
            wrapper.validate()?;
        }
        if let Some(check) = &self.check {
            check(&merged)?;
        }
        Ok(merged)
    }

    /// Construct: run every static check ([`EnvSpec::checked_kwargs`]),
    /// then the builder, then apply the kwarg-overridden wrapper chain.
    pub fn build(&self, user: &Kwargs) -> Result<DynEnv> {
        let merged = self.checked_kwargs(user)?;
        let wrappers = self.effective_wrappers(&merged)?;
        let base = (self.builder)(&merged)?;
        Ok(apply_wrappers(base, &wrappers))
    }
}

/// Range check for the puzzle `size` kwarg (the boards are quadratic;
/// a negative or absurd size would otherwise panic deep in a solver).
fn board_size(kw: &Kwargs, id: &str, min: i64) -> Result<usize> {
    let size = kw.i64_or("size", min);
    if size < min || size > 16 {
        return Err(CairlError::Config(format!(
            "{id}: kwarg \"size\" must be in {min}..=16, got {size}"
        )));
    }
    Ok(size as usize)
}

/// The shared [`BatchHook`] of the classic-control specs: fuse whenever
/// the effective chain is absorbable ([`WrapperSpec::as_fused_chain`])
/// — bare, a single `TimeLimit` (folded into the kernel's step
/// counter), and/or one trailing `NormalizeObs`/`RewardScale` (folded
/// into the kernel's affine epilogue); any other chain falls back to
/// scalar lanes.
fn classic_batch(
    build: fn(usize, &FusedChain) -> DynBatchEnv,
) -> impl Fn(&Kwargs, &[WrapperSpec]) -> Option<LaneBatchBuilder> + Send + Sync + 'static {
    move |_, wrappers| {
        WrapperSpec::as_fused_chain(wrappers)
            .map(|chain| -> LaneBatchBuilder { Arc::new(move |lanes| build(lanes, &chain)) })
    }
}

/// The [`BatchHook`] of the built-in `Script/*` specs: the source is
/// compiled to register bytecode once (here, eagerly — these sources
/// are compile-time constants), and absorbable chains build a
/// [`ScriptBatch`] SoA group stepping all lanes under that one program.
/// The *scalar* builder keeps the tree-walk interpreter — it is the
/// calibrated Gym-baseline surrogate — so only fused lane groups run
/// the bytecode VM, whose bit-equality with the tree-walk is pinned by
/// `rust/tests/script_vm.rs` and `rust/tests/batch_kernel.rs`.
fn script_batch(
    id: &'static str,
    src: &'static str,
    stream: u64,
) -> impl Fn(&Kwargs, &[WrapperSpec]) -> Option<LaneBatchBuilder> + Send + Sync + 'static {
    let program = Arc::new(compile_src(src).unwrap_or_else(|e| panic!("{id}: {e}")));
    move |_, wrappers| {
        let chain = WrapperSpec::as_fused_chain(wrappers)?;
        let program = Arc::clone(&program);
        Some(Arc::new(move |lanes| {
            Box::new(
                ScriptBatch::try_new(id, Arc::clone(&program), stream, lanes, &chain)
                    .unwrap_or_else(|e| panic!("{id}: {e}")),
            ) as DynBatchEnv
        }))
    }
}

/// The built-in table the registry is seeded with; runtime
/// registrations append after these.
fn builtin_specs() -> Vec<EnvSpec> {
    vec![
        EnvSpec::new("CartPole-v1", "native cart-pole balancing (500-step limit)", |_| {
            Ok(Box::new(CartPole::new()) as DynEnv)
        })
        .with_time_limit(500)
        .with_batch(classic_batch(|lanes, chain| -> DynBatchEnv {
            Box::new(CartPole::batch(lanes, chain.max_steps).with_epilogue(chain.epilogue.as_ref()))
        })),
        EnvSpec::new("MountainCar-v0", "native mountain car (200-step limit)", |_| {
            Ok(Box::new(MountainCar::new()) as DynEnv)
        })
        .with_time_limit(200)
        .with_batch(classic_batch(|lanes, chain| -> DynBatchEnv {
            Box::new(
                MountainCar::batch(lanes, chain.max_steps).with_epilogue(chain.epilogue.as_ref()),
            )
        })),
        EnvSpec::new("Acrobot-v1", "native acrobot swing-up (500-step limit)", |_| {
            Ok(Box::new(Acrobot::new()) as DynEnv)
        })
        .with_time_limit(500)
        .with_batch(classic_batch(|lanes, chain| -> DynBatchEnv {
            Box::new(Acrobot::batch(lanes, chain.max_steps).with_epilogue(chain.epilogue.as_ref()))
        })),
        EnvSpec::new(
            "Pendulum-v1",
            "native pendulum swing-up, continuous torque (200-step limit)",
            |_| Ok(Box::new(Pendulum::new()) as DynEnv),
        )
        .with_time_limit(200)
        .with_batch(classic_batch(|lanes, chain| -> DynBatchEnv {
            Box::new(Pendulum::batch(lanes, chain.max_steps).with_epilogue(chain.epilogue.as_ref()))
        })),
        EnvSpec::new(
            "PendulumDiscrete-v1",
            "pendulum with 5 discrete torque levels for DQN (200-step limit)",
            |_| Ok(Box::new(Pendulum::discrete()) as DynEnv),
        )
        .with_time_limit(200)
        .with_batch(classic_batch(|lanes, chain| -> DynBatchEnv {
            Box::new(
                Pendulum::batch_discrete(lanes, chain.max_steps)
                    .with_epilogue(chain.epilogue.as_ref()),
            )
        })),
        EnvSpec::new(
            "LineWars-v0",
            "Deep-Line-Wars-class lane strategy vs scripted opponent",
            |_| Ok(Box::new(LineWars::new()) as DynEnv),
        ),
        EnvSpec::new(
            "GridRTS-v0",
            "MicroRTS-class grid strategy vs scripted opponent",
            |_| Ok(Box::new(GridRts::new()) as DynEnv),
        ),
        EnvSpec::new(
            "Script/CartPole-v1",
            "cart-pole on the interpreted MiniPy runner (Gym baseline surrogate)",
            |_| Ok(Box::new(script::envs::cartpole()) as DynEnv),
        )
        .with_time_limit(500)
        .with_batch(script_batch(
            "Script/CartPole-v1",
            script::envs::CARTPOLE_SRC,
            script::envs::CARTPOLE_STREAM,
        )),
        EnvSpec::new(
            "Script/MountainCar-v0",
            "mountain car on the interpreted MiniPy runner",
            |_| Ok(Box::new(script::envs::mountain_car()) as DynEnv),
        )
        .with_time_limit(200)
        .with_batch(script_batch(
            "Script/MountainCar-v0",
            script::envs::MOUNTAINCAR_SRC,
            script::envs::MOUNTAINCAR_STREAM,
        )),
        EnvSpec::new(
            "Script/Acrobot-v1",
            "acrobot on the interpreted MiniPy runner",
            |_| Ok(Box::new(script::envs::acrobot()) as DynEnv),
        )
        .with_time_limit(500)
        .with_batch(script_batch(
            "Script/Acrobot-v1",
            script::envs::ACROBOT_SRC,
            script::envs::ACROBOT_STREAM,
        )),
        EnvSpec::new(
            "Script/Pendulum-v1",
            "discrete-torque pendulum on the interpreted MiniPy runner",
            |_| Ok(Box::new(script::envs::pendulum()) as DynEnv),
        )
        .with_time_limit(200)
        .with_batch(script_batch(
            "Script/Pendulum-v1",
            script::envs::PENDULUM_SRC,
            script::envs::PENDULUM_STREAM,
        )),
        EnvSpec::new(
            "Flash/Multitask-v0",
            "concurrent mini-games on the ASVM flash runner (paper SS IV-C)",
            |_| Ok(Box::new(flash::games::multitask()) as DynEnv),
        ),
        EnvSpec::new("Flash/Pong-v0", "pong on the ASVM flash runner", |_| {
            Ok(Box::new(flash::games::pong()) as DynEnv)
        }),
        EnvSpec::new(
            "Flash/Dodge-v0",
            "projectile dodging on the ASVM flash runner",
            |_| Ok(Box::new(flash::games::dodge()) as DynEnv),
        ),
        EnvSpec::new(
            "Flash/X1337Shooter-v0",
            "X1337 space shooter on the ASVM flash runner (paper SS III)",
            |_| Ok(Box::new(flash::games::shooter()) as DynEnv),
        ),
        EnvSpec::new(
            "Pixel/CartPole-v1",
            "cart-pole with 16x16 raw-pixel observations (software render)",
            |_| Ok(Box::new(CartPole::new()) as DynEnv),
        )
        .with_time_limit(500)
        .with_pixels(16),
        EnvSpec::new(
            "Puzzle/LightsOut-v0",
            "size x size lights-out puzzle with heuristic solver",
            |kw| Ok(Box::new(puzzles::LightsOut::env(kw.i64_or("size", 5) as usize)) as DynEnv),
        )
        .with_default("size", KwargValue::Int(5))
        .with_check(|kw| board_size(kw, "Puzzle/LightsOut-v0", 1).map(|_| ())),
        EnvSpec::new(
            "Puzzle/Fifteen-v0",
            "size x size sliding-tile puzzle with heuristic solver",
            |kw| Ok(Box::new(puzzles::Fifteen::env(kw.i64_or("size", 4) as usize)) as DynEnv),
        )
        .with_default("size", KwargValue::Int(4))
        .with_check(|kw| board_size(kw, "Puzzle/Fifteen-v0", 2).map(|_| ())),
        EnvSpec::new(
            "Puzzle/Nonogram-v0",
            "5x5 nonogram with line-logic solver",
            |_| Ok(Box::new(puzzles::Nonogram::env()) as DynEnv),
        ),
    ]
}

static REGISTRY: OnceLock<RwLock<Vec<EnvSpec>>> = OnceLock::new();

/// The process-wide spec table, lazily seeded with the built-ins.
fn registry() -> &'static RwLock<Vec<EnvSpec>> {
    REGISTRY.get_or_init(|| RwLock::new(builtin_specs()))
}

/// Characters an id can never contain: they are the mixture-spec,
/// wrapper-chain and kwarg metacharacters ([`MixtureSpec::is_mixture`]
/// relies on this).
const ID_METACHARS: [char; 6] = [':', ',', '?', '&', '=', '+'];

/// Register a spec.  Duplicate ids and ids containing mixture/kwarg
/// metacharacters or whitespace are [`CairlError::Config`] errors.
pub fn register(spec: EnvSpec) -> Result<()> {
    if spec.id.is_empty()
        || spec.id.contains(&ID_METACHARS[..])
        || spec.id.contains(char::is_whitespace)
    {
        return Err(CairlError::Config(format!(
            "env id {:?} is empty or contains one of ':,?&=+' or whitespace",
            spec.id
        )));
    }
    let mut specs = registry().write().unwrap_or_else(|e| e.into_inner());
    if specs.iter().any(|s| s.id == spec.id) {
        return Err(CairlError::Config(format!(
            "env id {:?} is already registered",
            spec.id
        )));
    }
    specs.push(spec);
    Ok(())
}

/// FNV-1a of the id: the PCG stream of runtime-registered script envs
/// (deterministic across runs and registration orders).
fn script_stream(id: &str) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for byte in id.as_bytes() {
        hash ^= *byte as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

/// The hot-reload cells of runtime-registered scripts: one
/// [`ScriptCell`] per [`register_script`] id, shared with every env
/// built from that id.
static SCRIPT_CELLS: OnceLock<RwLock<HashMap<String, Arc<ScriptCell>>>> = OnceLock::new();

fn script_cells() -> &'static RwLock<HashMap<String, Arc<ScriptCell>>> {
    SCRIPT_CELLS.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Register a MiniScript source as an environment in the `Script/`
/// namespace, returning the full registered id.  The source is
/// validated **now** on both runners — tree-walk load + probe (one
/// `reset()` + one `step(0)` shape check), then an eager bytecode
/// compile + VM probe for the fused path — so a broken script fails
/// here with a [`CairlError::Script`] instead of panicking inside a
/// worker later.  Registered ids are `batch_capable`: homogeneous lane
/// groups step through a [`ScriptBatch`] SoA kernel whenever the
/// effective wrapper chain is absorbable.
///
/// `name` may be bare (`"MyEnv"` registers `"Script/MyEnv"`) or a full
/// id containing `/`, which is used verbatim.
///
/// # Hot reload & concurrency
///
/// Re-registering an id previously created by `register_script`
/// **replaces the source in place** after the same eager validation —
/// the registry keeps its single entry for the id.  Envs and fused
/// groups built afterwards use the new program immediately; live
/// [`ScriptEnv`]s finish their current episode on the old program and
/// rebuild at their next `reset()`, re-seeded with their last
/// [`Env::seed`](crate::core::env::Env::seed) value.  A reload that
/// changes `obs_dim`/`n_actions` only affects envs built afterwards:
/// live envs keep the old program (their observation buffers are
/// already sized).  Fused [`ScriptBatch`] groups snapshot the program
/// at construction and never reload mid-run.  The swap is one `RwLock`
/// write over an `Arc` — concurrent builders observe either the old or
/// the new version atomically, never a mix.  Ids registered through
/// plain [`register`] (including the built-in `Script/*` baselines)
/// have no reload cell; re-registering them stays a duplicate-id
/// [`CairlError::Config`].
///
/// ```
/// use cairl::coordinator::registry;
///
/// let src = "
/// obs_dim = 1;
/// n_actions = 2;
/// def reset() { return [0]; }
/// def step(action) { return [action, 1.0, 1]; }
/// ";
/// let id = registry::register_script("DocsDemo", src).unwrap();
/// assert_eq!(id, "Script/DocsDemo");
/// let mut env = cairl::make("Script/DocsDemo").unwrap();
/// assert_eq!(env.reset(), vec![0.0]);
/// ```
pub fn register_script(name: &str, src: &str) -> Result<String> {
    let id = if name.contains('/') {
        name.to_string()
    } else {
        format!("Script/{name}")
    };
    let stream = script_stream(&id);
    // Validate on the tree-walk runner (the scalar path)...
    let mut probe = ScriptEnv::try_load(&id, src, stream, RenderHint::None)?;
    probe.probe()?;
    // ...and on the bytecode VM (the fused path).
    let program =
        Arc::new(compile_src(src).map_err(|e| CairlError::Script(format!("script env {id}: {e}")))?);
    let mut vm_probe = CompiledScriptEnv::from_program(&id, Arc::clone(&program), stream, RenderHint::None)?;
    vm_probe.probe()?;
    let obs_dim = crate::core::env::Env::obs_dim(&probe);
    let n_actions = match crate::core::env::Env::action_space(&probe) {
        Space::Discrete { n } => n,
        other => unreachable!("script envs are discrete, got {other:?}"),
    };
    let loaded = LoadedScript {
        src: src.to_string(),
        stream,
        obs_dim,
        n_actions,
        program,
        generation: 0,
    };
    let mut cells = script_cells().write().unwrap_or_else(|e| e.into_inner());
    if let Some(cell) = cells.get(&id) {
        // Hot reload: swap the cell contents; the registered spec's
        // closures read the cell at build time, so nothing else moves.
        cell.replace(loaded);
        return Ok(id);
    }
    let cell = Arc::new(ScriptCell::new(loaded));
    cells.insert(id.clone(), Arc::clone(&cell));
    let build_cell = Arc::clone(&cell);
    let build_id = id.clone();
    let hook_cell = Arc::clone(&cell);
    let hook_id = id.clone();
    let registered = register(
        EnvSpec::new(&id, "runtime-registered MiniScript environment", move |_| {
            let cur = build_cell.snapshot();
            Ok(Box::new(
                ScriptEnv::try_load(&build_id, &cur.src, cur.stream, RenderHint::None)?
                    .with_cell(Arc::clone(&build_cell)),
            ) as DynEnv)
        })
        .with_batch(move |_, wrappers| {
            let chain = WrapperSpec::as_fused_chain(wrappers)?;
            let cur = hook_cell.snapshot();
            let id = hook_id.clone();
            Some(Arc::new(move |lanes| {
                Box::new(
                    ScriptBatch::try_new(&id, Arc::clone(&cur.program), cur.stream, lanes, &chain)
                        .unwrap_or_else(|e| panic!("{id}: {e}")),
                ) as DynBatchEnv
            }))
        }),
    );
    if registered.is_err() {
        // The id exists in the registry but was not script-registered
        // (e.g. a built-in): no cell for it.
        cells.remove(&id);
    }
    registered?;
    Ok(id)
}

/// Split `"Id?key=value&key=value"` into the bare id and its kwargs.
fn parse_id_kwargs(spec: &str) -> Result<(String, Kwargs)> {
    match spec.split_once('?') {
        Some((id, query)) => Ok((id.trim().to_string(), Kwargs::parse_query(query)?)),
        None => Ok((spec.trim().to_string(), Kwargs::new())),
    }
}

/// Look up a spec by bare id (clones out of the read lock, so builders
/// never run under it).
fn find_spec(id: &str) -> Result<EnvSpec> {
    registry()
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .find(|s| s.id == id)
        .cloned()
        .ok_or_else(|| CairlError::UnknownEnv(id.to_string()))
}

/// The registered spec for a bare id (no kwargs).
pub fn env_spec(id: &str) -> Result<EnvSpec> {
    find_spec(id)
}

/// Construct an environment by id — the Gym-compatible dynamic API.
/// The id may carry Gym-style kwargs after `?`, validated against the
/// spec's typed defaults.
///
/// ```
/// let mut env = cairl::make("CartPole-v1").unwrap();
/// let _obs = env.reset();
///
/// // Parameterized: override the registered 500-step limit.
/// let mut short = cairl::make("CartPole-v1?max_steps=25").unwrap();
/// let _obs = short.reset();
///
/// // Unknown kwargs are errors, not silent fallbacks.
/// assert!(cairl::make("CartPole-v1?nope=1").is_err());
/// ```
pub fn make(spec: &str) -> Result<DynEnv> {
    let (id, kwargs) = parse_id_kwargs(spec)?;
    make_with(&id, &kwargs)
}

/// [`make`] with explicit kwargs: merge over the spec's defaults
/// (unknown key / uncoercible value → [`CairlError::Config`]), build,
/// apply the wrapper chain.
///
/// ```
/// use cairl::core::kwargs::{Kwargs, KwargValue};
///
/// let kwargs = Kwargs::new().with("max_steps", KwargValue::Int(25));
/// let mut env = cairl::coordinator::registry::make_with("CartPole-v1", &kwargs).unwrap();
/// let _obs = env.reset();
/// ```
pub fn make_with(id: &str, kwargs: &Kwargs) -> Result<DynEnv> {
    find_spec(id)?.build(kwargs)
}

/// Validate an `"Id?kwargs"` spec — id registered, kwargs well-formed,
/// wrapper overrides in range, spec-level checks satisfied — without
/// constructing the environment ([`EnvSpec::checked_kwargs`]).
pub fn validate(spec: &str) -> Result<()> {
    let (id, kwargs) = parse_id_kwargs(spec)?;
    find_spec(&id)?.checked_kwargs(&kwargs).map(|_| ())
}

/// All registered ids with one-line summaries, registration order
/// (built-ins first, runtime registrations after).
pub fn list_envs() -> Vec<(String, String)> {
    registry()
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .map(|s| (s.id.clone(), s.summary.clone()))
        .collect()
}

/// Every registered spec, cloned out of the table in registration order.
pub fn all_specs() -> Vec<EnvSpec> {
    registry()
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .clone()
}

/// Resolve the fused-batch builder for an `"Id?kwargs"` spec string —
/// `Ok(None)` when the id is registered but cannot fuse under this
/// configuration (the executors then fall back to
/// [`ScalarBatch`](crate::core::batch::ScalarBatch) lanes).
pub fn fused_lane_builder(spec: &str) -> Result<Option<LaneBatchBuilder>> {
    fused_lane_builder_with(spec, &[])
}

/// [`fused_lane_builder`] with an extra wrapper chain applied outside
/// the registered spec ([`EnvSpec::fused_builder_with`]) — how
/// `--wrap NormalizeObs` keeps classic-control lanes on the fused path.
pub fn fused_lane_builder_with(
    spec: &str,
    extra: &[WrapperSpec],
) -> Result<Option<LaneBatchBuilder>> {
    let (id, kwargs) = parse_id_kwargs(spec)?;
    find_spec(&id)?.fused_builder_with(&kwargs, extra)
}

/// The whole registry as a JSON document (`cairl envs --json`): one
/// entry per spec with id, summary, typed kwarg defaults, declarative
/// wrapper chain and the batch-capable flag — the experiment-provenance
/// dump the ROADMAP asks for.
pub fn registry_json() -> Value {
    let envs: Vec<Value> = all_specs()
        .iter()
        .map(|s| {
            let kwargs: BTreeMap<String, Value> = s
                .defaults()
                .iter()
                .map(|(key, value)| {
                    let v = match value {
                        KwargValue::Int(i) => Value::Num(*i as f64),
                        KwargValue::Float(x) => Value::Num(*x),
                        KwargValue::Bool(b) => Value::Bool(*b),
                        KwargValue::Str(t) => Value::Str(t.clone()),
                    };
                    (key.to_string(), v)
                })
                .collect();
            let wrappers: Vec<Value> =
                s.wrappers().iter().map(|w| Value::Str(w.render())).collect();
            let mut obj = BTreeMap::new();
            obj.insert("id".to_string(), Value::Str(s.id().to_string()));
            obj.insert("summary".to_string(), Value::Str(s.summary().to_string()));
            obj.insert("kwargs".to_string(), Value::Object(kwargs));
            obj.insert("wrappers".to_string(), Value::Array(wrappers));
            obj.insert("batch_capable".to_string(), Value::Bool(s.batch_capable()));
            Value::Object(obj)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Value::Str("cairl-envs/v1".to_string()));
    doc.insert("envs".to_string(), Value::Array(envs));
    Value::Object(doc)
}

/// One component of a [`MixtureSpec`]: an `"Id?kwargs"` spec string, a
/// lane count, and the per-component wrapper chain written with `+` in
/// the mixture grammar (`"CartPole-v1+NormalizeObs:8"`).
#[derive(Clone, Debug, PartialEq)]
pub struct MixtureEntry {
    /// The component's `"Id?kwargs"` spec (no wrappers, no count).
    pub spec: String,
    /// Number of consecutive lanes this component occupies.
    pub count: usize,
    /// Per-component wrappers, applied *outside* the registered spec's
    /// own chain and *inside* any pool-level `--wrap` chain.
    pub wrappers: Vec<WrapperSpec>,
}

impl MixtureEntry {
    /// A chainless entry — the pre-redesign `(id, count)` shape.
    pub fn bare(spec: impl Into<String>, count: usize) -> MixtureEntry {
        MixtureEntry {
            spec: spec.into(),
            count,
            wrappers: Vec::new(),
        }
    }

    /// The component as written in the mixture grammar, minus the lane
    /// count: `"Id?kwargs"` plus its `+`-joined wrapper chain.  This is
    /// the label lane lists carry.
    pub fn label(&self) -> String {
        let mut label = self.spec.clone();
        for w in &self.wrappers {
            label.push('+');
            label.push_str(&w.render());
        }
        label
    }
}

/// A parsed scenario-mixture spec: an ordered list of components, e.g.
/// `"CartPole-v1:32,Acrobot-v1:16"` → 32 CartPole lanes followed by 16
/// Acrobot lanes.  Components may carry id kwargs
/// (`"CartPole-v1?max_steps=200:4"`) and per-component wrapper chains
/// joined with `+` (`"CartPole-v1+NormalizeObs:8,Script/MyEnv+TimeLimit(200):4"`);
/// chains the fused kernels cannot absorb fall back to scalar lanes at
/// group-planning time — they never error.  Lane order is the spec
/// order, which fixes the per-lane seeds (`base_seed + lane`) and
/// therefore the bit-determinism contract of mixture pools.
#[derive(Clone, Debug, PartialEq)]
pub struct MixtureSpec {
    entries: Vec<MixtureEntry>,
}

impl MixtureSpec {
    /// Whether `spec` is a mixture spec (rather than a bare env id):
    /// mixtures contain a `:` lane count, a `,` separator or a `+`
    /// wrapper chain, none of which a registered id may contain
    /// ([`register`] enforces it).  Kwarg *values* containing these
    /// metacharacters would also trip this test, so string kwargs with
    /// `,`/`:`/`+` must go through [`make_with`] or a config file
    /// rather than a spec string.
    pub fn is_mixture(spec: &str) -> bool {
        spec.contains(':') || spec.contains(',') || spec.contains('+')
    }

    /// Parse `"Id-v1:32,Other-v0?k=v+NormalizeObs:16"`.  A component
    /// without `:count` contributes one lane.  Every id (with kwargs)
    /// is validated against the registry and every wrapper chain is
    /// parsed and range-checked eagerly; counts must be positive.
    /// Separators split at paren depth zero only, so wrapper arguments
    /// like `ClipReward(-1,1)` pass through intact.
    pub fn parse(spec: &str) -> Result<MixtureSpec> {
        let mut entries = Vec::new();
        for part in split_top_level(spec, ',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(CairlError::Config(format!(
                    "mixture spec {spec:?}: empty component"
                )));
            }
            let (head, count) = match part.rsplit_once(':') {
                Some((head, count)) => {
                    let count: usize = count.trim().parse().map_err(|_| {
                        CairlError::Config(format!(
                            "mixture spec {spec:?}: bad lane count in {part:?}"
                        ))
                    })?;
                    (head.trim(), count)
                }
                None => (part, 1),
            };
            if count == 0 {
                return Err(CairlError::Config(format!(
                    "mixture spec {spec:?}: {head:?} has zero lanes"
                )));
            }
            let mut segments = split_top_level(head, '+').into_iter();
            let id = segments.next().unwrap_or("").trim();
            if id.is_empty() {
                return Err(CairlError::Config(format!(
                    "mixture spec {spec:?}: component {part:?} has no env id"
                )));
            }
            let mut wrappers = Vec::new();
            for seg in segments {
                let wrapper = WrapperSpec::parse(seg.trim()).map_err(|e| {
                    CairlError::Config(format!(
                        "mixture spec {spec:?}: component {part:?}: {e}"
                    ))
                })?;
                wrapper.validate()?;
                wrappers.push(wrapper);
            }
            // Validate membership and kwargs eagerly so executor
            // construction can't fail on a bad component (no throwaway
            // env construction).
            validate(id)?;
            entries.push(MixtureEntry {
                spec: id.to_string(),
                count,
                wrappers,
            });
        }
        if entries.is_empty() {
            return Err(CairlError::Config(format!("empty mixture spec {spec:?}")));
        }
        Ok(MixtureSpec { entries })
    }

    /// The components in lane order.
    pub fn entries(&self) -> &[MixtureEntry] {
        &self.entries
    }

    /// Total lane count across all components.
    pub fn total_lanes(&self) -> usize {
        self.entries.iter().map(|e| e.count).sum()
    }

    /// Construct the lane-ordered env list (lane `i` runs the `i`-th
    /// env of the flattened spec).
    pub fn build_envs(&self) -> Result<Vec<DynEnv>> {
        Ok(self.build_labeled_envs()?.into_iter().map(|(_, e)| e).collect())
    }

    /// [`MixtureSpec::build_envs`] paired with each lane's component
    /// label ([`MixtureEntry::label`]) — the labels `lane_specs()`
    /// should carry (an env's own
    /// [`Env`](crate::core::env::Env)`::id` reports wrapper composition
    /// like `TimeLimit(CartPole-v1, 500)`, not the registry id).
    /// Parameterized components keep their kwargs and `+`-chains in
    /// the label; per-component wrappers are applied outside the
    /// registered spec's own chain.
    pub fn build_labeled_envs(&self) -> Result<Vec<(String, DynEnv)>> {
        let mut envs = Vec::with_capacity(self.total_lanes());
        for entry in &self.entries {
            let label = entry.label();
            for _ in 0..entry.count {
                let env = apply_wrappers(make(&entry.spec)?, &entry.wrappers);
                envs.push((label.clone(), env));
            }
        }
        Ok(envs)
    }

    /// Render back to the canonical `id+chain:count,...` spelling.
    pub fn render(&self) -> String {
        self.entries
            .iter()
            .map(|e| format!("{}:{}", e.label(), e.count))
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::env::Env;

    #[test]
    fn make_unknown_is_an_error() {
        match make("NoSuchEnv-v0") {
            Err(err) => assert!(matches!(err, CairlError::UnknownEnv(_))),
            Ok(_) => panic!("unknown env id must fail"),
        }
    }

    #[test]
    fn make_every_registered_env_and_reset() {
        for (id, _) in list_envs() {
            let mut env = make(&id).unwrap_or_else(|e| panic!("{id}: {e}"));
            let obs = env.reset();
            assert_eq!(obs.len(), env.obs_dim(), "{id}");
            assert!(env.obs_dim() > 0, "{id}");
        }
    }

    #[test]
    fn registered_ids_are_unique() {
        let ids: Vec<String> = list_envs().into_iter().map(|(id, _)| id).collect();
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(ids.len(), dedup.len());
    }

    #[test]
    fn id_kwargs_reach_the_time_limit() {
        let mut env = make("CartPole-v1?max_steps=500").unwrap();
        assert_eq!(env.id(), "TimeLimit(CartPole-v1, 500)");
        let mut env2 = make("CartPole-v1").unwrap();
        assert_eq!(env.reset().len(), env2.reset().len());
        let short = make("CartPole-v1?max_steps=7").unwrap();
        assert_eq!(short.id(), "TimeLimit(CartPole-v1, 7)");
    }

    #[test]
    fn id_kwargs_reject_unknown_keys_and_bad_values() {
        assert!(matches!(
            make("CartPole-v1?nope=3"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(
            make("CartPole-v1?max_steps=abc"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(
            make("CartPole-v1?max_steps"),
            Err(CairlError::Config(_))
        ));
        // Out of u32 range errors rather than silently clamping.
        assert!(matches!(
            make("CartPole-v1?max_steps=9999999999"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(make("NoSuchEnv-v0?x=1"), Err(CairlError::UnknownEnv(_))));
    }

    #[test]
    fn builder_kwargs_parameterize_puzzles() {
        let mut small = make("Puzzle/LightsOut-v0?size=3").unwrap();
        assert_eq!(small.obs_dim(), 9);
        let obs = small.reset();
        assert_eq!(obs.len(), 9);
        let mut default = make("Puzzle/LightsOut-v0").unwrap();
        assert_eq!(default.obs_dim(), 25);
        assert_eq!(default.reset().len(), 25);
        assert!(matches!(
            make("Puzzle/LightsOut-v0?size=0"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(
            make("Puzzle/Fifteen-v0?size=99"),
            Err(CairlError::Config(_))
        ));
    }

    #[test]
    fn register_rejects_duplicates_and_bad_ids() {
        register(EnvSpec::new("UnitReg/Once-v0", "unit test spec", |_| {
            Ok(Box::new(CartPole::new()) as DynEnv)
        }))
        .unwrap();
        let dup = register(EnvSpec::new("UnitReg/Once-v0", "again", |_| {
            Ok(Box::new(CartPole::new()) as DynEnv)
        }));
        assert!(matches!(dup, Err(CairlError::Config(_))));
        for bad in [
            "",
            "Has:Colon",
            "Has,Comma",
            "Has?Query",
            "Has Space",
            "a=b",
            "Has+Plus",
        ] {
            let r = register(EnvSpec::new(bad, "bad id", |_| {
                Ok(Box::new(CartPole::new()) as DynEnv)
            }));
            assert!(matches!(r, Err(CairlError::Config(_))), "{bad:?}");
        }
    }

    #[test]
    fn register_script_validates_the_source() {
        assert!(matches!(
            register_script("UnitBroken", "this is not MiniScript ("),
            Err(CairlError::Script(_))
        ));
        // Parses but violates the env protocol: no step().
        let no_step = "obs_dim = 1;\nn_actions = 1;\ndef reset() { return [0]; }";
        assert!(matches!(
            register_script("UnitNoStep", no_step),
            Err(CairlError::Script(_))
        ));
        // Wrong reset arity.
        let bad_shape = "obs_dim = 2;\nn_actions = 1;\n\
                         def reset() { return [0]; }\n\
                         def step(a) { return [0, 0, 0, 0]; }";
        assert!(matches!(
            register_script("UnitBadShape", bad_shape),
            Err(CairlError::Script(_))
        ));
    }

    #[test]
    fn mixture_spec_parses_and_builds_lane_ordered_envs() {
        let spec = MixtureSpec::parse("CartPole-v1:2, Script/CartPole-v1:1,Acrobot-v1").unwrap();
        assert_eq!(spec.total_lanes(), 4);
        assert_eq!(spec.entries()[1], MixtureEntry::bare("Script/CartPole-v1", 1));
        assert_eq!(spec.entries()[2], MixtureEntry::bare("Acrobot-v1", 1));
        let envs = spec.build_labeled_envs().unwrap();
        assert_eq!(envs.len(), 4);
        // Labels are the registry ids; the envs themselves report their
        // wrapper-composed Env::id.
        assert_eq!(envs[0].0, "CartPole-v1");
        assert_eq!(envs[0].1.id(), "TimeLimit(CartPole-v1, 500)");
        assert_eq!(envs[3].0, "Acrobot-v1");
        assert_eq!(spec.build_envs().unwrap().len(), 4);
        assert_eq!(spec.render(), "CartPole-v1:2,Script/CartPole-v1:1,Acrobot-v1:1");
    }

    #[test]
    fn mixture_spec_accepts_parameterized_components() {
        let spec = MixtureSpec::parse("CartPole-v1?max_steps=9:2,CartPole-v1:1").unwrap();
        assert_eq!(spec.total_lanes(), 3);
        assert_eq!(spec.entries()[0].spec, "CartPole-v1?max_steps=9");
        let envs = spec.build_labeled_envs().unwrap();
        assert_eq!(envs[0].0, "CartPole-v1?max_steps=9");
        assert_eq!(envs[0].1.id(), "TimeLimit(CartPole-v1, 9)");
        assert_eq!(envs[2].1.id(), "TimeLimit(CartPole-v1, 500)");
        assert_eq!(spec.render(), "CartPole-v1?max_steps=9:2,CartPole-v1:1");
    }

    #[test]
    fn mixture_spec_rejects_bad_input() {
        assert!(matches!(
            MixtureSpec::parse("CartPole-v1:0"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(
            MixtureSpec::parse("CartPole-v1:two"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(
            MixtureSpec::parse("NoSuchEnv-v0:4"),
            Err(CairlError::UnknownEnv(_))
        ));
        assert!(matches!(
            MixtureSpec::parse("CartPole-v1?bogus=1:4"),
            Err(CairlError::Config(_))
        ));
        // Spec-level checks run eagerly too: a builder-range violation
        // fails at parse, not later inside executor construction.
        assert!(matches!(
            MixtureSpec::parse("Puzzle/LightsOut-v0?size=0:4"),
            Err(CairlError::Config(_))
        ));
        assert!(MixtureSpec::parse("CartPole-v1:2,,Acrobot-v1:2").is_err());
    }

    #[test]
    fn mixture_detection_leaves_bare_ids_alone() {
        assert!(!MixtureSpec::is_mixture("CartPole-v1"));
        assert!(!MixtureSpec::is_mixture("Script/CartPole-v1"));
        assert!(!MixtureSpec::is_mixture("CartPole-v1?max_steps=200"));
        assert!(MixtureSpec::is_mixture("CartPole-v1:32"));
        assert!(MixtureSpec::is_mixture("CartPole-v1:32,Acrobot-v1:16"));
        // A wrapper chain makes a one-component spec a mixture too, so
        // `--env "CartPole-v1+NormalizeObs"` routes through the parser.
        assert!(MixtureSpec::is_mixture("CartPole-v1+NormalizeObs"));
        // No registered id may ever contain the mixture metacharacters.
        for (id, _) in list_envs() {
            assert!(!MixtureSpec::is_mixture(&id), "{id}");
        }
    }

    #[test]
    fn classic_specs_advertise_fused_builders() {
        for id in [
            "CartPole-v1",
            "MountainCar-v0",
            "Acrobot-v1",
            "Pendulum-v1",
            "PendulumDiscrete-v1",
        ] {
            assert!(env_spec(id).unwrap().batch_capable(), "{id}");
            let builder = fused_lane_builder(id).unwrap().unwrap_or_else(|| {
                panic!("{id}: registered TimeLimit chain must fuse")
            });
            let batch = (*builder)(3);
            assert_eq!(batch.lanes(), 3, "{id}");
            assert!(batch.obs_dim() > 0, "{id}");
        }
        // Kwargs flow into the fused limit path without erroring.
        assert!(fused_lane_builder("CartPole-v1?max_steps=25").unwrap().is_some());
        assert!(fused_lane_builder("CartPole-v1?bogus=1").is_err());
        // A single trailing affine wrapper is absorbed as a kernel
        // epilogue; longer extra chains fall back to scalar lanes.
        assert!(fused_lane_builder_with("CartPole-v1", &[WrapperSpec::NormalizeObs])
            .unwrap()
            .is_some());
        assert!(fused_lane_builder_with(
            "MountainCar-v0",
            &[WrapperSpec::RewardScale { scale: 0.5, shift: 0.0 }],
        )
        .unwrap()
        .is_some());
        assert!(fused_lane_builder_with(
            "CartPole-v1",
            &[WrapperSpec::NormalizeObs, WrapperSpec::NormalizeObs],
        )
        .unwrap()
        .is_none());
        // PixelObs in the chain blocks fusion.
        assert!(fused_lane_builder("Pixel/CartPole-v1").unwrap().is_none());
        assert!(matches!(
            fused_lane_builder("NoSuchEnv-v0"),
            Err(CairlError::UnknownEnv(_))
        ));
    }

    #[test]
    fn script_specs_advertise_fused_builders() {
        // The interpreted baselines fuse via the bytecode ScriptBatch
        // kernel (their registered TimeLimit chain is absorbable).
        for id in [
            "Script/CartPole-v1",
            "Script/MountainCar-v0",
            "Script/Acrobot-v1",
            "Script/Pendulum-v1",
        ] {
            assert!(env_spec(id).unwrap().batch_capable(), "{id}");
            let builder = fused_lane_builder(id)
                .unwrap()
                .unwrap_or_else(|| panic!("{id}: registered TimeLimit chain must fuse"));
            let batch = (*builder)(3);
            assert_eq!(batch.lanes(), 3, "{id}");
            assert!(batch.obs_dim() > 0, "{id}");
        }
        // Non-absorbable extra chains fall back to scalar, never error.
        assert!(fused_lane_builder_with(
            "Script/CartPole-v1",
            &[WrapperSpec::FrameStack { k: 2 }],
        )
        .unwrap()
        .is_none());
    }

    #[test]
    fn mixture_components_carry_wrapper_chains() {
        let spec = MixtureSpec::parse(
            "CartPole-v1+NormalizeObs:2,CartPole-v1?max_steps=9+ClipReward(-1,1):1",
        )
        .unwrap();
        assert_eq!(spec.total_lanes(), 3);
        assert_eq!(spec.entries()[0].wrappers, vec![WrapperSpec::NormalizeObs]);
        assert_eq!(spec.entries()[1].spec, "CartPole-v1?max_steps=9");
        assert_eq!(spec.entries()[1].wrappers.len(), 1);
        let envs = spec.build_labeled_envs().unwrap();
        assert_eq!(envs[0].0, "CartPole-v1+NormalizeObs");
        // Per-component wrappers apply outside the spec's own chain.
        assert_eq!(envs[0].1.id(), "NormalizeObs(TimeLimit(CartPole-v1, 500))");
        assert_eq!(
            envs[2].1.id(),
            "ClipReward(TimeLimit(CartPole-v1, 9), [-1, 1])"
        );
        // The grammar round-trips.
        assert_eq!(
            spec.render(),
            "CartPole-v1+NormalizeObs:2,CartPole-v1?max_steps=9+ClipReward(-1,1):1"
        );
        assert_eq!(MixtureSpec::parse(&spec.render()).unwrap(), spec);
        // A chained component without :count contributes one lane.
        assert_eq!(
            MixtureSpec::parse("CartPole-v1+NormalizeObs").unwrap().total_lanes(),
            1
        );
        // Bad chains fail eagerly at parse time.
        assert!(MixtureSpec::parse("CartPole-v1+NoSuchWrapper:2").is_err());
        assert!(MixtureSpec::parse("CartPole-v1+TimeLimit(0):2").is_err());
        assert!(MixtureSpec::parse("+NormalizeObs:2").is_err());
    }

    #[test]
    fn register_script_hot_reloads_in_place() {
        let src_a = "obs_dim = 1;\nn_actions = 2;\n\
                     def reset() { return [1.0]; }\n\
                     def step(action) { return [1.0, 1.0, 0]; }";
        let src_b = "obs_dim = 1;\nn_actions = 2;\n\
                     def reset() { return [2.0]; }\n\
                     def step(action) { return [2.0, 1.0, 0]; }";
        let id = register_script("UnitReload", src_a).unwrap();
        let table_len = list_envs().len();
        let mut env = make(&id).unwrap();
        env.seed(0);
        assert_eq!(env.reset(), vec![1.0]);
        // Re-registering replaces the source in place...
        register_script("UnitReload", src_b).unwrap();
        assert_eq!(list_envs().len(), table_len, "no second registry entry");
        // ...live envs rebuild on their next reset...
        assert_eq!(env.reset(), vec![2.0]);
        // ...and envs built afterwards start on the new program.
        let mut fresh = make(&id).unwrap();
        fresh.seed(0);
        assert_eq!(fresh.reset(), vec![2.0]);
        // A broken replacement is rejected and leaves the old version.
        assert!(register_script("UnitReload", "not a script (").is_err());
        assert_eq!(env.reset(), vec![2.0]);
        // Built-ins have no reload cell: still a duplicate-id error.
        assert!(matches!(
            register_script("Script/CartPole-v1", src_a),
            Err(CairlError::Config(_))
        ));
    }

    #[test]
    fn runtime_scripts_are_batch_capable() {
        let src = "obs_dim = 1;\nn_actions = 2;\n\
                   def reset() { return [0.5]; }\n\
                   def step(action) { return [0.5, 1.0, 0]; }";
        let id = register_script("UnitFused", src).unwrap();
        assert!(env_spec(&id).unwrap().batch_capable());
        let builder = fused_lane_builder(&id).unwrap().expect("bare chain fuses");
        let batch = (*builder)(2);
        assert_eq!(batch.lanes(), 2);
        assert_eq!(batch.obs_dim(), 1);
        // A chain the kernel cannot absorb falls back, never errors.
        assert!(fused_lane_builder_with(&id, &[WrapperSpec::Flatten])
            .unwrap()
            .is_none());
    }

    #[test]
    fn registry_json_dumps_every_spec() {
        let doc = registry_json();
        assert_eq!(doc.get("schema").and_then(Value::as_str), Some("cairl-envs/v1"));
        let envs = match doc.get("envs") {
            Some(Value::Array(envs)) => envs,
            other => panic!("envs must be an array, got {other:?}"),
        };
        assert!(envs.len() >= list_envs().len());
        let cartpole = envs
            .iter()
            .find(|e| e.get("id").and_then(Value::as_str) == Some("CartPole-v1"))
            .expect("CartPole-v1 in the dump");
        assert_eq!(cartpole.get("batch_capable"), Some(&Value::Bool(true)));
        assert_eq!(
            cartpole.get("kwargs").and_then(|k| k.get("max_steps")).and_then(Value::as_f64),
            Some(500.0)
        );
        assert_eq!(
            cartpole.get("wrappers").and_then(|w| w.idx(0)).and_then(Value::as_str),
            Some("TimeLimit(500)")
        );
        // The document round-trips through the in-tree JSON reader.
        let rendered = doc.render();
        assert_eq!(crate::core::json::parse(&rendered).unwrap(), doc);
    }

    #[test]
    fn cartpole_v1_has_gym_semantics() {
        let mut env = make("CartPole-v1").unwrap();
        assert_eq!(env.obs_dim(), 4);
        let obs = env.reset();
        assert!(obs.iter().all(|v| v.abs() <= 0.05));
    }
}
