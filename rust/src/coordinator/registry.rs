//! The environment registry behind [`make`] — the paper's
//! `cairl.make("CartPole-v1")` Gym-compatible entry point (Listing 2).
//!
//! Native envs, the interpreted-script baseline envs (`Script/...`), the
//! flash-runner games (`Flash/...`) and the puzzle runtime (`Puzzle/...`)
//! all register here, giving one uniform id namespace across runners —
//! the paper's "unified API for all environments" (§III-A Runners).

use crate::core::env::DynEnv;
use crate::core::error::{CairlError, Result};
use crate::envs::{Acrobot, CartPole, GridRts, LineWars, MountainCar, Pendulum};
use crate::flash;
use crate::puzzles;
use crate::script;
use crate::wrappers::TimeLimit;

/// One registry row: id, docstring, constructor.
struct Entry {
    id: &'static str,
    summary: &'static str,
    build: fn() -> DynEnv,
}

/// The static registry table.  Gym-standard time limits are part of the
/// registered id (CartPole-v1 is *defined* as 500-step-capped), matching
/// Gym's registration semantics.
fn table() -> &'static [Entry] {
    &[
        Entry {
            id: "CartPole-v1",
            summary: "native cart-pole balancing (500-step limit)",
            build: || Box::new(TimeLimit::new(CartPole::new(), 500)),
        },
        Entry {
            id: "MountainCar-v0",
            summary: "native mountain car (200-step limit)",
            build: || Box::new(TimeLimit::new(MountainCar::new(), 200)),
        },
        Entry {
            id: "Acrobot-v1",
            summary: "native acrobot swing-up (500-step limit)",
            build: || Box::new(TimeLimit::new(Acrobot::new(), 500)),
        },
        Entry {
            id: "Pendulum-v1",
            summary: "native pendulum swing-up, continuous torque (200-step limit)",
            build: || Box::new(TimeLimit::new(Pendulum::new(), 200)),
        },
        Entry {
            id: "PendulumDiscrete-v1",
            summary: "pendulum with 5 discrete torque levels for DQN (200-step limit)",
            build: || Box::new(TimeLimit::new(Pendulum::discrete(), 200)),
        },
        Entry {
            id: "LineWars-v0",
            summary: "Deep-Line-Wars-class lane strategy vs scripted opponent",
            build: || Box::new(LineWars::new()),
        },
        Entry {
            id: "GridRTS-v0",
            summary: "MicroRTS-class grid strategy vs scripted opponent",
            build: || Box::new(GridRts::new()),
        },
        Entry {
            id: "Script/CartPole-v1",
            summary: "cart-pole on the interpreted MiniPy runner (Gym baseline surrogate)",
            build: || Box::new(TimeLimit::new(script::envs::cartpole(), 500)),
        },
        Entry {
            id: "Script/MountainCar-v0",
            summary: "mountain car on the interpreted MiniPy runner",
            build: || Box::new(TimeLimit::new(script::envs::mountain_car(), 200)),
        },
        Entry {
            id: "Script/Acrobot-v1",
            summary: "acrobot on the interpreted MiniPy runner",
            build: || Box::new(TimeLimit::new(script::envs::acrobot(), 500)),
        },
        Entry {
            id: "Script/Pendulum-v1",
            summary: "discrete-torque pendulum on the interpreted MiniPy runner",
            build: || Box::new(TimeLimit::new(script::envs::pendulum(), 200)),
        },
        Entry {
            id: "Flash/Multitask-v0",
            summary: "concurrent mini-games on the ASVM flash runner (paper SS IV-C)",
            build: || Box::new(flash::games::multitask()),
        },
        Entry {
            id: "Flash/Pong-v0",
            summary: "pong on the ASVM flash runner",
            build: || Box::new(flash::games::pong()),
        },
        Entry {
            id: "Flash/Dodge-v0",
            summary: "projectile dodging on the ASVM flash runner",
            build: || Box::new(flash::games::dodge()),
        },
        Entry {
            id: "Flash/X1337Shooter-v0",
            summary: "X1337 space shooter on the ASVM flash runner (paper SS III)",
            build: || Box::new(flash::games::shooter()),
        },
        Entry {
            id: "Pixel/CartPole-v1",
            summary: "cart-pole with 16x16 raw-pixel observations (software render)",
            build: || {
                Box::new(crate::wrappers::PixelObs::new(
                    TimeLimit::new(CartPole::new(), 500),
                    16,
                ))
            },
        },
        Entry {
            id: "Puzzle/LightsOut-v0",
            summary: "5x5 lights-out puzzle with heuristic solver",
            build: || Box::new(puzzles::LightsOut::env(5)),
        },
        Entry {
            id: "Puzzle/Fifteen-v0",
            summary: "4x4 sliding-tile puzzle with heuristic solver",
            build: || Box::new(puzzles::Fifteen::env(4)),
        },
        Entry {
            id: "Puzzle/Nonogram-v0",
            summary: "5x5 nonogram with line-logic solver",
            build: || Box::new(puzzles::Nonogram::env()),
        },
    ]
}

/// Construct an environment by id — the Gym-compatible dynamic API.
///
/// ```no_run
/// let mut env = cairl::make("CartPole-v1").unwrap();
/// let _obs = env.reset();
/// ```
pub fn make(id: &str) -> Result<DynEnv> {
    table()
        .iter()
        .find(|e| e.id == id)
        .map(|e| (e.build)())
        .ok_or_else(|| CairlError::UnknownEnv(id.to_string()))
}

/// All registered ids with one-line summaries, registration order.
pub fn list_envs() -> Vec<(&'static str, &'static str)> {
    table().iter().map(|e| (e.id, e.summary)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::env::Env;

    #[test]
    fn make_unknown_is_an_error() {
        match make("NoSuchEnv-v0") {
            Err(err) => assert!(matches!(err, CairlError::UnknownEnv(_))),
            Ok(_) => panic!("unknown env id must fail"),
        }
    }

    #[test]
    fn make_every_registered_env_and_reset() {
        for (id, _) in list_envs() {
            let mut env = make(id).unwrap_or_else(|e| panic!("{id}: {e}"));
            let obs = env.reset();
            assert_eq!(obs.len(), env.obs_dim(), "{id}");
            assert!(env.obs_dim() > 0, "{id}");
        }
    }

    #[test]
    fn registered_ids_are_unique() {
        let ids: Vec<_> = list_envs().iter().map(|(id, _)| *id).collect();
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(ids.len(), dedup.len());
    }

    #[test]
    fn cartpole_v1_has_gym_semantics() {
        let mut env = make("CartPole-v1").unwrap();
        assert_eq!(env.obs_dim(), 4);
        let obs = env.reset();
        assert!(obs.iter().all(|v| v.abs() <= 0.05));
    }
}
