//! The environment registry behind [`make`] — the paper's
//! `cairl.make("CartPole-v1")` Gym-compatible entry point (Listing 2).
//!
//! Native envs, the interpreted-script baseline envs (`Script/...`), the
//! flash-runner games (`Flash/...`) and the puzzle runtime (`Puzzle/...`)
//! all register here, giving one uniform id namespace across runners —
//! the paper's "unified API for all environments" (§III-A Runners).
//!
//! The same namespace feeds **scenario mixtures** ([`MixtureSpec`]):
//! `"CartPole-v1:32,Acrobot-v1:16"` describes a heterogeneous lane list
//! that the batched executors run behind one interface (`cairl run
//! --env "CartPole-v1:32,Acrobot-v1:16"`); any registered id — native,
//! script, flash or puzzle — can appear as a mixture component.

use crate::core::env::DynEnv;
use crate::core::error::{CairlError, Result};
use crate::envs::{Acrobot, CartPole, GridRts, LineWars, MountainCar, Pendulum};
use crate::flash;
use crate::puzzles;
use crate::script;
use crate::wrappers::TimeLimit;

/// One registry row: id, docstring, constructor.
struct Entry {
    id: &'static str,
    summary: &'static str,
    build: fn() -> DynEnv,
}

/// The static registry table.  Gym-standard time limits are part of the
/// registered id (CartPole-v1 is *defined* as 500-step-capped), matching
/// Gym's registration semantics.
fn table() -> &'static [Entry] {
    &[
        Entry {
            id: "CartPole-v1",
            summary: "native cart-pole balancing (500-step limit)",
            build: || Box::new(TimeLimit::new(CartPole::new(), 500)),
        },
        Entry {
            id: "MountainCar-v0",
            summary: "native mountain car (200-step limit)",
            build: || Box::new(TimeLimit::new(MountainCar::new(), 200)),
        },
        Entry {
            id: "Acrobot-v1",
            summary: "native acrobot swing-up (500-step limit)",
            build: || Box::new(TimeLimit::new(Acrobot::new(), 500)),
        },
        Entry {
            id: "Pendulum-v1",
            summary: "native pendulum swing-up, continuous torque (200-step limit)",
            build: || Box::new(TimeLimit::new(Pendulum::new(), 200)),
        },
        Entry {
            id: "PendulumDiscrete-v1",
            summary: "pendulum with 5 discrete torque levels for DQN (200-step limit)",
            build: || Box::new(TimeLimit::new(Pendulum::discrete(), 200)),
        },
        Entry {
            id: "LineWars-v0",
            summary: "Deep-Line-Wars-class lane strategy vs scripted opponent",
            build: || Box::new(LineWars::new()),
        },
        Entry {
            id: "GridRTS-v0",
            summary: "MicroRTS-class grid strategy vs scripted opponent",
            build: || Box::new(GridRts::new()),
        },
        Entry {
            id: "Script/CartPole-v1",
            summary: "cart-pole on the interpreted MiniPy runner (Gym baseline surrogate)",
            build: || Box::new(TimeLimit::new(script::envs::cartpole(), 500)),
        },
        Entry {
            id: "Script/MountainCar-v0",
            summary: "mountain car on the interpreted MiniPy runner",
            build: || Box::new(TimeLimit::new(script::envs::mountain_car(), 200)),
        },
        Entry {
            id: "Script/Acrobot-v1",
            summary: "acrobot on the interpreted MiniPy runner",
            build: || Box::new(TimeLimit::new(script::envs::acrobot(), 500)),
        },
        Entry {
            id: "Script/Pendulum-v1",
            summary: "discrete-torque pendulum on the interpreted MiniPy runner",
            build: || Box::new(TimeLimit::new(script::envs::pendulum(), 200)),
        },
        Entry {
            id: "Flash/Multitask-v0",
            summary: "concurrent mini-games on the ASVM flash runner (paper SS IV-C)",
            build: || Box::new(flash::games::multitask()),
        },
        Entry {
            id: "Flash/Pong-v0",
            summary: "pong on the ASVM flash runner",
            build: || Box::new(flash::games::pong()),
        },
        Entry {
            id: "Flash/Dodge-v0",
            summary: "projectile dodging on the ASVM flash runner",
            build: || Box::new(flash::games::dodge()),
        },
        Entry {
            id: "Flash/X1337Shooter-v0",
            summary: "X1337 space shooter on the ASVM flash runner (paper SS III)",
            build: || Box::new(flash::games::shooter()),
        },
        Entry {
            id: "Pixel/CartPole-v1",
            summary: "cart-pole with 16x16 raw-pixel observations (software render)",
            build: || {
                Box::new(crate::wrappers::PixelObs::new(
                    TimeLimit::new(CartPole::new(), 500),
                    16,
                ))
            },
        },
        Entry {
            id: "Puzzle/LightsOut-v0",
            summary: "5x5 lights-out puzzle with heuristic solver",
            build: || Box::new(puzzles::LightsOut::env(5)),
        },
        Entry {
            id: "Puzzle/Fifteen-v0",
            summary: "4x4 sliding-tile puzzle with heuristic solver",
            build: || Box::new(puzzles::Fifteen::env(4)),
        },
        Entry {
            id: "Puzzle/Nonogram-v0",
            summary: "5x5 nonogram with line-logic solver",
            build: || Box::new(puzzles::Nonogram::env()),
        },
    ]
}

/// Construct an environment by id — the Gym-compatible dynamic API.
///
/// ```no_run
/// let mut env = cairl::make("CartPole-v1").unwrap();
/// let _obs = env.reset();
/// ```
pub fn make(id: &str) -> Result<DynEnv> {
    table()
        .iter()
        .find(|e| e.id == id)
        .map(|e| (e.build)())
        .ok_or_else(|| CairlError::UnknownEnv(id.to_string()))
}

/// All registered ids with one-line summaries, registration order.
pub fn list_envs() -> Vec<(&'static str, &'static str)> {
    table().iter().map(|e| (e.id, e.summary)).collect()
}

/// A parsed scenario-mixture spec: an ordered list of `(env_id, lanes)`
/// pairs, e.g. `"CartPole-v1:32,Acrobot-v1:16"` → 32 CartPole lanes
/// followed by 16 Acrobot lanes.  Lane order is the spec order, which
/// fixes the per-lane seeds (`base_seed + lane`) and therefore the
/// bit-determinism contract of mixture pools.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MixtureSpec {
    entries: Vec<(String, usize)>,
}

impl MixtureSpec {
    /// Whether `spec` is a mixture spec (rather than a bare env id):
    /// mixtures contain a `:` lane count or a `,` separator, which no
    /// registered id does.
    pub fn is_mixture(spec: &str) -> bool {
        spec.contains(':') || spec.contains(',')
    }

    /// Parse `"Id-v1:32,Other-v0:16"`.  A component without `:count`
    /// contributes one lane.  Every id is validated against the
    /// registry; counts must be positive.
    pub fn parse(spec: &str) -> Result<MixtureSpec> {
        let mut entries = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(CairlError::Config(format!(
                    "mixture spec {spec:?}: empty component"
                )));
            }
            let (id, count) = match part.rsplit_once(':') {
                Some((id, count)) => {
                    let count: usize = count.trim().parse().map_err(|_| {
                        CairlError::Config(format!(
                            "mixture spec {spec:?}: bad lane count in {part:?}"
                        ))
                    })?;
                    (id.trim(), count)
                }
                None => (part, 1),
            };
            if count == 0 {
                return Err(CairlError::Config(format!(
                    "mixture spec {spec:?}: {id:?} has zero lanes"
                )));
            }
            // Validate membership eagerly so executor construction can't
            // fail on an unknown id (no throwaway env construction).
            if !table().iter().any(|e| e.id == id) {
                return Err(CairlError::UnknownEnv(id.to_string()));
            }
            entries.push((id.to_string(), count));
        }
        if entries.is_empty() {
            return Err(CairlError::Config(format!("empty mixture spec {spec:?}")));
        }
        Ok(MixtureSpec { entries })
    }

    /// The `(env_id, lanes)` components in lane order.
    pub fn entries(&self) -> &[(String, usize)] {
        &self.entries
    }

    /// Total lane count across all components.
    pub fn total_lanes(&self) -> usize {
        self.entries.iter().map(|(_, n)| n).sum()
    }

    /// Construct the lane-ordered env list (lane `i` runs the `i`-th
    /// env of the flattened spec).
    pub fn build_envs(&self) -> Result<Vec<DynEnv>> {
        Ok(self.build_labeled_envs()?.into_iter().map(|(_, e)| e).collect())
    }

    /// [`MixtureSpec::build_envs`] paired with each lane's registry id —
    /// the labels `lane_specs()` should carry (an env's own
    /// [`Env`](crate::core::env::Env)`::id` reports wrapper composition
    /// like `TimeLimit(CartPole-v1, 500)`, not the registry id).
    pub fn build_labeled_envs(&self) -> Result<Vec<(String, DynEnv)>> {
        let mut envs = Vec::with_capacity(self.total_lanes());
        for (id, count) in &self.entries {
            for _ in 0..*count {
                envs.push((id.clone(), make(id)?));
            }
        }
        Ok(envs)
    }

    /// Render back to the canonical `id:count,id:count` spelling.
    pub fn render(&self) -> String {
        self.entries
            .iter()
            .map(|(id, count)| format!("{id}:{count}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::env::Env;

    #[test]
    fn make_unknown_is_an_error() {
        match make("NoSuchEnv-v0") {
            Err(err) => assert!(matches!(err, CairlError::UnknownEnv(_))),
            Ok(_) => panic!("unknown env id must fail"),
        }
    }

    #[test]
    fn make_every_registered_env_and_reset() {
        for (id, _) in list_envs() {
            let mut env = make(id).unwrap_or_else(|e| panic!("{id}: {e}"));
            let obs = env.reset();
            assert_eq!(obs.len(), env.obs_dim(), "{id}");
            assert!(env.obs_dim() > 0, "{id}");
        }
    }

    #[test]
    fn registered_ids_are_unique() {
        let ids: Vec<_> = list_envs().iter().map(|(id, _)| *id).collect();
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(ids.len(), dedup.len());
    }

    #[test]
    fn mixture_spec_parses_and_builds_lane_ordered_envs() {
        let spec = MixtureSpec::parse("CartPole-v1:2, Script/CartPole-v1:1,Acrobot-v1").unwrap();
        assert_eq!(spec.total_lanes(), 4);
        assert_eq!(spec.entries()[1], ("Script/CartPole-v1".to_string(), 1));
        assert_eq!(spec.entries()[2], ("Acrobot-v1".to_string(), 1));
        let envs = spec.build_labeled_envs().unwrap();
        assert_eq!(envs.len(), 4);
        // Labels are the registry ids; the envs themselves report their
        // wrapper-composed Env::id.
        assert_eq!(envs[0].0, "CartPole-v1");
        assert_eq!(envs[0].1.id(), "TimeLimit(CartPole-v1, 500)");
        assert_eq!(envs[3].0, "Acrobot-v1");
        assert_eq!(spec.build_envs().unwrap().len(), 4);
        assert_eq!(spec.render(), "CartPole-v1:2,Script/CartPole-v1:1,Acrobot-v1:1");
    }

    #[test]
    fn mixture_spec_rejects_bad_input() {
        assert!(matches!(
            MixtureSpec::parse("CartPole-v1:0"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(
            MixtureSpec::parse("CartPole-v1:two"),
            Err(CairlError::Config(_))
        ));
        assert!(matches!(
            MixtureSpec::parse("NoSuchEnv-v0:4"),
            Err(CairlError::UnknownEnv(_))
        ));
        assert!(MixtureSpec::parse("CartPole-v1:2,,Acrobot-v1:2").is_err());
    }

    #[test]
    fn mixture_detection_leaves_bare_ids_alone() {
        assert!(!MixtureSpec::is_mixture("CartPole-v1"));
        assert!(!MixtureSpec::is_mixture("Script/CartPole-v1"));
        assert!(MixtureSpec::is_mixture("CartPole-v1:32"));
        assert!(MixtureSpec::is_mixture("CartPole-v1:32,Acrobot-v1:16"));
        // No registered id may ever contain the mixture metacharacters.
        for (id, _) in list_envs() {
            assert!(!MixtureSpec::is_mixture(id), "{id}");
        }
    }

    #[test]
    fn cartpole_v1_has_gym_semantics() {
        let mut env = make("CartPole-v1").unwrap();
        assert_eq!(env.obs_dim(), 4);
        let obs = env.reset();
        assert!(obs.iter().all(|v| v.abs() <= 0.05));
    }
}
