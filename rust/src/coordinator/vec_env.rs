//! Vectorised environment execution: N environments stepped as one
//! batch, sequentially — the bit-exact reference the threaded pools are
//! tested against.
//!
//! The invariant the property tests pin down: a `VecEnv` over N
//! identically-seeded environments produces *exactly* the trajectories of
//! N sequential single-env loops — vectorisation (and threading) is a
//! pure performance transform, never a semantics change.  Auto-reset
//! follows the standard vector-env convention: when a lane finishes, the
//! returned observation is the *first observation of the next episode*.
//!
//! Lanes may run **different environments** ([`VecEnv::from_envs`], the
//! scenario-mixture constructor): observations are padded to the widest
//! lane and [`BatchedExecutor::lane_specs`] describes the layout — see
//! the [`crate::coordinator::pool`] module docs.

use crate::coordinator::pool::{BatchedExecutor, LaneSpec};
use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};

/// A batch of environments with auto-reset, stepped sequentially.
pub struct VecEnv<E: Env> {
    envs: Vec<E>,
    specs: Vec<LaneSpec>,
    padded: usize,
}

impl<E: Env> VecEnv<E> {
    /// Build a homogeneous batch from a factory; lane `i` is seeded
    /// `base_seed + i`.
    pub fn new(n: usize, base_seed: u64, factory: impl Fn() -> E) -> VecEnv<E> {
        assert!(n > 0);
        let envs: Vec<E> = (0..n).map(|_| factory()).collect();
        VecEnv::from_envs(envs, base_seed)
    }

    /// Build from an explicit lane-ordered env list — the
    /// scenario-mixture constructor.  Lane `i` runs `envs[i]` seeded
    /// `base_seed + i`; observations are padded to the widest lane with
    /// zeroed tails.  Lane labels come from [`Env::id`]; use
    /// [`VecEnv::from_labeled_envs`] to keep registry ids.
    pub fn from_envs(envs: Vec<E>, base_seed: u64) -> VecEnv<E> {
        let ids = crate::coordinator::pool::own_ids(&envs);
        VecEnv::from_labeled_envs(ids, envs, base_seed)
    }

    /// [`VecEnv::from_envs`] with explicit lane labels (`ids[i]` names
    /// lane `i` in [`BatchedExecutor::lane_specs`]).
    pub fn from_labeled_envs(ids: Vec<String>, mut envs: Vec<E>, base_seed: u64) -> VecEnv<E> {
        assert!(!envs.is_empty());
        for (i, env) in envs.iter_mut().enumerate() {
            env.seed(base_seed + i as u64);
        }
        let (specs, padded) = crate::coordinator::pool::lane_layout(&envs, &ids);
        VecEnv {
            envs,
            specs,
            padded,
        }
    }

    pub fn len(&self) -> usize {
        self.envs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.envs.is_empty()
    }

    /// Padded per-lane observation length (the widest lane's `obs_dim`).
    pub fn obs_dim(&self) -> usize {
        self.padded
    }

    /// Lane 0's action space (the shared space of a homogeneous batch).
    pub fn action_space(&self) -> Space {
        self.envs[0].action_space()
    }

    /// Reset every lane; `obs` is `[n * obs_dim]`.
    pub fn reset_into(&mut self, obs: &mut [f32]) {
        let d = self.padded;
        for (i, env) in self.envs.iter_mut().enumerate() {
            let slot = &mut obs[i * d..(i + 1) * d];
            let (lane_obs, tail) = slot.split_at_mut(self.specs[i].obs_dim);
            env.reset_into(lane_obs);
            tail.fill(0.0);
        }
    }

    /// Step every lane with its action; finished lanes auto-reset (their
    /// transition reports the episode end, their obs the new episode).
    pub fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.envs.len());
        assert_eq!(transitions.len(), self.envs.len());
        let d = self.padded;
        for (i, env) in self.envs.iter_mut().enumerate() {
            let slot = &mut obs[i * d..(i + 1) * d];
            let (lane_obs, tail) = slot.split_at_mut(self.specs[i].obs_dim);
            let t = env.step_into(&actions[i], lane_obs);
            transitions[i] = t;
            if t.done || t.truncated {
                env.reset_into(lane_obs);
            }
            tail.fill(0.0);
        }
    }

    /// Direct lane access.
    pub fn lane(&mut self, i: usize) -> &mut E {
        &mut self.envs[i]
    }
}

// The sequential reference implementation of the executor interface:
// `EnvPool` (sync) must reproduce these trajectories bit-for-bit.
impl<E: Env> BatchedExecutor for VecEnv<E> {
    fn num_lanes(&self) -> usize {
        self.len()
    }

    fn obs_dim(&self) -> usize {
        VecEnv::obs_dim(self)
    }

    fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    fn action_space(&self) -> Space {
        VecEnv::action_space(self)
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        VecEnv::reset_into(self, obs)
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        VecEnv::step_into(self, actions, obs, transitions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{CartPole, MountainCar};
    use crate::wrappers::TimeLimit;

    #[test]
    fn vec_env_matches_sequential_loops() {
        let n = 4;
        let mut vec_env = VecEnv::new(n, 100, || TimeLimit::new(CartPole::new(), 50));
        let mut obs = vec![0.0f32; n * 4];
        vec_env.reset_into(&mut obs);

        // Reference: n independent envs with the same seeds.
        let mut singles: Vec<_> = (0..n)
            .map(|i| {
                let mut e = TimeLimit::new(CartPole::new(), 50);
                e.seed(100 + i as u64);
                let mut o = vec![0.0f32; 4];
                e.reset_into(&mut o);
                (e, o)
            })
            .collect();
        for (i, (_, o)) in singles.iter().enumerate() {
            assert_eq!(&obs[i * 4..(i + 1) * 4], &o[..]);
        }

        // Fixed action pattern; trajectories must agree lane-for-lane.
        let mut transitions = vec![Transition::default(); n];
        for step in 0..120 {
            let actions: Vec<Action> =
                (0..n).map(|i| Action::Discrete((step + i) % 2)).collect();
            vec_env.step_into(&actions, &mut obs, &mut transitions);
            for (i, (env, o)) in singles.iter_mut().enumerate() {
                let t = env.step_into(&actions[i], o);
                assert_eq!(transitions[i], t, "lane {i} step {step}");
                if t.done || t.truncated {
                    env.reset_into(o);
                }
                assert_eq!(&obs[i * 4..(i + 1) * 4], &o[..], "lane {i} step {step}");
            }
        }
    }

    #[test]
    fn auto_reset_reports_episode_end_once() {
        let mut vec_env = VecEnv::new(1, 0, || TimeLimit::new(CartPole::new(), 5));
        let mut obs = vec![0.0f32; 4];
        let mut tr = vec![Transition::default(); 1];
        vec_env.reset_into(&mut obs);
        let mut ends = 0;
        for _ in 0..20 {
            vec_env.step_into(&[Action::Discrete(0)], &mut obs, &mut tr);
            if tr[0].done || tr[0].truncated {
                ends += 1;
            }
        }
        assert!(ends >= 3, "5-step limit over 20 steps: {ends}");
    }

    #[test]
    fn mixture_lanes_pad_to_the_widest_and_zero_the_tail() {
        // CartPole (4) + MountainCar (2): padded width 4.
        let envs: Vec<crate::core::env::DynEnv> = vec![
            Box::new(TimeLimit::new(CartPole::new(), 50)),
            Box::new(TimeLimit::new(MountainCar::new(), 50)),
        ];
        let mut v = VecEnv::from_envs(envs, 7);
        assert_eq!(v.obs_dim(), 4);
        let specs = BatchedExecutor::lane_specs(&v).to_vec();
        // Unlabeled construction falls back to the envs' own (wrapper
        // composed) ids; registry mixtures use `from_labeled_envs`.
        assert_eq!(specs[0].env_id, "TimeLimit(CartPole-v1, 50)");
        assert_eq!(specs[1].obs_dim, 2);
        assert_eq!(specs[1].offset, 4);

        // The mixture lane must match a lone MountainCar seeded 7 + 1.
        let mut single = TimeLimit::new(MountainCar::new(), 50);
        single.seed(8);
        let mut obs = vec![f32::NAN; 2 * 4];
        let mut single_obs = vec![0.0f32; 2];
        let mut tr = vec![Transition::default(); 2];
        v.reset_into(&mut obs);
        single.reset_into(&mut single_obs);
        assert_eq!(&obs[4..6], &single_obs[..]);
        assert_eq!(&obs[6..8], &[0.0, 0.0]);
        for step in 0..120 {
            let actions = [Action::Discrete(step % 2), Action::Discrete(step % 3)];
            v.step_into(&actions, &mut obs, &mut tr);
            let t = single.step_into(&actions[1], &mut single_obs);
            if t.done || t.truncated {
                single.reset_into(&mut single_obs);
            }
            assert_eq!(tr[1], t, "step {step}");
            assert_eq!(&obs[4..6], &single_obs[..], "step {step}");
            assert_eq!(&obs[6..8], &[0.0, 0.0], "step {step}: tail must stay zero");
        }
    }
}
