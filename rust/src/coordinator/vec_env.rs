//! Vectorised environment execution: N environments stepped as one
//! batch, sequentially — the bit-exact reference the threaded pools are
//! tested against.
//!
//! The invariant the property tests pin down: a `VecEnv` over N
//! identically-seeded environments produces *exactly* the trajectories of
//! N sequential single-env loops — vectorisation (and threading) is a
//! pure performance transform, never a semantics change.  Auto-reset
//! follows the standard vector-env convention: when a lane finishes, the
//! returned observation is the *first observation of the next episode*.
//!
//! Lanes may run **different environments** ([`VecEnv::from_envs`], the
//! scenario-mixture constructor): observations are padded to the widest
//! lane and [`BatchedExecutor::lane_specs`] describes the layout — see
//! the [`crate::coordinator::pool`] module docs.
//!
//! Internally the lanes are [`BatchEnv`](crate::core::batch::BatchEnv)
//! groups: the generic constructors wrap the env list in one
//! [`ScalarBatch`] (the historical per-lane loop, bit for bit), while
//! [`VecEnv::from_groups`] steps fused SoA kernels — one `step_batch`
//! call per homogeneous group instead of per-lane virtual dispatch (see
//! [`crate::core::batch`]).

use crate::coordinator::pool::{
    materialize_groups, BatchedExecutor, BuiltGroup, LaneGroupSpec, LaneSpec,
};
use crate::core::batch::{BatchEnv, ScalarBatch};
use crate::core::env::{DynEnv, Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::telemetry::trace::{self, SpanKind, SpanRecord};
use crate::telemetry::ExecMetrics;

/// The lane storage behind a [`VecEnv`]: one scalar group (generic
/// constructors, with direct lane access) or a fused group list.
enum Kernel<E: Env> {
    Scalar(ScalarBatch<E>),
    Groups(Vec<BuiltGroup>),
}

/// A batch of environments with auto-reset, stepped sequentially.
pub struct VecEnv<E: Env> {
    kernel: Kernel<E>,
    specs: Vec<LaneSpec>,
    padded: usize,
    n: usize,
    metrics: ExecMetrics,
    /// Trace id minted lazily on the first traced batch (0 until then).
    trace_id: u64,
}

impl<E: Env> VecEnv<E> {
    /// Build a homogeneous batch from a factory; lane `i` is seeded
    /// `base_seed + i`.
    pub fn new(n: usize, base_seed: u64, factory: impl Fn() -> E) -> VecEnv<E> {
        assert!(n > 0);
        let envs: Vec<E> = (0..n).map(|_| factory()).collect();
        VecEnv::from_envs(envs, base_seed)
    }

    /// Build from an explicit lane-ordered env list — the
    /// scenario-mixture constructor.  Lane `i` runs `envs[i]` seeded
    /// `base_seed + i`; observations are padded to the widest lane with
    /// zeroed tails.  Lane labels come from [`Env::id`]; use
    /// [`VecEnv::from_labeled_envs`] to keep registry ids.
    pub fn from_envs(envs: Vec<E>, base_seed: u64) -> VecEnv<E> {
        let ids = crate::coordinator::pool::own_ids(&envs);
        VecEnv::from_labeled_envs(ids, envs, base_seed)
    }

    /// [`VecEnv::from_envs`] with explicit lane labels (`ids[i]` names
    /// lane `i` in [`BatchedExecutor::lane_specs`]).
    pub fn from_labeled_envs(ids: Vec<String>, mut envs: Vec<E>, base_seed: u64) -> VecEnv<E> {
        assert!(!envs.is_empty());
        for (i, env) in envs.iter_mut().enumerate() {
            env.seed(base_seed + i as u64);
        }
        let (specs, padded) = crate::coordinator::pool::lane_layout(&envs, &ids);
        let n = envs.len();
        VecEnv {
            kernel: Kernel::Scalar(ScalarBatch::from_envs(envs)),
            specs,
            padded,
            n,
            metrics: ExecMetrics::for_executor("vec"),
            trace_id: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Padded per-lane observation length (the widest lane's `obs_dim`).
    pub fn obs_dim(&self) -> usize {
        self.padded
    }

    /// Lane 0's action space (the shared space of a homogeneous batch).
    pub fn action_space(&self) -> Space {
        self.specs[0].action_space.clone()
    }

    /// This executor's trace id, minted on first use while tracing is
    /// enabled; `0` while tracing is off (one load + branch).
    fn ensure_trace_id(&mut self) -> u64 {
        if !trace::enabled() {
            return 0;
        }
        if self.trace_id == 0 {
            self.trace_id = trace::new_trace_id();
        }
        self.trace_id
    }

    /// Reset every lane; `obs` is `[n * obs_dim]`.
    pub fn reset_into(&mut self, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.n * self.padded);
        let trace_id = self.ensure_trace_id();
        let t0 = if trace_id != 0 { trace::now_ns() } else { 0 };
        let d = self.padded;
        match &mut self.kernel {
            Kernel::Scalar(batch) => batch.reset_batch(obs, d),
            Kernel::Groups(groups) => {
                for group in groups {
                    let lanes = group.batch.lanes();
                    let start = group.lane_start * d;
                    group.batch.reset_batch(&mut obs[start..start + lanes * d], d);
                }
            }
        }
        if trace_id != 0 {
            trace::record(SpanRecord {
                span_id: trace::next_span_id(),
                parent: 0,
                trace_id,
                t_start_ns: t0,
                t_end_ns: trace::now_ns(),
                lane_group: self.n as u32,
                shard: trace::SHARD_LOCAL,
                kind: SpanKind::Reset,
            });
        }
    }

    /// Step every lane with its action; finished lanes auto-reset (their
    /// transition reports the episode end, their obs the new episode).
    pub fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.n);
        assert_eq!(obs.len(), self.n * self.padded);
        assert_eq!(transitions.len(), self.n);
        let trace_id = self.ensure_trace_id();
        let batch_span = if trace_id != 0 { trace::next_span_id() } else { 0 };
        let timed = trace_id != 0 || crate::telemetry::enabled();
        let t_batch = if timed { trace::now_ns() } else { 0 };
        let d = self.padded;
        let shard = trace::SHARD_LOCAL;
        match &mut self.kernel {
            Kernel::Scalar(batch) => {
                trace::with_span(SpanKind::Kernel, trace_id, batch_span, 0, shard, || {
                    batch.step_batch(actions, obs, d, transitions)
                });
            }
            Kernel::Groups(groups) => {
                for group in groups {
                    let lanes = group.batch.lanes();
                    let (first, start) = (group.lane_start, group.lane_start * d);
                    let lg = first as u32;
                    trace::with_span(SpanKind::Kernel, trace_id, batch_span, lg, shard, || {
                        group.batch.step_batch(
                            &actions[first..first + lanes],
                            &mut obs[start..start + lanes * d],
                            d,
                            &mut transitions[first..first + lanes],
                        )
                    });
                }
            }
        }
        let ends = transitions.iter().filter(|t| t.done || t.truncated).count();
        if timed {
            let t_end = trace::now_ns();
            if batch_span != 0 {
                trace::record(SpanRecord {
                    span_id: batch_span,
                    parent: 0,
                    trace_id,
                    t_start_ns: t_batch,
                    t_end_ns: t_end,
                    lane_group: self.n as u32,
                    shard,
                    kind: SpanKind::Batch,
                });
            }
            self.metrics.record_batch_timed(self.n, ends, t_batch, t_end);
        } else {
            self.metrics.record_batch(self.n, ends);
        }
    }

    /// Direct lane access (scalar-built batches only; a group-fused
    /// `VecEnv` has no per-lane `Env` values and panics here).
    pub fn lane(&mut self, i: usize) -> &mut E {
        match &mut self.kernel {
            Kernel::Scalar(batch) => batch.lane_mut(i),
            Kernel::Groups(_) => {
                panic!("VecEnv::lane is not available on a group-fused batch")
            }
        }
    }
}

impl VecEnv<DynEnv> {
    /// Build from a lane-group plan — the fused-kernel constructor
    /// ([`EnvPool::from_groups`](crate::coordinator::pool::EnvPool::from_groups)
    /// semantics, sequential).  Groups occupy contiguous lanes in plan
    /// order, lane `i` seeded `base_seed + i`.
    pub fn from_groups(groups: Vec<LaneGroupSpec>, base_seed: u64) -> VecEnv<DynEnv> {
        let n: usize = groups.iter().map(|g| g.lanes()).sum();
        assert!(n > 0);
        let (built, specs, padded) = materialize_groups(groups, base_seed, n);
        VecEnv {
            kernel: Kernel::Groups(built),
            specs,
            padded,
            n,
            metrics: ExecMetrics::for_executor("vec"),
            trace_id: 0,
        }
    }
}

// The sequential reference implementation of the executor interface:
// `EnvPool` (sync) must reproduce these trajectories bit-for-bit.
impl<E: Env> BatchedExecutor for VecEnv<E> {
    fn num_lanes(&self) -> usize {
        self.len()
    }

    fn obs_dim(&self) -> usize {
        VecEnv::obs_dim(self)
    }

    fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    fn action_space(&self) -> Space {
        VecEnv::action_space(self)
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        VecEnv::reset_into(self, obs)
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        VecEnv::step_into(self, actions, obs, transitions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{CartPole, MountainCar};
    use crate::wrappers::TimeLimit;

    #[test]
    fn vec_env_matches_sequential_loops() {
        let n = 4;
        let mut vec_env = VecEnv::new(n, 100, || TimeLimit::new(CartPole::new(), 50));
        let mut obs = vec![0.0f32; n * 4];
        vec_env.reset_into(&mut obs);

        // Reference: n independent envs with the same seeds.
        let mut singles: Vec<_> = (0..n)
            .map(|i| {
                let mut e = TimeLimit::new(CartPole::new(), 50);
                e.seed(100 + i as u64);
                let mut o = vec![0.0f32; 4];
                e.reset_into(&mut o);
                (e, o)
            })
            .collect();
        for (i, (_, o)) in singles.iter().enumerate() {
            assert_eq!(&obs[i * 4..(i + 1) * 4], &o[..]);
        }

        // Fixed action pattern; trajectories must agree lane-for-lane.
        let mut transitions = vec![Transition::default(); n];
        for step in 0..120 {
            let actions: Vec<Action> =
                (0..n).map(|i| Action::Discrete((step + i) % 2)).collect();
            vec_env.step_into(&actions, &mut obs, &mut transitions);
            for (i, (env, o)) in singles.iter_mut().enumerate() {
                let t = env.step_into(&actions[i], o);
                assert_eq!(transitions[i], t, "lane {i} step {step}");
                if t.done || t.truncated {
                    env.reset_into(o);
                }
                assert_eq!(&obs[i * 4..(i + 1) * 4], &o[..], "lane {i} step {step}");
            }
        }
    }

    #[test]
    fn auto_reset_reports_episode_end_once() {
        let mut vec_env = VecEnv::new(1, 0, || TimeLimit::new(CartPole::new(), 5));
        let mut obs = vec![0.0f32; 4];
        let mut tr = vec![Transition::default(); 1];
        vec_env.reset_into(&mut obs);
        let mut ends = 0;
        for _ in 0..20 {
            vec_env.step_into(&[Action::Discrete(0)], &mut obs, &mut tr);
            if tr[0].done || tr[0].truncated {
                ends += 1;
            }
        }
        assert!(ends >= 3, "5-step limit over 20 steps: {ends}");
    }

    #[test]
    fn mixture_lanes_pad_to_the_widest_and_zero_the_tail() {
        // CartPole (4) + MountainCar (2): padded width 4.
        let envs: Vec<crate::core::env::DynEnv> = vec![
            Box::new(TimeLimit::new(CartPole::new(), 50)),
            Box::new(TimeLimit::new(MountainCar::new(), 50)),
        ];
        let mut v = VecEnv::from_envs(envs, 7);
        assert_eq!(v.obs_dim(), 4);
        let specs = BatchedExecutor::lane_specs(&v).to_vec();
        // Unlabeled construction falls back to the envs' own (wrapper
        // composed) ids; registry mixtures use `from_labeled_envs`.
        assert_eq!(specs[0].env_id, "TimeLimit(CartPole-v1, 50)");
        assert_eq!(specs[1].obs_dim, 2);
        assert_eq!(specs[1].offset, 4);

        // The mixture lane must match a lone MountainCar seeded 7 + 1.
        let mut single = TimeLimit::new(MountainCar::new(), 50);
        single.seed(8);
        let mut obs = vec![f32::NAN; 2 * 4];
        let mut single_obs = vec![0.0f32; 2];
        let mut tr = vec![Transition::default(); 2];
        v.reset_into(&mut obs);
        single.reset_into(&mut single_obs);
        assert_eq!(&obs[4..6], &single_obs[..]);
        assert_eq!(&obs[6..8], &[0.0, 0.0]);
        for step in 0..120 {
            let actions = [Action::Discrete(step % 2), Action::Discrete(step % 3)];
            v.step_into(&actions, &mut obs, &mut tr);
            let t = single.step_into(&actions[1], &mut single_obs);
            if t.done || t.truncated {
                single.reset_into(&mut single_obs);
            }
            assert_eq!(tr[1], t, "step {step}");
            assert_eq!(&obs[4..6], &single_obs[..], "step {step}");
            assert_eq!(&obs[6..8], &[0.0, 0.0], "step {step}: tail must stay zero");
        }
    }

    #[test]
    fn from_groups_matches_scalar_construction_bitwise() {
        use crate::core::batch::DynBatchEnv;
        // Two groups: fused CartPole lanes + scalar MountainCar lanes —
        // the mixed fused/fallback shape the executors build.
        let groups = || {
            vec![
                LaneGroupSpec::new("CartPole-v1", 2, |lanes| -> DynBatchEnv {
                    Box::new(CartPole::batch(lanes, Some(30)))
                }),
                LaneGroupSpec::new("MountainCar-v0", 1, |lanes| -> DynBatchEnv {
                    let envs: Vec<crate::core::env::DynEnv> = (0..lanes)
                        .map(|_| {
                            Box::new(TimeLimit::new(MountainCar::new(), 30))
                                as crate::core::env::DynEnv
                        })
                        .collect();
                    Box::new(crate::core::batch::ScalarBatch::from_envs(envs))
                }),
            ]
        };
        let scalar_envs: Vec<crate::core::env::DynEnv> = vec![
            Box::new(TimeLimit::new(CartPole::new(), 30)),
            Box::new(TimeLimit::new(CartPole::new(), 30)),
            Box::new(TimeLimit::new(MountainCar::new(), 30)),
        ];
        let mut reference = VecEnv::from_envs(scalar_envs, 21);
        let mut fused = VecEnv::from_groups(groups(), 21);
        assert_eq!(fused.num_lanes(), 3);
        assert_eq!(fused.obs_dim(), 4);
        assert_eq!(fused.lane_specs()[0].env_id, "CartPole-v1");
        assert_eq!(fused.lane_specs()[2].obs_dim, 2);
        let mut obs_a = vec![f32::NAN; 3 * 4];
        let mut obs_b = vec![f32::NAN; 3 * 4];
        let mut tr_a = vec![Transition::default(); 3];
        let mut tr_b = vec![Transition::default(); 3];
        reference.reset_into(&mut obs_a);
        fused.reset_into(&mut obs_b);
        assert_eq!(obs_a, obs_b);
        for step in 0..100 {
            let actions: Vec<Action> =
                (0..3).map(|i| Action::Discrete((step + i) % 2)).collect();
            reference.step_into(&actions, &mut obs_a, &mut tr_a);
            fused.step_into(&actions, &mut obs_b, &mut tr_b);
            assert_eq!(tr_a, tr_b, "step {step}");
            assert_eq!(obs_a, obs_b, "step {step}");
        }
    }
}
