//! Vectorised environment execution: N environments stepped as one
//! batch, sequentially or across worker threads.
//!
//! The invariant the property tests pin down: a `VecEnv` over N
//! identically-seeded environments produces *exactly* the trajectories of
//! N sequential single-env loops — vectorisation (and threading) is a
//! pure performance transform, never a semantics change.  Auto-reset
//! follows the standard vector-env convention: when a lane finishes, the
//! returned observation is the *first observation of the next episode*.

use crate::coordinator::pool::{BatchedExecutor, EnvPool};
use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};

/// A batch of homogeneous environments with auto-reset.
pub struct VecEnv<E: Env> {
    envs: Vec<E>,
    obs_dim: usize,
}

impl<E: Env> VecEnv<E> {
    /// Build from a factory; lane `i` is seeded `base_seed + i`.
    pub fn new(n: usize, base_seed: u64, factory: impl Fn() -> E) -> VecEnv<E> {
        assert!(n > 0);
        let mut envs: Vec<E> = (0..n).map(|_| factory()).collect();
        for (i, env) in envs.iter_mut().enumerate() {
            env.seed(base_seed + i as u64);
        }
        let obs_dim = envs[0].obs_dim();
        VecEnv { envs, obs_dim }
    }

    pub fn len(&self) -> usize {
        self.envs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.envs.is_empty()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn action_space(&self) -> Space {
        self.envs[0].action_space()
    }

    /// Reset every lane; `obs` is `[n * obs_dim]`.
    pub fn reset_into(&mut self, obs: &mut [f32]) {
        let d = self.obs_dim;
        for (i, env) in self.envs.iter_mut().enumerate() {
            env.reset_into(&mut obs[i * d..(i + 1) * d]);
        }
    }

    /// Step every lane with its action; finished lanes auto-reset (their
    /// transition reports the episode end, their obs the new episode).
    pub fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.envs.len());
        assert_eq!(transitions.len(), self.envs.len());
        let d = self.obs_dim;
        for (i, env) in self.envs.iter_mut().enumerate() {
            let lane_obs = &mut obs[i * d..(i + 1) * d];
            let t = env.step_into(&actions[i], lane_obs);
            transitions[i] = t;
            if t.done || t.truncated {
                env.reset_into(lane_obs);
            }
        }
    }

    /// Direct lane access.
    pub fn lane(&mut self, i: usize) -> &mut E {
        &mut self.envs[i]
    }
}

// The sequential reference implementation of the executor interface:
// `EnvPool` (sync) must reproduce these trajectories bit-for-bit.
impl<E: Env> BatchedExecutor for VecEnv<E> {
    fn num_lanes(&self) -> usize {
        self.len()
    }

    fn obs_dim(&self) -> usize {
        VecEnv::obs_dim(self)
    }

    fn action_space(&self) -> Space {
        VecEnv::action_space(self)
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        VecEnv::reset_into(self, obs)
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        VecEnv::step_into(self, actions, obs, transitions)
    }
}

/// Step a workload of `total_steps` random-action steps across `threads`
/// persistent workers, one lane per worker (the throughput mode behind
/// the Fig.-1 aggregate numbers).  Returns total steps actually executed.
///
/// Since the executor refactor this runs on [`EnvPool`]'s worker-side
/// bulk rollout ([`EnvPool::random_rollout`]): workers are persistent,
/// but the loop itself is free-running — one barrier for the whole
/// workload, not one per step — so the per-step cost matches the
/// throwaway-thread implementation this replaced while the pool stays
/// reusable.  Lane seeding (`base_seed + lane`) and the per-lane action
/// streams match the old behaviour exactly.
pub fn parallel_random_steps<E, F>(
    threads: usize,
    total_steps: u64,
    base_seed: u64,
    factory: F,
) -> u64
where
    E: Env + Send + 'static,
    F: FnMut() -> E,
{
    assert!(threads > 0);
    let per_lane = total_steps / threads as u64;
    let mut pool = EnvPool::new(threads, base_seed, threads, factory);
    pool.random_rollout(per_lane)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::CartPole;
    use crate::wrappers::TimeLimit;

    #[test]
    fn vec_env_matches_sequential_loops() {
        let n = 4;
        let mut vec_env = VecEnv::new(n, 100, || TimeLimit::new(CartPole::new(), 50));
        let mut obs = vec![0.0f32; n * 4];
        vec_env.reset_into(&mut obs);

        // Reference: n independent envs with the same seeds.
        let mut singles: Vec<_> = (0..n)
            .map(|i| {
                let mut e = TimeLimit::new(CartPole::new(), 50);
                e.seed(100 + i as u64);
                let mut o = vec![0.0f32; 4];
                e.reset_into(&mut o);
                (e, o)
            })
            .collect();
        for (i, (_, o)) in singles.iter().enumerate() {
            assert_eq!(&obs[i * 4..(i + 1) * 4], &o[..]);
        }

        // Fixed action pattern; trajectories must agree lane-for-lane.
        let mut transitions = vec![Transition::default(); n];
        for step in 0..120 {
            let actions: Vec<Action> =
                (0..n).map(|i| Action::Discrete((step + i) % 2)).collect();
            vec_env.step_into(&actions, &mut obs, &mut transitions);
            for (i, (env, o)) in singles.iter_mut().enumerate() {
                let t = env.step_into(&actions[i], o);
                assert_eq!(transitions[i], t, "lane {i} step {step}");
                if t.done || t.truncated {
                    env.reset_into(o);
                }
                assert_eq!(&obs[i * 4..(i + 1) * 4], &o[..], "lane {i} step {step}");
            }
        }
    }

    #[test]
    fn auto_reset_reports_episode_end_once() {
        let mut vec_env = VecEnv::new(1, 0, || TimeLimit::new(CartPole::new(), 5));
        let mut obs = vec![0.0f32; 4];
        let mut tr = vec![Transition::default(); 1];
        vec_env.reset_into(&mut obs);
        let mut ends = 0;
        for _ in 0..20 {
            vec_env.step_into(&[Action::Discrete(0)], &mut obs, &mut tr);
            if tr[0].done || tr[0].truncated {
                ends += 1;
            }
        }
        assert!(ends >= 3, "5-step limit over 20 steps: {ends}");
    }

    #[test]
    fn parallel_steps_complete() {
        let total = parallel_random_steps(4, 40_000, 7, || {
            TimeLimit::new(CartPole::new(), 200)
        });
        assert_eq!(total, 40_000);
    }

    #[test]
    fn parallel_single_thread_equals_request() {
        let total =
            parallel_random_steps(1, 5_000, 3, || TimeLimit::new(CartPole::new(), 200));
        assert_eq!(total, 5_000);
    }
}
