//! The `cairl` launcher: Gym-style toolkit operations from the command
//! line (paper §III: "improve setup, development, and execution times").
//!
//! Argument parsing is in-tree (the offline build has no clap); see
//! [`Args`] for the tiny flag grammar: `cairl <command> [--flag value]...`.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use cairl::agents::dqn::{DqnAgent, DqnConfig};
use cairl::coordinator::config::{DqnSettings, ExperimentConfig};
use cairl::coordinator::pool::PanicPolicy;
use cairl::coordinator::experiment::{
    build_executor_with_kernel, run_batched_workload, run_recorded_workload,
    run_stepping_workload, ExecutorKind, KernelMode, RenderMode, SteppingResult,
};
use cairl::coordinator::registry::{self, MixtureSpec};
use cairl::core::env::Env;
use cairl::core::rng::Pcg32;
use cairl::energy::EnergyTracker;
use cairl::envs::gridrts::{play_match, Bot, HarvestBot, MatchResult, RandomBot, RushBot};
use cairl::faults::ChaosProfile;
use cairl::render::Framebuffer;
use cairl::runtime::Runtime;
use cairl::shard::{shard_status, ServeConfig, ShardPoolOptions, ShardServer, ShardedEnvPool};
use cairl::telemetry::{
    self, prometheus_from_snapshot, replay_against, TapeHeader, TapeReader, TapeWriter,
};
use cairl::tooling::tournament::{swiss, GameOutcome};
use cairl::wrappers::{apply_wrappers, WrapperSpec};
use cairl::{list_envs, make};

/// Parsed command line: a subcommand plus `--key value` / `--switch`
/// flags.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let Some(key) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument {arg:?}");
            };
            // Boolean switch if next token is absent or another flag.
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { flags })
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }

    fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }
}

const USAGE: &str = "\
cairl — CaiRL: a high-performance RL environment toolkit (CoG 2022 reproduction)

USAGE: cairl <command> [flags]

COMMANDS:
  list-envs | envs [--json]       list every registered environment id;
                                  --json dumps the full registry (id,
                                  summary, kwarg defaults, wrapper chain,
                                  batch-capable flag) for experiment
                                  provenance
  run        --env SPEC --steps N --seed S [--render] [--ascii]
             [--executor vec|pool|pool-async --lanes N --threads T]
             [--kernel scalar|fused]
             [--shard ADDR[,ADDR...]] [--pipeline K] [--token T]
             [--read-timeout MS] [--write-timeout MS] [--heartbeat MS]
             [--chaos PROFILE]
             [--returns-log FILE] [--record FILE] [--metrics FILE]
             [--trace FILE]
             [--wrap \"TimeLimit(200),NormalizeObs\"]
             [--register-script NAME=FILE.mpy[,NAME=FILE.mpy...]]
             [--config FILE.json]
                                  random-action stepping workload + throughput;
                                  SPEC is a registry id (CartPole-v1), optionally
                                  parameterized with Gym-style kwargs
                                  (CartPole-v1?max_steps=200), or a scenario
                                  mixture with per-lane env ids
                                  (\"Script/MyEnv:8,CartPole-v1?max_steps=200:4\"
                                  — lane counts come from the spec, --lanes is
                                  ignored); lanes > 1 or a mixture runs the
                                  batched executor layer; --register-script
                                  loads MiniScript sources into the Script/
                                  namespace before SPEC is parsed, --wrap
                                  applies a declarative wrapper chain to every
                                  env/lane; --kernel flips the batched stepping
                                  path between fused SoA kernels (default) and
                                  per-lane scalar dispatch for A/B benching
                                  (bit-identical either way); FILE.json's
                                  \"executor\" and \"wrappers\" blocks set the
                                  matching defaults; --shard routes the batched
                                  workload through remote `cairl serve` shards
                                  (cost-aware lane placement, bit-identical to
                                  the local run of the same SPEC/seed even
                                  across shard failures — lost lanes replay
                                  deterministically after reconnect);
                                  --pipeline keeps up to K batches in flight
                                  per shard (default 1 = lockstep), --token
                                  authenticates against a --token'd daemon,
                                  --wrap is forwarded to every shard in the
                                  Hello handshake (applied server-side,
                                  bit-identical to the local run), and
                                  --returns-log writes every finished episode's
                                  return, one per line, for seed-parity diffs;
                                  --record captures the batched workload as a
                                  checksummed binary tape (byte-identical across
                                  executor kinds, thread counts, kernels and
                                  shard placements — see `cairl replay`),
                                  --metrics dumps the process's telemetry
                                  registry as Prometheus text after the run
                                  (written atomically: temp file + rename), and
                                  --trace records every batch's spans (dispatch,
                                  kernel, epilogue, shard encode/wire/decode/
                                  server step, reassembly) and writes Chrome
                                  trace_event JSON after the run — loads in
                                  Perfetto / chrome://tracing, summarized by
                                  `cairl trace --summarize FILE`; sharded runs
                                  stitch server-side spans into the client
                                  timeline (one trace id end to end), and
                                  returns stay byte-identical with tracing
                                  on or off;
                                  --read-timeout/--write-timeout bound every
                                  shard frame (MS, 0 = block forever) so a
                                  frozen shard fails over within the deadline
                                  instead of stalling, --heartbeat pings idle
                                  connections every MS, and --chaos injects
                                  deterministic wire faults client-side
                                  (PROFILE: off | light@SEED | heavy@SEED |
                                  corrupt=BP,truncate=BP,delay=BP,reset=BP,
                                  delay_ms=N@SEED — rates in basis points;
                                  returns stay bit-identical, see
                                  docs/OPERATIONS.md)
  replay     --tape FILE [--executor vec|pool|pool-async] [--threads T]
             [--kernel scalar|fused] [--shard ADDR[,ADDR...]] [--token T]
             [--register-script NAME=FILE.mpy[,...]]
                                  re-execute a tape recorded by `cairl run
                                  --record` against a freshly built executor
                                  (spec, lanes and base seed come from the tape
                                  header) and compare every transition bit for
                                  bit; prints the first divergent (batch, lane)
                                  and exits non-zero on mismatch — executor,
                                  thread and kernel knobs are free to differ
                                  from the recording run, which is the
                                  determinism-bisect workflow
  metrics    [--addr ADDR] [--token T]
                                  print telemetry as Prometheus text: with
                                  --addr, query a running `cairl serve` daemon
                                  (its --status JSON embeds a metrics snapshot);
                                  without, dump this process's registry
  trace      --summarize FILE     critical-path attribution for a trace written
                                  by `cairl run --trace`: per span kind, count,
                                  total time, share of batch latency and
                                  p50/p95/p99 durations, plus a coverage line
                                  reporting how much of batch latency the
                                  recorded child spans account for
  serve      --env SPEC --lanes N --listen ADDR
             [--executor vec|pool|pool-async] [--threads T]
             [--kernel scalar|fused] [--max-lanes N] [--token T]
             [--allow ADDR[,ADDR...]] [--read-timeout MS]
             [--chaos PROFILE] [--on-panic poison|quarantine]
             [--wrap \"TimeLimit(200),NormalizeObs\"]
  serve      --status ADDR [--token T]
                                  host a batched environment shard: one framed
                                  stream and one private executor per client on
                                  a unix:///path.sock or tcp://host:port
                                  listener; clients (cairl run --shard,
                                  ShardedEnvPool) may request any registered
                                  spec — --env is the default for bare Hellos;
                                  --max-lanes caps total lanes across clients
                                  (over-budget Hellos get a Busy backpressure
                                  reply), --token requires clients to present a
                                  shared secret, --allow admits only peers whose
                                  address starts with one of the given prefixes
                                  (TCP peers render as ip:port; unix sockets are
                                  always admitted — filesystem permissions scope
                                  those), --wrap applies a wrapper chain
                                  to every hosted lane by default (a client's
                                  non-empty Hello wrap overrides it);
                                  --status ADDR queries a running
                                  daemon and prints its JSON report (per-client
                                  lanes, pipeline depth, frames/sec, reconnects);
                                  --read-timeout reaps connections idle for MS
                                  (heartbeating clients stay warm),
                                  --chaos injects deterministic wire faults on
                                  every hosted connection (same PROFILE grammar
                                  as `run`), --on-panic quarantine survives a
                                  panicking env lane (zeroed obs, done=true,
                                  lane marked dead) instead of poisoning the
                                  executor (default: poison); SIGTERM drains
                                  gracefully — in-flight batches finish, new
                                  Hellos get Busy, then the daemon exits
  train      --env NAME [--seed S] [--max-steps N] [--config FILE.json]
                                  train DQN via the PJRT artifacts
                                  (NAME: cartpole|mountaincar|acrobot|pendulum|multitask)
  config     [--show-dqn]         print config defaults / the Table-I DQN block
  tournament [--rounds N] [--seed S]
                                  Swiss tournament between the GridRTS bots
  energy     --env ID --steps N [--render]
                                  energy/carbon for a stepping workload (Table II)
";

/// Honour `--returns-log FILE`: every finished episode's return, one
/// per line, in the workload's deterministic completion order — the
/// seed-parity artifact the CI shard-smoke job diffs between a sharded
/// and a local run.
/// Honour `--register-script NAME=FILE.mpy[,...]`: load MiniScript
/// sources into the `Script/` namespace before any spec is parsed, so
/// `run` and `replay` can reference Script/NAME ids.
fn register_scripts(args: &Args) -> Result<()> {
    let Some(scripts) = args.opt("register-script") else {
        return Ok(());
    };
    for part in scripts.split(',') {
        let part = part.trim();
        let Some((name, path)) = part.split_once('=') else {
            bail!("--register-script expects NAME=FILE.mpy, got {part:?}");
        };
        let path = path.trim();
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("--register-script {part:?}"))?;
        let id = registry::register_script(name.trim(), &src).map_err(|e| anyhow!("{e}"))?;
        eprintln!("registered {id} from {path}");
    }
    Ok(())
}

/// Honour `--metrics FILE`: dump the process telemetry registry as
/// Prometheus text after the workload, so batch jobs leave a scrapeable
/// artifact without running an exporter.  Written atomically (temp file
/// + rename) so a concurrent scraper never reads a torn file.
fn write_metrics_dump(args: &Args) -> Result<()> {
    let Some(path) = args.opt("metrics") else {
        return Ok(());
    };
    telemetry::trace::write_atomic(
        std::path::Path::new(path),
        telemetry::render_prometheus().as_bytes(),
    )
    .with_context(|| format!("--metrics {path:?}"))?;
    eprintln!("wrote telemetry snapshot to {path}");
    Ok(())
}

/// Honour `--trace FILE`: drain every span ring into Chrome
/// `trace_event` JSON after the workload (atomic write, like
/// `--metrics`).  Span recording itself is switched on at the top of
/// `run`, before any executor is built.
fn write_trace_dump(args: &Args) -> Result<()> {
    let Some(path) = args.opt("trace") else {
        return Ok(());
    };
    let spans = telemetry::trace::write_chrome_trace(std::path::Path::new(path))
        .with_context(|| format!("--trace {path:?}"))?;
    eprintln!("wrote {spans} spans to {path}");
    Ok(())
}

/// Honour a `--KEY MS` millisecond knob: absent or `0` = disabled.
fn ms_flag(args: &Args, key: &str) -> Result<Option<Duration>> {
    Ok(match args.u64(key, 0)? {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    })
}

/// Resolve the chaos profile for a run: `--chaos PROFILE` wins, then the
/// config file's `chaos` block; an `off` profile resolves to `None`.
fn chaos_profile(args: &Args, file_cfg: &ExperimentConfig) -> Result<Option<ChaosProfile>> {
    match args.opt("chaos") {
        Some(spec) => {
            let p = ChaosProfile::parse(spec).map_err(|e| anyhow!("{e}"))?;
            Ok(if p.is_off() { None } else { Some(p) })
        }
        None => file_cfg.chaos.to_profile().map_err(|e| anyhow!("{e}")),
    }
}

fn write_returns_log(args: &Args, r: &SteppingResult) -> Result<()> {
    let Some(path) = args.opt("returns-log") else {
        return Ok(());
    };
    let mut out = String::with_capacity(r.episode_returns.len() * 8);
    for ret in &r.episode_returns {
        out.push_str(&format!("{ret}\n"));
    }
    std::fs::write(path, out).with_context(|| format!("--returns-log {path:?}"))?;
    eprintln!("wrote {} episode returns to {path}", r.episode_returns.len());
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(command) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;

    match command.as_str() {
        "list-envs" | "envs" => {
            if args.flag("json") {
                // The registry as JSON — experiment provenance: capture
                // exactly which specs (kwargs, wrappers, batch kernels)
                // a run had available.
                println!("{}", registry::registry_json().render());
            } else {
                for (id, summary) in list_envs() {
                    println!("{id:<28} {summary}");
                }
            }
        }
        "run" => {
            // User scripts register first, so --env (and the config env
            // field) can reference Script/NAME ids without recompiling.
            register_scripts(&args)?;
            // Span recording goes live before any executor exists, so
            // the very first reset/batch is captured.
            if args.opt("trace").is_some() {
                telemetry::trace::set_enabled(true);
            }
            // --config seeds the defaults (env, seed, wrappers and the
            // executor block); explicit flags win.
            let file_cfg = match args.opt("config") {
                Some(path) => ExperimentConfig::load(std::path::Path::new(path))
                    .map_err(|e| anyhow!("{e}"))?,
                None => ExperimentConfig::default(),
            };
            let env_id = args.str("env", &file_cfg.env);
            let steps = args.u64("steps", 100_000)?;
            let seed = args.u64("seed", file_cfg.seed)?;
            let lanes =
                args.u64("lanes", file_cfg.executor.lanes as u64)?.max(1) as usize;
            let executor = args.str("executor", &file_cfg.executor.kind);
            let wrap_src = match args.opt("wrap") {
                Some(chain) => chain.to_string(),
                None => file_cfg.wrappers.join(","),
            };
            let wrap_chain =
                WrapperSpec::parse_chain(&wrap_src).map_err(|e| anyhow!("{e}"))?;
            let shard_list: Vec<String> = match args.opt("shard") {
                Some(spec) => spec
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect(),
                None => file_cfg.executor.shards.clone(),
            };
            // A mixture spec always takes the batched path: its per-lane
            // env ids are meaningless to the single-env loop.
            let mixture = MixtureSpec::is_mixture(&env_id);
            if shard_list.is_empty() && args.opt("chaos").is_some() {
                bail!(
                    "--chaos injects faults at the shard wire; add --shard ADDR \
                     (or run a daemon with `cairl serve --chaos`)"
                );
            }
            if !shard_list.is_empty() {
                // Sharded path: the workload runs against remote
                // `cairl serve` daemons; executor knobs are theirs.
                // --wrap travels in the Hello `wrap` field and is
                // applied server-side, so the chain behaves exactly as
                // it would locally.
                for flag in ["executor", "threads", "kernel"] {
                    if args.opt(flag).is_some() {
                        eprintln!(
                            "note: --{flag} applies to the serving side and is \
                             ignored by sharded runs"
                        );
                    }
                }
                let pipeline = args
                    .u64("pipeline", file_cfg.executor.pipeline as u64)?
                    .max(1) as usize;
                let token = args.str("token", &file_cfg.executor.shard_token);
                let wrap = wrap_chain
                    .iter()
                    .map(|w| w.render())
                    .collect::<Vec<_>>()
                    .join(",");
                let chaos = chaos_profile(&args, &file_cfg)?;
                if let Some(profile) = &chaos {
                    eprintln!(
                        "chaos active (client side): {} — reproduce with \
                         --chaos \"{}\"",
                        profile.render(),
                        profile.render()
                    );
                }
                let opts = ShardPoolOptions {
                    lanes,
                    base_seed: seed,
                    pipeline,
                    token,
                    wrap: wrap.clone(),
                    read_timeout: ms_flag(&args, "read-timeout")?,
                    write_timeout: ms_flag(&args, "write-timeout")?,
                    heartbeat: ms_flag(&args, "heartbeat")?,
                    chaos,
                    ..Default::default()
                };
                let mut exec = ShardedEnvPool::connect_opts(&shard_list, &env_id, opts)
                    .map_err(|e| anyhow!("{e}"))?;
                eprintln!("shard plan: {}", exec.plan().describe());
                let lanes = cairl::coordinator::pool::BatchedExecutor::num_lanes(&exec);
                let steps_per_lane = (steps / lanes as u64).max(1);
                let r = if let Some(path) = args.opt("record") {
                    // Recording drives the pool lockstep through the
                    // shared workload driver: the action stream is
                    // identical to the pipelined one (lockstep RNG), so
                    // the tape matches a local recording byte for byte.
                    if pipeline > 1 {
                        eprintln!("note: --record steps lockstep; --pipeline is ignored");
                    }
                    let header =
                        TapeHeader::for_executor(&exec, &env_id, &wrap, seed, steps_per_lane);
                    let mut w = TapeWriter::create(std::path::Path::new(path), &header)
                        .map_err(|e| anyhow!("{e}"))?;
                    let r = run_recorded_workload(&mut exec, steps_per_lane, seed, Some(&mut w))
                        .map_err(|e| anyhow!("{e}"))?;
                    let batches = w.finish().map_err(|e| anyhow!("{e}"))?;
                    eprintln!("recorded {batches} batches to {path}");
                    r
                } else {
                    exec.run_pipelined_workload(steps_per_lane, seed)
                };
                println!(
                    "{env_id} [{} shards x {lanes} lanes]: {} lane-steps, \
                     {} episodes, {:.3}s, {:.0} steps/s",
                    exec.shards(),
                    r.steps,
                    r.episodes,
                    r.elapsed.as_secs_f64(),
                    r.throughput
                );
                let reconnects: u64 = exec.reconnects().iter().sum();
                if reconnects > 0 {
                    eprintln!(
                        "shard failover: {reconnects} reconnect(s) across {} shard(s) \
                         (returns unaffected — lost lanes replayed deterministically)",
                        exec.shards()
                    );
                }
                write_returns_log(&args, &r)?;
            } else if lanes > 1 || executor != "vec" || mixture {
                // Batched path: flip executors without touching the workload.
                if args.flag("render") || args.flag("ascii") {
                    eprintln!(
                        "note: --render/--ascii apply to the single-env path and \
                         are ignored by the batched executor"
                    );
                }
                if mixture && args.opt("lanes").is_some() {
                    eprintln!(
                        "note: --lanes is ignored for mixture specs \
                         (lane counts come from the spec)"
                    );
                }
                let kind = ExecutorKind::parse(&executor).ok_or_else(|| {
                    anyhow!("unknown executor {executor:?} (vec | pool | pool-async)")
                })?;
                let kernel_name = args.str("kernel", &file_cfg.executor.kernel);
                let kernel = KernelMode::parse(&kernel_name).ok_or_else(|| {
                    anyhow!("unknown kernel {kernel_name:?} (scalar | fused)")
                })?;
                let threads =
                    match args.u64("threads", file_cfg.executor.threads as u64)? as usize
                    {
                        0 => std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                        t => t,
                    };
                let mut exec = build_executor_with_kernel(
                    &env_id,
                    kind,
                    lanes,
                    threads,
                    seed,
                    &wrap_chain,
                    kernel,
                )
                .map_err(|e| anyhow!("{e}"))?;
                let lanes = exec.num_lanes();
                let steps_per_lane = (steps / lanes as u64).max(1);
                let r = if let Some(path) = args.opt("record") {
                    let wrap = wrap_chain
                        .iter()
                        .map(|w| w.render())
                        .collect::<Vec<_>>()
                        .join(",");
                    let header = TapeHeader::for_executor(
                        exec.as_ref(),
                        &env_id,
                        &wrap,
                        seed,
                        steps_per_lane,
                    );
                    let mut w = TapeWriter::create(std::path::Path::new(path), &header)
                        .map_err(|e| anyhow!("{e}"))?;
                    let r =
                        run_recorded_workload(exec.as_mut(), steps_per_lane, seed, Some(&mut w))
                            .map_err(|e| anyhow!("{e}"))?;
                    let batches = w.finish().map_err(|e| anyhow!("{e}"))?;
                    eprintln!("recorded {batches} batches to {path}");
                    r
                } else {
                    run_batched_workload(exec.as_mut(), steps_per_lane, seed)
                };
                println!(
                    "{env_id} [{} x {lanes} lanes, {} kernel]: {} lane-steps, \
                     {} episodes, {:.3}s, {:.0} steps/s",
                    kind.label(),
                    kernel.label(),
                    r.steps,
                    r.episodes,
                    r.elapsed.as_secs_f64(),
                    r.throughput
                );
                write_returns_log(&args, &r)?;
            } else {
                if args.opt("record").is_some() {
                    bail!(
                        "--record captures batched workloads; add --lanes/--executor \
                         (or a mixture spec) to take the batched path"
                    );
                }
                let env = make(&env_id).map_err(|e| anyhow!("{e}"))?;
                let mut e = apply_wrappers(env, &wrap_chain);
                let mode = if args.flag("render") {
                    RenderMode::Software
                } else {
                    RenderMode::Console
                };
                let r = run_stepping_workload(&mut e, steps, seed, mode);
                println!(
                    "{env_id}: {} steps, {} episodes, {:.3}s, {:.0} steps/s",
                    r.steps,
                    r.episodes,
                    r.elapsed.as_secs_f64(),
                    r.throughput
                );
                write_returns_log(&args, &r)?;
                if args.flag("ascii") {
                    let mut fb = Framebuffer::standard();
                    e.render(&mut fb);
                    println!("{}", fb.to_ascii());
                }
            }
            write_metrics_dump(&args)?;
            write_trace_dump(&args)?;
        }
        "replay" => {
            register_scripts(&args)?;
            let Some(path) = args.opt("tape") else {
                bail!("replay needs --tape FILE (recorded by `cairl run --record`)");
            };
            let mut reader =
                TapeReader::open(std::path::Path::new(path)).map_err(|e| anyhow!("{e}"))?;
            let header = reader.header().clone();
            eprintln!(
                "tape {path}: {} [{} lanes, seed {}, {} steps/lane{}]",
                header.spec,
                header.lanes,
                header.base_seed,
                header.steps_per_lane,
                if header.wrap.is_empty() {
                    String::new()
                } else {
                    format!(", wrap {}", header.wrap)
                }
            );
            let shard_list: Vec<String> = match args.opt("shard") {
                Some(spec) => spec
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect(),
                None => Vec::new(),
            };
            let outcome = if !shard_list.is_empty() {
                let opts = ShardPoolOptions {
                    lanes: header.lanes,
                    base_seed: header.base_seed,
                    token: args.str("token", ""),
                    wrap: header.wrap.clone(),
                    ..Default::default()
                };
                let mut exec = ShardedEnvPool::connect_opts(&shard_list, &header.spec, opts)
                    .map_err(|e| anyhow!("{e}"))?;
                replay_against(&mut exec, &mut reader).map_err(|e| anyhow!("{e}"))?
            } else {
                let wrap_chain =
                    WrapperSpec::parse_chain(&header.wrap).map_err(|e| anyhow!("{e}"))?;
                let executor = args.str("executor", "pool");
                let kind = ExecutorKind::parse(&executor).ok_or_else(|| {
                    anyhow!("unknown executor {executor:?} (vec | pool | pool-async)")
                })?;
                let kernel_name = args.str("kernel", KernelMode::default().label());
                let kernel = KernelMode::parse(&kernel_name).ok_or_else(|| {
                    anyhow!("unknown kernel {kernel_name:?} (scalar | fused)")
                })?;
                let threads = match args.u64("threads", 0)? as usize {
                    0 => std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1),
                    t => t,
                };
                let mut exec = build_executor_with_kernel(
                    &header.spec,
                    kind,
                    header.lanes,
                    threads,
                    header.base_seed,
                    &wrap_chain,
                    kernel,
                )
                .map_err(|e| anyhow!("{e}"))?;
                replay_against(exec.as_mut(), &mut reader).map_err(|e| anyhow!("{e}"))?
            };
            match outcome.divergence {
                None => println!(
                    "replay OK: {} batches x {} lanes match bit for bit",
                    outcome.batches, outcome.lanes
                ),
                Some(d) => {
                    println!(
                        "replay DIVERGED at batch {} lane {}: tape {:?}, fresh run {:?}",
                        d.batch, d.lane, d.expected, d.actual
                    );
                    bail!("tape {path:?} does not replay bit-identically");
                }
            }
        }
        "trace" => {
            let Some(path) = args.opt("summarize") else {
                bail!("trace needs --summarize FILE (written by `cairl run --trace`)");
            };
            let spans = telemetry::trace::read_chrome_trace(std::path::Path::new(path))
                .map_err(|e| anyhow!("{e}"))?;
            print!("{}", telemetry::trace::summarize(&spans));
        }
        "metrics" => {
            match args.opt("addr") {
                Some(addr) => {
                    // Remote: the daemon's --status JSON embeds a
                    // telemetry snapshot; render it as Prometheus text.
                    let token = args.str("token", "");
                    let report = shard_status(addr, &token).map_err(|e| anyhow!("{e}"))?;
                    let doc =
                        cairl::core::json::parse(&report).map_err(|e| anyhow!("{e}"))?;
                    let snap = doc.get("metrics").ok_or_else(|| {
                        anyhow!("daemon status has no metrics block (pre-telemetry build?)")
                    })?;
                    print!("{}", prometheus_from_snapshot(snap));
                }
                None => print!("{}", telemetry::render_prometheus()),
            }
        }
        "serve" => {
            if let Some(addr) = args.opt("status") {
                // Query mode: ask a running daemon for its JSON report.
                let token = args.str("token", "");
                let report = shard_status(addr, &token).map_err(|e| anyhow!("{e}"))?;
                println!("{report}");
                return Ok(());
            }
            let env_spec = args.str("env", "CartPole-v1");
            let listen = args.str("listen", "unix:///tmp/cairl-shard.sock");
            let lanes = args.u64("lanes", 1)?.max(1) as usize;
            let threads = args.u64("threads", 0)? as usize;
            let max_lanes = args.u64("max-lanes", 0)? as usize;
            let token = args.str("token", "");
            let allow = args.str("allow", "");
            let wrap = args.str("wrap", "");
            let executor = args.str("executor", "pool");
            let kind = ExecutorKind::parse(&executor).ok_or_else(|| {
                anyhow!("unknown executor {executor:?} (vec | pool | pool-async)")
            })?;
            let kernel_name = args.str("kernel", KernelMode::default().label());
            let kernel = KernelMode::parse(&kernel_name).ok_or_else(|| {
                anyhow!("unknown kernel {kernel_name:?} (scalar | fused)")
            })?;
            let read_timeout = ms_flag(&args, "read-timeout")?;
            let chaos = match args.opt("chaos") {
                Some(spec) => {
                    let p = ChaosProfile::parse(spec).map_err(|e| anyhow!("{e}"))?;
                    if p.is_off() {
                        None
                    } else {
                        Some(p)
                    }
                }
                None => None,
            };
            let on_panic = match args.opt("on-panic") {
                Some(s) => PanicPolicy::parse(s).ok_or_else(|| {
                    anyhow!("unknown --on-panic {s:?} (poison | quarantine)")
                })?,
                None => PanicPolicy::Poison,
            };
            if let Some(profile) = &chaos {
                eprintln!(
                    "chaos active (server side): {} — reproduce with \
                     --chaos \"{}\"",
                    profile.render(),
                    profile.render()
                );
            }
            let server = ShardServer::bind(
                &listen,
                ServeConfig {
                    env_spec: env_spec.clone(),
                    kind,
                    lanes,
                    threads,
                    kernel,
                    max_lanes,
                    token,
                    allow,
                    wrap,
                    read_timeout,
                    chaos,
                    on_panic,
                },
            )
            .map_err(|e| anyhow!("{e}"))?;
            println!(
                "serving {env_spec} [{} x {lanes} lanes, {} kernel] on {}",
                kind.label(),
                kernel.label(),
                server.local_addr()
            );
            // Make the banner visible to pipes/supervisors before the
            // accept loop takes over for good.
            use std::io::Write as _;
            let _ = std::io::stdout().flush();
            server.run().map_err(|e| anyhow!("{e}"))?;
        }
        "train" => {
            let env = args.str("env", "cartpole");
            let seed = args.u64("seed", 0)?;
            let settings = match args.opt("config") {
                Some(path) => ExperimentConfig::load(std::path::Path::new(path))
                    .map_err(|e| anyhow!("{e}"))?
                    .dqn,
                None => DqnSettings::default(),
            };
            let mut cfg: DqnConfig = settings.to_config(seed);
            if let Some(ms) = args.opt("max-steps") {
                cfg.max_steps = ms.parse().context("--max-steps")?;
            }
            // Solve thresholds per env (paper: train "until mastering").
            let (env_id, solve_return): (&str, f32) = match env.as_str() {
                "cartpole" => ("CartPole-v1", 195.0),
                "mountaincar" => ("MountainCar-v0", -130.0),
                "acrobot" => ("Acrobot-v1", -100.0),
                "pendulum" => ("PendulumDiscrete-v1", -300.0),
                "multitask" => ("Flash/Multitask-v0", 800.0),
                other => bail!("unknown artifact env {other:?}"),
            };
            cfg.solve_return = solve_return;
            let mut rt =
                Runtime::from_default_artifacts().map_err(|e| anyhow!("{e}"))?;
            let mut agent =
                DqnAgent::new(&rt, &env, cfg).map_err(|e| anyhow!("{e}"))?;
            let mut environment = make(env_id).map_err(|e| anyhow!("{e}"))?;
            println!("training DQN on {env_id} (artifacts: dqn_*_{env})");
            let outcome = agent
                .train(&mut rt, &mut environment)
                .map_err(|e| anyhow!("{e}"))?;
            println!(
                "solved={} steps={} train_steps={} episodes={} wall={:.1}s mean_return={:.1}",
                outcome.solved,
                outcome.env_steps,
                outcome.train_steps,
                outcome.episodes,
                outcome.wall_time.as_secs_f64(),
                outcome.final_mean_return
            );
        }
        "config" => {
            if args.flag("show-dqn") {
                println!("Table I — DQN hyperparameters");
                for (k, v) in DqnSettings::default().table_one() {
                    println!("  {k:<22} {v}");
                }
            } else {
                println!("{}", ExperimentConfig::default().render());
            }
        }
        "tournament" => {
            let rounds = args.u64("rounds", 3)? as u32;
            let seed = args.u64("seed", 0)?;
            let mut bots: Vec<Box<dyn Bot>> = vec![
                Box::new(RushBot),
                Box::new(HarvestBot),
                Box::new(RandomBot(Pcg32::new(seed, 1))),
                Box::new(RandomBot(Pcg32::new(seed, 2))),
            ];
            let names: Vec<String> =
                bots.iter().map(|b| b.name().to_string()).collect();
            let mut rng = Pcg32::new(seed, 99);
            let standings = swiss(bots.len(), rounds, &mut rng, |a, b| {
                let result = {
                    // Split borrow: take the two bots out by index.
                    let (lo, hi) = (a.min(b), a.max(b));
                    let (left, right) = bots.split_at_mut(hi);
                    let (bot_lo, bot_hi) = (&mut left[lo], &mut right[0]);
                    if a < b {
                        play_match(bot_lo.as_mut(), bot_hi.as_mut())
                    } else {
                        play_match(bot_hi.as_mut(), bot_lo.as_mut())
                    }
                };
                match result {
                    MatchResult::Win(0) => GameOutcome::WinA,
                    MatchResult::Win(_) => GameOutcome::WinB,
                    MatchResult::Draw => GameOutcome::Draw,
                }
            });
            println!("Swiss tournament, {rounds} rounds:");
            for (rank, s) in standings.iter().enumerate() {
                println!(
                    "  {}. {:<10} {} pts ({} played)",
                    rank + 1,
                    names[s.player],
                    s.score,
                    s.played
                );
            }
        }
        "energy" => {
            let env_id = args.str("env", "CartPole-v1");
            let steps = args.u64("steps", 100_000)?;
            let mut e = make(&env_id).map_err(|e| anyhow!("{e}"))?;
            let mode = if args.flag("render") {
                RenderMode::SimulatedHardware
            } else {
                RenderMode::Console
            };
            let tracker = EnergyTracker::start_default(&env_id);
            run_stepping_workload(&mut e, steps, 0, mode);
            let report = tracker.stop();
            println!("{report}");
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command {other:?}");
        }
    }
    Ok(())
}
