//! Classic-control environments as MiniScript programs + the
//! [`ScriptEnv`] adapter exposing them through the standard [`Env`]
//! trait.
//!
//! These are the experiments' **AI Gym baseline**: the same dynamics as
//! the native envs, executed by the interpreted runner.  The scripts
//! follow the Gym sources line by line (f64 arithmetic, like CPython
//! floats — the native envs use f32, so cross-runner tests compare with
//! tolerance).
//!
//! Script protocol:
//! * globals `obs_dim`, `n_actions` must be defined at the top level;
//! * `reset()` returns a list of `obs_dim` floats;
//! * `step(action)` returns a list of `obs_dim + 2` floats:
//!   `[obs..., reward, done]`.

use crate::core::env::{Env, Transition};
use crate::core::error::{CairlError, Result};
use crate::core::spaces::{Action, Space};
use crate::render::{software, Framebuffer};
use crate::script::compile::CompiledProgram;
use crate::script::interp::{Interpreter, Value};
use std::sync::{Arc, RwLock};

/// How to paint this scripted env (reads interpreter globals).
#[derive(Clone, Copy, Debug)]
pub enum RenderHint {
    CartPole,
    MountainCar,
    Acrobot,
    Pendulum,
    None,
}

/// One validated version of a runtime-registered script: the source, the
/// protocol dims it declared, and its eagerly compiled bytecode.
///
/// `generation` increases by one on every successful
/// [`register_script`](crate::coordinator::registry::register_script)
/// call for the same id; live [`ScriptEnv`]s compare it against the
/// generation they were built from to detect a hot reload.
pub struct LoadedScript {
    pub src: String,
    pub stream: u64,
    pub obs_dim: usize,
    pub n_actions: usize,
    pub program: Arc<CompiledProgram>,
    pub generation: u64,
}

/// Shared, swappable handle to the current [`LoadedScript`] of one
/// registry id.  The registry holds one cell per `register_script` id;
/// every env built from that id holds a clone, so swapping the cell's
/// contents reaches all of them at their next `reset()`.
pub struct ScriptCell {
    inner: RwLock<Arc<LoadedScript>>,
}

impl ScriptCell {
    pub fn new(loaded: LoadedScript) -> ScriptCell {
        ScriptCell {
            inner: RwLock::new(Arc::new(loaded)),
        }
    }

    /// The current version (cheap: clones the inner `Arc`).
    pub fn snapshot(&self) -> Arc<LoadedScript> {
        Arc::clone(&self.inner.read().unwrap())
    }

    /// Install a new version; its `generation` is forced to the
    /// predecessor's plus one regardless of what the caller set.
    pub fn replace(&self, mut loaded: LoadedScript) {
        let mut slot = self.inner.write().unwrap();
        loaded.generation = slot.generation + 1;
        *slot = Arc::new(loaded);
    }
}

/// A MiniScript program running behind the [`Env`] trait — the paper's
/// "Python environment in the toolkit" path (§IV-B).
pub struct ScriptEnv {
    id: String,
    interp: Interpreter,
    obs_dim: usize,
    n_actions: usize,
    stream: u64,
    hint: RenderHint,
    /// Hot-reload handle (runtime-registered scripts only).
    cell: Option<Arc<ScriptCell>>,
    /// Generation of `cell` this interpreter was built from.
    generation: u64,
    /// Last seed passed to [`Env::seed`], replayed after a hot reload so
    /// the rebuilt interpreter stays on the env's seeded stream.
    last_seed: u64,
}

impl ScriptEnv {
    /// Load a script.  `stream` is the PCG stream id of the *native*
    /// counterpart env (reset-noise parity); pass any constant for
    /// script-only envs.  Panics on a malformed script (the built-in
    /// sources are compile-time constants); user-supplied sources go
    /// through [`ScriptEnv::try_load`] instead.
    pub fn load(id: &str, src: &str, stream: u64, hint: RenderHint) -> ScriptEnv {
        ScriptEnv::try_load(id, src, stream, hint).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Non-panicking [`ScriptEnv::load`] — the path
    /// [`register_script`](crate::coordinator::registry::register_script)
    /// takes for runtime-registered sources, where a broken script must
    /// be a [`CairlError::Script`] the caller can report.
    pub fn try_load(id: &str, src: &str, stream: u64, hint: RenderHint) -> Result<ScriptEnv> {
        let interp = Interpreter::load(src)
            .map_err(|e| CairlError::Script(format!("script env {id}: {e}")))?;
        let read_dim = |name: &str| -> Result<usize> {
            let value = interp
                .global(name)
                .and_then(|v| v.as_num().ok())
                .ok_or_else(|| {
                    CairlError::Script(format!("script env {id}: missing {name} global"))
                })?;
            if value < 1.0 {
                return Err(CairlError::Script(format!(
                    "script env {id}: {name} must be >= 1, got {value}"
                )));
            }
            Ok(value as usize)
        };
        let obs_dim = read_dim("obs_dim")?;
        let n_actions = read_dim("n_actions")?;
        Ok(ScriptEnv {
            id: id.to_string(),
            interp,
            obs_dim,
            n_actions,
            stream,
            hint,
            cell: None,
            generation: 0,
            last_seed: 0,
        })
    }

    /// Attach a hot-reload cell: from now on every `reset()` first checks
    /// whether the cell holds a newer generation and, if the protocol
    /// dims still match, rebuilds the interpreter from the new source
    /// (re-seeded with the last [`Env::seed`] value).  A reload that
    /// *changed* `obs_dim`/`n_actions` is ignored by live envs — their
    /// observation buffers are already sized — and only affects envs
    /// built afterwards.
    pub fn with_cell(mut self, cell: Arc<ScriptCell>) -> ScriptEnv {
        self.generation = cell.snapshot().generation;
        self.cell = Some(cell);
        self
    }

    /// Rebuild the interpreter if the attached [`ScriptCell`] moved to a
    /// newer, shape-compatible generation.  Called on every `reset()`.
    fn maybe_reload(&mut self) {
        let Some(cell) = &self.cell else { return };
        let cur = cell.snapshot();
        if cur.generation == self.generation {
            return;
        }
        if cur.obs_dim != self.obs_dim || cur.n_actions != self.n_actions {
            // Shape-incompatible reload: stay on the old program (do not
            // record the generation, so a later compatible reload is
            // still picked up).
            return;
        }
        // The cell's contents were validated at registration time, so
        // this load cannot fail for the same source.
        self.interp = Interpreter::load(&cur.src)
            .unwrap_or_else(|e| panic!("{}: hot reload: {e}", self.id));
        self.interp.seed_with_stream(self.last_seed, self.stream);
        self.generation = cur.generation;
    }

    /// Exercise the env protocol once without panicking: seed, call
    /// `reset()` and `step(0)`, and shape-check both return values.
    /// Registration-time validation for user scripts.
    pub fn probe(&mut self) -> Result<()> {
        self.interp.seed_with_stream(0, self.stream);
        let v = self.interp.call("reset", &[])?;
        self.expect_list(&v, self.obs_dim, "reset()")?;
        let v = self.interp.call("step", &[Value::Num(0.0)])?;
        self.expect_list(&v, self.obs_dim + 2, "step(action)")?;
        Ok(())
    }

    fn expect_list(&self, v: &Value, want: usize, ctx: &str) -> Result<()> {
        match v {
            Value::List(xs) => {
                let n = xs.lock().unwrap().len();
                if n == want {
                    Ok(())
                } else {
                    Err(CairlError::Script(format!(
                        "{}: {ctx} returned {n} values, wanted {want}",
                        self.id
                    )))
                }
            }
            other => Err(CairlError::Script(format!(
                "{}: {ctx} returned {other:?}, wanted a list",
                self.id
            ))),
        }
    }

    /// Statements the interpreter has executed (profiling).
    pub fn statements_executed(&self) -> u64 {
        self.interp.steps_executed
    }

    fn global_f32(&self, name: &str) -> f32 {
        self.interp
            .global(name)
            .and_then(|v| v.as_num().ok())
            .unwrap_or(0.0) as f32
    }

    fn unpack_list(&self, v: Value, want: usize, ctx: &str) -> Vec<f32> {
        match v {
            Value::List(xs) => {
                let xs = xs.lock().unwrap();
                assert_eq!(
                    xs.len(),
                    want,
                    "{}: {ctx} returned {} values, wanted {want}",
                    self.id,
                    xs.len()
                );
                xs.iter()
                    .map(|v| v.as_num().unwrap_or(f64::NAN) as f32)
                    .collect()
            }
            other => panic!("{}: {ctx} returned {other:?}, wanted a list", self.id),
        }
    }
}

impl Env for ScriptEnv {
    fn id(&self) -> String {
        self.id.clone()
    }

    fn observation_space(&self) -> Space {
        // Scripts expose dynamics, not bounds; report an unbounded box of
        // the right dimension (agents in this toolkit read bounds from
        // native envs only).
        Space::box1(vec![f32::MIN; self.obs_dim], vec![f32::MAX; self.obs_dim])
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: self.n_actions }
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn seed(&mut self, seed: u64) {
        self.last_seed = seed;
        self.interp.seed_with_stream(seed, self.stream);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.maybe_reload();
        let v = self
            .interp
            .call("reset", &[])
            .unwrap_or_else(|e| panic!("{}: reset(): {e}", self.id));
        let vals = self.unpack_list(v, self.obs_dim, "reset()");
        obs.copy_from_slice(&vals);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let v = self
            .interp
            .call("step", &[Value::Num(action.index() as f64)])
            .unwrap_or_else(|e| panic!("{}: step(): {e}", self.id));
        let vals = self.unpack_list(v, self.obs_dim + 2, "step()");
        obs.copy_from_slice(&vals[..self.obs_dim]);
        Transition {
            reward: vals[self.obs_dim],
            done: vals[self.obs_dim + 1] != 0.0,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        match self.hint {
            RenderHint::CartPole => {
                software::paint_cartpole(fb, self.global_f32("x"), self.global_f32("th"))
            }
            RenderHint::MountainCar => software::paint_mountaincar(
                fb,
                self.global_f32("pos"),
                self.global_f32("vel"),
            ),
            RenderHint::Acrobot => {
                software::paint_acrobot(fb, self.global_f32("t1"), self.global_f32("t2"))
            }
            RenderHint::Pendulum => software::paint_pendulum(fb, self.global_f32("th")),
            RenderHint::None => {}
        }
    }
}

// --------------------------------------------------------------- sources

/// Gym CartPole-v1, line-for-line (explicit Euler, "euler" integrator).
pub const CARTPOLE_SRC: &str = r#"
obs_dim = 4;
n_actions = 2;
x = 0; xd = 0; th = 0; thd = 0;

def reset() {
    global x; global xd; global th; global thd;
    x = uniform(-0.05, 0.05);
    xd = uniform(-0.05, 0.05);
    th = uniform(-0.05, 0.05);
    thd = uniform(-0.05, 0.05);
    return [x, xd, th, thd];
}

def step(action) {
    global x; global xd; global th; global thd;
    force = -10.0;
    if (action == 1) { force = 10.0; }
    costh = cos(th);
    sinth = sin(th);
    # masspole*length = 0.05, total_mass = 1.1
    temp = (force + 0.05 * thd * thd * sinth) / 1.1;
    thacc = (9.8 * sinth - costh * temp)
        / (0.5 * (4.0 / 3.0 - 0.1 * costh * costh / 1.1));
    xacc = temp - 0.05 * thacc * costh / 1.1;
    x = x + 0.02 * xd;
    xd = xd + 0.02 * xacc;
    th = th + 0.02 * thd;
    thd = thd + 0.02 * thacc;
    done = 0;
    # theta threshold = 12 degrees = 0.20943951...
    if (x < -2.4 or x > 2.4 or th < -0.2094395102393195 or th > 0.2094395102393195) {
        done = 1;
    }
    return [x, xd, th, thd, 1.0, done];
}
"#;

/// Gym MountainCar-v0, line-for-line.
pub const MOUNTAINCAR_SRC: &str = r#"
obs_dim = 2;
n_actions = 3;
pos = 0; vel = 0;

def reset() {
    global pos; global vel;
    pos = uniform(-0.6, -0.4);
    vel = 0;
    return [pos, vel];
}

def step(action) {
    global pos; global vel;
    vel = vel + (action - 1) * 0.001 + cos(3 * pos) * (0 - 0.0025);
    vel = clamp(vel, -0.07, 0.07);
    pos = pos + vel;
    pos = clamp(pos, -1.2, 0.6);
    if (pos == -1.2 and vel < 0) { vel = 0; }
    done = 0;
    if (pos >= 0.5) { done = 1; }
    return [pos, vel, -1.0, done];
}
"#;

/// Gym Acrobot-v1 ("book" dynamics, single RK4 step of 0.2 s).
pub const ACROBOT_SRC: &str = r#"
obs_dim = 6;
n_actions = 3;
t1 = 0; t2 = 0; d1v = 0; d2v = 0;

def dsdt(s0, s1, s2, s3, torque) {
    # m1=m2=1, l1=1, lc1=lc2=0.5, I1=I2=1, g=9.8
    d1 = 1 * 0.25 + 1 * (1 + 0.25 + 2 * 0.5 * cos(s1)) + 1 + 1;
    d2 = 1 * (0.25 + 0.5 * cos(s1)) + 1;
    phi2 = 1 * 0.5 * 9.8 * cos(s0 + s1 - pi() / 2);
    phi1 = 0 - 1 * 0.5 * s3 * s3 * sin(s1)
        - 2 * 0.5 * s3 * s2 * sin(s1)
        + (1 * 0.5 + 1 * 1) * 9.8 * cos(s0 - pi() / 2)
        + phi2;
    dd2 = (torque + d2 / d1 * phi1 - 1 * 0.5 * s2 * s2 * sin(s1) - phi2)
        / (1 * 0.25 + 1 - d2 * d2 / d1);
    dd1 = 0 - (d2 * dd2 + phi1) / d1;
    return [s2, s3, dd1, dd2];
}

def wrap_pi(v) {
    while (v > pi()) { v = v - 2 * pi(); }
    while (v < 0 - pi()) { v = v + 2 * pi(); }
    return v;
}

def reset() {
    global t1; global t2; global d1v; global d2v;
    t1 = uniform(-0.1, 0.1);
    t2 = uniform(-0.1, 0.1);
    d1v = uniform(-0.1, 0.1);
    d2v = uniform(-0.1, 0.1);
    return [cos(t1), sin(t1), cos(t2), sin(t2), d1v, d2v];
}

def step(action) {
    global t1; global t2; global d1v; global d2v;
    torque = action - 1;
    dt = 0.2;
    k1 = dsdt(t1, t2, d1v, d2v, torque);
    k2 = dsdt(t1 + dt / 2 * k1[0], t2 + dt / 2 * k1[1],
              d1v + dt / 2 * k1[2], d2v + dt / 2 * k1[3], torque);
    k3 = dsdt(t1 + dt / 2 * k2[0], t2 + dt / 2 * k2[1],
              d1v + dt / 2 * k2[2], d2v + dt / 2 * k2[3], torque);
    k4 = dsdt(t1 + dt * k3[0], t2 + dt * k3[1],
              d1v + dt * k3[2], d2v + dt * k3[3], torque);
    t1 = t1 + dt / 6 * (k1[0] + 2 * k2[0] + 2 * k3[0] + k4[0]);
    t2 = t2 + dt / 6 * (k1[1] + 2 * k2[1] + 2 * k3[1] + k4[1]);
    d1v = d1v + dt / 6 * (k1[2] + 2 * k2[2] + 2 * k3[2] + k4[2]);
    d2v = d2v + dt / 6 * (k1[3] + 2 * k2[3] + 2 * k3[3] + k4[3]);
    t1 = wrap_pi(t1);
    t2 = wrap_pi(t2);
    d1v = clamp(d1v, -4 * pi(), 4 * pi());
    d2v = clamp(d2v, -9 * pi(), 9 * pi());
    done = 0;
    reward = -1.0;
    if (0 - cos(t1) - cos(t2 + t1) > 1.0) { done = 1; reward = 0.0; }
    return [cos(t1), sin(t1), cos(t2), sin(t2), d1v, d2v, reward, done];
}
"#;

/// Gym Pendulum-v1 with the toolkit's 5-level torque discretisation.
pub const PENDULUM_SRC: &str = r#"
obs_dim = 3;
n_actions = 5;
th = 0; thd = 0;

def angle_normalize(v) {
    while (v > pi()) { v = v - 2 * pi(); }
    while (v < 0 - pi()) { v = v + 2 * pi(); }
    return v;
}

def reset() {
    global th; global thd;
    th = uniform(0 - pi(), pi());
    thd = uniform(-1, 1);
    return [cos(th), sin(th), thd];
}

def step(action) {
    global th; global thd;
    u = (action - 2) * 1.0;
    u = clamp(u, -2, 2);
    norm = angle_normalize(th);
    cost = norm * norm + 0.1 * thd * thd + 0.001 * u * u;
    # g=10, m=1, l=1, dt=0.05
    thd = thd + (3 * 10.0 / 2.0 * sin(th) + 3.0 * u) * 0.05;
    thd = clamp(thd, -8, 8);
    th = th + thd * 0.05;
    return [cos(th), sin(th), thd, 0 - cost, 0];
}
"#;

// Stream ids matching the native envs (reset-noise parity for equal
// seeds).  pub(crate): the registry's batch hooks build [`ScriptBatch`]
// kernels on the same streams.
pub(crate) const CARTPOLE_STREAM: u64 = 0x9e3779b97f4a7c15;
pub(crate) const MOUNTAINCAR_STREAM: u64 = 0xd3c5b1a49e7f2263;
pub(crate) const ACROBOT_STREAM: u64 = 0x2545f4914f6cdd1d;
pub(crate) const PENDULUM_STREAM: u64 = 0x6a09e667f3bcc909;

/// CartPole on the interpreted runner.
pub fn cartpole() -> ScriptEnv {
    ScriptEnv::load(
        "Script/CartPole-v1",
        CARTPOLE_SRC,
        CARTPOLE_STREAM,
        RenderHint::CartPole,
    )
}

/// MountainCar on the interpreted runner.
pub fn mountain_car() -> ScriptEnv {
    ScriptEnv::load(
        "Script/MountainCar-v0",
        MOUNTAINCAR_SRC,
        MOUNTAINCAR_STREAM,
        RenderHint::MountainCar,
    )
}

/// Acrobot on the interpreted runner.
pub fn acrobot() -> ScriptEnv {
    ScriptEnv::load(
        "Script/Acrobot-v1",
        ACROBOT_SRC,
        ACROBOT_STREAM,
        RenderHint::Acrobot,
    )
}

/// Discrete-torque Pendulum on the interpreted runner.
pub fn pendulum() -> ScriptEnv {
    ScriptEnv::load(
        "Script/Pendulum-v1",
        PENDULUM_SRC,
        PENDULUM_STREAM,
        RenderHint::Pendulum,
    )
}

/// The script-runner registry ids, in registration order.
///
/// These ids participate in the scenario-mixture namespace like any
/// other registered env: `"CartPole-v1:32,Script/CartPole-v1:16"` runs
/// native and interpreted lanes side by side in one pool (the
/// `rust/tests/mixture_pool.rs` suite pins the cross-runner
/// determinism of exactly that shape).
pub fn ids() -> [&'static str; 4] {
    [
        "Script/CartPole-v1",
        "Script/MountainCar-v0",
        "Script/Acrobot-v1",
        "Script/Pendulum-v1",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;
    use crate::envs;

    #[test]
    fn all_four_scripts_load_and_reset() {
        for mut env in [cartpole(), mountain_car(), acrobot(), pendulum()] {
            env.seed(0);
            let obs = env.reset();
            assert_eq!(obs.len(), env.obs_dim());
            assert!(obs.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn script_cartpole_matches_native_trajectory() {
        let mut native = envs::CartPole::new();
        let mut script = cartpole();
        native.seed(123);
        script.seed(123);
        let mut on = vec![0.0f32; 4];
        let mut os = vec![0.0f32; 4];
        native.reset_into(&mut on);
        script.reset_into(&mut os);
        for (a, b) in on.iter().zip(&os) {
            assert!((a - b).abs() < 1e-5, "reset parity: {on:?} vs {os:?}");
        }
        // Follow the same action sequence for 50 steps; f32-vs-f64 drift
        // stays tiny over this horizon.
        let mut rng = Pcg32::new(7, 7);
        for step in 0..50 {
            let a = Action::Discrete(rng.below(2) as usize);
            let tn = native.step_into(&a, &mut on);
            let ts = script.step_into(&a, &mut os);
            for (x, y) in on.iter().zip(&os) {
                assert!(
                    (x - y).abs() < 1e-3,
                    "step {step}: {on:?} vs {os:?}"
                );
            }
            assert_eq!(tn.done, ts.done, "step {step}");
            if tn.done {
                break;
            }
        }
    }

    #[test]
    fn script_mountaincar_matches_native_trajectory() {
        let mut native = envs::MountainCar::new();
        let mut script = mountain_car();
        native.seed(5);
        script.seed(5);
        let mut on = vec![0.0f32; 2];
        let mut os = vec![0.0f32; 2];
        native.reset_into(&mut on);
        script.reset_into(&mut os);
        assert!((on[0] - os[0]).abs() < 1e-5);
        for _ in 0..100 {
            let a = Action::Discrete(2);
            native.step_into(&a, &mut on);
            script.step_into(&a, &mut os);
        }
        assert!((on[0] - os[0]).abs() < 1e-3, "{on:?} vs {os:?}");
        assert!((on[1] - os[1]).abs() < 1e-3);
    }

    #[test]
    fn script_acrobot_matches_native_trajectory() {
        let mut native = envs::Acrobot::new();
        let mut script = acrobot();
        native.seed(11);
        script.seed(11);
        let mut on = vec![0.0f32; 6];
        let mut os = vec![0.0f32; 6];
        native.reset_into(&mut on);
        script.reset_into(&mut os);
        for _ in 0..20 {
            let a = Action::Discrete(2);
            native.step_into(&a, &mut on);
            script.step_into(&a, &mut os);
        }
        for (x, y) in on.iter().zip(&os) {
            assert!((x - y).abs() < 5e-3, "{on:?} vs {os:?}");
        }
    }

    #[test]
    fn script_pendulum_matches_native_trajectory() {
        let mut native = envs::Pendulum::discrete();
        let mut script = pendulum();
        native.seed(3);
        script.seed(3);
        let mut on = vec![0.0f32; 3];
        let mut os = vec![0.0f32; 3];
        native.reset_into(&mut on);
        script.reset_into(&mut os);
        let mut tr_n = 0.0;
        let mut tr_s = 0.0;
        for _ in 0..50 {
            let a = Action::Discrete(4);
            tr_n += native.step_into(&a, &mut on).reward;
            tr_s += script.step_into(&a, &mut os).reward;
        }
        for (x, y) in on.iter().zip(&os) {
            assert!((x - y).abs() < 1e-2, "{on:?} vs {os:?}");
        }
        assert!((tr_n - tr_s).abs() < 0.1, "{tr_n} vs {tr_s}");
    }

    #[test]
    fn script_env_render_paints() {
        let mut env = cartpole();
        env.seed(0);
        env.reset();
        let mut fb = Framebuffer::standard();
        env.render(&mut fb);
        assert!(fb.sum() > 10.0);
    }

    #[test]
    fn statement_counter_advances() {
        let mut env = cartpole();
        env.seed(0);
        env.reset();
        let before = env.statements_executed();
        env.step(&Action::Discrete(0));
        assert!(env.statements_executed() > before + 10);
    }

    fn const_src(v: f64, obs_dim: usize) -> String {
        let obs = (0..obs_dim)
            .map(|_| format!("{v:?}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "obs_dim = {obs_dim}; n_actions = 2;\n\
             def reset() {{ return [{obs}]; }}\n\
             def step(action) {{ return [{obs}, 1.0, 0]; }}\n"
        )
    }

    fn loaded(src: &str, obs_dim: usize) -> LoadedScript {
        LoadedScript {
            src: src.to_string(),
            stream: 1,
            obs_dim,
            n_actions: 2,
            program: Arc::new(crate::script::compile::compile_src(src).unwrap()),
            generation: 0,
        }
    }

    #[test]
    fn hot_reload_rebuilds_on_next_reset() {
        let src_a = const_src(1.0, 1);
        let src_b = const_src(2.0, 1);
        let cell = Arc::new(ScriptCell::new(loaded(&src_a, 1)));
        let mut env = ScriptEnv::try_load("Script/Reload", &src_a, 1, RenderHint::None)
            .unwrap()
            .with_cell(Arc::clone(&cell));
        env.seed(9);
        assert_eq!(env.reset(), vec![1.0]);
        cell.replace(loaded(&src_b, 1));
        // Mid-episode steps keep running the old program...
        let mut obs = vec![0.0f32; 1];
        env.step_into(&Action::Discrete(0), &mut obs);
        assert_eq!(obs, vec![1.0]);
        // ...and the next reset() swaps in the new one.
        assert_eq!(env.reset(), vec![2.0]);
    }

    #[test]
    fn shape_incompatible_reload_is_ignored_by_live_envs() {
        let src_a = const_src(1.0, 1);
        let src_wide = const_src(3.0, 2);
        let src_b = const_src(2.0, 1);
        let cell = Arc::new(ScriptCell::new(loaded(&src_a, 1)));
        let mut env = ScriptEnv::try_load("Script/Reload", &src_a, 1, RenderHint::None)
            .unwrap()
            .with_cell(Arc::clone(&cell));
        env.seed(0);
        cell.replace(loaded(&src_wide, 2));
        // obs_dim changed: the live env stays on its old program.
        assert_eq!(env.reset(), vec![1.0]);
        // A later shape-compatible reload is still picked up.
        cell.replace(loaded(&src_b, 1));
        assert_eq!(env.reset(), vec![2.0]);
    }
}
