//! Register-bytecode VM for compiled MiniScript.
//!
//! Executes [`CompiledProgram`]s produced by
//! [`crate::script::compile`].  The design follows the in-repo Flash VM
//! (`rust/src/flash/vm.rs`): a flat instruction array, one `match` per
//! op, no recursion — calls push a [`CallInfo`] and reuse the same
//! register vector as a growing window stack.  All mutable state
//! (registers, globals, RNG) lives outside the shared
//! `Arc<CompiledProgram>`, which is what lets one compiled program
//! drive N batch lanes ([`crate::script::batch::ScriptBatch`]).
//!
//! **Equivalence contract:** a [`Vm`] is observably identical to
//! [`Interpreter`](crate::script::interp::Interpreter) on the same
//! source — same f64 results, same `uniform()` draw order, same error
//! strings — except that runaway recursion fails gracefully with a
//! `call depth exceeded` script error where the tree-walk would blow
//! the host stack.  `rust/tests/script_vm.rs` pins the contract.

use std::sync::Arc;

use crate::core::env::{Env, Transition};
use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{software, Framebuffer};
use crate::script::compile::{compile_src, Builtin, CompiledProgram, Op, NO_REG};
use crate::script::envs::RenderHint;
use crate::script::interp::Value;

/// The interpreter's default RNG stream (matches `Interpreter::load`).
pub(crate) const DEFAULT_STREAM: u64 = 0xe7037ed1a0b428db;

/// Recursion limit — the tree-walk overflows the host stack somewhere
/// past this; the VM turns it into a reportable script error instead.
const MAX_CALL_DEPTH: usize = 10_000;

/// A suspended caller: where to resume and where the result goes.
#[derive(Clone, Copy)]
pub(crate) struct CallInfo {
    ret_pc: usize,
    ret_dst: u16,
    base: usize,
}

/// Reusable execution state: the register window stack and call stack.
/// Kept outside [`run_function`] so the hot path never allocates after
/// the first episode.
#[derive(Default)]
pub(crate) struct Scratch {
    regs: Vec<Option<Value>>,
    calls: Vec<CallInfo>,
}

#[inline]
fn get<'a>(regs: &'a [Option<Value>], base: usize, i: u16) -> &'a Value {
    regs[base + usize::from(i)]
        .as_ref()
        .expect("vm: unset register (compiler bug)")
}

#[inline]
fn num(regs: &[Option<Value>], base: usize, i: u16) -> Result<f64> {
    get(regs, base, i).as_num()
}

/// Run one function (or the top-level code) to completion.
///
/// `globals` and `rng` are passed in rather than owned so batch lanes
/// can swap per-lane state under one shared program; `counter` counts
/// executed ops (the profiling analogue of the tree-walk's
/// `steps_executed`).
pub(crate) fn run_function(
    p: &CompiledProgram,
    entry: usize,
    n_regs: u16,
    args: &[Value],
    globals: &mut [Option<Value>],
    rng: &mut Pcg32,
    scratch: &mut Scratch,
    counter: &mut u64,
) -> Result<Value> {
    scratch.regs.clear();
    scratch.calls.clear();
    for a in args {
        scratch.regs.push(Some(a.clone()));
    }
    scratch.regs.resize(usize::from(n_regs), None);
    let mut pc = entry;
    let mut base = 0usize;
    loop {
        let op = p.code[pc];
        pc += 1;
        *counter += 1;
        let regs = &mut scratch.regs;
        match op {
            Op::Const { dst, idx } => {
                regs[base + usize::from(dst)] = Some(p.consts[usize::from(idx)].clone());
            }
            Op::Move { dst, src } => {
                let v = regs[base + usize::from(src)].clone();
                regs[base + usize::from(dst)] = v;
            }
            Op::LoadVar { dst, slot, global, name } => {
                let v = if slot != NO_REG && regs[base + usize::from(slot)].is_some() {
                    regs[base + usize::from(slot)].clone()
                } else if global != NO_REG && globals[usize::from(global)].is_some() {
                    globals[usize::from(global)].clone()
                } else {
                    return Err(CairlError::Script(format!(
                        "undefined variable {:?}",
                        p.strings[usize::from(name)]
                    )));
                };
                regs[base + usize::from(dst)] = v;
            }
            Op::StoreGlobal { idx, src } => {
                globals[usize::from(idx)] = Some(get(regs, base, src).clone());
            }
            Op::AsNum { dst, src } => {
                let v = num(regs, base, src)?;
                regs[base + usize::from(dst)] = Some(Value::Num(v));
            }
            Op::Add { dst, a, b } => {
                let v = num(regs, base, a)? + num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Num(v));
            }
            Op::Sub { dst, a, b } => {
                let v = num(regs, base, a)? - num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Num(v));
            }
            Op::Mul { dst, a, b } => {
                let v = num(regs, base, a)? * num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Num(v));
            }
            Op::Div { dst, a, b } => {
                let v = num(regs, base, a)? / num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Num(v));
            }
            Op::Mod { dst, a, b } => {
                let v = num(regs, base, a)?.rem_euclid(num(regs, base, b)?);
                regs[base + usize::from(dst)] = Some(Value::Num(v));
            }
            Op::Eq { dst, a, b } => {
                let v = num(regs, base, a)? == num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Ne { dst, a, b } => {
                let v = num(regs, base, a)? != num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Lt { dst, a, b } => {
                let v = num(regs, base, a)? < num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Le { dst, a, b } => {
                let v = num(regs, base, a)? <= num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Gt { dst, a, b } => {
                let v = num(regs, base, a)? > num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Ge { dst, a, b } => {
                let v = num(regs, base, a)? >= num(regs, base, b)?;
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Neg { dst, src } => {
                let v = num(regs, base, src)?;
                regs[base + usize::from(dst)] = Some(Value::Num(-v));
            }
            Op::Not { dst, src } => {
                let v = !get(regs, base, src).truthy();
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Truthy { dst, src } => {
                let v = get(regs, base, src).truthy();
                regs[base + usize::from(dst)] = Some(Value::Bool(v));
            }
            Op::Jmp(to) => {
                pc = to as usize;
            }
            Op::JmpIfFalse { cond, to } => {
                if !get(regs, base, cond).truthy() {
                    pc = to as usize;
                }
            }
            Op::JmpIfTrue { cond, to } => {
                if get(regs, base, cond).truthy() {
                    pc = to as usize;
                }
            }
            Op::MakeList { dst, start, n } => {
                let items: Vec<Value> = (0..usize::from(n))
                    .map(|i| get(regs, base, start + i as u16).clone())
                    .collect();
                regs[base + usize::from(dst)] = Some(Value::list(items));
            }
            Op::IndexGet { dst, xs, idx } => {
                // Interpreter order: numeric conversion of the index,
                // then the list-type check, then bounds.
                let i = num(regs, base, idx)? as usize;
                let v = match get(regs, base, xs) {
                    Value::List(items) => {
                        let items = items.lock().unwrap();
                        items.get(i).cloned().ok_or_else(|| {
                            CairlError::Script(format!(
                                "index {i} out of range (len {})",
                                items.len()
                            ))
                        })?
                    }
                    other => {
                        return Err(CairlError::Script(format!("cannot index into {other:?}")))
                    }
                };
                regs[base + usize::from(dst)] = Some(v);
            }
            Op::IndexSet { xs, idx, src } => {
                let i = num(regs, base, idx)? as usize;
                let v = get(regs, base, src).clone();
                match get(regs, base, xs) {
                    Value::List(items) => {
                        let mut items = items.lock().unwrap();
                        if i >= items.len() {
                            return Err(CairlError::Script(format!(
                                "index {i} out of range (len {})",
                                items.len()
                            )));
                        }
                        items[i] = v;
                    }
                    other => {
                        return Err(CairlError::Script(format!("cannot index into {other:?}")))
                    }
                }
            }
            Op::CallBuiltin { dst, builtin, start, argc } => {
                let args = &regs[base + usize::from(start)..base + usize::from(start + argc)];
                let v = builtin_call(builtin, args, rng)?;
                regs[base + usize::from(dst)] = Some(v);
            }
            Op::CallFn { dst, func, start, argc } => {
                if scratch.calls.len() >= MAX_CALL_DEPTH {
                    return Err(CairlError::Script("call depth exceeded".into()));
                }
                let f = &p.funcs[usize::from(func)];
                scratch.calls.push(CallInfo { ret_pc: pc, ret_dst: dst, base });
                let new_base = regs.len();
                for i in 0..usize::from(argc) {
                    let v = regs[base + usize::from(start) + i].clone();
                    regs.push(v);
                }
                regs.resize(new_base + usize::from(f.n_regs), None);
                base = new_base;
                pc = f.entry as usize;
            }
            Op::Return { src } => {
                let v = regs[base + usize::from(src)]
                    .take()
                    .expect("vm: unset register (compiler bug)");
                match scratch.calls.pop() {
                    None => return Ok(v),
                    Some(ci) => {
                        regs.truncate(base);
                        base = ci.base;
                        pc = ci.ret_pc;
                        regs[base + usize::from(ci.ret_dst)] = Some(v);
                    }
                }
            }
            Op::ReturnNone => match scratch.calls.pop() {
                None => return Ok(Value::None),
                Some(ci) => {
                    regs.truncate(base);
                    base = ci.base;
                    pc = ci.ret_pc;
                    regs[base + usize::from(ci.ret_dst)] = Some(Value::None);
                }
            },
            Op::Trap { msg } => {
                return Err(CairlError::Script(p.strings[usize::from(msg)].clone()));
            }
        }
    }
}

/// Builtin dispatch — formula-for-formula the tree-walk's `builtin`,
/// including argument conversion order (error parity) and the single
/// `uniform()` RNG draw.
fn builtin_call(b: Builtin, args: &[Option<Value>], rng: &mut Pcg32) -> Result<Value> {
    let arg = |i: usize| -> &Value {
        args[i].as_ref().expect("vm: unset argument register")
    };
    let num = |i: usize| -> Result<f64> { arg(i).as_num() };
    Ok(match b {
        Builtin::Cos => Value::Num(num(0)?.cos()),
        Builtin::Sin => Value::Num(num(0)?.sin()),
        Builtin::Tan => Value::Num(num(0)?.tan()),
        Builtin::Sqrt => Value::Num(num(0)?.sqrt()),
        Builtin::Exp => Value::Num(num(0)?.exp()),
        Builtin::Ln => Value::Num(num(0)?.ln()),
        Builtin::Abs => Value::Num(num(0)?.abs()),
        Builtin::Floor => Value::Num(num(0)?.floor()),
        Builtin::Ceil => Value::Num(num(0)?.ceil()),
        Builtin::Sign => Value::Num(num(0)?.signum()),
        Builtin::Pow => Value::Num(num(0)?.powf(num(1)?)),
        Builtin::Min => Value::Num(num(0)?.min(num(1)?)),
        Builtin::Max => Value::Num(num(0)?.max(num(1)?)),
        Builtin::Clamp => Value::Num(num(0)?.max(num(1)?).min(num(2)?)),
        Builtin::Pi => Value::Num(std::f64::consts::PI),
        Builtin::Uniform => {
            let lo = num(0)?;
            let hi = num(1)?;
            Value::Num(lo + (hi - lo) * rng.next_f64())
        }
        Builtin::Len => match arg(0) {
            Value::List(xs) => Value::Num(xs.lock().unwrap().len() as f64),
            other => return Err(CairlError::Script(format!("len of {other:?}"))),
        },
        Builtin::Push => match arg(0) {
            Value::List(xs) => {
                let v = arg(1).clone();
                xs.lock().unwrap().push(v);
                Value::None
            }
            other => return Err(CairlError::Script(format!("push to {other:?}"))),
        },
        Builtin::Zeros => {
            let n = num(0)? as usize;
            Value::list(vec![Value::Num(0.0); n])
        }
    })
}

/// A loaded bytecode program with its global state — the compiled
/// counterpart of [`Interpreter`](crate::script::interp::Interpreter),
/// API-compatible where it matters (`load` / `seed` /
/// `seed_with_stream` / `global` / `call`).
pub struct Vm {
    program: Arc<CompiledProgram>,
    globals: Vec<Option<Value>>,
    rng: Pcg32,
    /// Total bytecode ops executed (profiling; the compiled analogue of
    /// the tree-walk's `steps_executed`).
    pub ops_executed: u64,
    scratch: Scratch,
}

impl Vm {
    /// Compile `src` and run its top-level statements (builds globals).
    pub fn load(src: &str) -> Result<Vm> {
        Vm::with_program(Arc::new(compile_src(src)?))
    }

    /// Instantiate a VM over an already-compiled (shared) program and
    /// run its top-level statements.
    pub fn with_program(program: Arc<CompiledProgram>) -> Result<Vm> {
        let mut vm = Vm {
            globals: vec![None; program.global_names.len()],
            program,
            rng: Pcg32::new(0, DEFAULT_STREAM),
            ops_executed: 0,
            scratch: Scratch::default(),
        };
        let program = Arc::clone(&vm.program);
        run_function(
            &program,
            program.top_entry as usize,
            program.top_regs,
            &[],
            &mut vm.globals,
            &mut vm.rng,
            &mut vm.scratch,
            &mut vm.ops_executed,
        )?;
        Ok(vm)
    }

    /// The shared compiled program.
    pub fn program(&self) -> &Arc<CompiledProgram> {
        &self.program
    }

    /// Re-seed the `uniform()` builtin (default stream).
    pub fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, DEFAULT_STREAM);
    }

    /// Re-seed with an explicit PCG stream id — same contract as
    /// [`Interpreter::seed_with_stream`](crate::script::interp::Interpreter::seed_with_stream).
    pub fn seed_with_stream(&mut self, seed: u64, stream: u64) {
        self.rng = Pcg32::new(seed, stream);
    }

    /// Read a global variable.
    pub fn global(&self, name: &str) -> Option<&Value> {
        let idx = *self.program.global_map.get(name)?;
        self.globals[usize::from(idx)].as_ref()
    }

    /// Resolve a function name to its table index (for repeated calls
    /// without the map probe).
    pub fn func_index(&self, name: &str) -> Option<u16> {
        self.program.func_map.get(name).copied()
    }

    /// Call a script function by name.
    pub fn call(&mut self, name: &str, args: &[Value]) -> Result<Value> {
        let idx = self
            .func_index(name)
            .ok_or_else(|| CairlError::Script(format!("no function {name:?}")))?;
        self.call_index(idx, args)
    }

    /// Call a script function by table index.
    pub fn call_index(&mut self, idx: u16, args: &[Value]) -> Result<Value> {
        let program = Arc::clone(&self.program);
        let Vm { globals, rng, scratch, ops_executed, .. } = self;
        let f = &program.funcs[usize::from(idx)];
        if usize::from(f.n_params) != args.len() {
            return Err(CairlError::Script(format!(
                "{}() takes {} args, got {}",
                f.name,
                f.n_params,
                args.len()
            )));
        }
        run_function(
            &program,
            f.entry as usize,
            f.n_regs,
            args,
            globals,
            rng,
            scratch,
            ops_executed,
        )
    }

    /// Call with externally-held globals and RNG — the batch-lane path:
    /// one VM (program + scratch) steps many lanes' state columns.
    pub(crate) fn call_index_with(
        &mut self,
        idx: u16,
        args: &[Value],
        globals: &mut [Option<Value>],
        rng: &mut Pcg32,
    ) -> Result<Value> {
        let program = Arc::clone(&self.program);
        let Vm { scratch, ops_executed, .. } = self;
        let f = &program.funcs[usize::from(idx)];
        if usize::from(f.n_params) != args.len() {
            return Err(CairlError::Script(format!(
                "{}() takes {} args, got {}",
                f.name,
                f.n_params,
                args.len()
            )));
        }
        run_function(
            &program,
            f.entry as usize,
            f.n_regs,
            args,
            globals,
            rng,
            scratch,
            ops_executed,
        )
    }

    /// The VM's own global column (template for batch lanes).
    pub(crate) fn globals_snapshot(&self) -> &[Option<Value>] {
        &self.globals
    }
}

/// A MiniScript program compiled to bytecode, behind the [`Env`] trait
/// — drop-in for [`ScriptEnv`](crate::script::envs::ScriptEnv) with the
/// same script protocol, error strings, and (given equal seeds)
/// bit-identical trajectories.
pub struct CompiledScriptEnv {
    id: String,
    vm: Vm,
    obs_dim: usize,
    n_actions: usize,
    stream: u64,
    reset_f: Option<u16>,
    step_f: Option<u16>,
    hint: RenderHint,
}

impl CompiledScriptEnv {
    /// Compile and load a script (see
    /// [`ScriptEnv::try_load`](crate::script::envs::ScriptEnv::try_load)
    /// for the contract; errors carry the same messages).
    pub fn try_load(
        id: &str,
        src: &str,
        stream: u64,
        hint: RenderHint,
    ) -> Result<CompiledScriptEnv> {
        let vm =
            Vm::load(src).map_err(|e| CairlError::Script(format!("script env {id}: {e}")))?;
        CompiledScriptEnv::from_vm(id, vm, stream, hint)
    }

    /// Load from an already-compiled (shared) program — the batch /
    /// registry path, compiling once per spec rather than per lane.
    pub fn from_program(
        id: &str,
        program: Arc<CompiledProgram>,
        stream: u64,
        hint: RenderHint,
    ) -> Result<CompiledScriptEnv> {
        let vm = Vm::with_program(program)
            .map_err(|e| CairlError::Script(format!("script env {id}: {e}")))?;
        CompiledScriptEnv::from_vm(id, vm, stream, hint)
    }

    fn from_vm(id: &str, vm: Vm, stream: u64, hint: RenderHint) -> Result<CompiledScriptEnv> {
        let read_dim = |name: &str| -> Result<usize> {
            let value = vm.global(name).and_then(|v| v.as_num().ok()).ok_or_else(|| {
                CairlError::Script(format!("script env {id}: missing {name} global"))
            })?;
            if value < 1.0 {
                return Err(CairlError::Script(format!(
                    "script env {id}: {name} must be >= 1, got {value}"
                )));
            }
            Ok(value as usize)
        };
        let obs_dim = read_dim("obs_dim")?;
        let n_actions = read_dim("n_actions")?;
        let reset_f = vm.func_index("reset");
        let step_f = vm.func_index("step");
        Ok(CompiledScriptEnv {
            id: id.to_string(),
            vm,
            obs_dim,
            n_actions,
            stream,
            reset_f,
            step_f,
            hint,
        })
    }

    /// Registration-time validation: seed, `reset()`, `step(0)`, shape
    /// checks — mirrors
    /// [`ScriptEnv::probe`](crate::script::envs::ScriptEnv::probe).
    pub fn probe(&mut self) -> Result<()> {
        self.vm.seed_with_stream(0, self.stream);
        let v = self.vm.call("reset", &[])?;
        self.expect_list(&v, self.obs_dim, "reset()")?;
        let v = self.vm.call("step", &[Value::Num(0.0)])?;
        self.expect_list(&v, self.obs_dim + 2, "step(action)")?;
        Ok(())
    }

    fn expect_list(&self, v: &Value, want: usize, ctx: &str) -> Result<()> {
        match v {
            Value::List(xs) => {
                let n = xs.lock().unwrap().len();
                if n == want {
                    Ok(())
                } else {
                    Err(CairlError::Script(format!(
                        "{}: {ctx} returned {n} values, wanted {want}",
                        self.id
                    )))
                }
            }
            other => Err(CairlError::Script(format!(
                "{}: {ctx} returned {other:?}, wanted a list",
                self.id
            ))),
        }
    }

    /// Bytecode ops executed so far (profiling).
    pub fn ops_executed(&self) -> u64 {
        self.vm.ops_executed
    }

    fn global_f32(&self, name: &str) -> f32 {
        self.vm.global(name).and_then(|v| v.as_num().ok()).unwrap_or(0.0) as f32
    }

    fn unpack_list(&self, v: Value, want: usize, ctx: &str) -> Vec<f32> {
        match v {
            Value::List(xs) => {
                let xs = xs.lock().unwrap();
                assert_eq!(
                    xs.len(),
                    want,
                    "{}: {ctx} returned {} values, wanted {want}",
                    self.id,
                    xs.len()
                );
                xs.iter().map(|v| v.as_num().unwrap_or(f64::NAN) as f32).collect()
            }
            other => panic!("{}: {ctx} returned {other:?}, wanted a list", self.id),
        }
    }

    fn call_protocol(&mut self, f: Option<u16>, name: &str, args: &[Value]) -> Result<Value> {
        match f {
            Some(idx) => self.vm.call_index(idx, args),
            None => Err(CairlError::Script(format!("no function {name:?}"))),
        }
    }
}

impl Env for CompiledScriptEnv {
    fn id(&self) -> String {
        self.id.clone()
    }

    fn observation_space(&self) -> Space {
        Space::box1(vec![f32::MIN; self.obs_dim], vec![f32::MAX; self.obs_dim])
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: self.n_actions }
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn seed(&mut self, seed: u64) {
        self.vm.seed_with_stream(seed, self.stream);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        let v = self
            .call_protocol(self.reset_f, "reset", &[])
            .unwrap_or_else(|e| panic!("{}: reset(): {e}", self.id));
        let vals = self.unpack_list(v, self.obs_dim, "reset()");
        obs.copy_from_slice(&vals);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let v = self
            .call_protocol(self.step_f, "step", &[Value::Num(action.index() as f64)])
            .unwrap_or_else(|e| panic!("{}: step(): {e}", self.id));
        let vals = self.unpack_list(v, self.obs_dim + 2, "step()");
        obs.copy_from_slice(&vals[..self.obs_dim]);
        Transition {
            reward: vals[self.obs_dim],
            done: vals[self.obs_dim + 1] != 0.0,
            truncated: false,
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        match self.hint {
            RenderHint::CartPole => {
                software::paint_cartpole(fb, self.global_f32("x"), self.global_f32("th"))
            }
            RenderHint::MountainCar => {
                software::paint_mountaincar(fb, self.global_f32("pos"), self.global_f32("vel"))
            }
            RenderHint::Acrobot => {
                software::paint_acrobot(fb, self.global_f32("t1"), self.global_f32("t2"))
            }
            RenderHint::Pendulum => software::paint_pendulum(fb, self.global_f32("th")),
            RenderHint::None => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::script::interp::Interpreter;

    fn run(src: &str, func: &str, args: &[Value]) -> Value {
        let mut vm = Vm::load(src).unwrap();
        vm.call(func, args).unwrap()
    }

    #[test]
    fn arithmetic_and_return() {
        let v = run(
            "def f(a, b) { return a * 10 + b; }",
            "f",
            &[Value::Num(4.0), Value::Num(2.0)],
        );
        assert_eq!(v.as_num().unwrap(), 42.0);
    }

    #[test]
    fn globals_persist_between_calls() {
        let src = "count = 0; def bump() { global count; count = count + 1; return count; }";
        let mut vm = Vm::load(src).unwrap();
        assert_eq!(vm.call("bump", &[]).unwrap().as_num().unwrap(), 1.0);
        assert_eq!(vm.call("bump", &[]).unwrap().as_num().unwrap(), 2.0);
        assert_eq!(vm.global("count").unwrap().as_num().unwrap(), 2.0);
    }

    #[test]
    fn locals_do_not_leak_without_global() {
        let src = "x = 5; def f() { x = 10; return x; } def g() { return x; }";
        let mut vm = Vm::load(src).unwrap();
        assert_eq!(vm.call("f", &[]).unwrap().as_num().unwrap(), 10.0);
        assert_eq!(vm.call("g", &[]).unwrap().as_num().unwrap(), 5.0);
    }

    #[test]
    fn while_loop_with_break_continue() {
        let src = "def f() { s = 0; i = 0; while (true) { i += 1; if (i > 10) { break; } \
                   if (i % 2 == 0) { continue; } s += i; } return s; }";
        assert_eq!(run(src, "f", &[]).as_num().unwrap(), 25.0);
    }

    #[test]
    fn for_loop_sums() {
        let v = run("def f() { s = 0; for i = 0, 10 { s += i; } return s; }", "f", &[]);
        assert_eq!(v.as_num().unwrap(), 45.0);
    }

    #[test]
    fn lists_index_and_mutate() {
        let src = "def f() { xs = zeros(3); xs[1] = 7; push(xs, 9); \
                   return xs[1] + xs[3] + len(xs); }";
        assert_eq!(run(src, "f", &[]).as_num().unwrap(), 20.0);
    }

    #[test]
    fn recursion_works() {
        let src = "def fib(n) { if (n < 2) { return n; } return fib(n - 1) + fib(n - 2); }";
        assert_eq!(run(src, "fib", &[Value::Num(10.0)]).as_num().unwrap(), 55.0);
    }

    #[test]
    fn short_circuit_skips_rhs() {
        let src = "def f() { x = 0; if (x != 0 and 1 / x > 0) { return 1; } return 0; }";
        assert_eq!(run(src, "f", &[]).as_num().unwrap(), 0.0);
    }

    #[test]
    fn uniform_draws_match_the_tree_walk_bit_for_bit() {
        let src = "def f() { return uniform(-1, 1); }";
        let mut interp = Interpreter::load(src).unwrap();
        let mut vm = Vm::load(src).unwrap();
        interp.seed(42);
        vm.seed(42);
        for _ in 0..32 {
            let a = interp.call("f", &[]).unwrap().as_num().unwrap();
            let b = vm.call("f", &[]).unwrap().as_num().unwrap();
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn error_messages_match_the_tree_walk() {
        for (src, call) in [
            ("def f() { return missing; }", "f"),
            ("def f() { xs = zeros(2); return xs[5]; }", "f"),
            ("def f() { return len(1); }", "f"),
            ("def f() { return 1 + [1]; }", "f"),
            ("def g() { return 0; } def f() { return g(1); }", "f"),
            ("def f() { return nope(); }", "f"),
        ] {
            let te = Interpreter::load(src).unwrap().call(call, &[]).unwrap_err();
            let ve = Vm::load(src).unwrap().call(call, &[]).unwrap_err();
            assert_eq!(te.to_string(), ve.to_string(), "source: {src}");
        }
    }

    #[test]
    fn deep_recursion_errors_instead_of_overflowing() {
        let src = "def f(n) { return f(n + 1); }";
        let err = Vm::load(src).unwrap().call("f", &[Value::Num(0.0)]).unwrap_err();
        assert!(err.to_string().contains("call depth exceeded"));
    }

    #[test]
    fn compiled_cartpole_matches_tree_walk_bitwise() {
        use crate::script::envs::{cartpole, CARTPOLE_SRC};
        let mut tree = cartpole();
        let mut comp = CompiledScriptEnv::try_load(
            "Script/CartPole-v1",
            CARTPOLE_SRC,
            0x9e3779b97f4a7c15,
            RenderHint::CartPole,
        )
        .unwrap();
        comp.probe().unwrap();
        tree.seed(123);
        comp.seed(123);
        let mut ot = vec![0.0f32; 4];
        let mut oc = vec![0.0f32; 4];
        tree.reset_into(&mut ot);
        comp.reset_into(&mut oc);
        assert_eq!(ot, oc);
        for step in 0..200 {
            let a = Action::Discrete(step % 2);
            let tt = tree.step_into(&a, &mut ot);
            let tc = comp.step_into(&a, &mut oc);
            assert_eq!(ot, oc, "step {step}");
            assert_eq!(tt, tc, "step {step}");
        }
    }
}
