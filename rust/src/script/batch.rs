//! Batched stepping for compiled scripts: one VM, N lanes.
//!
//! [`ScriptBatch`] is the scripted-env counterpart of
//! [`FusedBatch`](crate::core::batch::FusedBatch): a lane group stepped
//! as one unit behind [`BatchEnv`], with per-lane PCG streams, the
//! registered `TimeLimit` folded into a step counter, the trailing
//! affine epilogue, and inline auto-reset.  Where a
//! [`LaneKernel`](crate::core::batch::LaneKernel) keeps f32 state
//! columns, a script's state is its global variables — so the SoA
//! layout here is **one shared compiled program + register scratch**
//! (the expensive, lane-invariant half) over **per-lane global columns
//! and RNGs** (the cheap, lane-varying half).  Stepping lane `k` swaps
//! in column `k` and runs the bytecode; no per-lane interpreter, no
//! per-lane wrapper chain, no per-lane virtual dispatch.
//!
//! Equivalence contract: a `ScriptBatch` lane is bit-identical to a
//! scalar `TimeLimit(ScriptEnv)` stack with the same seed — the
//! bytecode VM replays the tree-walk's arithmetic and RNG draws
//! exactly, and the shell replays `FusedBatch`'s step/truncate/reset
//! ordering exactly.  `rust/tests/batch_kernel.rs` and
//! `rust/tests/script_vm.rs` pin both halves.
//!
//! Lane isolation: list values are deep-cloned per lane (a naive
//! `Vec::clone` would share `Arc<Mutex<_>>` list cells across lanes and
//! let one lane's mutation corrupt another's episode).

use std::sync::Arc;

use crate::core::batch::{AffineEpilogue, BatchEnv, FusedChain, ObsAffine};
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::script::compile::CompiledProgram;
use crate::script::interp::Value;
use crate::script::vm::Vm;

/// Clone a value with fresh list cells (recursively) — lane columns
/// must not alias each other's `Arc<Mutex<_>>` lists.
fn deep_clone(v: &Value) -> Value {
    match v {
        Value::List(xs) => {
            let items = xs.lock().unwrap().iter().map(deep_clone).collect();
            Value::list(items)
        }
        other => other.clone(),
    }
}

/// A group of same-script lanes stepped by one shared VM — the batch
/// path behind `batch_capable` `Script/*` registry ids.
pub struct ScriptBatch {
    id: String,
    vm: Vm,
    obs_dim: usize,
    n_actions: usize,
    stream: u64,
    reset_f: u16,
    step_f: u16,
    /// Per-lane global columns (deep-cloned from the post-load
    /// snapshot, so every lane starts from the same top-level state).
    lane_globals: Vec<Vec<Option<Value>>>,
    rngs: Vec<Pcg32>,
    elapsed: Vec<u32>,
    max_steps: Option<u32>,
    obs_affine: Option<ObsAffine>,
    reward_affine: Option<(f32, f32)>,
}

impl ScriptBatch {
    /// Build a `lanes`-wide group over a shared compiled program.
    /// `stream` is the script's PCG stream id (the one its scalar
    /// [`ScriptEnv`](crate::script::envs::ScriptEnv) seeds with);
    /// `chain` is the fused wrapper chain
    /// ([`WrapperSpec::as_fused_chain`](crate::wrappers::WrapperSpec::as_fused_chain)).
    pub fn try_new(
        id: &str,
        program: Arc<CompiledProgram>,
        stream: u64,
        lanes: usize,
        chain: &FusedChain,
    ) -> Result<ScriptBatch> {
        assert!(lanes > 0, "a batch group needs at least one lane");
        let vm = Vm::with_program(program)
            .map_err(|e| CairlError::Script(format!("script env {id}: {e}")))?;
        let read_dim = |name: &str| -> Result<usize> {
            let value = vm.global(name).and_then(|v| v.as_num().ok()).ok_or_else(|| {
                CairlError::Script(format!("script env {id}: missing {name} global"))
            })?;
            if value < 1.0 {
                return Err(CairlError::Script(format!(
                    "script env {id}: {name} must be >= 1, got {value}"
                )));
            }
            Ok(value as usize)
        };
        let obs_dim = read_dim("obs_dim")?;
        let n_actions = read_dim("n_actions")?;
        let protocol_fn = |name: &str| -> Result<u16> {
            vm.func_index(name).ok_or_else(|| {
                CairlError::Script(format!("script env {id}: no function {name:?}"))
            })
        };
        let reset_f = protocol_fn("reset")?;
        let step_f = protocol_fn("step")?;
        let template = vm.globals_snapshot().to_vec();
        let lane_globals: Vec<Vec<Option<Value>>> = (0..lanes)
            .map(|_| template.iter().map(|g| g.as_ref().map(deep_clone)).collect())
            .collect();
        // NormalizeObs over an unbounded script space is the identity
        // map — derive it from the same space the scalar wrapper sees
        // so the two can never drift.
        let obs_affine = match &chain.epilogue {
            Some(AffineEpilogue::NormalizeObs) => Some(ObsAffine::from_space(&Space::box1(
                vec![f32::MIN; obs_dim],
                vec![f32::MAX; obs_dim],
            ))),
            _ => None,
        };
        let reward_affine = match &chain.epilogue {
            Some(AffineEpilogue::RewardScale { scale, shift }) => Some((*scale, *shift)),
            _ => None,
        };
        Ok(ScriptBatch {
            id: id.to_string(),
            vm,
            obs_dim,
            n_actions,
            stream,
            reset_f,
            step_f,
            lane_globals,
            rngs: (0..lanes).map(|_| Pcg32::new(0, stream)).collect(),
            elapsed: vec![0; lanes],
            max_steps: chain.max_steps,
            obs_affine,
            reward_affine,
        })
    }

    /// Run a protocol function against lane `k`'s global column.
    fn call_lane(&mut self, k: usize, f: u16, args: &[Value], ctx: &str) -> Value {
        let ScriptBatch { vm, lane_globals, rngs, id, .. } = self;
        vm.call_index_with(f, args, &mut lane_globals[k], &mut rngs[k])
            .unwrap_or_else(|e| panic!("{id}: {ctx}: {e}"))
    }

    fn unpack_list(&self, v: Value, want: usize, ctx: &str) -> Vec<f32> {
        match v {
            Value::List(xs) => {
                let xs = xs.lock().unwrap();
                assert_eq!(
                    xs.len(),
                    want,
                    "{}: {ctx} returned {} values, wanted {want}",
                    self.id,
                    xs.len()
                );
                xs.iter().map(|v| v.as_num().unwrap_or(f64::NAN) as f32).collect()
            }
            other => panic!("{}: {ctx} returned {other:?}, wanted a list", self.id),
        }
    }

    /// Reset without the obs epilogue (the caller applies it once, per
    /// the `FusedBatch` convention).
    fn reset_lane_inner(&mut self, k: usize, obs: &mut [f32]) {
        let v = self.call_lane(k, self.reset_f, &[], "reset()");
        let vals = self.unpack_list(v, self.obs_dim, "reset()");
        obs.copy_from_slice(&vals);
        self.elapsed[k] = 0;
    }
}

impl BatchEnv for ScriptBatch {
    fn lanes(&self) -> usize {
        self.lane_globals.len()
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: self.n_actions }
    }

    fn seed(&mut self, first_seed: u64) {
        for (k, rng) in self.rngs.iter_mut().enumerate() {
            *rng = Pcg32::new(first_seed + k as u64, self.stream);
        }
    }

    fn reset_lane(&mut self, k: usize, obs: &mut [f32]) {
        self.reset_lane_inner(k, obs);
        if let Some(affine) = &self.obs_affine {
            affine.apply(obs);
        }
    }

    fn step_lane(&mut self, k: usize, action: &Action, obs: &mut [f32]) -> Transition {
        let step_f = self.step_f;
        let v = self.call_lane(k, step_f, &[Value::Num(action.index() as f64)], "step()");
        let vals = self.unpack_list(v, self.obs_dim + 2, "step()");
        obs.copy_from_slice(&vals[..self.obs_dim]);
        let mut t = Transition {
            reward: vals[self.obs_dim],
            done: vals[self.obs_dim + 1] != 0.0,
            truncated: false,
        };
        self.elapsed[k] += 1;
        if let Some(max) = self.max_steps {
            if self.elapsed[k] >= max && !t.done {
                t.truncated = true;
            }
        }
        if let Some((scale, shift)) = self.reward_affine {
            t.reward = t.reward * scale + shift;
        }
        if t.done || t.truncated {
            self.reset_lane_inner(k, obs);
        }
        if let Some(affine) = &self.obs_affine {
            affine.apply(obs);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::env::Env;
    use crate::script::compile::compile_src;
    use crate::script::envs::{ScriptEnv, RenderHint, CARTPOLE_SRC};
    use crate::wrappers::TimeLimit;

    const CARTPOLE_STREAM: u64 = 0x9e3779b97f4a7c15;

    fn chain(max_steps: Option<u32>) -> FusedChain {
        FusedChain { max_steps, epilogue: None }
    }

    /// The load-bearing property: a batched script lane is bit-identical
    /// to the scalar TimeLimit(tree-walk ScriptEnv) stack, auto-reset
    /// included.
    #[test]
    fn batched_cartpole_matches_scalar_tree_walk_bitwise() {
        let lanes = 3;
        let limit = 25;
        let program = Arc::new(compile_src(CARTPOLE_SRC).unwrap());
        let mut batch = ScriptBatch::try_new(
            "Script/CartPole-v1",
            program,
            CARTPOLE_STREAM,
            lanes,
            &chain(Some(limit)),
        )
        .unwrap();
        batch.seed(41);
        let mut scalars: Vec<_> = (0..lanes)
            .map(|k| {
                let mut e = TimeLimit::new(
                    ScriptEnv::load(
                        "Script/CartPole-v1",
                        CARTPOLE_SRC,
                        CARTPOLE_STREAM,
                        RenderHint::CartPole,
                    ),
                    limit,
                );
                e.seed(41 + k as u64);
                e
            })
            .collect();
        let dim = batch.obs_dim();
        let mut obs = vec![0.0f32; lanes * dim];
        let mut tr = vec![Transition::default(); lanes];
        batch.reset_batch(&mut obs, dim);
        let mut ref_obs = vec![0.0f32; dim];
        for (k, e) in scalars.iter_mut().enumerate() {
            e.reset_into(&mut ref_obs);
            assert_eq!(&obs[k * dim..(k + 1) * dim], &ref_obs[..]);
        }
        for step in 0..120 {
            let actions: Vec<Action> =
                (0..lanes).map(|k| Action::Discrete((step + k) % 2)).collect();
            batch.step_batch(&actions, &mut obs, dim, &mut tr);
            for (k, e) in scalars.iter_mut().enumerate() {
                let t = e.step_into(&actions[k], &mut ref_obs);
                if t.done || t.truncated {
                    e.reset_into(&mut ref_obs);
                }
                assert_eq!(tr[k], t, "lane {k} step {step}");
                assert_eq!(
                    &obs[k * dim..(k + 1) * dim],
                    &ref_obs[..],
                    "lane {k} step {step}"
                );
            }
        }
    }

    #[test]
    fn lanes_do_not_alias_list_state() {
        // Global list state: with naive cloning every lane would share
        // one Arc'd list and the counters would interleave.
        let src = "obs_dim = 1; n_actions = 2; xs = zeros(1);\n\
                   def reset() { global xs; xs[0] = 0; return [xs[0]]; }\n\
                   def step(action) { global xs; xs[0] = xs[0] + 1; \
                   return [xs[0], 1.0, 0]; }";
        let program = Arc::new(compile_src(src).unwrap());
        let mut batch =
            ScriptBatch::try_new("Script/Counter", program, 7, 2, &chain(None)).unwrap();
        batch.seed(0);
        let mut obs = vec![0.0f32; 1];
        batch.reset_lane(0, &mut obs);
        batch.reset_lane(1, &mut obs);
        batch.step_lane(0, &Action::Discrete(0), &mut obs);
        batch.step_lane(0, &Action::Discrete(0), &mut obs);
        assert_eq!(obs[0], 2.0, "lane 0 stepped twice");
        batch.step_lane(1, &Action::Discrete(0), &mut obs);
        assert_eq!(obs[0], 1.0, "lane 1 stepped once, isolated from lane 0");
    }

    #[test]
    fn reseeding_reproduces_draws_per_lane() {
        let program = Arc::new(compile_src(CARTPOLE_SRC).unwrap());
        let mut batch = ScriptBatch::try_new(
            "Script/CartPole-v1",
            program,
            CARTPOLE_STREAM,
            2,
            &chain(None),
        )
        .unwrap();
        batch.seed(5);
        let dim = batch.obs_dim();
        let mut obs = vec![0.0f32; 2 * dim];
        batch.reset_batch(&mut obs, dim);
        assert_ne!(&obs[..dim], &obs[dim..], "lanes must differ");
        let first = obs.clone();
        batch.seed(5);
        batch.reset_batch(&mut obs, dim);
        assert_eq!(first, obs);
    }

    #[test]
    fn reward_scale_epilogue_applies_after_truncation_flags() {
        let src = "obs_dim = 1; n_actions = 2; x = 0;\n\
                   def reset() { global x; x = 0; return [x]; }\n\
                   def step(action) { global x; x = x + 1; return [x, 1.0, 0]; }";
        let program = Arc::new(compile_src(src).unwrap());
        let mut batch = ScriptBatch::try_new(
            "Script/Lin",
            program,
            7,
            1,
            &FusedChain {
                max_steps: Some(3),
                epilogue: Some(AffineEpilogue::RewardScale { scale: 2.0, shift: -0.5 }),
            },
        )
        .unwrap();
        batch.seed(0);
        let mut obs = vec![0.0f32; 1];
        batch.reset_lane(0, &mut obs);
        for step in 1..=6 {
            let t = batch.step_lane(0, &Action::Discrete(0), &mut obs);
            assert_eq!(t.reward, 1.5, "step {step}");
            assert_eq!(t.truncated, step % 3 == 0, "step {step}");
            // Auto-reset on truncation: obs restarts the count.
            let expect = if step % 3 == 0 { 0.0 } else { (step % 3) as f32 };
            assert_eq!(obs[0], expect, "step {step}");
        }
    }
}
