//! MiniScript lexer: source text -> token stream.
//!
//! The language is expression-oriented with C-style braces and
//! semicolons (no significant whitespace — keeps the parser simple while
//! the *interpreter* carries the Python-like dynamic costs, which is
//! what the baseline models).

use crate::core::error::{CairlError, Result};

/// One lexical token with its source line (for error messages).
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Num(f64),
    Ident(String),
    Str(String),
    // keywords
    Def,
    If,
    Elif,
    Else,
    While,
    For,
    Return,
    Break,
    Continue,
    True,
    False,
    None_,
    And,
    Or,
    Not,
    Global,
    // punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    // operators
    Assign,
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    PlusAssign,
    MinusAssign,
    Eof,
}

/// A token tagged with its 1-based source line.
#[derive(Clone, Debug, PartialEq)]
pub struct Spanned {
    pub tok: Tok,
    pub line: u32,
}

fn keyword(word: &str) -> Option<Tok> {
    Some(match word {
        "def" => Tok::Def,
        "if" => Tok::If,
        "elif" => Tok::Elif,
        "else" => Tok::Else,
        "while" => Tok::While,
        "for" => Tok::For,
        "return" => Tok::Return,
        "break" => Tok::Break,
        "continue" => Tok::Continue,
        "true" => Tok::True,
        "false" => Tok::False,
        "none" => Tok::None_,
        "and" => Tok::And,
        "or" => Tok::Or,
        "not" => Tok::Not,
        "global" => Tok::Global,
        _ => return None,
    })
}

/// Tokenise a full program.
pub fn lex(src: &str) -> Result<Vec<Spanned>> {
    let mut out = Vec::new();
    let bytes = src.as_bytes();
    let mut i = 0;
    let mut line = 1u32;
    let n = bytes.len();

    macro_rules! push {
        ($t:expr) => {
            out.push(Spanned { tok: $t, line })
        };
    }

    while i < n {
        let c = bytes[i] as char;
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            ' ' | '\t' | '\r' => i += 1,
            '#' => {
                // comment to end of line
                while i < n && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            '(' => {
                push!(Tok::LParen);
                i += 1;
            }
            ')' => {
                push!(Tok::RParen);
                i += 1;
            }
            '{' => {
                push!(Tok::LBrace);
                i += 1;
            }
            '}' => {
                push!(Tok::RBrace);
                i += 1;
            }
            '[' => {
                push!(Tok::LBracket);
                i += 1;
            }
            ']' => {
                push!(Tok::RBracket);
                i += 1;
            }
            ',' => {
                push!(Tok::Comma);
                i += 1;
            }
            ';' => {
                push!(Tok::Semi);
                i += 1;
            }
            '+' => {
                if i + 1 < n && bytes[i + 1] == b'=' {
                    push!(Tok::PlusAssign);
                    i += 2;
                } else {
                    push!(Tok::Plus);
                    i += 1;
                }
            }
            '-' => {
                if i + 1 < n && bytes[i + 1] == b'=' {
                    push!(Tok::MinusAssign);
                    i += 2;
                } else {
                    push!(Tok::Minus);
                    i += 1;
                }
            }
            '*' => {
                push!(Tok::Star);
                i += 1;
            }
            '/' => {
                push!(Tok::Slash);
                i += 1;
            }
            '%' => {
                push!(Tok::Percent);
                i += 1;
            }
            '=' => {
                if i + 1 < n && bytes[i + 1] == b'=' {
                    push!(Tok::Eq);
                    i += 2;
                } else {
                    push!(Tok::Assign);
                    i += 1;
                }
            }
            '!' => {
                if i + 1 < n && bytes[i + 1] == b'=' {
                    push!(Tok::Ne);
                    i += 2;
                } else {
                    return Err(CairlError::Script(format!("line {line}: lone '!'")));
                }
            }
            '<' => {
                if i + 1 < n && bytes[i + 1] == b'=' {
                    push!(Tok::Le);
                    i += 2;
                } else {
                    push!(Tok::Lt);
                    i += 1;
                }
            }
            '>' => {
                if i + 1 < n && bytes[i + 1] == b'=' {
                    push!(Tok::Ge);
                    i += 2;
                } else {
                    push!(Tok::Gt);
                    i += 1;
                }
            }
            '"' => {
                let start = i + 1;
                let mut j = start;
                while j < n && bytes[j] != b'"' {
                    if bytes[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
                if j == n {
                    return Err(CairlError::Script(format!(
                        "line {line}: unterminated string"
                    )));
                }
                push!(Tok::Str(src[start..j].to_string()));
                i = j + 1;
            }
            '0'..='9' | '.' => {
                let start = i;
                let mut seen_dot = false;
                while i < n {
                    match bytes[i] as char {
                        '0'..='9' => i += 1,
                        '.' if !seen_dot => {
                            seen_dot = true;
                            i += 1;
                        }
                        'e' | 'E' => {
                            i += 1;
                            if i < n && (bytes[i] == b'+' || bytes[i] == b'-') {
                                i += 1;
                            }
                        }
                        _ => break,
                    }
                }
                let text = &src[start..i];
                let v: f64 = text.parse().map_err(|_| {
                    CairlError::Script(format!("line {line}: bad number {text:?}"))
                })?;
                push!(Tok::Num(v));
            }
            'a'..='z' | 'A'..='Z' | '_' => {
                let start = i;
                while i < n
                    && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                }
                let word = &src[start..i];
                match keyword(word) {
                    Some(t) => push!(t),
                    None => push!(Tok::Ident(word.to_string())),
                }
            }
            other => {
                return Err(CairlError::Script(format!(
                    "line {line}: unexpected character {other:?}"
                )))
            }
        }
    }
    out.push(Spanned {
        tok: Tok::Eof,
        line,
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|s| s.tok).collect()
    }

    #[test]
    fn lexes_numbers_and_ops() {
        assert_eq!(
            toks("x = 1.5 + 2e3;"),
            vec![
                Tok::Ident("x".into()),
                Tok::Assign,
                Tok::Num(1.5),
                Tok::Plus,
                Tok::Num(2000.0),
                Tok::Semi,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn keywords_vs_identifiers() {
        assert_eq!(
            toks("if iffy"),
            vec![Tok::If, Tok::Ident("iffy".into()), Tok::Eof]
        );
    }

    #[test]
    fn comments_are_skipped() {
        assert_eq!(
            toks("x; # a comment\ny;"),
            vec![
                Tok::Ident("x".into()),
                Tok::Semi,
                Tok::Ident("y".into()),
                Tok::Semi,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn comparison_operators() {
        assert_eq!(
            toks("<= >= == != < >"),
            vec![Tok::Le, Tok::Ge, Tok::Eq, Tok::Ne, Tok::Lt, Tok::Gt, Tok::Eof]
        );
    }

    #[test]
    fn compound_assign() {
        assert_eq!(
            toks("x += 1; y -= 2;"),
            vec![
                Tok::Ident("x".into()),
                Tok::PlusAssign,
                Tok::Num(1.0),
                Tok::Semi,
                Tok::Ident("y".into()),
                Tok::MinusAssign,
                Tok::Num(2.0),
                Tok::Semi,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn tracks_line_numbers() {
        let spanned = lex("a;\nb;\nc;").unwrap();
        assert_eq!(spanned[0].line, 1);
        assert_eq!(spanned[2].line, 2);
        assert_eq!(spanned[4].line, 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(lex("x = @;").is_err());
        assert!(lex("\"unterminated").is_err());
    }

    #[test]
    fn strings() {
        assert_eq!(
            toks("\"hello\""),
            vec![Tok::Str("hello".into()), Tok::Eof]
        );
    }
}
