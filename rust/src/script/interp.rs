//! MiniScript tree-walking interpreter.
//!
//! Deliberately conventional (see the module docs in [`crate::script`]):
//! boxed values, string-keyed scope lookups, per-call frame allocation,
//! dynamic operator dispatch.  Do NOT optimise this module — it is the
//! measured baseline; making it fast would un-calibrate Fig. 1/2 and
//! Table II.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::script::ast::*;
use crate::script::parser::parse;

/// A dynamic MiniScript value (CPython `PyObject` analogue).
#[derive(Clone, Debug)]
pub enum Value {
    Num(f64),
    Bool(bool),
    Str(Arc<String>),
    List(Arc<Mutex<Vec<Value>>>),
    None,
}

impl Value {
    pub fn list(items: Vec<Value>) -> Value {
        Value::List(Arc::new(Mutex::new(items)))
    }

    pub fn as_num(&self) -> Result<f64> {
        match self {
            Value::Num(v) => Ok(*v),
            Value::Bool(b) => Ok(*b as u8 as f64),
            other => Err(CairlError::Script(format!("expected number, got {other:?}"))),
        }
    }

    pub fn truthy(&self) -> bool {
        match self {
            Value::Num(v) => *v != 0.0,
            Value::Bool(b) => *b,
            Value::Str(s) => !s.is_empty(),
            Value::List(xs) => !xs.lock().unwrap().is_empty(),
            Value::None => false,
        }
    }
}

enum Flow {
    Normal,
    Break,
    Continue,
    Return(Value),
}

/// A loaded MiniScript program with its global state.
pub struct Interpreter {
    funcs: HashMap<String, Arc<FuncDef>>,
    globals: HashMap<String, Value>,
    rng: Pcg32,
    /// Total statements executed (profiling / Fig.-1 accounting).
    pub steps_executed: u64,
}

struct Frame {
    locals: HashMap<String, Value>,
    global_decls: Vec<String>,
}

impl Interpreter {
    /// Parse `src` and run its top-level statements (builds globals).
    pub fn load(src: &str) -> Result<Interpreter> {
        let prog = parse(src)?;
        let mut interp = Interpreter {
            funcs: prog
                .funcs
                .into_iter()
                .map(|f| (f.name.clone(), Arc::new(f)))
                .collect(),
            globals: HashMap::new(),
            rng: Pcg32::new(0, 0xe7037ed1a0b428db),
            steps_executed: 0,
        };
        let mut top_frame = Frame {
            locals: HashMap::new(),
            global_decls: Vec::new(),
        };
        // Top-level assignments go straight to globals.
        for stmt in &prog.top {
            let flow = interp.exec_top(stmt, &mut top_frame)?;
            if !matches!(flow, Flow::Normal) {
                return Err(CairlError::Script(
                    "break/continue/return at top level".into(),
                ));
            }
        }
        Ok(interp)
    }

    /// Re-seed the interpreter's `uniform()` builtin.
    pub fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0xe7037ed1a0b428db);
    }

    /// Re-seed with an explicit PCG stream id.  [`ScriptEnv`]
    /// (crate::script::envs::ScriptEnv) uses the *same* stream id as the
    /// native counterpart env so that, for equal seeds, both runners draw
    /// identical reset noise — the cross-runner trajectory tests depend
    /// on this.
    pub fn seed_with_stream(&mut self, seed: u64, stream: u64) {
        self.rng = Pcg32::new(seed, stream);
    }

    /// Read a global variable.
    pub fn global(&self, name: &str) -> Option<&Value> {
        self.globals.get(name)
    }

    /// Call a script function by name.
    pub fn call(&mut self, name: &str, args: &[Value]) -> Result<Value> {
        let func = self
            .funcs
            .get(name)
            .cloned()
            .ok_or_else(|| CairlError::Script(format!("no function {name:?}")))?;
        if func.params.len() != args.len() {
            return Err(CairlError::Script(format!(
                "{name}() takes {} args, got {}",
                func.params.len(),
                args.len()
            )));
        }
        // A fresh frame per call — the CPython-frame analogue.
        let mut frame = Frame {
            locals: func
                .params
                .iter()
                .cloned()
                .zip(args.iter().cloned())
                .collect(),
            global_decls: Vec::new(),
        };
        match self.exec_block(&func.body, &mut frame)? {
            Flow::Return(v) => Ok(v),
            _ => Ok(Value::None),
        }
    }

    // ------------------------------------------------------------ exec

    /// Top-level statement: assignments bind globals directly.
    fn exec_top(&mut self, stmt: &Stmt, frame: &mut Frame) -> Result<Flow> {
        if let Stmt::Assign(name, e) = stmt {
            let v = self.eval(e, frame)?;
            self.globals.insert(name.clone(), v);
            self.steps_executed += 1;
            return Ok(Flow::Normal);
        }
        self.exec(stmt, frame)
    }

    fn exec_block(&mut self, stmts: &[Stmt], frame: &mut Frame) -> Result<Flow> {
        for s in stmts {
            match self.exec(s, frame)? {
                Flow::Normal => {}
                other => return Ok(other),
            }
        }
        Ok(Flow::Normal)
    }

    fn exec(&mut self, stmt: &Stmt, frame: &mut Frame) -> Result<Flow> {
        self.steps_executed += 1;
        match stmt {
            Stmt::Assign(name, e) => {
                let v = self.eval(e, frame)?;
                if frame.global_decls.iter().any(|g| g == name) {
                    self.globals.insert(name.clone(), v);
                } else {
                    frame.locals.insert(name.clone(), v);
                }
                Ok(Flow::Normal)
            }
            Stmt::IndexAssign(name, idx, e) => {
                let i = self.eval(idx, frame)?.as_num()? as usize;
                let v = self.eval(e, frame)?;
                let target = self.lookup(name, frame)?;
                match target {
                    Value::List(xs) => {
                        let mut xs = xs.lock().unwrap();
                        if i >= xs.len() {
                            return Err(CairlError::Script(format!(
                                "index {i} out of range (len {})",
                                xs.len()
                            )));
                        }
                        xs[i] = v;
                        Ok(Flow::Normal)
                    }
                    other => Err(CairlError::Script(format!(
                        "cannot index into {other:?}"
                    ))),
                }
            }
            Stmt::Expr(e) => {
                self.eval(e, frame)?;
                Ok(Flow::Normal)
            }
            Stmt::If { arms, else_body } => {
                for (cond, body) in arms {
                    if self.eval(cond, frame)?.truthy() {
                        return self.exec_block(body, frame);
                    }
                }
                self.exec_block(else_body, frame)
            }
            Stmt::While(cond, body) => {
                while self.eval(cond, frame)?.truthy() {
                    match self.exec_block(body, frame)? {
                        Flow::Break => break,
                        Flow::Continue | Flow::Normal => {}
                        ret @ Flow::Return(_) => return Ok(ret),
                    }
                }
                Ok(Flow::Normal)
            }
            Stmt::For(var, start, stop, body) => {
                let s = self.eval(start, frame)?.as_num()?;
                let e = self.eval(stop, frame)?.as_num()?;
                let mut i = s;
                while i < e {
                    frame.locals.insert(var.clone(), Value::Num(i));
                    match self.exec_block(body, frame)? {
                        Flow::Break => break,
                        Flow::Continue | Flow::Normal => {}
                        ret @ Flow::Return(_) => return Ok(ret),
                    }
                    i += 1.0;
                }
                Ok(Flow::Normal)
            }
            Stmt::Return(e) => {
                let v = match e {
                    Some(e) => self.eval(e, frame)?,
                    None => Value::None,
                };
                Ok(Flow::Return(v))
            }
            Stmt::Break => Ok(Flow::Break),
            Stmt::Continue => Ok(Flow::Continue),
            Stmt::Global(name) => {
                frame.global_decls.push(name.clone());
                Ok(Flow::Normal)
            }
        }
    }

    fn lookup(&self, name: &str, frame: &Frame) -> Result<Value> {
        // LOAD_FAST then LOAD_GLOBAL, both dict probes.
        if let Some(v) = frame.locals.get(name) {
            return Ok(v.clone());
        }
        if let Some(v) = self.globals.get(name) {
            return Ok(v.clone());
        }
        Err(CairlError::Script(format!("undefined variable {name:?}")))
    }

    // ------------------------------------------------------------ eval

    fn eval(&mut self, e: &Expr, frame: &mut Frame) -> Result<Value> {
        match e {
            Expr::Num(v) => Ok(Value::Num(*v)),
            Expr::Bool(b) => Ok(Value::Bool(*b)),
            Expr::Str(s) => Ok(Value::Str(Arc::new(s.clone()))),
            Expr::None_ => Ok(Value::None),
            Expr::Var(name) => self.lookup(name, frame),
            Expr::List(items) => {
                let mut out = Vec::with_capacity(items.len());
                for it in items {
                    out.push(self.eval(it, frame)?);
                }
                Ok(Value::list(out))
            }
            Expr::Index(target, idx) => {
                let xs = self.eval(target, frame)?;
                let i = self.eval(idx, frame)?.as_num()? as usize;
                match xs {
                    Value::List(xs) => {
                        let xs = xs.lock().unwrap();
                        xs.get(i).cloned().ok_or_else(|| {
                            CairlError::Script(format!(
                                "index {i} out of range (len {})",
                                xs.len()
                            ))
                        })
                    }
                    other => Err(CairlError::Script(format!(
                        "cannot index into {other:?}"
                    ))),
                }
            }
            Expr::Un(op, inner) => {
                let v = self.eval(inner, frame)?;
                match op {
                    UnOp::Neg => Ok(Value::Num(-v.as_num()?)),
                    UnOp::Not => Ok(Value::Bool(!v.truthy())),
                }
            }
            Expr::Bin(op, lhs, rhs) => {
                // Short-circuit logic first.
                if *op == BinOp::And {
                    let l = self.eval(lhs, frame)?;
                    if !l.truthy() {
                        return Ok(Value::Bool(false));
                    }
                    return Ok(Value::Bool(self.eval(rhs, frame)?.truthy()));
                }
                if *op == BinOp::Or {
                    let l = self.eval(lhs, frame)?;
                    if l.truthy() {
                        return Ok(Value::Bool(true));
                    }
                    return Ok(Value::Bool(self.eval(rhs, frame)?.truthy()));
                }
                let l = self.eval(lhs, frame)?;
                let r = self.eval(rhs, frame)?;
                let a = l.as_num()?;
                let b = r.as_num()?;
                Ok(match op {
                    BinOp::Add => Value::Num(a + b),
                    BinOp::Sub => Value::Num(a - b),
                    BinOp::Mul => Value::Num(a * b),
                    BinOp::Div => Value::Num(a / b),
                    BinOp::Mod => Value::Num(a.rem_euclid(b)),
                    BinOp::Eq => Value::Bool(a == b),
                    BinOp::Ne => Value::Bool(a != b),
                    BinOp::Lt => Value::Bool(a < b),
                    BinOp::Le => Value::Bool(a <= b),
                    BinOp::Gt => Value::Bool(a > b),
                    BinOp::Ge => Value::Bool(a >= b),
                    BinOp::And | BinOp::Or => unreachable!(),
                })
            }
            Expr::Call(name, args) => {
                let mut vals = Vec::with_capacity(args.len());
                for a in args {
                    vals.push(self.eval(a, frame)?);
                }
                self.call_any(name, vals)
            }
        }
    }

    fn call_any(&mut self, name: &str, args: Vec<Value>) -> Result<Value> {
        // Builtins take precedence (like CPython's builtins module probe
        // after globals miss — inverted here for simplicity; scripts don't
        // shadow builtins).
        if let Some(v) = self.builtin(name, &args)? {
            return Ok(v);
        }
        self.call(name, &args)
    }

    /// Math/builtin dispatch.  Returns Ok(None) when `name` is not a
    /// builtin (fall through to user functions).
    fn builtin(&mut self, name: &str, args: &[Value]) -> Result<Option<Value>> {
        let n1 = |args: &[Value]| -> Result<f64> { args[0].as_num() };
        let v = match (name, args.len()) {
            ("cos", 1) => Value::Num(n1(args)?.cos()),
            ("sin", 1) => Value::Num(n1(args)?.sin()),
            ("tan", 1) => Value::Num(n1(args)?.tan()),
            ("sqrt", 1) => Value::Num(n1(args)?.sqrt()),
            ("exp", 1) => Value::Num(n1(args)?.exp()),
            ("ln", 1) => Value::Num(n1(args)?.ln()),
            ("abs", 1) => Value::Num(n1(args)?.abs()),
            ("floor", 1) => Value::Num(n1(args)?.floor()),
            ("ceil", 1) => Value::Num(n1(args)?.ceil()),
            ("sign", 1) => Value::Num(n1(args)?.signum()),
            ("pow", 2) => Value::Num(n1(args)?.powf(args[1].as_num()?)),
            ("min", 2) => Value::Num(n1(args)?.min(args[1].as_num()?)),
            ("max", 2) => Value::Num(n1(args)?.max(args[1].as_num()?)),
            ("clamp", 3) => Value::Num(
                n1(args)?
                    .max(args[1].as_num()?)
                    .min(args[2].as_num()?),
            ),
            ("pi", 0) => Value::Num(std::f64::consts::PI),
            ("uniform", 2) => {
                let lo = args[0].as_num()?;
                let hi = args[1].as_num()?;
                Value::Num(lo + (hi - lo) * self.rng.next_f64())
            }
            ("len", 1) => match &args[0] {
                Value::List(xs) => Value::Num(xs.lock().unwrap().len() as f64),
                other => {
                    return Err(CairlError::Script(format!("len of {other:?}")))
                }
            },
            ("push", 2) => match &args[0] {
                Value::List(xs) => {
                    xs.lock().unwrap().push(args[1].clone());
                    Value::None
                }
                other => {
                    return Err(CairlError::Script(format!("push to {other:?}")))
                }
            },
            ("zeros", 1) => {
                let n = n1(args)? as usize;
                Value::list(vec![Value::Num(0.0); n])
            }
            _ => return Ok(None),
        };
        Ok(Some(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str, func: &str, args: &[Value]) -> Value {
        let mut interp = Interpreter::load(src).unwrap();
        interp.call(func, args).unwrap()
    }

    #[test]
    fn arithmetic_and_return() {
        let v = run("def f(a, b) { return a * 10 + b; }", "f",
                    &[Value::Num(4.0), Value::Num(2.0)]);
        assert_eq!(v.as_num().unwrap(), 42.0);
    }

    #[test]
    fn globals_persist_between_calls() {
        let src = "count = 0; def bump() { global count; count = count + 1; return count; }";
        let mut interp = Interpreter::load(src).unwrap();
        assert_eq!(interp.call("bump", &[]).unwrap().as_num().unwrap(), 1.0);
        assert_eq!(interp.call("bump", &[]).unwrap().as_num().unwrap(), 2.0);
        assert_eq!(interp.global("count").unwrap().as_num().unwrap(), 2.0);
    }

    #[test]
    fn locals_do_not_leak_without_global() {
        let src = "x = 5; def f() { x = 10; return x; } def g() { return x; }";
        let mut interp = Interpreter::load(src).unwrap();
        assert_eq!(interp.call("f", &[]).unwrap().as_num().unwrap(), 10.0);
        assert_eq!(interp.call("g", &[]).unwrap().as_num().unwrap(), 5.0);
    }

    #[test]
    fn while_loop_with_break_continue() {
        let src = "def f() { s = 0; i = 0; while (true) { i += 1; if (i > 10) { break; } \
                   if (i % 2 == 0) { continue; } s += i; } return s; }";
        let v = run(src, "f", &[]);
        assert_eq!(v.as_num().unwrap(), 25.0); // 1+3+5+7+9
    }

    #[test]
    fn for_loop_sums() {
        let v = run("def f() { s = 0; for i = 0, 10 { s += i; } return s; }", "f", &[]);
        assert_eq!(v.as_num().unwrap(), 45.0);
    }

    #[test]
    fn lists_index_and_mutate() {
        let src = "def f() { xs = zeros(3); xs[1] = 7; push(xs, 9); \
                   return xs[1] + xs[3] + len(xs); }";
        assert_eq!(run(src, "f", &[]).as_num().unwrap(), 20.0);
    }

    #[test]
    fn builtin_math() {
        let v = run("def f() { return clamp(cos(0) * 5, 0, 2) + sqrt(16); }", "f", &[]);
        assert_eq!(v.as_num().unwrap(), 6.0);
    }

    #[test]
    fn uniform_is_seeded() {
        let src = "def f() { return uniform(-1, 1); }";
        let mut a = Interpreter::load(src).unwrap();
        let mut b = Interpreter::load(src).unwrap();
        a.seed(42);
        b.seed(42);
        for _ in 0..10 {
            let va = a.call("f", &[]).unwrap().as_num().unwrap();
            let vb = b.call("f", &[]).unwrap().as_num().unwrap();
            assert_eq!(va, vb);
            assert!((-1.0..1.0).contains(&va));
        }
    }

    #[test]
    fn user_functions_call_each_other() {
        let src = "def sq(x) { return x * x; } def f(x) { return sq(x) + sq(x + 1); }";
        assert_eq!(run(src, "f", &[Value::Num(2.0)]).as_num().unwrap(), 13.0);
    }

    #[test]
    fn recursion_works() {
        let src = "def fib(n) { if (n < 2) { return n; } return fib(n - 1) + fib(n - 2); }";
        assert_eq!(run(src, "fib", &[Value::Num(10.0)]).as_num().unwrap(), 55.0);
    }

    #[test]
    fn short_circuit_logic() {
        // Division by zero on the rhs must not be evaluated.
        let src = "def f() { x = 0; if (x != 0 and 1 / x > 0) { return 1; } return 0; }";
        assert_eq!(run(src, "f", &[]).as_num().unwrap(), 0.0);
    }

    #[test]
    fn errors_are_reported() {
        let mut interp = Interpreter::load("def f() { return missing; }").unwrap();
        assert!(interp.call("f", &[]).is_err());
        assert!(interp.call("nope", &[]).is_err());
        let mut i2 = Interpreter::load("def f() { xs = zeros(2); return xs[5]; }").unwrap();
        assert!(i2.call("f", &[]).is_err());
    }

    #[test]
    fn elif_chains() {
        let src = "def f(x) { if (x > 0) { return 1; } elif (x < 0) { return -1; } \
                   else { return 0; } }";
        assert_eq!(run(src, "f", &[Value::Num(5.0)]).as_num().unwrap(), 1.0);
        assert_eq!(run(src, "f", &[Value::Num(-5.0)]).as_num().unwrap(), -1.0);
        assert_eq!(run(src, "f", &[Value::Num(0.0)]).as_num().unwrap(), 0.0);
    }
}
