//! MiniScript AST → register-bytecode compiler.
//!
//! Lowers the tree ([`crate::script::ast`]) to the compact register
//! bytecode executed by [`Vm`](crate::script::vm::Vm), modeled on the
//! in-repo Flash VM (`rust/src/flash/vm.rs`) but register-based: every
//! expression compiles into a destination register inside a per-call
//! register window, so the hot loop is a flat `match` over [`Op`]s with
//! no tree recursion, no string-keyed scope probes and no per-node
//! dispatch.
//!
//! The contract is **observable equivalence with the tree-walk
//! interpreter** ([`crate::script::interp::Interpreter`]): the same f64
//! arithmetic in the same order, the same `uniform()` RNG draw
//! sequence, and the same runtime error messages raised lazily at the
//! same execution points.  Calls to unknown functions or with the wrong
//! arity compile to an [`Op::Trap`] *after* the argument evaluation
//! code, so they fail exactly when (and only if) the tree-walk would.
//! `rust/tests/script_vm.rs` pins the equivalence over the shipped
//! scripts and an adversarial corpus; `rust/tests/batch_kernel.rs` pins
//! it transitively for batched lanes.
//!
//! Variable resolution is static: per function, `global` declarations
//! select [`Op::StoreGlobal`] targets, every other assigned name gets a
//! local register slot, and reads compile to [`Op::LoadVar`] which
//! replays the interpreter's locals-then-globals probe (a local slot
//! that has not been written yet falls through to the global, then to
//! the `undefined variable` error).  One deliberate approximation:
//! `global` declarations are hoisted to function scope at compile time,
//! where the tree-walk applies them at their execution point — scripts
//! that declare `global` before assigning, as every shipped source
//! does, behave identically.

use std::collections::HashMap;
use std::sync::Arc;

use crate::core::error::{CairlError, Result};
use crate::script::ast::{BinOp, Expr, FuncDef, Program as Ast, Stmt, UnOp};
use crate::script::interp::Value;
use crate::script::parser::parse;

/// Sentinel register / slot index meaning "absent".
pub const NO_REG: u16 = u16::MAX;

/// Builtin functions, resolved at compile time by `(name, arity)` —
/// the same key the tree-walk matches at call time, so a wrong-arity
/// builtin name falls through to user functions exactly as it does
/// there.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[allow(missing_docs)]
pub enum Builtin {
    Cos,
    Sin,
    Tan,
    Sqrt,
    Exp,
    Ln,
    Abs,
    Floor,
    Ceil,
    Sign,
    Pow,
    Min,
    Max,
    Clamp,
    Pi,
    Uniform,
    Len,
    Push,
    Zeros,
}

impl Builtin {
    /// Resolve a call-site `(name, argc)` pair to a builtin, mirroring
    /// the tree-walk's `(name, args.len())` match arms one for one.
    pub fn resolve(name: &str, argc: usize) -> Option<Builtin> {
        Some(match (name, argc) {
            ("cos", 1) => Builtin::Cos,
            ("sin", 1) => Builtin::Sin,
            ("tan", 1) => Builtin::Tan,
            ("sqrt", 1) => Builtin::Sqrt,
            ("exp", 1) => Builtin::Exp,
            ("ln", 1) => Builtin::Ln,
            ("abs", 1) => Builtin::Abs,
            ("floor", 1) => Builtin::Floor,
            ("ceil", 1) => Builtin::Ceil,
            ("sign", 1) => Builtin::Sign,
            ("pow", 2) => Builtin::Pow,
            ("min", 2) => Builtin::Min,
            ("max", 2) => Builtin::Max,
            ("clamp", 3) => Builtin::Clamp,
            ("pi", 0) => Builtin::Pi,
            ("uniform", 2) => Builtin::Uniform,
            ("len", 1) => Builtin::Len,
            ("push", 2) => Builtin::Push,
            ("zeros", 1) => Builtin::Zeros,
            _ => return None,
        })
    }
}

/// One register-bytecode instruction.  Register operands index the
/// current call's register window; jump targets are absolute code
/// offsets.
#[derive(Clone, Copy, Debug)]
#[allow(missing_docs)]
pub enum Op {
    /// `dst = consts[idx]`.
    Const { dst: u16, idx: u16 },
    /// `dst = src`.
    Move { dst: u16, src: u16 },
    /// The interpreter's locals-then-globals probe: `dst` gets the
    /// local `slot` if it has been written, else global `global` if
    /// set, else the run errors with `undefined variable
    /// strings[name]`.  Either index may be [`NO_REG`].
    LoadVar { dst: u16, slot: u16, global: u16, name: u16 },
    /// `globals[idx] = src`.
    StoreGlobal { idx: u16, src: u16 },
    /// `dst = Num(as_num(src))` — the interpreter's eager numeric
    /// conversion points (`for` bounds, index expressions).
    AsNum { dst: u16, src: u16 },
    Add { dst: u16, a: u16, b: u16 },
    Sub { dst: u16, a: u16, b: u16 },
    Mul { dst: u16, a: u16, b: u16 },
    Div { dst: u16, a: u16, b: u16 },
    /// Euclidean remainder, like the tree-walk's `%`.
    Mod { dst: u16, a: u16, b: u16 },
    Eq { dst: u16, a: u16, b: u16 },
    Ne { dst: u16, a: u16, b: u16 },
    Lt { dst: u16, a: u16, b: u16 },
    Le { dst: u16, a: u16, b: u16 },
    Gt { dst: u16, a: u16, b: u16 },
    Ge { dst: u16, a: u16, b: u16 },
    Neg { dst: u16, src: u16 },
    Not { dst: u16, src: u16 },
    /// `dst = Bool(truthy(src))` — the `and`/`or` result coercion.
    Truthy { dst: u16, src: u16 },
    Jmp(u32),
    JmpIfFalse { cond: u16, to: u32 },
    JmpIfTrue { cond: u16, to: u32 },
    /// `dst = [regs[start], ..., regs[start + n - 1]]` (fresh list).
    MakeList { dst: u16, start: u16, n: u16 },
    /// `dst = xs[idx]` with the interpreter's conversion/bounds errors.
    IndexGet { dst: u16, xs: u16, idx: u16 },
    /// `xs[idx] = src` (idx already numeric via [`Op::AsNum`]).
    IndexSet { xs: u16, idx: u16, src: u16 },
    /// Call `funcs[func]` with `argc` args at `regs[start..]`.
    CallFn { dst: u16, func: u16, start: u16, argc: u16 },
    /// Dispatch a [`Builtin`] over `argc` args at `regs[start..]`.
    CallBuiltin { dst: u16, builtin: Builtin, start: u16, argc: u16 },
    /// Return `src` to the caller (or finish the run at depth 0).
    Return { src: u16 },
    /// Return `None` (fallthrough off a function body, bare `return`,
    /// `break`/`continue` outside any loop inside a function).
    ReturnNone,
    /// Raise `CairlError::Script(strings[msg])` — pre-formatted
    /// call-resolution and top-level-flow errors, raised lazily.
    Trap { msg: u16 },
}

/// A compiled function's metadata.
#[derive(Clone, Debug)]
pub struct FuncInfo {
    /// Source name (error messages, [`Vm::call`](crate::script::vm::Vm::call)).
    pub name: String,
    /// Absolute entry offset into [`CompiledProgram::code`].
    pub entry: u32,
    /// Number of parameters (arity checks).
    pub n_params: u16,
    /// Register window size (params + locals + temps).
    pub n_regs: u16,
}

/// A compiled MiniScript program — immutable and shareable: VMs hold an
/// `Arc<CompiledProgram>` and keep all mutable state (globals,
/// registers, RNG) on the side, which is what lets one program step
/// many batch lanes.
#[derive(Clone, Debug, Default)]
pub struct CompiledProgram {
    /// Flat instruction stream (top-level first, then each function).
    pub code: Vec<Op>,
    /// Deduplicated constant pool.
    pub consts: Vec<Value>,
    /// Identifier / trap-message pool.
    pub strings: Vec<String>,
    /// Function table in definition order (duplicates kept; the map
    /// below points at the last definition, like the tree-walk).
    pub funcs: Vec<FuncInfo>,
    /// Function name → index of its (last) definition.
    pub func_map: HashMap<String, u16>,
    /// Global variable names in slot order.
    pub global_names: Vec<String>,
    /// Global name → slot.
    pub global_map: HashMap<String, u16>,
    /// Entry offset of the top-level statement code.
    pub top_entry: u32,
    /// Register window size of the top-level code.
    pub top_regs: u16,
}

/// Compile MiniScript source text (parse + lower).
pub fn compile_src(src: &str) -> Result<CompiledProgram> {
    compile(&parse(src)?)
}

/// Lower a parsed program to bytecode.
pub fn compile(prog: &Ast) -> Result<CompiledProgram> {
    let mut c = Compiler::default();
    // Pass 1: the global name space — top-level direct-assign targets
    // plus every `global` declaration anywhere (the only ways the
    // interpreter's globals map ever gains a key).
    for s in &prog.top {
        if let Stmt::Assign(name, _) = s {
            c.global_idx(name)?;
        }
    }
    let mut g_top = Vec::new();
    collect_global_decls(&prog.top, &mut g_top);
    for name in &g_top {
        c.global_idx(name)?;
    }
    for f in &prog.funcs {
        let mut g = Vec::new();
        collect_global_decls(&f.body, &mut g);
        for name in &g {
            c.global_idx(name)?;
        }
    }
    // Pass 2: the function table, before any body compiles (forward
    // references).  Last duplicate wins, like the tree-walk's HashMap.
    if prog.funcs.len() >= u16::MAX as usize {
        return Err(CairlError::Script("script too large: function table overflow".into()));
    }
    for (i, f) in prog.funcs.iter().enumerate() {
        if f.params.len() >= NO_REG as usize {
            return Err(CairlError::Script(format!(
                "{}(): too many parameters",
                f.name
            )));
        }
        c.funcs.push(FuncInfo {
            name: f.name.clone(),
            entry: 0,
            n_params: f.params.len() as u16,
            n_regs: 0,
        });
        c.func_map.insert(f.name.clone(), i as u16);
    }
    // Pass 3: code.
    let (top_entry, top_regs) = c.compile_top(prog, &g_top)?;
    for (i, f) in prog.funcs.iter().enumerate() {
        c.compile_func(i, f)?;
    }
    Ok(CompiledProgram {
        code: c.code,
        consts: c.consts,
        strings: c.strings,
        funcs: c.funcs,
        func_map: c.func_map,
        global_names: c.global_names,
        global_map: c.global_map,
        top_entry,
        top_regs,
    })
}

/// Collect `global` declarations recursively (compile-time hoisting).
fn collect_global_decls(stmts: &[Stmt], out: &mut Vec<String>) {
    for s in stmts {
        match s {
            Stmt::Global(name) => {
                if !out.iter().any(|n| n == name) {
                    out.push(name.clone());
                }
            }
            Stmt::If { arms, else_body } => {
                for (_, body) in arms {
                    collect_global_decls(body, out);
                }
                collect_global_decls(else_body, out);
            }
            Stmt::While(_, body) | Stmt::For(_, _, _, body) => {
                collect_global_decls(body, out);
            }
            _ => {}
        }
    }
}

/// Collect assignment-target names recursively.
fn collect_assign_targets(stmts: &[Stmt], out: &mut Vec<String>) {
    for s in stmts {
        match s {
            Stmt::Assign(name, _) => push_unique(out, name),
            Stmt::If { arms, else_body } => {
                for (_, body) in arms {
                    collect_assign_targets(body, out);
                }
                collect_assign_targets(else_body, out);
            }
            Stmt::While(_, body) | Stmt::For(_, _, _, body) => {
                collect_assign_targets(body, out);
            }
            _ => {}
        }
    }
}

/// Collect `for`-loop variables recursively — these are *always* local
/// (the tree-walk writes the counter straight into the frame's locals,
/// `global` declaration or not).
fn collect_for_vars(stmts: &[Stmt], out: &mut Vec<String>) {
    for s in stmts {
        match s {
            Stmt::For(var, _, _, body) => {
                push_unique(out, var);
                collect_for_vars(body, out);
            }
            Stmt::If { arms, else_body } => {
                for (_, body) in arms {
                    collect_for_vars(body, out);
                }
                collect_for_vars(else_body, out);
            }
            Stmt::While(_, body) => collect_for_vars(body, out),
            _ => {}
        }
    }
}

fn push_unique(out: &mut Vec<String>, name: &str) {
    if !out.iter().any(|n| n == name) {
        out.push(name.to_string());
    }
}

/// Constant-pool dedup key (`f64` by bit pattern).
#[derive(Clone, PartialEq, Eq, Hash)]
enum ConstKey {
    Num(u64),
    Bool(bool),
    Str(String),
    None,
}

/// An open loop: `break`/`continue` jump fixups.
struct LoopCtx {
    break_patches: Vec<usize>,
    continue_patches: Vec<usize>,
    /// `while` knows its condition label up front; `for` patches to the
    /// increment section after the body.
    continue_to: Option<u32>,
}

/// Per-function compilation state: the slot map and a stack-discipline
/// temp allocator (statements mark/reset, so temp pressure is the
/// deepest expression, not the function length).
struct FnScope {
    slots: HashMap<String, u16>,
    global_decls: Vec<String>,
    next: u16,
    max: u16,
    loops: Vec<LoopCtx>,
    /// Top-level code: `break`/`continue`/`return` that escape every
    /// loop trap instead of returning.
    top: bool,
}

impl FnScope {
    fn new(top: bool) -> FnScope {
        FnScope {
            slots: HashMap::new(),
            global_decls: Vec::new(),
            next: 0,
            max: 0,
            loops: Vec::new(),
            top,
        }
    }

    fn alloc(&mut self) -> Result<u16> {
        if self.next + 1 >= NO_REG {
            return Err(CairlError::Script(
                "script too large: register window overflow".into(),
            ));
        }
        let r = self.next;
        self.next += 1;
        if self.next > self.max {
            self.max = self.next;
        }
        Ok(r)
    }

    fn mark(&self) -> u16 {
        self.next
    }

    fn reset(&mut self, m: u16) {
        self.next = m;
    }

    fn add_slot(&mut self, name: &str) -> Result<()> {
        if !self.slots.contains_key(name) {
            let r = self.alloc()?;
            self.slots.insert(name.to_string(), r);
        }
        Ok(())
    }

    fn is_global(&self, name: &str) -> bool {
        self.global_decls.iter().any(|n| n == name)
    }
}

#[derive(Default)]
struct Compiler {
    code: Vec<Op>,
    consts: Vec<Value>,
    const_map: HashMap<ConstKey, u16>,
    strings: Vec<String>,
    string_map: HashMap<String, u16>,
    funcs: Vec<FuncInfo>,
    func_map: HashMap<String, u16>,
    global_names: Vec<String>,
    global_map: HashMap<String, u16>,
}

impl Compiler {
    fn emit(&mut self, op: Op) -> usize {
        self.code.push(op);
        self.code.len() - 1
    }

    fn here(&self) -> u32 {
        self.code.len() as u32
    }

    fn patch_to(&mut self, at: usize, to: u32) {
        match &mut self.code[at] {
            Op::Jmp(t) | Op::JmpIfFalse { to: t, .. } | Op::JmpIfTrue { to: t, .. } => *t = to,
            other => unreachable!("patch target {other:?} is not a jump"),
        }
    }

    fn patch_here(&mut self, at: usize) {
        let to = self.here();
        self.patch_to(at, to);
    }

    fn global_idx(&mut self, name: &str) -> Result<u16> {
        if let Some(&i) = self.global_map.get(name) {
            return Ok(i);
        }
        if self.global_names.len() >= NO_REG as usize {
            return Err(CairlError::Script("script too large: too many globals".into()));
        }
        let i = self.global_names.len() as u16;
        self.global_names.push(name.to_string());
        self.global_map.insert(name.to_string(), i);
        Ok(i)
    }

    fn const_idx(&mut self, key: ConstKey) -> Result<u16> {
        if let Some(&i) = self.const_map.get(&key) {
            return Ok(i);
        }
        if self.consts.len() >= u16::MAX as usize {
            return Err(CairlError::Script("script too large: constant pool overflow".into()));
        }
        let v = match &key {
            ConstKey::Num(bits) => Value::Num(f64::from_bits(*bits)),
            ConstKey::Bool(b) => Value::Bool(*b),
            ConstKey::Str(s) => Value::Str(Arc::new(s.clone())),
            ConstKey::None => Value::None,
        };
        let i = self.consts.len() as u16;
        self.consts.push(v);
        self.const_map.insert(key, i);
        Ok(i)
    }

    fn string_idx(&mut self, s: &str) -> Result<u16> {
        if let Some(&i) = self.string_map.get(s) {
            return Ok(i);
        }
        if self.strings.len() >= u16::MAX as usize {
            return Err(CairlError::Script("script too large: string pool overflow".into()));
        }
        let i = self.strings.len() as u16;
        self.strings.push(s.to_string());
        self.string_map.insert(s.to_string(), i);
        Ok(i)
    }

    fn emit_trap(&mut self, msg: &str) -> Result<()> {
        let i = self.string_idx(msg)?;
        self.emit(Op::Trap { msg: i });
        Ok(())
    }

    // -------------------------------------------------------- drivers

    /// Top-level statement code: direct assignments store globals (the
    /// interpreter's `exec_top` special case), everything else runs
    /// under normal scoping with the top-level `global` declarations.
    fn compile_top(&mut self, prog: &Ast, g_top: &[String]) -> Result<(u32, u16)> {
        let mut scope = FnScope::new(true);
        scope.global_decls = g_top.to_vec();
        // Locals of the top-level frame: names assigned inside nested
        // statements (not `global`-declared) plus `for` variables —
        // direct assignments bypass the frame entirely.
        let mut for_vars = Vec::new();
        let mut targets = Vec::new();
        for s in &prog.top {
            if !matches!(s, Stmt::Assign(..)) {
                collect_for_vars(std::slice::from_ref(s), &mut for_vars);
                collect_assign_targets(std::slice::from_ref(s), &mut targets);
            }
        }
        for name in &for_vars {
            scope.add_slot(name)?;
        }
        for name in &targets {
            if !scope.is_global(name) {
                scope.add_slot(name)?;
            }
        }
        let entry = self.here();
        for s in &prog.top {
            if let Stmt::Assign(name, e) = s {
                let m = scope.mark();
                let t = self.expr(&mut scope, e)?;
                let g = self.global_map[name.as_str()];
                self.emit(Op::StoreGlobal { idx: g, src: t });
                scope.reset(m);
            } else {
                self.stmt(&mut scope, s)?;
            }
        }
        self.emit(Op::ReturnNone);
        Ok((entry, scope.max))
    }

    fn compile_func(&mut self, idx: usize, def: &FuncDef) -> Result<()> {
        let mut scope = FnScope::new(false);
        collect_global_decls(&def.body, &mut scope.global_decls);
        for p in &def.params {
            scope.add_slot(p)?;
        }
        let mut for_vars = Vec::new();
        collect_for_vars(&def.body, &mut for_vars);
        for name in &for_vars {
            scope.add_slot(name)?;
        }
        let mut targets = Vec::new();
        collect_assign_targets(&def.body, &mut targets);
        for name in &targets {
            if !scope.is_global(name) {
                scope.add_slot(name)?;
            }
        }
        self.funcs[idx].entry = self.here();
        self.block(&mut scope, &def.body)?;
        self.emit(Op::ReturnNone);
        self.funcs[idx].n_regs = scope.max;
        Ok(())
    }

    // ----------------------------------------------------- statements

    fn block(&mut self, scope: &mut FnScope, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            self.stmt(scope, s)?;
        }
        Ok(())
    }

    fn stmt(&mut self, scope: &mut FnScope, s: &Stmt) -> Result<()> {
        match s {
            Stmt::Assign(name, e) => {
                let m = scope.mark();
                let t = self.expr(scope, e)?;
                if scope.is_global(name) {
                    let g = self.global_map[name.as_str()];
                    self.emit(Op::StoreGlobal { idx: g, src: t });
                } else {
                    let slot = scope.slots[name.as_str()];
                    self.emit(Op::Move { dst: slot, src: t });
                }
                scope.reset(m);
            }
            Stmt::IndexAssign(name, idx, e) => {
                // Interpreter order: index expression, numeric
                // conversion, value expression, *then* the name lookup
                // and the list-type/bounds checks.
                let m = scope.mark();
                let t0 = self.expr(scope, idx)?;
                let ti = scope.alloc()?;
                self.emit(Op::AsNum { dst: ti, src: t0 });
                let tv = self.expr(scope, e)?;
                let txs = self.load_var(scope, name)?;
                self.emit(Op::IndexSet { xs: txs, idx: ti, src: tv });
                scope.reset(m);
            }
            Stmt::Expr(e) => {
                let m = scope.mark();
                self.expr(scope, e)?;
                scope.reset(m);
            }
            Stmt::If { arms, else_body } => {
                let mut end_jumps = Vec::new();
                for (cond, body) in arms {
                    let m = scope.mark();
                    let t = self.expr(scope, cond)?;
                    let jf = self.emit(Op::JmpIfFalse { cond: t, to: 0 });
                    scope.reset(m);
                    self.block(scope, body)?;
                    end_jumps.push(self.emit(Op::Jmp(0)));
                    self.patch_here(jf);
                }
                self.block(scope, else_body)?;
                for j in end_jumps {
                    self.patch_here(j);
                }
            }
            Stmt::While(cond, body) => {
                let l_cond = self.here();
                let m = scope.mark();
                let t = self.expr(scope, cond)?;
                let jf = self.emit(Op::JmpIfFalse { cond: t, to: 0 });
                scope.reset(m);
                scope.loops.push(LoopCtx {
                    break_patches: Vec::new(),
                    continue_patches: Vec::new(),
                    continue_to: Some(l_cond),
                });
                self.block(scope, body)?;
                let ctx = scope.loops.pop().expect("loop context pushed above");
                self.emit(Op::Jmp(l_cond));
                self.patch_here(jf);
                for b in ctx.break_patches {
                    self.patch_here(b);
                }
            }
            Stmt::For(var, start, stop, body) => {
                // The loop counter is a hidden f64 (the tree-walk never
                // reads it back from the variable), kept in a register
                // that outlives the body alongside the bound.
                let m = scope.mark();
                let t_counter = scope.alloc()?;
                let t_stop = scope.alloc()?;
                {
                    let m2 = scope.mark();
                    let t = self.expr(scope, start)?;
                    self.emit(Op::AsNum { dst: t_counter, src: t });
                    scope.reset(m2);
                }
                {
                    let m2 = scope.mark();
                    let t = self.expr(scope, stop)?;
                    self.emit(Op::AsNum { dst: t_stop, src: t });
                    scope.reset(m2);
                }
                let l_cond = self.here();
                let m2 = scope.mark();
                let t = scope.alloc()?;
                self.emit(Op::Lt { dst: t, a: t_counter, b: t_stop });
                let jf = self.emit(Op::JmpIfFalse { cond: t, to: 0 });
                scope.reset(m2);
                let slot = scope.slots[var.as_str()];
                self.emit(Op::Move { dst: slot, src: t_counter });
                scope.loops.push(LoopCtx {
                    break_patches: Vec::new(),
                    continue_patches: Vec::new(),
                    continue_to: None,
                });
                self.block(scope, body)?;
                let ctx = scope.loops.pop().expect("loop context pushed above");
                let l_inc = self.here();
                for c in ctx.continue_patches {
                    self.patch_to(c, l_inc);
                }
                let m2 = scope.mark();
                let t_one = scope.alloc()?;
                let one = self.const_idx(ConstKey::Num(1.0f64.to_bits()))?;
                self.emit(Op::Const { dst: t_one, idx: one });
                self.emit(Op::Add { dst: t_counter, a: t_counter, b: t_one });
                scope.reset(m2);
                self.emit(Op::Jmp(l_cond));
                self.patch_here(jf);
                for b in ctx.break_patches {
                    self.patch_here(b);
                }
                scope.reset(m);
            }
            Stmt::Return(e) => {
                if scope.top {
                    // The tree-walk evaluates the expression, *then*
                    // rejects the flow — keep the side effects.
                    let m = scope.mark();
                    if let Some(e) = e {
                        self.expr(scope, e)?;
                    }
                    self.emit_trap("break/continue/return at top level")?;
                    scope.reset(m);
                } else {
                    match e {
                        Some(e) => {
                            let m = scope.mark();
                            let t = self.expr(scope, e)?;
                            self.emit(Op::Return { src: t });
                            scope.reset(m);
                        }
                        None => {
                            self.emit(Op::ReturnNone);
                        }
                    }
                }
            }
            Stmt::Break => {
                if let Some(ctx) = scope.loops.last_mut() {
                    let j = self.code.len();
                    self.code.push(Op::Jmp(0));
                    ctx.break_patches.push(j);
                } else if scope.top {
                    self.emit_trap("break/continue/return at top level")?;
                } else {
                    // Unwound silently to the caller, like the
                    // tree-walk's `call()` ignoring stray flow.
                    self.emit(Op::ReturnNone);
                }
            }
            Stmt::Continue => {
                if let Some(ctx) = scope.loops.last_mut() {
                    match ctx.continue_to {
                        Some(to) => {
                            self.emit(Op::Jmp(to));
                        }
                        None => {
                            let j = self.code.len();
                            self.code.push(Op::Jmp(0));
                            ctx.continue_patches.push(j);
                        }
                    }
                } else if scope.top {
                    self.emit_trap("break/continue/return at top level")?;
                } else {
                    self.emit(Op::ReturnNone);
                }
            }
            Stmt::Global(_) => {} // hoisted in the scope-analysis pass
        }
        Ok(())
    }

    // ---------------------------------------------------- expressions

    /// Emit a [`Op::LoadVar`] for `name` into a fresh temp.
    fn load_var(&mut self, scope: &mut FnScope, name: &str) -> Result<u16> {
        let dst = scope.alloc()?;
        let slot = scope.slots.get(name).copied().unwrap_or(NO_REG);
        let global = self.global_map.get(name).copied().unwrap_or(NO_REG);
        let n = self.string_idx(name)?;
        self.emit(Op::LoadVar { dst, slot, global, name: n });
        Ok(dst)
    }

    /// Compile an expression; returns the register holding the result.
    fn expr(&mut self, scope: &mut FnScope, e: &Expr) -> Result<u16> {
        match e {
            Expr::Num(v) => {
                let dst = scope.alloc()?;
                let idx = self.const_idx(ConstKey::Num(v.to_bits()))?;
                self.emit(Op::Const { dst, idx });
                Ok(dst)
            }
            Expr::Bool(b) => {
                let dst = scope.alloc()?;
                let idx = self.const_idx(ConstKey::Bool(*b))?;
                self.emit(Op::Const { dst, idx });
                Ok(dst)
            }
            Expr::Str(s) => {
                let dst = scope.alloc()?;
                let idx = self.const_idx(ConstKey::Str(s.clone()))?;
                self.emit(Op::Const { dst, idx });
                Ok(dst)
            }
            Expr::None_ => {
                let dst = scope.alloc()?;
                let idx = self.const_idx(ConstKey::None)?;
                self.emit(Op::Const { dst, idx });
                Ok(dst)
            }
            Expr::Var(name) => self.load_var(scope, name),
            Expr::List(items) => {
                if items.len() >= NO_REG as usize {
                    return Err(CairlError::Script("script too large: list literal".into()));
                }
                let dst = scope.alloc()?;
                let start = scope.mark();
                for _ in items {
                    scope.alloc()?;
                }
                for (i, item) in items.iter().enumerate() {
                    let m = scope.mark();
                    let t = self.expr(scope, item)?;
                    self.emit(Op::Move { dst: start + i as u16, src: t });
                    scope.reset(m);
                }
                self.emit(Op::MakeList { dst, start, n: items.len() as u16 });
                scope.reset(start);
                Ok(dst)
            }
            Expr::Index(target, idx) => {
                let dst = scope.alloc()?;
                let m = scope.mark();
                let t_xs = self.expr(scope, target)?;
                let t_i = self.expr(scope, idx)?;
                self.emit(Op::IndexGet { dst, xs: t_xs, idx: t_i });
                scope.reset(m);
                Ok(dst)
            }
            Expr::Un(op, inner) => {
                let dst = scope.alloc()?;
                let m = scope.mark();
                let src = self.expr(scope, inner)?;
                match op {
                    UnOp::Neg => self.emit(Op::Neg { dst, src }),
                    UnOp::Not => self.emit(Op::Not { dst, src }),
                };
                scope.reset(m);
                Ok(dst)
            }
            Expr::Bin(BinOp::And, lhs, rhs) => {
                let dst = scope.alloc()?;
                let m = scope.mark();
                let tl = self.expr(scope, lhs)?;
                let jf = self.emit(Op::JmpIfFalse { cond: tl, to: 0 });
                scope.reset(m);
                let tr = self.expr(scope, rhs)?;
                self.emit(Op::Truthy { dst, src: tr });
                let j_end = self.emit(Op::Jmp(0));
                self.patch_here(jf);
                let f = self.const_idx(ConstKey::Bool(false))?;
                self.emit(Op::Const { dst, idx: f });
                self.patch_here(j_end);
                scope.reset(m);
                Ok(dst)
            }
            Expr::Bin(BinOp::Or, lhs, rhs) => {
                let dst = scope.alloc()?;
                let m = scope.mark();
                let tl = self.expr(scope, lhs)?;
                let jt = self.emit(Op::JmpIfTrue { cond: tl, to: 0 });
                scope.reset(m);
                let tr = self.expr(scope, rhs)?;
                self.emit(Op::Truthy { dst, src: tr });
                let j_end = self.emit(Op::Jmp(0));
                self.patch_here(jt);
                let t = self.const_idx(ConstKey::Bool(true))?;
                self.emit(Op::Const { dst, idx: t });
                self.patch_here(j_end);
                scope.reset(m);
                Ok(dst)
            }
            Expr::Bin(op, lhs, rhs) => {
                let dst = scope.alloc()?;
                let m = scope.mark();
                let a = self.expr(scope, lhs)?;
                let b = self.expr(scope, rhs)?;
                let op = match op {
                    BinOp::Add => Op::Add { dst, a, b },
                    BinOp::Sub => Op::Sub { dst, a, b },
                    BinOp::Mul => Op::Mul { dst, a, b },
                    BinOp::Div => Op::Div { dst, a, b },
                    BinOp::Mod => Op::Mod { dst, a, b },
                    BinOp::Eq => Op::Eq { dst, a, b },
                    BinOp::Ne => Op::Ne { dst, a, b },
                    BinOp::Lt => Op::Lt { dst, a, b },
                    BinOp::Le => Op::Le { dst, a, b },
                    BinOp::Gt => Op::Gt { dst, a, b },
                    BinOp::Ge => Op::Ge { dst, a, b },
                    BinOp::And | BinOp::Or => unreachable!("handled above"),
                };
                self.emit(op);
                scope.reset(m);
                Ok(dst)
            }
            Expr::Call(name, args) => {
                if args.len() >= NO_REG as usize {
                    return Err(CairlError::Script("script too large: call arity".into()));
                }
                let dst = scope.alloc()?;
                let m = scope.mark();
                let start = scope.mark();
                for _ in args {
                    scope.alloc()?;
                }
                for (i, arg) in args.iter().enumerate() {
                    let m2 = scope.mark();
                    let t = self.expr(scope, arg)?;
                    self.emit(Op::Move { dst: start + i as u16, src: t });
                    scope.reset(m2);
                }
                let argc = args.len() as u16;
                // Resolution order mirrors `call_any`: builtins by
                // (name, arity) first, then user functions; failures
                // trap *after* the argument code so they fire exactly
                // when the tree-walk's runtime lookup would.
                if let Some(builtin) = Builtin::resolve(name, args.len()) {
                    self.emit(Op::CallBuiltin { dst, builtin, start, argc });
                } else if let Some(&fi) = self.func_map.get(name.as_str()) {
                    let n_params = self.funcs[fi as usize].n_params;
                    if n_params == argc {
                        self.emit(Op::CallFn { dst, func: fi, start, argc });
                    } else {
                        let msg =
                            format!("{name}() takes {n_params} args, got {argc}");
                        self.emit_trap(&msg)?;
                    }
                } else {
                    self.emit_trap(&format!("no function {name:?}"))?;
                }
                scope.reset(m);
                Ok(dst)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ops(src: &str) -> Vec<Op> {
        compile_src(src).unwrap().code
    }

    #[test]
    fn straight_line_compiles_to_flat_code() {
        let p = compile_src("x = 1 + 2;").unwrap();
        assert_eq!(p.global_names, vec!["x".to_string()]);
        assert_eq!(p.top_entry, 0);
        // Const, Const, Add, StoreGlobal, ReturnNone.
        assert_eq!(p.code.len(), 5);
        assert!(matches!(p.code[3], Op::StoreGlobal { idx: 0, .. }));
    }

    #[test]
    fn constants_are_deduplicated() {
        let p = compile_src("x = 1; y = 1; z = 1;").unwrap();
        let nums = p
            .consts
            .iter()
            .filter(|v| matches!(v, Value::Num(_)))
            .count();
        assert_eq!(nums, 1);
    }

    #[test]
    fn function_table_records_arity_and_entry() {
        let p = compile_src("def f(a, b) { return a + b; } def g() { return f(1, 2); }")
            .unwrap();
        assert_eq!(p.funcs.len(), 2);
        let f = &p.funcs[p.func_map["f"] as usize];
        assert_eq!(f.n_params, 2);
        assert!(f.n_regs >= 2);
        assert!(f.entry > 0, "top-level code compiles first");
    }

    #[test]
    fn duplicate_function_defs_resolve_to_the_last() {
        let p = compile_src("def f() { return 1; } def f() { return 2; }").unwrap();
        assert_eq!(p.funcs.len(), 2);
        assert_eq!(p.func_map["f"], 1);
    }

    #[test]
    fn unknown_call_compiles_to_a_lazy_trap() {
        // Compiles fine; the trap only fires if executed (parity with
        // the tree-walk's runtime lookup).
        let code = ops("def f() { return nope(); }");
        assert!(code.iter().any(|op| matches!(op, Op::Trap { .. })));
    }

    #[test]
    fn arity_mismatch_compiles_to_a_lazy_trap() {
        let p = compile_src("def f(a) { return a; } def g() { return f(1, 2); }").unwrap();
        let has_trap = p.code.iter().any(|op| matches!(op, Op::Trap { .. }));
        assert!(has_trap);
        assert!(p.strings.iter().any(|s| s == "f() takes 1 args, got 2"));
    }

    #[test]
    fn short_circuit_compiles_to_jumps() {
        let code = ops("def f(x) { return x != 0 and 1 / x > 0; }");
        assert!(code.iter().any(|op| matches!(op, Op::JmpIfFalse { .. })));
    }

    #[test]
    fn global_decls_select_store_global() {
        let p = compile_src("c = 0; def bump() { global c; c = c + 1; }").unwrap();
        let stores = p
            .code
            .iter()
            .filter(|op| matches!(op, Op::StoreGlobal { .. }))
            .count();
        assert_eq!(stores, 2, "top-level init + the function body");
    }

    #[test]
    fn builtin_resolution_is_arity_sensitive() {
        assert_eq!(Builtin::resolve("min", 2), Some(Builtin::Min));
        assert_eq!(Builtin::resolve("min", 3), None);
        assert_eq!(Builtin::resolve("pi", 0), Some(Builtin::Pi));
        assert_eq!(Builtin::resolve("nope", 1), None);
    }

    #[test]
    fn shipped_sources_compile() {
        use crate::script::envs;
        for src in [
            envs::CARTPOLE_SRC,
            envs::MOUNTAINCAR_SRC,
            envs::ACROBOT_SRC,
            envs::PENDULUM_SRC,
        ] {
            let p = compile_src(src).unwrap();
            assert!(p.func_map.contains_key("reset"));
            assert!(p.func_map.contains_key("step"));
        }
    }

    #[test]
    fn parse_errors_pass_through() {
        assert!(compile_src("def f( {").is_err());
    }
}
