//! The interpreted-script runner — CaiRL's "Python environment" path and
//! the experiments' AI-Gym baseline surrogate.
//!
//! The paper benchmarks compiled (C++) environments against the same
//! dynamics running under CPython.  This image has the environments in
//! Rust; to reproduce the *interpreted dynamic language vs compiled
//! native* comparison (Fig. 1, Fig. 2, Table II) without shipping
//! CPython, this module implements **MiniScript**: a small dynamic
//! language executed by a deliberately conventional tree-walking
//! interpreter with
//!
//! * boxed dynamic values ([`interp::Value`]) — every number is
//!   heap-semantics tagged data, like CPython's `PyObject*`,
//! * string-keyed hash-map variable lookup on every access — like
//!   CPython's `LOAD_NAME`/`LOAD_GLOBAL` dict probes,
//! * dynamic operator dispatch with run-time type checks — like
//!   CPython's `BINARY_OP` protocol,
//! * per-call environment allocation — like CPython frames.
//!
//! These are the overhead classes Zehra et al. [24] and Zhang et al. [16]
//! attribute Python's ~50x slowdown to; DESIGN.md §Substitutions states
//! the calibration argument.  The four classic-control environments are
//! re-implemented as MiniScript programs ([`envs`]) running behind the
//! standard [`Env`](crate::core::env::Env) trait, so every benchmark and
//! agent runs unchanged on either runner — the paper's "unified API
//! across run-times" (§III-A).
//!
//! MiniScript math is f64 (like Python floats) while the native envs use
//! f32; the cross-runner tests therefore compare trajectories with a
//! tolerance over bounded horizons.
//!
//! Next to the calibrated baseline sits the **bytecode pipeline**: the
//! same AST lowers to a compact register bytecode ([`compile`]) executed
//! by a Flash-VM-style virtual machine ([`vm`]) that replays the
//! tree-walk observably — identical arithmetic, RNG draw order and
//! error messages (pinned by `rust/tests/script_vm.rs`) — at a fraction
//! of the dispatch cost (`ablation_dispatch` measures the ratio).  The
//! batch half ([`batch::ScriptBatch`]) steps N lanes' global columns
//! under one shared program, which is what makes `Script/*` registry
//! ids `batch_capable` and lets them fuse into executor lane groups
//! like the classic-control envs.  The tree-walk stays the *scalar*
//! registry path (it is the measured Fig.-1/2 baseline); the bytecode
//! VM serves the fused path and the compiled-vs-interpreted ablation.

pub mod ast;
pub mod batch;
pub mod compile;
pub mod envs;
pub mod interp;
pub mod lexer;
pub mod parser;
pub mod vm;

pub use batch::ScriptBatch;
pub use compile::CompiledProgram;
pub use envs::ScriptEnv;
pub use interp::{Interpreter, Value};
pub use vm::{CompiledScriptEnv, Vm};
